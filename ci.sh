#!/usr/bin/env bash
# One-command verification harness: the tier-1 gate (which runs all
# unit + integration suites, incl. kernel_equivalence and
# serve_determinism) plus compile checks for every bench and example.
#
#   ./ci.sh          # full gate
#   ./ci.sh --fast   # tier-1 only (build + tests)
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" == "--fast" ]]; then
    echo "ci: tier-1 green (fast mode)"
    exit 0
fi

# Docs rot gate: module-level rustdoc is part of this repo's contract
# (serve/runtime/linear invariants are documented where the code
# lives), so broken intra-doc links or malformed docs fail CI. Scoped
# to the spectra crate: the vendored stand-ins are not a doc surface.
echo "== rustdoc gate (cargo doc --no-deps, warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet -p spectra

echo "== compile examples =="
cargo build --release --examples

# Bench harness rot gate: `cargo bench --no-run` builds every bench in
# the bench profile (serve_throughput.rs in particular), so the
# harness cannot silently stop compiling between perf runs. This
# replaces the old `cargo build --benches` step — building the benches
# in both profiles would just compile everything twice.
echo "== bench harness builds (cargo bench --no-run) =="
cargo bench --no-run

# Cross-family runtime smoke: tiny dims, all four serving families
# through the (pooled) scheduler — catches runtime panics (ragged
# groups, kernel tails, family builders, pool dispatch), not just
# compile errors. --json makes serve-bench write the machine-readable
# result and re-parse it, so a malformed BENCH file fails this step.
echo "== cross-family serve smoke (+ --json parse check) =="
cargo run --release --quiet -- serve-bench \
    --family float,quant3,quant4,ternary \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 4 --max-tokens 4 --batches 1,2 --threads 1 \
    --json runs/BENCH_serve_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 -m json.tool runs/BENCH_serve_smoke.json >/dev/null
    echo "runs/BENCH_serve_smoke.json: valid json (python3 cross-check)"
fi

# Attention serve smoke: the paged KV-cache decode model at tiny dims,
# all four families through the same scheduler — catches paging/
# admission/attention runtime panics and checks the schema-2 JSON
# (kv_bytes_per_token) re-parses.
echo "== paged kv-cache attention serve smoke (--attn) =="
cargo run --release --quiet -- serve-bench \
    --family float,quant3,quant4,ternary --attn --heads 4 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 4 --max-tokens 4 --batches 1,2 --threads 1 \
    --json runs/BENCH_serve_attn_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 -m json.tool runs/BENCH_serve_attn_smoke.json >/dev/null
    echo "runs/BENCH_serve_attn_smoke.json: valid json (python3 cross-check)"
fi

# Chunked-prefill + KV-backpressure smoke: long prompts (--prompt-tokens)
# ingested in chunks (--prefill-chunk) on the paged attention model,
# with the cache deliberately undersized (--kv-context 12 < prompt +
# max-tokens at 4 lanes) so admission defers and mid-flight lanes
# requeue — pre-fix this panicked in bind_and_begin. The schema-3 JSON
# (prefill_tokens_per_sec, ttft_steps, prefill_chunk, requeued) is
# parse-checked like the other BENCH smokes.
echo "== chunked prefill + kv-backpressure serve smoke =="
cargo run --release --quiet -- serve-bench \
    --family float,ternary --attn --heads 4 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 6 --max-tokens 4 --batches 1,4 --threads 1 \
    --prefill-chunk 4 --prompt-tokens 24 --kv-context 12 \
    --json runs/BENCH_serve_chunked_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 -m json.tool runs/BENCH_serve_chunked_smoke.json >/dev/null
    echo "runs/BENCH_serve_chunked_smoke.json: valid json (python3 cross-check)"
fi

# Shared-prefix + copy-on-write smoke: requests sharing a 20-token
# prefix (--shared-prefix-tokens) on the paged attention model with the
# cache again undersized (--kv-context 12), so prefix pins, CoW
# divergence, KV backpressure and the evict-pins-before-requeue path
# all run together — pre-fix, pinned pages under pressure tripped the
# scheduler's stall/sizing panics. The schema-7 JSON must re-parse and
# actually record prefix reuse: a run that silently never hits the
# prefix cache fails this step. The server-side counters
# (queue_depth_max / rejected_429 / rejected_413, and the robustness
# trio cancelled / deadline_expired / worker_restarts) must be present
# and zero on this socketless path — the HTTP smokes below are where
# they move — and so must the schema-7 speculative counters, which
# only move under --speculative (the dedicated smoke below).
echo "== shared-prefix + copy-on-write serve smoke =="
cargo run --release --quiet -- serve-bench \
    --family float,ternary --attn --heads 4 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 6 --max-tokens 4 --batches 1,4 --threads 1 \
    --prefill-chunk 4 --prompt-tokens 24 --shared-prefix-tokens 20 \
    --kv-context 12 \
    --json runs/BENCH_serve_prefix_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 - runs/BENCH_serve_prefix_smoke.json <<'PYEOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == 8, f"schema {doc['schema']} != 8"
assert doc["shared_prefix_tokens"] == 20, doc["shared_prefix_tokens"]
assert doc["speculative"] == 0 and doc["spec_k"] == 0, doc
hits = sum(f["prefix_hits"] for f in doc["families"])
reused = sum(f["prefix_tokens_reused"] for f in doc["families"])
assert hits > 0, "no serve-bench run ever hit the prefix cache"
assert reused >= hits, f"{hits} hits reused only {reused} tokens"
for fam in doc["families"]:
    for key in ("queue_depth_max", "rejected_429", "rejected_413",
                "cancelled", "deadline_expired", "worker_restarts"):
        assert fam[key] == 0, f"{fam['family']}: {key} != 0 off-HTTP"
    for key in ("spec_proposed", "spec_accepted", "spec_verify_steps",
                "accepted_per_step"):
        assert fam[key] == 0, \
            f"{fam['family']}: {key} != 0 without --speculative"
print(f"runs/BENCH_serve_prefix_smoke.json: schema 8, "
      f"{hits} prefix hits, {reused} tokens reused")
PYEOF
fi

# Speculative decoding smoke: TriLM drafts for a float, a 4-bit GPTQ,
# and a ternary target through the draft-verify lane (--speculative).
# Catches propose/verify/rollback runtime panics across families and
# checks the schema-7 speculative counters actually move: proposals
# and acceptances must be nonzero, accepted/step must sit in [0, k],
# and the ternary target — drafted by a bitwise-identical ternary
# model — must accept *every* proposal (the identical-draft invariant,
# end to end at the CLI).
echo "== speculative decoding serve smoke (--speculative) =="
cargo run --release --quiet -- serve-bench \
    --family float,quant4,ternary --attn --heads 4 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 4 --max-tokens 4 --batches 1,2 --threads 1 \
    --speculative --draft-family ternary --spec-k 3 \
    --json runs/BENCH_serve_spec_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 - runs/BENCH_serve_spec_smoke.json <<'PYEOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == 8, f"schema {doc['schema']} != 8"
assert doc["speculative"] == 1, doc
assert doc["draft_family"] == "ternary", doc["draft_family"]
assert doc["spec_k"] == 3, doc["spec_k"]
proposed = sum(f["spec_proposed"] for f in doc["families"])
accepted = sum(f["spec_accepted"] for f in doc["families"])
assert proposed > 0, "no serve-bench run ever proposed a draft token"
assert 0 < accepted <= proposed, f"{accepted} accepted of {proposed}"
for fam in doc["families"]:
    assert fam["spec_verify_steps"] > 0, f"{fam['family']}: no verify"
    assert 0.0 <= fam["accepted_per_step"] <= doc["spec_k"], \
        f"{fam['family']}: accepted/step {fam['accepted_per_step']}"
tern = next(f for f in doc["families"] if f["family"] == "TriLM")
assert tern["spec_accepted"] == tern["spec_proposed"], \
    "a bitwise-identical ternary draft must be fully accepted"
print(f"runs/BENCH_serve_spec_smoke.json: schema 8, "
      f"{accepted}/{proposed} draft tokens accepted")
PYEOF
fi

# GQA + sliding-window smoke: grouped-query attention at the extreme
# ratio (--kv-heads 1 = multi-query) with a finite --window on the
# undersized cache from the chunked smoke, so window page recycling,
# GQA attend, chunked prefill, and KV backpressure all run in one
# sweep. The schema-8 JSON must record the new geometry and the
# per-family kv_bytes_per_token must equal the head-ratio-shrunk
# layout (2 * layers * (hidden/heads) * kv_heads * 4), i.e. 1/4 of
# the MHA figure at 4 heads.
echo "== gqa + sliding-window serve smoke (--kv-heads --window) =="
cargo run --release --quiet -- serve-bench \
    --family float,ternary --attn --heads 4 --kv-heads 1 --window 8 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 6 --max-tokens 4 --batches 1,4 --threads 1 \
    --prefill-chunk 4 --prompt-tokens 24 --kv-context 12 \
    --json runs/BENCH_serve_gqa_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 - runs/BENCH_serve_gqa_smoke.json <<'PYEOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == 8, f"schema {doc['schema']} != 8"
assert doc["kv_heads"] == 1, doc["kv_heads"]
assert doc["window"] == 8, doc["window"]
assert doc["window_interleave"] == 0, doc["window_interleave"]
layers, hidden, heads = (doc["dims"]["layers"], doc["dims"]["hidden"],
                         doc["heads"])
want = 2 * layers * (hidden // heads) * doc["kv_heads"] * 4
for fam in doc["families"]:
    assert fam["kv_bytes_per_token"] == want, \
        f"{fam['family']}: kv_bytes_per_token {fam['kv_bytes_per_token']} " \
        f"!= head-ratio-shrunk {want}"
print(f"runs/BENCH_serve_gqa_smoke.json: schema 8, kv_heads 1, "
      f"window 8, kv_bytes_per_token {want} (vs "
      f"{2 * layers * hidden * 4} MHA)")
PYEOF
fi

# HTTP serving smoke: `spectra serve` on an ephemeral port, sized to
# choke — 1 shard, 1 lane, a cap-1 admission queue, and a KV context an
# over-context probe must overflow. Concurrent /generate bursts must
# produce at least one 429 (and at least one admitted stream), the
# over-context probe must 413, /stats must parse cleanly, and POST
# /shutdown must drain with zero leaked KV pages (`spectra serve`
# itself exits non-zero on a leak, so the exit code is the leak check).
echo "== http serving smoke (spectra serve) =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json, re, socket, subprocess, threading

proc = subprocess.Popen(
    ["target/release/spectra", "serve",
     "--port", "0", "--shards", "1", "--lanes", "1", "--threads", "1",
     "--queue-cap", "1", "--kv-context", "210", "--prefill-chunk", "4",
     "--attn", "--heads", "4", "--family", "ternary",
     "--vocab", "64", "--hidden", "32", "--glu", "48", "--layers", "2",
     "--mp", "1"],
    stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
try:
    port = None
    for _ in range(50):
        line = proc.stdout.readline()
        m = re.search(r"listening on 127\.0\.0\.1:(\d+)", line)
        if m:
            port = int(m.group(1))
            break
    assert port, "spectra serve never reported its address"

    def raw(method, path, body=b"", read_body=True):
        s = socket.create_connection(("127.0.0.1", port), timeout=60)
        head = (f"{method} {path} HTTP/1.1\r\nHost: smoke\r\n"
                f"Connection: close\r\nContent-Length: {len(body)}\r\n\r\n")
        s.sendall(head.encode() + body)
        f = s.makefile("rb")
        status = int(f.readline().split()[1])
        payload = b""
        if read_body:
            rest = f.read()
            payload = rest.split(b"\r\n\r\n", 1)[1] if b"\r\n\r\n" in rest \
                      else b""
        s.close()
        return status, payload

    # Concurrent burst: 6 threads x 6 requests of 200 decode steps
    # each against a single lane and a cap-1 queue. Arrivals land
    # within milliseconds of each other while each admitted request
    # holds the lane far longer, so the queue must overflow. Probes
    # hang up after the status line; the server drains those lanes
    # regardless (a client disconnect never leaks pages).
    statuses, lock = [], threading.Lock()
    def probe():
        for _ in range(6):
            st, _ = raw("POST", "/generate",
                        b'{"prompt":[5,9],"max_new_tokens":200,'
                        b'"tenant":"smoke"}', read_body=False)
            with lock:
                statuses.append(st)
    threads = [threading.Thread(target=probe) for _ in range(6)]
    for t in threads: t.start()
    for t in threads: t.join()
    assert statuses.count(200) >= 1, f"nothing admitted: {statuses}"
    assert statuses.count(429) >= 1, f"no 429 under load: {statuses}"
    assert set(statuses) <= {200, 429}, f"unexpected statuses: {statuses}"

    st, _ = raw("POST", "/generate",
                b'{"prompt":[1,2],"max_new_tokens":5000,"tenant":"big"}')
    assert st == 413, f"over-context request got {st}, want 413"

    st, body = raw("GET", "/stats")
    assert st == 200
    doc = json.loads(body)
    assert doc["rejected_429"] == statuses.count(429), doc
    assert doc["rejected_413"] == 1, doc
    assert doc["queue_depth_max"] >= 1, doc
    tenants = {t["tenant"]: t for t in doc["tenants"]}
    assert tenants["smoke"]["rejected"] == statuses.count(429), tenants

    st, _ = raw("POST", "/shutdown")
    assert st == 200
    out, _ = proc.communicate(timeout=300)
    assert proc.returncode == 0, f"serve exited {proc.returncode}:\n{out}"
    assert "0 kv pages leaked" in out, out
    print(f"spectra serve smoke: {statuses.count(200)}x200 + "
          f"{statuses.count(429)}x429, /stats parse clean, shutdown clean")
finally:
    if proc.poll() is None:
        proc.kill()
PYEOF
fi

# Windowed GQA serving smoke: `spectra serve` with multi-query
# attention (--kv-heads 1) and a sliding window far below the decode
# length, on a KV context sized to exactly the largest admissible
# request (undersized in absolute terms: 42 tokens, 3 pages/lane).
# Each stream decodes 40 tokens through a window of 8, so
# release_before recycles out-of-window pages dozens of times while
# requests queue behind the single lane; a refcount bug anywhere in
# that path surfaces as the leak check failing. /stats must parse and
# carry the schema's new spec_k_effective gauge (0 — not speculative),
# and POST /shutdown must drain with zero leaked KV pages (`spectra
# serve` exits non-zero on a leak, so the exit code is the leak check).
echo "== windowed gqa serving smoke (spectra serve --kv-heads --window) =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json, re, socket, subprocess, threading

proc = subprocess.Popen(
    ["target/release/spectra", "serve",
     "--port", "0", "--shards", "1", "--lanes", "1", "--threads", "1",
     "--queue-cap", "4", "--kv-context", "42", "--prefill-chunk", "4",
     "--attn", "--heads", "4", "--kv-heads", "1", "--window", "8",
     "--family", "ternary",
     "--vocab", "64", "--hidden", "32", "--glu", "48", "--layers", "2",
     "--mp", "1"],
    stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
try:
    port = None
    for _ in range(50):
        line = proc.stdout.readline()
        m = re.search(r"listening on 127\.0\.0\.1:(\d+)", line)
        if m:
            port = int(m.group(1))
            break
    assert port, "spectra serve never reported its address"

    def raw(method, path, body=b""):
        s = socket.create_connection(("127.0.0.1", port), timeout=120)
        head = (f"{method} {path} HTTP/1.1\r\nHost: smoke\r\n"
                f"Connection: close\r\nContent-Length: {len(body)}\r\n\r\n")
        s.sendall(head.encode() + body)
        f = s.makefile("rb")
        status = int(f.readline().split()[1])
        rest = f.read()
        s.close()
        payload = rest.split(b"\r\n\r\n", 1)[1] if b"\r\n\r\n" in rest \
                  else b""
        return status, payload

    # Three concurrent 40-token decodes against one lane: one runs,
    # two queue (cap 4, no 429s expected), every stream must close
    # with a done trailer — the window recycles its pages mid-decode.
    results, lock = [], threading.Lock()
    def stream():
        st, payload = raw("POST", "/generate",
                          b'{"prompt":[5,9],"max_new_tokens":40,'
                          b'"tenant":"windowed"}')
        with lock:
            results.append((st, payload))
    threads = [threading.Thread(target=stream) for _ in range(3)]
    for t in threads: t.start()
    for t in threads: t.join()
    for st, payload in results:
        assert st == 200, f"stream not admitted: {st}"
        assert b'"done"' in payload and b'"finish_reason"' in payload, \
            "windowed stream never reached its done trailer"

    st, body = raw("GET", "/stats")
    assert st == 200
    doc = json.loads(body)
    assert doc["served"] == 3, doc
    assert doc["spec_k_effective"] == 0, \
        f"spec_k_effective must be 0 off the speculative path: {doc}"
    assert doc["kv_pages"] >= 0, doc

    st, _ = raw("POST", "/shutdown")
    assert st == 200
    out, _ = proc.communicate(timeout=300)
    assert proc.returncode == 0, f"serve exited {proc.returncode}:\n{out}"
    assert "0 kv pages leaked" in out, out
    print("windowed gqa serve smoke: 3 streams through a window-8 "
          "multi-query lane, /stats parse clean, shutdown clean")
finally:
    if proc.poll() is None:
        proc.kill()
PYEOF
fi

# Chaos smoke: `spectra serve` under deliberate abuse — clients that
# hang up mid-stream (RST on close, so the relay's chunk write fails
# and cancels the lane) on BOTH shards, plus one fault-plan panic
# injected into shard 0's worker (--fault-panic-step). The server must
# keep answering: /stats shows cancelled > 0 and worker_restarts >= 1,
# a fresh request completes on each shard afterwards (shard 1 never
# died; shard 0 was rebuilt by its supervisor), and POST /shutdown
# still drains with zero leaked KV pages — `spectra serve` exits
# non-zero on a leak, so the exit code is the leak check.
echo "== chaos smoke (mid-stream disconnects + injected worker panic) =="
if command -v python3 >/dev/null 2>&1; then
    python3 - <<'PYEOF'
import json, re, socket, struct, subprocess, time

proc = subprocess.Popen(
    ["target/release/spectra", "serve",
     "--port", "0", "--shards", "2", "--lanes", "2", "--threads", "1",
     "--queue-cap", "8", "--kv-context", "420", "--prefill-chunk", "4",
     "--attn", "--heads", "4", "--family", "ternary",
     "--vocab", "64", "--hidden", "32", "--glu", "48", "--layers", "2",
     "--mp", "1", "--fault-panic-step", "3"],
    stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
try:
    port = None
    for _ in range(50):
        line = proc.stdout.readline()
        m = re.search(r"listening on 127\.0\.0\.1:(\d+)", line)
        if m:
            port = int(m.group(1))
            break
    assert port, "spectra serve never reported its address"

    def shard_of(prompt, shards):
        # Mirror of shard_for_prompt: FNV-1a over the first KV page
        # (16 tokens) of little-endian u32s.
        h = 0xcbf29ce484222325
        for t in prompt[:16]:
            for b in t.to_bytes(4, "little"):
                h ^= b
                h = (h * 0x100000001b3) % (1 << 64)
        return h % shards

    # One deterministic prompt per shard (distinct first tokens).
    prompt_on = {}
    for i in range(1, 200):
        prompt_on.setdefault(shard_of([i, 9], 2), [i, 9])
        if len(prompt_on) == 2:
            break
    assert set(prompt_on) == {0, 1}, prompt_on

    def gen_body(prompt, max_new):
        return (f'{{"prompt":{list(prompt)},"max_new_tokens":{max_new},'
                f'"tenant":"chaos"}}').encode()

    def disconnect_mid_stream(prompt):
        # Start a long stream, read the head + first chunk (so the
        # request provably holds a lane), then close with SO_LINGER 0:
        # the RST makes the server's next chunk write fail, which is
        # exactly what the relay's cancel path keys on.
        s = socket.create_connection(("127.0.0.1", port), timeout=60)
        body = gen_body(prompt, 400)
        s.sendall((f"POST /generate HTTP/1.1\r\nHost: chaos\r\n"
                   f"Connection: close\r\nContent-Length: {len(body)}"
                   f"\r\n\r\n").encode() + body)
        f = s.makefile("rb")
        status = int(f.readline().split()[1])
        assert status == 200, f"disconnect client not admitted: {status}"
        while f.readline() not in (b"\r\n", b""):
            pass  # headers
        assert f.readline().strip(), "first chunk size line"
        s.setsockopt(socket.SOL_SOCKET, socket.SO_LINGER,
                     struct.pack("ii", 1, 0))
        s.close()

    # Two hang-ups per shard. Shard 0's worker also panics after its
    # third step (fault plan) — in-flight lanes there die with the
    # incarnation; shard 1's cancels exercise the clean relay path.
    for shard in (0, 1):
        for _ in range(2):
            disconnect_mid_stream(prompt_on[shard])

    def stats():
        s = socket.create_connection(("127.0.0.1", port), timeout=60)
        s.sendall(b"GET /stats HTTP/1.1\r\nHost: chaos\r\n"
                  b"Connection: close\r\nContent-Length: 0\r\n\r\n")
        raw = s.makefile("rb").read()
        s.close()
        return json.loads(raw.split(b"\r\n\r\n", 1)[1])

    deadline = time.time() + 60
    doc = None
    while time.time() < deadline:
        doc = stats()
        if doc["cancelled"] >= 1 and doc["worker_restarts"] >= 1:
            break
        time.sleep(0.2)
    assert doc["cancelled"] >= 1, f"no cancels recorded: {doc}"
    assert doc["worker_restarts"] >= 1, f"no worker restart: {doc}"

    def complete_request(prompt):
        # A fresh request must stream to a done trailer (retry briefly:
        # lanes may still be winding down from the chaos above).
        for _ in range(50):
            s = socket.create_connection(("127.0.0.1", port), timeout=60)
            body = gen_body(prompt, 4)
            s.sendall((f"POST /generate HTTP/1.1\r\nHost: chaos\r\n"
                       f"Connection: close\r\nContent-Length: {len(body)}"
                       f"\r\n\r\n").encode() + body)
            f = s.makefile("rb")
            status = int(f.readline().split()[1])
            payload = f.read()
            s.close()
            if status == 200 and b'"done"' in payload and \
               b'"finish_reason"' in payload:
                return
            time.sleep(0.2)
        raise AssertionError(f"no completed stream on prompt {prompt}")

    complete_request(prompt_on[1])  # the shard that never died
    complete_request(prompt_on[0])  # the shard the supervisor rebuilt

    s = socket.create_connection(("127.0.0.1", port), timeout=60)
    s.sendall(b"POST /shutdown HTTP/1.1\r\nHost: chaos\r\n"
              b"Connection: close\r\nContent-Length: 0\r\n\r\n")
    assert int(s.makefile("rb").readline().split()[1]) == 200
    s.close()
    out, _ = proc.communicate(timeout=300)
    assert proc.returncode == 0, f"serve exited {proc.returncode}:\n{out}"
    assert "0 kv pages leaked" in out, out
    print(f"chaos smoke: cancelled={doc['cancelled']} "
          f"worker_restarts={doc['worker_restarts']}, both shards "
          f"answering, shutdown clean")
finally:
    if proc.poll() is None:
        proc.kill()
PYEOF
fi

echo "ci: all green"
