#!/usr/bin/env bash
# One-command verification harness: the tier-1 gate (which runs all
# unit + integration suites, incl. kernel_equivalence and
# serve_determinism) plus compile checks for every bench and example.
#
#   ./ci.sh          # full gate
#   ./ci.sh --fast   # tier-1 only (build + tests)
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" == "--fast" ]]; then
    echo "ci: tier-1 green (fast mode)"
    exit 0
fi

# Docs rot gate: module-level rustdoc is part of this repo's contract
# (serve/runtime/linear invariants are documented where the code
# lives), so broken intra-doc links or malformed docs fail CI. Scoped
# to the spectra crate: the vendored stand-ins are not a doc surface.
echo "== rustdoc gate (cargo doc --no-deps, warnings are errors) =="
RUSTDOCFLAGS="-D warnings" cargo doc --no-deps --quiet -p spectra

echo "== compile examples =="
cargo build --release --examples

# Bench harness rot gate: `cargo bench --no-run` builds every bench in
# the bench profile (serve_throughput.rs in particular), so the
# harness cannot silently stop compiling between perf runs. This
# replaces the old `cargo build --benches` step — building the benches
# in both profiles would just compile everything twice.
echo "== bench harness builds (cargo bench --no-run) =="
cargo bench --no-run

# Cross-family runtime smoke: tiny dims, all four serving families
# through the (pooled) scheduler — catches runtime panics (ragged
# groups, kernel tails, family builders, pool dispatch), not just
# compile errors. --json makes serve-bench write the machine-readable
# result and re-parse it, so a malformed BENCH file fails this step.
echo "== cross-family serve smoke (+ --json parse check) =="
cargo run --release --quiet -- serve-bench \
    --family float,quant3,quant4,ternary \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 4 --max-tokens 4 --batches 1,2 --threads 1 \
    --json runs/BENCH_serve_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 -m json.tool runs/BENCH_serve_smoke.json >/dev/null
    echo "runs/BENCH_serve_smoke.json: valid json (python3 cross-check)"
fi

# Attention serve smoke: the paged KV-cache decode model at tiny dims,
# all four families through the same scheduler — catches paging/
# admission/attention runtime panics and checks the schema-2 JSON
# (kv_bytes_per_token) re-parses.
echo "== paged kv-cache attention serve smoke (--attn) =="
cargo run --release --quiet -- serve-bench \
    --family float,quant3,quant4,ternary --attn --heads 4 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 4 --max-tokens 4 --batches 1,2 --threads 1 \
    --json runs/BENCH_serve_attn_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 -m json.tool runs/BENCH_serve_attn_smoke.json >/dev/null
    echo "runs/BENCH_serve_attn_smoke.json: valid json (python3 cross-check)"
fi

# Chunked-prefill + KV-backpressure smoke: long prompts (--prompt-tokens)
# ingested in chunks (--prefill-chunk) on the paged attention model,
# with the cache deliberately undersized (--kv-context 12 < prompt +
# max-tokens at 4 lanes) so admission defers and mid-flight lanes
# requeue — pre-fix this panicked in bind_and_begin. The schema-3 JSON
# (prefill_tokens_per_sec, ttft_steps, prefill_chunk, requeued) is
# parse-checked like the other BENCH smokes.
echo "== chunked prefill + kv-backpressure serve smoke =="
cargo run --release --quiet -- serve-bench \
    --family float,ternary --attn --heads 4 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 6 --max-tokens 4 --batches 1,4 --threads 1 \
    --prefill-chunk 4 --prompt-tokens 24 --kv-context 12 \
    --json runs/BENCH_serve_chunked_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 -m json.tool runs/BENCH_serve_chunked_smoke.json >/dev/null
    echo "runs/BENCH_serve_chunked_smoke.json: valid json (python3 cross-check)"
fi

# Shared-prefix + copy-on-write smoke: requests sharing a 20-token
# prefix (--shared-prefix-tokens) on the paged attention model with the
# cache again undersized (--kv-context 12), so prefix pins, CoW
# divergence, KV backpressure and the evict-pins-before-requeue path
# all run together — pre-fix, pinned pages under pressure tripped the
# scheduler's stall/sizing panics. The schema-4 JSON must re-parse and
# actually record prefix reuse: a run that silently never hits the
# prefix cache fails this step.
echo "== shared-prefix + copy-on-write serve smoke =="
cargo run --release --quiet -- serve-bench \
    --family float,ternary --attn --heads 4 \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 6 --max-tokens 4 --batches 1,4 --threads 1 \
    --prefill-chunk 4 --prompt-tokens 24 --shared-prefix-tokens 20 \
    --kv-context 12 \
    --json runs/BENCH_serve_prefix_smoke.json
if command -v python3 >/dev/null 2>&1; then
    python3 - runs/BENCH_serve_prefix_smoke.json <<'PYEOF'
import json, sys
doc = json.load(open(sys.argv[1]))
assert doc["schema"] == 4, f"schema {doc['schema']} != 4"
assert doc["shared_prefix_tokens"] == 20, doc["shared_prefix_tokens"]
hits = sum(f["prefix_hits"] for f in doc["families"])
reused = sum(f["prefix_tokens_reused"] for f in doc["families"])
assert hits > 0, "no serve-bench run ever hit the prefix cache"
assert reused >= hits, f"{hits} hits reused only {reused} tokens"
print(f"runs/BENCH_serve_prefix_smoke.json: schema 4, "
      f"{hits} prefix hits, {reused} tokens reused")
PYEOF
fi

echo "ci: all green"
