#!/usr/bin/env bash
# One-command verification harness: the tier-1 gate (which runs all
# unit + integration suites, incl. kernel_equivalence and
# serve_determinism) plus compile checks for every bench and example.
#
#   ./ci.sh          # full gate
#   ./ci.sh --fast   # tier-1 only (build + tests)
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" == "--fast" ]]; then
    echo "ci: tier-1 green (fast mode)"
    exit 0
fi

echo "== compile benches + examples =="
cargo build --release --benches --examples

# Cross-family runtime smoke: tiny dims, all four serving families
# through the scheduler — catches runtime panics (ragged groups, kernel
# tails, family builders), not just compile errors.
echo "== cross-family serve smoke =="
cargo run --release --quiet -- serve-bench \
    --family float,quant3,quant4,ternary \
    --vocab 64 --hidden 32 --glu 48 --layers 2 --mp 1 \
    --requests 4 --max-tokens 4 --batches 1,2 --threads 1

echo "ci: all green"
