#!/usr/bin/env bash
# One-command verification harness: the tier-1 gate (which runs all
# unit + integration suites, incl. kernel_equivalence and
# serve_determinism) plus compile checks for every bench and example.
#
#   ./ci.sh          # full gate
#   ./ci.sh --fast   # tier-1 only (build + tests)
set -euo pipefail
cd "$(dirname "$0")"

echo "== tier-1: cargo build --release =="
cargo build --release

echo "== tier-1: cargo test -q =="
cargo test -q

if [[ "${1:-}" == "--fast" ]]; then
    echo "ci: tier-1 green (fast mode)"
    exit 0
fi

echo "== compile benches + examples =="
cargo build --release --benches --examples

echo "ci: all green"
