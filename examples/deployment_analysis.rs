//! §2.1 / Appendix F deployment analytics: regenerates Table 4, Fig. 2a,
//! Fig. 2b and the Fig. 21 hardware trends, and demonstrates the packed
//! ternary CPU kernel realizing the memory-wall speedup on this machine.
//!
//!     cargo run --release --example deployment_analysis

use spectra::deploy::{self, SizeFamily};
use spectra::runtime::HostTensor;
use spectra::ternary::{matvec_dense, matvec_ternary_packed, Packed2Bit,
                       TernaryTensor};
use spectra::Result;

fn main() -> Result<()> {
    // Table 4 — sizes in bits across the paper's 9-size grid.
    println!("== Table 4: sizes in bits (x1e9) ==");
    print!("{:<16}", "family");
    for row in deploy::PAPER_SUITE.iter() {
        print!("{:>8}", row.label);
    }
    println!();
    for row in deploy::table4() {
        print!("{:<16}", row.family);
        for v in row.sizes_gbits {
            print!("{v:>8.2}");
        }
        println!();
    }

    // Fig 2a — capacity walls.
    println!("\n== Fig 2a: capacity walls ==");
    for (gpu, mem) in [("H100 (80GB)", 80.0), ("MI300X (192GB)", 192.0)] {
        println!("{gpu}: FloatLM {:.1}B | QuantLM4 {:.1}B | TriLM {:.1}B params",
                 deploy::max_params_fitting(mem, SizeFamily::Float) / 1e9,
                 deploy::max_params_fitting(mem,
                     SizeFamily::Quant { bits: 4, group: 128 }) / 1e9,
                 deploy::max_params_fitting(mem, SizeFamily::Ternary) / 1e9);
    }

    // Fig 2b — decode-speedup ceilings.
    println!("\n== Fig 2b: max decode speedup vs FP16 ==");
    for params in [1e9, 7e9, 70e9, 1e12] {
        println!("{:>7.0}B params: QuantLM4 {:.2}x | TriLM {:.2}x",
                 params / 1e9,
                 deploy::max_speedup_vs_fp16(params,
                     SizeFamily::Quant { bits: 4, group: 128 }),
                 deploy::max_speedup_vs_fp16(params, SizeFamily::Ternary));
    }

    // Fig 21 — hardware trends.
    println!("\n== Fig 21: memory & bandwidth per TFLOP trends ==");
    for fit in deploy::memory_per_tflop_trend() {
        println!("mem/TFLOP  {:?}: slope {:+.4} GB/TFLOP/yr", fit.vendor,
                 fit.slope);
    }
    for fit in deploy::bandwidth_per_tflop_trend() {
        println!("bw/TFLOP   {:?}: slope {:+.4} (GB/s)/TFLOP/yr", fit.vendor,
                 fit.slope);
    }

    // Realized speedup on this machine: memory-bound matvec, f32 vs 2-bit.
    println!("\n== §2.1 realized on this CPU: ternary matvec vs dense f32 ==");
    let (rows, cols) = (1024, 1024);
    let w = HostTensor::randn(vec![rows, cols], 0.05, 1);
    let t = TernaryTensor::from_latent(&w, 1);
    let packed = Packed2Bit::pack(&t.states);
    let x = HostTensor::randn(vec![1, cols], 1.0, 2).data;
    let dense_w = t.dequant();

    let time = |f: &mut dyn FnMut()| {
        let reps = 50;
        f(); // warm
        let t0 = std::time::Instant::now();
        for _ in 0..reps {
            f();
        }
        t0.elapsed().as_secs_f64() / reps as f64
    };
    let td = time(&mut || {
        std::hint::black_box(matvec_dense(&dense_w, &x));
    });
    let tt = time(&mut || {
        std::hint::black_box(matvec_ternary_packed(&packed, rows, cols,
                                                   &t.scales, &x));
    });
    println!("dense f32: {:.1} us | packed ternary: {:.1} us | speedup {:.2}x \
              (bytes ratio 16x; see benches/ternary_matmul.rs)",
             td * 1e6, tt * 1e6, td / tt);
    Ok(())
}
