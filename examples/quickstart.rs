//! Quickstart: train a tiny TriLM from Rust via the AOT-compiled JAX
//! graphs, watch the loss fall, evaluate it, and ternarize it for
//! deployment.
//!
//!     make artifacts && cargo run --release --example quickstart

use spectra::config::{Family, TrainConfig};
use spectra::coordinator::Trainer;
use spectra::data::{Batcher, Dataset};
use spectra::eval::Evaluator;
use spectra::runtime::Runtime;
use spectra::ternary::TernaryTensor;
use spectra::Result;

fn main() -> Result<()> {
    // 1. PJRT runtime over the artifacts directory (python ran once, at
    //    `make artifacts`; it is not involved from here on).
    let rt = Runtime::new("artifacts")?;
    println!("platform: {}", rt.platform());

    // 2. Synthetic corpus + BPE tokenizer (cached under runs/data).
    let data = Dataset::build(std::path::Path::new("runs/data"), 400_000, 0)?;
    println!("corpus: {} train tokens, vocab {}", data.train.len(),
             data.bpe.vocab_size());

    // 3. Train the smallest TriLM for 60 steps with the paper's
    //    two-intervention schedule.
    let model = "160k_ternary";
    let cfg = TrainConfig::for_family(Family::Ternary, 60);
    let mut trainer = Trainer::new(&rt, model, cfg)?;
    let mut batcher = Batcher::new(data.train.clone(),
                                   rt.manifest().train_batch,
                                   rt.manifest().seq, 0);
    trainer.train(&mut batcher, 60, |m| {
        if m.step % 10 == 0 {
            println!("step {:3}  loss {:.4}  lr {:.2e}", m.step, m.loss, m.lr);
        }
    })?;

    // 4. Evaluate perplexity on the held-out tail.
    let ev = Evaluator::new(&rt, model)?;
    let nll = ev.nll(trainer.param_literals(), &data.val)?;
    println!("validation nll {nll:.4} (ppl {:.2})", nll.exp());

    // 5. Ternarize a trained linear layer for deployment: states +
    //    per-shard scales, 2-bit packed.
    let params = trainer.params()?;
    let entry = rt.manifest().model(model)?;
    let (idx, spec) = entry.params.iter().enumerate()
        .find(|(_, p)| p.name == "l0.attn_q").unwrap();
    let t = TernaryTensor::from_latent(&params[idx], entry.config.mp);
    let packed = spectra::ternary::Packed2Bit::pack(&t.states);
    println!("{}: {:?} -> {} packed bytes ({:.2} bits/weight), \
              sparsity {:.1}%",
             spec.name, spec.shape, packed.bytes.len(),
             packed.bits_per_weight(), 100.0 * t.sparsity());
    println!("quickstart OK");
    Ok(())
}
