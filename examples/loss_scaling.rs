//! Table 5 reproduction: FP16 mixed-precision dynamic loss scaling —
//! min loss-scale reached and batches skipped per model/family, using
//! the fp16-gradient train graphs plus the Rust loss-scale state machine.
//!
//!     cargo run --release --example loss_scaling -- --steps 120

use std::path::PathBuf;

use spectra::config::{Family, TrainConfig};
use spectra::coordinator::Trainer;
use spectra::data::{Batcher, Dataset};
use spectra::runtime::Runtime;
use spectra::util::args::Args;
use spectra::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rt = Runtime::new(args.get("artifacts", "artifacts"))?;
    let steps = args.get_usize("steps", 120);
    let data = Dataset::build(&PathBuf::from("runs/data"), 1_000_000, 0)?;

    println!("{:<16} {:>10} {:>15} {:>16} {:>12}",
             "model", "final", "min loss-scale", "skipped batches",
             "floor >=128");
    // fp16 graphs exist at the FP16_SIZES study sizes (aot.py).
    for size in ["160k", "430k", "930k"] {
        for family in [Family::Float, Family::Ternary] {
            let model = format!("{size}_{}", family.as_str());
            let cfg = TrainConfig {
                fp16: true,
                ..TrainConfig::for_family(family, steps)
            };
            let mut trainer = Trainer::new(&rt, &model, cfg)?;
            let mut batcher = Batcher::new(data.train.clone(),
                                           rt.manifest().train_batch,
                                           rt.manifest().seq, 0);
            trainer.train(&mut batcher, steps, |_| {})?;
            println!("{:<16} {:>10.4} {:>15} {:>16} {:>12}",
                     model, trainer.log.final_loss(15),
                     trainer.loss_scale.min_seen, trainer.loss_scale.skipped,
                     trainer.loss_scale.above_recommended_floor());
        }
    }
    // At repro scale the gradients are small enough that 65536 never
    // overflows f16 (the paper's V100 runs at 99M+ params did overflow —
    // Table 5's min scales of 128-2048). To exercise the mechanism,
    // start from an absurd scale and watch the state machine walk down
    // and recover — the exact halve-and-skip dynamics behind Table 5.
    println!("\n== overflow-recovery demo (Table 5 mechanism) ==");
    let model = "160k_float";
    let cfg = TrainConfig { fp16: true,
                            ..TrainConfig::for_family(Family::Float, 40) };
    let mut trainer = Trainer::new(&rt, model, cfg)?;
    trainer.loss_scale.scale = 2f32.powi(30);
    trainer.loss_scale.max_scale = 2f32.powi(30);
    trainer.loss_scale.min_seen = trainer.loss_scale.scale;
    let mut batcher = Batcher::new(data.train.clone(),
                                   rt.manifest().train_batch,
                                   rt.manifest().seq, 0);
    for _ in 0..40 {
        let m = trainer.step(&batcher.next_batch())?;
        if !m.grads_finite {
            println!("  step {:2}: OVERFLOW at scale 2^{:.0} -> batch \
                      skipped, scale halved", m.step, m.loss_scale.log2());
        }
    }
    println!("  skipped {} batches; settled at scale {} (min seen {})",
             trainer.loss_scale.skipped, trainer.loss_scale.scale,
             trainer.loss_scale.min_seen);
    println!("\nTable 5's mechanism: scaled grads round-trip through f16 in \
              the train graph; overflow -> step skipped, scale halved; \
              200 clean steps -> scale doubled.");
    Ok(())
}
