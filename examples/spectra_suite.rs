//! The end-to-end Spectra suite driver (deliverable (b)/(d) headline):
//! trains the size x family grid on identical data, derives QuantLMs
//! from the trained FloatLMs via GPTQ, evaluates everything on the
//! synthetic benchmark suite, fits the Eq.-1 scaling laws, and prints
//! the paper-style report (Figs. 1/8/9/11/13, Tables 6/7/9 analogs).
//!
//!     cargo run --release --example spectra_suite -- \
//!         --sizes 160k,430k,930k --families float,ternary --steps 300
//!
//! The full-grid run recorded in EXPERIMENTS.md used:
//!     --sizes 160k,430k,930k,2.8m --families float,ternary,binary,bitnet

use std::path::PathBuf;

use spectra::config::Family;
use spectra::coordinator::{self, SuiteSpec};
use spectra::data::Dataset;
use spectra::runtime::Runtime;
use spectra::util::args::Args;
use spectra::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rt = Runtime::new(args.get("artifacts", "artifacts"))?;
    let seed = args.get_u64("seed", 0);
    let data = Dataset::build(&PathBuf::from("runs/data"),
                              args.get_usize("data-chars", 2_000_000), seed)?;
    let spec = SuiteSpec {
        sizes: args.get_list("sizes", "160k,430k"),
        families: args.get_list("families", "float,ternary").iter()
            .filter_map(|f| Family::parse(f)).collect(),
        steps: args.get_usize("steps", 120),
        quant_bits: args.get_list("quant-bits", "4").iter()
            .filter_map(|b| b.parse().ok()).collect(),
        eval_items: args.get_usize("eval-items", 24),
        calib_batches: 4,
        seed,
    };
    let run_dir = PathBuf::from("runs").join(args.get("tag", "suite_example"));
    let results = coordinator::run_suite(&rt, &data, &spec, &run_dir)?;

    println!("\n== Fig 9 analog: val loss across params & bits ==");
    for r in &results.records {
        println!("{:<16} params {:>9} bits {:>10.3e} val_nll {:.4}",
                 r.name, r.n_params, r.size_bits, r.val_nll);
    }
    println!("\n== Fig 1 / 11 analog: downstream accuracy ==");
    for r in &results.records {
        let fmt = |t: &str| r.tasks.iter().find(|x| x.task == t)
            .map(|x| format!("{:.3}", x.acc)).unwrap_or_default();
        println!("{:<16} cloze {} pattern {} fact {} recall {} stereo {}",
                 r.name, fmt("cloze"), fmt("pattern_mcq"), fmt("fact_mcq"),
                 fmt("fact_recall"), fmt("stereo_pairs"));
    }
    if let Some(rep) = coordinator::scaling_from_results(&results) {
        println!("\n== Eq. 1 analog ==");
        println!("TriLM:   A={:.1} alpha={:.3} eps={:.3}",
                 rep.trilm_offset.a, rep.trilm_offset.alpha,
                 rep.trilm_offset.eps);
        println!("FloatLM: A={:.1} alpha={:.3} eps={:.3}",
                 rep.floatlm_offset.a, rep.floatlm_offset.alpha,
                 rep.floatlm_offset.eps);
    }
    println!("\nresults: {}/suite_results.json; loss curves: \
              {}/<model>_loss.csv (Fig 8 analog)",
             results.run_dir, results.run_dir);
    Ok(())
}
