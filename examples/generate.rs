//! Completion demo on the serve engine: concurrent prompts decoded by
//! the continuous-batching scheduler over any storage family — dense
//! f32 (FloatLM), k-bit group-quantized (QuantLM, RTN or GPTQ), or
//! packed ternary (TriLM) — the pure-Rust inference request path, no
//! PJRT required.
//!
//! With a trained checkpoint, its mlp linears become the latent f32
//! weights and the prompts are BPE-tokenized against the run's
//! dataset; without one, synthetic latent weights serve the same
//! traffic so the demo (and its throughput readout) always runs. The
//! `--family` flag picks the storage format the same weights are
//! served in.
//!
//!     cargo run --release --example generate -- \
//!         --checkpoint runs/main/930k_ternary.spt --prompt "one day" \
//!         --family ternary --batch 4 --threads 2 --max-tokens 24

use std::path::PathBuf;

use spectra::checkpoint::Checkpoint;
use spectra::data::Dataset;
use spectra::serve::{DecodeModel, FamilySpec, GenRequest, LatentLm, LmDims,
                     Scheduler};
use spectra::util::args::Args;
use spectra::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let max_tokens = args.get_usize("max-tokens", 24);
    let batch = args.get_usize("batch", 4);
    let threads = args.get_usize("threads", 2);
    let group = args.get_usize("group", 128);
    let spec = FamilySpec::parse(&args.get("family", "ternary"), group)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown family (float | quant<bits> | gptq<bits> | ternary)"))?;
    let ck_path = PathBuf::from(
        args.get("checkpoint", "runs/main/930k_ternary.spt"));

    let prompts = [args.get("prompt", "one day"),
                   "the capital of".to_string(),
                   "if it rains , then".to_string()];

    // Latent weights + tokenization differ by source; the family
    // realization and the serve flow do not.
    type Decode = Box<dyn Fn(&[u32]) -> String>;
    let (latent, encoded, decode): (LatentLm, Vec<Vec<u32>>, Decode) =
        match Checkpoint::load(&ck_path) {
            Ok(ck) => {
                let latent = LatentLm::from_checkpoint(&ck)?;
                let data =
                    Dataset::build(&PathBuf::from("runs/data"), 400_000, 0)?;
                let encoded =
                    prompts.iter().map(|p| data.bpe.encode(p)).collect();
                let bpe = data.bpe;
                (latent, encoded, Box::new(move |t: &[u32]| bpe.decode(t)))
            }
            Err(e) => {
                eprintln!("no checkpoint ({e}); serving synthetic latent \
                           weights");
                let dims =
                    LmDims { vocab: 512, hidden: 128, glu: 352, layers: 4 };
                let latent = LatentLm::synthetic(dims, 1, 0);
                let encoded = prompts.iter()
                    .map(|p| p.bytes().map(|b| b as u32 % 512).collect())
                    .collect();
                (latent, encoded, Box::new(|t: &[u32]| format!("{t:?}")))
            }
        };

    let lm = latent.build(spec)?;
    println!("family {} ({}, {:.2} bits/param)", spec.label(),
             lm.family_label(), lm.effective_bits_per_param());

    let mut sched = Scheduler::new(lm.as_ref(), batch, threads);
    for (id, toks) in encoded.into_iter().enumerate() {
        sched.submit(GenRequest::greedy(id, toks, max_tokens));
    }
    let t0 = std::time::Instant::now();
    let done = sched.run();
    let stats = sched.stats();
    println!("served {} tokens ({} prefill) in {} batched steps, \
              peak occupancy {}: {:.0} tokens/s\n",
             stats.generated_tokens, stats.prefill_tokens,
             stats.batch_steps, stats.peak_occupancy,
             stats.generated_tokens as f64
                 / t0.elapsed().as_secs_f64().max(1e-9));
    for c in done {
        println!("PROMPT: {}\nOUTPUT: {}\n", prompts[c.id], decode(&c.tokens));
    }
    Ok(())
}
