//! Completion demo on the serve engine: concurrent prompts decoded by
//! the continuous-batching scheduler over packed ternary CPU kernels —
//! the pure-Rust inference request path, no PJRT required.
//!
//! With a trained checkpoint, its mlp linears are ternarized into a
//! [`TernaryLm`] and the prompts are BPE-tokenized against the run's
//! dataset; without one, a synthetic model serves the same traffic so
//! the demo (and its throughput readout) always runs.
//!
//!     cargo run --release --example generate -- \
//!         --checkpoint runs/main/930k_ternary.spt --prompt "one day" \
//!         --batch 4 --threads 2 --max-tokens 24

use std::path::PathBuf;

use spectra::checkpoint::Checkpoint;
use spectra::data::Dataset;
use spectra::serve::{GenRequest, LmDims, Scheduler, TernaryLm};
use spectra::util::args::Args;
use spectra::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let max_tokens = args.get_usize("max-tokens", 24);
    let batch = args.get_usize("batch", 4);
    let threads = args.get_usize("threads", 2);
    let ck_path = PathBuf::from(
        args.get("checkpoint", "runs/main/930k_ternary.spt"));

    let prompts = [args.get("prompt", "one day"),
                   "the capital of".to_string(),
                   "if it rains , then".to_string()];

    // Model + tokenization differ by source; the serve flow does not.
    type Decode = Box<dyn Fn(&[u32]) -> String>;
    let (lm, encoded, decode): (TernaryLm, Vec<Vec<u32>>, Decode) =
        match Checkpoint::load(&ck_path) {
            Ok(ck) => {
                let lm = TernaryLm::from_checkpoint(&ck)?;
                let data =
                    Dataset::build(&PathBuf::from("runs/data"), 400_000, 0)?;
                let encoded =
                    prompts.iter().map(|p| data.bpe.encode(p)).collect();
                let bpe = data.bpe;
                (lm, encoded, Box::new(move |t: &[u32]| bpe.decode(t)))
            }
            Err(e) => {
                eprintln!("no checkpoint ({e}); serving a synthetic \
                           ternary LM");
                let dims =
                    LmDims { vocab: 512, hidden: 128, glu: 352, layers: 4 };
                let (lm, _) = TernaryLm::synthetic_pair(dims, 1, 0);
                let encoded = prompts.iter()
                    .map(|p| p.bytes().map(|b| b as u32 % 512).collect())
                    .collect();
                (lm, encoded, Box::new(|t: &[u32]| format!("{t:?}")))
            }
        };

    let mut sched = Scheduler::new(&lm, batch, threads);
    for (id, toks) in encoded.into_iter().enumerate() {
        sched.submit(GenRequest::greedy(id, toks, max_tokens));
    }
    let t0 = std::time::Instant::now();
    let done = sched.run();
    let stats = sched.stats();
    println!("served {} tokens ({} prefill) in {} batched steps, \
              peak occupancy {}: {:.0} tokens/s\n",
             stats.generated_tokens, stats.prefill_tokens,
             stats.batch_steps, stats.peak_occupancy,
             stats.generated_tokens as f64
                 / t0.elapsed().as_secs_f64().max(1e-9));
    for c in done {
        println!("PROMPT: {}\nOUTPUT: {}\n", prompts[c.id], decode(&c.tokens));
    }
    Ok(())
}
