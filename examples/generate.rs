//! Completion demo on the serve engine: concurrent prompts decoded by
//! the continuous-batching scheduler over any storage family — dense
//! f32 (FloatLM), k-bit group-quantized (QuantLM, RTN or GPTQ), or
//! packed ternary (TriLM) — the pure-Rust inference request path, no
//! PJRT required.
//!
//! With a trained checkpoint, its linears become the latent f32
//! weights and the prompts are BPE-tokenized against the run's
//! dataset; without one, synthetic latent weights serve the same
//! traffic so the demo (and its throughput readout) always runs. The
//! `--family` flag picks the storage format the same weights are
//! served in; `--attn` serves the paged KV-cache attention model
//! instead of the decay-state model (checkpoints must then carry
//! `l{i}.attn_{q,k,v,o}` tensors — or a fused `l{i}.attn_qkv` stack;
//! `--heads` sets the head count and must divide hidden).
//! `--kv-heads` (default `--heads`) serves grouped-query attention:
//! query-head groups share `kv_heads` key/value heads, shrinking KV
//! bytes per token by `heads/kv_heads` (synthetic weights only — a
//! checkpoint's k/v tensor shapes already fix its kv-head count);
//! `--window W` bounds attention
//! to the last W tokens (0 = full context — bitwise identical to the
//! unwindowed model), with out-of-window KV pages recycled back to the
//! pool.
//!
//! `--prefill-chunk` ingests up to N prompt tokens per batched step
//! (chunked prefill — fewer steps to first token; the generated text
//! is bitwise identical at any chunk size).
//!
//! `--speculative` (needs `--attn`) adds a second, cheap draft model
//! built from the *same* weights in the `--draft-family` storage
//! format (TriLM by default): the draft proposes `--spec-k` tokens
//! per round and the target verifies them in one chunked pass. The
//! generated text is bitwise identical to plain decode — the readout
//! shows how many draft tokens the target accepted.
//!
//!     cargo run --release --example generate -- \
//!         --checkpoint runs/main/930k_ternary.spt --prompt "one day" \
//!         --family ternary --batch 4 --threads 2 --max-tokens 24 \
//!         [--attn] [--heads 4] [--kv-heads H] [--window 0] \
//!         [--group 128] [--prefill-chunk 8] \
//!         [--speculative] [--draft-family ternary] [--spec-k 3]

use std::path::PathBuf;

use spectra::checkpoint::Checkpoint;
use spectra::data::Dataset;
use spectra::serve::{DecodeModel, FamilySpec, GenRequest, LatentAttnLm,
                     LatentLm, LmDims, Scheduler, SpecConfig};
use spectra::util::args::Args;
use spectra::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let max_tokens = args.get_usize("max-tokens", 24);
    let batch = args.get_usize("batch", 4);
    let threads = args.get_usize("threads", 2);
    let group = args.get_usize("group", 128);
    let prefill_chunk = args.get_usize("prefill-chunk", 8);
    let attn = args.has("attn");
    let heads = args.get_usize("heads", 4);
    let kv_heads = args.get_usize("kv-heads", heads);
    if attn && (kv_heads == 0 || kv_heads > heads
                || heads % kv_heads != 0) {
        anyhow::bail!("--kv-heads {kv_heads} must divide --heads {heads} \
                       (each group of heads/kv_heads query heads shares \
                       one kv head)");
    }
    let window = args.get_usize("window", 0);
    let spec = FamilySpec::parse(&args.get("family", "ternary"), group)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown family (float | quant<bits> | gptq<bits> | ternary)"))?;
    let speculative = args.has("speculative");
    let spec_k = args.get_usize("spec-k", 3).max(1);
    let draft_spec =
        FamilySpec::parse(&args.get("draft-family", "ternary"), group)
            .ok_or_else(|| anyhow::anyhow!(
                "unknown draft family (float | quant<bits> | gptq<bits> \
                 | ternary)"))?;
    if speculative && !attn {
        anyhow::bail!("--speculative needs --attn: draft-verify rollback \
                       requires the paged-KV attention model (a decay \
                       carry cannot be rewound)");
    }
    let ck_path = PathBuf::from(
        args.get("checkpoint", "runs/main/930k_ternary.spt"));

    let prompts = [args.get("prompt", "one day"),
                   "the capital of".to_string(),
                   "if it rains , then".to_string()];

    // Latent weights + tokenization differ by source; the family
    // realization and the serve flow do not. `--attn` swaps the decay-
    // state model for the paged KV-cache attention model, cache sized
    // for `batch` lanes at prompt+completion context.
    type Decode = Box<dyn Fn(&[u32]) -> String>;
    type Built = (Box<dyn DecodeModel>, Option<Box<dyn DecodeModel>>);
    // `--speculative` realizes the same latent weights twice: once in
    // the target family, once in the draft family.
    let build = |encoded: &[Vec<u32>],
                 mk_decay: &dyn Fn() -> Result<LatentLm>,
                 mk_attn: &dyn Fn() -> Result<LatentAttnLm>|
                -> Result<Built> {
        let max_context = encoded.iter().map(|t| t.len()).max().unwrap_or(1)
            + max_tokens + 1;
        if attn {
            let latent = mk_attn()?;
            let lm = latent.build(spec, batch.max(1), max_context)?;
            let draft = if speculative {
                Some(latent.build(draft_spec, batch.max(1), max_context)?)
            } else {
                None
            };
            Ok((lm, draft))
        } else {
            Ok((mk_decay()?.build(spec)?, None))
        }
    };
    let ((lm, draft), encoded, decode): (Built, Vec<Vec<u32>>, Decode) =
        match Checkpoint::load(&ck_path) {
            Ok(ck) => {
                let data =
                    Dataset::build(&PathBuf::from("runs/data"), 400_000, 0)?;
                let encoded: Vec<Vec<u32>> =
                    prompts.iter().map(|p| data.bpe.encode(p)).collect();
                let built = build(
                    &encoded,
                    &|| LatentLm::from_checkpoint(&ck),
                    &|| Ok(LatentAttnLm::from_checkpoint(&ck, heads)?
                        .with_window(window, 0)))?;
                let bpe = data.bpe;
                (built, encoded, Box::new(move |t: &[u32]| bpe.decode(t)))
            }
            Err(e) => {
                eprintln!("no checkpoint ({e}); serving synthetic latent \
                           weights");
                let dims =
                    LmDims { vocab: 512, hidden: 128, glu: 352, layers: 4 };
                // Same clean failure as serve-bench --attn --heads: the
                // checkpoint path validates in from_checkpoint; the
                // synthetic path must not die on an assert instead.
                if attn && (heads == 0 || dims.hidden % heads != 0) {
                    anyhow::bail!("--heads {heads} must divide hidden {} \
                                   (attention head width is hidden/heads)",
                                  dims.hidden);
                }
                let encoded: Vec<Vec<u32>> = prompts.iter()
                    .map(|p| p.bytes().map(|b| b as u32 % 512).collect())
                    .collect();
                let built = build(
                    &encoded,
                    &|| Ok(LatentLm::synthetic(dims.clone(), 1, 0)),
                    &|| Ok(LatentAttnLm::synthetic(dims.clone(),
                                                   heads, 1, 0)
                        .with_kv_heads(kv_heads)
                        .with_window(window, 0)))?;
                (built, encoded, Box::new(|t: &[u32]| format!("{t:?}")))
            }
        };

    println!("family {} ({}, {:.2} bits/param{})", spec.label(),
             lm.family_label(), lm.effective_bits_per_param(),
             if attn {
                 format!(", {:.0} kv B/token", lm.kv_bytes_per_token())
             } else {
                 String::new()
             });

    let mut sched = Scheduler::with_prefill_chunk(lm.as_ref(), batch,
                                                  threads, prefill_chunk);
    if let Some(d) = draft.as_deref() {
        println!("speculative: {} draft ({:.2} bits/param) proposes \
                  {spec_k} tokens per verify round",
                 draft_spec.label(), d.effective_bits_per_param());
        sched.set_speculative(d, SpecConfig { draft_family: draft_spec,
                                              k: spec_k });
    }
    let mut n_req = 0usize;
    for (id, toks) in encoded.into_iter().enumerate() {
        sched.submit(GenRequest::greedy(id, toks, max_tokens));
        n_req += 1;
    }
    let t0 = std::time::Instant::now();
    let done = sched.run();
    let stats = sched.stats();
    println!("served {} tokens ({} prefill, chunk {}) in {} batched \
              steps, peak occupancy {}, mean ttft {:.1} steps: \
              {:.0} tokens/s\n",
             stats.generated_tokens, stats.prefill_tokens,
             sched.prefill_chunk(), stats.batch_steps,
             stats.peak_occupancy,
             stats.ttft_steps as f64 / n_req.max(1) as f64,
             stats.generated_tokens as f64
                 / t0.elapsed().as_secs_f64().max(1e-9));
    if draft.is_some() {
        println!("speculative: {}/{} draft tokens accepted — {:.2} per \
                  verify round over {} rounds (the text is bitwise \
                  identical to plain decode)\n",
                 stats.spec_accepted, stats.spec_proposed,
                 stats.accepted_per_step(), stats.spec_verify_steps);
    }
    for c in done {
        println!("PROMPT: {}\nOUTPUT: {}\n", prompts[c.id], decode(&c.tokens));
    }
    Ok(())
}
