//! Appendix-H-style completion demo: greedy decoding from a trained
//! checkpoint through the AOT `next_logits` graph — the pure-Rust
//! inference request path.
//!
//!     cargo run --release --example generate -- \
//!         --checkpoint runs/main/930k_ternary.spt --prompt "one day"

use std::path::PathBuf;

use spectra::checkpoint::Checkpoint;
use spectra::data::Dataset;
use spectra::runtime::{self, Runtime};
use spectra::util::args::Args;
use spectra::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rt = Runtime::new(args.get("artifacts", "artifacts"))?;
    let ck_path = args.get("checkpoint", "runs/main/930k_ternary.spt");
    let ck = Checkpoint::load(&PathBuf::from(&ck_path))?;
    let model = ck.metadata.get("model")
        .ok_or_else(|| anyhow::anyhow!("checkpoint missing 'model' meta"))?;
    let data = Dataset::build(&PathBuf::from("runs/data"), 400_000, 0)?;

    let graph = rt.load_graph(model, "next_logits")?;
    let seq = rt.manifest().seq;
    let lits: Vec<xla::Literal> = ck.tensor_list().iter()
        .map(runtime::literal_from_tensor)
        .collect::<Result<_>>()?;

    for prompt in [args.get("prompt", "one day"),
                   "the capital of".to_string(),
                   "if it rains , then".to_string()] {
        let mut tokens: Vec<i32> = data.bpe.encode(&prompt).iter()
            .map(|&t| t as i32).collect();
        for _ in 0..args.get_usize("max-tokens", 24) {
            let mut window = vec![0i32; seq];
            let tail = tokens.len().min(seq);
            window[seq - tail..].copy_from_slice(&tokens[tokens.len() - tail..]);
            let toks = runtime::literal_i32(&[1, seq], &window)?;
            let mut gargs: Vec<&xla::Literal> = lits.iter().collect();
            gargs.push(&toks);
            let outs = graph.run(&gargs)?;
            let logits = runtime::tensor_from_literal(&outs[0])?;
            let next = logits.data.iter().enumerate()
                .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
                .map(|(i, _)| i as i32).unwrap();
            tokens.push(next);
        }
        let text = data.bpe.decode(
            &tokens.iter().map(|&t| t as u32).collect::<Vec<_>>());
        println!("PROMPT: {prompt}\nOUTPUT: {text}\n");
    }
    Ok(())
}
