//! Fig. 6 / Tables 10-11 reproduction: the TriLM optimization-schedule
//! ablation — both interventions vs only-peak-LR vs only-L2-removal vs
//! the vanilla baseline — plus (--bitnet) the §A.6 architecture
//! comparison TriLM vs BitNet vs FloatLM at a fixed size.
//!
//!     cargo run --release --example schedule_ablation -- --steps 150

use std::path::PathBuf;

use spectra::config::{Family, TrainConfig};
use spectra::coordinator::{ScheduleVariant, Trainer};
use spectra::data::{Batcher, Dataset};
use spectra::eval::Evaluator;
use spectra::runtime::Runtime;
use spectra::util::args::Args;
use spectra::Result;

fn main() -> Result<()> {
    let args = Args::parse(std::env::args().skip(1));
    let rt = Runtime::new(args.get("artifacts", "artifacts"))?;
    let steps = args.get_usize("steps", 150);
    let size = args.get("size", "430k");
    let data = Dataset::build(&PathBuf::from("runs/data"), 1_000_000, 0)?;
    let out_dir = PathBuf::from("runs").join(args.get("tag", "ablation"));
    std::fs::create_dir_all(&out_dir)?;

    println!("== Fig 6 analog: TriLM {size}, {steps} steps, 4 schedules ==");
    let mut finals = Vec::new();
    for variant in ScheduleVariant::ALL {
        let cfg = variant.apply(TrainConfig::for_family(Family::Ternary, steps));
        let model = format!("{size}_ternary");
        let mut trainer = Trainer::new(&rt, &model, cfg)?;
        let mut batcher = Batcher::new(data.train.clone(),
                                       rt.manifest().train_batch,
                                       rt.manifest().seq, 0);
        trainer.train(&mut batcher, steps, |_| {})?;
        let final_loss = trainer.log.final_loss(15);
        trainer.log.write_csv(&out_dir.join(
            format!("schedule_{}.csv", variant.as_str())))?;
        println!("  {:<16} final train loss {:.4}", variant.as_str(), final_loss);
        finals.push((variant, final_loss));
    }
    // Paper ordering: both <= only-L2 <= only-peak <= baseline (roughly).
    let get = |v: ScheduleVariant| finals.iter().find(|(x, _)| *x == v)
        .unwrap().1;
    println!("\n  ordering check (paper: both best, baseline worst):");
    println!("    both {:.4} | only_l2 {:.4} | only_peak {:.4} | baseline {:.4}",
             get(ScheduleVariant::Both), get(ScheduleVariant::OnlyWdRemoval),
             get(ScheduleVariant::OnlyPeakLrDrop), get(ScheduleVariant::Baseline));

    if args.has("bitnet") {
        println!("\n== Fig 14 / §A.6 analog: architecture comparison @930k ==");
        for family in [Family::Ternary, Family::Float, Family::Bitnet] {
            let model = format!("930k_{}", family.as_str());
            let cfg = TrainConfig::for_family(family, steps);
            let mut trainer = Trainer::new(&rt, &model, cfg)?;
            let mut batcher = Batcher::new(data.train.clone(),
                                           rt.manifest().train_batch,
                                           rt.manifest().seq, 0);
            trainer.train(&mut batcher, steps, |_| {})?;
            let ev = Evaluator::new(&rt, &model)?;
            let nll = ev.nll(trainer.param_literals(), &data.val)?;
            println!("  {:<14} final train {:.4}  val nll {:.4}",
                     family.as_str(), trainer.log.final_loss(15), nll);
        }
    }
    Ok(())
}
