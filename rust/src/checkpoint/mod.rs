//! Checkpoint store: named f32 tensors + JSON metadata on disk.
//!
//! Format: one `.spt` file per checkpoint — a JSON header (names,
//! shapes, arbitrary metadata) length-prefixed with a u64, followed by
//! the raw little-endian f32 payloads in header order. This keeps the
//! 500+-checkpoint release workflow of the paper (§4.1 "Public
//! Accessibility") practical at repo scale.

use std::collections::BTreeMap;
use std::io::{Read, Write};
use std::path::Path;


use crate::runtime::HostTensor;
use crate::util::Json;
use crate::Result;

const MAGIC: &[u8; 8] = b"SPECTRA1";

/// An in-memory checkpoint: ordered named tensors + string metadata.
#[derive(Debug, Clone, Default)]
pub struct Checkpoint {
    pub tensors: Vec<(String, HostTensor)>,
    pub metadata: BTreeMap<String, String>,
}

impl Checkpoint {
    pub fn new(tensors: Vec<(String, HostTensor)>) -> Self {
        Checkpoint { tensors, metadata: BTreeMap::new() }
    }

    pub fn with_meta(mut self, key: &str, value: impl ToString) -> Self {
        self.metadata.insert(key.to_string(), value.to_string());
        self
    }

    pub fn get(&self, name: &str) -> Option<&HostTensor> {
        self.tensors.iter().find(|(n, _)| n == name).map(|(_, t)| t)
    }

    pub fn save(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let header = Json::obj(vec![
            ("tensors", Json::arr(self.tensors.iter().map(|(n, t)| {
                Json::obj(vec![
                    ("name", Json::str(n.clone())),
                    ("shape", Json::arr(t.shape.iter()
                        .map(|&d| Json::num(d as f64)))),
                ])
            }))),
            ("metadata", Json::Obj(self.metadata.iter()
                .map(|(k, v)| (k.clone(), Json::str(v.clone())))
                .collect())),
        ]);
        let hjson = header.to_string().into_bytes();
        let mut f = std::io::BufWriter::new(std::fs::File::create(path)?);
        f.write_all(MAGIC)?;
        f.write_all(&(hjson.len() as u64).to_le_bytes())?;
        f.write_all(&hjson)?;
        for (_, t) in &self.tensors {
            for &v in &t.data {
                f.write_all(&v.to_le_bytes())?;
            }
        }
        f.flush()?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let mut f = std::io::BufReader::new(std::fs::File::open(path)
            .map_err(|e| anyhow::anyhow!("opening {}: {e}", path.display()))?);
        let mut magic = [0u8; 8];
        f.read_exact(&mut magic)?;
        if &magic != MAGIC {
            anyhow::bail!("{} is not a spectra checkpoint", path.display());
        }
        let mut lenb = [0u8; 8];
        f.read_exact(&mut lenb)?;
        let mut hjson = vec![0u8; u64::from_le_bytes(lenb) as usize];
        f.read_exact(&mut hjson)?;
        let header = Json::parse(std::str::from_utf8(&hjson)?)?;
        let metas = header.get("tensors")?.as_arr()?;
        let mut tensors = Vec::with_capacity(metas.len());
        for meta in metas {
            let name = meta.get("name")?.as_str()?.to_string();
            let shape = meta.get("shape")?.as_usize_vec()?;
            let n: usize = shape.iter().product();
            let mut bytes = vec![0u8; n * 4];
            f.read_exact(&mut bytes)?;
            let data: Vec<f32> = bytes.chunks_exact(4)
                .map(|b| f32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            tensors.push((name, HostTensor::new(shape, data)));
        }
        let metadata = header.get("metadata")?.as_obj()?.iter()
            .map(|(k, v)| Ok((k.clone(), v.as_str()?.to_string())))
            .collect::<Result<BTreeMap<_, _>>>()?;
        Ok(Checkpoint { tensors, metadata })
    }

    /// Tensors in file order, without names (runtime calling convention).
    pub fn tensor_list(&self) -> Vec<HostTensor> {
        self.tensors.iter().map(|(_, t)| t.clone()).collect()
    }

    /// Total bytes of tensor payload.
    pub fn payload_bytes(&self) -> usize {
        self.tensors.iter().map(|(_, t)| t.len() * 4).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample() -> Checkpoint {
        Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![8, 4], 1.0, 1)),
            ("l0.attn_q".into(), HostTensor::randn(vec![4, 4], 1.0, 2)),
        ]).with_meta("step", 123).with_meta("family", "ternary")
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let path = dir.path().join("ckpt.spt");
        let ck = sample();
        ck.save(&path).unwrap();
        let back = Checkpoint::load(&path).unwrap();
        assert_eq!(back.tensors, ck.tensors);
        assert_eq!(back.metadata["step"], "123");
    }

    #[test]
    fn rejects_garbage() {
        let dir = crate::util::testutil::TempDir::new();
        let path = dir.path().join("junk.spt");
        std::fs::write(&path, b"not a checkpoint").unwrap();
        assert!(Checkpoint::load(&path).is_err());
    }

    #[test]
    fn get_by_name() {
        let ck = sample();
        assert!(ck.get("embed").is_some());
        assert!(ck.get("missing").is_none());
        assert_eq!(ck.payload_bytes(), (32 + 16) * 4);
    }
}
