//! Test helpers: a self-cleaning temp dir (tempfile stand-in).

use std::path::{Path, PathBuf};
use std::sync::atomic::{AtomicU64, Ordering};

static COUNTER: AtomicU64 = AtomicU64::new(0);

/// A unique directory under the system temp dir, removed on drop.
pub struct TempDir {
    path: PathBuf,
}

impl TempDir {
    pub fn new() -> TempDir {
        let n = COUNTER.fetch_add(1, Ordering::Relaxed);
        let path = std::env::temp_dir().join(format!(
            "spectra-test-{}-{}-{n}",
            std::process::id(),
            std::time::SystemTime::now()
                .duration_since(std::time::UNIX_EPOCH)
                .unwrap()
                .as_nanos()
        ));
        std::fs::create_dir_all(&path).expect("create temp dir");
        TempDir { path }
    }

    pub fn path(&self) -> &Path {
        &self.path
    }
}

impl Default for TempDir {
    fn default() -> Self {
        Self::new()
    }
}

impl Drop for TempDir {
    fn drop(&mut self) {
        let _ = std::fs::remove_dir_all(&self.path);
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn creates_and_cleans() {
        let p;
        {
            let td = TempDir::new();
            p = td.path().to_path_buf();
            std::fs::write(td.path().join("x"), "y").unwrap();
            assert!(p.exists());
        }
        assert!(!p.exists());
    }

    #[test]
    fn dirs_are_unique() {
        let a = TempDir::new();
        let b = TempDir::new();
        assert_ne!(a.path(), b.path());
    }
}
