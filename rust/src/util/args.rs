//! Tiny CLI argument parser: `--flag value` and boolean `--flag` styles,
//! with a leading subcommand word.

use std::collections::BTreeMap;

/// Parsed command line: subcommand + flags.
#[derive(Debug, Clone, Default)]
pub struct Args {
    pub command: String,
    flags: BTreeMap<String, String>,
    bools: Vec<String>,
}

impl Args {
    /// Parse from raw args (without argv[0]).
    pub fn parse<I: IntoIterator<Item = String>>(argv: I) -> Self {
        let mut out = Args::default();
        let mut iter = argv.into_iter().peekable();
        if let Some(first) = iter.peek() {
            if !first.starts_with("--") {
                out.command = iter.next().unwrap();
            }
        }
        while let Some(arg) = iter.next() {
            if let Some(name) = arg.strip_prefix("--") {
                match iter.peek() {
                    Some(v) if !v.starts_with("--") => {
                        out.flags.insert(name.to_string(), iter.next().unwrap());
                    }
                    _ => out.bools.push(name.to_string()),
                }
            }
        }
        out
    }

    pub fn from_env() -> Self {
        Args::parse(std::env::args().skip(1))
    }

    pub fn get(&self, name: &str, default: &str) -> String {
        self.flags.get(name).cloned().unwrap_or_else(|| default.to_string())
    }

    pub fn opt(&self, name: &str) -> Option<&str> {
        self.flags.get(name).map(|s| s.as_str())
    }

    pub fn get_usize(&self, name: &str, default: usize) -> usize {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn get_u64(&self, name: &str, default: u64) -> u64 {
        self.flags.get(name).and_then(|v| v.parse().ok()).unwrap_or(default)
    }

    pub fn has(&self, name: &str) -> bool {
        self.bools.iter().any(|b| b == name) || self.flags.contains_key(name)
    }

    /// Comma-separated list flag.
    pub fn get_list(&self, name: &str, default: &str) -> Vec<String> {
        self.get(name, default).split(',')
            .filter(|s| !s.is_empty())
            .map(String::from)
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn parse(s: &str) -> Args {
        Args::parse(s.split_whitespace().map(String::from))
    }

    #[test]
    fn subcommand_and_flags() {
        let a = parse("train --size 160k --steps 100 --fp16");
        assert_eq!(a.command, "train");
        assert_eq!(a.get("size", "x"), "160k");
        assert_eq!(a.get_usize("steps", 0), 100);
        assert!(a.has("fp16"));
        assert!(!a.has("missing"));
    }

    #[test]
    fn defaults_apply() {
        let a = parse("suite");
        assert_eq!(a.get("families", "float,ternary"), "float,ternary");
        assert_eq!(a.get_list("families", "a,b"), vec!["a", "b"]);
    }

    #[test]
    fn no_subcommand() {
        let a = parse("--x 1");
        assert_eq!(a.command, "");
        assert_eq!(a.get("x", ""), "1");
    }
}
