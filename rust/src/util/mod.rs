//! Offline-friendly utilities: JSON, CLI args, bench timing, temp dirs.
//!
//! The build environment ships only the `xla` crate's dependency
//! closure, so the usual ecosystem crates (serde, clap, criterion,
//! proptest, tempfile) are replaced by these small equivalents.

pub mod args;
pub mod bench;
pub mod json;
pub mod testutil;

pub use json::Json;
