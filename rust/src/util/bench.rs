//! Minimal benchmark harness (criterion stand-in for the offline build).
//!
//! Benches are plain binaries (`harness = false`): each calls
//! [`bench`] with a closure; we warm up, run timed iterations until a
//! wall-clock budget is spent, and report mean / p50 / p95 per
//! iteration plus derived throughput.

use std::time::{Duration, Instant};

/// One benchmark result.
#[derive(Debug, Clone)]
pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub mean: Duration,
    pub p50: Duration,
    pub p95: Duration,
}

impl BenchResult {
    pub fn mean_secs(&self) -> f64 {
        self.mean.as_secs_f64()
    }

    pub fn report(&self) {
        println!("{:<44} {:>10.3?} mean  {:>10.3?} p50  {:>10.3?} p95  ({} iters)",
                 self.name, self.mean, self.p50, self.p95, self.iters);
    }

    /// Report with a throughput line, e.g. items/sec or bytes/sec.
    pub fn report_throughput(&self, unit: &str, per_iter: f64) {
        self.report();
        println!("{:<44} {:>14.3e} {unit}/s", "", per_iter / self.mean_secs());
    }
}

/// Run `f` repeatedly for ~`budget` (after `warmup` iterations).
pub fn bench_for(name: &str, warmup: usize, budget: Duration,
                 mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut samples = Vec::new();
    let start = Instant::now();
    while start.elapsed() < budget || samples.is_empty() {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
        if samples.len() >= 10_000 {
            break;
        }
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        p95: samples[samples.len() * 95 / 100],
    }
}

/// Default: 3 warmup iterations, 2-second budget.
pub fn bench(name: &str, f: impl FnMut()) -> BenchResult {
    bench_for(name, 3, Duration::from_secs(2), f)
}

/// Short variant for expensive end-to-end benches.
pub fn bench_few(name: &str, iters: usize, mut f: impl FnMut()) -> BenchResult {
    f(); // warmup
    let mut samples = Vec::with_capacity(iters);
    for _ in 0..iters {
        let t = Instant::now();
        f();
        samples.push(t.elapsed());
    }
    samples.sort_unstable();
    let total: Duration = samples.iter().sum();
    BenchResult {
        name: name.to_string(),
        iters: samples.len(),
        mean: total / samples.len() as u32,
        p50: samples[samples.len() / 2],
        p95: samples[(samples.len() * 95 / 100).min(samples.len() - 1)],
    }
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_runs_and_reports() {
        let r = bench_for("noop", 1, Duration::from_millis(20), || {
            black_box(1 + 1);
        });
        assert!(r.iters > 10);
        assert!(r.p50 <= r.p95);
    }

    #[test]
    fn bench_few_counts() {
        let r = bench_few("sleepless", 5, || {
            black_box((0..1000).sum::<u64>());
        });
        assert_eq!(r.iters, 5);
    }
}
