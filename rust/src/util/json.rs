//! Minimal JSON: parser + writer.
//!
//! This repo builds fully offline against a registry snapshot that ships
//! only the `xla` crate's dependency closure, so serde is unavailable;
//! the manifest/results/checkpoint formats need exactly this much JSON.

use std::collections::BTreeMap;
use std::fmt::Write as _;

use crate::Result;

#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

impl Json {
    // ---- constructors ----
    pub fn obj(entries: Vec<(&str, Json)>) -> Json {
        Json::Obj(entries.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
    }

    pub fn arr<I: IntoIterator<Item = Json>>(items: I) -> Json {
        Json::Arr(items.into_iter().collect())
    }

    pub fn num(x: impl Into<f64>) -> Json {
        Json::Num(x.into())
    }

    pub fn str(s: impl Into<String>) -> Json {
        Json::Str(s.into())
    }

    // ---- accessors ----
    pub fn get(&self, key: &str) -> Result<&Json> {
        match self {
            Json::Obj(m) => m.get(key)
                .ok_or_else(|| anyhow::anyhow!("missing key '{key}'")),
            _ => anyhow::bail!("not an object (looking for '{key}')"),
        }
    }

    pub fn opt(&self, key: &str) -> Option<&Json> {
        match self {
            Json::Obj(m) => m.get(key),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Result<f64> {
        match self {
            Json::Num(x) => Ok(*x),
            _ => anyhow::bail!("not a number: {self:?}"),
        }
    }

    pub fn as_usize(&self) -> Result<usize> {
        Ok(self.as_f64()? as usize)
    }

    pub fn as_str(&self) -> Result<&str> {
        match self {
            Json::Str(s) => Ok(s),
            _ => anyhow::bail!("not a string: {self:?}"),
        }
    }

    pub fn as_bool(&self) -> Result<bool> {
        match self {
            Json::Bool(b) => Ok(*b),
            _ => anyhow::bail!("not a bool"),
        }
    }

    pub fn as_arr(&self) -> Result<&[Json]> {
        match self {
            Json::Arr(v) => Ok(v),
            _ => anyhow::bail!("not an array"),
        }
    }

    pub fn as_obj(&self) -> Result<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(m) => Ok(m),
            _ => anyhow::bail!("not an object"),
        }
    }

    /// usize vector helper (shapes etc.).
    pub fn as_usize_vec(&self) -> Result<Vec<usize>> {
        self.as_arr()?.iter().map(|v| v.as_usize()).collect()
    }

    // ---- serialization ----
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(b) => out.push_str(if *b { "true" } else { "false" }),
            Json::Num(x) => {
                if x.fract() == 0.0 && x.abs() < 9e15 {
                    let _ = write!(out, "{}", *x as i64);
                } else {
                    let _ = write!(out, "{x}");
                }
            }
            Json::Str(s) => write_escaped(s, out),
            Json::Arr(v) => {
                out.push('[');
                for (i, item) in v.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    item.write(out);
                }
                out.push(']');
            }
            Json::Obj(m) => {
                out.push('{');
                for (i, (k, v)) in m.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_escaped(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }

    // ---- parsing ----
    pub fn parse(text: &str) -> Result<Json> {
        let bytes = text.as_bytes();
        let mut pos = 0;
        let v = parse_value(bytes, &mut pos)?;
        skip_ws(bytes, &mut pos);
        if pos != bytes.len() {
            anyhow::bail!("trailing garbage at byte {pos}");
        }
        Ok(v)
    }
}

fn write_escaped(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            c if (c as u32) < 0x20 => {
                let _ = write!(out, "\\u{:04x}", c as u32);
            }
            c => out.push(c),
        }
    }
    out.push('"');
}

fn skip_ws(b: &[u8], pos: &mut usize) {
    while *pos < b.len() && matches!(b[*pos], b' ' | b'\t' | b'\n' | b'\r') {
        *pos += 1;
    }
}

fn parse_value(b: &[u8], pos: &mut usize) -> Result<Json> {
    skip_ws(b, pos);
    if *pos >= b.len() {
        anyhow::bail!("unexpected end of input");
    }
    match b[*pos] {
        b'{' => parse_obj(b, pos),
        b'[' => parse_arr(b, pos),
        b'"' => Ok(Json::Str(parse_string(b, pos)?)),
        b't' => lit(b, pos, "true", Json::Bool(true)),
        b'f' => lit(b, pos, "false", Json::Bool(false)),
        b'n' => lit(b, pos, "null", Json::Null),
        _ => parse_num(b, pos),
    }
}

fn lit(b: &[u8], pos: &mut usize, word: &str, v: Json) -> Result<Json> {
    if b[*pos..].starts_with(word.as_bytes()) {
        *pos += word.len();
        Ok(v)
    } else {
        anyhow::bail!("bad literal at byte {pos}")
    }
}

fn parse_num(b: &[u8], pos: &mut usize) -> Result<Json> {
    let start = *pos;
    while *pos < b.len()
        && matches!(b[*pos], b'0'..=b'9' | b'-' | b'+' | b'.' | b'e' | b'E')
    {
        *pos += 1;
    }
    let s = std::str::from_utf8(&b[start..*pos])?;
    Ok(Json::Num(s.parse::<f64>()
        .map_err(|e| anyhow::anyhow!("bad number '{s}': {e}"))?))
}

fn parse_string(b: &[u8], pos: &mut usize) -> Result<String> {
    if *pos >= b.len() || b[*pos] != b'"' {
        anyhow::bail!("expected string at byte {pos}");
    }
    *pos += 1;
    let mut out = String::new();
    loop {
        if *pos >= b.len() {
            anyhow::bail!("unterminated string");
        }
        match b[*pos] {
            b'"' => {
                *pos += 1;
                return Ok(out);
            }
            b'\\' => {
                *pos += 1;
                match b.get(*pos) {
                    Some(b'"') => out.push('"'),
                    Some(b'\\') => out.push('\\'),
                    Some(b'/') => out.push('/'),
                    Some(b'n') => out.push('\n'),
                    Some(b'r') => out.push('\r'),
                    Some(b't') => out.push('\t'),
                    Some(b'b') => out.push('\u{8}'),
                    Some(b'f') => out.push('\u{c}'),
                    Some(b'u') => {
                        if *pos + 5 > b.len() {
                            anyhow::bail!("truncated unicode escape");
                        }
                        let hex = std::str::from_utf8(&b[*pos + 1..*pos + 5])?;
                        let cp = u32::from_str_radix(hex, 16)?;
                        out.push(char::from_u32(cp).unwrap_or('\u{FFFD}'));
                        *pos += 4;
                    }
                    _ => anyhow::bail!("bad escape"),
                }
                *pos += 1;
            }
            _ => {
                // consume one UTF-8 scalar
                let s = std::str::from_utf8(&b[*pos..])
                    .map_err(|_| anyhow::anyhow!("invalid utf8 in string"))?;
                let c = s.chars().next().unwrap();
                out.push(c);
                *pos += c.len_utf8();
            }
        }
    }
}

fn parse_arr(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '['
    let mut items = Vec::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b']') {
        *pos += 1;
        return Ok(Json::Arr(items));
    }
    loop {
        items.push(parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b']') => {
                *pos += 1;
                return Ok(Json::Arr(items));
            }
            _ => anyhow::bail!("expected ',' or ']' at byte {pos}"),
        }
    }
}

fn parse_obj(b: &[u8], pos: &mut usize) -> Result<Json> {
    *pos += 1; // '{'
    let mut map = BTreeMap::new();
    skip_ws(b, pos);
    if b.get(*pos) == Some(&b'}') {
        *pos += 1;
        return Ok(Json::Obj(map));
    }
    loop {
        skip_ws(b, pos);
        let key = parse_string(b, pos)?;
        skip_ws(b, pos);
        if b.get(*pos) != Some(&b':') {
            anyhow::bail!("expected ':' at byte {pos}");
        }
        *pos += 1;
        map.insert(key, parse_value(b, pos)?);
        skip_ws(b, pos);
        match b.get(*pos) {
            Some(b',') => *pos += 1,
            Some(b'}') => {
                *pos += 1;
                return Ok(Json::Obj(map));
            }
            _ => anyhow::bail!("expected ',' or '}}' at byte {pos}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn roundtrip_nested() {
        let j = Json::obj(vec![
            ("a", Json::num(1.5)),
            ("b", Json::arr([Json::Bool(true), Json::Null])),
            ("c", Json::str("hi \"there\"\n")),
            ("d", Json::obj(vec![("x", Json::num(-3))])),
        ]);
        let s = j.to_string();
        assert_eq!(Json::parse(&s).unwrap(), j);
    }

    #[test]
    fn parses_manifest_style() {
        let j = Json::parse(r#"{"seq": 128, "models": {"a": {"params":
            [{"name": "embed", "shape": [512, 64]}]}}}"#).unwrap();
        assert_eq!(j.get("seq").unwrap().as_usize().unwrap(), 128);
        let p = j.get("models").unwrap().get("a").unwrap()
            .get("params").unwrap().as_arr().unwrap();
        assert_eq!(p[0].get("shape").unwrap().as_usize_vec().unwrap(),
                   vec![512, 64]);
    }

    #[test]
    fn parses_scientific_and_negative() {
        let j = Json::parse("[-1.5e-3, 2E2, 0.0]").unwrap();
        let v = j.as_arr().unwrap();
        assert_eq!(v[0].as_f64().unwrap(), -1.5e-3);
        assert_eq!(v[1].as_f64().unwrap(), 200.0);
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("{} extra").is_err());
    }

    #[test]
    fn unicode_escapes() {
        let j = Json::parse(r#""aAb""#).unwrap();
        assert_eq!(j.as_str().unwrap(), "aAb");
    }

    #[test]
    fn integers_serialize_without_decimal() {
        assert_eq!(Json::num(128).to_string(), "128");
        assert_eq!(Json::num(1.5).to_string(), "1.5");
    }
}
