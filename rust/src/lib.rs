//! Spectra: a three-layer reproduction of *"Spectra: A Comprehensive
//! Study of Ternary, Quantized, and FP16 Language Models"*.
//!
//! Layer 3 (this crate) is the coordinator and every substrate the paper
//! depends on; Layer 2 (JAX) and Layer 1 (Pallas) live in `python/` and
//! are AOT-compiled to HLO-text artifacts executed here via PJRT.
//! Python never runs on the request path.
//!
//! Module map (see DESIGN.md for the paper-experiment index):
//!
//! - [`config`] — suite/model/training configuration.
//! - [`runtime`] — PJRT client wrapper (load HLO text, compile,
//!   execute) + the CPU serving execution substrate: the persistent
//!   [`runtime::WorkerPool`] and reusable [`runtime::DecodeScratch`].
//! - [`data`] — synthetic corpus generator, BPE tokenizer, batcher.
//! - [`coordinator`] — training loop, Spectra optimization schedule,
//!   dynamic loss scaling, suite runner.
//! - [`checkpoint`] — tensor store for trained models.
//! - [`ternary`] — ternarization, 2-bit/base-3 packing, CPU kernels.
//! - [`quant`] — k-bit symmetric group quantization (QuantLM storage).
//! - [`linear`] — the family-unified [`linear::LinearFormat`] trait
//!   (dense f32 / packed ternary / packed k-bit quant) + the blocked
//!   threaded k-bit serving kernel.
//! - [`gptq`] — GPTQ post-training quantization (Hessian + Cholesky).
//! - [`analysis`] — scaling-law fits (Levenberg–Marquardt), entropy.
//! - [`deploy`] — hardware DB, model-bits accounting, memory-wall model
//!   (incl. the batched decode roofline).
//! - [`eval`] — perplexity + downstream benchmark harness.
//! - [`serve`] — batched decode engine: continuous-batching scheduler
//!   + blocked multi-threaded packed kernels (the §2.1 bandwidth win
//!   realized as a serving path), with two context mechanisms behind
//!   one `DecodeModel` trait: the decay-state [`serve::SpectraLm`] and
//!   the paged KV-cache attention [`serve::AttnLm`]
//!   ([`serve::kvcache`]).
//! - [`server`] — std-only HTTP/1.1 serving front end over [`serve`]:
//!   chunked token streaming, prefix-hash sharding across schedulers,
//!   tenant-fair bounded admission (429/413 instead of silent
//!   requeue), `/stats`, graceful drain (`spectra serve`).
//! - [`util`] — offline stand-ins for serde/clap/criterion/tempfile.

pub mod analysis;
pub mod checkpoint;
pub mod config;
pub mod coordinator;
pub mod data;
pub mod deploy;
pub mod eval;
pub mod gptq;
pub mod linear;
pub mod quant;
pub mod runtime;
pub mod serve;
pub mod server;
pub mod ternary;
pub mod util;

/// Crate-wide result type.
pub type Result<T> = anyhow::Result<T>;
