//! Weight-distribution analysis (§2.2, Figs. 3, 4, 20).
//!
//! - Shannon entropy of the binned weight distribution, across bin
//!   counts (Fig. 3): the average bits needed to encode a weight.
//! - Differential entropy of a Gaussian fit, H = 1/2 log2(2*pi*e*sigma^2)
//!   (Fig. 4): falls as weights concentrate with scale.
//! - Histogram + Gaussian-fit quality (Fig. 20 / App. E).


/// Mean and standard deviation of a sample.
pub fn gaussian_fit(xs: &[f32]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mean = xs.iter().map(|&x| x as f64).sum::<f64>() / n;
    let var = xs.iter().map(|&x| {
        let d = x as f64 - mean;
        d * d
    }).sum::<f64>() / n;
    (mean, var.sqrt())
}

/// Differential entropy (bits) of the Gaussian fit (Fig. 4).
pub fn differential_entropy_bits(sigma: f64) -> f64 {
    0.5 * (2.0 * std::f64::consts::PI * std::f64::consts::E * sigma * sigma).log2()
}

/// Equal-width histogram over [min, max].
pub fn histogram(xs: &[f32], bins: usize) -> (Vec<usize>, f64, f64) {
    assert!(bins >= 1 && !xs.is_empty());
    let min = xs.iter().cloned().fold(f32::INFINITY, f32::min) as f64;
    let max = xs.iter().cloned().fold(f32::NEG_INFINITY, f32::max) as f64;
    let width = ((max - min) / bins as f64).max(1e-30);
    let mut counts = vec![0usize; bins];
    for &x in xs {
        let b = (((x as f64 - min) / width) as usize).min(bins - 1);
        counts[b] += 1;
    }
    (counts, min, width)
}

/// Shannon entropy (bits) of the binned distribution (Fig. 3).
pub fn shannon_entropy_bits(xs: &[f32], bins: usize) -> f64 {
    let (counts, _, _) = histogram(xs, bins);
    let n = xs.len() as f64;
    counts.iter().filter(|&&c| c > 0).map(|&c| {
        let p = c as f64 / n;
        -p * p.log2()
    }).sum()
}

/// Excess kurtosis — 0 for a Gaussian; the App.-E normality proxy.
pub fn excess_kurtosis(xs: &[f32]) -> f64 {
    let (mean, sigma) = gaussian_fit(xs);
    let n = xs.len() as f64;
    let m4 = xs.iter().map(|&x| {
        let d = (x as f64 - mean) / sigma.max(1e-30);
        d.powi(4)
    }).sum::<f64>() / n;
    m4 - 3.0
}

/// Per-model weight-distribution report row (Figs. 3/4/20 data).
#[derive(Debug, Clone)]
pub struct WeightStats {
    pub model: String,
    pub n_weights: usize,
    pub mean: f64,
    pub sigma: f64,
    pub differential_entropy_bits: f64,
    /// Shannon entropy at each probed bin count.
    pub shannon_bits: Vec<(usize, f64)>,
    pub excess_kurtosis: f64,
}

/// Fig. 3's bin sweep.
pub const BIN_COUNTS: [usize; 4] = [64, 256, 1024, 4096];

/// Compute the full report for a pooled weight sample.
pub fn weight_stats(model: &str, xs: &[f32]) -> WeightStats {
    let (mean, sigma) = gaussian_fit(xs);
    WeightStats {
        model: model.to_string(),
        n_weights: xs.len(),
        mean,
        sigma,
        differential_entropy_bits: differential_entropy_bits(sigma),
        shannon_bits: BIN_COUNTS.iter()
            .map(|&b| (b, shannon_entropy_bits(xs, b)))
            .collect(),
        excess_kurtosis: excess_kurtosis(xs),
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SplitMix64;

    fn gaussian_sample(n: usize, sigma: f64, seed: u64) -> Vec<f32> {
        let mut rng = SplitMix64::new(seed);
        (0..n).map(|_| (sigma * rng.next_gaussian()) as f32).collect()
    }

    #[test]
    fn gaussian_fit_recovers_sigma() {
        let xs = gaussian_sample(50_000, 0.02, 1);
        let (mean, sigma) = gaussian_fit(&xs);
        assert!(mean.abs() < 1e-3);
        assert!((sigma - 0.02).abs() / 0.02 < 0.05);
    }

    #[test]
    fn differential_entropy_drops_with_concentration() {
        // The §2.2 claim: smaller sigma (more concentrated weights,
        // larger models) => lower differential entropy.
        assert!(differential_entropy_bits(0.01) < differential_entropy_bits(0.05));
    }

    #[test]
    fn differential_entropy_formula() {
        // H(N(0, 1)) = 0.5*log2(2*pi*e) ~= 2.047 bits.
        assert!((differential_entropy_bits(1.0) - 2.047).abs() < 0.01);
    }

    #[test]
    fn shannon_entropy_bounds() {
        let xs = gaussian_sample(10_000, 1.0, 2);
        let h = shannon_entropy_bits(&xs, 256);
        assert!(h > 0.0 && h <= 8.0); // <= log2(bins)
    }

    #[test]
    fn shannon_entropy_grows_with_bins() {
        let xs = gaussian_sample(100_000, 1.0, 3);
        let h64 = shannon_entropy_bits(&xs, 64);
        let h1024 = shannon_entropy_bits(&xs, 1024);
        assert!(h1024 > h64);
    }

    #[test]
    fn narrower_distribution_lower_shannon() {
        // Fig. 3's trend driver: same binning *range-relative* entropy
        // is scale-free, so compare mixtures — a spikier distribution
        // (more zeros) has lower entropy at fixed bins over fixed range.
        let wide = gaussian_sample(50_000, 1.0, 4);
        let mut narrow = gaussian_sample(25_000, 0.2, 5);
        narrow.extend(std::iter::repeat(0.0f32).take(25_000));
        // use a shared binning range by appending range markers
        let mut w = wide.clone();
        w.push(4.0);
        w.push(-4.0);
        let mut n = narrow.clone();
        n.push(4.0);
        n.push(-4.0);
        assert!(shannon_entropy_bits(&n, 256) < shannon_entropy_bits(&w, 256));
    }

    #[test]
    fn kurtosis_near_zero_for_gaussian() {
        let xs = gaussian_sample(100_000, 0.5, 6);
        assert!(excess_kurtosis(&xs).abs() < 0.1);
    }

    #[test]
    fn weight_stats_report_is_complete() {
        let xs = gaussian_sample(10_000, 0.02, 7);
        let s = weight_stats("test", &xs);
        assert_eq!(s.shannon_bits.len(), BIN_COUNTS.len());
        assert!(s.differential_entropy_bits < 0.0); // sigma << 1
    }
}
