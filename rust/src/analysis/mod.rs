//! Analysis: scaling-law fitting and weight-distribution entropy.

pub mod entropy;
pub mod scaling;

pub use entropy::{differential_entropy_bits, excess_kurtosis, gaussian_fit,
                  histogram, shannon_entropy_bits, weight_stats, WeightStats,
                  BIN_COUNTS};
pub use scaling::{fit_power_law, percent_gap, scaling_report, PowerLawFit,
                  ScalingReport};
