//! Scaling-law fitting (§4.3, Eq. 1; Figs. 9, 10, 19).
//!
//! Fits validation loss against parameter count with the paper's two
//! forms using Levenberg–Marquardt nonlinear least squares:
//!
//!   power law with offset:  L(N) = A / N^alpha + eps     (Hoffmann-style)
//!   pure power law:         L(N) = A / N^alpha           (Kaplan-style)
//!
//! and derives the Fig. 10 extrapolation: the percentage validation-loss
//! gap between two fitted families as N grows.


/// Fitted power law with optional offset.
#[derive(Debug, Clone, Copy)]
pub struct PowerLawFit {
    pub a: f64,
    pub alpha: f64,
    pub eps: f64,
    pub with_offset: bool,
    /// Residual sum of squares at the solution.
    pub rss: f64,
}

impl PowerLawFit {
    pub fn predict(&self, n: f64) -> f64 {
        self.a / n.powf(self.alpha) + self.eps
    }
}

fn solve3(mut m: [[f64; 3]; 3], mut b: [f64; 3]) -> Option<[f64; 3]> {
    // Gaussian elimination with partial pivoting, 3x3.
    for col in 0..3 {
        let piv = (col..3).max_by(|&i, &j| {
            m[i][col].abs().partial_cmp(&m[j][col].abs()).unwrap()
        })?;
        if m[piv][col].abs() < 1e-300 {
            return None;
        }
        m.swap(col, piv);
        b.swap(col, piv);
        for row in (col + 1)..3 {
            let f = m[row][col] / m[col][col];
            for k in col..3 {
                m[row][k] -= f * m[col][k];
            }
            b[row] -= f * b[col];
        }
    }
    let mut x = [0.0; 3];
    for row in (0..3).rev() {
        let mut s = b[row];
        for k in (row + 1)..3 {
            s -= m[row][k] * x[k];
        }
        x[row] = s / m[row][row];
    }
    Some(x)
}

fn rss_of(params: &[f64; 3], ns: &[f64], ys: &[f64]) -> f64 {
    ns.iter().zip(ys).map(|(&n, &y)| {
        let f = params[0] / n.powf(params[1]) + params[2];
        (y - f) * (y - f)
    }).sum()
}

/// Levenberg–Marquardt fit of L(N) = A/N^alpha (+ eps if `with_offset`).
///
/// `ns` in raw parameter counts; `ys` the final validation losses.
pub fn fit_power_law(ns: &[f64], ys: &[f64], with_offset: bool) -> PowerLawFit {
    assert!(ns.len() >= 3 && ns.len() == ys.len());
    // Initialization: alpha 0.3, eps = 0.9*min(y) (or 0), A from first point.
    let ymin = ys.iter().cloned().fold(f64::INFINITY, f64::min);
    let mut p = [0.0f64; 3];
    p[1] = 0.3;
    p[2] = if with_offset { 0.9 * ymin } else { 0.0 };
    p[0] = (ys[0] - p[2]) * ns[0].powf(p[1]);

    let mut lambda = 1e-3;
    let mut rss = rss_of(&p, ns, ys);
    for _ in 0..200 {
        // Jacobian-normal equations: (JtJ + lambda diag(JtJ)) d = Jt r
        let mut jtj = [[0.0f64; 3]; 3];
        let mut jtr = [0.0f64; 3];
        for (&n, &y) in ns.iter().zip(ys) {
            let npa = n.powf(-p[1]);
            let f = p[0] * npa + p[2];
            let r = y - f;
            let j = [npa, -p[0] * n.ln() * npa, if with_offset { 1.0 } else { 0.0 }];
            for i in 0..3 {
                jtr[i] += j[i] * r;
                for k in 0..3 {
                    jtj[i][k] += j[i] * j[k];
                }
            }
        }
        if !with_offset {
            jtj[2][2] = 1.0; // pin eps
            jtr[2] = 0.0;
        }
        let mut damped = jtj;
        for i in 0..3 {
            damped[i][i] += lambda * jtj[i][i].max(1e-12);
        }
        let Some(delta) = solve3(damped, jtr) else { break };
        let mut cand = [p[0] + delta[0], p[1] + delta[1], p[2] + delta[2]];
        if !with_offset {
            cand[2] = 0.0;
        }
        cand[0] = cand[0].max(1e-12);
        cand[1] = cand[1].clamp(0.01, 2.0);
        cand[2] = cand[2].max(0.0);
        let cand_rss = rss_of(&cand, ns, ys);
        if cand_rss < rss {
            p = cand;
            rss = cand_rss;
            lambda = (lambda * 0.5).max(1e-12);
            if delta.iter().all(|d| d.abs() < 1e-12) {
                break;
            }
        } else {
            lambda *= 2.0;
            if lambda > 1e12 {
                break;
            }
        }
    }
    PowerLawFit { a: p[0], alpha: p[1], eps: p[2], with_offset, rss }
}

/// Fig. 10: percentage loss gap of `fit_a` relative to `fit_b` at N.
pub fn percent_gap(fit_a: &PowerLawFit, fit_b: &PowerLawFit, n: f64) -> f64 {
    100.0 * (fit_a.predict(n) - fit_b.predict(n)) / fit_b.predict(n)
}

/// One Fig. 9/10 report: both families, both fit forms, extrapolations.
#[derive(Debug, Clone)]
pub struct ScalingReport {
    pub trilm_offset: PowerLawFit,
    pub floatlm_offset: PowerLawFit,
    pub trilm_pure: PowerLawFit,
    pub floatlm_pure: PowerLawFit,
    /// (N, %gap) extrapolation samples (Fig. 10 curve).
    pub gap_curve: Vec<(f64, f64)>,
}

pub fn scaling_report(trilm: &[(f64, f64)], floatlm: &[(f64, f64)])
                      -> ScalingReport {
    let split = |pts: &[(f64, f64)]| -> (Vec<f64>, Vec<f64>) {
        (pts.iter().map(|p| p.0).collect(), pts.iter().map(|p| p.1).collect())
    };
    let (tn, ty) = split(trilm);
    let (fx, fy) = split(floatlm);
    let trilm_offset = fit_power_law(&tn, &ty, true);
    let floatlm_offset = fit_power_law(&fx, &fy, true);
    let max_n = tn.iter().cloned().fold(0.0, f64::max);
    let gap_curve = (0..40).map(|i| {
        let n = max_n * 10f64.powf(i as f64 / 8.0); // out to ~1e5x
        (n, percent_gap(&trilm_offset, &floatlm_offset, n))
    }).collect();
    ScalingReport {
        trilm_offset,
        floatlm_offset,
        trilm_pure: fit_power_law(&tn, &ty, false),
        floatlm_pure: fit_power_law(&fx, &fy, false),
        gap_curve,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn synth(a: f64, alpha: f64, eps: f64, noise: f64) -> (Vec<f64>, Vec<f64>) {
        let mut rng = crate::runtime::SplitMix64::new(5);
        let ns: Vec<f64> = (0..8).map(|i| 1e5 * 4f64.powi(i)).collect();
        let ys = ns.iter().enumerate().map(|(i, &n)| {
            let _ = i;
            a / n.powf(alpha) + eps + noise * rng.next_gaussian()
        }).collect();
        (ns, ys)
    }

    #[test]
    fn recovers_exact_power_law_with_offset() {
        let (ns, ys) = synth(185.0, 0.26, 1.76, 0.0);
        let fit = fit_power_law(&ns, &ys, true);
        assert!((fit.alpha - 0.26).abs() < 0.01, "alpha {}", fit.alpha);
        assert!((fit.eps - 1.76).abs() < 0.05, "eps {}", fit.eps);
        assert!((fit.a - 185.0).abs() / 185.0 < 0.1, "a {}", fit.a);
    }

    #[test]
    fn recovers_pure_power_law() {
        let (ns, ys) = synth(50.0, 0.2, 0.0, 0.0);
        let fit = fit_power_law(&ns, &ys, false);
        assert_eq!(fit.eps, 0.0);
        assert!((fit.alpha - 0.2).abs() < 0.01);
    }

    #[test]
    fn noise_tolerant() {
        let (ns, ys) = synth(100.0, 0.3, 2.0, 0.01);
        let fit = fit_power_law(&ns, &ys, true);
        assert!((fit.alpha - 0.3).abs() < 0.1);
        assert!(fit.rss < 0.01);
    }

    #[test]
    fn offset_fit_beats_pure_when_offset_exists() {
        let (ns, ys) = synth(100.0, 0.3, 2.0, 0.0);
        let with = fit_power_law(&ns, &ys, true);
        let without = fit_power_law(&ns, &ys, false);
        assert!(with.rss < without.rss * 0.5,
                "{} !< {}", with.rss, without.rss);
    }

    #[test]
    fn paper_eq1_gap_closes_with_scale() {
        // Using the paper's own Eq. 1 constants, the TriLM-FloatLM gap
        // shrinks with N (Fig. 10): ~7% at 15.6B, ~6% at 330B.
        let trilm = PowerLawFit { a: 185.0, alpha: 0.26, eps: 1.76,
                                  with_offset: true, rss: 0.0 };
        let floatlm = PowerLawFit { a: 159.0, alpha: 0.26, eps: 1.67,
                                    with_offset: true, rss: 0.0 };
        let g15 = percent_gap(&trilm, &floatlm, 15.6e9);
        let g330 = percent_gap(&trilm, &floatlm, 330e9);
        assert!(g330 < g15);
        assert!((g15 - 7.0).abs() < 1.5, "gap@15.6B {g15}");
        assert!((g330 - 6.0).abs() < 1.5, "gap@330B {g330}");
    }
}
