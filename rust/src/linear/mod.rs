//! The family-unified linear-layer API: one trait over every weight
//! storage format the serve engine speaks.
//!
//! The paper's headline result is a *cross-family* comparison — FloatLM
//! vs QuantLM vs TriLM at matched bit budgets (§4.2, Table 4, Fig. 2).
//! [`LinearFormat`] is the serving-side abstraction that makes the
//! comparison executable: a linear layer is "something that can batched-
//! matmul, dequantize, and account for its own bits per parameter",
//! regardless of how the weights are stored. Three formats implement it:
//!
//! - [`DenseF32`] — f32 rows (the FloatLM-storage baseline; 32 bits).
//! - [`crate::ternary::PackedMatrix`] — 2-bit trits + shard scales, via
//!   the blocked threaded [`crate::ternary::matmul_ternary_packed`].
//! - [`QuantPacked`] — k-bit group-quantized bitstream + per-group
//!   scales, via the blocked threaded [`matmul_quant_packed`]
//!   (see [`qmatmul`]).
//!
//! All three honor the same numerical contract: per-output-element
//! accumulation order is fixed by `k` alone, so a lane's result is
//! bitwise identical at any batch size and thread count — the property
//! `serve`'s continuous-batching determinism rests on
//! (`tests/kernel_equivalence.rs` checks it bitwise per kernel;
//! `tests/pool_equivalence.rs` checks the pooled `_into` twins against
//! the scoped reference). Because the trait is storage-only, *every*
//! projection of the serve models — the gated MLP's gate/up/down, the
//! output head, and the attention model's q/k/v/o — is just another
//! `LinearFormat`, compressed and executed identically.
//! [`LinearFormat::effective_bits_per_param`] keys the deploy roofline
//! ([`crate::deploy::decode_tokens_per_sec_bits`]) so measured
//! throughput and the analytic bits-vs-bandwidth story line up.

pub mod qmatmul;

pub use qmatmul::{matmul_quant_packed, matmul_quant_packed_into, QuantPacked,
                  COL_BLOCK_VALS};

use crate::runtime::{HostTensor, WorkerPool};
use crate::ternary::matmul::blocked_rows_driver_pooled;
use crate::ternary::{matmul_dense, matmul_ternary_packed,
                     matmul_ternary_packed_into, PackedMatrix};

/// A served linear layer: y = x @ W^T over some weight storage format.
pub trait LinearFormat: Send + Sync {
    /// Output features (rows of W).
    fn out_features(&self) -> usize;

    /// Input features (cols of W).
    fn in_features(&self) -> usize;

    /// Batched matmul y = x @ W^T; x: (m, in) -> (m, out). `threads`
    /// is a partitioning hint (0 = auto); implementations must keep
    /// per-element accumulation order independent of both `threads`
    /// and the batch size `m`.
    ///
    /// Compatibility entry point: spawns/allocates per call. The serve
    /// hot path uses [`LinearFormat::matmul_batch_into`].
    fn matmul_batch(&self, x: &HostTensor, threads: usize) -> HostTensor;

    /// Scratch-aware batched matmul: execute on a persistent
    /// [`WorkerPool`], accumulating into the caller's `out_t` slab and
    /// writing the (m, out) result into `out` (reshaped in place). Must
    /// be bitwise identical to `matmul_batch(x, pool.threads())` — the
    /// pooled scheduler serves through this method, and the serve
    /// determinism contract rides on the equivalence.
    ///
    /// The default falls back to the allocating path so external
    /// formats stay correct; the built-in formats override it with
    /// allocation-free implementations.
    fn matmul_batch_into(&self, x: &HostTensor, pool: &WorkerPool,
                         out_t: &mut Vec<f32>, out: &mut HostTensor) {
        let _ = out_t;
        *out = self.matmul_batch(x, pool.threads());
    }

    /// Dequantized f32 weights — the equivalence-test reference.
    fn dequant(&self) -> HostTensor;

    /// Stored bits per weight parameter, scale overhead included (the
    /// paper's effective-bit accounting, §4.2).
    fn effective_bits_per_param(&self) -> f64;

    /// Short storage-format label (e.g. "fp32", "ternary", "q4g128").
    fn label(&self) -> String;
}

/// Dense f32 storage — the FloatLM serving baseline.
#[derive(Debug, Clone)]
pub struct DenseF32 {
    pub w: HostTensor,
}

impl From<HostTensor> for DenseF32 {
    fn from(w: HostTensor) -> Self {
        DenseF32 { w }
    }
}

/// Pooled dense kernel body for w-rows `[r0, r1)`: plain sequential
/// accumulation over `k` per (w-row, x-row) pair — the exact order of
/// [`matmul_dense`], so pooled dense results are bitwise identical to
/// the allocating path at any thread count and batch size.
fn dense_rows_kernel(w: &HostTensor, x: &HostTensor,
                     r0: usize, r1: usize, out_t: &mut [f32]) {
    let (m, k) = x.dims2();
    debug_assert_eq!(k, w.dims2().1);
    debug_assert_eq!(out_t.len(), (r1 - r0) * m);
    for r in r0..r1 {
        let wr = w.row(r);
        for mi in 0..m {
            let xr = x.row(mi);
            let mut acc = 0.0f32;
            for c in 0..k {
                acc += xr[c] * wr[c];
            }
            out_t[(r - r0) * m + mi] = acc;
        }
    }
}

impl LinearFormat for DenseF32 {
    fn out_features(&self) -> usize {
        self.w.dims2().0
    }

    fn in_features(&self) -> usize {
        self.w.dims2().1
    }

    fn matmul_batch(&self, x: &HostTensor, _threads: usize) -> HostTensor {
        matmul_dense(x, &self.w)
    }

    fn matmul_batch_into(&self, x: &HostTensor, pool: &WorkerPool,
                         out_t: &mut Vec<f32>, out: &mut HostTensor) {
        let (m, k) = x.dims2();
        assert_eq!(k, self.w.dims2().1,
                   "x cols {k} != dense weight cols {}", self.w.dims2().1);
        blocked_rows_driver_pooled(
            m, k, self.w.dims2().0, pool, out_t, out,
            |r0, r1, slab| dense_rows_kernel(&self.w, x, r0, r1, slab));
    }

    fn dequant(&self) -> HostTensor {
        self.w.clone()
    }

    fn effective_bits_per_param(&self) -> f64 {
        32.0
    }

    fn label(&self) -> String {
        "fp32".into()
    }
}

impl LinearFormat for PackedMatrix {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    fn matmul_batch(&self, x: &HostTensor, threads: usize) -> HostTensor {
        matmul_ternary_packed(x, self, threads)
    }

    fn matmul_batch_into(&self, x: &HostTensor, pool: &WorkerPool,
                         out_t: &mut Vec<f32>, out: &mut HostTensor) {
        matmul_ternary_packed_into(x, self, pool, out_t, out);
    }

    fn dequant(&self) -> HostTensor {
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            let g = self.row_scale(r);
            for s in self.unpack_row(r) {
                data.push(g * s as f32);
            }
        }
        HostTensor::new(vec![self.rows, self.cols], data)
    }

    fn effective_bits_per_param(&self) -> f64 {
        // 2-bit packed states (row padding included) + f16-accounted
        // shard scales (§A.5).
        self.bits_per_weight()
            + 16.0 * self.scales.len() as f64
                / (self.rows * self.cols).max(1) as f64
    }

    fn label(&self) -> String {
        "ternary".into()
    }
}

impl LinearFormat for QuantPacked {
    fn out_features(&self) -> usize {
        self.rows
    }

    fn in_features(&self) -> usize {
        self.cols
    }

    fn matmul_batch(&self, x: &HostTensor, threads: usize) -> HostTensor {
        matmul_quant_packed(x, self, threads)
    }

    fn matmul_batch_into(&self, x: &HostTensor, pool: &WorkerPool,
                         out_t: &mut Vec<f32>, out: &mut HostTensor) {
        matmul_quant_packed_into(x, self, pool, out_t, out);
    }

    fn dequant(&self) -> HostTensor {
        QuantPacked::dequant(self)
    }

    fn effective_bits_per_param(&self) -> f64 {
        self.effective_bits()
    }

    fn label(&self) -> String {
        format!("q{}g{}", self.bits, self.group)
    }
}

/// Row-stacked fusion of several same-input linears executed as one
/// logical projection: `y = x @ [W_0; W_1; ...]^T`.
///
/// The attention serve model fuses q/k/v into one QKV matmul and
/// gate/up into one matmul per block (two of the five per-block
/// dispatches removed). Fusion is a *dispatch* optimization, never a
/// numerical one: each part keeps its own storage object, quantized
/// exactly as the unfused layer would be — crucial for the ternary
/// family, whose mp-shard scales depend on the matrix they summarize
/// (fusing q/k/v *before* ternarization would change every scale and
/// break the bitwise fused-vs-unfused invariant). Output columns of
/// part `i` land at `[offset_i, offset_i + out_i)` in the fused row,
/// so splitting the fused output is pure slicing.
#[derive(Debug, Clone)]
pub struct FusedLinear<L: LinearFormat> {
    parts: Vec<L>,
}

impl<L: LinearFormat> FusedLinear<L> {
    /// Fuse `parts` (≥ 1, all sharing `in_features`) into one logical
    /// row-stacked projection.
    pub fn new(parts: Vec<L>) -> Self {
        assert!(!parts.is_empty(), "fused linear needs at least one part");
        let k = parts[0].in_features();
        for p in &parts[1..] {
            assert_eq!(p.in_features(), k,
                       "fused parts must share in_features");
        }
        FusedLinear { parts }
    }

    /// The fused constituent layers, in row-stack order.
    pub fn parts(&self) -> &[L] {
        &self.parts
    }

    /// Column offset of part `i` inside a fused output row.
    pub fn part_offset(&self, i: usize) -> usize {
        self.parts[..i].iter().map(|p| p.out_features()).sum()
    }

    /// One fused projection on the pooled hot path: each part runs its
    /// own allocation-free [`LinearFormat::matmul_batch_into`] into
    /// `stage`, and the staged rows are copied into the part's column
    /// stripe of `out` (shape `(m, Σ out_i)`). Per-element accumulation
    /// happens entirely inside the parts' kernels, so the fused result
    /// is bitwise identical to running the parts separately — the
    /// property the fused-vs-unfused equivalence tests pin down.
    pub fn matmul_batch_into_fused(&self, x: &HostTensor, pool: &WorkerPool,
                                   out_t: &mut Vec<f32>,
                                   stage: &mut HostTensor,
                                   out: &mut HostTensor) {
        let (m, _) = x.dims2();
        let total = self.out_features();
        out.reset2(m, total);
        let mut off = 0usize;
        for p in &self.parts {
            let n = p.out_features();
            p.matmul_batch_into(x, pool, out_t, stage);
            debug_assert_eq!(stage.dims2(), (m, n));
            for r in 0..m {
                let dst = &mut out.row_mut(r)[off..off + n];
                dst.copy_from_slice(stage.row(r));
            }
            off += n;
        }
    }
}

impl<L: LinearFormat> LinearFormat for FusedLinear<L> {
    fn out_features(&self) -> usize {
        self.parts.iter().map(|p| p.out_features()).sum()
    }

    fn in_features(&self) -> usize {
        self.parts[0].in_features()
    }

    fn matmul_batch(&self, x: &HostTensor, threads: usize) -> HostTensor {
        let (m, _) = x.dims2();
        let total = self.out_features();
        let mut out = HostTensor::zeros(vec![m, total]);
        let mut off = 0usize;
        for p in &self.parts {
            let n = p.out_features();
            let y = p.matmul_batch(x, threads);
            for r in 0..m {
                out.row_mut(r)[off..off + n].copy_from_slice(y.row(r));
            }
            off += n;
        }
        out
    }

    fn matmul_batch_into(&self, x: &HostTensor, pool: &WorkerPool,
                         out_t: &mut Vec<f32>, out: &mut HostTensor) {
        // Correct but per-call-allocating stage; the serve hot path
        // uses `matmul_batch_into_fused` with a persistent stage slab.
        let mut stage = HostTensor::zeros(vec![0, 0]);
        self.matmul_batch_into_fused(x, pool, out_t, &mut stage, out);
    }

    fn dequant(&self) -> HostTensor {
        let k = self.in_features();
        let total = self.out_features();
        let mut data = Vec::with_capacity(total * k);
        for p in &self.parts {
            data.extend_from_slice(&p.dequant().data);
        }
        HostTensor::new(vec![total, k], data)
    }

    fn effective_bits_per_param(&self) -> f64 {
        // Params-weighted mean over the parts (each part accounts its
        // own scale overhead, exactly as when unfused).
        let mut bits = 0.0f64;
        let mut params = 0.0f64;
        for p in &self.parts {
            let n = (p.out_features() * p.in_features()) as f64;
            bits += p.effective_bits_per_param() * n;
            params += n;
        }
        bits / params.max(1.0)
    }

    fn label(&self) -> String {
        self.parts[0].label()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::QuantTensor;
    use crate::ternary::TernaryTensor;

    fn formats(rows: usize, cols: usize, seed: u64)
               -> (DenseF32, PackedMatrix, QuantPacked) {
        let w = HostTensor::randn(vec![rows, cols], 0.05, seed);
        let pm = PackedMatrix::from_ternary(&TernaryTensor::from_latent(&w, 1));
        let qp = QuantPacked::from_quant(&QuantTensor::quantize_rtn(&w, 4, 32));
        (DenseF32 { w }, pm, qp)
    }

    #[test]
    fn all_formats_agree_with_their_own_dequant() {
        // The trait contract: matmul_batch == matmul_dense(x, dequant()).
        let (d, pm, qp) = formats(24, 36, 3);
        let x = HostTensor::randn(vec![4, 36], 1.0, 4);
        let fmts: [&dyn LinearFormat; 3] = [&d, &pm, &qp];
        for f in fmts {
            assert_eq!(f.out_features(), 24);
            assert_eq!(f.in_features(), 36);
            let got = f.matmul_batch(&x, 2);
            let want = matmul_dense(&x, &f.dequant());
            assert_eq!(got.shape, vec![4, 24]);
            for (a, b) in got.data.iter().zip(want.data.iter()) {
                assert!((a - b).abs() < 1e-3, "{}: {a} vs {b}", f.label());
            }
        }
    }

    #[test]
    fn matmul_batch_into_matches_allocating_path_bitwise() {
        // The trait contract the pooled scheduler rides on: the
        // scratch-aware path must be indistinguishable from the
        // allocating one, for every storage format, reusing one
        // scratch across formats and shapes.
        let (d, pm, qp) = formats(24, 36, 7);
        let pool = WorkerPool::new(3);
        let mut out_t = Vec::new();
        let mut out = HostTensor::zeros(vec![0, 0]);
        let fmts: [&dyn LinearFormat; 3] = [&d, &pm, &qp];
        for m in [1usize, 4, 8] {
            let x = HostTensor::randn(vec![m, 36], 1.0, 8 + m as u64);
            for f in fmts {
                let want = f.matmul_batch(&x, pool.threads());
                f.matmul_batch_into(&x, &pool, &mut out_t, &mut out);
                assert_eq!(out.shape, want.shape, "{} m{m}", f.label());
                assert_eq!(out.data, want.data, "{} m{m}", f.label());
            }
        }
    }

    #[test]
    fn bit_budgets_order_across_families() {
        // The Table 4 ordering, now queryable through one API.
        let (d, pm, qp) = formats(32, 64, 5);
        assert!(d.effective_bits_per_param()
                    > qp.effective_bits_per_param());
        assert!(qp.effective_bits_per_param()
                    > pm.effective_bits_per_param());
        assert_eq!(d.label(), "fp32");
        assert_eq!(pm.label(), "ternary");
        assert_eq!(qp.label(), "q4g32");
    }

    #[test]
    fn ternary_dequant_matches_tensor_dequant() {
        let w = HostTensor::randn(vec![10, 14], 0.05, 6);
        let t = TernaryTensor::from_latent(&w, 2);
        let pm = PackedMatrix::from_ternary(&t);
        assert_eq!(LinearFormat::dequant(&pm).data, t.dequant().data);
    }

    #[test]
    fn fused_matmul_is_bitwise_the_stacked_parts_in_every_format() {
        // The fusion contract the attention refactor rides on: one
        // fused dispatch == the unfused per-part dispatches, bitwise,
        // for dense, ternary, and quant storage alike, and on both the
        // allocating and the pooled staged path.
        let pool = WorkerPool::new(3);
        let k = 36;
        let mk = |rows: usize, seed: u64| {
            HostTensor::randn(vec![rows, k], 0.05, seed)
        };
        let dense = FusedLinear::new(vec![
            DenseF32 { w: mk(24, 1) },
            DenseF32 { w: mk(8, 2) },
            DenseF32 { w: mk(8, 3) },
        ]);
        let tern = FusedLinear::new(vec![
            PackedMatrix::from_ternary(&TernaryTensor::from_latent(&mk(24, 1), 1)),
            PackedMatrix::from_ternary(&TernaryTensor::from_latent(&mk(8, 2), 1)),
            PackedMatrix::from_ternary(&TernaryTensor::from_latent(&mk(8, 3), 1)),
        ]);
        let quant = FusedLinear::new(vec![
            QuantPacked::from_quant(&QuantTensor::quantize_rtn(&mk(24, 1), 4, 32)),
            QuantPacked::from_quant(&QuantTensor::quantize_rtn(&mk(8, 2), 4, 32)),
            QuantPacked::from_quant(&QuantTensor::quantize_rtn(&mk(8, 3), 4, 32)),
        ]);
        let x = HostTensor::randn(vec![5, k], 1.0, 9);

        fn check<L: LinearFormat>(f: &FusedLinear<L>, x: &HostTensor,
                                  pool: &WorkerPool) {
            assert_eq!(f.out_features(), 40);
            assert_eq!(f.in_features(), x.dims2().1);
            assert_eq!(f.part_offset(0), 0);
            assert_eq!(f.part_offset(1), 24);
            assert_eq!(f.part_offset(2), 32);
            let fused = f.matmul_batch(x, pool.threads());
            // Unfused reference: each part separately, stacked columns.
            let mut off = 0usize;
            for p in f.parts() {
                let y = p.matmul_batch(x, pool.threads());
                for r in 0..x.dims2().0 {
                    assert_eq!(&fused.row(r)[off..off + p.out_features()],
                               y.row(r), "{} part at {off}", f.label());
                }
                off += p.out_features();
            }
            // Pooled staged path == allocating path, bitwise.
            let (mut out_t, mut stage) = (Vec::new(), HostTensor::zeros(vec![0, 0]));
            let mut out = HostTensor::zeros(vec![0, 0]);
            f.matmul_batch_into_fused(x, pool, &mut out_t, &mut stage, &mut out);
            assert_eq!(out.shape, fused.shape);
            assert_eq!(out.data, fused.data, "{} pooled", f.label());
        }
        check(&dense, &x, &pool);
        check(&tern, &x, &pool);
        check(&quant, &x, &pool);
    }

    #[test]
    fn fused_bits_are_the_params_weighted_mean_of_the_parts() {
        let w_big = HostTensor::randn(vec![32, 16], 0.05, 11);
        let w_small = HostTensor::randn(vec![8, 16], 0.05, 12);
        let f = FusedLinear::new(vec![DenseF32 { w: w_big },
                                      DenseF32 { w: w_small }]);
        assert_eq!(f.effective_bits_per_param(), 32.0);
        assert_eq!(f.label(), "fp32");
        // Row-stacked dequant == concatenated part dequants.
        let d = LinearFormat::dequant(&f);
        assert_eq!(d.shape, vec![40, 16]);
        assert_eq!(&d.data[..32 * 16], &f.parts()[0].dequant().data[..]);
        assert_eq!(&d.data[32 * 16..], &f.parts()[1].dequant().data[..]);
    }
}
