//! Blocked, multi-threaded k-bit group-quantized matmul — the QuantLM
//! serving kernel.
//!
//! [`QuantPacked`] is the serving twin of [`crate::quant::QuantTensor`]:
//! the same signed k-bit values, but stored as a *row-aligned*
//! [`pack_kbit`] bitstream (every row starts on a byte boundary) so the
//! kernel can stream per-row byte ranges and worker threads can
//! partition rows without bit-offset bookkeeping across rows.
//!
//! [`matmul_quant_packed`] follows the same tiling and numerical
//! contract as the ternary serving kernel
//! ([`crate::ternary::matmul_ternary_packed`]):
//!
//! - weights walk in [`ROW_BLOCK`]-row blocks by column panels of
//!   [`COL_BLOCK_VALS`] values (rounded to a multiple of the quant
//!   group so scale groups never straddle a panel), with the x panel
//!   transposed once per (row-block, panel) so each decoded weight
//!   updates all batch lanes with one broadcast multiply-add;
//! - zero quant values are skipped (the symmetric grid's zero level);
//! - per output element, accumulation runs group-by-group in column
//!   order into a group accumulator, then folds in via one multiply by
//!   the group scale — an order fixed by `k` alone, so results are
//!   bitwise invariant to both the batch size and the thread count
//!   (`tests/kernel_equivalence.rs` locks this in);
//! - rows are partitioned across workers with disjoint transposed
//!   output slabs, capped by
//!   [`crate::ternary::matmul::MIN_WORK_PER_THREAD`].
//!
//! Execution substrates mirror the ternary kernel exactly:
//! [`matmul_quant_packed`] is the scoped-thread compatibility wrapper
//! (fresh spawns + fresh output per call); [`matmul_quant_packed_into`]
//! is the serving hot path — it dispatches the *same* row partition
//! onto a persistent [`crate::runtime::WorkerPool`] and reuses a
//! caller-owned accumulation slab and output tensor
//! ([`crate::runtime::DecodeScratch`] threads them down from the
//! scheduler). Per-worker decode scratch (the transposed x panel, the
//! i8 value buffer, the per-group accumulator) is thread-local and
//! persists across calls because pool workers are long-lived. Pooled
//! and scoped execution are bitwise identical at every thread count
//! (`tests/pool_equivalence.rs`).

use std::cell::RefCell;

use crate::quant::{pack_kbit, QuantTensor};
use crate::runtime::{HostTensor, WorkerPool};
use crate::ternary::matmul::{blocked_rows_driver, blocked_rows_driver_pooled,
                             COL_BLOCK_TRITS, ROW_BLOCK};

/// Values per column panel — the quant analog of [`COL_BLOCK_TRITS`]
/// (same L1-residency sizing; the effective panel is rounded to a
/// multiple of the group so a scale group never straddles panels).
pub const COL_BLOCK_VALS: usize = COL_BLOCK_TRITS;

/// A row-aligned k-bit group-quantized weight matrix: the storage the
/// QuantLM serving path streams.
#[derive(Debug, Clone, PartialEq)]
pub struct QuantPacked {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    /// Caller-requested group size (ragged final group per row when
    /// `cols % group != 0`; recorded verbatim, see `quant/`).
    pub group: usize,
    /// `(cols * bits).div_ceil(8)` — each row's byte footprint.
    pub bytes_per_row: usize,
    /// `rows * bytes_per_row` bytes; row `r`'s bitstream is
    /// `bytes[r*bytes_per_row..(r+1)*bytes_per_row]`, values packed
    /// LSB-first exactly as [`pack_kbit`] emits them.
    pub bytes: Vec<u8>,
    /// One scale per (row, group): rows * cols.div_ceil(group).
    pub scales: Vec<f32>,
}

impl QuantPacked {
    /// Re-pack a [`QuantTensor`] (RTN or GPTQ output) row-aligned for
    /// the serving kernel.
    pub fn from_quant(t: &QuantTensor) -> Self {
        assert!((2..=8).contains(&t.bits), "serving supports 2..=8 bits");
        let bytes_per_row = (t.cols * t.bits as usize).div_ceil(8);
        let mut bytes = Vec::with_capacity(t.rows * bytes_per_row);
        for r in 0..t.rows {
            let row = &t.q[r * t.cols..(r + 1) * t.cols];
            let packed = pack_kbit(row, t.bits);
            debug_assert_eq!(packed.len(), bytes_per_row);
            bytes.extend_from_slice(&packed);
        }
        QuantPacked {
            rows: t.rows,
            cols: t.cols,
            bits: t.bits,
            group: t.group,
            bytes_per_row,
            bytes,
            scales: t.scales.clone(),
        }
    }

    /// Scale groups per row (uniform width, ragged final group).
    #[inline]
    pub fn n_groups(&self) -> usize {
        QuantTensor::n_groups(self.cols, self.group)
    }

    /// Decode `len` values of row `r` starting at value index `start`
    /// into `out[..len]`. A value spans at most two bytes (bits <= 8),
    /// read LSB-first to mirror [`pack_kbit`].
    pub fn decode_row_range(&self, r: usize, start: usize, len: usize,
                            out: &mut [i8]) {
        debug_assert!(start + len <= self.cols);
        let bits = self.bits as usize;
        let qmax = (1i32 << (bits - 1)) - 1;
        let mask = (1u32 << bits) - 1;
        let row = &self.bytes[r * self.bytes_per_row..(r + 1) * self.bytes_per_row];
        let mut bitpos = start * bits;
        for o in out[..len].iter_mut() {
            let byte = bitpos / 8;
            let shift = bitpos % 8;
            let lo = (row[byte] as u32) >> shift;
            let have = 8 - shift;
            let v = if have >= bits {
                lo & mask
            } else {
                (lo | ((row[byte + 1] as u32) << have)) & mask
            };
            *o = (v as i32 - qmax) as i8;
            bitpos += bits;
        }
    }

    /// Dequantize to f32 (the kernel-equivalence reference path).
    pub fn dequant(&self) -> HostTensor {
        let ng = self.n_groups();
        let mut qrow = vec![0i8; self.cols];
        let mut data = Vec::with_capacity(self.rows * self.cols);
        for r in 0..self.rows {
            self.decode_row_range(r, 0, self.cols, &mut qrow);
            for (c, &qv) in qrow.iter().enumerate() {
                data.push(qv as f32 * self.scales[r * ng + c / self.group]);
            }
        }
        HostTensor::new(vec![self.rows, self.cols], data)
    }

    /// Effective bits per parameter with the paper's fp16-scale
    /// accounting (§4.2) — honest under ragged groups.
    pub fn effective_bits(&self) -> f64 {
        self.bits as f64 + 16.0 * self.n_groups() as f64 / self.cols as f64
    }
}

/// Per-thread quant decode scratch: the transposed x panel, the
/// bitstream-decoded i8 values of one row-panel, and the per-group
/// accumulator. Thread-local for the same reason as the ternary
/// kernel's panel scratch: pool workers are long-lived, so steady-state
/// decode steps never allocate here. Every buffer is written before it
/// is read within one panel/group, so stale contents cannot leak.
#[derive(Default)]
struct QuantScratch {
    x_t: Vec<f32>,
    qbuf: Vec<i8>,
    gacc: Vec<f32>,
}

fn with_quant_scratch<R>(x_t_len: usize, qbuf_len: usize, gacc_len: usize,
                         f: impl FnOnce(&mut [f32], &mut [i8], &mut [f32]) -> R)
                         -> R {
    thread_local! {
        static SCRATCH: RefCell<QuantScratch> =
            RefCell::new(QuantScratch::default());
    }
    SCRATCH.with(|s| {
        let mut s = s.borrow_mut();
        let QuantScratch { x_t, qbuf, gacc } = &mut *s;
        if x_t.len() < x_t_len {
            x_t.resize(x_t_len, 0.0);
        }
        if qbuf.len() < qbuf_len {
            qbuf.resize(qbuf_len, 0);
        }
        if gacc.len() < gacc_len {
            gacc.resize(gacc_len, 0.0);
        }
        f(&mut x_t[..x_t_len], &mut qbuf[..qbuf_len], &mut gacc[..gacc_len])
    })
}

/// The blocked quant-decode kernel body for w-rows `[r0, r1)`.
///
/// `out_t` is the (rows, m)-transposed output slab for this row range
/// (it must arrive zeroed), mirroring the ternary kernel. Per
/// (row-block, panel) the x block is transposed into `(k-panel, m)`
/// thread-local scratch; per row the panel's values are
/// bitstream-decoded once into an i8 scratch, then accumulated
/// group-by-group (group accumulator x group scale).
fn quant_rows_kernel(w: &QuantPacked, x: &HostTensor,
                     r0: usize, r1: usize, out_t: &mut [f32]) {
    let (m, k) = x.dims2();
    // Effective group width never exceeds k (a wider caller group is a
    // single ragged group); the panel is the largest multiple of the
    // group near COL_BLOCK_VALS so groups never straddle panels.
    let group = w.group.min(k).max(1);
    let panel = if group >= COL_BLOCK_VALS {
        group
    } else {
        (COL_BLOCK_VALS / group) * group
    };
    with_quant_scratch(panel * m, panel, m, |x_t, qbuf, gacc| {
        quant_rows_body(w, x, r0, r1, out_t, group, panel, x_t, qbuf, gacc)
    })
}

/// [`quant_rows_kernel`] with all scratch passed explicitly.
#[allow(clippy::too_many_arguments)]
fn quant_rows_body(w: &QuantPacked, x: &HostTensor,
                   r0: usize, r1: usize, out_t: &mut [f32],
                   group: usize, panel: usize,
                   x_t: &mut [f32], qbuf: &mut [i8], gacc: &mut [f32]) {
    let (m, k) = x.dims2();
    debug_assert_eq!(k, w.cols);
    debug_assert_eq!(out_t.len(), (r1 - r0) * m);
    let ng = w.n_groups();
    for rb in (r0..r1).step_by(ROW_BLOCK) {
        let rb_end = (rb + ROW_BLOCK).min(r1);
        let mut kb = 0usize;
        while kb < k {
            let kb_end = (kb + panel).min(k);
            let cb = kb_end - kb;
            // Transpose the x panel once; reused by every row in the block.
            for (c, col) in x_t.chunks_exact_mut(m).take(cb).enumerate() {
                for (mi, v) in col.iter_mut().enumerate() {
                    *v = x.data[mi * k + kb + c];
                }
            }
            for r in rb..rb_end {
                w.decode_row_range(r, kb, cb, qbuf);
                let acc = &mut out_t[(r - r0) * m..(r - r0 + 1) * m];
                let mut c0 = 0usize;
                while c0 < cb {
                    let c1 = (c0 + group).min(cb);
                    let g_global = (kb + c0) / group;
                    for a in gacc.iter_mut() {
                        *a = 0.0;
                    }
                    for (j, &qv) in qbuf[c0..c1].iter().enumerate() {
                        if qv == 0 {
                            continue; // zero level of the symmetric grid
                        }
                        let t = qv as f32;
                        let xs = &x_t[(c0 + j) * m..(c0 + j + 1) * m];
                        for (a, &xv) in gacc.iter_mut().zip(xs) {
                            *a += t * xv;
                        }
                    }
                    let s = w.scales[r * ng + g_global];
                    for (a, &gv) in acc.iter_mut().zip(gacc.iter()) {
                        *a += s * gv;
                    }
                    c0 = c1;
                }
            }
            kb = kb_end;
        }
    }
}

/// Batched k-bit group-quantized matmul: y = x @ w_packed^T with
/// per-group scales. x: (m, k), w: (n, k) packed -> (m, n).
///
/// Threading via the shared `blocked_rows_driver` scaffold in
/// `ternary::matmul` (identical partitioning and
/// [`crate::ternary::matmul::MIN_WORK_PER_THREAD`] capping as the ternary
/// kernel). Accumulation order per output element is fixed by `k`
/// alone — independent of `threads` and `m` — so results are bitwise
/// batch- and thread-invariant.
pub fn matmul_quant_packed(x: &HostTensor, w: &QuantPacked,
                           threads: usize) -> HostTensor {
    let (m, k) = x.dims2();
    assert_eq!(k, w.cols, "x cols {k} != packed weight cols {}", w.cols);
    blocked_rows_driver(m, k, w.rows, threads,
                        |r0, r1, slab| quant_rows_kernel(w, x, r0, r1, slab))
}

/// Allocation-free batched k-bit quant matmul: identical math and
/// partitioning to [`matmul_quant_packed`] (results are bitwise equal
/// at the pool's thread count), but executed on a persistent
/// [`WorkerPool`] with the accumulation slab and output tensor reused
/// from caller-owned scratch.
pub fn matmul_quant_packed_into(x: &HostTensor, w: &QuantPacked,
                                pool: &WorkerPool, out_t: &mut Vec<f32>,
                                out: &mut HostTensor) {
    let (m, k) = x.dims2();
    assert_eq!(k, w.cols, "x cols {k} != packed weight cols {}", w.cols);
    blocked_rows_driver_pooled(
        m, k, w.rows, pool, out_t, out,
        |r0, r1, slab| quant_rows_kernel(w, x, r0, r1, slab));
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::ternary::matmul_dense;

    fn quantized(rows: usize, cols: usize, bits: u32, group: usize,
                 seed: u64) -> (QuantTensor, QuantPacked) {
        let w = HostTensor::randn(vec![rows, cols], 0.05, seed);
        let qt = QuantTensor::quantize_rtn(&w, bits, group);
        let qp = QuantPacked::from_quant(&qt);
        (qt, qp)
    }

    #[test]
    fn packed_dequant_matches_quant_tensor_bitwise() {
        for (rows, cols, bits, group) in
            [(8usize, 32usize, 4u32, 16usize), (5, 21, 3, 8), (3, 130, 4, 128)]
        {
            let (qt, qp) = quantized(rows, cols, bits, group, 7);
            assert_eq!(qp.dequant().data, qt.dequant().data,
                       "{rows}x{cols} b{bits} g{group}");
        }
    }

    #[test]
    fn decode_row_range_matches_full_unpack_at_any_offset() {
        // Mid-row decode starts at arbitrary (non-byte-aligned) bit
        // offsets; every (start, len) window must agree with the full
        // row decode.
        let (qt, qp) = quantized(3, 37, 3, 16, 9);
        for r in 0..3 {
            let full: Vec<i8> = qt.q[r * 37..(r + 1) * 37].to_vec();
            let mut buf = vec![0i8; 37];
            for start in 0..37 {
                for len in [0usize, 1, 5, 37 - start] {
                    qp.decode_row_range(r, start, len, &mut buf);
                    assert_eq!(&buf[..len], &full[start..start + len],
                               "row {r} start {start} len {len}");
                }
            }
        }
    }

    #[test]
    fn quant_matmul_matches_dequant_reference() {
        for (rows, cols, bits, group) in [
            (16usize, 32usize, 4u32, 16usize),
            (33, 64, 3, 128), // single ragged group per row
            (7, 130, 4, 128), // ragged final group
            (ROW_BLOCK + 9, COL_BLOCK_VALS + 37, 3, 128), // spans tiles
        ] {
            let (qt, qp) = quantized(rows, cols, bits, group, 11);
            let dq = qt.dequant();
            for m in [1usize, 3, 8] {
                let x = HostTensor::randn(vec![m, cols], 1.0, 13 + m as u64);
                let want = matmul_dense(&x, &dq);
                for threads in [1usize, 2, 5] {
                    let got = matmul_quant_packed(&x, &qp, threads);
                    assert_eq!(got.shape, vec![m, rows]);
                    for (a, b) in got.data.iter().zip(want.data.iter()) {
                        assert!((a - b).abs() < 1e-3,
                                "{rows}x{cols} b{bits} g{group} m{m} \
                                 t{threads}: {a} vs {b}");
                    }
                }
            }
        }
    }

    #[test]
    fn quant_matmul_is_batch_and_thread_invariant() {
        let (_, qp) = quantized(40, 150, 4, 128, 17);
        let xb = HostTensor::randn(vec![8, 150], 1.0, 18);
        let reference = matmul_quant_packed(&xb, &qp, 1);
        for threads in [2usize, 3, 8] {
            let got = matmul_quant_packed(&xb, &qp, threads);
            assert_eq!(got.data, reference.data, "threads={threads}");
        }
        for mi in 0..8 {
            let x1 = HostTensor::stack_rows(&[xb.row(mi)]);
            let solo = matmul_quant_packed(&x1, &qp, 4);
            assert_eq!(solo.data, reference.row(mi), "lane {mi}");
        }
    }

    #[test]
    fn pooled_quant_matmul_is_bitwise_identical_to_scoped() {
        use crate::runtime::WorkerPool;
        let (_, qp) = quantized(ROW_BLOCK + 9, COL_BLOCK_VALS + 37, 3, 128,
                                23);
        let mut out_t = Vec::new();
        let mut out = HostTensor::zeros(vec![0, 0]);
        for threads in [1usize, 2, 5] {
            let pool = WorkerPool::new(threads);
            for m in [1usize, 8] {
                let x = HostTensor::randn(vec![m, qp.cols], 1.0,
                                          29 ^ (m as u64));
                let want = matmul_quant_packed(&x, &qp, threads);
                matmul_quant_packed_into(&x, &qp, &pool, &mut out_t,
                                         &mut out);
                assert_eq!(out.shape, want.shape, "t{threads} m{m}");
                assert_eq!(out.data, want.data, "t{threads} m{m}");
            }
        }
    }

    #[test]
    fn effective_bits_accounting() {
        let (_, qp) = quantized(4, 128, 3, 128, 19);
        assert!((qp.effective_bits() - 3.125).abs() < 1e-9);
        let (_, ragged) = quantized(4, 130, 3, 128, 19);
        assert!((ragged.effective_bits() - (3.0 + 32.0 / 130.0)).abs() < 1e-9);
    }
}
