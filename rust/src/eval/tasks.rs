//! Synthetic downstream benchmark tasks — the stand-ins for the paper's
//! evaluation suite (§5, Appendix D), built from the same [`World`] the
//! training corpus renders, so they are *learnable* and family/size
//! trends are measurable:
//!
//! - [`TaskKind::Cloze`]      ~ LAMBADA: predict a narrative's final word
//!   that only long-range context determines.
//! - [`TaskKind::PatternMcq`] ~ ARC/PIQA/HellaSwag: pick the consequent
//!   of a commonsense implication among distractors.
//! - [`TaskKind::FactMcq`]    ~ SciQ/MMLU: pick the value of a world fact
//!   among distractor values.
//! - [`TaskKind::FactRecall`] ~ TriviaQA (EM): produce the fact value —
//!   scored as argmax over the full value vocabulary (the exact-match
//!   analog when the answer space is closed).
//! - [`TaskKind::StereoPairs`] ~ CrowS-Pairs: likelihood preference for
//!   the corpus-biased attribute assertion over its counterfactual;
//!   the "pct stereotype" score.


use crate::data::corpus::{ATTRIBUTES, RELATIONS};
use crate::data::World;
use crate::runtime::SplitMix64;

#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum TaskKind {
    Cloze,
    PatternMcq,
    FactMcq,
    FactRecall,
    StereoPairs,
}

impl TaskKind {
    pub const ALL: [TaskKind; 5] = [TaskKind::Cloze, TaskKind::PatternMcq,
                                    TaskKind::FactMcq, TaskKind::FactRecall,
                                    TaskKind::StereoPairs];

    pub fn as_str(self) -> &'static str {
        match self {
            TaskKind::Cloze => "cloze",
            TaskKind::PatternMcq => "pattern_mcq",
            TaskKind::FactMcq => "fact_mcq",
            TaskKind::FactRecall => "fact_recall",
            TaskKind::StereoPairs => "stereo_pairs",
        }
    }

    /// The paper benchmark this task is the analog of.
    pub fn paper_analog(self) -> &'static str {
        match self {
            TaskKind::Cloze => "LAMBADA",
            TaskKind::PatternMcq => "ARC/PIQA/HellaSwag (C&R avg)",
            TaskKind::FactMcq => "SciQ / MMLU",
            TaskKind::FactRecall => "TriviaQA",
            TaskKind::StereoPairs => "CrowS-Pairs",
        }
    }
}

/// One zero-shot item: a context and scored continuations.
/// `answer` indexes the correct choice. For StereoPairs, choice 0 is the
/// corpus-biased ("stereotype") continuation and `answer` is 0 — the
/// *score* for stereo tasks is preference rate, not accuracy.
#[derive(Debug, Clone)]
pub struct TaskItem {
    pub context: String,
    pub choices: Vec<String>,
    pub answer: usize,
}

/// Generate `n` items of the given kind from the world, seeded.
pub fn generate(world: &World, kind: TaskKind, n: usize, seed: u64) -> Vec<TaskItem> {
    let mut rng = SplitMix64::new(seed ^ (kind as u64) << 48);
    (0..n).map(|_| one_item(world, kind, &mut rng)).collect()
}

fn distinct_indices(rng: &mut SplitMix64, n: usize, count: usize,
                    exclude: usize) -> Vec<usize> {
    let mut out = Vec::with_capacity(count);
    while out.len() < count {
        let i = rng.below(n);
        if i != exclude && !out.contains(&i) {
            out.push(i);
        }
    }
    out
}

fn one_item(world: &World, kind: TaskKind, rng: &mut SplitMix64) -> TaskItem {
    match kind {
        TaskKind::Cloze => {
            // Same narrative frame the Book domain trains on.
            let hi = rng.below(world.entities.len());
            let hero = &world.entities[hi];
            let filler = &world.content_words[rng.below(world.content_words.len())];
            let context = format!(
                "one day {hero} walked to the old bridge . the {filler} waited . \
                 at the end of the long road stood");
            let mut choices = vec![format!(" {hero}")];
            for d in distinct_indices(rng, world.entities.len(), 3, hi) {
                choices.push(format!(" {}", world.entities[d]));
            }
            TaskItem { context, choices, answer: 0 }
        }
        TaskKind::PatternMcq => {
            let pi = rng.below(world.patterns.len());
            let p = &world.patterns[pi];
            let context = format!("if {} , then", p.cause);
            let mut choices = vec![format!(" {}", p.effect)];
            for d in distinct_indices(rng, world.patterns.len(), 3, pi) {
                choices.push(format!(" {}", world.patterns[d].effect));
            }
            TaskItem { context, choices, answer: 0 }
        }
        TaskKind::FactMcq => {
            let f = &world.facts[rng.below(world.facts.len())];
            let (pre, mid) = RELATIONS[f.relation];
            let context = format!("{pre} {} {mid}", f.entity);
            let vi = world.values.iter().position(|v| *v == f.value).unwrap();
            let mut choices = vec![format!(" {}", f.value)];
            for d in distinct_indices(rng, world.values.len(), 3, vi) {
                choices.push(format!(" {}", world.values[d]));
            }
            TaskItem { context, choices, answer: 0 }
        }
        TaskKind::FactRecall => {
            let f = &world.facts[rng.below(world.facts.len())];
            let (pre, mid) = RELATIONS[f.relation];
            let context = format!("{pre} {} {mid}", f.entity);
            // Closed answer space: every value is a candidate; "exact
            // match" = the true value wins argmax.
            let vi = world.values.iter().position(|v| *v == f.value).unwrap();
            let mut choices: Vec<String> =
                world.values.iter().map(|v| format!(" {v}")).collect();
            choices.swap(0, vi);
            TaskItem { context, choices, answer: 0 }
        }
        TaskKind::StereoPairs => {
            let i = rng.below(world.entities.len());
            let biased = ATTRIBUTES[world.attributes[i]];
            let counter = ATTRIBUTES[1 - world.attributes[i]];
            let context = format!("everyone says that {} is very",
                                  world.entities[i]);
            TaskItem {
                context,
                choices: vec![format!(" {biased}"), format!(" {counter}")],
                answer: 0,
            }
        }
    }
}

/// Serving-traffic prompts for the decode engine: contexts drawn from
/// the same task generator the eval harness scores, cycling over task
/// kinds so a serve benchmark sees the corpus' real prompt mix (short
/// fact queries through long cloze narratives) rather than random
/// token soup. Deterministic in `seed`.
pub fn serve_prompts(world: &World, n: usize, seed: u64) -> Vec<String> {
    let kinds = [TaskKind::Cloze, TaskKind::PatternMcq, TaskKind::FactMcq,
                 TaskKind::StereoPairs];
    let mut rng = SplitMix64::new(seed ^ 0x5E47E);
    (0..n)
        .map(|i| one_item(world, kinds[i % kinds.len()], &mut rng).context)
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn serve_prompts_are_deterministic_and_nonempty() {
        let w = World::new(1);
        let a = serve_prompts(&w, 9, 4);
        let b = serve_prompts(&w, 9, 4);
        assert_eq!(a, b);
        assert_eq!(a.len(), 9);
        assert!(a.iter().all(|p| !p.is_empty()));
        // The mix cycles task kinds: not all prompts identical.
        assert!(a.iter().any(|p| p != &a[0]));
    }

    #[test]
    fn items_have_valid_answers() {
        let w = World::new(1);
        for kind in TaskKind::ALL {
            let items = generate(&w, kind, 16, 3);
            assert_eq!(items.len(), 16);
            for it in items {
                assert!(it.answer < it.choices.len());
                assert!(it.choices.len() >= 2);
                // choices must be distinct
                let mut c = it.choices.clone();
                c.sort();
                c.dedup();
                assert_eq!(c.len(), it.choices.len(), "{:?}", it.choices);
            }
        }
    }

    #[test]
    fn generation_is_deterministic() {
        let w = World::new(1);
        let a = generate(&w, TaskKind::FactMcq, 8, 5);
        let b = generate(&w, TaskKind::FactMcq, 8, 5);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.context, y.context);
            assert_eq!(x.choices, y.choices);
            assert_eq!(x.answer, y.answer);
        }
    }

    #[test]
    fn fact_mcq_answer_matches_world() {
        let w = World::new(1);
        for it in generate(&w, TaskKind::FactMcq, 32, 7) {
            // Recover the entity from the context and check the gold
            // choice is the world's fact value.
            let value = it.choices[it.answer].trim();
            assert!(w.facts.iter().any(|f| f.value == value),
                    "{value} not a fact value");
        }
    }

    #[test]
    fn recall_has_full_value_space() {
        let w = World::new(1);
        let items = generate(&w, TaskKind::FactRecall, 4, 9);
        for it in items {
            assert_eq!(it.choices.len(), w.values.len());
        }
    }

    #[test]
    fn cloze_answer_is_the_narrative_hero() {
        let w = World::new(1);
        for it in generate(&w, TaskKind::Cloze, 16, 11) {
            let hero = it.context.split_whitespace().nth(2).unwrap();
            assert_eq!(it.choices[it.answer].trim(), hero);
        }
    }
}
