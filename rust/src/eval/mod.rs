//! Evaluation: perplexity + the zero-shot downstream benchmark harness.
//!
//! Scoring follows lm-eval-harness (the paper's §D evaluation tool):
//! each multiple-choice option is scored by the sum of its tokens'
//! log-probabilities given the context (plus a length-normalized
//! variant, `acc_norm`); cloze/recall use the same machinery. All
//! scoring runs through the AOT-compiled `eval` graph — Rust composes
//! the padded token batches and masks.

pub mod tasks;

pub use tasks::{generate, serve_prompts, TaskItem, TaskKind};

use crate::data::Bpe;
use crate::runtime::{self, Graph, Runtime};
use crate::Result;

/// Wraps a model's compiled `eval` graph for batched logprob queries.
pub struct Evaluator {
    graph: Graph,
    pub batch: usize,
    pub seq: usize,
}

impl Evaluator {
    pub fn new(rt: &Runtime, model: &str) -> Result<Self> {
        let graph = rt.load_graph(model, "eval")?;
        Ok(Evaluator { graph, batch: rt.manifest().eval_batch,
                       seq: rt.manifest().seq })
    }

    /// Per-position target logprobs for a (batch, seq+1) token block:
    /// out[b][i] = log p(tokens[b][i+1] | tokens[b][..=i]).
    pub fn logprobs(&self, params: &[xla::Literal], tokens: &[i32])
                    -> Result<Vec<Vec<f32>>> {
        assert_eq!(tokens.len(), self.batch * (self.seq + 1));
        let toks = runtime::literal_i32(&[self.batch, self.seq + 1], tokens)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&toks);
        let outs = self.graph.run(&args)?;
        let t = runtime::tensor_from_literal(&outs[0])?;
        Ok((0..self.batch).map(|b| t.row(b).to_vec()).collect())
    }

    /// Mean negative log-likelihood per token over a stream (perplexity
    /// = exp of this). Deterministically chunks the stream into windows.
    pub fn nll(&self, params: &[xla::Literal], tokens: &[u32]) -> Result<f64> {
        let stride = self.seq + 1;
        let n_chunks = tokens.len() / stride;
        assert!(n_chunks > 0, "token stream shorter than one window");
        let mut total = 0.0f64;
        let mut count = 0usize;
        let mut chunk_idx = 0;
        while chunk_idx < n_chunks {
            let rows = self.batch.min(n_chunks - chunk_idx);
            let mut block = Vec::with_capacity(self.batch * stride);
            for r in 0..self.batch {
                let c = if r < rows { chunk_idx + r } else { chunk_idx }; // pad rows repeat
                block.extend(tokens[c * stride..(c + 1) * stride].iter()
                    .map(|&t| t as i32));
            }
            let lp = self.logprobs(params, &block)?;
            for row in lp.iter().take(rows) {
                for &l in row {
                    total -= l as f64;
                    count += 1;
                }
            }
            chunk_idx += rows;
        }
        Ok(total / count as f64)
    }

    /// Score one MCQ item: returns (sum_logprob, mean_logprob) per choice.
    /// Items whose tokenized context+choice exceed the window are
    /// truncated from the left (lm-eval behavior).
    pub fn score_choices(&self, params: &[xla::Literal], bpe: &Bpe,
                         item: &TaskItem) -> Result<Vec<(f64, f64)>> {
        // Build one padded row per choice; run in batches of `self.batch`.
        let stride = self.seq + 1;
        let mut rows: Vec<(Vec<i32>, usize, usize)> = Vec::new(); // (tokens, start, len)
        for choice in &item.choices {
            let ctx = bpe.encode(&item.context);
            let cho = bpe.encode(choice);
            let mut toks: Vec<i32> = ctx.iter().chain(cho.iter())
                .map(|&t| t as i32).collect();
            let keep = stride.min(toks.len());
            let dropped = toks.len() - keep;
            toks.drain(..dropped);
            // choice token span within the (possibly truncated) row
            let cho_start = ctx.len().saturating_sub(dropped);
            let cho_len = cho.len().min(keep.saturating_sub(cho_start));
            let pad_to = stride;
            toks.resize(pad_to, 0);
            rows.push((toks, cho_start, cho_len));
        }
        let mut scores = Vec::with_capacity(rows.len());
        for group in rows.chunks(self.batch) {
            let mut block = Vec::with_capacity(self.batch * stride);
            for r in 0..self.batch {
                let row = &group[r.min(group.len() - 1)].0;
                block.extend_from_slice(row);
            }
            let lp = self.logprobs(params, &block)?;
            for (r, (_, start, len)) in group.iter().enumerate() {
                // logprob index i predicts token i+1, so choice tokens
                // at positions [start, start+len) are predicted by
                // logprobs [start-1, start+len-1).
                let (mut sum, mut n) = (0.0f64, 0usize);
                for i in start.saturating_sub(1)..(start + len).saturating_sub(1) {
                    sum += lp[r][i] as f64;
                    n += 1;
                }
                scores.push((sum, sum / n.max(1) as f64));
            }
        }
        Ok(scores)
    }
}

/// Aggregate result of one task over one model.
#[derive(Debug, Clone)]
pub struct TaskScore {
    pub task: String,
    pub n: usize,
    /// sum-logprob argmax accuracy (lm-eval `acc`).
    pub acc: f64,
    /// length-normalized accuracy (lm-eval `acc_norm`).
    pub acc_norm: f64,
    /// binomial standard error of `acc`.
    pub stderr: f64,
}

/// Run a task's items through an evaluator; for `StereoPairs` the `acc`
/// field is the *pct-stereotype* preference rate.
pub fn run_task(ev: &Evaluator, params: &[xla::Literal], bpe: &Bpe,
                kind: TaskKind, items: &[TaskItem]) -> Result<TaskScore> {
    let mut correct = 0usize;
    let mut correct_norm = 0usize;
    for item in items {
        let scores = ev.score_choices(params, bpe, item)?;
        let argmax = |f: fn(&(f64, f64)) -> f64| {
            scores.iter().enumerate()
                .max_by(|a, b| f(a.1).partial_cmp(&f(b.1)).unwrap())
                .map(|(i, _)| i).unwrap()
        };
        if argmax(|s| s.0) == item.answer {
            correct += 1;
        }
        if argmax(|s| s.1) == item.answer {
            correct_norm += 1;
        }
    }
    let n = items.len();
    let acc = correct as f64 / n as f64;
    Ok(TaskScore {
        task: kind.as_str().to_string(),
        n,
        acc,
        acc_norm: correct_norm as f64 / n as f64,
        stderr: (acc * (1.0 - acc) / n as f64).sqrt(),
    })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn task_score_fields() {
        let s = TaskScore { task: "cloze".into(), n: 10, acc: 0.5,
                            acc_norm: 0.6, stderr: 0.15 };
        assert_eq!(s.task, "cloze");
        assert!(s.stderr > 0.0);
    }
}
