//! Model / training configuration — the Rust mirror of the suite grid in
//! `python/compile/model.py` (which is itself the repro-scale mirror of
//! the paper's Table 3).


/// The quantization family of a model's linear layers.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Family {
    /// FloatLM: full-precision linear layers (paper §4.2).
    Float,
    /// TriLM: on-the-fly absmean ternarization + STE (paper §3).
    Ternary,
    /// BiLM: centered-sign binarization (paper App. B).
    Binary,
    /// BitNet b1.58 replication (paper §A.6).
    Bitnet,
}

impl Family {
    pub fn as_str(self) -> &'static str {
        match self {
            Family::Float => "float",
            Family::Ternary => "ternary",
            Family::Binary => "binary",
            Family::Bitnet => "bitnet",
        }
    }

    pub fn parse(s: &str) -> Option<Self> {
        match s {
            "float" => Some(Family::Float),
            "ternary" => Some(Family::Ternary),
            "binary" => Some(Family::Binary),
            "bitnet" => Some(Family::Bitnet),
            _ => None,
        }
    }

    /// Effective weight bits per linear-layer parameter (paper §1/§2.3).
    pub fn weight_bits(self) -> f64 {
        match self {
            Family::Float => 16.0,
            // log2(3): ternary states pack to 1.58 bits with base-3 coding.
            Family::Ternary | Family::Bitnet => 3f64.log2(),
            Family::Binary => 1.0,
        }
    }
}

/// Architecture hyperparameters of one suite entry (Table 3 analog).
#[derive(Debug, Clone)]
pub struct ModelConfig {
    pub name: String,
    pub size: String,
    pub family: Family,
    pub vocab: usize,
    pub hidden: usize,
    pub glu: usize,
    pub heads: usize,
    pub layers: usize,
    pub seq: usize,
    /// Model-parallel degree: number of per-matrix scale shards (§A.5).
    pub mp: usize,
}

impl ModelConfig {
    /// The seven quantizable linear weights per layer, `(name, out, in)`.
    pub fn layer_linears(&self) -> Vec<(&'static str, usize, usize)> {
        vec![
            ("attn_q", self.hidden, self.hidden),
            ("attn_k", self.hidden, self.hidden),
            ("attn_v", self.hidden, self.hidden),
            ("attn_o", self.hidden, self.hidden),
            ("mlp_gate", self.glu, self.hidden),
            ("mlp_up", self.glu, self.hidden),
            ("mlp_down", self.hidden, self.glu),
        ]
    }

    /// Total parameter count (embedding + head + linears + norms).
    pub fn n_params(&self) -> usize {
        let embed = 2 * self.vocab * self.hidden;
        let per_layer: usize =
            self.layer_linears().iter().map(|(_, o, i)| o * i).sum::<usize>()
                + 2 * self.hidden;
        embed + self.layers * per_layer + self.hidden
    }

    /// Parameters in quantizable linear layers only.
    pub fn n_linear_params(&self) -> usize {
        self.layers * self.layer_linears().iter().map(|(_, o, i)| o * i).sum::<usize>()
    }
}

/// The repro suite grid. Mirrors `model.SUITE` in python — keep in sync
/// (checked against artifacts/manifest.json at runtime load).
pub const SUITE_SIZES: [&str; 6] = ["160k", "430k", "930k", "2.8m", "6.7m", "15m"];

pub fn suite_config(size: &str, family: Family) -> Option<ModelConfig> {
    let (hidden, glu, heads, layers, mp) = match size {
        "160k" => (64, 160, 1, 2, 1),
        "430k" => (96, 256, 2, 3, 1),
        "930k" => (128, 352, 2, 4, 1),
        "2.8m" => (192, 512, 3, 6, 2),
        "6.7m" => (256, 704, 4, 8, 2),
        "15m" => (384, 1056, 6, 8, 3),
        _ => return None,
    };
    Some(ModelConfig {
        name: format!("{size}_{}", family.as_str()),
        size: size.to_string(),
        family,
        vocab: 512,
        hidden,
        glu,
        heads,
        layers,
        seq: 128,
        mp,
    })
}

/// Learning-rate / optimization settings (paper §3.2, §A.4, Table 3).
#[derive(Debug, Clone)]
pub struct TrainConfig {
    pub steps: usize,
    pub warmup_steps: usize,
    pub peak_lr: f32,
    /// TriLM's second peak LR after the halfway drop (Table 3 arrows).
    pub post_drop_lr: f32,
    pub weight_decay: f32,
    pub batch: usize,
    pub seed: u64,
    /// Spectra schedule intervention 1: drop peak LR at the halfway mark.
    pub drop_peak_lr: bool,
    /// Spectra schedule intervention 2: remove weight decay at 2/3 mark.
    pub drop_weight_decay: bool,
    /// Cosine decay (FloatLM) vs linear decay (TriLM).
    pub cosine: bool,
    /// Use the fp16-gradient train graph + dynamic loss scaling (Table 5).
    pub fp16: bool,
}

impl TrainConfig {
    /// Paper-faithful defaults per family: TriLM/BiLM/BitNet use the
    /// high-LR two-intervention linear schedule; FloatLM uses cosine
    /// decay with constant weight decay.
    pub fn for_family(family: Family, steps: usize) -> Self {
        let quantized = family != Family::Float;
        TrainConfig {
            steps,
            warmup_steps: (steps / 100).max(10),
            // LR pair keeps the paper's TriLM-over-FloatLM ratio (~1.5x,
            // Table 3) but both are re-tuned for this testbed's short
            // token budget: the paper's absolute 3e-4 FloatLM peak is
            // compute-optimal at 300B tokens and badly undertrains at
            // 300 steps (see EXPERIMENTS.md Fig 9 note).
            peak_lr: if quantized { 1.8e-3 } else { 1.2e-3 },
            post_drop_lr: if quantized { 1.2e-3 } else { 1.2e-3 },
            weight_decay: 0.1,
            batch: 8,
            seed: 0,
            drop_peak_lr: quantized,
            drop_weight_decay: quantized,
            cosine: !quantized,
            fp16: false,
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn suite_param_counts_match_python() {
        // Values computed by python/compile/model.n_params.
        let expect = [
            ("160k", 160_064usize),
            ("430k", 430_752),
            ("930k", 935_040),
            ("2.8m", 2_853_312),
            ("6.7m", 6_689_024),
            ("15m", 14_850_432),
        ];
        for (size, want) in expect {
            let cfg = suite_config(size, Family::Float).unwrap();
            assert_eq!(cfg.n_params(), want, "{size}");
        }
    }

    #[test]
    fn family_bits() {
        assert_eq!(Family::Float.weight_bits(), 16.0);
        assert!((Family::Ternary.weight_bits() - 1.585).abs() < 1e-2);
        assert_eq!(Family::Binary.weight_bits(), 1.0);
    }

    #[test]
    fn family_roundtrip() {
        for f in [Family::Float, Family::Ternary, Family::Binary, Family::Bitnet] {
            assert_eq!(Family::parse(f.as_str()), Some(f));
        }
        assert_eq!(Family::parse("fp8"), None);
    }

    #[test]
    fn trilm_schedule_defaults_follow_paper() {
        let t = TrainConfig::for_family(Family::Ternary, 1000);
        assert!(t.drop_peak_lr && t.drop_weight_decay && !t.cosine);
        let f = TrainConfig::for_family(Family::Float, 1000);
        assert!(!f.drop_peak_lr && !f.drop_weight_decay && f.cosine);
        // TriLM peak LR stays above FloatLM's (Table 3 pattern).
        assert!(t.peak_lr / f.peak_lr > 1.2);
    }
}
