//! k-bit symmetric group quantization — the QuantLM storage format (§4.2).
//!
//! Symmetric (no zero offset), group size 128 along input channels,
//! matching the paper's GPTQ configuration: effective bit rates are
//! bits + 16/group (one fp16 scale per group), e.g. 3.25 / 4.25 bits at
//! group 128 — the numbers behind Table 4's QuantLM rows.
//!
//! Groups are *ragged*: a matrix whose `cols` is not a multiple of
//! `group` gets a short final group (and a matrix narrower than `group`
//! gets a single group of `cols`). The caller-requested `group` is
//! recorded verbatim, and [`QuantTensor::effective_bits`] is computed
//! from the scales actually stored, so the reported bit rate is always
//! the true one — narrow layers simply pay more scale overhead per
//! parameter instead of silently re-labelling their group size.


use crate::runtime::HostTensor;

/// A k-bit group-quantized matrix.
#[derive(Debug, Clone)]
pub struct QuantTensor {
    pub rows: usize,
    pub cols: usize,
    pub bits: u32,
    /// Caller-requested group size (recorded verbatim; the final group
    /// of a row is ragged when `cols % group != 0`).
    pub group: usize,
    /// Row-major signed k-bit values stored widened to i8.
    pub q: Vec<i8>,
    /// One scale per (row, group): rows * cols.div_ceil(group) values.
    pub scales: Vec<f32>,
}

impl QuantTensor {
    pub fn qmax(bits: u32) -> f32 {
        (1i32 << (bits - 1)) as f32 - 1.0
    }

    /// Scale groups per row: uniform `group`-wide groups plus a ragged
    /// final group when `group` does not divide `cols`.
    pub fn n_groups(cols: usize, group: usize) -> usize {
        assert!(group >= 1, "group size must be >= 1");
        cols.div_ceil(group)
    }

    /// Round-to-nearest symmetric group quantization (the non-GPTQ
    /// baseline; GPTQ improves on this using the Hessian — see gptq/).
    pub fn quantize_rtn(w: &HostTensor, bits: u32, group: usize) -> Self {
        let (rows, cols) = w.dims2();
        let ng = Self::n_groups(cols, group);
        let qmax = Self::qmax(bits);
        let mut q = Vec::with_capacity(rows * cols);
        let mut scales = Vec::with_capacity(rows * ng);
        for r in 0..rows {
            let row = w.row(r);
            for g in 0..ng {
                let seg = &row[g * group..((g + 1) * group).min(cols)];
                let absmax = seg.iter().fold(0.0f32, |a, &x| a.max(x.abs()));
                let scale = (absmax / qmax).max(1e-5);
                scales.push(scale);
                for &x in seg {
                    q.push((x / scale).round().clamp(-qmax, qmax) as i8);
                }
            }
        }
        QuantTensor { rows, cols, bits, group, q, scales }
    }

    pub fn dequant(&self) -> HostTensor {
        let ng = Self::n_groups(self.cols, self.group);
        let mut data = Vec::with_capacity(self.q.len());
        for r in 0..self.rows {
            for c in 0..self.cols {
                let scale = self.scales[r * ng + c / self.group];
                data.push(self.q[r * self.cols + c] as f32 * scale);
            }
        }
        HostTensor::new(vec![self.rows, self.cols], data)
    }

    /// Scale of (row, col)'s group.
    pub fn scale_at(&self, r: usize, c: usize) -> f32 {
        self.scales[r * Self::n_groups(self.cols, self.group) + c / self.group]
    }

    /// Effective bits per parameter including the fp16 group scales —
    /// the paper's 3.25/4.25 accounting (§4.2). Computed from the
    /// scales actually stored, so ragged groups (cols % group != 0 or
    /// cols < group) report their true overhead.
    pub fn effective_bits(&self) -> f64 {
        let ng = Self::n_groups(self.cols, self.group);
        self.bits as f64 + 16.0 * ng as f64 / self.cols as f64
    }

    /// Mean squared reconstruction error vs the original weights.
    pub fn mse(&self, w: &HostTensor) -> f64 {
        let dq = self.dequant();
        dq.data.iter().zip(w.data.iter())
            .map(|(a, b)| ((a - b) as f64).powi(2))
            .sum::<f64>() / w.data.len() as f64
    }
}

/// Pack widened i8 k-bit values into a dense bitstream — the storage
/// format [`crate::linear::QuantPacked`]'s serving kernel streams.
///
/// Values must lie in the symmetric range `[-qmax, qmax]`; this is a
/// hard `assert!` (not `debug_assert!`) because an out-of-range value
/// would silently corrupt *neighbouring* values in the bitstream, and
/// release builds are exactly where packed weights get served from.
pub fn pack_kbit(q: &[i8], bits: u32) -> Vec<u8> {
    let qmax = (1i32 << (bits - 1)) - 1;
    let mut out = Vec::with_capacity((q.len() * bits as usize).div_ceil(8));
    let mut acc: u64 = 0;
    let mut nbits = 0u32;
    for &v in q {
        assert!((v as i32) >= -qmax && (v as i32) <= qmax,
                "value {v} out of symmetric {bits}-bit range [-{qmax}, {qmax}]");
        let unsigned = (v as i32 + qmax) as u64; // bias to unsigned
        acc |= unsigned << nbits;
        nbits += bits;
        while nbits >= 8 {
            out.push((acc & 0xFF) as u8);
            acc >>= 8;
            nbits -= 8;
        }
    }
    if nbits > 0 {
        out.push((acc & 0xFF) as u8);
    }
    out
}

pub fn unpack_kbit(bytes: &[u8], bits: u32, len: usize) -> Vec<i8> {
    let qmax = (1i32 << (bits - 1)) - 1;
    let mask = (1u64 << bits) - 1;
    let mut out = Vec::with_capacity(len);
    let mut acc: u64 = 0;
    let mut nbits = 0u32;
    let mut iter = bytes.iter();
    while out.len() < len {
        while nbits < bits {
            acc |= (*iter.next().expect("bitstream underrun") as u64) << nbits;
            nbits += 8;
        }
        out.push(((acc & mask) as i32 - qmax) as i8);
        acc >>= bits;
        nbits -= bits;
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn rtn_error_bounded_by_half_step() {
        let w = HostTensor::randn(vec![16, 64], 0.1, 1);
        let q = QuantTensor::quantize_rtn(&w, 4, 32);
        let dq = q.dequant();
        for r in 0..16 {
            for c in 0..64 {
                let step = q.scale_at(r, c);
                assert!((w.at2(r, c) - dq.at2(r, c)).abs() <= 0.5 * step + 1e-6);
            }
        }
    }

    #[test]
    fn more_bits_less_error() {
        let w = HostTensor::randn(vec![32, 128], 0.1, 2);
        let errs: Vec<f64> = [3u32, 4, 6, 8].iter()
            .map(|&b| QuantTensor::quantize_rtn(&w, b, 128).mse(&w))
            .collect();
        for pair in errs.windows(2) {
            assert!(pair[1] < pair[0], "{errs:?}");
        }
    }

    #[test]
    fn effective_bits_match_paper() {
        let w = HostTensor::randn(vec![8, 128], 0.1, 3);
        assert!((QuantTensor::quantize_rtn(&w, 3, 128).effective_bits() - 3.125)
                    .abs() < 1e-9);
        assert!((QuantTensor::quantize_rtn(&w, 4, 128).effective_bits() - 4.125)
                    .abs() < 1e-9);
    }

    #[test]
    fn kbit_pack_roundtrip_property() {
        let mut rng = crate::runtime::SplitMix64::new(31);
        for trial in 0..300 {
            let bits = 2 + (rng.below(7) as u32); // 2..=8
            let qmax = (1i32 << (bits - 1)) - 1;
            let len = trial % 101;
            let vals: Vec<i8> = (0..len)
                .map(|_| (rng.below((2 * qmax + 1) as usize) as i32 - qmax) as i8)
                .collect();
            let packed = pack_kbit(&vals, bits);
            assert_eq!(unpack_kbit(&packed, bits, vals.len()), vals,
                       "bits {bits} trial {trial}");
        }
    }

    #[test]
    fn packed_size_matches_bits() {
        let vals = vec![0i8; 1024];
        assert_eq!(pack_kbit(&vals, 4).len(), 512);
        assert_eq!(pack_kbit(&vals, 3).len(), 384);
    }

    // Satellite: exhaustive roundtrip over bits 2..=8 x lengths 0..=257
    // (mirroring the ternary pack suite) — every partial-final-byte
    // phase of every bitwidth.
    #[test]
    fn kbit_pack_roundtrip_every_bits_and_length() {
        let mut rng = crate::runtime::SplitMix64::new(41);
        for bits in 2u32..=8 {
            let qmax = (1i32 << (bits - 1)) - 1;
            for len in 0..=257usize {
                let vals: Vec<i8> = (0..len)
                    .map(|_| (rng.below((2 * qmax + 1) as usize) as i32 - qmax)
                        as i8)
                    .collect();
                let packed = pack_kbit(&vals, bits);
                assert_eq!(packed.len(), (len * bits as usize).div_ceil(8),
                           "bits {bits} len {len}: packed size");
                assert_eq!(unpack_kbit(&packed, bits, len), vals,
                           "bits {bits} len {len}");
            }
        }
    }

    // Satellite: the range check must hold in release builds too — an
    // out-of-range value would corrupt neighbouring bitstream values.
    #[test]
    #[should_panic(expected = "out of symmetric")]
    fn pack_kbit_rejects_out_of_range_values() {
        pack_kbit(&[0i8, 4, 0], 3); // 3-bit qmax is 3
    }

    #[test]
    #[should_panic(expected = "out of symmetric")]
    fn pack_kbit_rejects_asymmetric_min() {
        pack_kbit(&[-8i8], 4); // -2^(b-1) is outside the symmetric range
    }

    // Satellite: a group wider than the matrix is recorded verbatim and
    // effective_bits() reports the rate actually achieved (one scale
    // over `cols` params), not the rate `group` would suggest.
    #[test]
    fn narrow_matrix_records_caller_group_with_honest_bits() {
        let w = HostTensor::randn(vec![8, 32], 0.1, 4);
        let q = QuantTensor::quantize_rtn(&w, 4, 128);
        assert_eq!(q.group, 128, "caller-visible group must be preserved");
        assert_eq!(q.scales.len(), 8, "one ragged group per row");
        assert!((q.effective_bits() - (4.0 + 16.0 / 32.0)).abs() < 1e-9,
                "true rate is bits + 16/cols, got {}", q.effective_bits());
    }

    #[test]
    fn ragged_final_group_roundtrips_within_half_step() {
        // cols = 130, group 128: a 2-wide ragged final group per row.
        let w = HostTensor::randn(vec![4, 130], 0.1, 5);
        let q = QuantTensor::quantize_rtn(&w, 3, 128);
        assert_eq!(q.scales.len(), 4 * 2);
        assert!((q.effective_bits() - (3.0 + 32.0 / 130.0)).abs() < 1e-9);
        let dq = q.dequant();
        for r in 0..4 {
            for c in 0..130 {
                let step = q.scale_at(r, c);
                assert!((w.at2(r, c) - dq.at2(r, c)).abs() <= 0.5 * step + 1e-6,
                        "({r},{c})");
            }
        }
    }
}
