//! CPU matmul kernels: dense f32 baseline vs packed-ternary.
//!
//! These realize the paper's §2.1 decode-speedup claim on this testbed:
//! autoregressive decoding is a memory-bound mat*vec*; streaming 2-bit
//! weights moves 8x fewer bytes than f32 (16x vs fp16's claimed 10x
//! ceiling — we measure against f32 since that is our storage), and the
//! inner loop is add/sub (+ skip on zero), not multiply.
//! `benches/ternary_matmul.rs` measures the realized ratio.

use super::pack::Packed2Bit;
use super::TernaryTensor;
use crate::runtime::HostTensor;

/// Dense f32 mat*vec: y[r] = sum_c w[r,c] * x[c]. The FloatLM baseline.
pub fn matvec_dense(w: &HostTensor, x: &[f32]) -> Vec<f32> {
    let (rows, cols) = w.dims2();
    assert_eq!(cols, x.len());
    let mut y = vec![0.0f32; rows];
    for r in 0..rows {
        let row = &w.data[r * cols..(r + 1) * cols];
        let mut acc = 0.0f32;
        for c in 0..cols {
            acc += row[c] * x[c];
        }
        y[r] = acc;
    }
    y
}

/// 256-entry byte -> 4 x f32 {-1,0,+1} decode table (built once).
/// Branch-free decode: the first §Perf iteration used per-trit `match`
/// branches, which defeated vectorization and ran ~10x *slower* than the
/// SIMD-vectorized dense f32 matvec; the LUT turns the inner loop into
/// straight-line multiply-accumulate the compiler can vectorize.
fn trit_lut() -> &'static [[f32; 4]; 256] {
    use std::sync::OnceLock;
    static LUT: OnceLock<[[f32; 4]; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut lut = [[0.0f32; 4]; 256];
        for (b, entry) in lut.iter_mut().enumerate() {
            for k in 0..4 {
                entry[k] = super::pack::dec2((b >> (2 * k)) as u8) as f32;
            }
        }
        lut
    })
}

/// Packed-ternary mat*vec with per-row scale: LUT-decode 4 trits per
/// byte into {-1,0,+1} factors and multiply-accumulate (see trit_lut).
pub fn matvec_ternary_packed(packed: &Packed2Bit, rows: usize, cols: usize,
                             scales: &[f32], x: &[f32]) -> Vec<f32> {
    assert_eq!(packed.len, rows * cols);
    assert_eq!(cols % 4, 0, "cols must be a multiple of 4 for packed rows");
    assert_eq!(x.len(), cols);
    let lut = trit_lut();
    let shard = rows / scales.len();
    let bytes_per_row = cols / 4;
    let mut y = vec![0.0f32; rows];
    for r in 0..rows {
        let row_bytes = &packed.bytes[r * bytes_per_row..(r + 1) * bytes_per_row];
        let mut acc = 0.0f32;
        for (i, &b) in row_bytes.iter().enumerate() {
            let t = &lut[b as usize];
            let xs = &x[4 * i..4 * i + 4];
            acc += t[0] * xs[0] + t[1] * xs[1] + t[2] * xs[2] + t[3] * xs[3];
        }
        y[r] = acc * scales[r / shard];
    }
    y
}

/// Dense f32 matmul y = x @ w^T, x: (m, k), w: (n, k) -> (m, n).
pub fn matmul_dense(x: &HostTensor, w: &HostTensor) -> HostTensor {
    let (m, k) = x.dims2();
    let (n, k2) = w.dims2();
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xi = x.row(i);
        for j in 0..n {
            let wj = w.row(j);
            let mut acc = 0.0f32;
            for c in 0..k {
                acc += xi[c] * wj[c];
            }
            out[i * n + j] = acc;
        }
    }
    HostTensor::new(vec![m, n], out)
}

/// Ternary matmul with unpacked i8 states (reference for the packed path).
pub fn matmul_ternary_dense(x: &HostTensor, t: &TernaryTensor) -> HostTensor {
    let (m, k) = x.dims2();
    assert_eq!(k, t.cols);
    let mut out = vec![0.0f32; m * t.rows];
    for i in 0..m {
        let xi = x.row(i);
        for r in 0..t.rows {
            let row = &t.states[r * t.cols..(r + 1) * t.cols];
            let mut acc = 0.0f32;
            for c in 0..k {
                match row[c] {
                    1 => acc += xi[c],
                    -1 => acc -= xi[c],
                    _ => {}
                }
            }
            out[i * t.rows + r] = acc * t.row_scale(r);
        }
    }
    HostTensor::new(vec![m, t.rows], out)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(rows: usize, cols: usize) -> (HostTensor, TernaryTensor, Vec<f32>) {
        let w = HostTensor::randn(vec![rows, cols], 0.05, 11);
        let t = TernaryTensor::from_latent(&w, 2);
        let x: Vec<f32> = HostTensor::randn(vec![1, cols], 1.0, 12).data;
        (w, t, x)
    }

    #[test]
    fn packed_matvec_matches_dequant_dense() {
        let (_, t, x) = setup(32, 16);
        let packed = Packed2Bit::pack(&t.states);
        let got = matvec_ternary_packed(&packed, t.rows, t.cols, &t.scales, &x);
        let want = matvec_dense(&t.dequant(), &x);
        for (a, b) in got.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn ternary_dense_matches_dequant_matmul() {
        let (_, t, _) = setup(24, 12);
        let x = HostTensor::randn(vec![5, 12], 1.0, 13);
        let got = matmul_ternary_dense(&x, &t);
        let want = matmul_dense(&x, &t.dequant());
        for (a, b) in got.data.iter().zip(want.data.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn matvec_dense_identity() {
        let eye = HostTensor::new(vec![3, 3],
                                  vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        assert_eq!(matvec_dense(&eye, &[2.0, 3.0, 4.0]), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn packed_bytes_are_8x_smaller_than_f32() {
        let (_, t, _) = setup(64, 64);
        let packed = Packed2Bit::pack(&t.states);
        let f32_bytes = t.states.len() * 4;
        assert_eq!(packed.bytes.len() * 16, f32_bytes); // 2 bits vs 32
    }
}
