//! CPU matmul kernels: dense f32 baseline vs packed-ternary.
//!
//! These realize the paper's §2.1 decode-speedup claim on this testbed:
//! autoregressive decoding is a memory-bound mat*vec*; streaming 2-bit
//! weights moves 8x fewer bytes than f32 (16x vs fp16's claimed 10x
//! ceiling — we measure against f32 since that is our storage), and the
//! inner loop is add/sub (+ skip on zero), not multiply.
//!
//! Two generations of kernels live here:
//!
//! - Scalar decode ([`matvec_ternary_packed`]): one request, one token —
//!   the original single-stream path over a flat [`Packed2Bit`].
//! - Blocked batched decode ([`matmul_ternary_packed`]): the serving
//!   path. N concurrent requests share one weight stream: weights are
//!   walked in row blocks of [`ROW_BLOCK`] x column panels of
//!   [`COL_BLOCK_TRITS`] trits (x-panel scratch stays L1-resident), the
//!   x panel is transposed once per block so each decoded trit applies
//!   to all batch lanes with one broadcast multiply-add, zero trits are
//!   skipped (ternary sparsity, §2.3), and row ranges are partitioned
//!   across `std::thread` workers with per-thread output slabs.
//!
//! Numerical contract the serve scheduler relies on: for a fixed weight
//! matrix, the accumulation order over `k` for every (x-row, w-row)
//! pair is independent of the batch size and thread count, so a lane's
//! logits are bitwise identical whether it decodes alone or batched —
//! see `tests/serve_determinism.rs`.
//!
//! Execution substrates — two drivers share every kernel body:
//!
//! - `blocked_rows_driver` (scoped): spawns a fresh
//!   `std::thread::scope` per call and allocates its own output
//!   buffers. The original path; kept as the compatibility wrapper
//!   behind [`matmul_ternary_packed`] and as the reference the pooled
//!   path is tested bitwise against.
//! - `blocked_rows_driver_pooled` (hot path): dispatches the same
//!   row partition onto a persistent [`crate::runtime::WorkerPool`]
//!   and accumulates into a caller-owned scratch slab
//!   ([`matmul_ternary_packed_into`]). Zero spawns, zero allocations
//!   at steady state. Partition arithmetic is shared, every row chunk
//!   writes a disjoint slab, and per-worker panel scratch is
//!   thread-local (workers are long-lived), so pooled results are
//!   bitwise identical to scoped results at every thread count —
//!   `tests/pool_equivalence.rs` locks this in.
//!
//! Scratch ownership: the caller owns the `(n, m)` transposed slab and
//! the output tensor (threaded down from
//! [`crate::runtime::DecodeScratch`]); the transposed x panel each
//! worker transposes per (row-block, panel) pair lives in a
//! thread-local buffer that persists across calls.
//!
//! `benches/ternary_matmul.rs` and `benches/serve_throughput.rs`
//! measure the realized ratios.

use std::cell::RefCell;

use super::pack::{Packed2Bit, PackedMatrix};
use super::TernaryTensor;
use crate::runtime::{HostTensor, WorkerPool};

/// Rows of packed weights processed per column-panel pass. Sized so a
/// block's accumulators (`ROW_BLOCK * batch` f32, 4 KiB at batch 8)
/// and its weight panel (`ROW_BLOCK * COL_BLOCK_TRITS / 4` = 16 KiB)
/// stay cache-resident while one transposed x panel is hot, and large
/// enough to amortize that panel's transpose (done once per
/// (row-block, panel) pair) over many rows.
pub const ROW_BLOCK: usize = 128;

/// Trits (k-elements) per column panel. 512 trits = 128 weight bytes
/// per row-pass; the transposed x panel is `512 * batch * 4` bytes —
/// 16 KiB at batch 8, sized to stay L1-resident. Fixed (never derived
/// from the batch size) so k-accumulation order is batch-invariant.
pub const COL_BLOCK_TRITS: usize = 512;

/// Minimum accumulate operations (`n * k * m`) a worker must have
/// before another scoped thread pays for itself. The serve hot path
/// issues several small matmuls per decode step; below this bound the
/// per-call spawn/join overhead exceeds the kernel work, so the call
/// degrades to fewer threads (never changing results — thread count
/// only partitions rows, it does not reorder accumulation).
pub const MIN_WORK_PER_THREAD: usize = 1 << 16;

/// Dense f32 mat*vec: y[r] = sum_c w[r,c] * x[c]. The FloatLM baseline.
pub fn matvec_dense(w: &HostTensor, x: &[f32]) -> Vec<f32> {
    let (rows, cols) = w.dims2();
    assert_eq!(cols, x.len());
    let mut y = vec![0.0f32; rows];
    for r in 0..rows {
        let row = &w.data[r * cols..(r + 1) * cols];
        let mut acc = 0.0f32;
        for c in 0..cols {
            acc += row[c] * x[c];
        }
        y[r] = acc;
    }
    y
}

/// 256-entry byte -> 4 x f32 {-1,0,+1} decode table (built once).
/// Branch-free decode: the first §Perf iteration used per-trit `match`
/// branches, which defeated vectorization and ran ~10x *slower* than the
/// SIMD-vectorized dense f32 matvec; the LUT turns the inner loop into
/// straight-line multiply-accumulate the compiler can vectorize.
fn trit_lut() -> &'static [[f32; 4]; 256] {
    use std::sync::OnceLock;
    static LUT: OnceLock<[[f32; 4]; 256]> = OnceLock::new();
    LUT.get_or_init(|| {
        let mut lut = [[0.0f32; 4]; 256];
        for (b, entry) in lut.iter_mut().enumerate() {
            for k in 0..4 {
                entry[k] = super::pack::dec2((b >> (2 * k)) as u8) as f32;
            }
        }
        lut
    })
}

/// Packed-ternary mat*vec with per-shard scales: LUT-decode 4 trits per
/// byte into {-1,0,+1} factors and multiply-accumulate (see trit_lut).
///
/// `packed` is a flat packing of the `rows * cols` states. When
/// `cols % 4 == 0` rows are byte-aligned and the fast full-byte path
/// runs; otherwise rows start mid-byte and a per-trit decode path is
/// used (correct for any shape, ~4x slower — pack a [`PackedMatrix`]
/// and call [`matmul_ternary_packed`] for aligned tail handling).
pub fn matvec_ternary_packed(packed: &Packed2Bit, rows: usize, cols: usize,
                             scales: &[f32], x: &[f32]) -> Vec<f32> {
    assert_eq!(packed.len, rows * cols,
               "packed len {} != rows*cols {}", packed.len, rows * cols);
    assert_eq!(x.len(), cols);
    assert!(!scales.is_empty(), "need at least one scale shard");
    assert_eq!(rows % scales.len(), 0,
               "scale shards {} must divide rows {rows} — a non-divisor \
                silently mis-shards row->scale assignment", scales.len());
    let shard = rows / scales.len();
    let mut y = vec![0.0f32; rows];
    if cols % 4 == 0 {
        let lut = trit_lut();
        let bytes_per_row = cols / 4;
        for r in 0..rows {
            let row_bytes =
                &packed.bytes[r * bytes_per_row..(r + 1) * bytes_per_row];
            let mut acc = 0.0f32;
            for (i, &b) in row_bytes.iter().enumerate() {
                let t = &lut[b as usize];
                let xs = &x[4 * i..4 * i + 4];
                acc += t[0] * xs[0] + t[1] * xs[1] + t[2] * xs[2] + t[3] * xs[3];
            }
            y[r] = acc * scales[r / shard];
        }
    } else {
        // Unaligned tail path: rows are not byte-aligned in the flat
        // packing, so decode trit-by-trit at absolute positions.
        for r in 0..rows {
            let mut acc = 0.0f32;
            for c in 0..cols {
                match packed.get(r * cols + c) {
                    1 => acc += x[c],
                    -1 => acc -= x[c],
                    _ => {}
                }
            }
            y[r] = acc * scales[r / shard];
        }
    }
    y
}

/// Dense f32 matmul y = x @ w^T, x: (m, k), w: (n, k) -> (m, n).
pub fn matmul_dense(x: &HostTensor, w: &HostTensor) -> HostTensor {
    let (m, k) = x.dims2();
    let (n, k2) = w.dims2();
    assert_eq!(k, k2);
    let mut out = vec![0.0f32; m * n];
    for i in 0..m {
        let xi = x.row(i);
        for j in 0..n {
            let wj = w.row(j);
            let mut acc = 0.0f32;
            for c in 0..k {
                acc += xi[c] * wj[c];
            }
            out[i * n + j] = acc;
        }
    }
    HostTensor::new(vec![m, n], out)
}

/// Ternary matmul with unpacked i8 states (reference for the packed path).
pub fn matmul_ternary_dense(x: &HostTensor, t: &TernaryTensor) -> HostTensor {
    let (m, k) = x.dims2();
    assert_eq!(k, t.cols);
    let mut out = vec![0.0f32; m * t.rows];
    for i in 0..m {
        let xi = x.row(i);
        for r in 0..t.rows {
            let row = &t.states[r * t.cols..(r + 1) * t.cols];
            let mut acc = 0.0f32;
            for c in 0..k {
                match row[c] {
                    1 => acc += xi[c],
                    -1 => acc -= xi[c],
                    _ => {}
                }
            }
            out[i * t.rows + r] = acc * t.row_scale(r);
        }
    }
    HostTensor::new(vec![m, t.rows], out)
}

/// Per-thread transposed-x-panel scratch. Persistent because both
/// executors keep their threads alive across calls: pool workers live
/// for the scheduler's lifetime, and the calling thread is long-lived
/// by definition — so steady-state decode steps never allocate here.
/// Scoped-thread workers (the legacy driver) get a fresh buffer per
/// spawn, which is exactly the allocation the pool removes. The buffer
/// is only ever *written-then-read* within one panel (`[..cb * m]`), so
/// stale contents can never leak into results.
fn with_panel_scratch<R>(len: usize, f: impl FnOnce(&mut [f32]) -> R) -> R {
    thread_local! {
        static X_PANEL: RefCell<Vec<f32>> = RefCell::new(Vec::new());
    }
    X_PANEL.with(|buf| {
        let mut buf = buf.borrow_mut();
        if buf.len() < len {
            buf.resize(len, 0.0);
        }
        f(&mut buf[..len])
    })
}

/// The blocked batched-decode kernel body for w-rows `[r0, r1)`.
///
/// `out_t` is the (rows, m)-transposed output slab for this row range:
/// `out_t[(r - r0) * m + mi]` accumulates x-row `mi` against w-row `r`
/// (the slab must arrive zeroed). Walks column panels of
/// [`COL_BLOCK_TRITS`]; per panel the x block is transposed into
/// `(k, m)` thread-local scratch so each decoded trit updates all m
/// lanes with one broadcast multiply-add over a contiguous m-vector.
fn packed_rows_kernel(w: &PackedMatrix, x: &HostTensor,
                      r0: usize, r1: usize, out_t: &mut [f32]) {
    let m = x.dims2().0;
    with_panel_scratch(COL_BLOCK_TRITS * m, |x_t| {
        packed_rows_body(w, x, r0, r1, out_t, x_t)
    })
}

/// [`packed_rows_kernel`] with the `(k-panel, m)` transpose scratch
/// passed explicitly (scratch acquisition split out for readability).
fn packed_rows_body(w: &PackedMatrix, x: &HostTensor,
                    r0: usize, r1: usize, out_t: &mut [f32],
                    x_t: &mut [f32]) {
    let (m, k) = x.dims2();
    debug_assert_eq!(k, w.cols);
    debug_assert_eq!(out_t.len(), (r1 - r0) * m);
    debug_assert_eq!(x_t.len(), COL_BLOCK_TRITS * m);
    let lut = trit_lut();
    for rb in (r0..r1).step_by(ROW_BLOCK) {
        let rb_end = (rb + ROW_BLOCK).min(r1);
        let mut kb = 0usize;
        while kb < k {
            let kb_end = (kb + COL_BLOCK_TRITS).min(k);
            let cb = kb_end - kb;
            // Transpose the x panel once; reused by every row in the block.
            for (c, col) in x_t.chunks_exact_mut(m).take(cb).enumerate() {
                for (mi, v) in col.iter_mut().enumerate() {
                    *v = x.data[mi * k + kb + c];
                }
            }
            let full_bytes = cb / 4;
            let tail = cb % 4; // only the final panel of a row has one
            for r in rb..rb_end {
                let bytes = &w.row_bytes(r)[kb / 4..(kb + cb).div_ceil(4)];
                let acc = &mut out_t[(r - r0) * m..(r - r0 + 1) * m];
                for (bi, &b) in bytes[..full_bytes].iter().enumerate() {
                    if b == 0 {
                        continue; // 4 zero trits: ternary sparsity skip
                    }
                    let t = &lut[b as usize];
                    for (j, &tj) in t.iter().enumerate() {
                        if tj == 0.0 {
                            continue;
                        }
                        let xs = &x_t[(4 * bi + j) * m..(4 * bi + j + 1) * m];
                        for (a, &xv) in acc.iter_mut().zip(xs) {
                            *a += tj * xv;
                        }
                    }
                }
                if tail > 0 {
                    let t = &lut[bytes[full_bytes] as usize];
                    for (j, &tj) in t.iter().take(tail).enumerate() {
                        if tj == 0.0 {
                            continue;
                        }
                        let xs =
                            &x_t[(4 * full_bytes + j) * m..(4 * full_bytes + j + 1) * m];
                        for (a, &xv) in acc.iter_mut().zip(xs) {
                            *a += tj * xv;
                        }
                    }
                }
            }
            kb = kb_end;
        }
        // Apply per-shard scales once per output element.
        for r in rb..rb_end {
            let g = w.row_scale(r);
            for a in &mut out_t[(r - r0) * m..(r - r0 + 1) * m] {
                *a *= g;
            }
        }
    }
}

/// Shared *scoped-thread* driver for blocked row-partitioned matmul
/// kernels (the ternary kernel here and the k-bit quant kernel in
/// `linear::qmatmul` run through the same scaffold, so their threading
/// behavior cannot diverge). Spawns fresh threads and allocates fresh
/// buffers per call; [`blocked_rows_driver_pooled`] is the
/// overhead-free twin the serving hot path uses, with partitioning
/// shared via [`effective_threads`].
///
/// `threads = 0` uses `std::thread::available_parallelism()`. The `n`
/// weight rows (output columns) are partitioned into contiguous
/// chunks, one per worker; `kernel(r0, r1, slab)` fills the disjoint
/// (r1-r0, m)-transposed slab for its row range, and the slabs are
/// assembled into row-major (m, n) at the end. The worker count is
/// additionally capped so each has at least [`MIN_WORK_PER_THREAD`]
/// accumulate ops — small decode-step matmuls run single-threaded
/// rather than paying spawn/join per call. Thread count only
/// partitions rows; it never reorders accumulation.
pub(crate) fn blocked_rows_driver(
    m: usize, k: usize, n: usize, threads: usize,
    kernel: impl Fn(usize, usize, &mut [f32]) + Sync,
) -> HostTensor {
    if m == 0 || n == 0 {
        return HostTensor::new(vec![m, n], vec![0.0; m * n]);
    }
    let threads = effective_threads(m, k, n, threads);

    let mut out_t = vec![0.0f32; n * m]; // (n, m) transposed
    if threads == 1 {
        kernel(0, n, &mut out_t);
    } else {
        let chunk = n.div_ceil(threads);
        let kernel = &kernel;
        std::thread::scope(|s| {
            for (ti, slab) in out_t.chunks_mut(chunk * m).enumerate() {
                let r0 = ti * chunk;
                let r1 = (r0 + chunk).min(n);
                s.spawn(move || kernel(r0, r1, slab));
            }
        });
    }
    let mut out = vec![0.0f32; m * n];
    for r in 0..n {
        for mi in 0..m {
            out[mi * n + r] = out_t[r * m + mi];
        }
    }
    HostTensor::new(vec![m, n], out)
}

/// Effective worker count for an (m, k, n) matmul given a requested
/// thread budget: capped by the row count and by
/// [`MIN_WORK_PER_THREAD`]. Shared by the scoped and pooled drivers so
/// their row partitioning can never diverge (the bitwise-equivalence
/// contract of `tests/pool_equivalence.rs`).
pub(crate) fn effective_threads(m: usize, k: usize, n: usize,
                                requested: usize) -> usize {
    let work = n.saturating_mul(k).saturating_mul(m);
    let requested = if requested == 0 {
        std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
    } else {
        requested
    };
    requested.min(n).min((work / MIN_WORK_PER_THREAD).max(1)).max(1)
}

/// `*mut f32` that can cross into pool jobs. Each job derives a
/// disjoint slab from it (`[r0 * m, r1 * m)` with non-overlapping row
/// ranges), so concurrent writes never alias.
#[derive(Clone, Copy)]
struct SlabBase(*mut f32);
unsafe impl Send for SlabBase {}
unsafe impl Sync for SlabBase {}

/// The pooled twin of [`blocked_rows_driver`]: same row partitioning,
/// same kernel bodies, but jobs dispatch onto a persistent
/// [`WorkerPool`] and accumulation reuses the caller's `out_t` slab
/// and `out` tensor — no thread spawns and no allocations at steady
/// state (buffers grow once, then stabilize). `out` is reshaped to
/// (m, n) in place and fully overwritten.
pub(crate) fn blocked_rows_driver_pooled(
    m: usize, k: usize, n: usize, pool: &WorkerPool,
    out_t: &mut Vec<f32>, out: &mut HostTensor,
    kernel: impl Fn(usize, usize, &mut [f32]) + Sync,
) {
    out.reset2(m, n);
    if m == 0 || n == 0 {
        return;
    }
    let threads = effective_threads(m, k, n, pool.threads());
    // clear + resize = one memset of the slab the kernels accumulate
    // into (they require zero-initialized accumulators).
    out_t.clear();
    out_t.resize(n * m, 0.0);
    let chunk = n.div_ceil(threads);
    let jobs = n.div_ceil(chunk);
    if jobs == 1 {
        kernel(0, n, &mut out_t[..]);
    } else {
        let base = SlabBase(out_t.as_mut_ptr());
        pool.scope(jobs, &|ti| {
            let r0 = ti * chunk;
            let r1 = (r0 + chunk).min(n);
            // SAFETY: job `ti` exclusively owns rows [r0, r1) of the
            // (n, m) slab; ranges are disjoint across jobs and `out_t`
            // is not touched elsewhere until `scope` returns.
            let slab = unsafe {
                std::slice::from_raw_parts_mut(base.0.add(r0 * m),
                                               (r1 - r0) * m)
            };
            kernel(r0, r1, slab);
        });
    }
    // Assemble row-major (m, n) from the (n, m) accumulation slab.
    for r in 0..n {
        for mi in 0..m {
            out.data[mi * n + r] = out_t[r * m + mi];
        }
    }
}

/// Batched packed-ternary matmul: y = x @ w_packed^T with per-shard
/// scales. x: (m, k), w: (n, k) packed -> (m, n).
///
/// Threading via the internal `blocked_rows_driver`. Accumulation order per
/// output element is independent of both `threads` and `m` (fixed
/// [`COL_BLOCK_TRITS`] panels), so results are batch-invariant.
///
/// Compatibility wrapper: spawns scoped threads and allocates its
/// output per call. The serving hot path uses
/// [`matmul_ternary_packed_into`] instead.
pub fn matmul_ternary_packed(x: &HostTensor, w: &PackedMatrix,
                             threads: usize) -> HostTensor {
    let (m, k) = x.dims2();
    assert_eq!(k, w.cols, "x cols {k} != packed weight cols {}", w.cols);
    blocked_rows_driver(m, k, w.rows, threads,
                        |r0, r1, slab| packed_rows_kernel(w, x, r0, r1, slab))
}

/// Allocation-free batched packed-ternary matmul: identical math and
/// partitioning to [`matmul_ternary_packed`] (results are bitwise
/// equal at the pool's thread count), but executed on a persistent
/// [`WorkerPool`] with the accumulation slab and output tensor reused
/// from caller-owned scratch.
pub fn matmul_ternary_packed_into(x: &HostTensor, w: &PackedMatrix,
                                  pool: &WorkerPool, out_t: &mut Vec<f32>,
                                  out: &mut HostTensor) {
    let (m, k) = x.dims2();
    assert_eq!(k, w.cols, "x cols {k} != packed weight cols {}", w.cols);
    blocked_rows_driver_pooled(
        m, k, w.rows, pool, out_t, out,
        |r0, r1, slab| packed_rows_kernel(w, x, r0, r1, slab));
}

#[cfg(test)]
mod tests {
    use super::*;

    fn setup(rows: usize, cols: usize) -> (HostTensor, TernaryTensor, Vec<f32>) {
        let w = HostTensor::randn(vec![rows, cols], 0.05, 11);
        let t = TernaryTensor::from_latent(&w, 2);
        let x: Vec<f32> = HostTensor::randn(vec![1, cols], 1.0, 12).data;
        (w, t, x)
    }

    #[test]
    fn packed_matvec_matches_dequant_dense() {
        let (_, t, x) = setup(32, 16);
        let packed = Packed2Bit::pack(&t.states);
        let got = matvec_ternary_packed(&packed, t.rows, t.cols, &t.scales, &x);
        let want = matvec_dense(&t.dequant(), &x);
        for (a, b) in got.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn packed_matvec_handles_unaligned_cols() {
        // cols % 4 != 0: rows start mid-byte in the flat packing; the
        // per-trit path must still match the dequantized reference.
        let w = HostTensor::randn(vec![6, 10], 0.05, 17);
        let t = TernaryTensor::from_latent(&w, 2);
        let x: Vec<f32> = HostTensor::randn(vec![1, 10], 1.0, 18).data;
        let packed = Packed2Bit::pack(&t.states);
        let got = matvec_ternary_packed(&packed, t.rows, t.cols, &t.scales, &x);
        let want = matvec_dense(&t.dequant(), &x);
        for (a, b) in got.iter().zip(want.iter()) {
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    #[should_panic(expected = "must divide rows")]
    fn packed_matvec_rejects_missharded_scales() {
        let (_, t, x) = setup(32, 16);
        let packed = Packed2Bit::pack(&t.states);
        matvec_ternary_packed(&packed, t.rows, t.cols, &[1.0, 1.0, 1.0], &x);
    }

    #[test]
    fn ternary_dense_matches_dequant_matmul() {
        let (_, t, _) = setup(24, 12);
        let x = HostTensor::randn(vec![5, 12], 1.0, 13);
        let got = matmul_ternary_dense(&x, &t);
        let want = matmul_dense(&x, &t.dequant());
        for (a, b) in got.data.iter().zip(want.data.iter()) {
            assert!((a - b).abs() < 1e-4);
        }
    }

    #[test]
    fn batched_packed_matches_dequant_matmul() {
        for (rows, cols, m) in [(32, 16, 4), (64, 48, 8), (7, 10, 3)] {
            let w = HostTensor::randn(vec![rows, cols], 0.05, 21);
            let t = TernaryTensor::from_latent(&w, 1);
            let pm = PackedMatrix::from_ternary(&t);
            let x = HostTensor::randn(vec![m, cols], 1.0, 22);
            let want = matmul_dense(&x, &t.dequant());
            for threads in [1, 3] {
                let got = matmul_ternary_packed(&x, &pm, threads);
                assert_eq!(got.shape, vec![m, rows]);
                for (a, b) in got.data.iter().zip(want.data.iter()) {
                    assert!((a - b).abs() < 1e-4,
                            "{rows}x{cols} m{m} t{threads}: {a} vs {b}");
                }
            }
        }
    }

    #[test]
    fn batched_packed_spans_multiple_panels_and_row_blocks() {
        // cols > COL_BLOCK_TRITS and rows > ROW_BLOCK exercise the
        // panel loop, the block loop and the panel-boundary tail.
        let cols = COL_BLOCK_TRITS + 37;
        let rows = ROW_BLOCK + 9;
        let w = HostTensor::randn(vec![rows, cols], 0.05, 23);
        let t = TernaryTensor::from_latent(&w, 1);
        let pm = PackedMatrix::from_ternary(&t);
        let x = HostTensor::randn(vec![2, cols], 1.0, 24);
        let got = matmul_ternary_packed(&x, &pm, 2);
        let want = matmul_dense(&x, &t.dequant());
        for (a, b) in got.data.iter().zip(want.data.iter()) {
            // Same 1e-4 bar as tests/kernel_equivalence.rs: ~50x margin
            // over observed-order f32 drift at this k.
            assert!((a - b).abs() < 1e-4, "{a} vs {b}");
        }
    }

    #[test]
    fn batched_packed_is_batch_invariant() {
        // The serve scheduler's determinism contract: a lane's output
        // is bitwise identical at batch 1 and batch 8, any thread count.
        let w = HostTensor::randn(vec![40, 24], 0.05, 25);
        let t = TernaryTensor::from_latent(&w, 2);
        let pm = PackedMatrix::from_ternary(&t);
        let xb = HostTensor::randn(vec![8, 24], 1.0, 26);
        let batched = matmul_ternary_packed(&xb, &pm, 4);
        for mi in 0..8 {
            let x1 = HostTensor::stack_rows(&[xb.row(mi)]);
            let solo = matmul_ternary_packed(&x1, &pm, 1);
            assert_eq!(solo.data, batched.row(mi),
                       "lane {mi} diverges between batch sizes");
        }
    }

    #[test]
    fn pooled_matmul_is_bitwise_identical_to_scoped() {
        use crate::runtime::WorkerPool;
        let w = HostTensor::randn(vec![ROW_BLOCK + 9, COL_BLOCK_TRITS + 37],
                                  0.05, 27);
        // mp=1: 137 rows are not divisible into multiple scale shards.
        let t = TernaryTensor::from_latent(&w, 1);
        let pm = PackedMatrix::from_ternary(&t);
        let mut out_t = Vec::new();
        let mut out = HostTensor::zeros(vec![0, 0]);
        for threads in [1usize, 2, 4] {
            let pool = WorkerPool::new(threads);
            for m in [1usize, 3, 8] {
                let x = HostTensor::randn(vec![m, t.cols], 1.0,
                                          28 ^ (m as u64));
                let want = matmul_ternary_packed(&x, &pm, threads);
                // Reuse the same scratch across calls: stale contents
                // from the previous (larger or smaller) shape must not
                // leak through.
                matmul_ternary_packed_into(&x, &pm, &pool, &mut out_t,
                                           &mut out);
                assert_eq!(out.shape, want.shape, "t{threads} m{m}");
                assert_eq!(out.data, want.data, "t{threads} m{m}");
            }
        }
    }

    #[test]
    fn matvec_dense_identity() {
        let eye = HostTensor::new(vec![3, 3],
                                  vec![1., 0., 0., 0., 1., 0., 0., 0., 1.]);
        assert_eq!(matvec_dense(&eye, &[2.0, 3.0, 4.0]), vec![2.0, 3.0, 4.0]);
    }

    #[test]
    fn packed_bytes_are_8x_smaller_than_f32() {
        let (_, t, _) = setup(64, 64);
        let packed = Packed2Bit::pack(&t.states);
        let f32_bytes = t.states.len() * 4;
        assert_eq!(packed.bytes.len() * 16, f32_bytes); // 2 bits vs 32
    }
}
