//! Packed storage for ternary states.
//!
//! - [`Packed2Bit`]: 4 trits per byte, 2 bits each (00=0, 01=+1, 10=-1).
//!   Fast to decode, used by the CPU inference kernels.
//! - [`PackedBase3`]: 5 trits per byte (3^5 = 243 <= 256), 1.6 bits per
//!   weight — the near-entropy coding behind the paper's Table 4 sizes.


/// 2-bit packing: 4 ternary states per byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Packed2Bit {
    pub len: usize,
    pub bytes: Vec<u8>,
}

#[inline]
fn enc2(s: i8) -> u8 {
    match s {
        0 => 0b00,
        1 => 0b01,
        -1 => 0b10,
        _ => panic!("not a ternary state: {s}"),
    }
}

#[inline]
pub fn dec2(b: u8) -> i8 {
    match b & 0b11 {
        0b00 => 0,
        0b01 => 1,
        0b10 => -1,
        _ => 0, // 0b11 unused; treat as zero for robustness
    }
}

impl Packed2Bit {
    pub fn pack(states: &[i8]) -> Self {
        let mut bytes = vec![0u8; states.len().div_ceil(4)];
        for (i, &s) in states.iter().enumerate() {
            bytes[i / 4] |= enc2(s) << ((i % 4) * 2);
        }
        Packed2Bit { len: states.len(), bytes }
    }

    pub fn unpack(&self) -> Vec<i8> {
        (0..self.len)
            .map(|i| dec2(self.bytes[i / 4] >> ((i % 4) * 2)))
            .collect()
    }

    /// Decode position i without unpacking everything.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        dec2(self.bytes[i / 4] >> ((i % 4) * 2))
    }

    pub fn bits_per_weight(&self) -> f64 {
        8.0 * self.bytes.len() as f64 / self.len as f64
    }
}

/// Base-3 packing: 5 ternary states per byte (1.6 bits/weight).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBase3 {
    pub len: usize,
    pub bytes: Vec<u8>,
}

impl PackedBase3 {
    pub fn pack(states: &[i8]) -> Self {
        let mut bytes = Vec::with_capacity(states.len().div_ceil(5));
        for chunk in states.chunks(5) {
            let mut v: u16 = 0;
            // little-endian base-3 digits, states mapped -1,0,1 -> 0,1,2
            for &s in chunk.iter().rev() {
                debug_assert!((-1..=1).contains(&s));
                v = v * 3 + (s + 1) as u16;
            }
            bytes.push(v as u8);
        }
        PackedBase3 { len: states.len(), bytes }
    }

    pub fn unpack(&self) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.len);
        for (ci, &b) in self.bytes.iter().enumerate() {
            let mut v = b as u16;
            let n = (self.len - ci * 5).min(5);
            for _ in 0..n {
                out.push((v % 3) as i8 - 1);
                v /= 3;
            }
        }
        out
    }

    pub fn bits_per_weight(&self) -> f64 {
        8.0 * self.bytes.len() as f64 / self.len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SplitMix64;

    fn random_states(rng: &mut SplitMix64, len: usize) -> Vec<i8> {
        (0..len).map(|_| rng.below(3) as i8 - 1).collect()
    }

    // Property sweeps (seeded stand-ins for proptest; see util/mod.rs).
    #[test]
    fn pack2_roundtrip_property() {
        let mut rng = SplitMix64::new(21);
        for trial in 0..200 {
            let states = random_states(&mut rng, trial % 97);
            let p = Packed2Bit::pack(&states);
            assert_eq!(p.unpack(), states, "trial {trial}");
        }
    }

    #[test]
    fn pack3_roundtrip_property() {
        let mut rng = SplitMix64::new(22);
        for trial in 0..200 {
            let states = random_states(&mut rng, trial % 103);
            let p = PackedBase3::pack(&states);
            assert_eq!(p.unpack(), states, "trial {trial}");
        }
    }

    #[test]
    fn pack2_random_access_property() {
        let mut rng = SplitMix64::new(23);
        for trial in 0..100 {
            let states = random_states(&mut rng, 1 + trial % 77);
            let p = Packed2Bit::pack(&states);
            for (i, &s) in states.iter().enumerate() {
                assert_eq!(p.get(i), s);
            }
        }
    }

    #[test]
    fn bits_per_weight_targets() {
        let states = vec![0i8; 10_000];
        assert!((Packed2Bit::pack(&states).bits_per_weight() - 2.0).abs() < 0.01);
        assert!((PackedBase3::pack(&states).bits_per_weight() - 1.6).abs() < 0.01);
    }

    #[test]
    fn base3_is_denser_than_2bit() {
        let states = vec![1i8; 100_000];
        assert!(PackedBase3::pack(&states).bytes.len()
                < Packed2Bit::pack(&states).bytes.len());
    }
}
