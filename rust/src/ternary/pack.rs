//! Packed storage for ternary states.
//!
//! - [`Packed2Bit`]: 4 trits per byte, 2 bits each (00=0, 01=+1, 10=-1).
//!   Fast to decode, used by the CPU inference kernels.
//! - [`PackedMatrix`]: a row-aligned 2-bit weight matrix — every row
//!   starts on a byte boundary (final byte zero-padded), so the blocked
//!   batched kernels in [`super::matmul`] can slice per-row byte ranges
//!   for any `cols`, including `cols % 4 != 0`.
//! - [`PackedBase3`]: 5 trits per byte (3^5 = 243 <= 256), 1.6 bits per
//!   weight — the near-entropy coding behind the paper's Table 4 sizes.

use super::TernaryTensor;


/// 2-bit packing: 4 ternary states per byte.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Packed2Bit {
    pub len: usize,
    pub bytes: Vec<u8>,
}

#[inline]
fn enc2(s: i8) -> u8 {
    match s {
        0 => 0b00,
        1 => 0b01,
        -1 => 0b10,
        _ => panic!("not a ternary state: {s}"),
    }
}

#[inline]
pub fn dec2(b: u8) -> i8 {
    match b & 0b11 {
        0b00 => 0,
        0b01 => 1,
        0b10 => -1,
        _ => 0, // 0b11 unused; treat as zero for robustness
    }
}

impl Packed2Bit {
    pub fn pack(states: &[i8]) -> Self {
        let mut bytes = vec![0u8; states.len().div_ceil(4)];
        for (i, &s) in states.iter().enumerate() {
            bytes[i / 4] |= enc2(s) << ((i % 4) * 2);
        }
        Packed2Bit { len: states.len(), bytes }
    }

    pub fn unpack(&self) -> Vec<i8> {
        (0..self.len)
            .map(|i| dec2(self.bytes[i / 4] >> ((i % 4) * 2)))
            .collect()
    }

    /// Decode position i without unpacking everything.
    #[inline]
    pub fn get(&self, i: usize) -> i8 {
        dec2(self.bytes[i / 4] >> ((i % 4) * 2))
    }

    pub fn bits_per_weight(&self) -> f64 {
        8.0 * self.bytes.len() as f64 / self.len as f64
    }
}

/// A row-aligned 2-bit ternary weight matrix with per-shard scales.
///
/// Unlike a flat [`Packed2Bit`] over `rows * cols` states (where a row
/// may start mid-byte when `cols % 4 != 0`), every row here occupies
/// `cols.div_ceil(4)` bytes; the trailing lanes of the final byte are
/// the zero encoding, so full-byte decode over a row never fabricates
/// a contribution. This is the storage format the batched decode
/// kernels ([`super::matmul::matmul_ternary_packed`]) consume.
#[derive(Debug, Clone, PartialEq)]
pub struct PackedMatrix {
    pub rows: usize,
    pub cols: usize,
    /// `cols.div_ceil(4)` — each row's byte footprint.
    pub bytes_per_row: usize,
    /// `rows * bytes_per_row` bytes, row-major, rows byte-aligned.
    pub bytes: Vec<u8>,
    /// Per-shard absmean scales; `scales.len()` must divide `rows` and
    /// row `r` uses `scales[r / (rows / scales.len())]` (§A.5).
    pub scales: Vec<f32>,
}

impl PackedMatrix {
    /// Pack row-major states with explicit shard scales.
    pub fn from_states(rows: usize, cols: usize, states: &[i8],
                       scales: Vec<f32>) -> Self {
        assert_eq!(states.len(), rows * cols,
                   "states len {} != rows*cols {}", states.len(), rows * cols);
        assert!(!scales.is_empty(), "need at least one scale shard");
        assert_eq!(rows % scales.len(), 0,
                   "scale shards {} must divide rows {rows}", scales.len());
        let bytes_per_row = cols.div_ceil(4);
        let mut bytes = vec![0u8; rows * bytes_per_row];
        for r in 0..rows {
            for c in 0..cols {
                let s = states[r * cols + c];
                bytes[r * bytes_per_row + c / 4] |= enc2(s) << ((c % 4) * 2);
            }
        }
        PackedMatrix { rows, cols, bytes_per_row, bytes, scales }
    }

    /// Pack a ternarized tensor (states + scales) for the decode path.
    pub fn from_ternary(t: &TernaryTensor) -> Self {
        PackedMatrix::from_states(t.rows, t.cols, &t.states, t.scales.clone())
    }

    /// The packed bytes of row `r`.
    #[inline]
    pub fn row_bytes(&self, r: usize) -> &[u8] {
        &self.bytes[r * self.bytes_per_row..(r + 1) * self.bytes_per_row]
    }

    /// The absmean scale applied to row `r`.
    #[inline]
    pub fn row_scale(&self, r: usize) -> f32 {
        self.scales[r / (self.rows / self.scales.len())]
    }

    /// Decode a single state.
    #[inline]
    pub fn get(&self, r: usize, c: usize) -> i8 {
        dec2(self.bytes[r * self.bytes_per_row + c / 4] >> ((c % 4) * 2))
    }

    /// Decode one row back to i8 states.
    pub fn unpack_row(&self, r: usize) -> Vec<i8> {
        (0..self.cols).map(|c| self.get(r, c)).collect()
    }

    /// Storage bits per weight, *including* row-padding overhead.
    pub fn bits_per_weight(&self) -> f64 {
        8.0 * self.bytes.len() as f64 / (self.rows * self.cols).max(1) as f64
    }
}

/// Base-3 packing: 5 ternary states per byte (1.6 bits/weight).
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct PackedBase3 {
    pub len: usize,
    pub bytes: Vec<u8>,
}

impl PackedBase3 {
    pub fn pack(states: &[i8]) -> Self {
        let mut bytes = Vec::with_capacity(states.len().div_ceil(5));
        for chunk in states.chunks(5) {
            let mut v: u16 = 0;
            // little-endian base-3 digits, states mapped -1,0,1 -> 0,1,2
            for &s in chunk.iter().rev() {
                debug_assert!((-1..=1).contains(&s));
                v = v * 3 + (s + 1) as u16;
            }
            bytes.push(v as u8);
        }
        PackedBase3 { len: states.len(), bytes }
    }

    pub fn unpack(&self) -> Vec<i8> {
        let mut out = Vec::with_capacity(self.len);
        for (ci, &b) in self.bytes.iter().enumerate() {
            let mut v = b as u16;
            let n = (self.len - ci * 5).min(5);
            for _ in 0..n {
                out.push((v % 3) as i8 - 1);
                v /= 3;
            }
        }
        out
    }

    pub fn bits_per_weight(&self) -> f64 {
        8.0 * self.bytes.len() as f64 / self.len as f64
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SplitMix64;

    fn random_states(rng: &mut SplitMix64, len: usize) -> Vec<i8> {
        (0..len).map(|_| rng.below(3) as i8 - 1).collect()
    }

    // Property sweeps (seeded stand-ins for proptest; see util/mod.rs).
    #[test]
    fn pack2_roundtrip_property() {
        let mut rng = SplitMix64::new(21);
        for trial in 0..200 {
            let states = random_states(&mut rng, trial % 97);
            let p = Packed2Bit::pack(&states);
            assert_eq!(p.unpack(), states, "trial {trial}");
        }
    }

    #[test]
    fn pack3_roundtrip_property() {
        let mut rng = SplitMix64::new(22);
        for trial in 0..200 {
            let states = random_states(&mut rng, trial % 103);
            let p = PackedBase3::pack(&states);
            assert_eq!(p.unpack(), states, "trial {trial}");
        }
    }

    #[test]
    fn pack2_random_access_property() {
        let mut rng = SplitMix64::new(23);
        for trial in 0..100 {
            let states = random_states(&mut rng, 1 + trial % 77);
            let p = Packed2Bit::pack(&states);
            for (i, &s) in states.iter().enumerate() {
                assert_eq!(p.get(i), s);
            }
        }
    }

    // Satellite: exhaustive roundtrip over every length 0..=257 — the
    // partial final byte (len % 4 and % 5) is covered at every phase.
    #[test]
    fn pack2_roundtrip_every_length_0_to_257() {
        let mut rng = SplitMix64::new(31);
        for len in 0..=257usize {
            let states = random_states(&mut rng, len);
            let p = Packed2Bit::pack(&states);
            assert_eq!(p.bytes.len(), len.div_ceil(4), "len {len}");
            assert_eq!(p.unpack(), states, "len {len}");
        }
    }

    #[test]
    fn pack3_roundtrip_every_length_0_to_257() {
        let mut rng = SplitMix64::new(32);
        for len in 0..=257usize {
            let states = random_states(&mut rng, len);
            let p = PackedBase3::pack(&states);
            assert_eq!(p.unpack(), states, "len {len}");
        }
    }

    #[test]
    fn packed_matrix_roundtrip_all_col_phases() {
        let mut rng = SplitMix64::new(33);
        for rows in [1usize, 2, 5, 8] {
            for cols in [1usize, 3, 4, 6, 7, 8, 13, 16] {
                let states = random_states(&mut rng, rows * cols);
                let m = PackedMatrix::from_states(rows, cols, &states,
                                                  vec![1.0]);
                assert_eq!(m.bytes_per_row, cols.div_ceil(4));
                for r in 0..rows {
                    assert_eq!(m.unpack_row(r), states[r * cols..(r + 1) * cols],
                               "{rows}x{cols} row {r}");
                }
            }
        }
    }

    #[test]
    fn packed_matrix_row_padding_is_zero_encoded() {
        // cols = 5: three pad lanes in each row's final byte must decode
        // to 0 so full-byte LUT passes cannot fabricate contributions.
        let states = vec![1i8; 2 * 5];
        let m = PackedMatrix::from_states(2, 5, &states, vec![1.0]);
        for r in 0..2 {
            let last = m.row_bytes(r)[m.bytes_per_row - 1];
            for lane in 1..4 {
                assert_eq!(dec2(last >> (2 * lane)), 0, "row {r} lane {lane}");
            }
        }
    }

    #[test]
    fn packed_matrix_shard_scales() {
        let states = vec![1i8; 4 * 4];
        let m = PackedMatrix::from_states(4, 4, &states, vec![2.0, 3.0]);
        assert_eq!(m.row_scale(0), 2.0);
        assert_eq!(m.row_scale(1), 2.0);
        assert_eq!(m.row_scale(2), 3.0);
        assert_eq!(m.row_scale(3), 3.0);
    }

    #[test]
    #[should_panic(expected = "must divide rows")]
    fn packed_matrix_rejects_missharded_scales() {
        PackedMatrix::from_states(5, 4, &vec![0i8; 20], vec![1.0, 1.0]);
    }

    #[test]
    fn bits_per_weight_targets() {
        let states = vec![0i8; 10_000];
        assert!((Packed2Bit::pack(&states).bits_per_weight() - 2.0).abs() < 0.01);
        assert!((PackedBase3::pack(&states).bits_per_weight() - 1.6).abs() < 0.01);
    }

    #[test]
    fn base3_is_denser_than_2bit() {
        let states = vec![1i8; 100_000];
        assert!(PackedBase3::pack(&states).bytes.len()
                < Packed2Bit::pack(&states).bytes.len());
    }
}
