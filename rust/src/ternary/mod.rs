//! Ternary deployment substrate: ternarization, packed storage formats,
//! and CPU inference kernels.
//!
//! This is the "deployment" half of the paper's story (§2.1): once a
//! TriLM is trained, inference needs only the ternary states and the
//! per-shard scales. Two packings are provided:
//!
//! - [`Packed2Bit`] — 2 bits/weight (4 trits per byte): the simple
//!   hardware-friendly packing the paper's Fig. 2a "appropriate packing"
//!   refers to for GPU deployment.
//! - [`PackedBase3`] — 5 trits per byte = 1.6 bits/weight, approaching
//!   the information-theoretic 1.58 bits (log2 3) used in the paper's
//!   size accounting (Table 4).
//!
//! The CPU matmul kernels realize the §2.1/F.2 claim that memory-bound
//! decoding speeds up ~proportionally to the compression factor:
//! `matmul_ternary_*` streams 2-bit weights instead of 32-bit floats
//! and replaces multiplies with add/sub (benches/ternary_matmul.rs).
//! The blocked, multi-threaded batched kernel
//! ([`matmul::matmul_ternary_packed`] over a row-aligned
//! [`pack::PackedMatrix`]) is the hot path of the `serve` subsystem;
//! its tiling parameters are [`matmul::ROW_BLOCK`] and
//! [`matmul::COL_BLOCK_TRITS`] (see the module docs there).

pub mod matmul;
pub mod pack;

pub use matmul::{matvec_dense, matvec_ternary_packed, matmul_dense,
                 matmul_ternary_dense, matmul_ternary_packed,
                 matmul_ternary_packed_into};
pub use pack::{Packed2Bit, PackedBase3, PackedMatrix};

use crate::runtime::HostTensor;

/// Per-shard absmean scales (§A.5), mirroring `ref.ternary_scales`.
pub fn ternary_scales(w: &HostTensor, mp: usize) -> Vec<f32> {
    let (rows, cols) = w.dims2();
    assert_eq!(rows % mp, 0, "rows {rows} not divisible by mp {mp}");
    let shard = rows / mp;
    (0..mp)
        .map(|s| {
            let start = s * shard * cols;
            let end = (s + 1) * shard * cols;
            let sum: f64 = w.data[start..end].iter().map(|x| x.abs() as f64).sum();
            1e-5 + (sum / (shard * cols) as f64) as f32
        })
        .collect()
}

/// A ternarized weight matrix: states in {-1, 0, +1} plus per-shard scales.
#[derive(Debug, Clone)]
pub struct TernaryTensor {
    pub rows: usize,
    pub cols: usize,
    /// Row-major states, one i8 in {-1, 0, 1} per weight.
    pub states: Vec<i8>,
    /// mp scale values; row r uses scales[r / (rows/mp)].
    pub scales: Vec<f32>,
}

impl TernaryTensor {
    /// Ternarize latent FP weights (round(clip(w/gamma, -1, 1))), the
    /// exact inference-time transform of Table 1.
    pub fn from_latent(w: &HostTensor, mp: usize) -> Self {
        let (rows, cols) = w.dims2();
        let scales = ternary_scales(w, mp);
        let shard = rows / mp;
        let mut states = Vec::with_capacity(rows * cols);
        for r in 0..rows {
            let g = scales[r / shard];
            for c in 0..cols {
                let t = (w.at2(r, c) / g).clamp(-1.0, 1.0).round() as i8;
                states.push(t);
            }
        }
        TernaryTensor { rows, cols, states, scales }
    }

    /// Dequantize back to floats (gamma * w_hat).
    pub fn dequant(&self) -> HostTensor {
        let shard = self.rows / self.scales.len();
        let mut data = Vec::with_capacity(self.states.len());
        for r in 0..self.rows {
            let g = self.scales[r / shard];
            for c in 0..self.cols {
                data.push(g * self.states[r * self.cols + c] as f32);
            }
        }
        HostTensor::new(vec![self.rows, self.cols], data)
    }

    /// Fraction of zero states — the sparsity ternary hardware exploits
    /// (§2.3, Broader-Impact "Cerebras" note).
    pub fn sparsity(&self) -> f64 {
        self.states.iter().filter(|&&s| s == 0).count() as f64
            / self.states.len().max(1) as f64
    }

    /// Row scale for row r.
    pub fn row_scale(&self, r: usize) -> f32 {
        self.scales[r / (self.rows / self.scales.len())]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn sample(rows: usize, cols: usize, seed: u64) -> HostTensor {
        HostTensor::randn(vec![rows, cols], 0.05, seed)
    }

    #[test]
    fn scales_match_absmean() {
        let w = sample(8, 4, 0);
        let s = ternary_scales(&w, 2);
        assert_eq!(s.len(), 2);
        let manual: f32 =
            w.data[..16].iter().map(|x| x.abs()).sum::<f32>() / 16.0 + 1e-5;
        assert!((s[0] - manual).abs() < 1e-6);
    }

    #[test]
    fn states_are_ternary() {
        let t = TernaryTensor::from_latent(&sample(16, 8, 1), 4);
        assert!(t.states.iter().all(|&s| (-1..=1).contains(&s)));
        assert_eq!(t.scales.len(), 4);
    }

    #[test]
    fn dequant_error_bounded_by_half_gamma() {
        let w = sample(16, 8, 2);
        let t = TernaryTensor::from_latent(&w, 1);
        let dq = t.dequant();
        let g = t.scales[0];
        for (a, b) in w.data.iter().zip(dq.data.iter()) {
            // For |w| <= 1.5*gamma the rounding error is <= gamma/2;
            // beyond that the clip dominates, error <= |w| - gamma.
            let bound = if a.abs() <= 1.5 * g { g / 2.0 + 1e-6 }
                        else { a.abs() - g + 1e-6 };
            assert!((a - b).abs() <= bound, "{a} vs {b} (gamma {g})");
        }
    }

    #[test]
    fn typical_gaussian_weights_have_nonzero_sparsity() {
        // For N(0, sigma), absmean = sigma*sqrt(2/pi); |w| < gamma/2
        // happens ~31% of the time -> zero states exist in bulk.
        let t = TernaryTensor::from_latent(&sample(64, 64, 3), 1);
        let sp = t.sparsity();
        assert!(sp > 0.15 && sp < 0.5, "sparsity {sp}");
    }

    #[test]
    fn mp_shards_get_independent_scales() {
        let mut w = sample(8, 4, 4);
        for v in &mut w.data[16..] {
            *v *= 10.0; // second shard much larger
        }
        let t = TernaryTensor::from_latent(&w, 2);
        assert!(t.scales[1] > 5.0 * t.scales[0]);
    }
}
