//! Std-only HTTP/1.1 serving front end: the network face of the
//! [`crate::serve`] engine, turning the ROADMAP's "continuous-batching
//! scheduler" into a system that serves traffic — with backpressure as
//! *protocol*, not as internal state.
//!
//! - [`http`] — the wire layer: request parsing (bounded head/body),
//!   response writing, chunked transfer encoding, and the loopback
//!   client the integration harness uses. No dependencies, no async
//!   runtime: thread-per-connection with `Connection: close`, which is
//!   exactly as much server as a CPU-bound batch-8 decode engine can
//!   feed.
//! - [`api`] — the JSON surface: `POST /generate` bodies, admission
//!   control (out-of-vocab → 400, `prompt + max_new_tokens` over the
//!   per-lane KV context → 413), error→status mapping (429 carries
//!   `Retry-After`), ndjson stream lines, the `/stats` document.
//! - [`shard`] — per-shard tenant-fair bounded admission queues
//!   ([`shard::ShardHandle`]) and the worker loop
//!   ([`shard::run_shard`]) that owns a shard's model +
//!   [`crate::serve::Scheduler`] and streams each sampled token
//!   through the requester's channel the moment
//!   [`crate::serve::scheduler::StreamEvent::Token`] fires.
//!
//! Sharding: [`Server::start`] builds `shards` identical models (same
//! latent seed → bitwise-identical weights, so routing never changes a
//! stream) each with its own scheduler, worker thread group, and
//! *shard-local* prefix cache; [`shard::shard_for_prompt`] routes by
//! FNV hash of the first KV page of prompt tokens, so repeated system
//! prompts always hit the shard whose cache already holds their pages.
//!
//! Speculative decoding: `--speculative` gives every shard worker a
//! second, cheap draft model ([`ServerConfig::draft_family`], TriLM by
//! default) realized over the same seeded latent weights; the shard's
//! scheduler verifies the draft's proposals in chunked target passes
//! ([`crate::serve::Scheduler::set_speculative`]). Streams stay
//! bitwise identical to plain decode, and `/stats` carries the
//! acceptance counters (`spec_proposed` / `spec_accepted` /
//! `accepted_per_step`) plus the `spec_k_effective` gauge — the
//! acceptance-adaptive proposal length the scheduler is currently
//! drafting at (halved on low acceptance, nudged back up on full
//! acceptance, clamped to the configured `--spec-k`).
//!
//! Endpoints: `POST /generate` (chunked ndjson token stream),
//! `GET /stats`, `GET /healthz`, `POST /shutdown`. Streaming format
//! and status codes are documented in the README's "Serving over
//! HTTP" section; `tests/server_e2e.rs` is the acceptance harness
//! (bitwise stream equality vs a direct [`crate::serve::Scheduler`],
//! deterministic 429/413, stats consistency, zero leaked KV pages
//! after drain).

pub mod api;
pub mod http;
pub mod shard;

use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::{mpsc, Arc, Mutex};
use std::time::Duration;

use crate::serve::model::{FamilySpec, LatentAttnLm, LatentLm, LmDims,
                          QuantMethod};
use crate::serve::{DecodeModel, FaultPlan, SpecConfig};
use crate::Result;

pub use api::{AdmissionLimits, ApiError, GenerateBody, ShardSnapshot};
pub use shard::{run_shard, run_shard_spec, run_shard_supervised,
                run_shard_supervised_spec, shard_for_prompt,
                ShardConfig, ShardHandle, StreamItem};

/// Everything `spectra serve` configures. One config builds the whole
/// server: `shards` schedulers over `shards` identical synthetic
/// models (seeded by `seed`, so every shard decodes bitwise the same).
#[derive(Debug, Clone)]
pub struct ServerConfig {
    /// TCP port on 127.0.0.1 (0 = ephemeral; read it back from
    /// [`Server::addr`]).
    pub port: u16,
    /// Scheduler shards (worker thread groups).
    pub shards: usize,
    /// Lanes (max batch) per shard.
    pub lanes: usize,
    /// Kernel pool threads per shard (0 = auto).
    pub threads: usize,
    /// Prefill chunk per shard scheduler.
    pub prefill_chunk: usize,
    /// Bounded admission queue cap per shard — depth `cap` is where
    /// 429 starts.
    pub queue_cap: usize,
    /// Per-lane KV context tokens: pool capacity for attention models
    /// and the 413 admission bound for every family.
    pub kv_context: usize,
    pub family: FamilySpec,
    /// Paged-KV attention models (`AttnLm`) vs decay-state models
    /// (`SpectraLm`).
    pub attn: bool,
    /// Attention heads (ignored when `attn` is false).
    pub heads: usize,
    /// Grouped-query attention: shared key/value heads (`<= heads`,
    /// `heads % kv_heads == 0`). `kv_heads == heads` is classic MHA —
    /// bitwise identical to the pre-GQA server. Ignored when `attn` is
    /// false.
    pub kv_heads: usize,
    /// Sliding-window attention span in tokens (0 = full context).
    /// Shrinking the window below the context changes streams; the
    /// default 0 is bitwise identical to the unwindowed server.
    pub window: usize,
    /// With a finite `window`, every `window_interleave + 1`-th layer
    /// attends globally (Gemma3-style `window:global` interleave; 0 =
    /// all layers windowed, which is what lets the KV cache recycle
    /// out-of-window pages).
    pub window_interleave: usize,
    pub dims: LmDims,
    /// Ternary mixed-precision group size.
    pub mp: usize,
    /// Latent weight seed (also the GPTQ calibration seed).
    pub seed: u64,
    /// Socket read timeout: a client must deliver its request head +
    /// body within this.
    pub read_timeout_ms: u64,
    /// Socket write timeout per chunk write (bounds one write, not the
    /// whole stream).
    pub write_timeout_ms: u64,
    /// Relay silence budget: with no stream item for this long the
    /// relay gives up with an in-band `relay_timeout` error line. This
    /// unwedges a stalled worker; worker *death* is detected
    /// separately (channel disconnect → `worker_restarted`), and slow
    /// queues are bounded by `queue_deadline_ms` — three causes, three
    /// distinct client-visible outcomes.
    pub relay_timeout_ms: u64,
    /// Queue-admission deadline: a request parked longer than this
    /// expires with a `deadline_expired` error line (0 = wait forever).
    pub queue_deadline_ms: u64,
    /// Decode wall-clock cap per request: past it the stream is
    /// truncated with `finish_reason = "deadline_expired"` (0 = decode
    /// to budget).
    pub decode_deadline_ms: u64,
    /// Deterministic fault injection, applied to shard 0 only so the
    /// other shards double as the blast-radius control group.
    pub fault_plan: FaultPlan,
    /// Draft-verify speculative decoding (`--speculative`): every
    /// shard worker holds a second, cheap draft model (same latent
    /// weights, `draft_family` storage) and the scheduler verifies its
    /// proposals in chunked target passes. Streams stay bitwise
    /// identical; requires `attn` (rollback needs the paged-KV model).
    pub speculative: bool,
    /// Storage family of the draft model (TriLM by default — the
    /// paper's bits-per-param win as a latency win).
    pub draft_family: FamilySpec,
    /// Draft tokens proposed per verify round (>= 1).
    pub spec_k: usize,
}

impl Default for ServerConfig {
    /// Small synthetic geometry, 2 shards × 2 lanes — the e2e-test
    /// shape. `spectra serve` overrides from flags.
    fn default() -> ServerConfig {
        ServerConfig {
            port: 0,
            shards: 2,
            lanes: 2,
            threads: 1,
            prefill_chunk: 4,
            queue_cap: 8,
            kv_context: 64,
            family: FamilySpec::Float,
            attn: true,
            heads: 4,
            kv_heads: 4,
            window: 0,
            window_interleave: 0,
            dims: LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 },
            mp: 1,
            seed: 11,
            read_timeout_ms: 10_000,
            write_timeout_ms: 30_000,
            relay_timeout_ms: 120_000,
            queue_deadline_ms: 0,
            decode_deadline_ms: 0,
            fault_plan: FaultPlan::default(),
            speculative: false,
            draft_family: FamilySpec::Ternary,
            spec_k: 3,
        }
    }
}

/// `0` means "off" for the deadline knobs; everything else is a
/// duration in milliseconds.
fn ms_opt(ms: u64) -> Option<Duration> {
    (ms > 0).then(|| Duration::from_millis(ms))
}

/// Build one shard's model. Matches on the concrete builders (not
/// [`LatentAttnLm::build`]) because a worker thread needs the `Send`
/// bound in its box, and every concrete model is plain data +
/// `Mutex`-guarded KV state.
fn build_model(cfg: &ServerConfig) -> Result<Box<dyn DecodeModel + Send>> {
    Ok(if cfg.attn {
        build_attn_model(cfg, cfg.family)?
    } else {
        let latent = LatentLm::synthetic(cfg.dims.clone(), cfg.mp, cfg.seed);
        match cfg.family {
            FamilySpec::Float => Box::new(latent.build_float()),
            FamilySpec::Ternary => Box::new(latent.build_ternary()),
            FamilySpec::Quant { bits, group, method: QuantMethod::Rtn } =>
                Box::new(latent.build_quant_rtn(bits, group)),
            FamilySpec::Quant { bits, group, method: QuantMethod::Gptq } =>
                Box::new(latent.build_quant_gptq(bits, group, cfg.seed)?),
        }
    })
}

/// Realize `family` storage over the shard's attention latent (the same
/// seeded weights every family shares). Both the target and — under
/// `--speculative` — the draft model come through here, so a
/// same-family draft is bitwise-identical to its target.
fn build_attn_model(cfg: &ServerConfig, family: FamilySpec)
                    -> Result<Box<dyn DecodeModel + Send>> {
    let latent = LatentAttnLm::synthetic(cfg.dims.clone(), cfg.heads,
                                         cfg.mp, cfg.seed)
        .with_kv_heads(cfg.kv_heads)
        .with_window(cfg.window, cfg.window_interleave);
    Ok(match family {
        FamilySpec::Float =>
            Box::new(latent.build_float(cfg.lanes, cfg.kv_context)),
        FamilySpec::Ternary =>
            Box::new(latent.build_ternary(cfg.lanes, cfg.kv_context)),
        FamilySpec::Quant { bits, group, method: QuantMethod::Rtn } =>
            Box::new(latent.build_quant_rtn(bits, group, cfg.lanes,
                                            cfg.kv_context)),
        FamilySpec::Quant { bits, group, method: QuantMethod::Gptq } =>
            Box::new(latent.build_quant_gptq(bits, group, cfg.seed,
                                             cfg.lanes, cfg.kv_context)?),
    })
}

/// Build one shard's speculative draft model: the same latent weights
/// as the target, realized in `draft_family` storage. `Ok(None)` when
/// speculation is off; an error when the config cannot speculate at
/// all (decay models cannot roll back rejected tokens).
fn build_draft(cfg: &ServerConfig)
               -> Result<Option<Box<dyn DecodeModel + Send>>> {
    if !cfg.speculative {
        return Ok(None);
    }
    if !cfg.attn {
        anyhow::bail!("--speculative needs --attn: draft-verify rollback \
                       requires the paged-KV attention model (a decay \
                       carry cannot be rewound)");
    }
    Ok(Some(build_attn_model(cfg, cfg.draft_family)?))
}

/// Shared state a connection handler routes against.
struct Router {
    shards: Vec<Arc<ShardHandle>>,
    limits: AdmissionLimits,
    /// Set by `POST /shutdown`; [`Server::shutdown_requested`] exposes
    /// it so the CLI loop knows when to begin the drain.
    shutdown_flag: Arc<AtomicBool>,
    read_timeout: Duration,
    write_timeout: Duration,
    relay_timeout: Duration,
}

/// A running server: accept loop + `shards` worker threads, stopped by
/// [`Server::shutdown`] (drain) — dropping a `Server` without calling
/// it leaves threads running, so the CLI and tests always shut down
/// explicitly.
pub struct Server {
    addr: SocketAddr,
    shards: Vec<Arc<ShardHandle>>,
    shutdown_flag: Arc<AtomicBool>,
    accept: Option<std::thread::JoinHandle<()>>,
    workers: Vec<std::thread::JoinHandle<usize>>,
    conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>,
}

impl Server {
    /// Build the shard models, bind `127.0.0.1:port`, spawn one worker
    /// thread per shard and the accept loop. Returns once the socket
    /// is listening (the address is immediately connectable).
    pub fn start(cfg: ServerConfig) -> Result<Server> {
        let shards_n = cfg.shards.max(1);
        // Validate the model config (e.g. GPTQ calibration failures)
        // once, here, where an error can still be returned; the
        // supervised workers below rebuild on demand and may therefore
        // expect success.
        drop(build_model(&cfg)?);
        drop(build_draft(&cfg)?);
        let limits = AdmissionLimits {
            vocab: cfg.dims.vocab,
            max_context: cfg.kv_context,
        };
        let shard_cfg = ShardConfig {
            lanes: cfg.lanes,
            threads: cfg.threads,
            prefill_chunk: cfg.prefill_chunk,
            queue_deadline: ms_opt(cfg.queue_deadline_ms),
            decode_deadline: ms_opt(cfg.decode_deadline_ms),
            faults: FaultPlan::default(),
            spec: cfg.speculative.then(|| SpecConfig {
                draft_family: cfg.draft_family,
                k: cfg.spec_k.max(1),
            }),
        };
        let shards: Vec<Arc<ShardHandle>> = (0..shards_n)
            .map(|_| Arc::new(ShardHandle::new(cfg.queue_cap)))
            .collect();
        let workers = shards.iter().enumerate().map(|(i, h)| {
            let h = h.clone();
            let model_cfg = cfg.clone();
            let mut scfg = shard_cfg.clone();
            // Faults hit shard 0 only: the other shards double as the
            // chaos tests' blast-radius control group.
            if i == 0 {
                scfg.faults = cfg.fault_plan.clone();
            }
            std::thread::spawn(move || {
                run_shard_supervised_spec(
                    || (build_model(&model_cfg)
                            .expect("model config was validated at \
                                     startup"),
                        build_draft(&model_cfg)
                            .expect("draft config was validated at \
                                     startup")),
                    &h, &scfg)
            })
        }).collect();

        let listener = TcpListener::bind(("127.0.0.1", cfg.port))
            .map_err(|e| anyhow::anyhow!("bind 127.0.0.1:{}: {e}",
                                         cfg.port))?;
        let addr = listener.local_addr()
            .map_err(|e| anyhow::anyhow!("local_addr: {e}"))?;
        listener.set_nonblocking(true)
            .map_err(|e| anyhow::anyhow!("set_nonblocking: {e}"))?;

        let shutdown_flag = Arc::new(AtomicBool::new(false));
        let conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>> =
            Arc::new(Mutex::new(Vec::new()));
        let router = Arc::new(Router {
            shards: shards.clone(),
            limits,
            shutdown_flag: shutdown_flag.clone(),
            read_timeout: Duration::from_millis(cfg.read_timeout_ms.max(1)),
            write_timeout: Duration::from_millis(cfg.write_timeout_ms.max(1)),
            relay_timeout: Duration::from_millis(cfg.relay_timeout_ms.max(1)),
        });
        let accept = {
            let stop = shutdown_flag.clone();
            let conns = conns.clone();
            std::thread::spawn(move || accept_loop(listener, router, stop,
                                                   conns))
        };
        Ok(Server {
            addr,
            shards,
            shutdown_flag,
            accept: Some(accept),
            workers,
            conns,
        })
    }

    /// The bound address (resolves port 0).
    pub fn addr(&self) -> SocketAddr {
        self.addr
    }

    /// True once `POST /shutdown` has been received (or
    /// [`Server::shutdown`] begun) — the CLI's cue to drain.
    pub fn shutdown_requested(&self) -> bool {
        self.shutdown_flag.load(Ordering::SeqCst)
    }

    /// Live `/stats` snapshots, one per shard.
    pub fn snapshots(&self) -> Vec<ShardSnapshot> {
        self.shards.iter().enumerate()
            .map(|(i, h)| h.snapshot(i))
            .collect()
    }

    /// Graceful shutdown: stop accepting, refuse new admissions (503),
    /// let every queued and live request run to completion with its
    /// stream closed properly, release prefix-cache pins, join all
    /// threads. Returns the final per-shard snapshots with `kv_pages`
    /// set to the post-drain page count — 0 everywhere unless pages
    /// leaked.
    pub fn shutdown(mut self) -> Vec<ShardSnapshot> {
        self.shutdown_flag.store(true, Ordering::SeqCst);
        for h in &self.shards {
            h.request_shutdown();
        }
        if let Some(a) = self.accept.take() {
            let _ = a.join();
        }
        // Workers drain (serving every parked request), then handlers
        // observe their Done items and finish writing.
        let finals: Vec<usize> = self.workers.drain(..)
            .map(|w| w.join().unwrap_or(usize::MAX))
            .collect();
        let conns = std::mem::take(&mut *lock_ignore_poison(&self.conns));
        for c in conns {
            let _ = c.join();
        }
        self.shards.iter().enumerate().map(|(i, h)| {
            let mut snap = h.snapshot(i);
            snap.kv_pages = finals[i];
            snap
        }).collect()
    }
}

fn lock_ignore_poison<T>(m: &Mutex<T>) -> std::sync::MutexGuard<'_, T> {
    m.lock().unwrap_or_else(|e| e.into_inner())
}

fn accept_loop(listener: TcpListener, router: Arc<Router>,
               stop: Arc<AtomicBool>,
               conns: Arc<Mutex<Vec<std::thread::JoinHandle<()>>>>) {
    loop {
        match listener.accept() {
            Ok((stream, _peer)) => {
                let router = router.clone();
                let h = std::thread::spawn(move || {
                    handle_connection(stream, &router);
                });
                let mut g = lock_ignore_poison(&conns);
                // Reap finished handlers so a long-lived server does
                // not accumulate handles.
                g.retain(|c| !c.is_finished());
                g.push(h);
            }
            Err(e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
            Err(_) => {
                if stop.load(Ordering::SeqCst) {
                    return;
                }
                std::thread::sleep(Duration::from_millis(2));
            }
        }
    }
}

fn respond_error(stream: &mut TcpStream, err: &ApiError) {
    let headers = err.extra_headers();
    let header_refs: Vec<(&str, &str)> = headers.iter()
        .map(|(n, v)| (n.as_str(), v.as_str()))
        .collect();
    let _ = http::write_response(stream, err.status(), &header_refs,
                                 "application/json",
                                 err.body().as_bytes());
}

fn handle_connection(mut stream: TcpStream, router: &Router) {
    let _ = stream.set_nodelay(true);
    // A client must deliver its request promptly; streaming out has no
    // deadline (`write_timeout` bounds each chunk write, not the
    // stream). Both knobs come from `ServerConfig` (`--read-timeout-ms`
    // / `--write-timeout-ms`).
    let _ = stream.set_read_timeout(Some(router.read_timeout));
    let _ = stream.set_write_timeout(Some(router.write_timeout));
    let req = {
        let mut reader = std::io::BufReader::new(
            match stream.try_clone() {
                Ok(s) => s,
                Err(_) => return,
            });
        match http::read_request(&mut reader) {
            Ok(r) => r,
            Err(http::HttpError::Io(_)) => return,
            Err(http::HttpError::BadRequest(m)) => {
                respond_error(&mut stream, &ApiError::BadRequest(m));
                return;
            }
            Err(http::HttpError::TooLarge(m)) => {
                let _ = http::write_response(
                    &mut stream, 413, &[], "application/json",
                    api::ApiError::BadRequest(m).body().as_bytes());
                return;
            }
        }
    };
    match (req.method.as_str(), req.path.as_str()) {
        ("POST", "/generate") => handle_generate(stream, router, &req.body),
        ("GET", "/stats") => {
            let snaps: Vec<ShardSnapshot> = router.shards.iter().enumerate()
                .map(|(i, h)| h.snapshot(i))
                .collect();
            let _ = http::write_response(&mut stream, 200, &[],
                                         "application/json",
                                         api::stats_json(&snaps).as_bytes());
        }
        ("GET", "/healthz") => {
            let _ = http::write_response(&mut stream, 200, &[],
                                         "application/json",
                                         b"{\"ok\":true}");
        }
        ("POST", "/shutdown") => {
            router.shutdown_flag.store(true, Ordering::SeqCst);
            let _ = http::write_response(&mut stream, 200, &[],
                                         "application/json",
                                         b"{\"shutting_down\":true}");
        }
        ("POST", _) | ("GET", _) | ("HEAD", _) => {
            let known = matches!(req.path.as_str(),
                                 "/generate" | "/stats" | "/healthz"
                                 | "/shutdown");
            let err = if known { ApiError::MethodNotAllowed }
                      else { ApiError::NotFound };
            respond_error(&mut stream, &err);
        }
        _ => respond_error(&mut stream, &ApiError::MethodNotAllowed),
    }
}

/// `POST /generate`: parse → admission-check → route by prefix hash →
/// park in the shard's fair queue → relay [`StreamItem`]s as chunked
/// ndjson until the done trailer.
fn handle_generate(mut stream: TcpStream, router: &Router, body: &[u8]) {
    let parsed = match api::parse_generate(body) {
        Ok(p) => p,
        Err(e) => return respond_error(&mut stream, &e),
    };
    let shard_idx = shard_for_prompt(&parsed.prompt, router.shards.len());
    let shard = &router.shards[shard_idx];
    if let Err(e) = api::check_admission(&parsed, &router.limits) {
        if matches!(e, ApiError::ContextTooLarge { .. }) {
            shard.note_rejected_413(&parsed.tenant);
        }
        return respond_error(&mut stream, &e);
    }
    let (tx, rx) = mpsc::channel();
    let ticket = match shard.try_admit(parsed, tx) {
        Ok(t) => t,
        Err(e) => return respond_error(&mut stream, &e),
    };
    if http::write_chunked_head(&mut stream, 200,
                                "application/x-ndjson").is_err() {
        // Client gone before the first byte: cancel so the request
        // never occupies a lane (or leaves one, pages freed, within a
        // step if it already went live).
        shard.cancel(ticket);
        return;
    }
    let mut out = http::ChunkedWriter::new(stream);
    // A parked request decodes only once a lane frees up; under a full
    // server that wait is real, so the relay timeout is generous — it
    // exists to unwedge a *stalled* worker. Worker death is a channel
    // disconnect (distinct arm below), and slow queues are the queue
    // deadline's job; each failure mode gets its own error line.
    loop {
        match rx.recv_timeout(router.relay_timeout) {
            Ok(StreamItem::Token { token, index }) => {
                if out.chunk(api::token_line(index, token)
                             .as_bytes()).is_err() {
                    // Client hung up mid-stream: cancel the lane so
                    // its KV pages return within one scheduler step
                    // instead of decoding to completion for nobody.
                    shard.cancel(ticket);
                    return;
                }
            }
            Ok(StreamItem::Done(c)) => {
                let _ = out.chunk(api::done_line(
                    c.tokens.len(), c.prompt_len, c.lane_steps,
                    c.ttft_steps, c.finish_reason.as_str()).as_bytes());
                let _ = out.finish();
                return;
            }
            Ok(StreamItem::Error { kind, detail }) => {
                // In-band failure from the shard (queue-deadline
                // expiry, supervisor giving up): one error line, then
                // close.
                let _ = out.chunk(api::error_line(kind, &detail)
                                  .as_bytes());
                let _ = out.finish();
                return;
            }
            Err(mpsc::RecvTimeoutError::Timeout) => {
                // No stream progress at all within the relay budget:
                // the worker is wedged (or the queue deadline is off
                // and the backlog truly is this deep). Tell the client
                // which timeout fired and release the request.
                let _ = out.chunk(api::error_line(
                    "relay_timeout",
                    "no stream progress within the relay timeout")
                    .as_bytes());
                let _ = out.finish();
                shard.cancel(ticket);
                return;
            }
            Err(mpsc::RecvTimeoutError::Disconnected) => {
                // The worker dropped our sender without a done
                // trailer: it panicked mid-request and its supervisor
                // is rebuilding the shard. Fail fast — the old
                // behavior conflated this with a slow queue and sat
                // out the full relay timeout.
                let _ = out.chunk(api::error_line(
                    "worker_restarted",
                    "shard worker crashed mid-request and was \
                     restarted; retry")
                    .as_bytes());
                let _ = out.finish();
                return;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::Scheduler;
    use crate::util::json::Json;

    /// One loopback smoke over real sockets: healthz, a greedy
    /// generate stream (checked against a direct [`Scheduler`] run on
    /// an identical model), stats, graceful shutdown with zero leaked
    /// pages. The full four-family matrix + 429/413 live in
    /// `tests/server_e2e.rs`.
    #[test]
    fn loopback_generate_stats_shutdown() {
        let cfg = ServerConfig { shards: 2, lanes: 2,
                                 ..ServerConfig::default() };
        let server = Server::start(cfg.clone()).unwrap();
        let addr = server.addr();

        let ok = http::client_roundtrip(&addr, "GET", "/healthz", b"")
            .unwrap();
        assert_eq!(ok.status, 200);

        let prompt = vec![3u32, 9, 27];
        let resp = http::client_roundtrip(
            &addr, "POST", "/generate",
            br#"{"prompt":[3,9,27],"max_new_tokens":4,"tenant":"t"}"#)
            .unwrap();
        assert_eq!(resp.status, 200);
        let mut streamed = Vec::new();
        let mut saw_done = false;
        for line in resp.body_str().lines() {
            let doc = Json::parse(line).unwrap();
            if doc.opt("done").is_some() {
                saw_done = true;
                assert_eq!(doc.get("tokens").unwrap().as_usize().unwrap(),
                           streamed.len());
            } else {
                assert_eq!(doc.get("index").unwrap().as_usize().unwrap(),
                           streamed.len());
                streamed.push(doc.get("token").unwrap()
                              .as_usize().unwrap() as u32);
            }
        }
        assert!(saw_done, "stream must close with a done trailer");

        // Reference: identical model (same cfg seed), direct scheduler.
        let model = build_model(&cfg).unwrap();
        let mut sched = Scheduler::new(&*model, 1, 1);
        sched.submit(crate::serve::GenRequest::greedy(0, prompt, 4));
        let direct = sched.run().remove(0).tokens;
        assert_eq!(streamed, direct,
                   "HTTP stream must be bitwise-equal to direct decode");

        let stats = http::client_roundtrip(&addr, "GET", "/stats", b"")
            .unwrap();
        let doc = Json::parse(&stats.body_str()).unwrap();
        assert_eq!(doc.get("served").unwrap().as_usize().unwrap(), 1);
        assert_eq!(doc.get("shards").unwrap().as_arr().unwrap().len(), 2);

        // Unknown path / wrong method.
        assert_eq!(http::client_roundtrip(&addr, "GET", "/nope", b"")
                   .unwrap().status, 404);
        assert_eq!(http::client_roundtrip(&addr, "GET", "/generate", b"")
                   .unwrap().status, 405);
        assert_eq!(http::client_roundtrip(&addr, "POST", "/generate",
                                          b"not json").unwrap().status, 400);

        let finals = server.shutdown();
        assert_eq!(finals.len(), 2);
        for s in &finals {
            assert_eq!(s.kv_pages, 0, "shard {} leaked pages", s.shard);
        }
        assert_eq!(finals.iter().map(|s| s.served).sum::<usize>(), 1);
    }

    #[test]
    fn loopback_speculative_stream_is_lossless() {
        let cfg = ServerConfig { shards: 1, lanes: 2, speculative: true,
                                 ..ServerConfig::default() };
        let server = Server::start(cfg.clone()).unwrap();
        let addr = server.addr();

        let prompt = vec![5u32, 12, 31];
        let resp = http::client_roundtrip(
            &addr, "POST", "/generate",
            br#"{"prompt":[5,12,31],"max_new_tokens":6,"tenant":"t"}"#)
            .unwrap();
        assert_eq!(resp.status, 200);
        let mut streamed = Vec::new();
        for line in resp.body_str().lines() {
            let doc = Json::parse(line).unwrap();
            if doc.opt("done").is_none() {
                streamed.push(doc.get("token").unwrap()
                              .as_usize().unwrap() as u32);
            }
        }

        // Reference: plain (non-speculative) decode on the identical
        // target model — speculation must be invisible in the stream.
        let plain = ServerConfig { speculative: false, ..cfg };
        let model = build_model(&plain).unwrap();
        let mut sched = Scheduler::new(&*model, 1, 1);
        sched.submit(crate::serve::GenRequest::greedy(0, prompt, 6));
        let direct = sched.run().remove(0).tokens;
        assert_eq!(streamed, direct,
                   "speculative HTTP stream must be bitwise-equal to \
                    plain decode");

        // `/stats` carries the schema-7 acceptance counters.
        let stats = http::client_roundtrip(&addr, "GET", "/stats", b"")
            .unwrap();
        let doc = Json::parse(&stats.body_str()).unwrap();
        assert!(doc.get("spec_proposed").unwrap()
                .as_usize().unwrap() > 0,
                "the draft must have proposed tokens");
        assert!(doc.get("spec_accepted").unwrap().as_usize().unwrap()
                <= doc.get("spec_proposed").unwrap()
                    .as_usize().unwrap());

        let finals = server.shutdown();
        assert_eq!(finals[0].kv_pages, 0,
                   "target and draft caches must both drain clean");
    }

    #[test]
    fn post_shutdown_sets_the_drain_flag() {
        let server = Server::start(ServerConfig {
            shards: 1, ..ServerConfig::default() }).unwrap();
        assert!(!server.shutdown_requested());
        let resp = http::client_roundtrip(&server.addr(), "POST",
                                          "/shutdown", b"").unwrap();
        assert_eq!(resp.status, 200);
        assert!(server.shutdown_requested());
        // After drain begins, new work is refused with 503.
        let finals = server.shutdown();
        assert_eq!(finals[0].kv_pages, 0);
    }
}
