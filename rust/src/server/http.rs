//! Std-only HTTP/1.1 plumbing: request parsing, response writing,
//! chunked transfer encoding — and the matching loopback client the
//! integration harness drives real sockets with.
//!
//! Scope is deliberately the subset serving needs (matching the repo's
//! offline-vendoring pattern: no hyper, no tokio, no serde): one
//! request per connection (`Connection: close`), `Content-Length`
//! bodies in, fixed or chunked bodies out. Every parser is a pure
//! function over byte buffers so the whole layer unit-tests without a
//! socket; the only I/O here is `read_request`'s buffered fill and the
//! writers' `Write` calls.

use std::io::{BufRead, Read, Write};

/// Request head larger than this is refused (431-class garbage guard).
pub const MAX_HEAD_BYTES: usize = 16 * 1024;
/// Request body larger than this is refused before buffering it.
pub const MAX_BODY_BYTES: usize = 1 << 20;

/// A parsed HTTP/1.1 request.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct Request {
    pub method: String,
    /// Path as sent (query strings are not split off; the serving API
    /// does not use them).
    pub path: String,
    /// Header `(name, value)` pairs, names lowercased.
    pub headers: Vec<(String, String)>,
    pub body: Vec<u8>,
}

impl Request {
    /// First value of `name` (ASCII case-insensitive lookup; names are
    /// stored lowercased).
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }
}

/// Protocol-level failure while reading a request. `Io` is the
/// connection dying (nothing to respond to); the other two map to
/// status codes.
#[derive(Debug)]
pub enum HttpError {
    Io(std::io::Error),
    /// Malformed request line / headers — respond 400.
    BadRequest(String),
    /// Head or declared body over the hard limits — respond 413.
    TooLarge(String),
}

impl std::fmt::Display for HttpError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            HttpError::Io(e) => write!(f, "io: {e}"),
            HttpError::BadRequest(m) => write!(f, "bad request: {m}"),
            HttpError::TooLarge(m) => write!(f, "too large: {m}"),
        }
    }
}

impl From<std::io::Error> for HttpError {
    fn from(e: std::io::Error) -> HttpError {
        HttpError::Io(e)
    }
}

/// Read and parse one request from `r`. The head is read through the
/// `BufRead` buffer line by line (never past the body), then exactly
/// `Content-Length` body bytes are read. Requests with
/// `Transfer-Encoding` bodies are refused — the serving API takes
/// small JSON documents, not streams.
pub fn read_request<R: BufRead>(r: &mut R) -> Result<Request, HttpError> {
    let mut head = Vec::new();
    let mut line = Vec::new();
    // Request line + headers, terminated by an empty line.
    loop {
        line.clear();
        let n = r.read_until(b'\n', &mut line)?;
        if n == 0 {
            if head.is_empty() {
                // Peer closed without sending anything (health probes
                // do this); report as a clean EOF-ish error.
                return Err(HttpError::Io(std::io::Error::new(
                    std::io::ErrorKind::UnexpectedEof,
                    "connection closed before request line")));
            }
            return Err(HttpError::BadRequest("truncated head".into()));
        }
        head.extend_from_slice(&line);
        if head.len() > MAX_HEAD_BYTES {
            return Err(HttpError::TooLarge(format!(
                "request head exceeds {MAX_HEAD_BYTES} bytes")));
        }
        if line == b"\r\n" || line == b"\n" {
            break;
        }
    }
    let head = std::str::from_utf8(&head)
        .map_err(|_| HttpError::BadRequest("head is not utf-8".into()))?;
    let mut lines = head.split_terminator('\n')
        .map(|l| l.strip_suffix('\r').unwrap_or(l));
    let request_line = lines.next().unwrap_or("");
    let mut parts = request_line.split_whitespace();
    let (method, path, version) = match (parts.next(), parts.next(),
                                         parts.next(), parts.next()) {
        (Some(m), Some(p), Some(v), None) => (m, p, v),
        _ => return Err(HttpError::BadRequest(format!(
            "malformed request line '{request_line}'"))),
    };
    if !version.starts_with("HTTP/1.") {
        return Err(HttpError::BadRequest(format!(
            "unsupported version '{version}'")));
    }
    let mut headers = Vec::new();
    for l in lines {
        if l.is_empty() {
            break;
        }
        let Some((name, value)) = l.split_once(':') else {
            return Err(HttpError::BadRequest(format!(
                "malformed header line '{l}'")));
        };
        headers.push((name.trim().to_ascii_lowercase(),
                      value.trim().to_string()));
    }
    let req = Request {
        method: method.to_string(),
        path: path.to_string(),
        headers,
        body: Vec::new(),
    };
    if req.header("transfer-encoding").is_some() {
        return Err(HttpError::BadRequest(
            "request bodies must use content-length".into()));
    }
    let len = match req.header("content-length") {
        None => 0,
        Some(v) => v.parse::<usize>().map_err(|_| HttpError::BadRequest(
            format!("bad content-length '{v}'")))?,
    };
    if len > MAX_BODY_BYTES {
        return Err(HttpError::TooLarge(format!(
            "declared body of {len} bytes exceeds {MAX_BODY_BYTES}")));
    }
    let mut body = vec![0u8; len];
    r.read_exact(&mut body)?;
    Ok(Request { body, ..req })
}

/// Canonical reason phrase for the status codes this server emits.
pub fn status_text(code: u16) -> &'static str {
    match code {
        200 => "OK",
        400 => "Bad Request",
        404 => "Not Found",
        405 => "Method Not Allowed",
        413 => "Payload Too Large",
        429 => "Too Many Requests",
        500 => "Internal Server Error",
        503 => "Service Unavailable",
        _ => "Unknown",
    }
}

/// Write a complete fixed-length response (status + headers + body).
/// Always closes the exchange (`Connection: close` — one request per
/// connection keeps the server loop stateless).
pub fn write_response<W: Write>(w: &mut W, status: u16,
                                extra_headers: &[(&str, &str)],
                                content_type: &str, body: &[u8])
                                -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, status_text(status))?;
    write!(w, "content-type: {content_type}\r\n")?;
    write!(w, "content-length: {}\r\n", body.len())?;
    write!(w, "connection: close\r\n")?;
    for (n, v) in extra_headers {
        write!(w, "{n}: {v}\r\n")?;
    }
    w.write_all(b"\r\n")?;
    w.write_all(body)?;
    w.flush()
}

/// Write the head of a chunked streaming response; follow with a
/// [`ChunkedWriter`] over the same stream.
pub fn write_chunked_head<W: Write>(w: &mut W, status: u16,
                                    content_type: &str)
                                    -> std::io::Result<()> {
    write!(w, "HTTP/1.1 {} {}\r\n", status, status_text(status))?;
    write!(w, "content-type: {content_type}\r\n")?;
    write!(w, "transfer-encoding: chunked\r\n")?;
    write!(w, "connection: close\r\n\r\n")?;
    w.flush()
}

/// Chunked transfer encoder: each [`ChunkedWriter::chunk`] flushes one
/// `size-hex CRLF data CRLF` frame (so a streamed token is on the wire
/// the moment it is sampled), [`ChunkedWriter::finish`] writes the
/// zero-length terminator.
pub struct ChunkedWriter<W: Write> {
    w: W,
    finished: bool,
}

impl<W: Write> ChunkedWriter<W> {
    pub fn new(w: W) -> ChunkedWriter<W> {
        ChunkedWriter { w, finished: false }
    }

    /// Emit one chunk. Empty payloads are skipped — an empty chunk is
    /// the stream terminator in the wire format, which only
    /// [`ChunkedWriter::finish`] may write.
    pub fn chunk(&mut self, data: &[u8]) -> std::io::Result<()> {
        debug_assert!(!self.finished, "chunk() after finish()");
        if data.is_empty() {
            return Ok(());
        }
        write!(self.w, "{:x}\r\n", data.len())?;
        self.w.write_all(data)?;
        self.w.write_all(b"\r\n")?;
        self.w.flush()
    }

    /// Terminate the stream (idempotent).
    pub fn finish(&mut self) -> std::io::Result<()> {
        if self.finished {
            return Ok(());
        }
        self.finished = true;
        self.w.write_all(b"0\r\n\r\n")?;
        self.w.flush()
    }
}

/// Decode a complete chunked-encoded body back into its payload bytes
/// — the consumer side of [`ChunkedWriter`], used by the loopback
/// client and the encoder's own round-trip tests.
pub fn decode_chunked(mut b: &[u8]) -> Result<Vec<u8>, String> {
    let mut out = Vec::new();
    loop {
        let nl = b.iter().position(|&c| c == b'\n')
            .ok_or("missing chunk-size line")?;
        let size_line = std::str::from_utf8(&b[..nl])
            .map_err(|_| "chunk size not utf-8")?
            .trim();
        // Chunk extensions (";...") are legal; we never emit them.
        let size_hex = size_line.split(';').next().unwrap_or("");
        let size = usize::from_str_radix(size_hex, 16)
            .map_err(|_| format!("bad chunk size '{size_line}'"))?;
        b = &b[nl + 1..];
        if size == 0 {
            return Ok(out);
        }
        if b.len() < size {
            return Err(format!("chunk of {size} bytes truncated"));
        }
        out.extend_from_slice(&b[..size]);
        b = &b[size..];
        // Trailing CRLF after each chunk.
        b = b.strip_prefix(b"\r\n").or_else(|| b.strip_prefix(b"\n"))
            .ok_or("missing chunk terminator")?;
    }
}

/// A parsed response on the client side of the loopback harness.
#[derive(Debug, Clone)]
pub struct ClientResponse {
    pub status: u16,
    /// Header `(name, value)` pairs, names lowercased.
    pub headers: Vec<(String, String)>,
    /// Decoded payload (chunked bodies are de-chunked).
    pub body: Vec<u8>,
}

impl ClientResponse {
    pub fn header(&self, name: &str) -> Option<&str> {
        let name = name.to_ascii_lowercase();
        self.headers.iter()
            .find(|(n, _)| *n == name)
            .map(|(_, v)| v.as_str())
    }

    pub fn body_str(&self) -> String {
        String::from_utf8_lossy(&self.body).into_owned()
    }
}

/// Parse the raw bytes of a full `Connection: close` response (as read
/// until EOF): status line, headers, body (chunked decoded when the
/// response says so).
pub fn parse_response(raw: &[u8]) -> Result<ClientResponse, String> {
    let sep = raw.windows(4).position(|w| w == b"\r\n\r\n")
        .map(|p| (p, p + 4))
        .or_else(|| raw.windows(2).position(|w| w == b"\n\n")
                     .map(|p| (p, p + 2)))
        .ok_or("no header/body separator")?;
    let head = std::str::from_utf8(&raw[..sep.0])
        .map_err(|_| "response head not utf-8")?;
    let mut lines = head.lines();
    let status_line = lines.next().ok_or("empty response")?;
    let status: u16 = status_line.split_whitespace().nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| format!("bad status line '{status_line}'"))?;
    let mut headers = Vec::new();
    for l in lines {
        if let Some((n, v)) = l.split_once(':') {
            headers.push((n.trim().to_ascii_lowercase(),
                          v.trim().to_string()));
        }
    }
    let body_raw = &raw[sep.1..];
    let chunked = headers.iter().any(|(n, v)| {
        n == "transfer-encoding" && v.to_ascii_lowercase().contains("chunked")
    });
    let body = if chunked {
        decode_chunked(body_raw)?
    } else {
        body_raw.to_vec()
    };
    Ok(ClientResponse { status, headers, body })
}

/// Minimal loopback client: one request, read to EOF, parse. The
/// integration harness and the ci.sh smoke drive the server over real
/// sockets with exactly this.
pub fn client_roundtrip(addr: &std::net::SocketAddr, method: &str,
                        path: &str, body: &[u8])
                        -> std::io::Result<ClientResponse> {
    let mut stream = std::net::TcpStream::connect(addr)?;
    stream.set_nodelay(true)?;
    send_request_head(&mut stream, method, path, body.len())?;
    stream.write_all(body)?;
    stream.flush()?;
    let mut raw = Vec::new();
    stream.read_to_end(&mut raw)?;
    parse_response(&raw).map_err(|e| std::io::Error::new(
        std::io::ErrorKind::InvalidData, e))
}

/// Write a request head (+ promise of `body_len` bytes) — split out so
/// streaming-aware test clients can read the response incrementally.
pub fn send_request_head<W: Write>(w: &mut W, method: &str, path: &str,
                                   body_len: usize) -> std::io::Result<()> {
    write!(w, "{method} {path} HTTP/1.1\r\n")?;
    write!(w, "host: loopback\r\n")?;
    write!(w, "content-type: application/json\r\n")?;
    write!(w, "content-length: {body_len}\r\n")?;
    write!(w, "connection: close\r\n\r\n")?;
    w.flush()
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::io::BufReader;

    fn parse(raw: &[u8]) -> Result<Request, HttpError> {
        read_request(&mut BufReader::new(raw))
    }

    #[test]
    fn parses_post_with_body() {
        let req = parse(
            b"POST /generate HTTP/1.1\r\nHost: x\r\nContent-Type: \
              application/json\r\nContent-Length: 9\r\n\r\n{\"a\": 1}\
              trailing-junk-ignored").unwrap();
        assert_eq!(req.method, "POST");
        assert_eq!(req.path, "/generate");
        assert_eq!(req.header("host"), Some("x"));
        assert_eq!(req.header("Content-Type"), Some("application/json"));
        assert_eq!(req.body, b"{\"a\": 1}");
    }

    #[test]
    fn parses_get_without_body() {
        let req = parse(b"GET /stats HTTP/1.1\r\n\r\n").unwrap();
        assert_eq!(req.method, "GET");
        assert_eq!(req.path, "/stats");
        assert!(req.body.is_empty());
        // Bare-LF line endings are tolerated too.
        let req = parse(b"GET /healthz HTTP/1.1\nhost: y\n\n").unwrap();
        assert_eq!(req.path, "/healthz");
        assert_eq!(req.header("host"), Some("y"));
    }

    #[test]
    fn rejects_malformed_heads() {
        assert!(matches!(parse(b"NOT-HTTP\r\n\r\n"),
                         Err(HttpError::BadRequest(_))));
        assert!(matches!(parse(b"GET /x SPDY/3\r\n\r\n"),
                         Err(HttpError::BadRequest(_))));
        assert!(matches!(parse(b"GET /x HTTP/1.1\r\nbroken header\r\n\r\n"),
                         Err(HttpError::BadRequest(_))));
        assert!(matches!(
            parse(b"POST /x HTTP/1.1\r\ncontent-length: ten\r\n\r\n"),
            Err(HttpError::BadRequest(_))));
        assert!(matches!(
            parse(b"POST /x HTTP/1.1\r\ntransfer-encoding: chunked\r\n\r\n"),
            Err(HttpError::BadRequest(_))));
    }

    #[test]
    fn rejects_oversized_head_and_body() {
        let huge = format!("POST /x HTTP/1.1\r\ncontent-length: {}\r\n\r\n",
                           MAX_BODY_BYTES + 1);
        assert!(matches!(parse(huge.as_bytes()),
                         Err(HttpError::TooLarge(_))));
        let mut head = b"GET /x HTTP/1.1\r\n".to_vec();
        while head.len() <= MAX_HEAD_BYTES {
            head.extend_from_slice(b"x-pad: aaaaaaaaaaaaaaaaaaaaaaaa\r\n");
        }
        head.extend_from_slice(b"\r\n");
        assert!(matches!(parse(&head), Err(HttpError::TooLarge(_))));
    }

    #[test]
    fn truncated_body_is_io_error() {
        assert!(matches!(
            parse(b"POST /x HTTP/1.1\r\ncontent-length: 10\r\n\r\nshort"),
            Err(HttpError::Io(_))));
    }

    #[test]
    fn chunked_roundtrip() {
        let mut wire = Vec::new();
        let mut cw = ChunkedWriter::new(&mut wire);
        cw.chunk(b"{\"token\":1}\n").unwrap();
        cw.chunk(b"").unwrap(); // skipped, not a terminator
        cw.chunk(b"{\"token\":22}\n").unwrap();
        cw.finish().unwrap();
        cw.finish().unwrap(); // idempotent
        let body = decode_chunked(&wire).unwrap();
        assert_eq!(body, b"{\"token\":1}\n{\"token\":22}\n");
        assert!(decode_chunked(b"zz\r\n").is_err());
        assert!(decode_chunked(b"5\r\nab").is_err());
    }

    #[test]
    fn response_roundtrip_fixed_and_chunked() {
        let mut raw = Vec::new();
        write_response(&mut raw, 429, &[("retry-after", "1")],
                       "application/json", b"{\"error\":\"full\"}").unwrap();
        let resp = parse_response(&raw).unwrap();
        assert_eq!(resp.status, 429);
        assert_eq!(resp.header("Retry-After"), Some("1"));
        assert_eq!(resp.body, b"{\"error\":\"full\"}");

        let mut raw = Vec::new();
        write_chunked_head(&mut raw, 200, "application/x-ndjson").unwrap();
        let mut cw = ChunkedWriter::new(&mut raw);
        cw.chunk(b"{\"index\":0,\"token\":7}\n").unwrap();
        cw.chunk(b"{\"done\":true}\n").unwrap();
        cw.finish().unwrap();
        let resp = parse_response(&raw).unwrap();
        assert_eq!(resp.status, 200);
        assert_eq!(resp.body_str(),
                   "{\"index\":0,\"token\":7}\n{\"done\":true}\n");
    }

    #[test]
    fn status_texts_cover_the_served_codes() {
        for code in [200, 400, 404, 405, 413, 429, 500, 503] {
            assert_ne!(status_text(code), "Unknown");
        }
    }
}
