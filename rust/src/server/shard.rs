//! Shard plumbing: the tenant-fair bounded admission queue in front of
//! each scheduler, the worker loop that drains it through
//! [`Scheduler::step_observed`] while streaming tokens back over
//! channels, and the prefix-hash shard picker.
//!
//! One shard = one [`ShardHandle`] (shared with connection handlers) +
//! one worker thread owning a `Box<dyn DecodeModel + Send>` and its
//! [`Scheduler`]. Handlers never touch the scheduler; they enqueue a
//! [`Pending`] under the handle's lock and read [`StreamItem`]s off
//! their channel. The worker feeds the scheduler one lane's worth at a
//! time from the fair queue — the scheduler's internal queue is plain
//! FIFO, so fairness only holds if requests wait *here*, in the
//! per-tenant queues, until a lane is actually free.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::Duration;

use crate::serve::scheduler::{StreamEvent, TenantStats};
use crate::serve::{Completion, DecodeModel, GenRequest, Scheduler,
                   ServeStats, KV_PAGE_TOKENS};
use crate::server::api::{ApiError, GenerateBody, ShardSnapshot};

/// What a shard worker sends back to the connection handler that
/// admitted a request.
#[derive(Debug)]
pub enum StreamItem {
    /// One sampled token at generated-stream position `index`. Requeue
    /// replays are already deduped (high-water mark per request), so a
    /// handler forwards these verbatim.
    Token { token: u32, index: usize },
    /// The request finished; closes the stream.
    Done(Completion),
}

/// A request parked in the admission queue: its parsed body plus the
/// channel its tokens flow back through.
pub struct Pending {
    pub body: GenerateBody,
    pub sink: mpsc::Sender<StreamItem>,
}

struct TenantQueue {
    tenant: String,
    queue: VecDeque<Pending>,
    served: usize,
    rejected: usize,
}

/// Admission state behind the [`ShardHandle`] lock.
struct Admission {
    tenants: Vec<TenantQueue>,
    /// Round-robin cursor: the tenant index [`Admission::pop_fair`]
    /// scans from next.
    cursor: usize,
    /// Total parked requests across tenants (the bounded quantity).
    depth: usize,
    cap: usize,
    queue_depth_max: usize,
    rejected_429: usize,
    rejected_413: usize,
    served: usize,
    shutdown: bool,
    /// Worker-published view for `/stats`: the scheduler's counters
    /// plus live-lane and KV-page occupancy (handlers cannot read the
    /// scheduler directly — it lives on the worker thread).
    sched_stats: ServeStats,
    live_lanes: usize,
    kv_pages: usize,
}

impl Admission {
    /// Pop the next request round-robin across tenants: scan from the
    /// cursor for the first non-empty tenant queue, advance the cursor
    /// past it. Three tenants with queues A:3 B:2 C:1 drain
    /// A,B,C,A,B,A — no tenant's backlog starves another's first
    /// request.
    fn pop_fair(&mut self) -> Option<Pending> {
        let n = self.tenants.len();
        for off in 0..n {
            let i = (self.cursor + off) % n;
            if let Some(p) = self.tenants[i].queue.pop_front() {
                self.cursor = (i + 1) % n;
                self.depth -= 1;
                return Some(p);
            }
        }
        None
    }

    fn tenant_mut(&mut self, tenant: &str) -> &mut TenantQueue {
        if let Some(i) = self.tenants.iter().position(|t| t.tenant == tenant) {
            return &mut self.tenants[i];
        }
        self.tenants.push(TenantQueue {
            tenant: tenant.to_string(),
            queue: VecDeque::new(),
            served: 0,
            rejected: 0,
        });
        self.tenants.last_mut().expect("just pushed")
    }
}

/// The handler-facing half of a shard: bounded tenant-fair admission +
/// the worker's published stats. Shared as `Arc<ShardHandle>` between
/// the accept loop's connection handlers and the shard's worker
/// thread.
pub struct ShardHandle {
    inner: Mutex<Admission>,
    /// Signalled on admission and on shutdown; the worker parks here
    /// when idle.
    work: Condvar,
}

impl ShardHandle {
    pub fn new(queue_cap: usize) -> ShardHandle {
        ShardHandle {
            inner: Mutex::new(Admission {
                tenants: Vec::new(),
                cursor: 0,
                depth: 0,
                cap: queue_cap.max(1),
                queue_depth_max: 0,
                rejected_429: 0,
                rejected_413: 0,
                served: 0,
                shutdown: false,
                sched_stats: ServeStats::default(),
                live_lanes: 0,
                kv_pages: 0,
            }),
            work: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Admission> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Park `body` in its tenant's queue, or refuse: `503` while
    /// draining, `429 Retry-After` when the shard already holds
    /// `queue_cap` parked requests (the tentpole's
    /// backpressure-as-protocol boundary — beyond this point load
    /// becomes the *client's* signal, not a silent requeue pile).
    pub fn try_admit(&self, body: GenerateBody,
                     sink: mpsc::Sender<StreamItem>)
                     -> Result<(), ApiError> {
        let mut g = self.lock();
        if g.shutdown {
            return Err(ApiError::ShuttingDown);
        }
        if g.depth >= g.cap {
            g.rejected_429 += 1;
            let tenant = body.tenant.clone();
            g.tenant_mut(&tenant).rejected += 1;
            return Err(ApiError::QueueFull { retry_after_secs: 1 });
        }
        g.depth += 1;
        g.queue_depth_max = g.queue_depth_max.max(g.depth);
        let tenant = body.tenant.clone();
        g.tenant_mut(&tenant).queue.push_back(Pending { body, sink });
        drop(g);
        self.work.notify_all();
        Ok(())
    }

    /// Record a context-too-large refusal (the `413` happens in the
    /// handler *before* admission; the counter lives here so `/stats`
    /// sees it per shard and per tenant).
    pub fn note_rejected_413(&self, tenant: &str) {
        let mut g = self.lock();
        g.rejected_413 += 1;
        g.tenant_mut(tenant).rejected += 1;
    }

    /// Begin draining: no new admissions (503), worker finishes queued
    /// + live work and exits.
    pub fn request_shutdown(&self) {
        self.lock().shutdown = true;
        self.work.notify_all();
    }

    pub fn shutdown_requested(&self) -> bool {
        self.lock().shutdown
    }

    /// Point-in-time `/stats` view. The embedded [`ServeStats`] is the
    /// worker's last published scheduler counters with the server-side
    /// fields (queue depth, 429/413, tenants) overlaid — the "complete"
    /// stats the schema-5 fields describe.
    pub fn snapshot(&self, shard: usize) -> ShardSnapshot {
        let g = self.lock();
        let tenants: Vec<TenantStats> = g.tenants.iter().map(|t| TenantStats {
            tenant: t.tenant.clone(),
            served: t.served,
            queued: t.queue.len(),
            rejected: t.rejected,
        }).collect();
        let mut sched = g.sched_stats.clone();
        sched.queue_depth_max = g.queue_depth_max;
        sched.rejected_429 = g.rejected_429;
        sched.rejected_413 = g.rejected_413;
        sched.tenants = tenants.clone();
        ShardSnapshot {
            shard,
            queue_depth: g.depth,
            queue_cap: g.cap,
            queue_depth_max: g.queue_depth_max,
            rejected_429: g.rejected_429,
            rejected_413: g.rejected_413,
            served: g.served,
            live_lanes: g.live_lanes,
            kv_pages: g.kv_pages,
            tenants,
            sched,
        }
    }

    // ---- worker side ----

    fn try_pop(&self) -> Option<Pending> {
        self.lock().pop_fair()
    }

    /// Park until admission or shutdown (bounded wait so a worker
    /// never wedges on a missed wakeup).
    fn wait_for_work(&self, timeout: Duration) {
        let g = self.lock();
        if g.depth == 0 && !g.shutdown {
            let _ = self.work.wait_timeout(g, timeout);
        }
    }

    fn note_served(&self, tenant: &str) {
        let mut g = self.lock();
        g.served += 1;
        g.tenant_mut(tenant).served += 1;
    }

    fn publish(&self, stats: &ServeStats, live_lanes: usize,
               kv_pages: usize) {
        let mut g = self.lock();
        g.sched_stats = stats.clone();
        g.live_lanes = live_lanes;
        g.kv_pages = kv_pages;
    }
}

/// Per-request worker bookkeeping: the reply channel plus the
/// streaming high-water mark (tokens with `index < emitted` were
/// already sent — a requeued lane's deterministic replay is filtered
/// against it, so clients see each position exactly once).
struct SinkEntry {
    sink: mpsc::Sender<StreamItem>,
    emitted: usize,
    tenant: String,
}

/// Configuration one shard worker runs with.
#[derive(Debug, Clone, Copy)]
pub struct ShardConfig {
    /// Scheduler lanes (max batch).
    pub lanes: usize,
    /// Kernel pool threads per shard (0 = auto).
    pub threads: usize,
    /// Prefill chunk (1 = classic one-token prefill).
    pub prefill_chunk: usize,
}

/// The shard worker loop: owns the model and its [`Scheduler`], feeds
/// it from the fair queue one free lane at a time, streams every
/// sampled token through the per-request channel the moment
/// [`StreamEvent::Token`] fires, and publishes stats after every step.
/// Returns the model's final KV-page count (after dropping prefix-cache
/// pins) — the leak check graceful shutdown asserts on.
///
/// On shutdown the loop *drains*: already-parked and live requests run
/// to completion (their streams close with a done trailer); only fresh
/// admissions are refused (503, by [`ShardHandle::try_admit`]). A
/// client that disconnects mid-stream only makes its channel sends
/// fail — the lane still decodes to completion and retires normally,
/// so its KV pages always come back.
pub fn run_shard(model: Box<dyn DecodeModel + Send>, handle: &ShardHandle,
                 cfg: ShardConfig) -> usize {
    let model: &dyn DecodeModel = &*model;
    let lanes = cfg.lanes.max(1);
    let mut sched = Scheduler::with_prefill_chunk(
        model, lanes, cfg.threads, cfg.prefill_chunk);
    let mut sinks: HashMap<usize, SinkEntry> = HashMap::new();
    let mut next_id = 0usize;
    let mut done: Vec<Completion> = Vec::new();
    loop {
        // Feed while a lane is free. Admitting more than `lanes` would
        // move waiting into the scheduler's FIFO queue, where tenant
        // fairness no longer applies.
        while sched.pending() < lanes {
            let Some(p) = handle.try_pop() else { break };
            let id = next_id;
            next_id += 1;
            sinks.insert(id, SinkEntry {
                sink: p.sink,
                emitted: 0,
                tenant: p.body.tenant.clone(),
            });
            sched.submit(GenRequest {
                id,
                prompt: p.body.prompt,
                max_new_tokens: p.body.max_new_tokens,
                sampling: p.body.sampling,
            });
        }
        if sched.pending() == 0 {
            if handle.shutdown_requested() {
                break;
            }
            handle.publish(sched.stats(), 0, model.kv_pages_in_use());
            handle.wait_for_work(Duration::from_millis(5));
            continue;
        }
        done.clear();
        sched.step_observed(&mut done, &mut |ev| {
            if let StreamEvent::Token { id, token, index } = ev {
                if let Some(e) = sinks.get_mut(&id) {
                    if index >= e.emitted {
                        // Receiver gone = client hung up; keep decoding
                        // (the lane retires normally) but stop caring.
                        let _ = e.sink.send(StreamItem::Token { token, index });
                        e.emitted = index + 1;
                    }
                }
            }
            // Requeued: nothing to do — `emitted` already holds the
            // high-water mark the replay is deduped against.
        });
        for c in done.drain(..) {
            if let Some(e) = sinks.remove(&c.id) {
                handle.note_served(&e.tenant);
                let _ = e.sink.send(StreamItem::Done(c));
            }
        }
        handle.publish(sched.stats(), sched.live_lanes(),
                       model.kv_pages_in_use());
    }
    // Drained. Drop prefix-cache pins so every page returns to the
    // pool, then report what is still held (0 unless something leaked).
    model.release_cached_pages();
    let final_pages = model.kv_pages_in_use();
    handle.publish(sched.stats(), 0, final_pages);
    final_pages
}

/// Route a prompt to a shard by FNV-1a over its first page of tokens
/// (same page-granular window the prefix cache keys on), so repeated
/// system prompts always land on the shard whose shard-local
/// [`crate::serve::model::AttnLm`] prefix cache already holds their KV
/// pages.
pub fn shard_for_prompt(prompt: &[u32], shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in prompt.iter().take(KV_PAGE_TOKENS) {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    (h % shards as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::{LatentLm, LmDims};
    use crate::serve::Sampling;

    fn body(tenant: &str, prompt: Vec<u32>, max_new: usize) -> GenerateBody {
        GenerateBody {
            prompt,
            max_new_tokens: max_new,
            tenant: tenant.to_string(),
            sampling: Sampling::Greedy,
        }
    }

    #[test]
    fn pop_fair_round_robins_tenants() {
        let h = ShardHandle::new(16);
        for (tenant, tag) in [("a", 0u32), ("a", 1), ("a", 2),
                              ("b", 3), ("b", 4), ("c", 5)] {
            let (tx, _rx) = mpsc::channel();
            // _rx dropped: sends fail silently, irrelevant here.
            h.try_admit(body(tenant, vec![tag], 1), tx).unwrap();
        }
        let order: Vec<String> = std::iter::from_fn(|| h.try_pop())
            .map(|p| p.body.tenant)
            .collect();
        assert_eq!(order, ["a", "b", "c", "a", "b", "a"],
                   "a backlogged tenant must not starve the others");
        assert_eq!(h.snapshot(0).queue_depth, 0);
    }

    #[test]
    fn full_queue_is_429_with_counters() {
        let h = ShardHandle::new(2);
        for i in 0..2 {
            let (tx, _rx) = mpsc::channel();
            h.try_admit(body("t", vec![i], 1), tx).unwrap();
        }
        let (tx, _rx) = mpsc::channel();
        let e = h.try_admit(body("t", vec![9], 1), tx).unwrap_err();
        assert_eq!(e, ApiError::QueueFull { retry_after_secs: 1 });
        h.note_rejected_413("t");
        let s = h.snapshot(3);
        assert_eq!(s.shard, 3);
        assert_eq!(s.queue_depth, 2);
        assert_eq!(s.queue_cap, 2);
        assert_eq!(s.queue_depth_max, 2);
        assert_eq!(s.rejected_429, 1);
        assert_eq!(s.rejected_413, 1);
        assert_eq!(s.tenants.len(), 1);
        assert_eq!(s.tenants[0].rejected, 2);
        assert_eq!(s.tenants[0].queued, 2);
        // The overlaid ServeStats carries the same server-side fields.
        assert_eq!(s.sched.rejected_429, 1);
        assert_eq!(s.sched.rejected_413, 1);
        assert_eq!(s.sched.queue_depth_max, 2);
        assert_eq!(s.sched.tenants, s.tenants);
    }

    #[test]
    fn shutdown_refuses_with_503() {
        let h = ShardHandle::new(4);
        h.request_shutdown();
        let (tx, _rx) = mpsc::channel();
        assert_eq!(h.try_admit(body("t", vec![1], 1), tx).unwrap_err(),
                   ApiError::ShuttingDown);
        assert!(h.shutdown_requested());
    }

    #[test]
    fn shard_picker_is_deterministic_prefix_keyed_and_in_range() {
        let long_a: Vec<u32> = (0..40).collect();
        // Same first KV_PAGE_TOKENS tokens, different tail: same shard
        // (that is the point — the prefix cache is page-granular).
        let mut long_b = long_a.clone();
        long_b[KV_PAGE_TOKENS + 2] = 999;
        for shards in [1, 2, 3, 8] {
            let s = shard_for_prompt(&long_a, shards);
            assert!(s < shards);
            assert_eq!(s, shard_for_prompt(&long_a, shards));
            assert_eq!(s, shard_for_prompt(&long_b, shards),
                       "routing must key on the first page only");
        }
        // Distinct prefixes spread: not all of 32 prompts on one shard.
        let hits: std::collections::BTreeSet<usize> = (0..32u32)
            .map(|i| shard_for_prompt(&[i, i + 1, i + 2], 4))
            .collect();
        assert!(hits.len() > 1, "picker must actually spread traffic");
    }

    #[test]
    fn worker_streams_match_direct_scheduler_bitwise() {
        let dims = LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 };
        let latent = LatentLm::synthetic(dims, 1, 21);
        let reqs: Vec<Vec<u32>> =
            (0..5u32).map(|i| vec![i, i + 7, i + 11]).collect();

        // Reference: the same prompts through a Scheduler directly.
        let direct = latent.build_float();
        let mut sched = Scheduler::new(&direct, 2, 1);
        for (id, p) in reqs.iter().enumerate() {
            sched.submit(GenRequest::greedy(id, p.clone(), 4));
        }
        let mut expect: HashMap<Vec<u32>, Vec<u32>> = HashMap::new();
        for c in sched.run() {
            expect.insert(reqs[c.id].clone(), c.tokens);
        }

        // Server path: worker thread + fair queue + channels.
        let h = std::sync::Arc::new(ShardHandle::new(16));
        let model: Box<dyn DecodeModel + Send> =
            Box::new(latent.build_float());
        let worker = {
            let h = h.clone();
            std::thread::spawn(move || {
                run_shard(model, &h,
                          ShardConfig { lanes: 2, threads: 1,
                                        prefill_chunk: 1 })
            })
        };
        let mut rxs = Vec::new();
        for (i, p) in reqs.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            let tenant = if i % 2 == 0 { "even" } else { "odd" };
            h.try_admit(body(tenant, p.clone(), 4), tx).unwrap();
            rxs.push((p.clone(), rx));
        }
        for (prompt, rx) in rxs {
            let mut streamed = Vec::new();
            loop {
                match rx.recv_timeout(Duration::from_secs(30)).unwrap() {
                    StreamItem::Token { token, index } => {
                        assert_eq!(index, streamed.len(),
                                   "tokens must stream in order, deduped");
                        streamed.push(token);
                    }
                    StreamItem::Done(c) => {
                        assert_eq!(c.tokens, streamed,
                                   "stream and completion must agree");
                        break;
                    }
                }
            }
            assert_eq!(streamed, expect[&prompt],
                       "server stream must be bitwise-equal to direct \
                        scheduler output");
        }
        h.request_shutdown();
        let leaked = worker.join().unwrap();
        assert_eq!(leaked, 0, "decay model holds no KV pages");
        let s = h.snapshot(0);
        assert_eq!(s.served, 5);
        assert_eq!(s.queue_depth, 0);
        let by_name = |n: &str| s.tenants.iter()
            .find(|t| t.tenant == n).unwrap().served;
        assert_eq!(by_name("even"), 3);
        assert_eq!(by_name("odd"), 2);
    }
}
