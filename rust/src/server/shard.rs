//! Shard plumbing: the tenant-fair bounded admission queue in front of
//! each scheduler, the worker loop that drains it through
//! [`Scheduler::step_observed`] while streaming tokens back over
//! channels, and the prefix-hash shard picker.
//!
//! One shard = one [`ShardHandle`] (shared with connection handlers) +
//! one worker thread owning a `Box<dyn DecodeModel + Send>` and its
//! [`Scheduler`]. Handlers never touch the scheduler; they enqueue a
//! [`Pending`] under the handle's lock and read [`StreamItem`]s off
//! their channel. The worker feeds the scheduler one lane's worth at a
//! time from the fair queue — the scheduler's internal queue is plain
//! FIFO, so fairness only holds if requests wait *here*, in the
//! per-tenant queues, until a lane is actually free.
//!
//! Request lifecycle robustness rides the same plumbing:
//!
//! - **Cancellation**: [`ShardHandle::try_admit`] returns a *ticket*
//!   (also the scheduler request id); [`ShardHandle::cancel`] aborts
//!   the ticket whether it is still parked (removed from its tenant
//!   queue) or live (queued to the worker, which calls
//!   [`Scheduler::cancel`] before its next step — KV pages come back
//!   within one step of the disconnect). The worker also
//!   *self*-cancels a lane the moment a token send fails: a dropped
//!   receiver is a hung-up client, and decoding for nobody burns the
//!   exact compute and cache the paper's bit savings pay for.
//! - **Deadlines**: a parked request past the shard's queue-admission
//!   deadline leaves the queue with a [`StreamItem::Error`] line;
//!   a live request past the decode wall-clock cap is truncated via
//!   [`Scheduler::expire`] and closes with an explicit
//!   `finish_reason`.
//! - **Crash isolation**: [`run_shard_supervised`] wraps the worker
//!   loop in `catch_unwind`; a panic drops the scheduler (lanes retire
//!   and pages free on unwind) and the in-flight sinks (relays see a
//!   disconnect promptly instead of hanging to the relay timeout),
//!   then the model+scheduler stack is rebuilt and parked requests —
//!   which live *here*, in the handle — are served by the next
//!   incarnation. Stats accumulate across restarts
//!   ([`ServeStats::absorb`] into a base the snapshot overlays), so
//!   `/stats` never goes backwards.
//! - **Fault injection**: a [`FaultPlan`] in [`ShardConfig`] scripts
//!   forced KV refusals (scheduler), worker panics, and mid-stream
//!   client disconnects at deterministic coordinates.
//! - **Speculative decoding**: [`ShardConfig::spec`] plus a draft
//!   model box ([`run_shard_spec`] / [`run_shard_supervised_spec`])
//!   install draft-verify decoding on the shard's scheduler
//!   ([`Scheduler::set_speculative`]). Streams stay bitwise identical
//!   to the plain worker; `/stats` gains the schema-7 counters; KV
//!   occupancy published to the handle sums target *and* draft pages,
//!   so the leak check covers both caches.

use std::collections::HashMap;
use std::collections::VecDeque;
use std::panic::{catch_unwind, AssertUnwindSafe};
use std::sync::mpsc;
use std::sync::{Condvar, Mutex};
use std::time::{Duration, Instant};

use crate::serve::scheduler::{StreamEvent, TenantStats};
use crate::serve::{Completion, DecodeModel, FaultPlan, GenRequest,
                   Scheduler, ServeStats, SpecConfig, KV_PAGE_TOKENS};
use crate::server::api::{ApiError, GenerateBody, ShardSnapshot};

/// Consecutive worker panics after which the supervisor stops
/// rebuilding a shard (fails its parked requests and refuses new ones
/// instead of burning CPU in a panic loop). Injected fault-plan panics
/// never get near this: they are consumed by the first incarnation.
pub const MAX_WORKER_RESTARTS: usize = 8;

/// What a shard worker sends back to the connection handler that
/// admitted a request.
#[derive(Debug)]
pub enum StreamItem {
    /// One sampled token at generated-stream position `index`. Requeue
    /// replays are already deduped (high-water mark per request), so a
    /// handler forwards these verbatim.
    Token { token: u32, index: usize },
    /// The request finished; closes the stream. The completion's
    /// `finish_reason` says how (budget-complete, deadline-truncated,
    /// kv-overflow).
    Done(Completion),
    /// The request failed or expired before producing a completion;
    /// the relay writes one error line and closes the stream.
    Error { kind: &'static str, detail: String },
}

/// A request parked in the admission queue: its parsed body plus the
/// channel its tokens flow back through, its admission ticket, and
/// its queue-admission deadline (if the shard has one).
pub struct Pending {
    pub body: GenerateBody,
    pub sink: mpsc::Sender<StreamItem>,
    /// Admission ticket — also the scheduler request id once the
    /// worker feeds it, so [`ShardHandle::cancel`] addresses parked
    /// and live requests with one number.
    pub ticket: usize,
    /// Expire out of the queue at this instant if still parked.
    pub deadline: Option<Instant>,
}

struct TenantQueue {
    tenant: String,
    queue: VecDeque<Pending>,
    served: usize,
    rejected: usize,
}

/// Admission state behind the [`ShardHandle`] lock.
struct Admission {
    tenants: Vec<TenantQueue>,
    /// Round-robin cursor: the tenant index [`Admission::pop_fair`]
    /// scans from next.
    cursor: usize,
    /// Total parked requests across tenants (the bounded quantity).
    depth: usize,
    cap: usize,
    queue_depth_max: usize,
    rejected_429: usize,
    rejected_413: usize,
    served: usize,
    shutdown: bool,
    /// Worker-published view for `/stats`: the scheduler's counters
    /// plus live-lane and KV-page occupancy (handlers cannot read the
    /// scheduler directly — it lives on the worker thread).
    sched_stats: ServeStats,
    live_lanes: usize,
    kv_pages: usize,
    /// Next admission ticket. Handle-global (survives worker restarts)
    /// so a ticket uniquely names a request for the shard's lifetime.
    next_ticket: usize,
    /// Tickets the relay side cancelled that were not parked (i.e.
    /// already fed to the scheduler); the worker drains these before
    /// each step and aborts the matching lanes.
    cancels: Vec<usize>,
    /// Requests cancelled while still parked (live-lane cancels are
    /// counted by the scheduler itself).
    cancelled_parked: usize,
    /// Requests expired out of the admission queue (live-lane expiries
    /// are counted by the scheduler itself).
    deadline_expired_parked: usize,
    /// Stamp on every admission: park no longer than this before
    /// expiring with an error line. Installed by the worker from its
    /// [`ShardConfig`].
    queue_deadline: Option<Duration>,
    /// Scheduler counters accumulated from worker incarnations that
    /// have since panicked; [`ShardHandle::snapshot`] overlays the
    /// current incarnation's published stats on top, so `/stats`
    /// counters never reset across a crash-restart.
    sched_base: ServeStats,
    worker_restarts: usize,
}

impl Admission {
    /// Pop the next request round-robin across tenants: scan from the
    /// cursor for the first non-empty tenant queue, advance the cursor
    /// past it. Three tenants with queues A:3 B:2 C:1 drain
    /// A,B,C,A,B,A — no tenant's backlog starves another's first
    /// request.
    fn pop_fair(&mut self) -> Option<Pending> {
        let n = self.tenants.len();
        for off in 0..n {
            let i = (self.cursor + off) % n;
            if let Some(p) = self.tenants[i].queue.pop_front() {
                self.cursor = (i + 1) % n;
                self.depth -= 1;
                return Some(p);
            }
        }
        None
    }

    fn tenant_mut(&mut self, tenant: &str) -> &mut TenantQueue {
        if let Some(i) = self.tenants.iter().position(|t| t.tenant == tenant) {
            return &mut self.tenants[i];
        }
        self.tenants.push(TenantQueue {
            tenant: tenant.to_string(),
            queue: VecDeque::new(),
            served: 0,
            rejected: 0,
        });
        self.tenants.last_mut().expect("just pushed")
    }
}

/// The handler-facing half of a shard: bounded tenant-fair admission +
/// the worker's published stats. Shared as `Arc<ShardHandle>` between
/// the accept loop's connection handlers and the shard's worker
/// thread.
pub struct ShardHandle {
    inner: Mutex<Admission>,
    /// Signalled on admission and on shutdown; the worker parks here
    /// when idle.
    work: Condvar,
}

impl ShardHandle {
    pub fn new(queue_cap: usize) -> ShardHandle {
        ShardHandle {
            inner: Mutex::new(Admission {
                tenants: Vec::new(),
                cursor: 0,
                depth: 0,
                cap: queue_cap.max(1),
                queue_depth_max: 0,
                rejected_429: 0,
                rejected_413: 0,
                served: 0,
                shutdown: false,
                sched_stats: ServeStats::default(),
                live_lanes: 0,
                kv_pages: 0,
                next_ticket: 0,
                cancels: Vec::new(),
                cancelled_parked: 0,
                deadline_expired_parked: 0,
                queue_deadline: None,
                sched_base: ServeStats::default(),
                worker_restarts: 0,
            }),
            work: Condvar::new(),
        }
    }

    fn lock(&self) -> std::sync::MutexGuard<'_, Admission> {
        self.inner.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// Park `body` in its tenant's queue, or refuse: `503` while
    /// draining, `429 Retry-After` when the shard already holds
    /// `queue_cap` parked requests (the tentpole's
    /// backpressure-as-protocol boundary — beyond this point load
    /// becomes the *client's* signal, not a silent requeue pile).
    ///
    /// Returns the admission *ticket*: the id the worker submits to
    /// the scheduler, and the number [`ShardHandle::cancel`] takes to
    /// abort the request if the client hangs up.
    pub fn try_admit(&self, body: GenerateBody,
                     sink: mpsc::Sender<StreamItem>)
                     -> Result<usize, ApiError> {
        let mut g = self.lock();
        if g.shutdown {
            return Err(ApiError::ShuttingDown);
        }
        if g.depth >= g.cap {
            g.rejected_429 += 1;
            let tenant = body.tenant.clone();
            g.tenant_mut(&tenant).rejected += 1;
            return Err(ApiError::QueueFull { retry_after_secs: 1 });
        }
        g.depth += 1;
        g.queue_depth_max = g.queue_depth_max.max(g.depth);
        let ticket = g.next_ticket;
        g.next_ticket += 1;
        let deadline = g.queue_deadline.map(|d| Instant::now() + d);
        let tenant = body.tenant.clone();
        g.tenant_mut(&tenant).queue
            .push_back(Pending { body, sink, ticket, deadline });
        drop(g);
        self.work.notify_all();
        Ok(ticket)
    }

    /// Abort `ticket` wherever it is. Parked: removed from its tenant
    /// queue here, immediately. Live (or already finished): queued for
    /// the worker, which calls [`Scheduler::cancel`] before its next
    /// step — the lane's KV pages are released within one step, and a
    /// stale ticket (request already completed) is a no-op there.
    pub fn cancel(&self, ticket: usize) {
        let mut g = self.lock();
        let mut parked = None;
        'scan: for (ti, t) in g.tenants.iter().enumerate() {
            if let Some(qi) = t.queue.iter()
                .position(|p| p.ticket == ticket) {
                parked = Some((ti, qi));
                break 'scan;
            }
        }
        match parked {
            Some((ti, qi)) => {
                g.tenants[ti].queue.remove(qi);
                g.depth -= 1;
                g.cancelled_parked += 1;
            }
            None => {
                g.cancels.push(ticket);
                drop(g);
                // Wake an idle worker so a stale ticket doesn't linger.
                self.work.notify_all();
            }
        }
    }

    /// Sweep parked requests past their queue-admission deadline: each
    /// leaves its tenant queue and gets one `deadline_expired` error
    /// line down its sink. Returns how many expired. Called by the
    /// worker every loop; free when no deadline is configured.
    fn expire_parked(&self) -> usize {
        let mut g = self.lock();
        if g.queue_deadline.is_none() {
            return 0;
        }
        let now = Instant::now();
        let mut expired = 0;
        for ti in 0..g.tenants.len() {
            let mut qi = 0;
            while qi < g.tenants[ti].queue.len() {
                let due = g.tenants[ti].queue[qi].deadline
                    .is_some_and(|d| d <= now);
                if !due {
                    qi += 1;
                    continue;
                }
                let p = g.tenants[ti].queue.remove(qi)
                    .expect("index checked against queue length");
                g.depth -= 1;
                g.deadline_expired_parked += 1;
                expired += 1;
                let _ = p.sink.send(StreamItem::Error {
                    kind: "deadline_expired",
                    detail: "expired in the admission queue before a \
                             lane was free".to_string(),
                });
            }
        }
        expired
    }

    /// Record a worker panic: fold the dead incarnation's published
    /// scheduler counters into the across-restart base (so the next
    /// incarnation's fresh counters overlay correctly) and zero the
    /// live occupancy — the panicked worker's model, lanes, and KV
    /// pool are gone.
    fn note_worker_panic(&self) {
        let mut g = self.lock();
        let current = std::mem::take(&mut g.sched_stats);
        g.sched_base.absorb(&current);
        g.worker_restarts += 1;
        g.live_lanes = 0;
        g.kv_pages = 0;
    }

    /// Fail every parked request with an error line (the supervisor's
    /// last resort when a shard exceeds [`MAX_WORKER_RESTARTS`]).
    fn fail_parked(&self, kind: &'static str, detail: &str) {
        let mut g = self.lock();
        for ti in 0..g.tenants.len() {
            while let Some(p) = g.tenants[ti].queue.pop_front() {
                g.depth -= 1;
                let _ = p.sink.send(StreamItem::Error {
                    kind,
                    detail: detail.to_string(),
                });
            }
        }
    }

    /// Install the queue-admission deadline future admissions are
    /// stamped with. The worker calls this from its [`ShardConfig`] at
    /// startup; requests admitted in the instant before it runs simply
    /// park without a deadline.
    fn set_queue_deadline(&self, deadline: Option<Duration>) {
        self.lock().queue_deadline = deadline;
    }

    /// Record a context-too-large refusal (the `413` happens in the
    /// handler *before* admission; the counter lives here so `/stats`
    /// sees it per shard and per tenant).
    pub fn note_rejected_413(&self, tenant: &str) {
        let mut g = self.lock();
        g.rejected_413 += 1;
        g.tenant_mut(tenant).rejected += 1;
    }

    /// Begin draining: no new admissions (503), worker finishes queued
    /// + live work and exits.
    pub fn request_shutdown(&self) {
        self.lock().shutdown = true;
        self.work.notify_all();
    }

    pub fn shutdown_requested(&self) -> bool {
        self.lock().shutdown
    }

    /// Point-in-time `/stats` view. The embedded [`ServeStats`] is the
    /// across-restart base with the current worker incarnation's
    /// published counters absorbed on top, then the server-side fields
    /// (queue depth, 429/413, parked cancels/expiries, restarts,
    /// tenants) overlaid — the "complete" stats the schema fields
    /// describe.
    pub fn snapshot(&self, shard: usize) -> ShardSnapshot {
        let g = self.lock();
        let tenants: Vec<TenantStats> = g.tenants.iter().map(|t| TenantStats {
            tenant: t.tenant.clone(),
            served: t.served,
            queued: t.queue.len(),
            rejected: t.rejected,
        }).collect();
        let mut sched = g.sched_base.clone();
        sched.absorb(&g.sched_stats);
        sched.queue_depth_max = g.queue_depth_max;
        sched.rejected_429 = g.rejected_429;
        sched.rejected_413 = g.rejected_413;
        sched.cancelled += g.cancelled_parked;
        sched.deadline_expired += g.deadline_expired_parked;
        sched.worker_restarts = g.worker_restarts;
        sched.tenants = tenants.clone();
        ShardSnapshot {
            shard,
            queue_depth: g.depth,
            queue_cap: g.cap,
            queue_depth_max: g.queue_depth_max,
            rejected_429: g.rejected_429,
            rejected_413: g.rejected_413,
            served: g.served,
            live_lanes: g.live_lanes,
            kv_pages: g.kv_pages,
            cancelled: sched.cancelled,
            deadline_expired: sched.deadline_expired,
            worker_restarts: g.worker_restarts,
            tenants,
            sched,
        }
    }

    // ---- worker side ----

    fn try_pop(&self) -> Option<Pending> {
        self.lock().pop_fair()
    }

    /// Drain the relay-side cancel queue (tickets that were live when
    /// [`ShardHandle::cancel`] ran). Cheap when empty: taking an empty
    /// `Vec` does not allocate.
    fn take_cancels(&self) -> Vec<usize> {
        std::mem::take(&mut self.lock().cancels)
    }

    /// Park until admission or shutdown (bounded wait so a worker
    /// never wedges on a missed wakeup).
    fn wait_for_work(&self, timeout: Duration) {
        let g = self.lock();
        if g.depth == 0 && !g.shutdown {
            let _ = self.work.wait_timeout(g, timeout);
        }
    }

    fn note_served(&self, tenant: &str) {
        let mut g = self.lock();
        g.served += 1;
        g.tenant_mut(tenant).served += 1;
    }

    fn publish(&self, stats: &ServeStats, live_lanes: usize,
               kv_pages: usize) {
        let mut g = self.lock();
        g.sched_stats = stats.clone();
        g.live_lanes = live_lanes;
        g.kv_pages = kv_pages;
    }
}

/// Per-request worker bookkeeping: the reply channel plus the
/// streaming high-water mark (tokens with `index < emitted` were
/// already sent — a requeued lane's deterministic replay is filtered
/// against it, so clients see each position exactly once).
struct SinkEntry {
    sink: mpsc::Sender<StreamItem>,
    emitted: usize,
    tenant: String,
    /// Decode wall-clock cap: truncate the stream via
    /// [`Scheduler::expire`] once past this instant (stamped when the
    /// worker feeds the request, `None` when the shard has no cap).
    deadline: Option<Instant>,
    /// Scripted client disconnect (fault plan): cancel the lane once
    /// this generated-token index has been delivered.
    disconnect_at: Option<usize>,
}

/// Configuration one shard worker runs with.
#[derive(Debug, Clone)]
pub struct ShardConfig {
    /// Scheduler lanes (max batch).
    pub lanes: usize,
    /// Kernel pool threads per shard (0 = auto).
    pub threads: usize,
    /// Prefill chunk (1 = classic one-token prefill).
    pub prefill_chunk: usize,
    /// Max time a request may wait parked in the admission queue
    /// before expiring with an error line (`None` = wait forever).
    pub queue_deadline: Option<Duration>,
    /// Max decode wall-clock per request: past it the stream is
    /// truncated with `finish_reason = "deadline_expired"` (`None` =
    /// decode to budget).
    pub decode_deadline: Option<Duration>,
    /// Deterministic fault injection (empty = no faults).
    pub faults: FaultPlan,
    /// Draft-verify speculative decoding: when set (and a draft model
    /// box is passed to [`run_shard_spec`]), the worker installs it on
    /// its scheduler via [`Scheduler::set_speculative`]. `None` = plain
    /// decode.
    pub spec: Option<SpecConfig>,
}

impl Default for ShardConfig {
    fn default() -> ShardConfig {
        ShardConfig {
            lanes: 1,
            threads: 1,
            prefill_chunk: 1,
            queue_deadline: None,
            decode_deadline: None,
            faults: FaultPlan::default(),
            spec: None,
        }
    }
}

/// The shard worker loop: owns the model and its [`Scheduler`], feeds
/// it from the fair queue one free lane at a time, streams every
/// sampled token through the per-request channel the moment
/// [`StreamEvent::Token`] fires, and publishes stats after every step.
/// Returns the model's final KV-page count (after dropping prefix-cache
/// pins) — the leak check graceful shutdown asserts on.
///
/// On shutdown the loop *drains*: already-parked and live requests run
/// to completion (their streams close with a done trailer); only fresh
/// admissions are refused (503, by [`ShardHandle::try_admit`]).
///
/// A client that disconnects mid-stream makes its channel sends fail;
/// the worker cancels that lane right after the step that observed the
/// failure, so its KV pages are back in the pool within one scheduler
/// step. Relay-side cancels ([`ShardHandle::cancel`]) and scripted
/// fault-plan disconnects take the same path. Decode deadlines are
/// checked after every step; an expired lane is truncated through
/// [`Scheduler::expire`] and its stream closes with an explicit
/// `finish_reason` rather than an ambiguous timeout.
pub fn run_shard(model: Box<dyn DecodeModel + Send>, handle: &ShardHandle,
                 cfg: &ShardConfig) -> usize {
    run_shard_spec(model, None, handle, cfg)
}

/// [`run_shard`] with an optional speculative draft model: when both
/// `draft` and [`ShardConfig::spec`] are present, the worker's
/// scheduler runs draft-verify decoding
/// ([`Scheduler::set_speculative`]) — bitwise identical streams, fewer
/// target steps. The published KV-page occupancy (and the returned
/// final leak count) sums target and draft caches, and the drain path
/// releases the draft's cached pages too.
pub fn run_shard_spec(model: Box<dyn DecodeModel + Send>,
                      draft: Option<Box<dyn DecodeModel + Send>>,
                      handle: &ShardHandle, cfg: &ShardConfig) -> usize {
    let model: &dyn DecodeModel = &*model;
    let draft: Option<&dyn DecodeModel> =
        draft.as_deref().map(|d| d as &dyn DecodeModel);
    let pages_in_use = || {
        model.kv_pages_in_use()
            + draft.map_or(0, |d| d.kv_pages_in_use())
    };
    let lanes = cfg.lanes.max(1);
    let mut sched = Scheduler::with_prefill_chunk(
        model, lanes, cfg.threads, cfg.prefill_chunk);
    sched.set_fault_plan(cfg.faults.clone());
    debug_assert_eq!(cfg.spec.is_some(), draft.is_some(),
                     "a speculative config needs a draft model box and \
                      vice versa");
    if let (Some(spec), Some(d)) = (cfg.spec, draft) {
        sched.set_speculative(d, spec);
    }
    handle.set_queue_deadline(cfg.queue_deadline);
    let mut sinks: HashMap<usize, SinkEntry> = HashMap::new();
    let mut done: Vec<Completion> = Vec::new();
    let mut to_cancel: Vec<usize> = Vec::new();
    let mut worker_steps = 0usize;
    loop {
        // Relay-driven cancels first: a hung-up client's lane must not
        // hold pages into the next step. A stale ticket (request
        // already finished) makes `Scheduler::cancel` a no-op.
        for ticket in handle.take_cancels() {
            if sched.cancel(ticket) {
                sinks.remove(&ticket);
            }
        }
        // Parked requests past their admission deadline leave with an
        // error line instead of eventually wasting a lane.
        handle.expire_parked();
        // Feed while a lane is free. Admitting more than `lanes` would
        // move waiting into the scheduler's FIFO queue, where tenant
        // fairness no longer applies.
        while sched.pending() < lanes {
            let Some(p) = handle.try_pop() else { break };
            sinks.insert(p.ticket, SinkEntry {
                sink: p.sink,
                emitted: 0,
                tenant: p.body.tenant.clone(),
                deadline: cfg.decode_deadline.map(|d| Instant::now() + d),
                disconnect_at: cfg.faults.disconnect_index(p.ticket),
            });
            sched.submit(GenRequest {
                id: p.ticket,
                prompt: p.body.prompt,
                max_new_tokens: p.body.max_new_tokens,
                sampling: p.body.sampling,
            });
        }
        if sched.pending() == 0 {
            if handle.shutdown_requested() {
                break;
            }
            handle.publish(sched.stats(), 0, pages_in_use());
            handle.wait_for_work(Duration::from_millis(5));
            continue;
        }
        done.clear();
        sched.step_observed(&mut done, &mut |ev| {
            if let StreamEvent::Token { id, token, index } = ev {
                if let Some(e) = sinks.get_mut(&id) {
                    if index >= e.emitted {
                        let sent = e.sink
                            .send(StreamItem::Token { token, index });
                        e.emitted = index + 1;
                        // Receiver gone = client hung up. Decoding for
                        // nobody burns the exact compute and KV pages
                        // the bit savings pay for, so mark the lane
                        // for cancellation; it is aborted right after
                        // this step. Scripted fault-plan disconnects
                        // cut at a deterministic token index the same
                        // way.
                        let scripted = e.disconnect_at
                            .is_some_and(|cut| index >= cut);
                        if sent.is_err() || scripted {
                            to_cancel.push(id);
                        }
                    }
                }
            }
            // Requeued: nothing to do — `emitted` already holds the
            // high-water mark the replay is deduped against.
        });
        worker_steps += 1;
        for id in to_cancel.drain(..) {
            // False = the lane finished on this very step; the done
            // drain below owns it.
            if sched.cancel(id) {
                sinks.remove(&id);
            }
        }
        for c in done.drain(..) {
            if let Some(e) = sinks.remove(&c.id) {
                handle.note_served(&e.tenant);
                let _ = e.sink.send(StreamItem::Done(c));
            }
        }
        if cfg.decode_deadline.is_some() {
            let now = Instant::now();
            to_cancel.extend(sinks.iter()
                .filter(|(_, e)| e.deadline.is_some_and(|d| d <= now))
                .map(|(&id, _)| id));
            for id in to_cancel.drain(..) {
                let Some(c) = sched.expire(id) else { continue };
                if let Some(e) = sinks.remove(&id) {
                    handle.note_served(&e.tenant);
                    let _ = e.sink.send(StreamItem::Done(c));
                }
            }
        }
        handle.publish(sched.stats(), sched.live_lanes(),
                       pages_in_use());
        if cfg.faults.panics_after(worker_steps) {
            panic!("injected shard-worker panic (fault plan, after step \
                    {worker_steps})");
        }
    }
    // Drained. Drop prefix-cache pins so every page returns to the
    // pool, then report what is still held (0 unless something leaked)
    // — counting the draft model's cache too, so a speculative shard's
    // leak check covers both KV pools.
    model.release_cached_pages();
    if let Some(d) = draft {
        d.release_cached_pages();
    }
    let final_pages = pages_in_use();
    handle.publish(sched.stats(), 0, final_pages);
    final_pages
}

/// Crash-isolated shard worker: run [`run_shard`] under
/// `catch_unwind`, and on a panic rebuild the model+scheduler stack
/// and keep serving. Unwinding drops the dead incarnation's scheduler
/// (lanes retire, its KV pool frees with the model) and its in-flight
/// sinks (relays observe a disconnect promptly instead of hanging to
/// the relay timeout); parked requests live in the handle and are
/// served by the next incarnation. Fault-plan faults are consumed by
/// the first incarnation only — an injected panic cannot re-fire after
/// the restart it was scripted to cause.
///
/// After [`MAX_WORKER_RESTARTS`] panics the supervisor gives up:
/// parked requests fail with `worker_failed` error lines, the shard
/// stops admitting (shutdown), and `usize::MAX` is returned so the
/// caller's leak check reports the shard as failed rather than clean.
pub fn run_shard_supervised<F>(build: F, handle: &ShardHandle,
                               cfg: &ShardConfig) -> usize
where
    F: Fn() -> Box<dyn DecodeModel + Send>,
{
    run_shard_supervised_spec(|| (build(), None), handle, cfg)
}

/// [`run_shard_supervised`] for speculative shards: the builder
/// returns the target model *and* its optional draft, so every
/// post-panic incarnation rebuilds both (a crash drops both KV pools
/// with the dead scheduler; the rebuilt pair starts clean).
pub fn run_shard_supervised_spec<F>(build: F, handle: &ShardHandle,
                                    cfg: &ShardConfig) -> usize
where
    F: Fn() -> (Box<dyn DecodeModel + Send>,
                Option<Box<dyn DecodeModel + Send>>),
{
    let mut cfg = cfg.clone();
    loop {
        let (model, draft) = build();
        // The handle's Mutex ignores poisoning (`lock()` above) and
        // every update under it is single-field-coherent, so resuming
        // after an unwind observed mid-update state is safe.
        let result = catch_unwind(AssertUnwindSafe(|| {
            run_shard_spec(model, draft, handle, &cfg)
        }));
        match result {
            Ok(final_pages) => return final_pages,
            Err(_) => {
                handle.note_worker_panic();
                // One incarnation, one shot at each scripted fault.
                cfg.faults = FaultPlan::default();
                if handle.lock().worker_restarts >= MAX_WORKER_RESTARTS {
                    handle.request_shutdown();
                    handle.fail_parked(
                        "worker_failed",
                        "shard worker exceeded its restart budget");
                    return usize::MAX;
                }
            }
        }
    }
}

/// Route a prompt to a shard by FNV-1a over its first page of tokens
/// (same page-granular window the prefix cache keys on), so repeated
/// system prompts always land on the shard whose shard-local
/// [`crate::serve::model::AttnLm`] prefix cache already holds their KV
/// pages.
pub fn shard_for_prompt(prompt: &[u32], shards: usize) -> usize {
    if shards <= 1 {
        return 0;
    }
    let mut h: u64 = 0xcbf29ce484222325;
    for &t in prompt.iter().take(KV_PAGE_TOKENS) {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    (h % shards as u64) as usize
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::{LatentLm, LmDims};
    use crate::serve::Sampling;

    fn body(tenant: &str, prompt: Vec<u32>, max_new: usize) -> GenerateBody {
        GenerateBody {
            prompt,
            max_new_tokens: max_new,
            tenant: tenant.to_string(),
            sampling: Sampling::Greedy,
        }
    }

    #[test]
    fn pop_fair_round_robins_tenants() {
        let h = ShardHandle::new(16);
        for (tenant, tag) in [("a", 0u32), ("a", 1), ("a", 2),
                              ("b", 3), ("b", 4), ("c", 5)] {
            let (tx, _rx) = mpsc::channel();
            // _rx dropped: sends fail silently, irrelevant here.
            h.try_admit(body(tenant, vec![tag], 1), tx).unwrap();
        }
        let order: Vec<String> = std::iter::from_fn(|| h.try_pop())
            .map(|p| p.body.tenant)
            .collect();
        assert_eq!(order, ["a", "b", "c", "a", "b", "a"],
                   "a backlogged tenant must not starve the others");
        assert_eq!(h.snapshot(0).queue_depth, 0);
    }

    #[test]
    fn full_queue_is_429_with_counters() {
        let h = ShardHandle::new(2);
        for i in 0..2 {
            let (tx, _rx) = mpsc::channel();
            h.try_admit(body("t", vec![i], 1), tx).unwrap();
        }
        let (tx, _rx) = mpsc::channel();
        let e = h.try_admit(body("t", vec![9], 1), tx).unwrap_err();
        assert_eq!(e, ApiError::QueueFull { retry_after_secs: 1 });
        h.note_rejected_413("t");
        let s = h.snapshot(3);
        assert_eq!(s.shard, 3);
        assert_eq!(s.queue_depth, 2);
        assert_eq!(s.queue_cap, 2);
        assert_eq!(s.queue_depth_max, 2);
        assert_eq!(s.rejected_429, 1);
        assert_eq!(s.rejected_413, 1);
        assert_eq!(s.tenants.len(), 1);
        assert_eq!(s.tenants[0].rejected, 2);
        assert_eq!(s.tenants[0].queued, 2);
        // The overlaid ServeStats carries the same server-side fields.
        assert_eq!(s.sched.rejected_429, 1);
        assert_eq!(s.sched.rejected_413, 1);
        assert_eq!(s.sched.queue_depth_max, 2);
        assert_eq!(s.sched.tenants, s.tenants);
    }

    #[test]
    fn shutdown_refuses_with_503() {
        let h = ShardHandle::new(4);
        h.request_shutdown();
        let (tx, _rx) = mpsc::channel();
        assert_eq!(h.try_admit(body("t", vec![1], 1), tx).unwrap_err(),
                   ApiError::ShuttingDown);
        assert!(h.shutdown_requested());
    }

    #[test]
    fn shard_picker_is_deterministic_prefix_keyed_and_in_range() {
        let long_a: Vec<u32> = (0..40).collect();
        // Same first KV_PAGE_TOKENS tokens, different tail: same shard
        // (that is the point — the prefix cache is page-granular).
        let mut long_b = long_a.clone();
        long_b[KV_PAGE_TOKENS + 2] = 999;
        for shards in [1, 2, 3, 8] {
            let s = shard_for_prompt(&long_a, shards);
            assert!(s < shards);
            assert_eq!(s, shard_for_prompt(&long_a, shards));
            assert_eq!(s, shard_for_prompt(&long_b, shards),
                       "routing must key on the first page only");
        }
        // Distinct prefixes spread: not all of 32 prompts on one shard.
        let hits: std::collections::BTreeSet<usize> = (0..32u32)
            .map(|i| shard_for_prompt(&[i, i + 1, i + 2], 4))
            .collect();
        assert!(hits.len() > 1, "picker must actually spread traffic");
    }

    #[test]
    fn worker_streams_match_direct_scheduler_bitwise() {
        let dims = LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 };
        let latent = LatentLm::synthetic(dims, 1, 21);
        let reqs: Vec<Vec<u32>> =
            (0..5u32).map(|i| vec![i, i + 7, i + 11]).collect();

        // Reference: the same prompts through a Scheduler directly.
        let direct = latent.build_float();
        let mut sched = Scheduler::new(&direct, 2, 1);
        for (id, p) in reqs.iter().enumerate() {
            sched.submit(GenRequest::greedy(id, p.clone(), 4));
        }
        let mut expect: HashMap<Vec<u32>, Vec<u32>> = HashMap::new();
        for c in sched.run() {
            expect.insert(reqs[c.id].clone(), c.tokens);
        }

        // Server path: worker thread + fair queue + channels.
        let h = std::sync::Arc::new(ShardHandle::new(16));
        let model: Box<dyn DecodeModel + Send> =
            Box::new(latent.build_float());
        let worker = {
            let h = h.clone();
            std::thread::spawn(move || {
                run_shard(model, &h,
                          &ShardConfig { lanes: 2, threads: 1,
                                         prefill_chunk: 1,
                                         ..ShardConfig::default() })
            })
        };
        let mut rxs = Vec::new();
        for (i, p) in reqs.iter().enumerate() {
            let (tx, rx) = mpsc::channel();
            let tenant = if i % 2 == 0 { "even" } else { "odd" };
            h.try_admit(body(tenant, p.clone(), 4), tx).unwrap();
            rxs.push((p.clone(), rx));
        }
        for (prompt, rx) in rxs {
            let mut streamed = Vec::new();
            loop {
                let item = rx.recv_timeout(Duration::from_secs(30))
                    .unwrap_or_else(|e| panic!(
                        "worker stream stalled: no item within 30s ({e})"));
                match item {
                    StreamItem::Token { token, index } => {
                        assert_eq!(index, streamed.len(),
                                   "tokens must stream in order, deduped");
                        streamed.push(token);
                    }
                    StreamItem::Done(c) => {
                        assert_eq!(c.tokens, streamed,
                                   "stream and completion must agree");
                        break;
                    }
                    StreamItem::Error { kind, detail } => {
                        panic!("unexpected stream error {kind}: {detail}");
                    }
                }
            }
            assert_eq!(streamed, expect[&prompt],
                       "server stream must be bitwise-equal to direct \
                        scheduler output");
        }
        h.request_shutdown();
        let leaked = worker.join().unwrap();
        assert_eq!(leaked, 0, "decay model holds no KV pages");
        let s = h.snapshot(0);
        assert_eq!(s.served, 5);
        assert_eq!(s.queue_depth, 0);
        let by_name = |n: &str| s.tenants.iter()
            .find(|t| t.tenant == n).unwrap().served;
        assert_eq!(by_name("even"), 3);
        assert_eq!(by_name("odd"), 2);
    }

    #[test]
    fn parked_cancel_removes_the_request_and_counts_it() {
        let h = ShardHandle::new(8);
        let (tx, _rx) = mpsc::channel();
        let t0 = h.try_admit(body("t", vec![1], 1), tx).unwrap();
        let (tx, _rx2) = mpsc::channel();
        let t1 = h.try_admit(body("t", vec![2], 1), tx).unwrap();
        assert_eq!((t0, t1), (0, 1), "tickets are sequential per shard");
        h.cancel(t0);
        let s = h.snapshot(0);
        assert_eq!(s.queue_depth, 1, "cancelled request left the queue");
        assert_eq!(s.cancelled, 1);
        assert_eq!(s.sched.cancelled, 1,
                   "overlaid ServeStats carries the parked cancel");
        let survivor = h.try_pop().expect("one request still parked");
        assert_eq!(survivor.ticket, t1);
        // Cancelling an unknown (live or stale) ticket queues it for
        // the worker instead of touching the parked queues.
        h.cancel(77);
        assert_eq!(h.take_cancels(), vec![77]);
    }

    #[test]
    fn parked_requests_past_their_deadline_expire_with_an_error() {
        let h = ShardHandle::new(8);
        h.set_queue_deadline(Some(Duration::from_millis(0)));
        let (tx, rx) = mpsc::channel();
        h.try_admit(body("t", vec![1], 1), tx).unwrap();
        std::thread::sleep(Duration::from_millis(2));
        assert_eq!(h.expire_parked(), 1);
        match rx.try_recv().expect("expiry must send an error line") {
            StreamItem::Error { kind, .. } => {
                assert_eq!(kind, "deadline_expired");
            }
            other => panic!("want an error line, got {other:?}"),
        }
        let s = h.snapshot(0);
        assert_eq!(s.queue_depth, 0);
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.sched.deadline_expired, 1);
        // Nothing to expire when the queue is empty.
        assert_eq!(h.expire_parked(), 0);
    }

    #[test]
    fn decode_deadline_truncates_streams_with_an_explicit_reason() {
        let dims = LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 };
        let latent = LatentLm::synthetic(dims, 1, 23);
        let h = std::sync::Arc::new(ShardHandle::new(8));
        let model: Box<dyn DecodeModel + Send> =
            Box::new(latent.build_float());
        let worker = {
            let h = h.clone();
            std::thread::spawn(move || {
                run_shard(model, &h, &ShardConfig {
                    lanes: 2,
                    threads: 1,
                    prefill_chunk: 4,
                    decode_deadline: Some(Duration::from_millis(0)),
                    ..ShardConfig::default()
                })
            })
        };
        let (tx, rx) = mpsc::channel();
        h.try_admit(body("t", vec![1, 2], 50), tx).unwrap();
        let mut streamed = 0usize;
        loop {
            let item = rx.recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!(
                    "deadline stream stalled: no item within 30s ({e})"));
            match item {
                StreamItem::Token { .. } => streamed += 1,
                StreamItem::Done(c) => {
                    assert_eq!(c.finish_reason,
                               crate::serve::FinishReason::DeadlineExpired,
                               "a zero decode budget must truncate");
                    assert!(c.tokens.len() < 50,
                            "stream must stop long before the token \
                             budget");
                    assert_eq!(c.tokens.len(), streamed);
                    break;
                }
                StreamItem::Error { kind, detail } => {
                    panic!("unexpected stream error {kind}: {detail}");
                }
            }
        }
        h.request_shutdown();
        assert_eq!(worker.join().unwrap(), 0,
                   "expired lane must leave no KV pages behind");
        let s = h.snapshot(0);
        assert_eq!(s.deadline_expired, 1);
        assert_eq!(s.served, 1, "a truncated stream was still delivered");
    }

    #[test]
    fn supervisor_survives_injected_panics_and_serves_parked_requests() {
        let dims = LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 };
        let latent = LatentLm::synthetic(dims, 1, 22);
        let h = std::sync::Arc::new(ShardHandle::new(16));
        let cfg = ShardConfig {
            lanes: 1,
            threads: 1,
            prefill_chunk: 1,
            faults: FaultPlan {
                panic_after_step: Some(1),
                ..FaultPlan::default()
            },
            ..ShardConfig::default()
        };
        // Admit before the worker starts: with one lane, A goes live
        // (and dies with incarnation one), B stays parked in the
        // handle and must survive the crash.
        let (tx_a, rx_a) = mpsc::channel();
        h.try_admit(body("t", vec![1], 5), tx_a).unwrap();
        let (tx_b, rx_b) = mpsc::channel();
        h.try_admit(body("t", vec![2], 3), tx_b).unwrap();
        let worker = {
            let h = h.clone();
            std::thread::spawn(move || {
                run_shard_supervised(
                    || Box::new(latent.build_float())
                        as Box<dyn DecodeModel + Send>,
                    &h, &cfg)
            })
        };
        // B completes under the rebuilt incarnation.
        let mut b_tokens = Vec::new();
        loop {
            let item = rx_b.recv_timeout(Duration::from_secs(30))
                .unwrap_or_else(|e| panic!(
                    "survivor stream stalled: no item within 30s ({e})"));
            match item {
                StreamItem::Token { token, .. } => b_tokens.push(token),
                StreamItem::Done(c) => {
                    assert_eq!(c.tokens, b_tokens);
                    assert_eq!(c.tokens.len(), 3,
                               "survivor must decode its full budget");
                    break;
                }
                StreamItem::Error { kind, detail } => {
                    panic!("survivor hit stream error {kind}: {detail}");
                }
            }
        }
        // A's stream ended in a disconnect (sender dropped in the
        // unwind), never a Done — the relay layer maps that to a
        // worker_restarted error line.
        let mut a_done = false;
        while let Ok(item) = rx_a.recv_timeout(Duration::from_secs(5)) {
            if matches!(item, StreamItem::Done(_)) {
                a_done = true;
            }
        }
        assert!(!a_done, "the lane that died mid-panic must not \
                          complete");
        h.request_shutdown();
        assert_eq!(worker.join().unwrap(), 0,
                   "rebuilt shard must drain with zero pages held");
        let s = h.snapshot(0);
        assert_eq!(s.worker_restarts, 1);
        assert_eq!(s.sched.worker_restarts, 1);
        assert_eq!(s.served, 1);
        assert_eq!(s.queue_depth, 0);
        assert!(s.sched.generated_tokens >= 3,
                "stats must accumulate across the restart");
    }

    #[test]
    fn speculative_worker_streams_match_direct_scheduler_bitwise() {
        use crate::serve::model::{FamilySpec, LatentAttnLm};
        let dims = LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 };
        let latent = LatentAttnLm::synthetic(dims, 4, 1, 25);
        let reqs: Vec<Vec<u32>> =
            (0..5u32).map(|i| vec![i, i + 7, i + 11]).collect();

        // Reference: same prompts through a plain (non-speculative)
        // Scheduler on the same target weights.
        let direct = latent.build_float(2, 24);
        let mut sched = Scheduler::new(&direct, 2, 1);
        for (id, p) in reqs.iter().enumerate() {
            sched.submit(GenRequest::greedy(id, p.clone(), 4));
        }
        let mut expect: HashMap<Vec<u32>, Vec<u32>> = HashMap::new();
        for c in sched.run() {
            expect.insert(reqs[c.id].clone(), c.tokens);
        }
        drop(sched);
        assert_eq!(direct.kv_pages_in_use(), 0);

        // Server path with a TriLM draft installed on the worker.
        let h = std::sync::Arc::new(ShardHandle::new(16));
        let model: Box<dyn DecodeModel + Send> =
            Box::new(latent.build_float(2, 24));
        let draft: Box<dyn DecodeModel + Send> =
            Box::new(latent.build_ternary(2, 24));
        let cfg = ShardConfig {
            lanes: 2,
            threads: 1,
            prefill_chunk: 1,
            spec: Some(SpecConfig {
                draft_family: FamilySpec::Ternary,
                k: 3,
            }),
            ..ShardConfig::default()
        };
        let worker = {
            let h = h.clone();
            std::thread::spawn(move || {
                run_shard_spec(model, Some(draft), &h, &cfg)
            })
        };
        let mut rxs = Vec::new();
        for p in &reqs {
            let (tx, rx) = mpsc::channel();
            h.try_admit(body("t", p.clone(), 4), tx).unwrap();
            rxs.push((p.clone(), rx));
        }
        for (prompt, rx) in rxs {
            let mut streamed = Vec::new();
            loop {
                let item = rx.recv_timeout(Duration::from_secs(30))
                    .unwrap_or_else(|e| panic!(
                        "speculative stream stalled: no item within \
                         30s ({e})"));
                match item {
                    StreamItem::Token { token, index } => {
                        assert_eq!(index, streamed.len(),
                                   "tokens must stream in order, deduped");
                        streamed.push(token);
                    }
                    StreamItem::Done(c) => {
                        assert_eq!(c.tokens, streamed);
                        break;
                    }
                    StreamItem::Error { kind, detail } => {
                        panic!("unexpected stream error {kind}: {detail}");
                    }
                }
            }
            assert_eq!(streamed, expect[&prompt],
                       "speculative server stream must be bitwise-equal \
                        to plain decode");
        }
        h.request_shutdown();
        let leaked = worker.join().unwrap();
        assert_eq!(leaked, 0,
                   "target and draft KV caches must both drain clean");
        let s = h.snapshot(0);
        assert_eq!(s.served, 5);
        assert!(s.sched.spec_proposed > 0,
                "the draft must actually have proposed tokens");
        assert!(s.sched.spec_accepted <= s.sched.spec_proposed);
        assert!(s.sched.spec_verify_steps > 0);
    }
}
