//! The serving API surface: JSON generate-request parsing, admission
//! control, error→status mapping, and the `/stats` document. Pure
//! functions over byte buffers and snapshots — everything here
//! unit-tests without a socket or a model.

use crate::serve::Sampling;
use crate::util::json::Json;

/// A parsed, not-yet-validated `POST /generate` body.
#[derive(Debug, Clone, PartialEq)]
pub struct GenerateBody {
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    /// Fairness key; requests without one share the `"default"` lane.
    pub tenant: String,
    pub sampling: Sampling,
}

/// What the admission layer checks a [`GenerateBody`] against: the
/// shard's vocab and the per-lane KV context its pool was sized for.
#[derive(Debug, Clone, Copy)]
pub struct AdmissionLimits {
    pub vocab: usize,
    /// Per-lane token capacity (`--kv-context`): a request needs
    /// `prompt + max_new_tokens` of it. Scheduler admission panics past
    /// this by design (sizing bug server-side); the front end's job is
    /// to turn it into `413` client-side.
    pub max_context: usize,
}

/// Request-level refusals, each carrying its HTTP status. `QueueFull`
/// is the tentpole's backpressure-as-protocol story: the bounded
/// admission queue turns KV pressure into `429 Retry-After` instead of
/// an unbounded silent requeue pile.
#[derive(Debug, Clone, PartialEq)]
pub enum ApiError {
    /// 400 — malformed JSON, wrong types, out-of-vocab tokens.
    BadRequest(String),
    /// 413 — `prompt + max_new_tokens` exceeds the per-lane KV context.
    ContextTooLarge { need: usize, cap: usize },
    /// 429 — the shard's bounded admission queue is full.
    QueueFull { retry_after_secs: u32 },
    /// 404 — unknown path.
    NotFound,
    /// 405 — known path, wrong method.
    MethodNotAllowed,
    /// 503 — server is draining for shutdown.
    ShuttingDown,
}

impl ApiError {
    pub fn status(&self) -> u16 {
        match self {
            ApiError::BadRequest(_) => 400,
            ApiError::ContextTooLarge { .. } => 413,
            ApiError::QueueFull { .. } => 429,
            ApiError::NotFound => 404,
            ApiError::MethodNotAllowed => 405,
            ApiError::ShuttingDown => 503,
        }
    }

    /// Extra response headers the status mandates (`Retry-After` on
    /// 429/503).
    pub fn extra_headers(&self) -> Vec<(String, String)> {
        match self {
            ApiError::QueueFull { retry_after_secs } => {
                vec![("retry-after".into(), retry_after_secs.to_string())]
            }
            ApiError::ShuttingDown => {
                vec![("retry-after".into(), "1".into())]
            }
            _ => Vec::new(),
        }
    }

    /// JSON error body.
    pub fn body(&self) -> String {
        let (kind, detail) = match self {
            ApiError::BadRequest(m) => ("bad_request", m.clone()),
            ApiError::ContextTooLarge { need, cap } => (
                "context_too_large",
                format!("request needs {need} context tokens, \
                         per-lane capacity is {cap}")),
            ApiError::QueueFull { retry_after_secs } => (
                "queue_full",
                format!("admission queue full; retry after \
                         {retry_after_secs}s")),
            ApiError::NotFound => ("not_found", "unknown path".into()),
            ApiError::MethodNotAllowed =>
                ("method_not_allowed", "wrong method for path".into()),
            ApiError::ShuttingDown =>
                ("shutting_down", "server is draining".into()),
        };
        Json::obj(vec![
            ("error", Json::str(kind)),
            ("detail", Json::str(detail)),
        ]).to_string()
    }
}

/// Parse a `POST /generate` JSON body:
///
/// ```json
/// {"prompt": [1, 2, 3], "max_new_tokens": 8, "tenant": "alice",
///  "top_k": 40, "temperature": 0.8, "seed": 7}
/// ```
///
/// `prompt` is required and non-empty; everything else defaults
/// (`max_new_tokens` 16, tenant `"default"`, greedy sampling unless
/// `top_k` is present).
pub fn parse_generate(body: &[u8]) -> Result<GenerateBody, ApiError> {
    let text = std::str::from_utf8(body)
        .map_err(|_| ApiError::BadRequest("body is not utf-8".into()))?;
    let doc = Json::parse(text)
        .map_err(|e| ApiError::BadRequest(format!("bad json: {e}")))?;
    let prompt_json = doc.opt("prompt")
        .ok_or_else(|| ApiError::BadRequest("missing 'prompt'".into()))?;
    let mut prompt = Vec::new();
    for v in prompt_json.as_arr()
        .map_err(|_| ApiError::BadRequest("'prompt' must be an array".into()))?
    {
        let x = v.as_f64().map_err(|_| ApiError::BadRequest(
            "'prompt' entries must be numbers".into()))?;
        if x < 0.0 || x.fract() != 0.0 || x > u32::MAX as f64 {
            return Err(ApiError::BadRequest(format!(
                "'prompt' entry {x} is not a token id")));
        }
        prompt.push(x as u32);
    }
    if prompt.is_empty() {
        return Err(ApiError::BadRequest("'prompt' must be non-empty".into()));
    }
    let field_usize = |name: &str, default: usize| -> Result<usize, ApiError> {
        match doc.opt(name) {
            None => Ok(default),
            Some(v) => {
                let x = v.as_f64().map_err(|_| ApiError::BadRequest(
                    format!("'{name}' must be a number")))?;
                if x < 0.0 || x.fract() != 0.0 {
                    return Err(ApiError::BadRequest(format!(
                        "'{name}' must be a non-negative integer")));
                }
                Ok(x as usize)
            }
        }
    };
    let max_new_tokens = field_usize("max_new_tokens", 16)?;
    if max_new_tokens == 0 {
        return Err(ApiError::BadRequest(
            "'max_new_tokens' must be >= 1".into()));
    }
    let tenant = match doc.opt("tenant") {
        None => "default".to_string(),
        Some(v) => {
            let s = v.as_str().map_err(|_| ApiError::BadRequest(
                "'tenant' must be a string".into()))?;
            if s.is_empty() {
                return Err(ApiError::BadRequest(
                    "'tenant' must be non-empty".into()));
            }
            s.to_string()
        }
    };
    let sampling = match doc.opt("top_k") {
        None => Sampling::Greedy,
        Some(_) => {
            let k = field_usize("top_k", 0)?;
            if k == 0 {
                return Err(ApiError::BadRequest("'top_k' must be >= 1".into()));
            }
            let temperature = match doc.opt("temperature") {
                None => 1.0f32,
                Some(v) => v.as_f64().map_err(|_| ApiError::BadRequest(
                    "'temperature' must be a number".into()))? as f32,
            };
            let seed = field_usize("seed", 0)? as u64;
            Sampling::TopK { k, temperature, seed }
        }
    };
    Ok(GenerateBody { prompt, max_new_tokens, tenant, sampling })
}

/// Admission control: out-of-vocab token ids → 400, and the max-context
/// check that turns the scheduler's sizing panic into a `413` — a
/// request needs `prompt + max_new_tokens` tokens of per-lane context.
pub fn check_admission(body: &GenerateBody, limits: &AdmissionLimits)
                       -> Result<(), ApiError> {
    if let Some(&t) = body.prompt.iter().find(|&&t| t as usize >= limits.vocab) {
        return Err(ApiError::BadRequest(format!(
            "token id {t} out of vocab {}", limits.vocab)));
    }
    let need = body.prompt.len() + body.max_new_tokens;
    if need > limits.max_context {
        return Err(ApiError::ContextTooLarge { need, cap: limits.max_context });
    }
    Ok(())
}

/// One `{"index":I,"token":T}` ndjson stream line.
pub fn token_line(index: usize, token: u32) -> String {
    let mut s = Json::obj(vec![
        ("index", Json::num(index as f64)),
        ("token", Json::num(token as f64)),
    ]).to_string();
    s.push('\n');
    s
}

/// The `{"done":true,...}` ndjson trailer closing a stream.
/// `finish_reason` says *why* the stream ended — `"length"` (token
/// budget), `"deadline_expired"` (decode wall-clock cap), or
/// `"kv_overflow"` (request larger than the whole KV pool) — so a
/// truncated stream is never mistaken for a complete one.
pub fn done_line(tokens: usize, prompt_len: usize, lane_steps: usize,
                 ttft_steps: usize, finish_reason: &str) -> String {
    let mut s = Json::obj(vec![
        ("done", Json::Bool(true)),
        ("tokens", Json::num(tokens as f64)),
        ("prompt_len", Json::num(prompt_len as f64)),
        ("lane_steps", Json::num(lane_steps as f64)),
        ("ttft_steps", Json::num(ttft_steps as f64)),
        ("finish_reason", Json::str(finish_reason)),
    ]).to_string();
    s.push('\n');
    s
}

/// A mid-stream failure line: the stream cannot complete (queue
/// deadline expired, worker restarted under the request, relay
/// timeout), and since the HTTP status line already went out as `200`
/// when streaming began, the error travels in-band as the final ndjson
/// line before the stream closes.
pub fn error_line(kind: &str, detail: &str) -> String {
    let mut s = Json::obj(vec![
        ("error", Json::str(kind)),
        ("detail", Json::str(detail)),
    ]).to_string();
    s.push('\n');
    s
}

/// Point-in-time view of one shard, as published by its worker and
/// admission lock — the unit `/stats` aggregates and the value
/// [`crate::server::Server::shutdown`] returns per shard.
#[derive(Debug, Clone, Default)]
pub struct ShardSnapshot {
    pub shard: usize,
    /// Requests waiting in the bounded admission queue right now.
    pub queue_depth: usize,
    /// The queue's cap (`--queue-cap`); depth == cap is when 429 fires.
    pub queue_cap: usize,
    /// Deepest the queue has been.
    pub queue_depth_max: usize,
    pub rejected_429: usize,
    pub rejected_413: usize,
    /// Completions delivered (streams closed with a done trailer).
    pub served: usize,
    /// Lanes live in the shard's scheduler at snapshot time.
    pub live_lanes: usize,
    /// KV pages held by the shard's model (0 for decay models).
    pub kv_pages: usize,
    /// Requests cancelled before completing (client hung up, relay
    /// write failed) — parked and live cancels combined.
    pub cancelled: usize,
    /// Requests that hit a deadline: expired out of the admission
    /// queue or truncated mid-decode.
    pub deadline_expired: usize,
    /// Times this shard's worker panicked and was rebuilt by its
    /// supervisor.
    pub worker_restarts: usize,
    /// Per-tenant counters, tenant-sorted.
    pub tenants: Vec<crate::serve::scheduler::TenantStats>,
    /// The shard scheduler's own counters.
    pub sched: crate::serve::ServeStats,
}

/// Render the `/stats` JSON document from per-shard snapshots.
pub fn stats_json(shards: &[ShardSnapshot]) -> String {
    let mut tenant_totals: std::collections::BTreeMap<String, (usize, usize, usize)> =
        std::collections::BTreeMap::new();
    for s in shards {
        for t in &s.tenants {
            let e = tenant_totals.entry(t.tenant.clone()).or_default();
            e.0 += t.served;
            e.1 += t.queued;
            e.2 += t.rejected;
        }
    }
    let shard_docs = shards.iter().map(|s| Json::obj(vec![
        ("shard", Json::num(s.shard as f64)),
        ("queue_depth", Json::num(s.queue_depth as f64)),
        ("queue_cap", Json::num(s.queue_cap as f64)),
        ("queue_depth_max", Json::num(s.queue_depth_max as f64)),
        ("rejected_429", Json::num(s.rejected_429 as f64)),
        ("rejected_413", Json::num(s.rejected_413 as f64)),
        ("served", Json::num(s.served as f64)),
        ("live_lanes", Json::num(s.live_lanes as f64)),
        ("kv_pages", Json::num(s.kv_pages as f64)),
        ("cancelled", Json::num(s.cancelled as f64)),
        ("deadline_expired", Json::num(s.deadline_expired as f64)),
        ("worker_restarts", Json::num(s.worker_restarts as f64)),
        ("generated_tokens", Json::num(s.sched.generated_tokens as f64)),
        ("prefill_tokens", Json::num(s.sched.prefill_tokens as f64)),
        ("requeued", Json::num(s.sched.requeued as f64)),
        ("prefix_hits", Json::num(s.sched.prefix_hits as f64)),
        ("spec_proposed", Json::num(s.sched.spec_proposed as f64)),
        ("spec_accepted", Json::num(s.sched.spec_accepted as f64)),
        ("spec_verify_steps", Json::num(s.sched.spec_verify_steps as f64)),
        ("accepted_per_step", Json::num(s.sched.accepted_per_step())),
        ("spec_k_effective", Json::num(s.sched.spec_k_effective as f64)),
    ]));
    let tenant_docs = tenant_totals.iter().map(|(name, (served, queued,
                                                        rejected))| {
        Json::obj(vec![
            ("tenant", Json::str(name.as_str())),
            ("served", Json::num(*served as f64)),
            ("queued", Json::num(*queued as f64)),
            ("rejected", Json::num(*rejected as f64)),
        ])
    });
    let total = |f: &dyn Fn(&ShardSnapshot) -> usize| -> f64 {
        shards.iter().map(|s| f(s)).sum::<usize>() as f64
    };
    Json::obj(vec![
        ("shards", Json::arr(shard_docs)),
        ("tenants", Json::arr(tenant_docs)),
        ("queue_depth", Json::num(total(&|s| s.queue_depth))),
        ("queue_depth_max", Json::num(total(&|s| s.queue_depth_max))),
        ("rejected_429", Json::num(total(&|s| s.rejected_429))),
        ("rejected_413", Json::num(total(&|s| s.rejected_413))),
        ("served", Json::num(total(&|s| s.served))),
        ("kv_pages", Json::num(total(&|s| s.kv_pages))),
        ("cancelled", Json::num(total(&|s| s.cancelled))),
        ("deadline_expired", Json::num(total(&|s| s.deadline_expired))),
        ("worker_restarts", Json::num(total(&|s| s.worker_restarts))),
        ("spec_proposed", Json::num(total(&|s| s.sched.spec_proposed))),
        ("spec_accepted", Json::num(total(&|s| s.sched.spec_accepted))),
        // A gauge, not a counter: totals report the most aggressive
        // shard (matches `ServeStats::absorb`'s max semantics).
        ("spec_k_effective", Json::num(
            shards.iter().map(|s| s.sched.spec_k_effective)
                .max().unwrap_or(0) as f64)),
    ]).to_string()
}

#[cfg(test)]
mod tests {
    use super::*;

    fn limits() -> AdmissionLimits {
        AdmissionLimits { vocab: 256, max_context: 32 }
    }

    #[test]
    fn parses_full_and_minimal_bodies() {
        let b = parse_generate(
            br#"{"prompt":[1,2,3],"max_new_tokens":8,"tenant":"alice",
                "top_k":40,"temperature":0.5,"seed":7}"#).unwrap();
        assert_eq!(b.prompt, vec![1, 2, 3]);
        assert_eq!(b.max_new_tokens, 8);
        assert_eq!(b.tenant, "alice");
        assert_eq!(b.sampling,
                   Sampling::TopK { k: 40, temperature: 0.5, seed: 7 });

        let b = parse_generate(br#"{"prompt":[9]}"#).unwrap();
        assert_eq!(b.prompt, vec![9]);
        assert_eq!(b.max_new_tokens, 16);
        assert_eq!(b.tenant, "default");
        assert_eq!(b.sampling, Sampling::Greedy);
    }

    #[test]
    fn malformed_bodies_are_400() {
        for bad in [
            &b"not json"[..],
            br#"{"max_new_tokens":4}"#,          // missing prompt
            br#"{"prompt":[]}"#,                 // empty prompt
            br#"{"prompt":"abc"}"#,              // wrong type
            br#"{"prompt":[1.5]}"#,              // fractional token id
            br#"{"prompt":[-1]}"#,               // negative token id
            br#"{"prompt":[1],"max_new_tokens":0}"#,
            br#"{"prompt":[1],"tenant":""}"#,
            br#"{"prompt":[1],"top_k":0}"#,
            b"\xff\xfe",                         // not utf-8
        ] {
            let e = parse_generate(bad).unwrap_err();
            assert_eq!(e.status(), 400, "{bad:?} must be a 400: {e:?}");
        }
    }

    #[test]
    fn admission_maps_oversize_to_413_and_oov_to_400() {
        let ok = GenerateBody {
            prompt: vec![1, 2], max_new_tokens: 30,
            tenant: "t".into(), sampling: Sampling::Greedy,
        };
        assert!(check_admission(&ok, &limits()).is_ok());

        let over = GenerateBody { max_new_tokens: 31, ..ok.clone() };
        let e = check_admission(&over, &limits()).unwrap_err();
        assert_eq!(e.status(), 413);
        assert_eq!(e, ApiError::ContextTooLarge { need: 33, cap: 32 });

        let oov = GenerateBody { prompt: vec![1, 256], ..ok };
        assert_eq!(check_admission(&oov, &limits()).unwrap_err().status(), 400);
    }

    #[test]
    fn queue_full_carries_retry_after() {
        let e = ApiError::QueueFull { retry_after_secs: 2 };
        assert_eq!(e.status(), 429);
        assert_eq!(e.extra_headers(),
                   vec![("retry-after".to_string(), "2".to_string())]);
        assert!(e.body().contains("queue_full"));
        // Every error body is parseable JSON with an "error" key.
        for e in [ApiError::BadRequest("x".into()),
                  ApiError::ContextTooLarge { need: 9, cap: 4 },
                  ApiError::QueueFull { retry_after_secs: 1 },
                  ApiError::NotFound, ApiError::MethodNotAllowed,
                  ApiError::ShuttingDown] {
            let doc = Json::parse(&e.body()).unwrap();
            assert!(doc.get("error").unwrap().as_str().is_ok());
        }
    }

    #[test]
    fn stream_lines_are_ndjson() {
        let t = token_line(3, 99);
        assert!(t.ends_with('\n'));
        let doc = Json::parse(t.trim()).unwrap();
        assert_eq!(doc.get("index").unwrap().as_usize().unwrap(), 3);
        assert_eq!(doc.get("token").unwrap().as_usize().unwrap(), 99);
        let d = done_line(4, 2, 6, 2, "length");
        let doc = Json::parse(d.trim()).unwrap();
        assert!(doc.get("done").unwrap().as_bool().unwrap());
        assert_eq!(doc.get("tokens").unwrap().as_usize().unwrap(), 4);
        assert_eq!(doc.get("ttft_steps").unwrap().as_usize().unwrap(), 2);
        assert_eq!(doc.get("finish_reason").unwrap().as_str().unwrap(),
                   "length");
        let e = error_line("deadline_expired", "queue wait exceeded");
        assert!(e.ends_with('\n'));
        let doc = Json::parse(e.trim()).unwrap();
        assert_eq!(doc.get("error").unwrap().as_str().unwrap(),
                   "deadline_expired");
        assert_eq!(doc.get("detail").unwrap().as_str().unwrap(),
                   "queue wait exceeded");
    }

    #[test]
    fn stats_json_aggregates_shards_and_tenants() {
        use crate::serve::scheduler::TenantStats;
        let shards = vec![
            ShardSnapshot {
                shard: 0, queue_depth: 1, queue_cap: 4, queue_depth_max: 3,
                rejected_429: 2, rejected_413: 1, served: 5, live_lanes: 2,
                kv_pages: 7, cancelled: 2, deadline_expired: 1,
                worker_restarts: 1,
                tenants: vec![TenantStats {
                    tenant: "a".into(), served: 5, queued: 1, rejected: 3 }],
                sched: Default::default(),
            },
            ShardSnapshot {
                shard: 1, queue_cap: 4, served: 2,
                tenants: vec![
                    TenantStats { tenant: "a".into(), served: 1,
                                  ..Default::default() },
                    TenantStats { tenant: "b".into(), served: 1,
                                  ..Default::default() }],
                ..Default::default()
            },
        ];
        let doc = Json::parse(&stats_json(&shards)).unwrap();
        assert_eq!(doc.get("rejected_429").unwrap().as_usize().unwrap(), 2);
        assert_eq!(doc.get("rejected_413").unwrap().as_usize().unwrap(), 1);
        assert_eq!(doc.get("queue_depth_max").unwrap().as_usize().unwrap(), 3);
        assert_eq!(doc.get("served").unwrap().as_usize().unwrap(), 7);
        assert_eq!(doc.get("kv_pages").unwrap().as_usize().unwrap(), 7);
        assert_eq!(doc.get("cancelled").unwrap().as_usize().unwrap(), 2);
        assert_eq!(doc.get("deadline_expired").unwrap()
                       .as_usize().unwrap(), 1);
        assert_eq!(doc.get("worker_restarts").unwrap()
                       .as_usize().unwrap(), 1);
        assert_eq!(doc.get("shards").unwrap().as_arr().unwrap().len(), 2);
        let shard0 = &doc.get("shards").unwrap().as_arr().unwrap()[0];
        assert_eq!(shard0.get("cancelled").unwrap().as_usize().unwrap(), 2);
        assert_eq!(shard0.get("worker_restarts").unwrap()
                         .as_usize().unwrap(), 1);
        let tenants = doc.get("tenants").unwrap().as_arr().unwrap();
        assert_eq!(tenants.len(), 2, "tenant 'a' merges across shards");
        assert_eq!(tenants[0].get("tenant").unwrap().as_str().unwrap(), "a");
        assert_eq!(tenants[0].get("served").unwrap().as_usize().unwrap(), 6);
        assert_eq!(tenants[0].get("rejected").unwrap().as_usize().unwrap(), 3);
    }
}
