//! Deployment analytics: the paper's memory-wall argument (§2.1, App. F).
//!
//! - [`hardware`] — accelerator datasheet DB + Fig. 21 trend fits.
//! - [`bits`] — model-size accounting (Table 4, Fig. 2a axes).
//! - this module — the Fig. 2 analytical models: model-GB vs parameter
//!   count against GPU capacities, and the max theoretical decode
//!   speedup from the compression factor (Kim et al.'s memory wall:
//!   token generation is bandwidth-bound, so speedup ≈ bytes ratio).

pub mod bits;
pub mod hardware;

pub use bits::{model_size_bits, table4, ArchRow, SizeFamily, Table4Row,
               PAPER_SUITE};
pub use hardware::{bandwidth_per_tflop_trend, memory_per_tflop_trend,
                   Accelerator, Vendor, ACCELERATORS};


/// A hypothetical LLaMa-3-style deployment config at parameter count `n`
/// (Fig. 2's x-axis; 128k vocab per §2.1's setup).
#[derive(Debug, Clone, Copy)]
pub struct DeployPoint {
    pub params: f64,
    pub hidden: f64,
}

/// Approximate hidden size for a given total parameter count using the
/// LLaMa aspect recipe params ≈ 12 * layers * hidden^2, layers ≈ hidden/128.
pub fn hidden_for_params(params: f64) -> f64 {
    // params = 12 * (hidden/128) * hidden^2 -> hidden = (params * 128/12)^(1/3)
    (params * 128.0 / 12.0).cbrt()
}

/// Linear-weight bits per parameter for a size family (the paper's
/// effective-bit accounting, §4.2).
pub fn family_linear_bits(fam: SizeFamily) -> f64 {
    match fam {
        SizeFamily::Float => 16.0,
        SizeFamily::Quant { bits, group } => bits as f64 + 16.0 / group as f64,
        SizeFamily::Ternary => 3f64.log2(),
        SizeFamily::Binary => 1.0,
    }
}

/// Model size in GB at parameter count `params` for an *arbitrary*
/// linear-weight bit rate, keeping embeddings (128k vocab, tied pair)
/// in FP16 (§2.1). This is the hook the serve engine's
/// `LinearFormat::effective_bits_per_param` plugs into, so measured
/// storage formats and the analytic memory-wall model share one axis.
pub fn size_gb_at_bits(params: f64, linear_bits: f64) -> f64 {
    let hidden = hidden_for_params(params);
    let embed = 2.0 * 128_000.0 * hidden; // embedding + head
    let linear = (params - embed).max(0.0);
    (embed * 16.0 + linear * linear_bits) / 8.0 / 1e9
}

/// Model size in GB at parameter count `params` for a family.
pub fn size_gb_at(params: f64, fam: SizeFamily) -> f64 {
    size_gb_at_bits(params, family_linear_bits(fam))
}

/// Fig. 2a: the largest parameter count whose weights fit in `mem_gb`.
pub fn max_params_fitting(mem_gb: f64, fam: SizeFamily) -> f64 {
    // Bisection over params.
    let (mut lo, mut hi): (f64, f64) = (1e6, 1e14);
    for _ in 0..200 {
        let mid = (lo * hi).sqrt();
        if size_gb_at(mid, fam) > mem_gb {
            hi = mid;
        } else {
            lo = mid;
        }
    }
    lo
}

/// Fig. 2b: theoretical max autoregressive-decoding speedup vs FP16 at
/// parameter count `params` — the ratio of weight bytes streamed per
/// token (the memory wall makes decode bandwidth-bound).
pub fn max_speedup_vs_fp16(params: f64, fam: SizeFamily) -> f64 {
    size_gb_at(params, SizeFamily::Float) / size_gb_at(params, fam)
}

/// Batched decode roofline: tokens/sec at batch size `batch` on `hw`.
///
/// Extends the Fig. 2b single-stream model with the batching term the
/// serve engine exploits: per decode step the weights are streamed
/// once and amortized over all lanes (bandwidth cost independent of
/// batch), while compute grows linearly with batch. The step time is
/// the roofline max of the two, so throughput rises ~linearly with
/// batch until the compute roof, then flattens:
///
///   t_step = max(weight_bytes / BW,  batch * 2 * params / FLOPS)
///   tokens/sec = batch / t_step
pub fn decode_tokens_per_sec(params: f64, fam: SizeFamily,
                             hw: &Accelerator, batch: f64) -> f64 {
    decode_tokens_per_sec_bits(params, family_linear_bits(fam), hw, batch)
}

/// [`decode_tokens_per_sec`] keyed by an arbitrary linear-weight bit
/// rate — the per-family decode roofline `spectra serve-bench --family`
/// cross-references against each serving model's measured
/// `effective_bits_per_param`.
pub fn decode_tokens_per_sec_bits(params: f64, linear_bits: f64,
                                  hw: &Accelerator, batch: f64) -> f64 {
    assert!(batch >= 1.0, "batch must be >= 1");
    let weight_bytes = size_gb_at_bits(params, linear_bits) * 1e9;
    let t_bw = weight_bytes / (hw.bw_gbs * 1e9);
    let t_compute = batch * 2.0 * params / (hw.tflops_fp16 * 1e12);
    batch / t_bw.max(t_compute)
}

/// FP16 KV-cache bytes appended per decoded token at parameter count
/// `params` under the LLaMa aspect recipe (k + v, `layers x hidden`
/// halfprec each): the per-token bandwidth tax attention serving pays
/// on top of weight streaming. The serving engine's measured analog is
/// `DecodeModel::kv_bytes_per_token` (f32 cache at bench scale);
/// `spectra serve-bench --attn` cross-references the two.
pub fn kv_bytes_per_token_fp16(params: f64) -> f64 {
    let hidden = hidden_for_params(params);
    let layers = hidden / 128.0;
    2.0 * layers * hidden * 2.0
}

/// [`kv_bytes_per_token_fp16`] under grouped-query attention: with
/// `kv_heads` shared key/value heads the cache stores `kv_heads * dh`
/// channels per layer instead of `hidden`, so the per-token tax
/// shrinks by exactly `kv_heads / heads`. `kv_heads == heads` degrades
/// to the MHA figure bit-for-bit. The serving engine's measured analog
/// is `DecodeModel::kv_bytes_per_token` on a `--kv-heads` model.
pub fn kv_bytes_per_token_fp16_gqa(params: f64, heads: usize,
                                   kv_heads: usize) -> f64 {
    assert!(kv_heads >= 1 && kv_heads <= heads && heads % kv_heads == 0,
            "kv_heads must divide heads");
    kv_bytes_per_token_fp16(params) * kv_heads as f64 / heads as f64
}

/// The context a sliding-window decode step actually reads: `window`
/// caps it when finite (`window > 0`), and 0 means unwindowed — the
/// identity. Feed the result to [`decode_tokens_per_sec_bits_kv`]'s
/// `context` to get the windowed KV roofline: past the window the KV
/// bandwidth term stops growing with context, which is the analytic
/// shadow of the paged cache's `kv_pages_in_use` plateau. (A
/// `window:global` interleave re-adds the global layers' full-context
/// stream; this helper models the all-windowed bound.)
pub fn effective_kv_context(context: f64, window: f64) -> f64 {
    assert!(context >= 0.0 && window >= 0.0);
    if window > 0.0 {
        context.min(window)
    } else {
        context
    }
}

/// KV-aware decode roofline: [`decode_tokens_per_sec_bits`] plus the
/// attention bandwidth term. Per decode step the weights stream once
/// (amortized over the batch) but *every lane* additionally streams
/// its own KV cache — `context * kv_bytes_per_token` bytes that
/// compression of the weights does not shrink:
///
///   t_step = max((W + batch*context*kv) / BW, batch * 2P / FLOPS)
///   tokens/sec = batch / t_step
///
/// With `kv_bytes_per_token = 0` this degrades exactly to
/// [`decode_tokens_per_sec_bits`]. As context grows, the KV term
/// dominates and the families' speedups converge — the reason KV-cache
/// layout is the load-bearing design axis for ternary serving
/// (TernaryLLM 2406.07177, Ma et al. 2409.17870).
pub fn decode_tokens_per_sec_bits_kv(params: f64, linear_bits: f64,
                                     kv_bytes_per_token: f64, context: f64,
                                     hw: &Accelerator, batch: f64) -> f64 {
    assert!(batch >= 1.0, "batch must be >= 1");
    assert!(context >= 0.0 && kv_bytes_per_token >= 0.0);
    let weight_bytes = size_gb_at_bits(params, linear_bits) * 1e9;
    let kv_bytes = batch * context * kv_bytes_per_token;
    let t_bw = (weight_bytes + kv_bytes) / (hw.bw_gbs * 1e9);
    let t_compute = batch * 2.0 * params / (hw.tflops_fp16 * 1e12);
    batch / t_bw.max(t_compute)
}

/// Chunked-prefill roofline: prompt tokens/sec when prompts are
/// ingested `chunk` positions per forward pass (batch 1 lane). Prefill
/// reuses the decode roofline with the batch axis replaced by the
/// chunk axis: the weights stream once per pass and amortize over the
/// `chunk` positions flattened into the batch dimension, while compute
/// grows linearly with the chunk —
///
///   t_pass = max(weight_bytes / BW,  chunk * 2 * params / FLOPS)
///   prompt tokens/sec = chunk / t_pass
///
/// At chunk 1 (the one-token prefill the serve engine shipped with)
/// prompt ingestion is as bandwidth-bound as decode and low-bit
/// families keep their full §2.1 advantage; past
/// [`saturation_batch_bits`] positions per pass it turns
/// *compute*-bound and the families converge — compression buys
/// bandwidth, not FLOPs. This asymmetry (memory-bound decode vs
/// compute-bound prefill) is the serving regime the companion Spectra
/// study frames, and `spectra serve-bench --prefill-chunk` measures
/// its engine-side analog (`prefill_tokens_per_sec` in
/// BENCH_serve.json).
pub fn prefill_tokens_per_sec_bits(params: f64, linear_bits: f64,
                                   hw: &Accelerator, chunk: f64) -> f64 {
    assert!(chunk >= 1.0, "chunk must be >= 1");
    let weight_bytes = size_gb_at_bits(params, linear_bits) * 1e9;
    let t_bw = weight_bytes / (hw.bw_gbs * 1e9);
    let t_compute = chunk * 2.0 * params / (hw.tflops_fp16 * 1e12);
    chunk / t_bw.max(t_compute)
}

/// Prefill speedup of chunked ingestion over the one-token path at the
/// same bit rate — linear in `chunk` while bandwidth-bound, flat once
/// the chunk saturates compute.
pub fn prefill_speedup_vs_one_token(params: f64, linear_bits: f64,
                                    hw: &Accelerator, chunk: f64) -> f64 {
    prefill_tokens_per_sec_bits(params, linear_bits, hw, chunk)
        / prefill_tokens_per_sec_bits(params, linear_bits, hw, 1.0)
}

/// Prefix-aware TTFT roofline: scheduler steps from admission to the
/// first sampled token when `reused_tokens` of a `prompt_tokens`-long
/// prompt are *mapped* from a warm prefix cache instead of prefilled —
/// `ceil((prompt - reused) / chunk)`, never below 1 (the final prompt
/// token is always fed through the model, because its logits seed
/// sampling; the serve engine's `prefix_reuse` caps reuse at
/// `prompt - 1` for exactly this reason). With `reused_tokens = 0`
/// this is the cold-cache `ceil(prompt / chunk)` the chunked-prefill
/// roofline prices, and a fully warm cache pins TTFT at 1 step —
/// the "repeated prompts become nearly free" limit of vLLM-style
/// prefix sharing. Steps, not seconds: multiply by the per-step time
/// from [`prefill_tokens_per_sec_bits`] for wall-clock TTFT.
pub fn prefix_ttft_steps(prompt_tokens: usize, reused_tokens: usize,
                         chunk: usize) -> usize {
    assert!(prompt_tokens >= 1, "prompt must be >= 1 token");
    assert!(reused_tokens < prompt_tokens,
            "reuse must leave >= 1 token to feed");
    let chunk = chunk.max(1);
    (prompt_tokens - reused_tokens).div_ceil(chunk).max(1)
}

/// TTFT speedup a warm prefix cache buys over a cold one at the same
/// prefill chunk: `ceil(P/c) / ceil((P-reused)/c)`. Grows toward P/c
/// as reuse approaches P-1 — prefix sharing is to TTFT what chunking
/// is to prefill throughput, and the two compose multiplicatively.
pub fn prefix_ttft_speedup(prompt_tokens: usize, reused_tokens: usize,
                           chunk: usize) -> f64 {
    prefix_ttft_steps(prompt_tokens, 0, chunk) as f64
        / prefix_ttft_steps(prompt_tokens, reused_tokens, chunk) as f64
}

/// End-to-end prefill seconds for one prompt ingested `chunk` tokens
/// per pass: `ceil(prompt / chunk)` passes, each priced by the
/// chunked-prefill roofline (a partial final chunk still streams the
/// full weights, which is why this is pass-counted rather than
/// `prompt / tokens_per_sec`).
pub fn e2e_prefill_seconds(params: f64, linear_bits: f64, hw: &Accelerator,
                           prompt_tokens: usize, chunk: usize) -> f64 {
    let chunk = chunk.max(1);
    let passes = prompt_tokens.max(1).div_ceil(chunk) as f64;
    let t_pass = chunk as f64
        / prefill_tokens_per_sec_bits(params, linear_bits, hw, chunk as f64);
    passes * t_pass
}

/// End-to-end request-latency roofline: seconds from admission to last
/// token for one request on a `batch`-loaded server — the number the
/// HTTP front end (`spectra serve`) turns every synthetic roofline
/// into. Chunked prefill of the whole prompt
/// ([`e2e_prefill_seconds`]), then `new_tokens` decode steps at the
/// lane's share of the KV-aware batched throughput
/// ([`decode_tokens_per_sec_bits_kv`] is aggregate across lanes, so
/// one lane advances at `1/batch` of it). Queueing delay is excluded:
/// this is the service-time floor a request pays once admitted, the
/// baseline the server's measured `lane_steps`/`ttft_steps` compare
/// against.
pub fn e2e_request_latency_s(params: f64, linear_bits: f64,
                             kv_bytes_per_token: f64, context: f64,
                             hw: &Accelerator, batch: f64,
                             prompt_tokens: usize, new_tokens: usize,
                             chunk: usize) -> f64 {
    let prefill_s = e2e_prefill_seconds(params, linear_bits, hw,
                                        prompt_tokens, chunk);
    let lane_tps = decode_tokens_per_sec_bits_kv(
        params, linear_bits, kv_bytes_per_token, context, hw, batch) / batch;
    prefill_s + new_tokens as f64 / lane_tps
}

/// Speculative-decoding roofline: expected decode speedup over plain
/// target decode for a draft-verify lane, keyed by the bits/param of
/// *both* families — the `spectra serve-bench --speculative` analytic
/// companion, fed with the harness's measured `accepted_per_step`.
///
/// Per verify round a lane pays `k` draft steps plus one chunked
/// verify pass and emits `accepted_per_step + 1` tokens (the accepted
/// prefix plus the correction/bonus sample — every round emits at
/// least one). Each step is the batched decode roofline
/// ([`decode_tokens_per_sec_bits`]'s `t_step`); the verify pass
/// streams the target weights *once* but computes `k + 1` positions
/// per lane:
///
///   t_draft  = max(W_draft / BW,  batch * 2P / FLOPS)
///   t_verify = max(W_target / BW, batch * (k+1) * 2P / FLOPS)
///   speedup  = (accepted/step + 1) * t_target / (k*t_draft + t_verify)
///
/// While bandwidth-bound `t_verify == t_target` (chunked verification
/// is free — the §2.1 memory wall working *for* speculation), so the
/// speedup approaches `(accepted/step + 1) / (1 + k * W_draft /
/// W_target)`: a TriLM draft under a float target costs ~1/10th of a
/// target step, which is what makes the paper's ternary family the
/// natural `draft_family`. Low acceptance makes this < 1 — speculation
/// is not free, it is a bet on the draft agreeing with the target.
pub fn speculative_speedup_bits(params: f64, target_bits: f64,
                                draft_bits: f64, hw: &Accelerator,
                                batch: f64, k: f64,
                                accepted_per_step: f64) -> f64 {
    assert!(batch >= 1.0, "batch must be >= 1");
    assert!(k >= 1.0, "speculative k must be >= 1");
    assert!((0.0..=k).contains(&accepted_per_step),
            "accepted/step must lie in [0, k]");
    let step = |bits: f64, positions: f64| {
        let weight_bytes = size_gb_at_bits(params, bits) * 1e9;
        let t_bw = weight_bytes / (hw.bw_gbs * 1e9);
        let t_compute = batch * positions * 2.0 * params
            / (hw.tflops_fp16 * 1e12);
        t_bw.max(t_compute)
    };
    let t_target = step(target_bits, 1.0);
    let t_draft = step(draft_bits, 1.0);
    let t_verify = step(target_bits, k + 1.0);
    (accepted_per_step + 1.0) * t_target / (k * t_draft + t_verify)
}

/// Decode speedup over FP16 at a given batch size for an arbitrary
/// linear-weight bit rate.
pub fn batched_speedup_vs_fp16_bits(params: f64, linear_bits: f64,
                                    hw: &Accelerator, batch: f64) -> f64 {
    decode_tokens_per_sec_bits(params, linear_bits, hw, batch)
        / decode_tokens_per_sec_bits(params, 16.0, hw, batch)
}

/// Decode speedup over FP16 at a given batch size — the Fig. 2b ratio
/// with the batching term. At batch 1 both families are bandwidth-bound
/// and this equals [`max_speedup_vs_fp16`]; at large batch both hit the
/// same compute roof and the ratio collapses toward 1 (compression buys
/// bandwidth, not FLOPs).
pub fn batched_speedup_vs_fp16(params: f64, fam: SizeFamily,
                               hw: &Accelerator, batch: f64) -> f64 {
    decode_tokens_per_sec(params, fam, hw, batch)
        / decode_tokens_per_sec(params, SizeFamily::Float, hw, batch)
}

/// The batch size where a family's decode turns compute-bound on `hw`
/// (weight-streaming time == compute time). Ternary saturates at a
/// smaller batch than FP16 — it streams ~10x fewer bytes, so the
/// bandwidth headroom runs out sooner.
pub fn saturation_batch(params: f64, fam: SizeFamily, hw: &Accelerator) -> f64 {
    saturation_batch_bits(params, family_linear_bits(fam), hw)
}

/// [`saturation_batch`] keyed by an arbitrary linear-weight bit rate.
pub fn saturation_batch_bits(params: f64, linear_bits: f64,
                             hw: &Accelerator) -> f64 {
    let weight_bytes = size_gb_at_bits(params, linear_bits) * 1e9;
    let t_bw = weight_bytes / (hw.bw_gbs * 1e9);
    let t_compute_per_lane = 2.0 * params / (hw.tflops_fp16 * 1e12);
    (t_bw / t_compute_per_lane).max(1.0)
}

/// One row of the Fig. 2 series dump.
#[derive(Debug, Clone)]
pub struct Fig2Row {
    pub params: f64,
    pub float_gb: f64,
    pub quant4_gb: f64,
    pub trilm_gb: f64,
    pub quant4_speedup: f64,
    pub trilm_speedup: f64,
}

/// The Fig. 2 series over a parameter sweep (1B..1T, log-spaced).
pub fn fig2_series() -> Vec<Fig2Row> {
    let q4 = SizeFamily::Quant { bits: 4, group: 128 };
    (0..=30).map(|i| {
        let params = 1e9 * 10f64.powf(i as f64 / 10.0); // 1B..1T
        Fig2Row {
            params,
            float_gb: size_gb_at(params, SizeFamily::Float),
            quant4_gb: size_gb_at(params, q4),
            trilm_gb: size_gb_at(params, SizeFamily::Ternary),
            quant4_speedup: max_speedup_vs_fp16(params, q4),
            trilm_speedup: max_speedup_vs_fp16(params, SizeFamily::Ternary),
        }
    }).collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn e2e_latency_roofline_is_monotone_and_rewards_compression() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        let kvb = kv_bytes_per_token_fp16(7e9);
        let lat = |bits: f64, ctx: f64, batch: f64, prompt: usize,
                   new: usize, chunk: usize| {
            e2e_request_latency_s(7e9, bits, kvb, ctx, hw, batch, prompt,
                                  new, chunk)
        };
        let base = lat(16.0, 1024.0, 8.0, 256, 64, 64);
        assert!(base > 0.0 && base.is_finite());
        // More work, more context, more contending lanes: never faster.
        assert!(lat(16.0, 1024.0, 8.0, 512, 64, 64) > base);
        assert!(lat(16.0, 1024.0, 8.0, 256, 128, 64) > base);
        assert!(lat(16.0, 8192.0, 8.0, 256, 64, 64) > base);
        assert!(lat(16.0, 1024.0, 16.0, 256, 64, 64) > base);
        // Bigger prefill chunks only help (fewer weight streams).
        assert!(lat(16.0, 1024.0, 8.0, 256, 64, 256) <= base);
        // Ternary bits beat fp16 end to end while bandwidth-bound.
        assert!(lat(1.58, 1024.0, 8.0, 256, 64, 64) < base);
        // Prefill is pass-counted: a 1-token and a full-chunk prompt
        // pay the same single pass.
        let one = e2e_prefill_seconds(7e9, 16.0, hw, 1, 64);
        assert!((one - e2e_prefill_seconds(7e9, 16.0, hw, 64, 64)).abs()
                < one * 1e-9);
        assert!((e2e_prefill_seconds(7e9, 16.0, hw, 65, 64) - 2.0 * one)
                .abs() < one * 1e-6);
    }

    #[test]
    fn gqa_kv_bytes_scale_by_the_head_ratio_and_degrade_to_mha() {
        let mha = kv_bytes_per_token_fp16(7e9);
        // kv_heads == heads is the identity, bit for bit.
        assert_eq!(kv_bytes_per_token_fp16_gqa(7e9, 32, 32), mha);
        // Fewer kv heads scale linearly: 8/32 = a 4x smaller stream.
        let gqa = kv_bytes_per_token_fp16_gqa(7e9, 32, 8);
        assert!((gqa * 4.0 - mha).abs() < mha * 1e-12);
        // MQA is the floor: one shared kv head.
        let mqa = kv_bytes_per_token_fp16_gqa(7e9, 32, 1);
        assert!((mqa * 32.0 - mha).abs() < mha * 1e-12);
    }

    #[test]
    #[should_panic(expected = "kv_heads must divide heads")]
    fn gqa_kv_bytes_reject_a_non_dividing_head_count() {
        kv_bytes_per_token_fp16_gqa(7e9, 32, 5);
    }

    #[test]
    fn windowed_context_caps_the_kv_term_and_degrades_to_identity() {
        // window 0 = unwindowed: the identity at every context.
        assert_eq!(effective_kv_context(8192.0, 0.0), 8192.0);
        // A finite window caps context but never raises it.
        assert_eq!(effective_kv_context(8192.0, 1024.0), 1024.0);
        assert_eq!(effective_kv_context(512.0, 1024.0), 512.0);
        // Through the roofline: past the window, decode throughput
        // stops degrading with context (the kv_pages_in_use plateau,
        // analytically), while the unwindowed model keeps paying.
        let hw = hardware::by_name("H100-SXM").unwrap();
        let kvb = kv_bytes_per_token_fp16_gqa(7e9, 32, 8);
        let at = |ctx: f64, window: f64| decode_tokens_per_sec_bits_kv(
            7e9, 1.58, kvb, effective_kv_context(ctx, window), hw, 8.0);
        assert_eq!(at(8192.0, 1024.0), at(32768.0, 1024.0),
                   "windowed decode must plateau past the window");
        assert!(at(32768.0, 0.0) < at(32768.0, 1024.0),
                "unwindowed decode keeps paying for context");
        // And GQA composes: fewer kv heads, faster at equal context.
        let mha_kvb = kv_bytes_per_token_fp16(7e9);
        assert!(decode_tokens_per_sec_bits_kv(7e9, 1.58, mha_kvb, 8192.0,
                                              hw, 8.0)
                < decode_tokens_per_sec_bits_kv(7e9, 1.58, kvb, 8192.0,
                                                hw, 8.0));
    }

    #[test]
    fn floatlm_hits_h100_wall_around_34b() {
        // §2.1: "FloatLM reaches the memory capacity of a single H100 at
        // 34B parameters."
        let max = max_params_fitting(80.0, SizeFamily::Float);
        assert!(max > 25e9 && max < 45e9, "{max:.3e}");
    }

    #[test]
    fn trilm_fits_300b_on_h100() {
        // §2.1: "TriLMs, with over 300B parameters and appropriate
        // packing, can fit on a single H100."
        let max = max_params_fitting(80.0, SizeFamily::Ternary);
        assert!(max > 300e9, "{max:.3e}");
    }

    #[test]
    fn quantlm4_supports_300b_on_mi300x() {
        let q4 = SizeFamily::Quant { bits: 4, group: 128 };
        let max = max_params_fitting(192.0, q4);
        assert!(max > 300e9, "{max:.3e}");
    }

    #[test]
    fn speedup_plateaus_match_paper() {
        // §2.1: QuantLM-4 plateaus at ~4x, TriLM at ~10x; at 7B TriLM
        // is already >4x and ~2x QuantLM-4.
        let q4 = SizeFamily::Quant { bits: 4, group: 128 };
        let t_1t = max_speedup_vs_fp16(1e12, SizeFamily::Ternary);
        let q_1t = max_speedup_vs_fp16(1e12, q4);
        assert!(t_1t > 9.0 && t_1t < 10.5, "TriLM plateau {t_1t}");
        assert!(q_1t > 3.5 && q_1t < 4.0, "Q4 plateau {q_1t}");
        let t_7b = max_speedup_vs_fp16(7e9, SizeFamily::Ternary);
        let q_7b = max_speedup_vs_fp16(7e9, q4);
        assert!(t_7b > 4.0, "TriLM@7B {t_7b}");
        // Paper: "2 times faster than QuantLM 4-bit" at 7B; with our
        // untied-embedding accounting the ratio lands slightly lower.
        assert!(t_7b / q_7b > 1.5, "ratio {t_7b}/{q_7b}");
    }

    #[test]
    fn speedup_grows_with_scale() {
        // Embedding share shrinks with N, so speedup is monotone in N.
        let s1 = max_speedup_vs_fp16(1e9, SizeFamily::Ternary);
        let s2 = max_speedup_vs_fp16(100e9, SizeFamily::Ternary);
        assert!(s2 > s1);
    }

    #[test]
    fn batched_roofline_behaviour() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        let fam = SizeFamily::Ternary;
        // Throughput is nondecreasing in batch...
        let mut last = 0.0;
        for b in [1.0, 2.0, 8.0, 64.0, 1024.0] {
            let tps = decode_tokens_per_sec(7e9, fam, hw, b);
            assert!(tps >= last * 0.999, "batch {b}: {tps} < {last}");
            last = tps;
        }
        // ...and exactly linear while bandwidth-bound.
        let sat = saturation_batch(7e9, fam, hw);
        assert!(sat > 1.0);
        let b = (sat / 2.0).max(1.0);
        let ratio = decode_tokens_per_sec(7e9, fam, hw, b)
            / decode_tokens_per_sec(7e9, fam, hw, 1.0);
        assert!((ratio - b).abs() / b < 1e-6, "ratio {ratio} at batch {b}");
    }

    #[test]
    fn ternary_saturates_before_fp16() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        let t = saturation_batch(7e9, SizeFamily::Ternary, hw);
        let f = saturation_batch(7e9, SizeFamily::Float, hw);
        assert!(t < f, "ternary {t} vs float {f}");
    }

    #[test]
    fn batched_speedup_interpolates_fig2b_to_one() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        let fam = SizeFamily::Ternary;
        // Batch 1: the classic Fig. 2b bytes-ratio speedup.
        let s1 = batched_speedup_vs_fp16(7e9, fam, hw, 1.0);
        assert!((s1 - max_speedup_vs_fp16(7e9, fam)).abs() < 1e-9);
        // Huge batch: both compute-bound, advantage collapses.
        let s_inf = batched_speedup_vs_fp16(7e9, fam, hw, 1e6);
        assert!(s_inf < 1.01, "compute-bound speedup {s_inf}");
        // In between it is monotonically nonincreasing.
        let s8 = batched_speedup_vs_fp16(7e9, fam, hw, 8.0);
        assert!(s8 <= s1 + 1e-9 && s_inf <= s8 + 1e-9);
    }

    #[test]
    fn bits_keyed_roofline_matches_family_keyed() {
        // The serve engine keys the roofline by measured bits/param;
        // family-keyed and bits-keyed forms must agree exactly.
        let hw = hardware::by_name("H100-SXM").unwrap();
        let q4 = SizeFamily::Quant { bits: 4, group: 128 };
        for fam in [SizeFamily::Float, q4, SizeFamily::Ternary] {
            let bits = family_linear_bits(fam);
            assert_eq!(size_gb_at(7e9, fam), size_gb_at_bits(7e9, bits));
            for b in [1.0, 8.0, 256.0] {
                assert_eq!(decode_tokens_per_sec(7e9, fam, hw, b),
                           decode_tokens_per_sec_bits(7e9, bits, hw, b));
            }
            assert_eq!(saturation_batch(7e9, fam, hw),
                       saturation_batch_bits(7e9, bits, hw));
        }
    }

    #[test]
    fn fewer_bits_more_tokens_while_bandwidth_bound() {
        // The bits-vs-throughput story serve-bench reproduces: at batch
        // 1 (bandwidth-bound) throughput rises monotonically as the
        // linear-weight bit rate falls.
        let hw = hardware::by_name("H100-SXM").unwrap();
        let mut last = 0.0;
        for bits in [32.0, 16.0, 8.125, 4.125, 3.125, 3f64.log2()] {
            let tps = decode_tokens_per_sec_bits(7e9, bits, hw, 1.0);
            assert!(tps > last, "bits {bits}: {tps} <= {last}");
            last = tps;
        }
        // fp32 storage serves *slower* than the fp16 reference.
        assert!(batched_speedup_vs_fp16_bits(7e9, 32.0, hw, 1.0) < 1.0);
        assert!(batched_speedup_vs_fp16_bits(7e9, 3f64.log2(), hw, 1.0) > 4.0);
    }

    #[test]
    fn kv_aware_roofline_degrades_to_plain_at_zero_kv() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        for bits in [16.0, 4.125, 3f64.log2()] {
            for b in [1.0, 8.0, 64.0] {
                assert_eq!(
                    decode_tokens_per_sec_bits_kv(7e9, bits, 0.0, 4096.0,
                                                  hw, b),
                    decode_tokens_per_sec_bits(7e9, bits, hw, b));
            }
        }
    }

    #[test]
    fn kv_traffic_is_monotone_tax_and_erodes_compression_speedup() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        let kv = kv_bytes_per_token_fp16(7e9);
        assert!(kv > 0.0);
        // More context -> more bytes per step -> fewer tokens/sec.
        let mut last = f64::INFINITY;
        for ctx in [0.0, 512.0, 4096.0, 32768.0] {
            let tps = decode_tokens_per_sec_bits_kv(7e9, 4.125, kv, ctx,
                                                    hw, 8.0);
            assert!(tps <= last, "ctx {ctx}: {tps} > {last}");
            last = tps;
        }
        // The KV stream is family-independent, so at long context the
        // ternary-vs-fp16 advantage shrinks below the weights-only
        // ratio — the §2.1 speedup claim needs the cache story told.
        let tern = 3f64.log2();
        let speedup = |ctx: f64| {
            decode_tokens_per_sec_bits_kv(7e9, tern, kv, ctx, hw, 8.0)
                / decode_tokens_per_sec_bits_kv(7e9, 16.0, kv, ctx, hw, 8.0)
        };
        assert!(speedup(16384.0) < speedup(0.0),
                "kv traffic should erode the compression speedup");
    }

    #[test]
    fn prefill_roofline_is_linear_then_compute_bound() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        let tern = 3f64.log2();
        // Chunk 1 prefill IS the decode roofline at batch 1 — the
        // one-token prompt path the engine used to have.
        assert_eq!(prefill_tokens_per_sec_bits(7e9, tern, hw, 1.0),
                   decode_tokens_per_sec_bits(7e9, tern, hw, 1.0));
        // Linear while bandwidth-bound...
        let sat = saturation_batch_bits(7e9, tern, hw);
        assert!(sat > 1.0);
        let c = (sat / 2.0).max(1.0);
        let s = prefill_speedup_vs_one_token(7e9, tern, hw, c);
        assert!((s - c).abs() / c < 1e-6, "speedup {s} at chunk {c}");
        // ...and flat at the compute roof, where the families converge
        // (compression buys bandwidth, not FLOPs).
        let t_huge = prefill_tokens_per_sec_bits(7e9, tern, hw, 16384.0);
        let f_huge = prefill_tokens_per_sec_bits(7e9, 16.0, hw, 16384.0);
        assert!((t_huge / f_huge - 1.0).abs() < 0.01,
                "compute-bound prefill must be family-blind: {t_huge} vs \
                 {f_huge}");
        // Monotone nondecreasing in chunk throughout.
        let mut last = 0.0;
        for chunk in [1.0, 4.0, 64.0, 1024.0, 65536.0] {
            let tps = prefill_tokens_per_sec_bits(7e9, tern, hw, chunk);
            assert!(tps >= last * 0.999, "chunk {chunk}: {tps} < {last}");
            last = tps;
        }
        // Low-bit prefill saturates at a smaller chunk: fewer bytes
        // streamed means the bandwidth headroom runs out sooner.
        assert!(saturation_batch_bits(7e9, tern, hw)
                    < saturation_batch_bits(7e9, 16.0, hw));
    }

    #[test]
    fn prefix_ttft_roofline_counts_only_unshared_tokens() {
        // Cold cache: the chunked-prefill step count.
        assert_eq!(prefix_ttft_steps(48, 0, 1), 48);
        assert_eq!(prefix_ttft_steps(48, 0, 16), 3);
        // Warm cache: only the divergent tail pays prefill steps.
        assert_eq!(prefix_ttft_steps(48, 32, 16), 1);
        assert_eq!(prefix_ttft_steps(48, 32, 1), 16);
        assert_eq!(prefix_ttft_steps(48, 40, 16), 1);
        // Max reuse (P-1 tokens) pins TTFT at one step — "repeated
        // prompts become nearly free".
        assert_eq!(prefix_ttft_steps(48, 47, 1), 1);
        assert!((prefix_ttft_speedup(48, 47, 1) - 48.0).abs() < 1e-12);
        // Speedup composes with chunking and is 1.0 with no reuse.
        assert!((prefix_ttft_speedup(48, 0, 16) - 1.0).abs() < 1e-12);
        assert!((prefix_ttft_speedup(48, 32, 16) - 3.0).abs() < 1e-12);
        // Monotone nondecreasing in reuse.
        let mut last = 0.0;
        for reused in [0, 8, 16, 24, 32, 40, 47] {
            let s = prefix_ttft_speedup(48, reused, 4);
            assert!(s >= last, "reuse {reused}: {s} < {last}");
            last = s;
        }
    }

    #[test]
    #[should_panic(expected = "reuse must leave")]
    fn prefix_ttft_rejects_full_reuse() {
        prefix_ttft_steps(16, 16, 4);
    }

    #[test]
    fn speculative_roofline_rewards_acceptance_and_cheap_drafts() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        let tern = 3f64.log2();
        // Monotone increasing in accepted/step: every extra accepted
        // token is a target step the lane did not pay for.
        let mut last = 0.0;
        for aps in [0.0, 0.5, 1.0, 2.0, 3.0] {
            let s = speculative_speedup_bits(7e9, 16.0, tern, hw, 8.0,
                                             3.0, aps);
            assert!(s > last, "aps {aps}: {s} <= {last}");
            last = s;
        }
        // A TriLM draft under a float target wins at good acceptance —
        // the paper's bits-per-param advantage as a latency win.
        let s = speculative_speedup_bits(7e9, 16.0, tern, hw, 8.0,
                                         3.0, 2.5);
        assert!(s > 1.5, "ternary-draft speedup {s}");
        // ...and never exceeds the emit bound of k + 1 tokens/round.
        let max = speculative_speedup_bits(7e9, 16.0, tern, hw, 1.0,
                                           3.0, 3.0);
        assert!(max <= 4.0 + 1e-9, "round emits at most k+1: {max}");
        // A draft as expensive as its target with nothing accepted is
        // pure overhead: k wasted full-price steps per emitted token.
        let loss = speculative_speedup_bits(7e9, 16.0, 16.0, hw, 8.0,
                                            3.0, 0.0);
        assert!(loss < 0.5, "same-cost draft at zero acceptance: {loss}");
        // While bandwidth-bound the chunked verify pass is free
        // (weights stream once), so the k=1 closed form holds:
        // (aps+1) / (1 + W_draft/W_target).
        let wd = size_gb_at_bits(7e9, tern);
        let wt = size_gb_at_bits(7e9, 16.0);
        let got = speculative_speedup_bits(7e9, 16.0, tern, hw, 1.0,
                                           1.0, 1.0);
        let want = 2.0 / (1.0 + wd / wt);
        assert!((got - want).abs() < 1e-9, "{got} vs {want}");
    }

    #[test]
    #[should_panic(expected = "accepted/step must lie in [0, k]")]
    fn speculative_roofline_rejects_impossible_acceptance() {
        let hw = hardware::by_name("H100-SXM").unwrap();
        speculative_speedup_bits(7e9, 16.0, 2.0, hw, 1.0, 2.0, 2.5);
    }

    #[test]
    fn fig2_series_has_monotone_sizes() {
        let series = fig2_series();
        for w in series.windows(2) {
            assert!(w[1].float_gb > w[0].float_gb);
            assert!(w[1].trilm_gb > w[0].trilm_gb);
        }
    }
}
