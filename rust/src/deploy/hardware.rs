//! Accelerator datasheet database (paper Appendix F.1) and the
//! memory-vs-compute trend fits behind Fig. 21.
//!
//! Values are from the same public datasheets the paper cites (peak
//! half-precision dense TFLOPs, HBM/DRAM capacity and bandwidth).


#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Vendor {
    Nvidia,
    Amd,
    Intel,
    Google,
}

#[derive(Debug, Clone)]
pub struct Accelerator {
    pub name: &'static str,
    pub vendor: Vendor,
    pub year: u32,
    /// Memory capacity, GB.
    pub mem_gb: f64,
    /// Memory bandwidth, GB/s.
    pub bw_gbs: f64,
    /// Peak dense FP16/BF16 TFLOPs.
    pub tflops_fp16: f64,
}

/// The Appendix-F accelerator survey.
pub const ACCELERATORS: &[Accelerator] = &[
    // Nvidia
    Accelerator { name: "V100-SXM", vendor: Vendor::Nvidia, year: 2018,
                  mem_gb: 32.0, bw_gbs: 900.0, tflops_fp16: 125.0 },
    Accelerator { name: "A100-40G", vendor: Vendor::Nvidia, year: 2020,
                  mem_gb: 40.0, bw_gbs: 1555.0, tflops_fp16: 312.0 },
    Accelerator { name: "A100-80G", vendor: Vendor::Nvidia, year: 2021,
                  mem_gb: 80.0, bw_gbs: 2039.0, tflops_fp16: 312.0 },
    Accelerator { name: "H100-SXM", vendor: Vendor::Nvidia, year: 2022,
                  mem_gb: 80.0, bw_gbs: 3350.0, tflops_fp16: 990.0 },
    Accelerator { name: "H200", vendor: Vendor::Nvidia, year: 2023,
                  mem_gb: 141.0, bw_gbs: 4800.0, tflops_fp16: 990.0 },
    Accelerator { name: "B200", vendor: Vendor::Nvidia, year: 2024,
                  mem_gb: 192.0, bw_gbs: 8000.0, tflops_fp16: 2250.0 },
    // AMD
    Accelerator { name: "MI210", vendor: Vendor::Amd, year: 2022,
                  mem_gb: 64.0, bw_gbs: 1638.0, tflops_fp16: 181.0 },
    Accelerator { name: "MI250X", vendor: Vendor::Amd, year: 2022,
                  mem_gb: 128.0, bw_gbs: 3277.0, tflops_fp16: 383.0 },
    Accelerator { name: "MI300X", vendor: Vendor::Amd, year: 2023,
                  mem_gb: 192.0, bw_gbs: 5300.0, tflops_fp16: 1307.0 },
    Accelerator { name: "MI325X", vendor: Vendor::Amd, year: 2024,
                  mem_gb: 256.0, bw_gbs: 6000.0, tflops_fp16: 1307.0 },
    // Intel
    Accelerator { name: "Gaudi2", vendor: Vendor::Intel, year: 2022,
                  mem_gb: 96.0, bw_gbs: 2450.0, tflops_fp16: 432.0 },
    Accelerator { name: "Gaudi3", vendor: Vendor::Intel, year: 2024,
                  mem_gb: 128.0, bw_gbs: 3700.0, tflops_fp16: 1835.0 },
    // Google TPUs
    Accelerator { name: "TPUv3", vendor: Vendor::Google, year: 2018,
                  mem_gb: 16.0, bw_gbs: 900.0, tflops_fp16: 123.0 },
    Accelerator { name: "TPUv4", vendor: Vendor::Google, year: 2021,
                  mem_gb: 32.0, bw_gbs: 1200.0, tflops_fp16: 275.0 },
    Accelerator { name: "TPUv5e", vendor: Vendor::Google, year: 2023,
                  mem_gb: 16.0, bw_gbs: 819.0, tflops_fp16: 197.0 },
    Accelerator { name: "TPUv5p", vendor: Vendor::Google, year: 2023,
                  mem_gb: 95.0, bw_gbs: 2765.0, tflops_fp16: 459.0 },
];

/// Simple least-squares line y = a + b x.
pub fn linear_fit(xs: &[f64], ys: &[f64]) -> (f64, f64) {
    let n = xs.len() as f64;
    let mx = xs.iter().sum::<f64>() / n;
    let my = ys.iter().sum::<f64>() / n;
    let cov: f64 = xs.iter().zip(ys).map(|(x, y)| (x - mx) * (y - my)).sum();
    let var: f64 = xs.iter().map(|x| (x - mx) * (x - mx)).sum();
    let b = cov / var.max(1e-12);
    (my - b * mx, b)
}

/// One Fig. 21 series: per-vendor linear fit of ratio-vs-year.
#[derive(Debug, Clone)]
pub struct TrendFit {
    pub vendor: Vendor,
    pub metric: &'static str,
    pub intercept: f64,
    pub slope: f64,
    pub points: Vec<(u32, f64)>,
}

/// Fig. 21a: GB of memory per TFLOP, per vendor, fit over years.
pub fn memory_per_tflop_trend() -> Vec<TrendFit> {
    trend(|a| a.mem_gb / a.tflops_fp16, "mem_gb_per_tflop")
}

/// Fig. 21b: GB/s of bandwidth per TFLOP, per vendor, fit over years.
pub fn bandwidth_per_tflop_trend() -> Vec<TrendFit> {
    trend(|a| a.bw_gbs / a.tflops_fp16, "bw_gbs_per_tflop")
}

fn trend(f: impl Fn(&Accelerator) -> f64, metric: &'static str) -> Vec<TrendFit> {
    [Vendor::Nvidia, Vendor::Amd, Vendor::Intel, Vendor::Google]
        .into_iter()
        .map(|vendor| {
            let pts: Vec<(u32, f64)> = ACCELERATORS.iter()
                .filter(|a| a.vendor == vendor)
                .map(|a| (a.year, f(a)))
                .collect();
            let xs: Vec<f64> = pts.iter().map(|&(y, _)| y as f64).collect();
            let ys: Vec<f64> = pts.iter().map(|&(_, v)| v).collect();
            let (intercept, slope) = linear_fit(&xs, &ys);
            TrendFit { vendor, metric, intercept, slope, points: pts }
        })
        .collect()
}

pub fn by_name(name: &str) -> Option<&'static Accelerator> {
    ACCELERATORS.iter().find(|a| a.name == name)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn linear_fit_exact_line() {
        let (a, b) = linear_fit(&[0.0, 1.0, 2.0], &[1.0, 3.0, 5.0]);
        assert!((a - 1.0).abs() < 1e-9 && (b - 2.0).abs() < 1e-9);
    }

    #[test]
    fn fig21_slopes_are_downward() {
        // The paper's headline: memory and bandwidth per FLOP are falling.
        // With public datasheet numbers the GPU vendors are strictly
        // downward; Google's TPUv5p (95 GB) bucks the *capacity* trend,
        // so Fig 21a holds for the three GPU vendors and Fig 21b for all.
        for fit in memory_per_tflop_trend() {
            if fit.vendor != Vendor::Google {
                assert!(fit.slope < 0.0, "{:?} mem slope {}", fit.vendor,
                        fit.slope);
            }
        }
        for fit in bandwidth_per_tflop_trend() {
            assert!(fit.slope < 0.0, "{:?} bw slope {}", fit.vendor, fit.slope);
        }
    }

    #[test]
    fn lookup_works() {
        assert!(by_name("H100-SXM").is_some());
        assert!(by_name("GTX1080").is_none());
    }
}
