//! Model-size accounting in bits — the machinery behind Table 4, Fig. 2a
//! and the suite's "size (bits)" axis (Figs. 1, 9a, 11, 12).
//!
//! Accounting rules follow the paper exactly (§2.1, §4.2, §A.5):
//! embedding and LM head stay FP16 in every family; linear-layer weights
//! cost `weight_bits` each; TriLM adds `mp` FP16 scales per matrix;
//! QuantLM adds one FP16 scale per group of 128 input channels
//! (effective 3.25/4.25/6.125/8.125 bits per parameter).


use crate::config::{Family, ModelConfig};

/// A family variant for size accounting: the three trained families plus
/// the four post-training QuantLM bitwidths.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum SizeFamily {
    Float,
    Quant { bits: u32, group: usize },
    Ternary,
    Binary,
}

impl SizeFamily {
    pub const TABLE4: [SizeFamily; 6] = [
        SizeFamily::Float,
        SizeFamily::Quant { bits: 8, group: 128 },
        SizeFamily::Quant { bits: 6, group: 128 },
        SizeFamily::Quant { bits: 4, group: 128 },
        SizeFamily::Quant { bits: 3, group: 128 },
        SizeFamily::Ternary,
    ];

    pub fn label(self) -> String {
        match self {
            SizeFamily::Float => "FloatLM".into(),
            SizeFamily::Quant { bits, .. } => format!("QuantLM {bits}-Bit"),
            SizeFamily::Ternary => "TriLM".into(),
            SizeFamily::Binary => "BiLM".into(),
        }
    }

    pub fn from_family(f: Family) -> Self {
        match f {
            Family::Float => SizeFamily::Float,
            Family::Ternary | Family::Bitnet => SizeFamily::Ternary,
            Family::Binary => SizeFamily::Binary,
        }
    }
}

/// A paper-scale architecture row (Table 3) for exact Table 4 output.
#[derive(Debug, Clone)]
pub struct ArchRow {
    pub label: &'static str,
    pub hidden: usize,
    pub glu: usize,
    pub heads: usize,
    pub layers: usize,
    pub mp: usize,
    pub vocab: usize,
}

/// The paper's Table 3 grid (GPT-NeoX 20B tokenizer, vocab 50,432,
/// embeddings rounded up to a multiple of 128*mp per §A.2).
pub const PAPER_SUITE: [ArchRow; 9] = [
    ArchRow { label: "99M", hidden: 512, glu: 1280, heads: 8, layers: 16, mp: 1, vocab: 50432 },
    ArchRow { label: "190M", hidden: 768, glu: 2048, heads: 12, layers: 16, mp: 1, vocab: 50432 },
    ArchRow { label: "390M", hidden: 1024, glu: 2560, heads: 16, layers: 24, mp: 1, vocab: 50432 },
    ArchRow { label: "560M", hidden: 1280, glu: 3072, heads: 20, layers: 24, mp: 1, vocab: 50432 },
    ArchRow { label: "830M", hidden: 1536, glu: 4096, heads: 24, layers: 24, mp: 1, vocab: 50432 },
    ArchRow { label: "1.1B", hidden: 1792, glu: 5120, heads: 28, layers: 24, mp: 2, vocab: 50432 },
    ArchRow { label: "1.5B", hidden: 2048, glu: 6144, heads: 32, layers: 24, mp: 2, vocab: 50432 },
    ArchRow { label: "2.4B", hidden: 2304, glu: 7680, heads: 36, layers: 30, mp: 3, vocab: 50432 },
    ArchRow { label: "3.9B", hidden: 3072, glu: 9216, heads: 24, layers: 30, mp: 6, vocab: 50432 },
];

impl ArchRow {
    pub fn embed_params(&self) -> u64 {
        // embedding + untied LM head, each vocab x hidden
        2 * self.vocab as u64 * self.hidden as u64
    }

    pub fn linear_params(&self) -> u64 {
        let h = self.hidden as u64;
        let g = self.glu as u64;
        self.layers as u64 * (4 * h * h + 3 * g * h)
    }

    pub fn other_params(&self) -> u64 {
        // RMSNorm scales: 2 per layer + final
        (2 * self.layers + 1) as u64 * self.hidden as u64
    }

    pub fn total_params(&self) -> u64 {
        self.embed_params() + self.linear_params() + self.other_params()
    }

    /// Total model size in bits for one family variant.
    pub fn size_bits(&self, fam: SizeFamily) -> f64 {
        let embed = self.embed_params() as f64 * 16.0;
        let other = self.other_params() as f64 * 16.0;
        let lin = self.linear_params() as f64;
        let n_matrices = (self.layers * 7) as f64;
        let lin_bits = match fam {
            SizeFamily::Float => lin * 16.0,
            SizeFamily::Quant { bits, group } => {
                lin * bits as f64 + (lin / group as f64) * 16.0
            }
            // Ternary states at the 1.58-bit entropy coding (Table 4's
            // accounting) + mp fp16 scales per matrix (§A.5).
            SizeFamily::Ternary => {
                lin * 3f64.log2() + n_matrices * self.mp as f64 * 16.0
            }
            SizeFamily::Binary => lin + n_matrices * self.mp as f64 * 16.0,
        };
        embed + other + lin + lin_bits - lin // embed+other+lin_bits
    }

    pub fn size_gb(&self, fam: SizeFamily) -> f64 {
        self.size_bits(fam) / 8.0 / 1e9
    }
}

/// One regenerated Table 4 row.
#[derive(Debug, Clone)]
pub struct Table4Row {
    pub family: String,
    /// Size in bits x 1e9 per paper column, in PAPER_SUITE order.
    pub sizes_gbits: Vec<f64>,
}

/// Regenerate Table 4 ("Sizes in bits (*10^9)").
pub fn table4() -> Vec<Table4Row> {
    SizeFamily::TABLE4.iter().map(|&fam| Table4Row {
        family: fam.label(),
        sizes_gbits: PAPER_SUITE.iter()
            .map(|row| row.size_bits(fam) / 1e9)
            .collect(),
    }).collect()
}

/// Size accounting for a *repro-suite* config (our small models).
pub fn model_size_bits(cfg: &ModelConfig, fam: SizeFamily) -> f64 {
    let embed = (2 * cfg.vocab * cfg.hidden) as f64 * 16.0;
    let other = ((2 * cfg.layers + 1) * cfg.hidden) as f64 * 16.0;
    let lin = cfg.n_linear_params() as f64;
    let n_matrices = (cfg.layers * 7) as f64;
    let lin_bits = match fam {
        SizeFamily::Float => lin * 16.0,
        SizeFamily::Quant { bits, group } => {
            lin * bits as f64 + (lin / group as f64) * 16.0
        }
        SizeFamily::Ternary => lin * 3f64.log2() + n_matrices * cfg.mp as f64 * 16.0,
        SizeFamily::Binary => lin + n_matrices * cfg.mp as f64 * 16.0,
    };
    embed + other + lin_bits
}

#[cfg(test)]
mod tests {
    use super::*;

    /// Paper Table 4, FloatLM row (bits x 1e9).
    const PAPER_FLOATLM: [f64; 9] =
        [1.60, 3.05, 6.28, 9.11, 13.34, 18.39, 24.23, 39.38, 63.83];
    /// Paper Table 4, TriLM row.
    const PAPER_TRILM: [f64; 9] =
        [0.90, 1.42, 2.11, 2.76, 3.55, 4.42, 5.36, 7.23, 10.76];
    /// Paper Table 4, QuantLM 4-bit row.
    const PAPER_Q4: [f64; 9] =
        [1.03, 1.72, 2.88, 3.93, 5.36, 7.00, 8.86, 13.18, 20.59];

    fn check_row(fam: SizeFamily, paper: &[f64; 9], tol: f64) {
        for (row, &want) in PAPER_SUITE.iter().zip(paper.iter()) {
            let got = row.size_bits(fam) / 1e9;
            let rel = (got - want).abs() / want;
            assert!(rel < tol, "{} {}: got {got:.2}, paper {want:.2} \
                     (rel {rel:.3})", fam.label(), row.label);
        }
    }

    #[test]
    fn table4_floatlm_matches_paper() {
        check_row(SizeFamily::Float, &PAPER_FLOATLM, 0.03);
    }

    #[test]
    fn table4_trilm_matches_paper() {
        check_row(SizeFamily::Ternary, &PAPER_TRILM, 0.06);
    }

    #[test]
    fn table4_quantlm4_matches_paper() {
        check_row(SizeFamily::Quant { bits: 4, group: 128 }, &PAPER_Q4, 0.04);
    }

    #[test]
    fn paper_param_counts_match_table3() {
        // Table 3's "Params" column (to ~1%).
        let want = [99.74e6, 190.0e6, 392.4e6, 569.2e6, 834.0e6,
                    1.149e9, 1.515e9, 2.461e9, 3.989e9];
        for (row, &w) in PAPER_SUITE.iter().zip(want.iter()) {
            let got = row.total_params() as f64;
            assert!((got - w).abs() / w < 0.015,
                    "{}: {got:.3e} vs {w:.3e}", row.label);
        }
    }

    #[test]
    fn trilm_is_about_10x_smaller_than_floatlm_at_scale() {
        let row = &PAPER_SUITE[8]; // 3.9B
        let ratio = row.size_bits(SizeFamily::Float)
            / row.size_bits(SizeFamily::Ternary);
        assert!(ratio > 5.5 && ratio < 10.5, "ratio {ratio}");
    }

    #[test]
    fn repro_suite_bits_ordering() {
        let cfg = crate::config::suite_config("6.7m", Family::Ternary).unwrap();
        let f = model_size_bits(&cfg, SizeFamily::Float);
        let q4 = model_size_bits(&cfg, SizeFamily::Quant { bits: 4, group: 128 });
        let t = model_size_bits(&cfg, SizeFamily::Ternary);
        let b = model_size_bits(&cfg, SizeFamily::Binary);
        assert!(f > q4 && q4 > t && t > b);
    }

    use crate::config::Family;
}
