//! `spectra` — the L3 coordinator CLI.
//!
//! Everything runs from AOT-compiled artifacts (`make artifacts` once);
//! no Python on any code path here. Subcommands:
//!
//!   train         train one model
//!   suite         train + evaluate the size x family grid
//!   configs       print the suite configuration grid (Table 3 analog)
//!   eval          evaluate a saved checkpoint
//!   analyze       scaling-law / entropy analysis
//!   deploy        Table 4 / Fig 2 / Fig 21 analytics
//!   generate      greedy text generation (Appendix H demo)
//!   serve-bench   cross-family batched decode throughput (serve engine;
//!                 --attn serves the paged KV-cache attention model)
//!   serve         std-only HTTP serving front end (token streaming,
//!                 sharded schedulers, tenant-fair admission)
//!   bench-report  paper-style tables from a suite run
//!   help          print the usage text

use std::path::PathBuf;

use spectra::checkpoint::Checkpoint;
use spectra::config::{suite_config, Family, TrainConfig};
use spectra::coordinator::{self, SuiteSpec, Trainer};
use spectra::data::{Batcher, Dataset};
use spectra::deploy;
use spectra::eval::Evaluator;
use spectra::runtime::{self, Runtime};
use spectra::util::args::Args;
use spectra::{analysis, Result};

const USAGE: &str = "\
spectra <command> [--flags]

commands:
  train         train one model
                --size 160k --family ternary --steps 200 [--fp16]
                [--seed 0] [--tag train] [--data-chars 2000000]
  suite         train + evaluate the size x family grid
                --sizes 160k,430k,930k --families float,ternary
                --steps 300 [--quant-bits 3,4,8] [--eval-items 50]
                [--calib-batches 4] [--seed 0] [--tag suite]
  configs       print the suite configuration grid (no flags)
  eval          evaluate a saved checkpoint
                --checkpoint runs/train/160k_ternary.spt [--eval-items 50]
  analyze       scaling-law / entropy analysis
                [--results runs/suite/suite_results.json] [--checkpoint x.spt]
  deploy        Table 4 / Fig 2 / Fig 21 analytics
                --output 4|2a|2b|21
  generate      greedy generation via the PJRT next_logits graph
                --checkpoint x.spt --prompt 'one day' [--max-tokens 48]
  serve-bench   cross-family batched decode throughput (serve engine)
                --family float,quant3,quant4,ternary --group 128
                --requests 32 --max-tokens 32 --batches 1,2,4,8
                --threads 1,2,4 --vocab 512 --hidden 256 --glu 704
                --layers 4 --mp 2 [--attn] [--heads 4] [--kv-heads H]
                [--window 0] [--window-interleave 0] [--seed 0]
                [--prefill-chunk 1] [--prompt-tokens 16]
                [--shared-prefix-tokens 0] [--kv-context N]
                [--speculative] [--draft-family ternary] [--spec-k 3]
                [--json BENCH_serve.json]
                --attn serves the paged KV-cache attention model (adds
                kv_bytes_per_token to the table and JSON; see
                docs/BENCH_SCHEMA.md). --kv-heads (default --heads)
                turns on grouped-query attention: query-head groups
                share kv_heads key/value heads and kv_bytes_per_token
                shrinks by heads/kv_heads. --window W bounds attention
                to the last W tokens per layer (0 = full context);
                --window-interleave N makes every (N+1)-th layer global
                (Gemma3-style window:global interleave; 0 = all layers
                windowed, which lets the paged cache recycle
                out-of-window pages). --prefill-chunk ingests up to N
                prompt tokens per batched step (chunked prefill;
                streams are bitwise chunk-invariant), --prompt-tokens
                sets the exact prompt length of the bench traffic,
                --shared-prefix-tokens gives every request the same
                first N prompt tokens (with --attn the prefix cache
                maps them instead of re-running prefill: prefix_hits /
                prefix_tokens_reused / cow_copies land in the table
                and JSON), and --kv-context caps the attention cache's
                per-lane context (sizes below prompt+max-tokens
                exercise KV backpressure: refused lanes requeue —
                pinned prefixes are evicted first — never panic).
                --speculative (needs --attn) adds a --draft-family
                draft model (default ternary) proposing --spec-k
                tokens per decode round; the target verifies them in
                one chunked pass and rolls rejections back out of the
                KV cache — streams stay bitwise identical to plain
                decode, and spec_proposed / spec_accepted /
                accepted_per_step land in the table and JSON (schema 8)
  serve         std-only HTTP/1.1 serving front end over the serve engine
                [--port 8080] [--shards 2] [--lanes 8] [--threads 0]
                [--queue-cap 32] [--kv-context 256] [--prefill-chunk 8]
                [--family float] [--attn] [--heads 4] [--kv-heads H]
                [--window 0] [--window-interleave 0] [--group 128]
                [--vocab 512] [--hidden 256] [--glu 704] [--layers 4]
                [--mp 2] [--seed 0]
                [--speculative] [--draft-family ternary] [--spec-k 3]
                [--read-timeout-ms 10000] [--write-timeout-ms 30000]
                [--relay-timeout-ms 120000] [--queue-deadline-ms 0]
                [--decode-deadline-ms 0] [--fault-panic-step 0]
                endpoints: POST /generate (JSON {\"prompt\":[ids],
                \"max_new_tokens\":N, \"tenant\":\"x\", \"top_k\":K,
                \"temperature\":T, \"seed\":S}; streams ndjson token
                lines via chunked transfer encoding), GET /stats,
                GET /healthz, POST /shutdown (graceful drain). Traffic
                is routed across --shards schedulers by prefix hash;
                each shard has a --queue-cap bounded tenant-fair
                admission queue (429 + Retry-After when full; 413 when
                prompt+max_new_tokens exceeds --kv-context; see the
                README's \"Serving over HTTP\" and \"Robustness\"
                sections). Robustness knobs: --queue-deadline-ms
                expires requests parked longer than N ms with an
                in-band deadline_expired error line (0 = wait forever),
                --decode-deadline-ms truncates streams decoding longer
                than N ms with finish_reason deadline_expired (0 =
                decode to budget), --relay-timeout-ms bounds stream
                silence before the relay gives up (relay_timeout error
                line; worker crashes are reported separately as
                worker_restarted), --read/--write-timeout-ms set the
                socket timeouts, and --fault-panic-step N injects one
                worker panic on shard 0 after its Nth scheduler step
                (chaos testing: the supervisor restarts the worker and
                /stats counts worker_restarts). --speculative (needs
                --attn) gives every shard a --draft-family draft model
                proposing --spec-k tokens per round — streams stay
                bitwise identical and /stats gains spec_proposed /
                spec_accepted / accepted_per_step / spec_k_effective
                (the acceptance-adaptive proposal length). --kv-heads /
                --window / --window-interleave (with --attn) serve the
                grouped-query / sliding-window model: fewer kv heads
                shrink KV bytes per token by heads/kv_heads, a finite
                window bounds per-lane KV growth (out-of-window pages
                are recycled when every layer is windowed)
  bench-report  paper-style tables from a suite run
                --results runs/suite/suite_results.json --experiment all
  help          print this text (also: bare `spectra` or --help)

global: --artifacts artifacts --runs runs
docs:   README.md (repo map + quickstart), docs/BENCH_SCHEMA.md
        (serve-bench --json schema)";

fn main() -> Result<()> {
    let args = Args::from_env();
    let artifacts = PathBuf::from(args.get("artifacts", "artifacts"));
    let runs = PathBuf::from(args.get("runs", "runs"));
    match args.command.as_str() {
        "train" => cmd_train(&args, &artifacts, &runs),
        "suite" => cmd_suite(&args, &artifacts, &runs),
        "configs" => cmd_configs(),
        "eval" => cmd_eval(&args, &artifacts, &runs),
        "analyze" => cmd_analyze(&args),
        "deploy" => {
            print_deploy(&args.get("output", "4"));
            Ok(())
        }
        "generate" => cmd_generate(&args, &artifacts, &runs),
        "serve-bench" => cmd_serve_bench(&args),
        "serve" => cmd_serve(&args),
        "bench-report" => {
            let res = coordinator::SuiteResults::load(
                &PathBuf::from(args.get("results", "")))?;
            bench_report(&res, &args.get("experiment", "all"));
            Ok(())
        }
        // Bare `spectra`, `spectra help`, and `spectra --help` (parsed
        // as a bool flag, so command stays empty) are help requests.
        "" | "help" => {
            println!("{USAGE}");
            Ok(())
        }
        other => {
            // A typo'd command must fail loudly: scripts and CI rely on
            // a non-zero exit, not on someone reading the usage text —
            // but the human gets the full usage text too.
            eprintln!("{USAGE}");
            anyhow::bail!("unknown command '{other}' (see usage above, or \
                           run `spectra help`)");
        }
    }
}

fn cmd_train(args: &Args, artifacts: &PathBuf, runs: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let size = args.get("size", "160k");
    let family = Family::parse(&args.get("family", "ternary"))
        .ok_or_else(|| anyhow::anyhow!("bad family"))?;
    let steps = args.get_usize("steps", 200);
    let seed = args.get_u64("seed", 0);
    let model = format!("{size}_{}", family.as_str());
    let run = runs.join(args.get("tag", "train"));
    let data = Dataset::build(&runs.join("data"),
                              args.get_usize("data-chars", 2_000_000), seed)?;
    let cfg = TrainConfig {
        seed,
        fp16: args.has("fp16"),
        ..TrainConfig::for_family(family, steps)
    };
    let mut trainer = Trainer::new(&rt, &model, cfg)?;
    let mut batcher = Batcher::new(data.train.clone(), rt.manifest().train_batch,
                                   rt.manifest().seq, seed);
    trainer.train(&mut batcher, steps, |m| {
        if m.step % 20 == 0 {
            println!("step {:5}  loss {:.4}  lr {:.2e}  scale {}",
                     m.step, m.loss, m.lr, m.loss_scale);
        }
    })?;
    std::fs::create_dir_all(&run)?;
    trainer.log.write_csv(&run.join(format!("{model}_loss.csv")))?;
    trainer.save_checkpoint(&rt, &model, &run.join(format!("{model}.spt")))?;
    println!("final loss {:.4}; skipped {} batches; min scale {}",
             trainer.log.final_loss(20), trainer.loss_scale.skipped,
             trainer.loss_scale.min_seen);
    Ok(())
}

fn cmd_suite(args: &Args, artifacts: &PathBuf, runs: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let seed = args.get_u64("seed", 0);
    let data = Dataset::build(&runs.join("data"),
                              args.get_usize("data-chars", 4_000_000), seed)?;
    let spec = SuiteSpec {
        sizes: args.get_list("sizes", "160k,430k,930k"),
        families: args.get_list("families", "float,ternary").iter()
            .filter_map(|f| Family::parse(f)).collect(),
        steps: args.get_usize("steps", 300),
        quant_bits: args.get_list("quant-bits", "3,4,8").iter()
            .filter_map(|b| b.parse().ok()).collect(),
        eval_items: args.get_usize("eval-items", 50),
        calib_batches: args.get_usize("calib-batches", 4),
        seed,
    };
    let results = coordinator::run_suite(&rt, &data, &spec,
                                         &runs.join(args.get("tag", "suite")))?;
    print_suite_table(&results);
    if let Some(rep) = coordinator::scaling_from_results(&results) {
        print_scaling(&rep);
    }
    Ok(())
}

fn cmd_configs() -> Result<()> {
    println!("{:<6} {:>7} {:>5} {:>6} {:>6} {:>3} {:>10} {:>12}",
             "size", "hidden", "glu", "heads", "layers", "mp", "params",
             "TriLM bits");
    for size in spectra::config::SUITE_SIZES {
        let c = suite_config(size, Family::Ternary).unwrap();
        println!("{:<6} {:>7} {:>5} {:>6} {:>6} {:>3} {:>10} {:>12.0}",
                 size, c.hidden, c.glu, c.heads, c.layers, c.mp, c.n_params(),
                 deploy::model_size_bits(&c, deploy::SizeFamily::Ternary));
    }
    Ok(())
}

fn cmd_eval(args: &Args, artifacts: &PathBuf, runs: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let ck = Checkpoint::load(&PathBuf::from(args.get("checkpoint", "")))?;
    let model = ck.metadata.get("model")
        .ok_or_else(|| anyhow::anyhow!("checkpoint missing model meta"))?
        .clone();
    let seed = args.get_u64("seed", 0);
    let data = Dataset::build(&runs.join("data"),
                              args.get_usize("data-chars", 2_000_000), seed)?;
    let ev = Evaluator::new(&rt, &model)?;
    let lits: Vec<xla::Literal> = ck.tensor_list().iter()
        .map(runtime::literal_from_tensor).collect::<Result<_>>()?;
    println!("val nll: {:.4}", ev.nll(&lits, &data.val)?);
    for kind in spectra::eval::TaskKind::ALL {
        let items = spectra::eval::generate(
            &data.world, kind, args.get_usize("eval-items", 50), seed ^ 0xE0);
        let score = spectra::eval::run_task(&ev, &lits, &data.bpe, kind, &items)?;
        println!("{:<14} acc {:.3} acc_norm {:.3} (n={})  [{}]",
                 score.task, score.acc, score.acc_norm, score.n,
                 kind.paper_analog());
    }
    Ok(())
}

fn cmd_analyze(args: &Args) -> Result<()> {
    if let Some(path) = args.opt("results") {
        let res = coordinator::SuiteResults::load(&PathBuf::from(path))?;
        if let Some(rep) = coordinator::scaling_from_results(&res) {
            print_scaling(&rep);
        } else {
            println!("not enough per-family points for scaling fits");
        }
    }
    if let Some(path) = args.opt("checkpoint") {
        let ck = Checkpoint::load(&PathBuf::from(path))?;
        // Pool linear-layer weights only (§2.2 analyzes linears).
        let mut pool = Vec::new();
        for (name, t) in &ck.tensors {
            if name.contains("attn_") || name.contains("mlp_") {
                pool.extend_from_slice(&t.data);
            }
        }
        let label = ck.metadata.get("model").cloned()
            .unwrap_or_else(|| path.to_string());
        let stats = analysis::weight_stats(&label, &pool);
        println!("{label}: sigma {:.5} H_diff {:.3} bits kurtosis {:+.3}",
                 stats.sigma, stats.differential_entropy_bits,
                 stats.excess_kurtosis);
        for (bins, h) in &stats.shannon_bits {
            println!("  shannon[{bins:>5} bins] = {h:.3} bits");
        }
    }
    Ok(())
}

fn cmd_generate(args: &Args, artifacts: &PathBuf, runs: &PathBuf) -> Result<()> {
    let rt = Runtime::new(artifacts)?;
    let ck = Checkpoint::load(&PathBuf::from(args.get("checkpoint", "")))?;
    let model = ck.metadata.get("model").unwrap().clone();
    let data = Dataset::build(&runs.join("data"),
                              args.get_usize("data-chars", 2_000_000), 0)?;
    let text = generate(&rt, &model, &ck, &data, &args.get("prompt", "one day"),
                        args.get_usize("max-tokens", 48))?;
    println!("{text}");
    Ok(())
}

/// Benchmark the serve engine across storage families: one table of
/// decode + prefill tokens/sec, TTFT and effective bits/param per
/// family (the paper's bits-vs-throughput story on the serving path),
/// plus the ternary batch/thread sweep against the single-thread
/// scalar reference and the analytic per-family decode *and prefill*
/// rooflines keyed by each model's measured bit rate. `--attn` swaps
/// in the paged KV-cache attention model (same latent-weight
/// discipline, real attention + paging) and adds each family's
/// measured KV bytes/token; `--prefill-chunk` ingests prompts in
/// chunks (bitwise stream-invariant); `--prompt-tokens` fixes the
/// traffic's prompt length; `--shared-prefix-tokens` makes the first N
/// prompt tokens identical across requests, so the attention model's
/// prefix cache + copy-on-write path carries real traffic (hits,
/// reused tokens and CoW copies reported per family); `--kv-context`
/// can undersize the cache to exercise the backpressure path (requeues
/// reported per family; pinned prefixes are evicted before any lane
/// requeues); `--speculative` (with `--attn`) installs a draft model —
/// `--draft-family` (TriLM by default) realized from the same latent
/// weights — that proposes `--spec-k` tokens per decode round for the
/// target to verify in one chunked pass (streams stay bitwise identical
/// to plain decode; proposed/accepted counters and accepted-per-step
/// land in the table, the JSON, and the speculative roofline);
/// `--kv-heads` serves grouped-query attention (query-head groups
/// share `kv_heads` key/value heads, shrinking KV bytes/token by the
/// head ratio) and `--window`/`--window-interleave` bound attention to
/// a sliding window with optional Gemma3-style global layers. `--json
/// <path>` additionally writes the machine-readable sweep
/// (BENCH_serve.json, schema 8 — see docs/BENCH_SCHEMA.md; the
/// server-side and robustness fields are zero on this socketless path)
/// and re-parses the file so a malformed write fails loudly.
fn cmd_serve_bench(args: &Args) -> Result<()> {
    use spectra::serve::{bench_requests_shared, DecodeModel, FamilySpec,
                         LatentAttnLm, LatentLm, LmDims, Scheduler,
                         SpecConfig};

    let dims = LmDims {
        vocab: args.get_usize("vocab", 512),
        hidden: args.get_usize("hidden", 256),
        glu: args.get_usize("glu", 704),
        layers: args.get_usize("layers", 4),
    };
    let mp = args.get_usize("mp", 2);
    if mp == 0 || dims.glu % mp != 0 || dims.hidden % mp != 0 {
        anyhow::bail!("--mp {mp} must divide both --glu {} and --hidden {} \
                       (ternary scale shards are per row range)",
                      dims.glu, dims.hidden);
    }
    let attn = args.has("attn");
    let heads = args.get_usize("heads", 4);
    if attn && (heads == 0 || dims.hidden % heads != 0) {
        anyhow::bail!("--heads {heads} must divide --hidden {} \
                       (attention head width is hidden/heads)",
                      dims.hidden);
    }
    let kv_heads = args.get_usize("kv-heads", heads);
    if attn && (kv_heads == 0 || kv_heads > heads
                || heads % kv_heads != 0) {
        anyhow::bail!("--kv-heads {kv_heads} must divide --heads {heads} \
                       (each group of heads/kv_heads query heads shares \
                       one kv head)");
    }
    let window = args.get_usize("window", 0);
    let window_interleave = args.get_usize("window-interleave", 0);
    if window == 0 && window_interleave > 0 {
        anyhow::bail!("--window-interleave needs a finite --window \
                       (all layers already attend globally)");
    }
    let group = args.get_usize("group", 128);
    let seed = args.get_u64("seed", 0);
    let n_req = args.get_usize("requests", 32);
    let max_new = args.get_usize("max-tokens", 32);
    let batches: Vec<usize> = args.get_list("batches", "1,2,4,8").iter()
        .filter_map(|b| b.parse().ok()).collect();
    let threads_list: Vec<usize> = args.get_list("threads", "1,2,4").iter()
        .filter_map(|t| t.parse().ok()).collect();
    let families: Vec<FamilySpec> = args
        .get_list("family", "float,quant3,quant4,ternary").iter()
        .map(|f| FamilySpec::parse(f, group).ok_or_else(|| anyhow::anyhow!(
            "unknown family '{f}' (float | quant<bits> | gptq<bits> | \
             ternary)")))
        .collect::<Result<_>>()?;
    let fam_batch = batches.iter().copied().max().unwrap_or(8);
    let fam_threads = threads_list.iter().copied().max().unwrap_or(1);
    let prefill_chunk = args.get_usize("prefill-chunk", 1).max(1);
    let prompt_tokens = args.get_usize("prompt-tokens", 16).max(1);
    let shared_prefix = args.get_usize("shared-prefix-tokens", 0)
        .min(prompt_tokens.saturating_sub(1));
    let speculative = args.has("speculative");
    let spec_k = args.get_usize("spec-k", 3).max(1);
    let draft_name = args.get("draft-family", "ternary");
    let draft_family = FamilySpec::parse(&draft_name, group)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown --draft-family '{draft_name}' (float | quant<bits> | \
             gptq<bits> | ternary)"))?;
    if speculative && !attn {
        anyhow::bail!("--speculative needs --attn: verify rolls rejected \
                       tokens back out of the paged KV cache, and a decay \
                       carry cannot be rewound");
    }
    if speculative && shared_prefix > 0 {
        anyhow::bail!("--speculative disables the prefix cache (the draft \
                       has no mapping for reused pages) — drop \
                       --shared-prefix-tokens");
    }
    // Default cache sizing: full prompt + completion per lane, +1
    // headroom so the page pool never runs exactly dry; a speculative
    // verify claims up to 1+k tokens past the committed context before
    // rolling the rejected tail back, so it budgets k more.
    // --kv-context overrides it downward to exercise KV backpressure
    // (refused lanes requeue; the run still completes).
    let spec_headroom = if speculative { spec_k } else { 0 };
    let max_context = args.get_usize("kv-context",
                                     prompt_tokens + max_new + 1
                                         + spec_headroom);

    println!("serve-bench: vocab {} hidden {} glu {} layers {} | \
              {n_req} requests x {prompt_tokens} prompt ({shared_prefix} \
              shared) + {max_new} new \
              tokens | prefill chunk {prefill_chunk} | group {group}{}{}",
             dims.vocab, dims.hidden, dims.glu, dims.layers,
             if attn {
                 format!(" | attn ({heads} heads, {kv_heads} kv heads, \
                          {}, paged kv cache, {max_context}-token \
                          context/lane)",
                         if window > 0 {
                             format!("window {window}:{window_interleave}")
                         } else {
                             "full context".into()
                         })
             } else {
                 String::new()
             },
             if speculative {
                 format!(" | speculative ({} draft, k={spec_k})",
                         draft_family.label())
             } else {
                 String::new()
             });
    // One latent weight set per mode; every family serves the same
    // model in a different storage format.
    let decay_latent =
        (!attn).then(|| LatentLm::synthetic(dims.clone(), mp, seed));
    let attn_latent = attn
        .then(|| LatentAttnLm::synthetic(dims.clone(), heads, mp, seed)
            .with_kv_heads(kv_heads)
            .with_window(window, window_interleave));
    let build = |spec: FamilySpec| -> Result<Box<dyn DecodeModel>> {
        match (&decay_latent, &attn_latent) {
            (Some(latent), _) => latent.build(spec),
            (_, Some(latent)) => latent.build(spec, fam_batch, max_context),
            (None, None) => unreachable!("one latent mode is always built"),
        }
    };

    struct RunPoint {
        tps: f64,
        prefill_tps: f64,
        steps: usize,
        ttft: f64,
        requeued: usize,
        prefix_hits: usize,
        prefix_reused: usize,
        cow_copies: usize,
        spec_proposed: usize,
        spec_accepted: usize,
        spec_verify_steps: usize,
    }
    struct FamRow {
        label: String,
        bits: f64,
        tps_b1: f64,
        tps: f64,
        prefill_tps: f64,
        ttft: f64,
        steps: usize,
        kvb: f64,
        requeued: usize,
        prefix_hits: usize,
        prefix_reused: usize,
        cow_copies: usize,
        spec_proposed: usize,
        spec_accepted: usize,
        spec_verify_steps: usize,
    }
    impl FamRow {
        fn accepted_per_step(&self) -> f64 {
            if self.spec_verify_steps == 0 {
                0.0
            } else {
                self.spec_accepted as f64 / self.spec_verify_steps as f64
            }
        }
    }
    let run_once = |model: &dyn DecodeModel, draft: Option<&dyn DecodeModel>,
                    batch: usize, threads: usize| -> RunPoint {
        let mut sched = Scheduler::with_prefill_chunk(model, batch, threads,
                                                      prefill_chunk);
        if let Some(d) = draft {
            sched.set_speculative(d, SpecConfig { draft_family, k: spec_k });
        }
        for r in bench_requests_shared(dims.vocab, n_req, max_new, seed,
                                       prompt_tokens, shared_prefix) {
            sched.submit(r);
        }
        let t0 = std::time::Instant::now();
        let done = sched.run();
        let secs = t0.elapsed().as_secs_f64();
        let st = sched.stats();
        RunPoint {
            tps: st.generated_tokens as f64 / secs,
            prefill_tps: st.prefill_tokens as f64 / secs,
            steps: st.batch_steps,
            ttft: st.ttft_steps as f64 / done.len().max(1) as f64,
            requeued: st.requeued,
            prefix_hits: st.prefix_hits,
            prefix_reused: st.prefix_tokens_reused,
            cow_copies: st.cow_copies,
            spec_proposed: st.spec_proposed,
            spec_accepted: st.spec_accepted,
            spec_verify_steps: st.spec_verify_steps,
        }
    };

    // Cross-family sweep: every family serves the *same* latent model
    // on the same traffic, measured at batch 1 and at the largest
    // batch/thread setting (the two points the perf trajectory in
    // BENCH_serve.json tracks).
    let mut rows: Vec<FamRow> = Vec::new();
    let mut float_tps = None;
    // One draft model shared across the family sweep: the same latent
    // weights realized in the draft family (TriLM by default — the
    // paper's bits-per-param winner proposing for every target).
    let draft_model: Option<Box<dyn DecodeModel>> = if speculative {
        Some(build(draft_family)?)
    } else {
        None
    };
    let draft_bits = draft_model.as_ref()
        .map(|d| d.effective_bits_per_param());
    for spec in &families {
        let model = build(*spec)?;
        let draft = draft_model.as_deref();
        let b1 = run_once(model.as_ref(), draft, 1, fam_threads);
        let bx = run_once(model.as_ref(), draft, fam_batch, fam_threads);
        if matches!(spec, FamilySpec::Float) {
            float_tps = Some(bx.tps);
        }
        rows.push(FamRow {
            label: spec.label(),
            bits: model.effective_bits_per_param(),
            tps_b1: b1.tps,
            tps: bx.tps,
            prefill_tps: bx.prefill_tps,
            ttft: bx.ttft,
            steps: bx.steps,
            kvb: model.kv_bytes_per_token(),
            requeued: bx.requeued + b1.requeued,
            prefix_hits: bx.prefix_hits + b1.prefix_hits,
            prefix_reused: bx.prefix_reused + b1.prefix_reused,
            cow_copies: bx.cow_copies + b1.cow_copies,
            spec_proposed: bx.spec_proposed + b1.spec_proposed,
            spec_accepted: bx.spec_accepted + b1.spec_accepted,
            spec_verify_steps: bx.spec_verify_steps + b1.spec_verify_steps,
        });
    }
    println!("\ncross-family @ {fam_threads} threads (identical latent \
              weights)");
    println!("{:<22} {:>10} {:>11} {:>11} {:>11} {:>6} {:>6} {:>8} {:>9}",
             "family", "bits/param", "tok/s b1",
             format!("tok/s b{fam_batch}"), "prefill/s", "ttft", "steps",
             "kvB/tok", "vs float");
    for r in &rows {
        let rel = float_tps
            .map(|f| format!("{:.2}x", r.tps / f))
            .unwrap_or_else(|| "-".into());
        println!("{:<22} {:>10.2} {:>11.0} {:>11.0} {:>11.0} {:>6.1} \
                  {:>6} {:>8.0} {:>9}",
                 r.label, r.bits, r.tps_b1, r.tps, r.prefill_tps, r.ttft,
                 r.steps, r.kvb, rel);
    }
    let total_requeued: usize = rows.iter().map(|r| r.requeued).sum();
    if total_requeued > 0 {
        println!("kv backpressure: {total_requeued} lane requeue(s) — the \
                  cache is smaller than the offered concurrency; requests \
                  queued instead of failing");
    }
    let total_hits: usize = rows.iter().map(|r| r.prefix_hits).sum();
    if total_hits > 0 {
        let total_reused: usize = rows.iter().map(|r| r.prefix_reused).sum();
        let total_cow: usize = rows.iter().map(|r| r.cow_copies).sum();
        println!("prefix cache: {total_hits} hit(s), {total_reused} prompt \
                  token(s) mapped instead of prefilled, {total_cow} \
                  copy-on-write page cop{} at divergence",
                 if total_cow == 1 { "y" } else { "ies" });
    }
    if speculative {
        println!("\nspeculative ({} draft, k={spec_k}): accepted draft \
                  tokens per verify step (streams stay bitwise identical \
                  to plain decode)", draft_family.label());
        for r in &rows {
            println!("  {:<22} proposed {:>6}  accepted {:>6}  \
                      accepted/step {:>5.2}",
                     r.label, r.spec_proposed, r.spec_accepted,
                     r.accepted_per_step());
        }
    }

    // Machine-readable trajectory point: --json <path> writes the
    // sweep (and re-parses it, so a malformed file fails the run —
    // ci.sh leans on that).
    if let Some(path) = args.opt("json") {
        use spectra::util::json::Json;
        let fam_json: Vec<Json> = rows.iter()
            .map(|r| Json::obj(vec![
                ("family", Json::str(r.label.as_str())),
                ("bits_per_param", Json::num(r.bits)),
                ("tokens_per_sec_batch1", Json::num(r.tps_b1)),
                ("tokens_per_sec_batch_max", Json::num(r.tps)),
                ("prefill_tokens_per_sec", Json::num(r.prefill_tps)),
                ("ttft_steps", Json::num(r.ttft)),
                ("batch_max", Json::num(fam_batch as f64)),
                ("batch_steps", Json::num(r.steps as f64)),
                ("kv_bytes_per_token", Json::num(r.kvb)),
                ("requeued", Json::num(r.requeued as f64)),
                ("prefix_hits", Json::num(r.prefix_hits as f64)),
                ("prefix_tokens_reused",
                 Json::num(r.prefix_reused as f64)),
                ("cow_copies", Json::num(r.cow_copies as f64)),
                // Server-side counters (schema 5) and robustness
                // counters (schema 6): serve-bench drives the
                // scheduler directly — no HTTP admission layer, no
                // client disconnects, no supervised workers — so all
                // of these are structurally zero here; `spectra
                // serve`'s /stats is where they move. Kept in the
                // schema so one parser reads both.
                ("queue_depth_max", Json::num(0.0)),
                ("rejected_429", Json::num(0.0)),
                ("rejected_413", Json::num(0.0)),
                ("cancelled", Json::num(0.0)),
                ("deadline_expired", Json::num(0.0)),
                ("worker_restarts", Json::num(0.0)),
                // Speculative counters (schema 7): structurally zero
                // unless --speculative installed a draft model.
                ("spec_proposed", Json::num(r.spec_proposed as f64)),
                ("spec_accepted", Json::num(r.spec_accepted as f64)),
                ("spec_verify_steps",
                 Json::num(r.spec_verify_steps as f64)),
                ("accepted_per_step", Json::num(r.accepted_per_step())),
            ]))
            .collect();
        let doc = Json::obj(vec![
            ("bench", Json::str("serve")),
            ("schema", Json::num(8.0)),
            ("dims", Json::obj(vec![
                ("vocab", Json::num(dims.vocab as f64)),
                ("hidden", Json::num(dims.hidden as f64)),
                ("glu", Json::num(dims.glu as f64)),
                ("layers", Json::num(dims.layers as f64)),
            ])),
            ("attn", Json::num(if attn { 1.0 } else { 0.0 })),
            ("heads", Json::num(if attn { heads as f64 } else { 0.0 })),
            // GQA / sliding-window geometry (schema 8): kv_heads ==
            // heads and window 0 are the classic MHA/full-context
            // shape, bitwise identical to schema-7 runs.
            ("kv_heads", Json::num(if attn { kv_heads as f64 }
                                   else { 0.0 })),
            ("window", Json::num(if attn { window as f64 } else { 0.0 })),
            ("window_interleave", Json::num(if attn {
                window_interleave as f64
            } else {
                0.0
            })),
            ("threads", Json::num(fam_threads as f64)),
            ("requests", Json::num(n_req as f64)),
            ("max_new_tokens", Json::num(max_new as f64)),
            ("prompt_tokens", Json::num(prompt_tokens as f64)),
            ("shared_prefix_tokens", Json::num(shared_prefix as f64)),
            ("prefill_chunk", Json::num(prefill_chunk as f64)),
            ("kv_context", Json::num(if attn {
                max_context as f64
            } else {
                0.0
            })),
            ("group", Json::num(group as f64)),
            ("mp", Json::num(mp as f64)),
            ("seed", Json::num(seed as f64)),
            ("speculative", Json::num(if speculative { 1.0 } else { 0.0 })),
            ("draft_family", Json::str(if speculative {
                draft_name.as_str()
            } else {
                ""
            })),
            ("spec_k", Json::num(if speculative {
                spec_k as f64
            } else {
                0.0
            })),
            ("families", Json::Arr(fam_json)),
        ]);
        let path = PathBuf::from(path);
        if let Some(parent) = path.parent() {
            if !parent.as_os_str().is_empty() {
                std::fs::create_dir_all(parent)?;
            }
        }
        std::fs::write(&path, doc.to_string())?;
        let back = std::fs::read_to_string(&path)?;
        let parsed = Json::parse(&back)
            .map_err(|e| anyhow::anyhow!(
                "BENCH json at {} failed to re-parse: {e}", path.display()))?;
        let n_fams = parsed.get("families")?.as_arr()?.len();
        println!("\nwrote {} ({n_fams} families, parse-checked)",
                 path.display());
    }

    // Ternary batch/thread sweep vs the single-thread scalar reference.
    if families.contains(&FamilySpec::Ternary) {
        let tlm = build(FamilySpec::Ternary)?;
        let tlm = tlm.as_ref();
        let scalar_tps = run_once(tlm, None, 1, 1).tps;
        println!("\n{:<10} {:>7} {:>14} {:>12} {:>10}",
                 "kernel", "batch", "threads", "tokens/s", "vs scalar");
        println!("{:<10} {:>7} {:>14} {:>12.0} {:>10}",
                 "ternary", 1, 1, scalar_tps, "1.00x");
        let mut best_b8 = 0.0f64;
        for &threads in &threads_list {
            for &batch in &batches {
                if batch == 1 && threads == 1 {
                    continue;
                }
                let tps = run_once(tlm, None, batch, threads).tps;
                if batch == 8 {
                    best_b8 = best_b8.max(tps);
                }
                println!("{:<10} {:>7} {:>14} {:>12.0} {:>9.2}x",
                         "ternary", batch, threads, tps, tps / scalar_tps);
            }
        }
        if best_b8 > 0.0 {
            println!("\nbatch-8 threaded ternary vs single-thread scalar: \
                      {:.2}x (target >= 3x)", best_b8 / scalar_tps);
        }
    }

    // Analytic cross-reference: each family's decode roofline at scale,
    // keyed by the bits/param measured on the serving model itself.
    if let Some(hw) = spectra::deploy::hardware::by_name("H100-SXM") {
        use spectra::deploy::{batched_speedup_vs_fp16_bits,
                              decode_tokens_per_sec_bits_kv,
                              effective_kv_context,
                              kv_bytes_per_token_fp16,
                              kv_bytes_per_token_fp16_gqa,
                              prefill_speedup_vs_one_token,
                              prefill_tokens_per_sec_bits,
                              saturation_batch_bits,
                              speculative_speedup_bits};
        println!("\nroofline @7B on {} (speedup vs fp16 by measured \
                  bits/param):", hw.name);
        for r in &rows {
            println!("  {:<22} {:>6.2} bits -> {:>5.1}x (b=1) \
                      {:>5.1}x (b=8) {:>5.1}x (b=256); saturates at \
                      batch {:.0}",
                     r.label, r.bits,
                     batched_speedup_vs_fp16_bits(7e9, r.bits, hw, 1.0),
                     batched_speedup_vs_fp16_bits(7e9, r.bits, hw, 8.0),
                     batched_speedup_vs_fp16_bits(7e9, r.bits, hw, 256.0),
                     saturation_batch_bits(7e9, r.bits, hw));
        }
        // The prefill roofline beside the decode one: chunked prompt
        // ingestion amortizes the weight stream over the chunk, so it
        // is linear in chunk until the compute roof — where the
        // families converge (compression buys bandwidth, not FLOPs).
        // Decode stays bandwidth-bound; prefill is the compute-bound
        // half of the serving asymmetry.
        let chunk = prefill_chunk.max(64) as f64;
        println!("\nprefill roofline @7B on {} (chunked ingestion, \
                  weights streamed once per chunk):", hw.name);
        for r in &rows {
            println!("  {:<22} chunk 1: {:>9.0} tok/s; chunk {:.0}: \
                      {:>5.1}x one-token; compute-bound past chunk {:.0}",
                     r.label,
                     prefill_tokens_per_sec_bits(7e9, r.bits, hw, 1.0),
                     chunk,
                     prefill_speedup_vs_one_token(7e9, r.bits, hw, chunk),
                     saturation_batch_bits(7e9, r.bits, hw));
        }
        if attn {
            // The KV-aware roofline: the cache stream is family-blind
            // (fp16 activations at scale), so long contexts erode the
            // compression speedup — the serving story the paged cache
            // makes measurable. GQA divides the stream by the head
            // ratio and a sliding window caps how much of the context
            // a decode step reads at all; the fp16 baseline stays the
            // classic MHA/full-context server, so the ratios show the
            // combined bits + kv-geometry win.
            let kvb = kv_bytes_per_token_fp16_gqa(7e9, heads, kv_heads);
            let kvb_mha = kv_bytes_per_token_fp16(7e9);
            println!("\nkv-aware roofline @7B, fp16 cache ({kvb:.0} \
                      B/token at {kv_heads}/{heads} kv heads{}), batch 8:",
                     if window > 0 {
                         format!(", window {window}")
                     } else {
                         String::new()
                     });
            let fp16_at = |ctx: f64| {
                decode_tokens_per_sec_bits_kv(7e9, 16.0, kvb_mha, ctx,
                                              hw, 8.0)
            };
            for r in &rows {
                let at = |ctx: f64| {
                    decode_tokens_per_sec_bits_kv(
                        7e9, r.bits, kvb,
                        effective_kv_context(ctx, window as f64), hw, 8.0)
                };
                println!("  {:<22} vs fp16: {:>5.1}x @ctx 1k \
                          {:>5.1}x @ctx 8k {:>5.1}x @ctx 32k",
                         r.label,
                         at(1024.0) / fp16_at(1024.0),
                         at(8192.0) / fp16_at(8192.0),
                         at(32768.0) / fp16_at(32768.0));
            }
        }
        if let Some(db) = draft_bits {
            // The speculative roofline: each verify round buys
            // accepted/step + 1 tokens for k draft steps plus one
            // chunked (k+1)-token target pass — keyed by the measured
            // bits/param of both families and the acceptance rate the
            // sweep just measured. Ternary's bits-per-param win (the
            // paper's Table 4/Fig 2 story) is exactly what makes its
            // draft steps nearly free against a float target.
            println!("\nspeculative roofline @7B on {} ({} draft at \
                      {db:.2} bits/param, k={spec_k}, measured \
                      accepted/step):",
                     hw.name, draft_family.label());
            for r in &rows {
                let aps = r.accepted_per_step();
                println!("  {:<22} accepted/step {:>5.2} -> expected \
                          {:>5.2}x vs plain decode",
                         r.label, aps,
                         speculative_speedup_bits(
                             7e9, r.bits, db, hw, fam_batch as f64,
                             spec_k as f64, aps));
            }
        }
    }

    // Prefix-aware TTFT roofline: a warm prefix cache maps the shared
    // region instead of prefilling it, so TTFT only pays
    // ceil((prompt - reused) / chunk) steps. Family-blind (TTFT is
    // counted in scheduler steps), hence one line, not one per family.
    // Reuse needs at least one full page to index; past that the
    // token-verified tail extension reuses the whole shared region.
    if shared_prefix > 0 {
        use spectra::deploy::{prefix_ttft_speedup, prefix_ttft_steps};
        use spectra::serve::KV_PAGE_TOKENS;
        let reused = if shared_prefix >= KV_PAGE_TOKENS {
            shared_prefix
        } else {
            0
        };
        println!("\nprefix-aware ttft roofline: {prompt_tokens}-token \
                  prompt, {reused} reusable -> {} prefill step(s) at \
                  chunk {prefill_chunk} vs {} cold ({:.1}x)",
                 prefix_ttft_steps(prompt_tokens, reused, prefill_chunk),
                 prefix_ttft_steps(prompt_tokens, 0, prefill_chunk),
                 prefix_ttft_speedup(prompt_tokens, reused, prefill_chunk));
    }
    Ok(())
}

/// `spectra serve` — run the std-only HTTP serving front end until a
/// `POST /shutdown` arrives, then drain gracefully and report per-shard
/// serving counters plus the KV-page leak check. Prints the bound
/// address on a parseable `listening on ...` line (ephemeral `--port 0`
/// is how the ci.sh smoke finds it) and the analytic end-to-end
/// request-latency roofline the measured traffic can be compared
/// against. Exits non-zero if any shard still holds KV pages after the
/// drain — a leak is a bug, not a statistic.
fn cmd_serve(args: &Args) -> Result<()> {
    use spectra::serve::{FamilySpec, FaultPlan, LmDims};
    use spectra::server::{Server, ServerConfig};

    let dims = LmDims {
        vocab: args.get_usize("vocab", 512),
        hidden: args.get_usize("hidden", 256),
        glu: args.get_usize("glu", 704),
        layers: args.get_usize("layers", 4),
    };
    let mp = args.get_usize("mp", 2);
    if mp == 0 || dims.glu % mp != 0 || dims.hidden % mp != 0 {
        anyhow::bail!("--mp {mp} must divide both --glu {} and --hidden {} \
                       (ternary scale shards are per row range)",
                      dims.glu, dims.hidden);
    }
    let attn = args.has("attn");
    let heads = args.get_usize("heads", 4);
    if attn && (heads == 0 || dims.hidden % heads != 0) {
        anyhow::bail!("--heads {heads} must divide --hidden {} \
                       (attention head width is hidden/heads)",
                      dims.hidden);
    }
    let kv_heads = args.get_usize("kv-heads", heads);
    if attn && (kv_heads == 0 || kv_heads > heads
                || heads % kv_heads != 0) {
        anyhow::bail!("--kv-heads {kv_heads} must divide --heads {heads} \
                       (each group of heads/kv_heads query heads shares \
                       one kv head)");
    }
    let window = args.get_usize("window", 0);
    let window_interleave = args.get_usize("window-interleave", 0);
    if window == 0 && window_interleave > 0 {
        anyhow::bail!("--window-interleave needs a finite --window \
                       (all layers already attend globally)");
    }
    let group = args.get_usize("group", 128);
    let family_name = args.get("family", "float");
    let family = FamilySpec::parse(&family_name, group)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown family '{family_name}' (float | quant<bits> | \
             gptq<bits> | ternary)"))?;
    let speculative = args.has("speculative");
    let draft_name = args.get("draft-family", "ternary");
    let draft_family = FamilySpec::parse(&draft_name, group)
        .ok_or_else(|| anyhow::anyhow!(
            "unknown draft family '{draft_name}' (float | quant<bits> | \
             gptq<bits> | ternary)"))?;
    let spec_k = args.get_usize("spec-k", 3).max(1);
    if speculative && !attn {
        anyhow::bail!("--speculative needs --attn: draft-verify rollback \
                       requires the paged-KV attention model");
    }
    let cfg = ServerConfig {
        port: args.get_usize("port", 8080) as u16,
        shards: args.get_usize("shards", 2).max(1),
        lanes: args.get_usize("lanes", 8).max(1),
        threads: args.get_usize("threads", 0),
        prefill_chunk: args.get_usize("prefill-chunk", 8).max(1),
        queue_cap: args.get_usize("queue-cap", 32).max(1),
        kv_context: args.get_usize("kv-context", 256).max(2),
        family,
        attn,
        heads,
        kv_heads,
        window,
        window_interleave,
        dims,
        mp,
        seed: args.get_u64("seed", 0),
        read_timeout_ms: args.get_u64("read-timeout-ms", 10_000).max(1),
        write_timeout_ms: args.get_u64("write-timeout-ms", 30_000).max(1),
        relay_timeout_ms: args.get_u64("relay-timeout-ms", 120_000).max(1),
        queue_deadline_ms: args.get_u64("queue-deadline-ms", 0),
        decode_deadline_ms: args.get_u64("decode-deadline-ms", 0),
        fault_plan: FaultPlan {
            panic_after_step: match args.get_usize("fault-panic-step", 0) {
                0 => None,
                n => Some(n),
            },
            ..FaultPlan::default()
        },
        speculative,
        draft_family,
        spec_k,
    };
    let shards = cfg.shards;
    let lanes = cfg.lanes;
    let server = Server::start(cfg.clone())?;
    println!("spectra serve: listening on {} ({} shard(s) x {} lane(s), \
              family {}, {}, queue cap {}, kv context {}/lane{})",
             server.addr(), shards, lanes, family.label(),
             if attn {
                 format!("paged-kv attention ({kv_heads}/{heads} kv \
                          heads, {})",
                         if window > 0 {
                             format!("window {window}:{window_interleave}")
                         } else {
                             "full context".into()
                         })
             } else {
                 "decay state".into()
             },
             cfg.queue_cap, cfg.kv_context,
             if speculative {
                 format!(", speculative {} draft k={spec_k}",
                         draft_family.label())
             } else {
                 String::new()
             });
    // The analytic floor the measured traffic compares against: what
    // one admitted request costs end to end at this batch depth, at
    // paper scale on real hardware.
    if let Some(hw) = spectra::deploy::hardware::by_name("H100-SXM") {
        // GQA scales the cache stream by kv_heads/heads; a finite
        // window caps how much context a decode step reads.
        let kvb = if attn {
            spectra::deploy::kv_bytes_per_token_fp16_gqa(7e9, heads,
                                                         kv_heads)
        } else {
            spectra::deploy::kv_bytes_per_token_fp16(7e9)
        };
        let bits = match family {
            FamilySpec::Float => 16.0,
            FamilySpec::Quant { bits, .. } => bits as f64,
            FamilySpec::Ternary => 1.58,
        };
        let lat = spectra::deploy::e2e_request_latency_s(
            7e9, bits, kvb,
            spectra::deploy::effective_kv_context(cfg.kv_context as f64,
                                                  window as f64),
            hw, lanes as f64, 16, 32, cfg.prefill_chunk);
        println!("e2e roofline @7B on {}: 16-token prompt + 32 new tokens \
                  at batch {} ~ {:.1} ms/request ({:.1} bits/param)",
                 hw.name, lanes, lat * 1e3, bits);
    }
    while !server.shutdown_requested() {
        std::thread::sleep(std::time::Duration::from_millis(50));
    }
    println!("spectra serve: draining...");
    let finals = server.shutdown();
    let mut leaked = 0usize;
    for s in &finals {
        println!("shard {}: served {} | 429 {} | 413 {} | cancelled {} | \
                  deadline expired {} | worker restarts {} | queue depth \
                  max {} | generated {} tok | requeued {} | prefix hits \
                  {} | kv pages after drain {}",
                 s.shard, s.served, s.rejected_429, s.rejected_413,
                 s.cancelled, s.deadline_expired, s.worker_restarts,
                 s.queue_depth_max, s.sched.generated_tokens,
                 s.sched.requeued, s.sched.prefix_hits, s.kv_pages);
        for t in &s.tenants {
            println!("  tenant {:<12} served {} queued {} rejected {}",
                     t.tenant, t.served, t.queued, t.rejected);
        }
        leaked = leaked.saturating_add(s.kv_pages);
    }
    if leaked > 0 {
        // usize::MAX marks a shard whose worker failed permanently
        // (restart budget exhausted) rather than a literal page count.
        anyhow::bail!("{leaked} kv page(s) leaked across shards after drain");
    }
    println!("spectra serve: shutdown clean, 0 kv pages leaked");
    Ok(())
}

/// Greedy decoding via the `next_logits` graph (Appendix-H-style demo).
fn generate(rt: &Runtime, model: &str, ck: &Checkpoint, data: &Dataset,
            prompt: &str, max_tokens: usize) -> Result<String> {
    let graph = rt.load_graph(model, "next_logits")?;
    let seq = rt.manifest().seq;
    let lits: Vec<xla::Literal> = ck.tensor_list().iter()
        .map(runtime::literal_from_tensor).collect::<Result<_>>()?;
    let mut tokens: Vec<i32> = data.bpe.encode(prompt).iter()
        .map(|&t| t as i32).collect();
    for _ in 0..max_tokens {
        // Left-pad/truncate to the fixed window.
        let mut window = vec![0i32; seq];
        let tail = tokens.len().min(seq);
        window[seq - tail..].copy_from_slice(&tokens[tokens.len() - tail..]);
        let toks = runtime::literal_i32(&[1, seq], &window)?;
        let mut gargs: Vec<&xla::Literal> = lits.iter().collect();
        gargs.push(&toks);
        let outs = graph.run(&gargs)?;
        let logits = runtime::tensor_from_literal(&outs[0])?;
        let next = logits.data.iter().enumerate()
            .max_by(|a, b| a.1.partial_cmp(b.1).unwrap())
            .map(|(i, _)| i as i32).unwrap();
        tokens.push(next);
    }
    Ok(data.bpe.decode(&tokens.iter().map(|&t| t as u32).collect::<Vec<_>>()))
}

fn print_suite_table(results: &coordinator::SuiteResults) {
    println!("\n{:<16} {:>10} {:>12} {:>9} {:>9} {:>9}",
             "model", "params", "bits", "train", "val_nll", "cloze");
    for r in &results.records {
        let cloze = r.tasks.iter().find(|t| t.task == "cloze")
            .map(|t| format!("{:.3}", t.acc)).unwrap_or_default();
        println!("{:<16} {:>10} {:>12.3e} {:>9.4} {:>9.4} {:>9}",
                 r.name, r.n_params, r.size_bits, r.final_train_loss,
                 r.val_nll, cloze);
    }
}

fn print_scaling(rep: &analysis::ScalingReport) {
    println!("\nScaling fits  L(N) = A/N^alpha + eps   (Eq. 1 analog)");
    for (label, fit) in [("TriLM", &rep.trilm_offset),
                         ("FloatLM", &rep.floatlm_offset)] {
        println!("  {label:<8} A={:<8.3} alpha={:<6.3} eps={:<6.3} rss={:.2e}",
                 fit.a, fit.alpha, fit.eps, fit.rss);
    }
    println!("  gap extrapolation (Fig. 10 analog):");
    for (n, gap) in rep.gap_curve.iter().step_by(8) {
        println!("    N = {n:>12.3e}: TriLM {gap:+.2}% vs FloatLM");
    }
}

fn print_deploy(output: &str) {
    match output {
        "4" => {
            println!("Table 4: sizes in bits (x1e9)");
            print!("{:<16}", "family");
            for row in deploy::PAPER_SUITE.iter() {
                print!("{:>8}", row.label);
            }
            println!();
            for row in deploy::table4() {
                print!("{:<16}", row.family);
                for v in row.sizes_gbits {
                    print!("{v:>8.2}");
                }
                println!();
            }
        }
        "2a" => {
            println!("Fig 2a: model size (GB) vs params");
            println!("{:>12} {:>10} {:>10} {:>10}",
                     "params", "FloatLM", "QuantLM4", "TriLM");
            for r in deploy::fig2_series().iter().step_by(3) {
                println!("{:>12.3e} {:>10.1} {:>10.1} {:>10.1}",
                         r.params, r.float_gb, r.quant4_gb, r.trilm_gb);
            }
            for (gpu, mem) in [("H100", 80.0), ("MI300X", 192.0)] {
                println!("max params on one {gpu} ({mem} GB): \
                          FloatLM {:.2e}, QuantLM4 {:.2e}, TriLM {:.2e}",
                         deploy::max_params_fitting(mem, deploy::SizeFamily::Float),
                         deploy::max_params_fitting(
                             mem, deploy::SizeFamily::Quant { bits: 4, group: 128 }),
                         deploy::max_params_fitting(mem, deploy::SizeFamily::Ternary));
            }
        }
        "2b" => {
            println!("Fig 2b: theoretical max decode speedup vs FP16");
            println!("{:>12} {:>10} {:>10}", "params", "QuantLM4", "TriLM");
            for r in deploy::fig2_series().iter().step_by(3) {
                println!("{:>12.3e} {:>10.2} {:>10.2}",
                         r.params, r.quant4_speedup, r.trilm_speedup);
            }
        }
        "21" => {
            println!("Fig 21a: memory (GB) per TFLOP trends");
            for f in deploy::memory_per_tflop_trend() {
                println!("  {:?}: slope {:+.4}/yr  points {:?}",
                         f.vendor, f.slope, f.points);
            }
            println!("Fig 21b: bandwidth (GB/s) per TFLOP trends");
            for f in deploy::bandwidth_per_tflop_trend() {
                println!("  {:?}: slope {:+.4}/yr", f.vendor, f.slope);
            }
        }
        other => println!("unknown deploy output '{other}' (use 4|2a|2b|21)"),
    }
}

fn bench_report(res: &coordinator::SuiteResults, experiment: &str) {
    let all = experiment == "all";
    if all || experiment == "fig1" {
        section("Fig 1 / Tables 6-7 analog: C&R (pattern_mcq) + LAMBADA \
                 (cloze) by size & family");
        table_by_task(res, &["pattern_mcq", "cloze"]);
    }
    if all || experiment == "fig9" {
        section("Fig 9 analog: final val loss across size (bits) and params");
        println!("{:<16} {:>10} {:>12} {:>9}", "model", "params", "bits",
                 "val_nll");
        for r in &res.records {
            println!("{:<16} {:>10} {:>12.3e} {:>9.4}",
                     r.name, r.n_params, r.size_bits, r.val_nll);
        }
    }
    if all || experiment == "fig11" {
        section("Figs 11-12 / Tables 9,13 analog: knowledge tasks");
        table_by_task(res, &["fact_mcq", "fact_recall"]);
    }
    if all || experiment == "fig13" {
        section("Fig 13 analog: cross-domain NLL");
        for r in &res.records {
            let doms: Vec<String> = r.domain_nll.iter()
                .map(|(d, v)| format!("{d} {v:.3}")).collect();
            println!("{:<16} {}", r.name, doms.join("  "));
        }
    }
    if all || experiment == "toxicity" {
        section("Table 12 analog: stereotype preference (CrowS-Pairs-like)");
        table_by_task(res, &["stereo_pairs"]);
    }
}

fn section(title: &str) {
    println!("\n=== {title} ===");
}

fn table_by_task(res: &coordinator::SuiteResults, tasks: &[&str]) {
    print!("{:<16} {:>10} {:>12}", "model", "params", "bits");
    for t in tasks {
        print!(" {t:>12}");
    }
    println!();
    for r in &res.records {
        print!("{:<16} {:>10} {:>12.3e}", r.name, r.n_params, r.size_bits);
        for t in tasks {
            let s = r.tasks.iter().find(|x| x.task == *t)
                .map(|x| format!("{:.3}", x.acc)).unwrap_or_default();
            print!(" {s:>12}");
        }
        println!();
    }
}
