//! The artifact manifest: the calling convention shared with
//! `python/compile/aot.py`. Parameter order, graph files, and I/O specs
//! are all defined by `artifacts/manifest.json`.

use std::collections::BTreeMap;
use std::path::Path;

use crate::util::Json;
use crate::Result;

#[derive(Debug, Clone)]
pub struct Manifest {
    pub seq: usize,
    pub train_batch: usize,
    pub eval_batch: usize,
    pub capture_batch: usize,
    pub models: BTreeMap<String, ModelEntry>,
}

#[derive(Debug, Clone)]
pub struct ModelEntry {
    pub size: String,
    pub family: String,
    pub config: ConfigSpec,
    pub n_params: usize,
    pub params: Vec<ParamSpec>,
    pub graphs: BTreeMap<String, GraphSpec>,
}

#[derive(Debug, Clone)]
pub struct ConfigSpec {
    pub vocab: usize,
    pub hidden: usize,
    pub glu: usize,
    pub heads: usize,
    pub layers: usize,
    pub seq: usize,
    pub mp: usize,
    pub family: String,
}

#[derive(Debug, Clone)]
pub struct ParamSpec {
    pub name: String,
    pub shape: Vec<usize>,
}

#[derive(Debug, Clone)]
pub struct GraphSpec {
    pub file: String,
    pub inputs: Vec<IoSpec>,
    pub outputs: Vec<IoSpec>,
}

#[derive(Debug, Clone)]
pub struct IoSpec {
    pub shape: Vec<usize>,
    pub dtype: String,
}

fn io_spec(j: &Json) -> Result<IoSpec> {
    Ok(IoSpec {
        shape: j.get("shape")?.as_usize_vec()?,
        dtype: j.get("dtype")?.as_str()?.to_string(),
    })
}

impl Manifest {
    pub fn parse(text: &str) -> Result<Self> {
        let j = Json::parse(text)?;
        let mut models = BTreeMap::new();
        for (name, m) in j.get("models")?.as_obj()? {
            let cfg = m.get("config")?;
            let config = ConfigSpec {
                vocab: cfg.get("vocab")?.as_usize()?,
                hidden: cfg.get("hidden")?.as_usize()?,
                glu: cfg.get("glu")?.as_usize()?,
                heads: cfg.get("heads")?.as_usize()?,
                layers: cfg.get("layers")?.as_usize()?,
                seq: cfg.get("seq")?.as_usize()?,
                mp: cfg.get("mp")?.as_usize()?,
                family: cfg.get("family")?.as_str()?.to_string(),
            };
            let params = m.get("params")?.as_arr()?.iter().map(|p| {
                Ok(ParamSpec {
                    name: p.get("name")?.as_str()?.to_string(),
                    shape: p.get("shape")?.as_usize_vec()?,
                })
            }).collect::<Result<Vec<_>>>()?;
            let mut graphs = BTreeMap::new();
            for (gname, g) in m.get("graphs")?.as_obj()? {
                graphs.insert(gname.clone(), GraphSpec {
                    file: g.get("file")?.as_str()?.to_string(),
                    inputs: g.get("inputs")?.as_arr()?.iter()
                        .map(io_spec).collect::<Result<Vec<_>>>()?,
                    outputs: g.get("outputs")?.as_arr()?.iter()
                        .map(io_spec).collect::<Result<Vec<_>>>()?,
                });
            }
            models.insert(name.clone(), ModelEntry {
                size: m.get("size")?.as_str()?.to_string(),
                family: m.get("family")?.as_str()?.to_string(),
                config,
                n_params: m.get("n_params")?.as_usize()?,
                params,
                graphs,
            });
        }
        Ok(Manifest {
            seq: j.get("seq")?.as_usize()?,
            train_batch: j.get("train_batch")?.as_usize()?,
            eval_batch: j.get("eval_batch")?.as_usize()?,
            capture_batch: j.get("capture_batch")?.as_usize()?,
            models,
        })
    }

    pub fn load(path: &Path) -> Result<Self> {
        let text = std::fs::read_to_string(path)
            .map_err(|e| anyhow::anyhow!("reading {}: {e} (run `make artifacts`)",
                                         path.display()))?;
        Self::parse(&text)
    }

    pub fn model(&self, name: &str) -> Result<&ModelEntry> {
        self.models.get(name).ok_or_else(|| {
            anyhow::anyhow!("model '{name}' not in manifest (have: {:?})",
                            self.models.keys().collect::<Vec<_>>())
        })
    }
}

impl ModelEntry {
    pub fn graph(&self, name: &str) -> Result<&GraphSpec> {
        self.graphs.get(name).ok_or_else(|| {
            anyhow::anyhow!("graph '{name}' not lowered for this model \
                             (have: {:?})", self.graphs.keys().collect::<Vec<_>>())
        })
    }

    /// Number of flat parameter arrays P (train graphs take 3P + 5 inputs).
    pub fn n_param_arrays(&self) -> usize {
        self.params.len()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_manifest_shape() {
        let json = r#"{
            "seq": 128, "train_batch": 8, "eval_batch": 8,
            "capture_batch": 4,
            "adam": {"b1": 0.9, "b2": 0.95, "eps": 1e-8},
            "models": {
                "160k_float": {
                    "size": "160k", "family": "float",
                    "config": {"vocab": 512, "hidden": 64, "glu": 160,
                               "heads": 1, "layers": 2, "seq": 128,
                               "mp": 1, "family": "float"},
                    "n_params": 160064,
                    "params": [{"name": "embed", "shape": [512, 64]}],
                    "graphs": {"train": {"file": "x.hlo.txt",
                                          "inputs": [{"shape": [2], "dtype": "f32"}],
                                          "outputs": []}}
                }
            }
        }"#;
        let m = Manifest::parse(json).unwrap();
        let entry = &m.models["160k_float"];
        assert_eq!(entry.params[0].name, "embed");
        assert_eq!(entry.params[0].shape, vec![512, 64]);
        assert_eq!(entry.config.hidden, 64);
        assert_eq!(entry.graphs["train"].inputs[0].dtype, "f32");
        assert!(entry.graph("train").is_ok());
        assert!(entry.graph("missing").is_err());
    }

    #[test]
    fn real_manifest_parses_if_present() {
        let path = Path::new("artifacts/manifest.json");
        if path.exists() {
            let m = Manifest::load(path).unwrap();
            assert!(m.models.len() >= 4);
            for entry in m.models.values() {
                assert!(entry.n_param_arrays() > 0);
                assert!(entry.graphs.contains_key("eval"));
            }
        }
    }
}
