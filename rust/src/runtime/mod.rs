//! PJRT runtime: load AOT-compiled HLO-text artifacts and execute them.
//!
//! This is the only bridge between the Rust coordinator and the
//! JAX/Pallas layers: `python/compile/aot.py` lowers every graph once to
//! `artifacts/*.hlo.txt`; this module compiles them on the PJRT CPU
//! client and runs them with concrete inputs. HLO *text* is the
//! interchange format (jax>=0.5 protos use 64-bit instruction ids that
//! xla_extension 0.5.1 rejects; the text parser reassigns ids).
//!
//! The [`pool`] submodule is the *CPU* execution substrate: the
//! persistent [`WorkerPool`] and reusable [`DecodeScratch`] the serve
//! engine's allocation-free decode hot path runs on.

pub mod manifest;
pub mod pool;
pub mod tensor;

use std::collections::HashMap;
use std::path::{Path, PathBuf};

pub use manifest::{GraphSpec, Manifest, ModelEntry};
pub use pool::{DecodeScratch, WorkerPool};
pub use tensor::{HostTensor, SplitMix64};

use crate::Result;

/// A wrapper over the PJRT CPU client plus the artifact manifest.
pub struct Runtime {
    client: xla::PjRtClient,
    dir: PathBuf,
    manifest: Manifest,
}

/// A compiled executable plus its manifest spec.
pub struct Graph {
    exe: xla::PjRtLoadedExecutable,
    client: xla::PjRtClient,
    pub spec: GraphSpec,
    pub name: String,
}

impl Runtime {
    /// Create a CPU PJRT client and load `artifacts/manifest.json`.
    pub fn new(artifacts_dir: impl AsRef<Path>) -> Result<Self> {
        let dir = artifacts_dir.as_ref().to_path_buf();
        let manifest = Manifest::load(&dir.join("manifest.json"))?;
        let client = xla::PjRtClient::cpu().map_err(wrap)?;
        Ok(Runtime { client, dir, manifest })
    }

    pub fn manifest(&self) -> &Manifest {
        &self.manifest
    }

    pub fn platform(&self) -> String {
        self.client.platform_name()
    }

    /// Load + compile one graph of one model (e.g. `("6.7m_ternary", "train")`).
    pub fn load_graph(&self, model: &str, graph: &str) -> Result<Graph> {
        let entry = self.manifest.model(model)?;
        let spec = entry.graph(graph)?.clone();
        let path = self.dir.join(&spec.file);
        let proto = xla::HloModuleProto::from_text_file(&path).map_err(wrap)?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self.client.compile(&comp).map_err(wrap)?;
        Ok(Graph { exe, client: self.client.clone(), spec,
                   name: format!("{model}/{graph}") })
    }
}

impl Graph {
    /// Execute with host literals; returns the flattened output tuple.
    ///
    /// Inputs are staged as self-managed `PjRtBuffer`s and executed via
    /// `execute_b`, NOT `execute(&[Literal])`: the crate's literal-based
    /// shim `release()`s the input buffers it creates without freeing
    /// them, leaking every argument on every call (fatal for a training
    /// loop — a suite run leaked ~36 GB before being OOM-killed).
    pub fn run(&self, args: &[&xla::Literal]) -> Result<Vec<xla::Literal>> {
        if !self.spec.inputs.is_empty() && args.len() != self.spec.inputs.len() {
            anyhow::bail!("{}: expected {} inputs, got {}", self.name,
                        self.spec.inputs.len(), args.len());
        }
        let bufs: Vec<xla::PjRtBuffer> = args.iter()
            .map(|lit| self.client.buffer_from_host_literal(None, lit))
            .collect::<std::result::Result<_, _>>().map_err(wrap)?;
        let outs = self.exe.execute_b::<xla::PjRtBuffer>(&bufs).map_err(wrap)?;
        drop(bufs);
        let lit = outs[0][0].to_literal_sync().map_err(wrap)?;
        // aot.py lowers with return_tuple=True: output is one tuple literal.
        lit.to_tuple().map_err(wrap).map_err(Into::into)
    }
}

fn wrap(e: xla::Error) -> anyhow::Error {
    anyhow::anyhow!("xla: {e}")
}

// ---------------------------------------------------------------------------
// Literal <-> host conversions
// ---------------------------------------------------------------------------

/// f32 literal with the given shape.
pub fn literal_f32(shape: &[usize], data: &[f32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(wrap).map_err(Into::into)
}

/// i32 literal with the given shape (token batches).
pub fn literal_i32(shape: &[usize], data: &[i32]) -> Result<xla::Literal> {
    let dims: Vec<i64> = shape.iter().map(|&d| d as i64).collect();
    xla::Literal::vec1(data).reshape(&dims).map_err(wrap).map_err(Into::into)
}

/// f32 scalar literal.
pub fn scalar_f32(x: f32) -> xla::Literal {
    xla::Literal::scalar(x)
}

pub fn literal_from_tensor(t: &HostTensor) -> Result<xla::Literal> {
    literal_f32(&t.shape, &t.data)
}

pub fn tensor_from_literal(lit: &xla::Literal) -> Result<HostTensor> {
    let shape = lit.array_shape().map_err(wrap)?;
    let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
    let data = lit.to_vec::<f32>().map_err(wrap)?;
    Ok(HostTensor::new(dims, data))
}

/// Extract the f32 scalar from a rank-0 literal.
pub fn scalar_from_literal(lit: &xla::Literal) -> Result<f32> {
    lit.get_first_element::<f32>().map_err(wrap).map_err(Into::into)
}

/// The full model state threaded through a train graph:
/// params, first and second Adam moments, and the step counter.
pub struct TrainState {
    pub params: Vec<xla::Literal>,
    pub m: Vec<xla::Literal>,
    pub v: Vec<xla::Literal>,
    pub step: xla::Literal,
}

impl TrainState {
    /// Fresh state: params from host tensors, zeroed moments, step 0.
    pub fn init(params: &[HostTensor]) -> Result<Self> {
        let p = params.iter().map(literal_from_tensor).collect::<Result<Vec<_>>>()?;
        let zeros = |t: &HostTensor| literal_f32(&t.shape, &vec![0.0; t.len()]);
        let m = params.iter().map(zeros).collect::<Result<Vec<_>>>()?;
        let v = params.iter().map(zeros).collect::<Result<Vec<_>>>()?;
        Ok(TrainState { params: p, m, v, step: scalar_f32(0.0) })
    }

    /// Copy params back to host tensors (checkpointing, analysis, GPTQ).
    pub fn params_to_host(&self) -> Result<Vec<HostTensor>> {
        self.params.iter().map(tensor_from_literal).collect()
    }
}

/// Initialize parameters host-side following the python init recipe
/// (normal(0, 0.02), residual-out projections scaled by 1/sqrt(2L),
/// norms at 1). The RNG stream differs from jax's; the *distribution*
/// is what matters for training from scratch in Rust.
pub fn init_params_like(entry: &ModelEntry, seed: u64) -> Vec<HostTensor> {
    let layers = entry.config.layers as f32;
    let resid_scale = 1.0 / (2.0 * layers).sqrt();
    entry.params.iter().enumerate().map(|(i, p)| {
        if p.name.ends_with("norm") {
            HostTensor::new(p.shape.clone(), vec![1.0; p.shape.iter().product()])
        } else {
            let std = if p.name.ends_with("attn_o") || p.name.ends_with("mlp_down") {
                0.02 * resid_scale
            } else {
                0.02
            };
            HostTensor::randn(p.shape.clone(), std, seed ^ ((i as u64) << 32))
        }
    }).collect()
}

/// Name -> host tensor map helper used by GPTQ / analysis code.
pub fn params_by_name(entry: &ModelEntry, params: &[HostTensor])
                      -> HashMap<String, HostTensor> {
    entry.params.iter().zip(params.iter())
        .map(|(spec, t)| (spec.name.clone(), t.clone()))
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_f32() {
        let t = HostTensor::new(vec![2, 3], vec![1., 2., 3., 4., 5., 6.]);
        let lit = literal_from_tensor(&t).unwrap();
        let back = tensor_from_literal(&lit).unwrap();
        assert_eq!(back, t);
    }

    #[test]
    fn literal_i32_shape() {
        let lit = literal_i32(&[2, 2], &[1, 2, 3, 4]).unwrap();
        assert_eq!(lit.element_count(), 4);
    }

    #[test]
    fn scalar_roundtrip() {
        let lit = scalar_f32(3.5);
        assert_eq!(scalar_from_literal(&lit).unwrap(), 3.5);
    }
}
