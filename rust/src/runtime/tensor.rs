//! Host-side tensors: the plain-Rust counterpart of a device `Literal`.
//!
//! Everything that is not a PJRT execution (GPTQ, entropy analysis,
//! checkpointing, packing) works on [`HostTensor`]s.


/// A dense f32 tensor on the host, row-major.
#[derive(Debug, Clone, PartialEq)]
pub struct HostTensor {
    pub shape: Vec<usize>,
    pub data: Vec<f32>,
}

impl HostTensor {
    pub fn new(shape: Vec<usize>, data: Vec<f32>) -> Self {
        assert_eq!(shape.iter().product::<usize>(), data.len(),
                   "shape {shape:?} != data len {}", data.len());
        HostTensor { shape, data }
    }

    pub fn zeros(shape: Vec<usize>) -> Self {
        let n = shape.iter().product();
        HostTensor { shape, data: vec![0.0; n] }
    }

    /// Seeded He-style normal init (matches magnitude, not RNG stream,
    /// of the python init — real training always starts from python-
    /// initialized params loaded from a checkpoint or from `init_like`).
    pub fn randn(shape: Vec<usize>, std: f32, seed: u64) -> Self {
        let n = shape.iter().product();
        let mut rng = SplitMix64::new(seed);
        let data = (0..n).map(|_| std * rng.next_gaussian() as f32).collect();
        HostTensor { shape, data }
    }

    pub fn len(&self) -> usize {
        self.data.len()
    }

    pub fn is_empty(&self) -> bool {
        self.data.is_empty()
    }

    /// Rows/cols of a 2-D tensor.
    pub fn dims2(&self) -> (usize, usize) {
        assert_eq!(self.shape.len(), 2, "expected 2-D, got {:?}", self.shape);
        (self.shape[0], self.shape[1])
    }

    pub fn at2(&self, r: usize, c: usize) -> f32 {
        self.data[r * self.shape[1] + c]
    }

    pub fn row(&self, r: usize) -> &[f32] {
        let (_, cols) = self.dims2();
        &self.data[r * cols..(r + 1) * cols]
    }

    pub fn row_mut(&mut self, r: usize) -> &mut [f32] {
        let (_, cols) = self.dims2();
        &mut self.data[r * cols..(r + 1) * cols]
    }

    /// Contiguous view of rows `[r0, r1)` of a 2-D tensor (batched
    /// window over row-major storage).
    pub fn rows_range(&self, r0: usize, r1: usize) -> &[f32] {
        let (rows, cols) = self.dims2();
        assert!(r0 <= r1 && r1 <= rows, "rows [{r0}, {r1}) out of 0..{rows}");
        &self.data[r0 * cols..r1 * cols]
    }

    /// Reshape to (rows, cols) in place, reusing the allocation. The
    /// contents are unspecified afterwards — callers must overwrite
    /// every element. This is the reuse primitive behind
    /// [`crate::runtime::DecodeScratch`]: steady-state decode steps
    /// resize within capacity instead of allocating fresh tensors.
    pub fn reset2(&mut self, rows: usize, cols: usize) {
        self.shape.clear();
        self.shape.push(rows);
        self.shape.push(cols);
        self.data.resize(rows * cols, 0.0);
    }

    /// Stack equal-length row slices into a (len, cols) batch tensor.
    pub fn stack_rows(rows: &[&[f32]]) -> HostTensor {
        assert!(!rows.is_empty(), "cannot stack zero rows");
        let cols = rows[0].len();
        let mut data = Vec::with_capacity(rows.len() * cols);
        for (i, r) in rows.iter().enumerate() {
            assert_eq!(r.len(), cols, "row {i} length {} != {cols}", r.len());
            data.extend_from_slice(r);
        }
        HostTensor::new(vec![rows.len(), cols], data)
    }
}

/// SplitMix64 — tiny deterministic RNG used wherever reproducibility
/// across runs matters more than statistical sophistication.
#[derive(Debug, Clone)]
pub struct SplitMix64 {
    state: u64,
    spare: Option<f64>,
}

impl SplitMix64 {
    pub fn new(seed: u64) -> Self {
        SplitMix64 { state: seed, spare: None }
    }

    pub fn next_u64(&mut self) -> u64 {
        self.state = self.state.wrapping_add(0x9E3779B97F4A7C15);
        let mut z = self.state;
        z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
        z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
        z ^ (z >> 31)
    }

    /// Uniform in [0, 1).
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform integer in [0, n).
    pub fn below(&mut self, n: usize) -> usize {
        (self.next_u64() % n.max(1) as u64) as usize
    }

    /// Standard normal via Box–Muller.
    pub fn next_gaussian(&mut self) -> f64 {
        if let Some(s) = self.spare.take() {
            return s;
        }
        let (u1, u2) = (self.next_f64().max(1e-12), self.next_f64());
        let r = (-2.0 * u1.ln()).sqrt();
        let theta = 2.0 * std::f64::consts::PI * u2;
        self.spare = Some(r * theta.sin());
        r * theta.cos()
    }

    /// Fisher–Yates shuffle of indices 0..n.
    pub fn permutation(&mut self, n: usize) -> Vec<usize> {
        let mut idx: Vec<usize> = (0..n).collect();
        for i in (1..n).rev() {
            idx.swap(i, self.below(i + 1));
        }
        idx
    }

    /// Sample an index from unnormalized weights.
    pub fn weighted(&mut self, weights: &[f64]) -> usize {
        let total: f64 = weights.iter().sum();
        let mut x = self.next_f64() * total;
        for (i, w) in weights.iter().enumerate() {
            x -= w;
            if x <= 0.0 {
                return i;
            }
        }
        weights.len() - 1
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn tensor_shape_checks() {
        let t = HostTensor::new(vec![2, 3], vec![0.0; 6]);
        assert_eq!(t.dims2(), (2, 3));
        assert_eq!(t.row(1).len(), 3);
    }

    #[test]
    #[should_panic]
    fn tensor_shape_mismatch_panics() {
        HostTensor::new(vec![2, 3], vec![0.0; 5]);
    }

    #[test]
    fn batched_views() {
        let mut t = HostTensor::new(vec![3, 2], vec![1., 2., 3., 4., 5., 6.]);
        assert_eq!(t.rows_range(1, 3), &[3., 4., 5., 6.]);
        assert_eq!(t.rows_range(1, 1), &[] as &[f32]);
        t.row_mut(0)[1] = 9.0;
        assert_eq!(t.row(0), &[1., 9.]);
    }

    #[test]
    fn reset2_reuses_allocation() {
        let mut t = HostTensor::new(vec![4, 3], vec![1.0; 12]);
        let cap = t.data.capacity();
        t.reset2(2, 3); // shrink within capacity: no realloc
        assert_eq!(t.dims2(), (2, 3));
        assert_eq!(t.data.capacity(), cap);
        t.reset2(4, 3); // grow back within original capacity
        assert_eq!(t.dims2(), (4, 3));
        assert_eq!(t.len(), 12);
        assert_eq!(t.data.capacity(), cap);
    }

    #[test]
    fn stack_rows_builds_batch() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32, 4.0];
        let s = HostTensor::stack_rows(&[&a, &b]);
        assert_eq!(s.shape, vec![2, 2]);
        assert_eq!(s.data, vec![1., 2., 3., 4.]);
    }

    #[test]
    #[should_panic(expected = "length")]
    fn stack_rows_rejects_ragged() {
        let a = [1.0f32, 2.0];
        let b = [3.0f32];
        HostTensor::stack_rows(&[&a, &b]);
    }

    #[test]
    fn splitmix_is_deterministic() {
        let mut a = SplitMix64::new(42);
        let mut b = SplitMix64::new(42);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn gaussian_moments() {
        let mut rng = SplitMix64::new(7);
        let n = 20_000;
        let xs: Vec<f64> = (0..n).map(|_| rng.next_gaussian()).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean) * (x - mean)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.03, "mean {mean}");
        assert!((var - 1.0).abs() < 0.05, "var {var}");
    }

    #[test]
    fn permutation_is_a_permutation() {
        let mut rng = SplitMix64::new(3);
        let mut p = rng.permutation(100);
        p.sort_unstable();
        assert_eq!(p, (0..100).collect::<Vec<_>>());
    }

    #[test]
    fn weighted_respects_weights() {
        let mut rng = SplitMix64::new(9);
        let mut counts = [0usize; 3];
        for _ in 0..10_000 {
            counts[rng.weighted(&[1.0, 0.0, 3.0])] += 1;
        }
        assert_eq!(counts[1], 0);
        assert!(counts[2] > 2 * counts[0]);
    }
}
