//! Persistent worker pool + reusable decode workspace: the serving
//! engine's execution substrate.
//!
//! The paper's §2.1 decode-speedup claim is a *bandwidth* story, and it
//! only survives measurement if the runtime does not burn the saved
//! bytes on per-step overhead. The first serving iteration spawned a
//! fresh `std::thread::scope` inside every blocked matmul — several
//! spawn/join cycles per layer per decode step — and allocated fresh
//! output tensors and transposed scratch on every call. This module
//! provides the two pieces that remove that overhead:
//!
//! - [`WorkerPool`] — long-lived worker threads with condvar job
//!   dispatch. [`WorkerPool::scope`] runs a borrowed parallel-for body
//!   (`Fn(usize)`) across the workers *and* the calling thread, and
//!   does not return until every job index has completed, so borrowed
//!   data stays valid exactly as it would under `std::thread::scope`.
//!   Work items are claimed dynamically, but the *partitioning* of rows
//!   into items is computed by the caller with the same arithmetic as
//!   the scoped-thread driver, and every item writes a disjoint output
//!   slab — results are therefore bitwise identical to scoped-thread
//!   execution at every thread count (`tests/pool_equivalence.rs`).
//! - [`DecodeScratch`] — the per-scheduler workspace: the transposed
//!   accumulation slab shared by the blocked drivers plus every
//!   activation buffer of the serve model's forward pass (residual
//!   stream, norms, GLU halves, logits, and the attention model's
//!   q/k/v/attention-mix buffers and score vector). One scratch
//!   lives as long as its [`crate::serve::Scheduler`]; buffers are
//!   reshaped in place ([`HostTensor::reset2`]) and only grow.
//!
//! Scratch-reuse contract (what `tests/pool_equivalence.rs` enforces):
//! every `_into` entry point fully overwrites the scratch regions it
//! hands back, so contents left by a previous call of *any* shape or
//! family can never leak into results — one scratch is shared across
//! every model and step a scheduler ever runs.
//!
//! Ownership contract: the *caller* owns pool and scratch and threads
//! `&WorkerPool` / `&mut DecodeScratch` down the hot path
//! (`Scheduler::step` -> `DecodeModel::step_batch_into` ->
//! `LinearFormat::matmul_batch_into` -> the pooled blocked drivers).
//! Per-worker panel scratch (the transposed x panels, quant decode
//! buffers) is thread-local inside the kernel modules — workers are
//! long-lived, so those buffers also persist across decode steps.
//!
//! `threads = 1` (or 0 resolving to 1) spawns no workers at all:
//! `scope` runs every job inline on the caller, the exact fallback the
//! scoped driver had.

use std::sync::{Arc, Condvar, Mutex, MutexGuard};

use super::tensor::HostTensor;

/// A type-erased pointer to the current parallel-for body. The 'static
/// lifetime is a lie told only inside [`WorkerPool::scope`], which does
/// not return until every job finished — the same soundness argument
/// `std::thread::scope` makes.
#[derive(Clone, Copy)]
struct JobPtr(*const (dyn Fn(usize) + Sync + 'static));

// SAFETY: the pointee is `Sync` (shared-callable from any thread) and
// `scope` keeps it alive for the whole dispatch window.
unsafe impl Send for JobPtr {}

struct PoolState {
    /// Body of the in-flight `scope` call, if any.
    job: Option<JobPtr>,
    /// Total job indices of the in-flight call.
    n_jobs: usize,
    /// Next unclaimed job index.
    next_idx: usize,
    /// Claimed-or-unclaimed jobs not yet completed.
    unfinished: usize,
    /// A job body panicked; re-raised on the calling thread.
    panicked: bool,
    shutdown: bool,
}

struct Shared {
    state: Mutex<PoolState>,
    /// Workers wait here for a new task (or shutdown).
    work_cv: Condvar,
    /// The caller waits here for task completion.
    done_cv: Condvar,
}

impl Shared {
    /// Poisoning is ignored on purpose: a panicking job is reported via
    /// `PoolState::panicked` and re-raised by `scope`; the pool itself
    /// stays usable.
    fn lock(&self) -> MutexGuard<'_, PoolState> {
        self.state.lock().unwrap_or_else(|e| e.into_inner())
    }
}

/// Decrements `unfinished` when a job body returns *or unwinds*, so a
/// panicking kernel can never leave `scope` (or its workers) waiting
/// forever.
struct DoneGuard<'a> {
    shared: &'a Shared,
}

impl Drop for DoneGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        if std::thread::panicking() {
            st.panicked = true;
        }
        st.unfinished -= 1;
        if st.unfinished == 0 {
            self.shared.done_cv.notify_all();
        }
    }
}

fn run_job(shared: &Shared, job: JobPtr, idx: usize) {
    let _guard = DoneGuard { shared };
    // SAFETY: `scope` keeps the pointee alive until `unfinished == 0`,
    // and `_guard` only decrements after this call returns or unwinds.
    unsafe { (&*job.0)(idx) };
}

fn worker_loop(shared: &Shared) {
    let mut st = shared.lock();
    loop {
        if st.shutdown {
            return;
        }
        if let Some(job) = st.job {
            if st.next_idx < st.n_jobs {
                let idx = st.next_idx;
                st.next_idx += 1;
                drop(st);
                // Contain a panicking job body: DoneGuard has already
                // recorded it (re-raised on the calling thread), and
                // swallowing the unwind here keeps this worker alive —
                // otherwise every job panic would silently shrink the
                // pool below its advertised `threads()` width.
                let _ = std::panic::catch_unwind(
                    std::panic::AssertUnwindSafe(|| {
                        run_job(shared, job, idx);
                    }));
                st = shared.lock();
                continue;
            }
        }
        st = shared.work_cv.wait(st).unwrap_or_else(|e| e.into_inner());
    }
}

/// Clears the finished task on exit from [`WorkerPool::scope`] — even
/// when the caller's own share of the work panicked — after waiting for
/// every outstanding job, so borrowed closures never outlive `scope`.
struct TaskGuard<'a> {
    shared: &'a Shared,
}

impl Drop for TaskGuard<'_> {
    fn drop(&mut self) {
        let mut st = self.shared.lock();
        while st.unfinished > 0 {
            st = self.shared.done_cv.wait(st)
                .unwrap_or_else(|e| e.into_inner());
        }
        st.job = None;
        st.n_jobs = 0;
        st.next_idx = 0;
        self.shared.done_cv.notify_all();
    }
}

/// A persistent pool of `threads - 1` worker threads (the caller is the
/// remaining executor). Created once per [`crate::serve::Scheduler`]
/// and reused for every matmul of every decode step.
pub struct WorkerPool {
    shared: Arc<Shared>,
    workers: Vec<std::thread::JoinHandle<()>>,
    threads: usize,
}

impl WorkerPool {
    /// `threads = 0` resolves to `std::thread::available_parallelism()`
    /// — the same convention the kernel `threads` hint always had.
    /// `threads = 1` spawns no workers (inline execution).
    pub fn new(threads: usize) -> WorkerPool {
        let threads = if threads == 0 {
            std::thread::available_parallelism().map(|t| t.get()).unwrap_or(1)
        } else {
            threads
        };
        let shared = Arc::new(Shared {
            state: Mutex::new(PoolState {
                job: None,
                n_jobs: 0,
                next_idx: 0,
                unfinished: 0,
                panicked: false,
                shutdown: false,
            }),
            work_cv: Condvar::new(),
            done_cv: Condvar::new(),
        });
        let workers = (1..threads).map(|i| {
            let shared = Arc::clone(&shared);
            std::thread::Builder::new()
                .name(format!("spectra-pool-{i}"))
                .spawn(move || worker_loop(&shared))
                .expect("spawn pool worker")
        }).collect();
        WorkerPool { shared, workers, threads }
    }

    /// The execution width: worker threads + the calling thread. This
    /// is the number the blocked drivers feed into their partitioning
    /// arithmetic, exactly where the scoped drivers used the `threads`
    /// hint — so pooled and scoped partitioning are identical.
    pub fn threads(&self) -> usize {
        self.threads
    }

    /// Run `body(0..n_jobs)` across the pool, blocking until every job
    /// index has completed. Jobs are claimed dynamically (any thread
    /// may run any index), so bodies must write disjoint data keyed by
    /// index — the blocked drivers' row slabs do exactly that. Panics
    /// in a body are re-raised here after all jobs settle.
    pub fn scope(&self, n_jobs: usize, body: &(dyn Fn(usize) + Sync)) {
        if n_jobs == 0 {
            return;
        }
        if self.workers.is_empty() {
            // threads = 1 fallback: pure inline execution, no locking.
            for idx in 0..n_jobs {
                body(idx);
            }
            return;
        }
        let raw: *const (dyn Fn(usize) + Sync + '_) = body;
        // SAFETY: only the trait-object lifetime is erased (fat-pointer
        // layout is unchanged); `TaskGuard` and the completion loop
        // below keep the pointee alive until every job has run.
        let job = JobPtr(unsafe { std::mem::transmute(raw) });
        let shared = &*self.shared;
        let mut st = shared.lock();
        // A previous task can only still be pending if its caller
        // panicked mid-scope on another thread; wait it out.
        while st.job.is_some() || st.unfinished > 0 {
            st = shared.done_cv.wait(st).unwrap_or_else(|e| e.into_inner());
        }
        st.job = Some(job);
        st.n_jobs = n_jobs;
        st.next_idx = 0;
        st.unfinished = n_jobs;
        // A prior scope that unwound out of its own job share leaves
        // the flag set after propagating its panic; a fresh task must
        // not inherit it.
        st.panicked = false;
        drop(st);
        shared.work_cv.notify_all();

        let guard = TaskGuard { shared };
        // The caller is executor #0: claim jobs alongside the workers.
        let mut st = shared.lock();
        loop {
            if st.next_idx < st.n_jobs {
                let idx = st.next_idx;
                st.next_idx += 1;
                drop(st);
                run_job(shared, job, idx);
                st = shared.lock();
            } else if st.unfinished > 0 {
                st = shared.done_cv.wait(st)
                    .unwrap_or_else(|e| e.into_inner());
            } else {
                break;
            }
        }
        let panicked = std::mem::replace(&mut st.panicked, false);
        drop(st);
        drop(guard);
        if panicked {
            panic!("WorkerPool: a pooled job panicked");
        }
    }
}

impl Drop for WorkerPool {
    fn drop(&mut self) {
        {
            let mut st = self.shared.lock();
            st.shutdown = true;
        }
        self.shared.work_cv.notify_all();
        for w in self.workers.drain(..) {
            let _ = w.join();
        }
    }
}

/// The reusable decode workspace: one per scheduler, threaded by `&mut`
/// through `step_batch_into` -> `matmul_batch_into`. Buffers are
/// reshaped in place each step and only ever grow, so a steady-state
/// decode step allocates nothing here (the scheduler's one remaining
/// per-step allocation is its batch-sized vector of lane-state
/// borrows, which cannot be cached across steps).
pub struct DecodeScratch {
    /// (n, m)-transposed accumulation slab shared by every pooled
    /// blocked driver call (gate/up/down/head reuse it in turn).
    pub out_t: Vec<f32>,
    /// (batch, hidden) residual-stream input (`gather_input_into`).
    pub x: HostTensor,
    /// (batch, hidden) RMS-normed activations (`rmsnorm_into`).
    pub norm: HostTensor,
    /// (batch, glu) gate projection, fused in place into the GLU
    /// activation.
    pub gate: HostTensor,
    /// (batch, glu) up projection.
    pub up: HostTensor,
    /// (batch, hidden) down projection (residual delta); the attention
    /// model also reuses it for the attention-out projection.
    pub down: HostTensor,
    /// (batch, vocab) output logits — the step's result lives here.
    pub logits: HostTensor,
    /// (batch, hidden) query projection (attention models only).
    pub q: HostTensor,
    /// (batch, hidden) key projection, appended to the KV cache.
    pub k: HostTensor,
    /// (batch, hidden) value projection, appended to the KV cache.
    pub v: HostTensor,
    /// (batch, hidden) per-lane attention mix softmax(q·k)·v — the
    /// input to the attention-out projection.
    pub attn: HostTensor,
    /// (batch, hidden + 2·kv_dim) fused QKV projection rows — q, k, v
    /// column stripes split by slicing (attention models only).
    pub qkv: HostTensor,
    /// (batch, 2·glu) fused gate/up projection rows (attention models
    /// only; gate stripe first).
    pub gateup: HostTensor,
    /// Per-part staging for [`crate::linear::FusedLinear`]'s pooled
    /// fused matmul: each part's kernel writes its (batch, part_out)
    /// result here before the copy into the fused stripe.
    pub fused_stage: HostTensor,
    /// Per-(lane, head) attention scores over the lane's cached
    /// positions; cleared and refilled per head, grows to the longest
    /// context served.
    pub scores: Vec<f32>,
    /// Lane -> KV-cache sequence bindings staged per step.
    pub seqs: Vec<usize>,
    /// Lane ordinals the model *rejected* on the current span step
    /// (KV-capacity backpressure; see
    /// [`crate::serve::model::DecodeModel::step_spans_into`]). Cleared
    /// by the model on entry, always sorted ascending; the scheduler
    /// reads it after the step to requeue refused lanes.
    pub rejected: Vec<usize>,
    /// Copy-on-write KV page copies the model performed on the current
    /// span step (shared-prefix divergence; attention models only).
    /// Cleared by the model on entry; the scheduler accumulates it
    /// into [`crate::serve::ServeStats::cow_copies`].
    pub cow_copies: usize,
    /// Accepted lanes' first claimed cache position this span step
    /// (attention models only).
    pub starts: Vec<usize>,
    /// Accepted lanes' span lengths this span step.
    pub spans: Vec<usize>,
    /// Accepted lanes' tokens, flattened in lane order, for this span
    /// step (rejected lanes' tokens are dropped from the batch).
    pub span_tokens: Vec<u32>,
    /// (lanes, hidden) gathered final-span-position activations that
    /// feed the output head on span steps — only each lane's last
    /// position needs logits, so the head never runs over whole
    /// prefill chunks.
    pub head_in: HostTensor,
    /// (lanes, vocab) staging for per-lane final logits while the
    /// default span driver iterates sub-steps (sequential-state
    /// models).
    pub sample_logits: HostTensor,
    /// (total span rows, vocab) per-*position* logits of the whole
    /// flattened span batch, filled only when [`Self::want_span_logits`]
    /// is set. Rows are lane-major and position-contiguous per accepted
    /// lane (lane 0's span, then lane 1's, ...), matching the span
    /// forward's row layout; rejected lanes contribute no rows.
    /// Speculative verification reads every proposal position's logits
    /// from here while `logits` keeps its usual final-row-per-lane
    /// contract.
    pub span_logits: HostTensor,
    /// Ask the next `step_spans_into` call to fill [`Self::span_logits`]
    /// (draft-verify lanes need logits at every span position, not just
    /// the last). Off by default: prefill chunks keep paying the head
    /// for one row per lane.
    pub want_span_logits: bool,
}

impl DecodeScratch {
    pub fn new() -> DecodeScratch {
        let empty = || HostTensor::zeros(vec![0, 0]);
        DecodeScratch {
            out_t: Vec::new(),
            x: empty(),
            norm: empty(),
            gate: empty(),
            up: empty(),
            down: empty(),
            logits: empty(),
            q: empty(),
            k: empty(),
            v: empty(),
            attn: empty(),
            qkv: empty(),
            gateup: empty(),
            fused_stage: empty(),
            scores: Vec::new(),
            seqs: Vec::new(),
            rejected: Vec::new(),
            cow_copies: 0,
            starts: Vec::new(),
            spans: Vec::new(),
            span_tokens: Vec::new(),
            head_in: empty(),
            sample_logits: empty(),
            span_logits: empty(),
            want_span_logits: false,
        }
    }
}

impl Default for DecodeScratch {
    fn default() -> Self {
        DecodeScratch::new()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicUsize, Ordering};

    #[test]
    fn every_job_index_runs_exactly_once() {
        let pool = WorkerPool::new(4);
        let hits: Vec<AtomicUsize> =
            (0..97).map(|_| AtomicUsize::new(0)).collect();
        pool.scope(97, &|i| {
            hits[i].fetch_add(1, Ordering::SeqCst);
        });
        for (i, h) in hits.iter().enumerate() {
            assert_eq!(h.load(Ordering::SeqCst), 1, "index {i}");
        }
    }

    #[test]
    fn pool_is_reusable_across_many_scopes() {
        // The whole point: one pool, many dispatches (a decode step
        // issues several matmuls; a serve run issues thousands).
        let pool = WorkerPool::new(3);
        let total = AtomicUsize::new(0);
        for round in 0..200 {
            let jobs = 1 + round % 7;
            pool.scope(jobs, &|i| {
                total.fetch_add(i + 1, Ordering::SeqCst);
            });
        }
        let want: usize = (0..200).map(|r| {
            let j = 1 + r % 7;
            j * (j + 1) / 2
        }).sum();
        assert_eq!(total.load(Ordering::SeqCst), want);
    }

    #[test]
    fn single_thread_pool_runs_inline() {
        let pool = WorkerPool::new(1);
        assert_eq!(pool.threads(), 1);
        let mut out = vec![0usize; 8];
        // With no workers, bodies run on the caller: &mut capture via
        // interior mutability is unnecessary for the pool's own test —
        // use a Mutex to keep the body Fn + Sync like real callers.
        let cells = Mutex::new(&mut out);
        pool.scope(8, &|i| {
            cells.lock().unwrap()[i] = i * i;
        });
        assert_eq!(out, vec![0, 1, 4, 9, 16, 25, 36, 49]);
    }

    #[test]
    fn zero_jobs_is_a_noop() {
        let pool = WorkerPool::new(2);
        pool.scope(0, &|_| panic!("no jobs should run"));
    }

    #[test]
    fn more_jobs_than_threads_all_complete() {
        let pool = WorkerPool::new(2);
        let count = AtomicUsize::new(0);
        pool.scope(64, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 64);
    }

    #[test]
    fn zero_threads_resolves_to_available_parallelism() {
        let pool = WorkerPool::new(0);
        assert!(pool.threads() >= 1);
        let count = AtomicUsize::new(0);
        pool.scope(5, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 5);
    }

    #[test]
    fn job_panic_is_reraised_and_pool_survives() {
        let pool = WorkerPool::new(2);
        let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
            pool.scope(4, &|i| {
                if i == 2 {
                    panic!("boom");
                }
            });
        }));
        assert!(res.is_err(), "panic must propagate to the caller");
        // The pool must still dispatch correctly afterwards.
        let count = AtomicUsize::new(0);
        pool.scope(6, &|_| {
            count.fetch_add(1, Ordering::SeqCst);
        });
        assert_eq!(count.load(Ordering::SeqCst), 6);
    }

    #[test]
    fn scratch_starts_empty() {
        let s = DecodeScratch::new();
        assert!(s.out_t.is_empty());
        assert_eq!(s.logits.len(), 0);
    }
}
