//! Family-complete batched decode serving: the ROADMAP's "heavy
//! traffic" path across every storage family the paper compares.
//!
//! The paper's headline comparison — FloatLM vs QuantLM vs TriLM at
//! matched bit budgets (§4.2, Table 4, Fig. 2) — and its §2.1 systems
//! claim (compressed weights turn memory-bound autoregressive decoding
//! into a bandwidth win, cf. Ma et al. 2409.17870, TernaryLLM
//! 2406.07177) both materialize here as one serving engine:
//!
//! - [`model`] — the family-generic [`model::SpectraLm`]`<L:`
//!   [`crate::linear::LinearFormat`]`>`: the same gated-MLP decode math
//!   over dense f32 ([`model::DenseLm`]), k-bit group-quantized
//!   bitstreams ([`model::QuantLm`], RTN or GPTQ), or packed 2-bit
//!   ternary ([`model::TernaryLm`]). [`model::LatentLm`] holds the
//!   family-agnostic f32 weights (synthetic or checkpoint) and realizes
//!   any [`model::FamilySpec`] from them, so every family serves the
//!   *same* model in a different storage format.
//! - [`scheduler`] — [`scheduler::Scheduler`]: admits N concurrent
//!   [`scheduler::GenRequest`]s, groups the live lanes' token *spans*
//!   into one flattened kernel step — a lane with unconsumed prompt
//!   feeds up to `prefill_chunk` tokens per step (chunked prefill,
//!   bitwise stream-invariant; TTFT drops from `prompt_len` to
//!   `ceil(prompt_len / chunk)` steps) — samples per lane (greedy /
//!   top-k), and retires finished sequences with mid-flight refill
//!   (continuous batching). KV-capacity exhaustion surfaces as
//!   per-lane rejection that the scheduler absorbs by deferring
//!   admission and requeueing refused lanes with their pages released
//!   (an overcommitted server queues; it never panics). It drives any
//!   [`model::DecodeModel`], family-blind.
//!   [`scheduler::Scheduler::step_observed`] adds an incremental
//!   per-token observer ([`scheduler::StreamEvent`]) — the hook the
//!   HTTP front end ([`crate::server`]) streams tokens through.
//!   Draft-verify speculative decoding
//!   ([`scheduler::Scheduler::set_speculative`] +
//!   [`scheduler::SpecConfig`]) rides the same span step: a cheap
//!   draft model (TriLM by default — the paper's bits-per-param win
//!   turned into a latency win) proposes k tokens per decode round,
//!   the target verifies them in one chunked pass and rolls the
//!   rejected tail back out of both KV caches
//!   ([`kvcache::KvCache::truncate_seq`]), bitwise-losslessly
//!   (`tests/speculative.rs`).
//! - [`kvcache`] + [`model::AttnLm`] — the paged KV-cache attention
//!   path: real pre-norm attention whose per-lane context lives in
//!   fixed-size token pages ([`kvcache::KvCache`], free-list
//!   allocated, recycled when a lane retires through
//!   [`model::DecodeModel::retire_state`]). The q/k/v and gate/up
//!   projections are row-stacked into fused matrices
//!   ([`crate::linear::FusedLinear`] — one kernel pass per fusion in
//!   every storage family), key/value heads may be shared across
//!   query-head groups (grouped-query attention,
//!   [`model::LatentAttnLm::with_kv_heads`]: `kv_bytes_per_token`
//!   shrinks by `heads/kv_heads`), and attention can be bounded to a
//!   sliding window with optional interleaved global layers
//!   ([`model::LatentAttnLm::with_window`]); when every layer is
//!   windowed, out-of-window pages are returned to the pool mid-flight
//!   ([`kvcache::KvCache::release_before`]), so long-context lanes
//!   plateau at the window bound instead of holding O(context). All
//!   four families serve with real attention and the KV-cache memory
//!   pressure production decoding actually has —
//!   [`model::DecodeModel::kv_bytes_per_token`] reports the per-token
//!   bandwidth tax ([`crate::deploy::decode_tokens_per_sec_bits_kv`]
//!   is the matching analytic roofline).
//!
//! Kernel tiling (see `ternary::matmul` and `linear::qmatmul`): weights
//! walk in [`crate::ternary::matmul::ROW_BLOCK`]-row blocks by
//! [`crate::ternary::matmul::COL_BLOCK_TRITS`]-element column panels
//! with the x panel transposed once per block (L1-resident at batch 8),
//! and w-rows are partitioned across the scheduler's persistent
//! [`crate::runtime::WorkerPool`] (dispatched, not spawned — see
//! `runtime::pool` for the execution substrate and the
//! [`crate::runtime::DecodeScratch`] buffer-reuse contract; the decode
//! hot path is allocation-free at steady state). Every format keeps
//! accumulation order batch- and thread-invariant, which is what makes
//! serving deterministic: the same request decodes to the same tokens
//! at any batch size, in any family (`tests/serve_determinism.rs`),
//! and pooled execution is bitwise identical to the scoped-thread
//! reference (`tests/pool_equivalence.rs`).
//!
//! Throughput: `benches/serve_throughput.rs` and `spectra serve-bench
//! --family float,quant3,quant4,ternary` report tokens/sec and
//! effective bits/param per family in one table — the paper's
//! bits-vs-throughput story measured on the serving path — and
//! `deploy::decode_tokens_per_sec_bits` gives the analytic roofline
//! keyed by each model's [`model::DecodeModel::effective_bits_per_param`].

pub mod faults;
pub mod kvcache;
pub mod model;
pub mod scheduler;

pub use faults::FaultPlan;
pub use kvcache::{KvCache, KvCacheConfig, OutOfPages, KV_PAGE_TOKENS};
pub use model::{AttnBlock, AttnLm, DecodeModel, DenseLm, FamilySpec,
                LatentAttnBlock, LatentAttnLm, LatentBlock, LatentLm,
                LmDims, QuantLm, QuantMethod, SpectraBlock, SpectraLm,
                TernaryLm};
pub use scheduler::{Completion, FinishReason, GenRequest, Sampling,
                    Scheduler, ServeStats, SpecConfig, StreamEvent,
                    TenantStats};

/// Deterministic corpus-shaped bench/demo traffic: prompt strings from
/// [`crate::eval::serve_prompts`] (the eval task generator's contexts,
/// cycling cloze/pattern/fact/stereo mixes), byte-mapped into the
/// model's vocab and truncated to 16 tokens so decode dominates
/// prefill. The single source of benchmark workload for both `spectra
/// serve-bench` and `benches/serve_throughput.rs`, so subcommand and
/// bench always measure the same traffic.
pub fn bench_requests(vocab: usize, n: usize, max_new_tokens: usize,
                      seed: u64) -> Vec<GenRequest> {
    let world = crate::data::World::new(seed);
    crate::eval::serve_prompts(&world, n, seed)
        .into_iter()
        .enumerate()
        .map(|(id, prompt)| {
            let toks: Vec<u32> = prompt.bytes().take(16)
                .map(|b| b as u32 % vocab as u32)
                .collect();
            GenRequest::greedy(id, toks, max_new_tokens)
        })
        .collect()
}

/// [`bench_requests`] with an explicit prompt length: every request's
/// prompt bytes are *cycled* to exactly `prompt_tokens` tokens, so the
/// traffic's prefill share is controlled precisely — the long-prompt
/// workload `serve-bench --prompt-tokens` uses to measure chunked
/// prefill throughput and TTFT (one-token prefill pays `prompt_tokens`
/// steps before the first sampled token; a chunk of c pays
/// `ceil(prompt_tokens / c)`).
pub fn bench_requests_sized(vocab: usize, n: usize, max_new_tokens: usize,
                            seed: u64, prompt_tokens: usize)
                            -> Vec<GenRequest> {
    let world = crate::data::World::new(seed);
    crate::eval::serve_prompts(&world, n, seed)
        .into_iter()
        .enumerate()
        .map(|(id, prompt)| {
            let toks: Vec<u32> = prompt.bytes().cycle()
                .take(prompt_tokens.max(1))
                .map(|b| b as u32 % vocab as u32)
                .collect();
            GenRequest::greedy(id, toks, max_new_tokens)
        })
        .collect()
}

/// [`bench_requests_sized`] with a *shared* prompt prefix: every
/// request's first `min(shared_prefix_tokens, prompt_tokens - 1)`
/// tokens are one fixed seeded sequence (the "system prompt" of the
/// workload), the rest are the request's own cycled prompt bytes — so
/// requests diverge after the shared region and the prefix-cache +
/// copy-on-write path is actually exercised. `shared_prefix_tokens =
/// 0` degrades to [`bench_requests_sized`] exactly. At least one
/// trailing token is always per-request, matching the serving
/// invariant that a lane feeds >= 1 prompt token.
pub fn bench_requests_shared(vocab: usize, n: usize, max_new_tokens: usize,
                             seed: u64, prompt_tokens: usize,
                             shared_prefix_tokens: usize)
                             -> Vec<GenRequest> {
    let prompt_tokens = prompt_tokens.max(1);
    let shared = shared_prefix_tokens.min(prompt_tokens - 1);
    if shared == 0 {
        return bench_requests_sized(vocab, n, max_new_tokens, seed,
                                    prompt_tokens);
    }
    let mut rng = crate::runtime::SplitMix64::new(seed ^ 0x5f3759df);
    let prefix: Vec<u32> = (0..shared)
        .map(|_| rng.next_u64() as u32 % vocab as u32)
        .collect();
    let world = crate::data::World::new(seed);
    crate::eval::serve_prompts(&world, n, seed)
        .into_iter()
        .enumerate()
        .map(|(id, prompt)| {
            let mut toks = prefix.clone();
            toks.extend(prompt.bytes().cycle()
                .take(prompt_tokens - shared)
                .map(|b| b as u32 % vocab as u32));
            GenRequest::greedy(id, toks, max_new_tokens)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_requests_are_deterministic_and_bounded() {
        let a = bench_requests(512, 10, 8, 3);
        let b = bench_requests(512, 10, 8, 3);
        assert_eq!(a.len(), 10);
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.id, i);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, 8);
            assert!(!x.prompt.is_empty() && x.prompt.len() <= 16);
            assert!(x.prompt.iter().all(|&t| t < 512));
        }
    }

    #[test]
    fn shared_bench_requests_share_exactly_the_prefix() {
        let a = bench_requests_shared(512, 6, 4, 3, 48, 32);
        let b = bench_requests_shared(512, 6, 4, 3, 48, 32);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.prompt, y.prompt, "shared traffic must be seeded");
            assert_eq!(x.prompt.len(), 48);
            assert_eq!(x.prompt[..32], a[0].prompt[..32],
                       "first 32 tokens must be the shared prefix");
        }
        // Tails diverge for at least one pair (requests are distinct).
        assert!(a.iter().any(|x| x.prompt[32..] != a[0].prompt[32..]),
                "per-request tails must diverge");
        // A shared prefix >= prompt length is capped to leave one
        // per-request token; 0 degrades to the sized generator.
        let capped = bench_requests_shared(512, 4, 4, 3, 16, 99);
        for x in &capped {
            assert_eq!(x.prompt.len(), 16);
            assert_eq!(x.prompt[..15], capped[0].prompt[..15]);
        }
        let zero = bench_requests_shared(512, 4, 4, 3, 16, 0);
        let sized = bench_requests_sized(512, 4, 4, 3, 16);
        for (x, y) in zero.iter().zip(sized.iter()) {
            assert_eq!(x.prompt, y.prompt,
                       "shared=0 must match the sized generator");
        }
    }

    #[test]
    fn sized_bench_requests_hit_exact_prompt_length() {
        let a = bench_requests_sized(512, 6, 4, 3, 48);
        let b = bench_requests_sized(512, 6, 4, 3, 48);
        assert_eq!(a.len(), 6);
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.prompt, y.prompt, "sized traffic must be seeded");
            assert_eq!(x.prompt.len(), 48,
                       "prompt bytes must cycle to the requested length");
            assert!(x.prompt.iter().all(|&t| t < 512));
        }
    }
}
