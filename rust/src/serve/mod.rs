//! Batched ternary decode serving: the ROADMAP's "heavy traffic" path.
//!
//! The paper's §2.1 systems claim — ternary weights turn memory-bound
//! autoregressive decoding into a bandwidth win — only materializes
//! under batched, blocked execution (cf. Ma et al. 2409.17870,
//! TernaryLLM 2406.07177). This subsystem builds that layer on CPU:
//!
//! - [`model`] — [`model::DecodeModel`]s executed per batched step:
//!   [`model::TernaryLm`] over packed 2-bit weights (the hot path) and
//!   its weight-identical dequantized twin [`model::DenseLm`] (the
//!   f32-storage baseline).
//! - [`scheduler`] — [`scheduler::Scheduler`]: admits N concurrent
//!   [`scheduler::GenRequest`]s, groups the live lanes into one
//!   (batch x hidden) kernel step, samples per lane (greedy / top-k),
//!   and retires finished sequences with mid-flight refill
//!   (continuous batching).
//!
//! Kernel tiling (see `ternary::matmul`): weights are walked in
//! [`crate::ternary::matmul::ROW_BLOCK`]-row blocks by
//! [`crate::ternary::matmul::COL_BLOCK_TRITS`]-trit column panels with
//! the x panel transposed once per block (L1-resident at batch 8), and
//! w-rows are partitioned across `std::thread` workers. Accumulation
//! order is batch- and thread-invariant, which is what makes serving
//! deterministic: the same request decodes to the same tokens at any
//! batch size (`tests/serve_determinism.rs`).
//!
//! Throughput: `benches/serve_throughput.rs` and the `spectra
//! serve-bench` subcommand report tokens/sec vs batch size and thread
//! count against the dense baseline; `deploy::decode_tokens_per_sec`
//! gives the analytic roofline the measurements are compared to.

pub mod model;
pub mod scheduler;

pub use model::{DecodeModel, DenseLm, LmDims, TernaryLm};
pub use scheduler::{Completion, GenRequest, Sampling, Scheduler, ServeStats};

/// Deterministic corpus-shaped bench/demo traffic: prompt strings from
/// [`crate::eval::serve_prompts`] (the eval task generator's contexts,
/// cycling cloze/pattern/fact/stereo mixes), byte-mapped into the
/// model's vocab and truncated to 16 tokens so decode dominates
/// prefill. The single source of benchmark workload for both `spectra
/// serve-bench` and `benches/serve_throughput.rs`, so subcommand and
/// bench always measure the same traffic.
pub fn bench_requests(vocab: usize, n: usize, max_new_tokens: usize,
                      seed: u64) -> Vec<GenRequest> {
    let world = crate::data::World::new(seed);
    crate::eval::serve_prompts(&world, n, seed)
        .into_iter()
        .enumerate()
        .map(|(id, prompt)| {
            let toks: Vec<u32> = prompt.bytes().take(16)
                .map(|b| b as u32 % vocab as u32)
                .collect();
            GenRequest::greedy(id, toks, max_new_tokens)
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn bench_requests_are_deterministic_and_bounded() {
        let a = bench_requests(512, 10, 8, 3);
        let b = bench_requests(512, 10, 8, 3);
        assert_eq!(a.len(), 10);
        for (i, (x, y)) in a.iter().zip(b.iter()).enumerate() {
            assert_eq!(x.id, i);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, 8);
            assert!(!x.prompt.is_empty() && x.prompt.len() <= 16);
            assert!(x.prompt.iter().all(|&t| t < 512));
        }
    }
}
