//! Family-generic CPU decode models driven by the serve scheduler.
//!
//! The PJRT transformer graphs remain the fidelity path for training
//! and evaluation; serving instead runs a compact gated-MLP language
//! model directly on packed CPU kernels, because that is the layer the
//! paper's §2.1 bandwidth argument lives in: per decode step every
//! linear is one batched (batch x in) @ (out x in)^T against
//! compressed weights. Long-range context is carried by a per-lane
//! exponential state (updated after each step) instead of a KV cache,
//! which keeps every lane's computation independent of its batch
//! neighbours — the property the scheduler's determinism guarantee
//! (batch-1 == batch-8 token streams) is built on.
//!
//! One model, every storage family: [`SpectraLm<L>`] is generic over
//! [`LinearFormat`], so the same decode math serves
//!
//! - [`DenseLm`] = `SpectraLm<DenseF32>` — f32 rows (FloatLM storage),
//! - [`QuantLm`] = `SpectraLm<QuantPacked>` — k-bit group-quantized
//!   bitstreams (QuantLM storage, RTN or GPTQ),
//! - [`TernaryLm`] = `SpectraLm<PackedMatrix>` — packed 2-bit trits
//!   (TriLM storage, the original hot path).
//!
//! [`LatentLm`] holds the family-agnostic f32 weights (synthetic or
//! from a checkpoint) and realizes any [`FamilySpec`] from them, so
//! cross-family benches compare storage formats of the *same* model —
//! the serving analog of the paper's matched-bit-budget comparison
//! (§4.2, Table 4).
//!
//! Two context mechanisms share the [`DecodeModel`] trait:
//!
//! - [`SpectraLm`] — the per-lane exponential decay state above: no
//!   attention, no per-token memory growth (the original serve model).
//! - [`AttnLm`] — real pre-norm multi-head attention with a block-paged
//!   [`KvCache`]: each lane binds a cache sequence on admission (the
//!   binding rides in the lane's state buffer, so the scheduler stays
//!   model-blind), appends one k/v per layer per step, and attends over
//!   its own positions only. Retired lanes release their pages through
//!   [`DecodeModel::retire_state`]. [`LatentAttnLm`] is the attention
//!   analog of [`LatentLm`], realizing all four storage families from
//!   one latent weight set.
//!
//! Both uphold the same scheduler contract: lane i's outputs depend
//! only on lane i's state/tokens, so token streams are identical at
//! any batch size, and the pooled `_into` path is bitwise identical to
//! the allocating path.

use std::collections::HashMap;
use std::sync::{Mutex, MutexGuard};

use super::kvcache::{KvCache, OutOfPages, KV_PAGE_TOKENS};
use crate::checkpoint::Checkpoint;
use crate::gptq::{gptq_quantize, GptqConfig, HessianAccumulator};
use crate::linear::{DenseF32, FusedLinear, LinearFormat, QuantPacked};
use crate::quant::QuantTensor;
use crate::runtime::{DecodeScratch, HostTensor, SplitMix64, WorkerPool};
use crate::ternary::{matmul_dense, PackedMatrix, TernaryTensor};
use crate::Result;

/// Architecture sizes of a decode model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LmDims {
    pub vocab: usize,
    pub hidden: usize,
    pub glu: usize,
    pub layers: usize,
}

/// Per-lane context state decay: `state' = DECAY*state + (1-DECAY)*x`.
pub const STATE_DECAY: f32 = 0.5;

const RMS_EPS: f32 = 1e-6;

/// Serve-side GPTQ calibration traffic: lanes x steps of seeded tokens
/// driven through the f32 latent weights to accumulate per-linear
/// input Hessians.
const CALIB_LANES: usize = 8;
const CALIB_STEPS: usize = 24;

/// A model the scheduler can drive: one batched decode step at a time.
pub trait DecodeModel {
    fn dims(&self) -> &LmDims;

    /// Advance every lane by one token. `states[i]` is lane i's hidden
    /// context (len = `dims().hidden`, updated in place); `tokens[i]`
    /// is the token it consumes. Returns (batch, vocab) logits.
    ///
    /// Contract: lane i's outputs and state update depend only on
    /// (`states[i]`, `tokens[i]`) — never on the other lanes — so a
    /// request decodes identically at any batch size.
    ///
    /// Compatibility entry point: allocates its activations and output
    /// per call. The pooled scheduler drives
    /// [`DecodeModel::step_batch_into`] instead.
    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  threads: usize) -> HostTensor;

    /// Scratch-aware decode step: identical math and numerics to
    /// [`DecodeModel::step_batch`] at `threads = pool.threads()`
    /// (bitwise — the serve determinism suite checks this), but
    /// executed on a persistent [`WorkerPool`] with every activation
    /// buffer reused from `scratch`. The logits land in
    /// `scratch.logits` as a (batch, vocab) tensor.
    ///
    /// The default falls back to the allocating path so external
    /// models stay correct.
    fn step_batch_into(&self, states: &mut [&mut [f32]], tokens: &[u32],
                       pool: &WorkerPool, scratch: &mut DecodeScratch) {
        scratch.logits = self.step_batch(states, tokens, pool.threads());
    }

    /// Advance every lane by a *span* of consecutive tokens in one
    /// call — the chunked-prefill entry point the scheduler drives.
    /// `spans[i] >= 1` is the number of tokens lane i consumes this
    /// step; lane i's tokens sit at `tokens[o_i..o_i + spans[i]]`
    /// where `o_i` is the prefix sum of earlier spans. Logits for each
    /// lane's *final* span position land in `scratch.logits`, one row
    /// per lane that ran, in lane order — intermediate prompt
    /// positions produce no logits row, so the output head never runs
    /// over whole prefill chunks.
    ///
    /// Backpressure: a model with per-lane admission control (the
    /// paged-KV [`AttnLm`]) may *reject* lanes whose cache claim fails
    /// this step. Rejected lane ordinals (indices into
    /// `states`/`spans`) are recorded in `scratch.rejected` (cleared
    /// on entry, sorted ascending); rejected lanes contribute no batch
    /// rows and no logits row, their `states` entry is untouched, and
    /// nothing is claimed on their behalf. The scheduler requeues them
    /// — capacity exhaustion degrades to queueing, never to a panic.
    /// Models without per-lane resources never reject.
    ///
    /// Bitwise contract: every kernel keeps per-element accumulation
    /// order batch-invariant and lanes are independent, so a span of n
    /// tokens must produce exactly the logits and state the same lane
    /// would reach through n one-token steps — `tests/
    /// prefill_chunking.rs` locks this in per family and model kind.
    ///
    /// The default implementation *iterates* the chunk: sub-step j
    /// re-batches every lane with `spans[i] > j` through
    /// [`DecodeModel::step_batch_into`], staging each lane's
    /// final-position logits. Sequential-state models ([`SpectraLm`]'s
    /// decay carry needs position t's full forward before position
    /// t+1's input) are served correctly by this; models whose span
    /// positions flatten into the batch dimension ([`AttnLm`], via
    /// intra-chunk causal attention) override it with a true
    /// multi-token forward.
    fn step_spans_into(&self, states: &mut [&mut [f32]], tokens: &[u32],
                       spans: &[usize], pool: &WorkerPool,
                       scratch: &mut DecodeScratch) {
        debug_assert_eq!(states.len(), spans.len());
        debug_assert_eq!(tokens.len(), spans.iter().sum::<usize>());
        scratch.rejected.clear();
        scratch.cow_copies = 0;
        if spans.iter().all(|&s| s == 1) {
            // Decode steady state: a span step of all-1 spans *is* a
            // plain batched step — no staging, no extra copies.
            self.step_batch_into(states, tokens, pool, scratch);
            if scratch.want_span_logits {
                // One row per lane: the span view of an all-1 step is
                // the batched logits themselves, copied so the
                // `span_logits` contract holds on every exit.
                let n = states.len();
                scratch.span_logits.reset2(n, self.dims().vocab);
                for i in 0..n {
                    let (dst, src) = (&mut scratch.span_logits,
                                      &scratch.logits);
                    dst.row_mut(i).copy_from_slice(src.row(i));
                }
            }
            return;
        }
        let n = spans.len();
        scratch.sample_logits.reset2(n, self.dims().vocab);
        if scratch.want_span_logits {
            scratch.span_logits.reset2(tokens.len(), self.dims().vocab);
        }
        let mut offs = Vec::with_capacity(n);
        let mut off = 0usize;
        for &s in spans {
            debug_assert!(s >= 1, "spans must be >= 1");
            offs.push(off);
            off += s;
        }
        let max_span = spans.iter().copied().max().unwrap_or(0);
        let mut sub_tokens: Vec<u32> = Vec::with_capacity(n);
        let mut participants: Vec<usize> = Vec::with_capacity(n);
        for j in 0..max_span {
            sub_tokens.clear();
            participants.clear();
            for (i, &s) in spans.iter().enumerate() {
                if j < s {
                    participants.push(i);
                    sub_tokens.push(tokens[offs[i] + j]);
                }
            }
            let mut refs: Vec<&mut [f32]> = states.iter_mut().enumerate()
                .filter(|(i, _)| j < spans[*i])
                .map(|(_, s)| &mut **s)
                .collect();
            self.step_batch_into(&mut refs, &sub_tokens, pool, scratch);
            drop(refs);
            for (row, &i) in participants.iter().enumerate() {
                if scratch.want_span_logits {
                    // Sub-step j produced position j's logits for every
                    // participant: stage them at the lane's flat span
                    // offset so verification sees all positions, not
                    // just the final one.
                    let (dst, src) =
                        (&mut scratch.span_logits, &scratch.logits);
                    dst.row_mut(offs[i] + j).copy_from_slice(src.row(row));
                }
                if spans[i] == j + 1 {
                    let (dst, src) =
                        (&mut scratch.sample_logits, &scratch.logits);
                    dst.row_mut(i).copy_from_slice(src.row(row));
                }
            }
        }
        std::mem::swap(&mut scratch.logits, &mut scratch.sample_logits);
    }

    /// Release any model-side per-lane resource bound to `state` (the
    /// paged KV-cache sequence of an [`AttnLm`] lane) and clear the
    /// binding. The scheduler calls this exactly once per retired lane,
    /// *before* recycling the state buffer — the lane-retire → page-
    /// recycle path. Decay-state models hold no per-lane resources; the
    /// default is a no-op.
    fn retire_state(&self, state: &mut [f32]) {
        let _ = state;
    }

    /// Whether [`DecodeModel::rollback_state`] can rewind this model's
    /// per-lane state to an earlier committed length. True only for
    /// models whose lane state is positional (the paged-KV [`AttnLm`]:
    /// rolling back is a page-table truncation); a decay-state carry
    /// mixes every past token into one vector and cannot be rewound.
    /// Speculative decoding requires this of both the draft and the
    /// target — [`crate::serve::Scheduler::set_speculative`] asserts it.
    fn supports_rollback(&self) -> bool {
        false
    }

    /// Rewind the lane bound to `state` to `new_len` committed tokens,
    /// releasing whatever per-lane resource the rejected suffix held
    /// (KV pages, via [`KvCache::truncate_seq`] — refcount-aware, so a
    /// shared prefix donor is never invalidated). The speculative
    /// scheduler calls this after each verify round to drop the
    /// mis-speculated tail from both the target and the draft cache.
    /// Calling it on a model that does not
    /// [`DecodeModel::supports_rollback`] is a scheduler bug.
    fn rollback_state(&self, state: &mut [f32], new_len: usize) {
        let _ = (state, new_len);
        panic!("rollback_state on a model without rollback support \
                (family {})", self.family_label());
    }

    /// Try to serve a prefix of `prompt` from a model-side prefix cache
    /// by *mapping* already-committed KV pages into the lane bound to
    /// `state` instead of re-running prefill over them. Returns the
    /// number of prompt tokens now committed for this lane (0 = miss);
    /// on a hit the scheduler starts prefill at that position, so the
    /// returned count is always `< prompt.len()` (at least one token
    /// must be fed to produce sampling logits). Called by the scheduler
    /// at admission, before the lane's first step. Models without a KV
    /// cache never hit; the default is a no-op miss.
    fn prefix_reuse(&self, state: &mut [f32], prompt: &[u32]) -> usize {
        let _ = (state, prompt);
        0
    }

    /// Offer a lane's fully-prefilled prompt to the model's prefix
    /// cache (the scheduler calls this once per lane, right after the
    /// lane's first sampled token proves the whole prompt is
    /// committed). The model may pin the covered KV pages so later
    /// [`DecodeModel::prefix_reuse`] calls can map them. Default: no
    /// cache, no-op.
    fn prefix_register(&self, state: &mut [f32], prompt: &[u32]) {
        let _ = (state, prompt);
    }

    /// Release every page the model's prefix cache has pinned. The
    /// scheduler calls this when lanes are being rejected for KV
    /// capacity (backpressure): pinned prefixes are a *cache*, and
    /// under memory pressure cached pages must yield to live lanes —
    /// otherwise an all-rejected drain would free nothing and the
    /// stall guard would fire on a recoverable state. Returns whether
    /// anything was actually released (the scheduler counts a release
    /// as forward progress). Default: nothing pinned, `false`.
    fn release_cached_pages(&self) -> bool {
        false
    }

    /// Bytes this model appends to its KV cache per lane per decode
    /// step (0 for cache-free decay-state models). Serving telemetry:
    /// the `kv_bytes_per_token` field of BENCH_serve.json and the key
    /// of the KV-aware deploy roofline
    /// ([`crate::deploy::decode_tokens_per_sec_bits_kv`]).
    fn kv_bytes_per_token(&self) -> f64 {
        0.0
    }

    /// Physical KV pages currently held (live lanes + prefix pins; a
    /// shared page counts once); 0 for cache-free models. Leak
    /// telemetry for trait-object users: the HTTP server's
    /// graceful-shutdown path asserts this returns to 0 after a drain,
    /// and `/stats` reports it live. Default: no cache, always 0.
    fn kv_pages_in_use(&self) -> usize {
        0
    }

    /// Storage-format label of the linears (e.g. "fp32", "q4g128",
    /// "ternary") — serving telemetry for the cross-family table.
    fn family_label(&self) -> String;

    /// Params-weighted effective bits per linear-weight parameter
    /// (embeddings excluded; they stay float per §2.1). Keys the
    /// deploy roofline ([`crate::deploy::decode_tokens_per_sec_bits`]).
    fn effective_bits_per_param(&self) -> f64;
}

/// One gated-MLP residual block over any linear storage format.
pub struct SpectraBlock<L> {
    /// (glu, hidden)
    pub gate: L,
    /// (glu, hidden)
    pub up: L,
    /// (hidden, glu)
    pub down: L,
}

/// The family-generic serving model. Embeddings stay f32 (the paper
/// keeps embeddings in halfprec; §2.1); every linear is an `L`.
pub struct SpectraLm<L: LinearFormat> {
    pub dims: LmDims,
    /// (vocab, hidden) f32 input embeddings.
    pub embed: HostTensor,
    pub blocks: Vec<SpectraBlock<L>>,
    /// (vocab, hidden) output head.
    pub head: L,
}

/// TriLM storage: packed 2-bit trits ([`crate::ternary::matmul_ternary_packed`]).
pub type TernaryLm = SpectraLm<PackedMatrix>;

/// FloatLM storage: dense f32 rows.
pub type DenseLm = SpectraLm<DenseF32>;

/// QuantLM storage: k-bit group-quantized bitstreams
/// ([`crate::linear::matmul_quant_packed`]).
pub type QuantLm = SpectraLm<QuantPacked>;

#[inline]
fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Row-wise RMS norm into a reused buffer (no learned gain — the serve
/// model keeps norms parameter-free so checkpoint import only needs
/// the linears). `out` is reshaped in place and fully overwritten; the
/// decode hot path feeds it from [`DecodeScratch`] instead of cloning
/// the full activation tensor every layer.
fn rmsnorm_into(x: &HostTensor, out: &mut HostTensor) {
    let (rows, cols) = x.dims2();
    out.reset2(rows, cols);
    for r in 0..rows {
        let xr = x.row(r);
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / cols as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for (o, &v) in out.row_mut(r).iter_mut().zip(xr) {
            *o = v * inv;
        }
    }
}

/// Allocating [`rmsnorm_into`] wrapper (calibration + compatibility
/// paths; bitwise-identical output).
fn rmsnorm(x: &HostTensor) -> HostTensor {
    let mut out = HostTensor::zeros(vec![0, 0]);
    rmsnorm_into(x, &mut out);
    out
}

/// x = embed[token] + state, written into a reused (batch, hidden)
/// buffer (reshaped in place, fully overwritten).
fn gather_input_into(embed: &HostTensor, states: &[&mut [f32]],
                     tokens: &[u32], x: &mut HostTensor) {
    let (vocab, hidden) = embed.dims2();
    assert_eq!(states.len(), tokens.len());
    x.reset2(tokens.len(), hidden);
    for (bi, (&tok, st)) in tokens.iter().zip(states.iter()).enumerate() {
        assert_eq!(st.len(), hidden, "lane {bi} state len");
        let e = embed.row(tok as usize % vocab);
        let row = x.row_mut(bi);
        for j in 0..hidden {
            row[j] = e[j] + st[j];
        }
    }
}

/// Allocating [`gather_input_into`] wrapper (compatibility path).
fn gather_input(embed: &HostTensor, states: &[&mut [f32]], tokens: &[u32])
                -> HostTensor {
    let mut x = HostTensor::zeros(vec![0, 0]);
    gather_input_into(embed, states, tokens, &mut x);
    x
}

/// state' = DECAY*state + (1-DECAY)*x_row — the per-lane context carry.
fn update_states(states: &mut [&mut [f32]], x: &HostTensor) {
    for (bi, st) in states.iter_mut().enumerate() {
        let row = x.row(bi);
        for (s, &v) in st.iter_mut().zip(row) {
            *s = STATE_DECAY * *s + (1.0 - STATE_DECAY) * v;
        }
    }
}

impl<L: LinearFormat> DecodeModel for SpectraLm<L> {
    fn dims(&self) -> &LmDims {
        &self.dims
    }

    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  threads: usize) -> HostTensor {
        let mut x = gather_input(&self.embed, states, tokens);
        for blk in &self.blocks {
            let y = rmsnorm(&x);
            let g = blk.gate.matmul_batch(&y, threads);
            let u = blk.up.matmul_batch(&y, threads);
            let mut a = g;
            for (av, &uv) in a.data.iter_mut().zip(u.data.iter()) {
                *av = silu(*av) * uv;
            }
            let d = blk.down.matmul_batch(&a, threads);
            for (xv, &dv) in x.data.iter_mut().zip(d.data.iter()) {
                *xv += dv;
            }
        }
        let y = rmsnorm(&x);
        update_states(states, &x);
        self.head.matmul_batch(&y, threads)
    }

    /// The allocation-free decode step: every buffer lives in
    /// `scratch`, every matmul runs on `pool`. Identical math (and
    /// bitwise-identical results) to [`SpectraLm::step_batch`]; the
    /// only differences are where buffers come from and that threads
    /// are dispatched instead of spawned.
    fn step_batch_into(&self, states: &mut [&mut [f32]], tokens: &[u32],
                       pool: &WorkerPool, scratch: &mut DecodeScratch) {
        gather_input_into(&self.embed, states, tokens, &mut scratch.x);
        for blk in &self.blocks {
            rmsnorm_into(&scratch.x, &mut scratch.norm);
            blk.gate.matmul_batch_into(&scratch.norm, pool,
                                       &mut scratch.out_t, &mut scratch.gate);
            blk.up.matmul_batch_into(&scratch.norm, pool,
                                     &mut scratch.out_t, &mut scratch.up);
            // Fuse the GLU activation in place into the gate buffer.
            for (av, &uv) in scratch.gate.data.iter_mut()
                .zip(scratch.up.data.iter())
            {
                *av = silu(*av) * uv;
            }
            blk.down.matmul_batch_into(&scratch.gate, pool,
                                       &mut scratch.out_t, &mut scratch.down);
            for (xv, &dv) in scratch.x.data.iter_mut()
                .zip(scratch.down.data.iter())
            {
                *xv += dv;
            }
        }
        rmsnorm_into(&scratch.x, &mut scratch.norm);
        update_states(states, &scratch.x);
        self.head.matmul_batch_into(&scratch.norm, pool, &mut scratch.out_t,
                                    &mut scratch.logits);
    }

    fn family_label(&self) -> String {
        self.head.label()
    }

    fn effective_bits_per_param(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut params = 0.0f64;
        for l in self.linears() {
            let p = (l.out_features() * l.in_features()) as f64;
            bits += l.effective_bits_per_param() * p;
            params += p;
        }
        bits / params.max(1.0)
    }
}

impl<L: LinearFormat> SpectraLm<L> {
    /// Fresh per-lane context state.
    pub fn zero_state(&self) -> Vec<f32> {
        vec![0.0; self.dims.hidden]
    }

    /// Every linear in the model (blocks then head).
    pub fn linears(&self) -> Vec<&L> {
        let mut out = Vec::with_capacity(3 * self.blocks.len() + 1);
        for b in &self.blocks {
            out.push(&b.gate);
            out.push(&b.up);
            out.push(&b.down);
        }
        out.push(&self.head);
        out
    }
}

/// How quant-family weights are produced from the latent f32 weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMethod {
    /// Round-to-nearest group quantization.
    Rtn,
    /// GPTQ with serve-side synthetic calibration (Hessians accumulated
    /// by driving the latent f32 model on seeded token traffic).
    Gptq,
}

/// A serving family at a bit budget — the §4.2 axis, executable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FamilySpec {
    Float,
    Quant { bits: u32, group: usize, method: QuantMethod },
    Ternary,
}

impl FamilySpec {
    /// Parse a CLI family token: `float` | `ternary` | `quant<bits>` |
    /// `gptq<bits>` (bits 2..=8). `group` applies to the quant forms.
    pub fn parse(s: &str, group: usize) -> Option<FamilySpec> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "float" | "fp32" | "dense" => return Some(FamilySpec::Float),
            "ternary" | "trilm" => return Some(FamilySpec::Ternary),
            _ => {}
        }
        for (prefix, method) in [("quant", QuantMethod::Rtn),
                                 ("rtn", QuantMethod::Rtn),
                                 ("gptq", QuantMethod::Gptq)] {
            if let Some(rest) = s.strip_prefix(prefix) {
                if let Ok(bits) = rest.parse::<u32>() {
                    if (2..=8).contains(&bits) {
                        return Some(FamilySpec::Quant { bits, group, method });
                    }
                }
            }
        }
        None
    }

    /// Paper-style family name for tables.
    pub fn label(&self) -> String {
        match *self {
            FamilySpec::Float => "FloatLM".into(),
            FamilySpec::Ternary => "TriLM".into(),
            FamilySpec::Quant { bits, method: QuantMethod::Rtn, .. } => {
                format!("QuantLM {bits}-bit")
            }
            FamilySpec::Quant { bits, method: QuantMethod::Gptq, .. } => {
                format!("QuantLM {bits}-bit (GPTQ)")
            }
        }
    }
}

/// One block of family-agnostic latent f32 weights.
pub struct LatentBlock {
    pub gate: HostTensor,
    pub up: HostTensor,
    pub down: HostTensor,
}

/// Family-agnostic latent weights: the single source every serving
/// family is realized from (checkpoint-trained or synthetic), so
/// cross-family comparisons are between storage formats of the same
/// model, never between different models.
pub struct LatentLm {
    pub dims: LmDims,
    /// (vocab, hidden) f32 embeddings (stay float in every family).
    pub embed: HostTensor,
    pub blocks: Vec<LatentBlock>,
    /// (vocab, hidden) latent output head.
    pub head: HostTensor,
    /// Ternary scale shards per block matrix (§A.5); head uses 1.
    pub mp: usize,
}

impl LatentLm {
    /// Seeded random latent weights (the synthetic bench/test model).
    pub fn synthetic(dims: LmDims, mp: usize, seed: u64) -> LatentLm {
        let embed = HostTensor::randn(vec![dims.vocab, dims.hidden], 0.5,
                                      seed ^ 0xE3BED);
        let mut blocks = Vec::with_capacity(dims.layers);
        for l in 0..dims.layers {
            let ls = seed ^ ((l as u64 + 1) << 20);
            blocks.push(LatentBlock {
                gate: HostTensor::randn(vec![dims.glu, dims.hidden], 0.08,
                                        ls ^ 1),
                up: HostTensor::randn(vec![dims.glu, dims.hidden], 0.08,
                                      ls ^ 2),
                down: HostTensor::randn(vec![dims.hidden, dims.glu], 0.08,
                                        ls ^ 3),
            });
        }
        let head = HostTensor::randn(vec![dims.vocab, dims.hidden], 0.08,
                                     seed ^ 0x6EAD);
        LatentLm { dims, embed, blocks, head, mp }
    }

    /// Latent weights from a trained checkpoint: the `embed` table plus
    /// every `l{i}.mlp_{gate,up,down}` linear; the head falls back to
    /// the tied embedding table when absent.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<LatentLm> {
        let embed = ck.get("embed")
            .ok_or_else(|| anyhow::anyhow!(
                "checkpoint has no 'embed' tensor; cannot build serve model"))?
            .clone();
        let (vocab, hidden) = embed.dims2();
        let mut blocks = Vec::new();
        let mut glu = 0usize;
        for l in 0.. {
            let Some(gate) = ck.get(&format!("l{l}.mlp_gate")) else { break };
            let up = ck.get(&format!("l{l}.mlp_up")).ok_or_else(
                || anyhow::anyhow!("layer {l}: mlp_gate without mlp_up"))?;
            let down = ck.get(&format!("l{l}.mlp_down")).ok_or_else(
                || anyhow::anyhow!("layer {l}: mlp_gate without mlp_down"))?;
            if l == 0 {
                glu = gate.dims2().0;
            }
            // Reject shape drift here: step_batch's element-wise zips
            // would silently truncate on mismatched tensors and serve
            // garbage logits instead of failing.
            for (name, t, want) in [("mlp_gate", gate, (glu, hidden)),
                                    ("mlp_up", up, (glu, hidden)),
                                    ("mlp_down", down, (hidden, glu))] {
                if t.dims2() != want {
                    anyhow::bail!(
                        "layer {l}: {name} is {:?}, expected {:?} (from \
                         embed hidden {hidden} and l0 glu {glu})",
                        t.dims2(), want);
                }
            }
            blocks.push(LatentBlock {
                gate: gate.clone(),
                up: up.clone(),
                down: down.clone(),
            });
        }
        if blocks.is_empty() {
            anyhow::bail!("checkpoint has no l0.mlp_gate — not a spectra LM");
        }
        let head = ck.get("head").unwrap_or(&embed).clone();
        if head.dims2().1 != hidden {
            anyhow::bail!("head is {:?}, expected (vocab, {hidden})",
                          head.dims2());
        }
        let layers = blocks.len();
        Ok(LatentLm {
            dims: LmDims { vocab, hidden, glu, layers },
            embed,
            blocks,
            head,
            mp: 1,
        })
    }

    fn realize<L: LinearFormat>(&self, f: impl Fn(&HostTensor) -> L)
                                -> SpectraLm<L> {
        SpectraLm {
            dims: self.dims.clone(),
            embed: self.embed.clone(),
            blocks: self.blocks.iter().map(|b| SpectraBlock {
                gate: f(&b.gate),
                up: f(&b.up),
                down: f(&b.down),
            }).collect(),
            head: f(&self.head),
        }
    }

    /// FloatLM storage: the latent f32 weights served directly.
    pub fn build_float(&self) -> DenseLm {
        self.realize(|w| DenseF32 { w: w.clone() })
    }

    /// TriLM storage: absmean-ternarized (§A.5, mp shards per block
    /// matrix, single-shard head) and packed 2-bit.
    pub fn build_ternary(&self) -> TernaryLm {
        let tern = |w: &HostTensor, mp: usize| {
            PackedMatrix::from_ternary(&TernaryTensor::from_latent(w, mp))
        };
        SpectraLm {
            dims: self.dims.clone(),
            embed: self.embed.clone(),
            blocks: self.blocks.iter().map(|b| SpectraBlock {
                gate: tern(&b.gate, self.mp),
                up: tern(&b.up, self.mp),
                down: tern(&b.down, self.mp),
            }).collect(),
            head: tern(&self.head, 1),
        }
    }

    /// QuantLM storage via round-to-nearest group quantization.
    pub fn build_quant_rtn(&self, bits: u32, group: usize) -> QuantLm {
        self.realize(|w| {
            QuantPacked::from_quant(&QuantTensor::quantize_rtn(w, bits, group))
        })
    }

    /// QuantLM storage via GPTQ: per-linear input Hessians are
    /// accumulated by driving the latent f32 model on seeded synthetic
    /// token traffic (the serving analog of the training-distribution
    /// calibration in `gptq::pipeline`), then each linear is quantized
    /// with second-order error compensation.
    pub fn build_quant_gptq(&self, bits: u32, group: usize, seed: u64)
                            -> Result<QuantLm> {
        let (acc_h, acc_g, acc_head) = self.calibration_hessians(seed);
        let cfg = GptqConfig::new(bits, group);
        let qp = |w: &HostTensor, acc: &HessianAccumulator|
                 -> Result<QuantPacked> {
            Ok(QuantPacked::from_quant(
                &gptq_quantize(w, &acc.finalize(), cfg)?))
        };
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (l, b) in self.blocks.iter().enumerate() {
            blocks.push(SpectraBlock {
                gate: qp(&b.gate, &acc_h[l])?,
                up: qp(&b.up, &acc_h[l])?,
                down: qp(&b.down, &acc_g[l])?,
            });
        }
        Ok(SpectraLm {
            dims: self.dims.clone(),
            embed: self.embed.clone(),
            blocks,
            head: qp(&self.head, &acc_head)?,
        })
    }

    /// Realize any family as a boxed [`DecodeModel`] the scheduler can
    /// drive — the one entry point `serve-bench --family` and the
    /// cross-family test harnesses use.
    pub fn build(&self, spec: FamilySpec) -> Result<Box<dyn DecodeModel>> {
        let model: Box<dyn DecodeModel> = match spec {
            FamilySpec::Float => Box::new(self.build_float()),
            FamilySpec::Ternary => Box::new(self.build_ternary()),
            FamilySpec::Quant { bits, group, method: QuantMethod::Rtn } => {
                Box::new(self.build_quant_rtn(bits, group))
            }
            FamilySpec::Quant { bits, group, method: QuantMethod::Gptq } => {
                Box::new(self.build_quant_gptq(bits, group, 0)?)
            }
        };
        Ok(model)
    }

    /// Drive the latent f32 weights through the decode math on seeded
    /// token traffic, accumulating every linear's input Hessian:
    /// gate/up share the block-input accumulator (identical inputs),
    /// down gets the activated GLU, the head gets the final norm.
    fn calibration_hessians(&self, seed: u64)
                            -> (Vec<HessianAccumulator>,
                                Vec<HessianAccumulator>,
                                HessianAccumulator) {
        let d = &self.dims;
        let mut acc_h: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.hidden)).collect();
        let mut acc_g: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.glu)).collect();
        let mut acc_head = HessianAccumulator::new(d.hidden);
        let mut rng = SplitMix64::new(seed ^ 0xCA11B);
        let mut states = HostTensor::zeros(vec![CALIB_LANES, d.hidden]);
        for _ in 0..CALIB_STEPS {
            let mut x = HostTensor::zeros(vec![CALIB_LANES, d.hidden]);
            for b in 0..CALIB_LANES {
                let e = self.embed.row(rng.below(d.vocab));
                let s = states.row(b);
                let row = x.row_mut(b);
                for j in 0..d.hidden {
                    row[j] = e[j] + s[j];
                }
            }
            for (l, blk) in self.blocks.iter().enumerate() {
                let y = rmsnorm(&x);
                acc_h[l].add_batch(&y);
                let g = matmul_dense(&y, &blk.gate);
                let u = matmul_dense(&y, &blk.up);
                let mut a = g;
                for (av, &uv) in a.data.iter_mut().zip(u.data.iter()) {
                    *av = silu(*av) * uv;
                }
                acc_g[l].add_batch(&a);
                let dd = matmul_dense(&a, &blk.down);
                for (xv, &dv) in x.data.iter_mut().zip(dd.data.iter()) {
                    *xv += dv;
                }
            }
            acc_head.add_batch(&rmsnorm(&x));
            for b in 0..CALIB_LANES {
                let row = &x.data[b * d.hidden..(b + 1) * d.hidden];
                let s = states.row_mut(b);
                for (sv, &xv) in s.iter_mut().zip(row) {
                    *sv = STATE_DECAY * *sv + (1.0 - STATE_DECAY) * xv;
                }
            }
        }
        (acc_h, acc_g, acc_head)
    }
}

impl SpectraLm<PackedMatrix> {
    /// Seeded random weights, ternarized with `mp` scale shards —
    /// plus the dequantized f32 twin holding *identical* weights, so
    /// benches compare storage formats and tests check equivalence.
    pub fn synthetic_pair(dims: LmDims, mp: usize, seed: u64)
                          -> (TernaryLm, DenseLm) {
        let latent = LatentLm::synthetic(dims, mp, seed);
        let ternary = latent.build_ternary();
        // The dense twin dequantizes the *ternarized* weights (not the
        // latent ones): identical math up to fp rounding.
        let dense = SpectraLm {
            dims: latent.dims.clone(),
            embed: latent.embed.clone(),
            blocks: ternary.blocks.iter().map(|b| SpectraBlock {
                gate: DenseF32 { w: b.gate.dequant() },
                up: DenseF32 { w: b.up.dequant() },
                down: DenseF32 { w: b.down.dequant() },
            }).collect(),
            head: DenseF32 { w: ternary.head.dequant() },
        };
        (ternary, dense)
    }

    /// Ternarized serving model from a trained checkpoint (single-shard
    /// absmean, the §A.5 transform at mp=1).
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<TernaryLm> {
        Ok(LatentLm::from_checkpoint(ck)?.build_ternary())
    }
}

// ---------------------------------------------------------------------------
// Paged KV-cache attention serving
// ---------------------------------------------------------------------------

/// x = embed[token], written into a reused (batch, hidden) buffer
/// (reshaped in place, fully overwritten). The attention model carries
/// no decay state: context arrives through the KV cache, so the
/// residual stream starts from the embedding alone.
fn gather_embed_into(embed: &HostTensor, tokens: &[u32], x: &mut HostTensor) {
    let (vocab, hidden) = embed.dims2();
    x.reset2(tokens.len(), hidden);
    for (bi, &tok) in tokens.iter().enumerate() {
        x.row_mut(bi).copy_from_slice(embed.row(tok as usize % vocab));
    }
}

/// Allocating [`gather_embed_into`] wrapper (compatibility path).
fn gather_embed(embed: &HostTensor, tokens: &[u32]) -> HostTensor {
    let mut x = HostTensor::zeros(vec![0, 0]);
    gather_embed_into(embed, tokens, &mut x);
    x
}

/// Per-layer window policy shared by [`AttnLm`] and the latent
/// calibration forward: `window == 0` disables windowing everywhere;
/// `interleave == 0` windows *every* layer (the only policy under
/// which out-of-window pages can be recycled — the token-major page
/// layout cannot truncate per layer); `interleave = n` keeps every
/// (n+1)-th layer global (the Gemma3-style `window:global = n:1`
/// interleave, e.g. `n = 5`).
fn window_for_layer(window: usize, interleave: usize, layer: usize)
                    -> Option<usize> {
    if window == 0 {
        None
    } else if interleave > 0 && (layer + 1) % (interleave + 1) == 0 {
        None // the global layer of each interleave period
    } else {
        Some(window)
    }
}

/// First kv rows of a latent projection: `(n, cols)` sliced out of
/// `t`'s row-major data starting at row `start` (fused checkpoint
/// splitting and GQA head truncation both reduce to this).
fn slice_rows(t: &HostTensor, start: usize, n: usize) -> HostTensor {
    let (rows, cols) = t.dims2();
    assert!(start + n <= rows, "slice_rows {start}+{n} > {rows}");
    let mut out = HostTensor::zeros(vec![n, cols]);
    out.data
        .copy_from_slice(&t.data[start * cols..(start + n) * cols]);
    out
}

/// Single-query grouped multi-head attention for one lane over its own
/// cached positions: per query head, dot(q, k)/sqrt(dh) scores over
/// positions `first..limit`, max-subtracted softmax, then the weighted
/// sum of the cached values into `out` (fully overwritten).
///
/// Grouped-query attention: the cache rows are `kv_heads * dh` wide
/// (`kv_heads <= heads`, `heads % kv_heads == 0`) and query head `h`
/// reads shared kv head `h / (heads / kv_heads)`. At
/// `kv_heads == heads` the mapping is the identity and the math is
/// bitwise the classic multi-head form.
///
/// `limit` is the number of attendable positions — `seq_len` for a
/// one-token decode step; `start + j + 1` for the j-th position of a
/// prefill chunk, which is what makes intra-chunk attention *causal*:
/// a chunk position never sees the chunk positions after it, so a
/// multi-token forward reads exactly the cache prefix the one-token
/// path would have seen. `first` is the sliding-window floor
/// (`limit - window` on windowed layers, clamped at 0): positions
/// before it are skipped entirely, so at `first == 0` the windowed
/// path is bitwise the unwindowed one.
///
/// Determinism contract: the loops run in position order with a fixed
/// f32 accumulation order, and only `seq`'s own slots are read — so a
/// lane's attention output is bitwise identical at any batch size,
/// chunk size, thread count, and physical page placement. `scores` is
/// a reused per-(lane, head) buffer; it is cleared and refilled before
/// use.
#[allow(clippy::too_many_arguments)]
fn attend_one(cache: &KvCache, seq: usize, layer: usize, heads: usize,
              kv_heads: usize, q: &[f32], out: &mut [f32],
              scores: &mut Vec<f32>, first: usize, limit: usize) {
    let hidden = q.len();
    debug_assert_eq!(out.len(), hidden);
    debug_assert_eq!(hidden % heads, 0);
    debug_assert_eq!(heads % kv_heads, 0);
    let dh = hidden / heads;
    let group = heads / kv_heads;
    debug_assert!(limit >= 1, "attend before begin_token");
    debug_assert!(first < limit, "empty attention window");
    debug_assert!(limit <= cache.seq_len(seq), "attend past committed slots");
    let scale = 1.0 / (dh as f32).sqrt();
    out.fill(0.0);
    for h in 0..heads {
        let qh = &q[h * dh..(h + 1) * dh];
        // The shared kv head this query head's group reads.
        let kh0 = (h / group) * dh;
        scores.clear();
        let mut mx = f32::NEG_INFINITY;
        for pos in first..limit {
            let (k, _) = cache.kv(seq, layer, pos);
            let kh = &k[kh0..kh0 + dh];
            let mut s = 0.0f32;
            for j in 0..dh {
                s += qh[j] * kh[j];
            }
            let s = s * scale;
            scores.push(s);
            if s > mx {
                mx = s;
            }
        }
        let mut denom = 0.0f32;
        for s in scores.iter_mut() {
            *s = (*s - mx).exp();
            denom += *s;
        }
        // The max-score position contributes exp(0) = 1, so denom >= 1.
        let inv = 1.0 / denom;
        let oh = &mut out[h * dh..(h + 1) * dh];
        for (i, pos) in (first..limit).enumerate() {
            let w = scores[i] * inv;
            let (_, v) = cache.kv(seq, layer, pos);
            let vh = &v[kh0..kh0 + dh];
            for (o, &vv) in oh.iter_mut().zip(vh) {
                *o += w * vv;
            }
        }
    }
}

/// Bind a lane's state buffer to a KV-cache sequence and claim an
/// `n`-token span of slots. The binding is the state's first element
/// (`seq_id + 1`; `0.0` = unbound — exactly what the scheduler's
/// zeroed fresh/recycled buffers carry), so the scheduler stays
/// model-blind: admission needs no new plumbing, and retirement goes
/// through [`DecodeModel::retire_state`].
///
/// On success returns `(seq, start_position)`. On [`OutOfPages`] the
/// refusal is *harmless*: a fresh lane's just-allocated sequence is
/// given straight back (the state stays unbound, zero), a mid-flight
/// lane's sequence and pages are left exactly as they were — so the
/// scheduler can defer or requeue the lane and retry later. This is
/// the backpressure path that replaced the old hard panic.
fn try_bind_and_begin(cache: &mut KvCache, st: &mut [f32], n: usize)
                      -> std::result::Result<(usize, usize), OutOfPages> {
    if st[0] == 0.0 {
        let seq = cache.alloc_seq();
        match cache.begin_tokens(seq, n) {
            Ok(start) => {
                st[0] = (seq + 1) as f32;
                Ok((seq, start))
            }
            Err(e) => {
                // Hand the empty sequence straight back: a refused
                // admission must leave no trace.
                cache.free_seq(seq);
                Err(e)
            }
        }
    } else {
        let seq = st[0] as usize - 1;
        cache.begin_tokens(seq, n).map(|start| (seq, start))
    }
}

/// Strict single-token [`try_bind_and_begin`]: the legacy
/// [`DecodeModel::step_batch`] entry point has no rejection channel,
/// so capacity exhaustion can only panic there. The serving path
/// ([`DecodeModel::step_spans_into`]) rejects gracefully instead.
fn bind_and_begin(cache: &mut KvCache, st: &mut [f32]) -> usize {
    match try_bind_and_begin(cache, st, 1) {
        Ok((seq, _)) => seq,
        Err(e) => panic!(
            "AttnLm: {e} — the legacy step path cannot defer lanes; \
             serve through the scheduler (which requeues on \
             backpressure) or size the cache for max_batch lanes x \
             (prompt + max_new_tokens) context"),
    }
}

/// Order-independent FNV-1a over token ids — the prefix-index key.
/// Deterministic across runs (unlike `RandomState`-seeded hashers), so
/// hit/miss behavior is reproducible; every lookup is token-verified,
/// so a collision can only cost a miss, never a wrong mapping.
fn hash_tokens(tokens: &[u32]) -> u64 {
    let mut h = 0xcbf29ce484222325u64;
    for &t in tokens {
        for b in t.to_le_bytes() {
            h ^= b as u64;
            h = h.wrapping_mul(0x100000001b3);
        }
    }
    h
}

/// One pinned prompt held alive in the KV cache: a dedicated sequence
/// whose page table maps the donor lane's committed prefix pages
/// ([`KvCache::share_prefix`]), plus the full prompt for verified
/// lookups and tail extension past the last page boundary.
struct PrefixPin {
    seq: usize,
    tokens: Vec<u32>,
    /// Logical clock value of this pin's most recent verified hit
    /// (0 = never hit) — the LRU key of one-at-a-time eviction.
    last_hit: u64,
}

/// The model-side prompt prefix cache: pins plus a page-boundary-keyed
/// index. Keys are hashes of `prompt[..b]` for every page boundary `b`
/// of a pinned prompt (first writer wins per key); lookups walk a new
/// prompt's boundaries longest-first, verify tokens against the pin,
/// then extend reuse token-by-token through the pin's unaligned tail —
/// so two identical P-token prompts share P-1 tokens, not just the
/// aligned floor. Pins are a cache, not a reservation: under KV
/// backpressure [`DecodeModel::release_cached_pages`] evicts them
/// one at a time, least-recently-hit first — repeated pressure drains
/// the whole cache, one pin per refused step, and the index rebuilds
/// from live traffic.
#[derive(Default)]
struct PrefixIndex {
    pins: Vec<PrefixPin>,
    /// hash of `tokens[..boundary]` -> (pin index, boundary).
    by_hash: HashMap<u64, (usize, usize)>,
    /// Monotonic hit clock feeding [`PrefixPin::last_hit`].
    clock: u64,
}

impl PrefixIndex {
    /// Longest verified reuse for `prompt`: `(pin index, tokens)` with
    /// `tokens < prompt.len()` (at least one prompt token is always
    /// left to feed, so the lane's first step produces sampling
    /// logits), or `None` on a miss. A hit stamps the pin with the
    /// advancing clock, so eviction can rank pins by recency.
    fn lookup(&mut self, prompt: &[u32], page_tokens: usize)
              -> Option<(usize, usize)> {
        if prompt.len() < 2 {
            return None;
        }
        let top = ((prompt.len() - 1) / page_tokens) * page_tokens;
        let mut b = top;
        while b >= page_tokens {
            if let Some(&(pin_idx, stored_b)) =
                self.by_hash.get(&hash_tokens(&prompt[..b]))
            {
                let pin = &self.pins[pin_idx];
                if stored_b == b && pin.tokens.len() >= b
                    && pin.tokens[..b] == prompt[..b]
                {
                    let cap = (prompt.len() - 1).min(pin.tokens.len());
                    let mut r = b;
                    while r < cap && pin.tokens[r] == prompt[r] {
                        r += 1;
                    }
                    self.clock += 1;
                    self.pins[pin_idx].last_hit = self.clock;
                    return Some((pin_idx, r));
                }
            }
            b -= page_tokens;
        }
        None
    }

    /// Index of the eviction victim: the least-recently-hit pin
    /// (never-hit pins carry clock 0, so they go first).
    fn lru_pin(&self) -> Option<usize> {
        (0..self.pins.len()).min_by_key(|&i| self.pins[i].last_hit)
    }
}

/// Interior state behind [`AttnLm`]'s mutex: the paged cache plus the
/// prefix index that pins pages inside it (one lock, so a reuse/
/// register/evict decision and its page-table effect are atomic).
struct KvState {
    cache: KvCache,
    prefix: PrefixIndex,
}

/// One attention + gated-MLP residual block over any linear storage
/// format. The projections are *fused*: q/k/v are one row-stacked
/// [`FusedLinear`] (parts `[q (hidden), k (kv_dim), v (kv_dim)]`
/// rows), gate/up another (`[gate (glu), up (glu)]`), so a decode
/// step dispatches one kernel pass per fusion instead of one per
/// matrix. Each part is still compressed separately by its
/// [`LinearFormat`] (scales summarize the matrix they came from), so
/// fused logits are bitwise the unfused ones in every family.
pub struct AttnBlock<L: LinearFormat> {
    /// Fused (hidden + 2*kv_dim, hidden) q/k/v projection.
    pub wqkv: FusedLinear<L>,
    /// (hidden, hidden) attention-out projection.
    pub wo: L,
    /// Fused (2*glu, hidden) gate/up projection.
    pub gateup: FusedLinear<L>,
    /// (hidden, glu)
    pub down: L,
}

/// The paged KV-cache attention serving model: pre-norm multi-head
/// attention + gated MLP per block, every linear an `L`, per-lane
/// context held in a block-paged [`KvCache`] instead of the decay
/// state [`SpectraLm`] uses.
///
/// Scheduler integration (the lane lifecycle, with the `Scheduler`
/// itself unchanged and model-blind):
///
/// - *Admit*: the scheduler hands a zeroed state buffer to the first
///   `step_batch*` call; the model allocates a cache sequence and
///   stores the binding in `state[0]` (`bind_and_begin`).
/// - *Step*: each live lane claims one token slot, appends one k/v per
///   layer, and attends over its own positions only — lane
///   independence, so batch-1 == batch-N token streams hold exactly as
///   for the decay-state model.
/// - *Retire*: the scheduler's state-recycling path calls
///   [`DecodeModel::retire_state`], which frees the sequence — its
///   pages return to the free list for the next admitted lane.
///
/// The cache is interior-mutable (`Mutex`) because the scheduler holds
/// the model by shared reference; the lock is uncontended (one
/// scheduler thread) and never held by kernel workers.
pub struct AttnLm<L: LinearFormat> {
    pub dims: LmDims,
    /// Attention (query) heads (`hidden % heads == 0`).
    pub heads: usize,
    /// Shared kv heads (`kv_heads <= heads`, `heads % kv_heads == 0`);
    /// `kv_heads == heads` is classic multi-head attention.
    pub kv_heads: usize,
    /// (vocab, hidden) f32 input embeddings.
    pub embed: HostTensor,
    pub blocks: Vec<AttnBlock<L>>,
    /// (vocab, hidden) output head.
    pub head: L,
    /// Sliding-window width in tokens (0 = unbounded attention).
    window: usize,
    /// Windowed layers per global layer (0 = every layer windowed;
    /// see [`window_for_layer`]).
    window_interleave: usize,
    kv: Mutex<KvState>,
}

impl<L: LinearFormat> AttnLm<L> {
    /// Build from realized parts, sizing the page pool for `lanes`
    /// concurrent sequences of up to `max_context` tokens each. The kv
    /// head count is inferred from the fused projection itself (the k
    /// part's row count over the head dim), so GQA needs no extra
    /// constructor plumbing; windowing defaults to off — chain
    /// [`AttnLm::with_window`] to enable it.
    pub fn new(dims: LmDims, heads: usize, embed: HostTensor,
               blocks: Vec<AttnBlock<L>>, head: L,
               lanes: usize, max_context: usize) -> AttnLm<L> {
        assert!(heads >= 1 && dims.hidden % heads == 0,
                "heads {heads} must divide hidden {}", dims.hidden);
        assert_eq!(embed.dims2(), (dims.vocab, dims.hidden),
                   "embed shape mismatch");
        assert_eq!(blocks.len(), dims.layers, "block count != layers");
        let dh = dims.hidden / heads;
        let kv_dim = blocks.first()
            .map(|b| b.wqkv.parts()[1].out_features())
            .unwrap_or(dims.hidden);
        assert!(kv_dim >= dh && kv_dim % dh == 0,
                "k projection rows {kv_dim} must be a multiple of the \
                 head dim {dh}");
        let kv_heads = kv_dim / dh;
        assert!(kv_heads <= heads && heads % kv_heads == 0,
                "kv_heads {kv_heads} must divide heads {heads}");
        for (l, b) in blocks.iter().enumerate() {
            let p = b.wqkv.parts();
            assert!(p.len() == 3 && p[0].out_features() == dims.hidden
                        && p[1].out_features() == kv_dim
                        && p[2].out_features() == kv_dim,
                    "layer {l}: fused qkv parts must be \
                     [hidden, kv_dim, kv_dim] rows");
            let g = b.gateup.parts();
            assert!(g.len() == 2 && g[0].out_features() == dims.glu
                        && g[1].out_features() == dims.glu,
                    "layer {l}: fused gate/up parts must be [glu, glu] rows");
        }
        // The cache stores kv_dim-wide rows: kv_bytes_per_token shrinks
        // by the head ratio automatically.
        let cache = KvCache::for_lanes(dims.layers, kv_dim,
                                       KV_PAGE_TOKENS, lanes, max_context);
        AttnLm { dims, heads, kv_heads, embed, blocks, head,
                 window: 0, window_interleave: 0,
                 kv: Mutex::new(KvState { cache,
                                          prefix: PrefixIndex::default() }) }
    }

    /// Enable sliding-window attention: `window` tokens per windowed
    /// layer (0 = off), with every (`interleave`+1)-th layer kept
    /// global when `interleave > 0` (Gemma3-style `window:global`
    /// interleave; `interleave == 0` windows every layer, which is
    /// also the only policy under which out-of-window pages are
    /// recycled). A window covering the whole context is bitwise the
    /// unwindowed model.
    pub fn with_window(mut self, window: usize, interleave: usize)
                       -> AttnLm<L> {
        self.window = window;
        self.window_interleave = interleave;
        self
    }

    /// Width of one cached k (or v) row: `kv_heads * head_dim`.
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * (self.dims.hidden / self.heads)
    }

    /// Sliding-window width (0 = unbounded).
    pub fn window(&self) -> usize {
        self.window
    }

    /// Windowed layers per global layer (0 = all windowed).
    pub fn window_interleave(&self) -> usize {
        self.window_interleave
    }

    /// This layer's attention window, per the configured interleave.
    fn window_for_layer(&self, layer: usize) -> Option<usize> {
        window_for_layer(self.window, self.window_interleave, layer)
    }

    /// Whether out-of-window pages can be returned to the pool: only
    /// when *every* layer is windowed — the token-major interleaved
    /// page layout cannot front-truncate a single layer's stream.
    fn recycles_pages(&self) -> bool {
        self.window > 0 && self.window_interleave == 0
    }

    fn lock_cache(&self) -> MutexGuard<'_, KvState> {
        // Poisoning ignored on purpose (a panicking step is re-raised
        // by the caller; the cache data itself stays well-formed).
        self.kv.lock().unwrap_or_else(|e| e.into_inner())
    }

    /// *Physical* pages currently held by live lanes and prefix pins
    /// (a shared page counts once) — serving telemetry; drops back to
    /// 0 once every request has retired and every pin is released.
    pub fn kv_pages_in_use(&self) -> usize {
        self.lock_cache().cache.pages_in_use()
    }

    /// Live (bound, not yet retired) cache sequences, prefix-pin
    /// sequences included.
    pub fn kv_live_seqs(&self) -> usize {
        self.lock_cache().cache.live_seqs()
    }

    /// Prompts currently pinned by the prefix cache.
    pub fn kv_prefix_pins(&self) -> usize {
        self.lock_cache().prefix.pins.len()
    }

    /// Copy-on-write page copies performed since construction.
    pub fn kv_cow_copies(&self) -> usize {
        self.lock_cache().cache.cow_copies()
    }

    /// Fault injection: force the next `n` KV page claims to refuse
    /// with `OutOfPages` ([`KvCache::inject_refusals`]), driving the
    /// model's *real* refusal/rejection path — chaos tests use it to
    /// prove injected and genuine pool exhaustion behave identically
    /// (per-lane rejection, release, requeue; never a panic).
    pub fn inject_kv_refusals(&self, n: usize) {
        self.lock_cache().cache.inject_refusals(n);
    }

    /// Every linear in the model (per block: q, k, v, o, gate, up,
    /// down — the fused matrices contribute their parts in stacking
    /// order; then the head).
    pub fn linears(&self) -> Vec<&L> {
        let mut out = Vec::with_capacity(7 * self.blocks.len() + 1);
        for b in &self.blocks {
            out.extend(b.wqkv.parts());
            out.push(&b.wo);
            out.extend(b.gateup.parts());
            out.push(&b.down);
        }
        out.push(&self.head);
        out
    }
}

impl<L: LinearFormat> DecodeModel for AttnLm<L> {
    fn dims(&self) -> &LmDims {
        &self.dims
    }

    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  threads: usize) -> HostTensor {
        assert_eq!(states.len(), tokens.len());
        let hidden = self.dims.hidden;
        let glu = self.dims.glu;
        let kv_dim = self.kv_dim();
        let mut guard = self.lock_cache();
        let cache = &mut guard.cache;
        let seqs: Vec<usize> = states.iter_mut()
            .map(|st| bind_and_begin(cache, st)).collect();
        let mut x = gather_embed(&self.embed, tokens);
        let mut scores = Vec::new();
        for (l, blk) in self.blocks.iter().enumerate() {
            let y = rmsnorm(&x);
            // One fused pass: row bi is [q (hidden) | k (kv_dim) |
            // v (kv_dim)], each part computed by its own kernel so the
            // values are bitwise the unfused projections'.
            let qkv = blk.wqkv.matmul_batch(&y, threads);
            for (bi, &seq) in seqs.iter().enumerate() {
                let r = qkv.row(bi);
                cache.write_kv(seq, l, &r[hidden..hidden + kv_dim],
                               &r[hidden + kv_dim..]);
            }
            let mut attn = HostTensor::zeros(vec![tokens.len(), hidden]);
            let win = self.window_for_layer(l);
            for (bi, &seq) in seqs.iter().enumerate() {
                let limit = cache.seq_len(seq);
                let first = win.map_or(0, |w| limit.saturating_sub(w));
                attend_one(cache, seq, l, self.heads, self.kv_heads,
                           &qkv.row(bi)[..hidden], attn.row_mut(bi),
                           &mut scores, first, limit);
            }
            let o = blk.wo.matmul_batch(&attn, threads);
            for (xv, &ov) in x.data.iter_mut().zip(o.data.iter()) {
                *xv += ov;
            }
            let y2 = rmsnorm(&x);
            // One fused pass: row bi is [gate (glu) | up (glu)].
            let gu = blk.gateup.matmul_batch(&y2, threads);
            let mut a = HostTensor::zeros(vec![tokens.len(), glu]);
            for bi in 0..tokens.len() {
                let gur = gu.row(bi);
                let ar = a.row_mut(bi);
                for j in 0..glu {
                    ar[j] = silu(gur[j]) * gur[glu + j];
                }
            }
            let d = blk.down.matmul_batch(&a, threads);
            for (xv, &dv) in x.data.iter_mut().zip(d.data.iter()) {
                *xv += dv;
            }
        }
        if self.recycles_pages() {
            // This step appended position len-1; everything the *next*
            // step can still attend sits at >= len - window, so pages
            // wholly before (len-1) - window return to the pool.
            for &seq in &seqs {
                let start = cache.seq_len(seq) - 1;
                cache.release_before(seq,
                                     start.saturating_sub(self.window));
            }
        }
        let y = rmsnorm(&x);
        self.head.matmul_batch(&y, threads)
    }

    /// The pooled/scratch twin: identical math and bitwise-identical
    /// logits, state tags, and cache contents to
    /// [`AttnLm::step_batch`] at `threads = pool.threads()` — only the
    /// buffer sources (scratch vs fresh) and the execution substrate
    /// (dispatched pool vs spawned scope) differ. Implemented as the
    /// all-ones span step; like [`AttnLm::step_batch`] this legacy
    /// entry point has no rejection channel, so a lane the span step
    /// would merely defer becomes a panic here.
    fn step_batch_into(&self, states: &mut [&mut [f32]], tokens: &[u32],
                       pool: &WorkerPool, scratch: &mut DecodeScratch) {
        assert_eq!(states.len(), tokens.len());
        let spans = vec![1usize; tokens.len()];
        self.step_spans_into(states, tokens, &spans, pool, scratch);
        if let Some(&lane) = scratch.rejected.first() {
            panic!("AttnLm: kv cache out of pages for lane {lane} — the \
                    legacy step path cannot defer lanes; serve through \
                    the scheduler (which requeues on backpressure) or \
                    size the cache for max_batch lanes x (prompt + \
                    max_new_tokens) context");
        }
    }

    /// The true multi-token forward behind chunked prefill: every
    /// accepted lane's whole span is flattened into the batch
    /// dimension of one kernel pass per projection, with intra-chunk
    /// *causal* attention (span position j attends over `start + j + 1`
    /// cache positions — exactly the prefix the one-token path would
    /// see), so a chunk of n tokens is bitwise identical to n
    /// one-token steps while invoking each kernel once instead of n
    /// times.
    ///
    /// Admission is per lane and all-or-nothing: each lane claims its
    /// whole span via [`KvCache::begin_tokens`] up front; a lane whose
    /// claim is refused is recorded in `scratch.rejected`, contributes
    /// nothing to the batch, and keeps its sequence (or unbound state)
    /// untouched — the KV-capacity backpressure contract of
    /// [`DecodeModel::step_spans_into`].
    fn step_spans_into(&self, states: &mut [&mut [f32]], tokens: &[u32],
                       spans: &[usize], pool: &WorkerPool,
                       scratch: &mut DecodeScratch) {
        debug_assert_eq!(states.len(), spans.len());
        debug_assert_eq!(tokens.len(), spans.iter().sum::<usize>());
        scratch.rejected.clear();
        scratch.cow_copies = 0;
        scratch.seqs.clear();
        scratch.starts.clear();
        scratch.spans.clear();
        scratch.span_tokens.clear();
        let mut guard = self.lock_cache();
        let cache = &mut guard.cache;
        let cow_before = cache.cow_copies();
        let mut off = 0usize;
        for (i, st) in states.iter_mut().enumerate() {
            let span = spans[i];
            debug_assert!(span >= 1, "lane {i}: span must be >= 1");
            match try_bind_and_begin(cache, st, span) {
                Ok((seq, start)) => {
                    scratch.seqs.push(seq);
                    scratch.starts.push(start);
                    scratch.spans.push(span);
                    scratch.span_tokens
                        .extend_from_slice(&tokens[off..off + span]);
                }
                Err(_) => scratch.rejected.push(i),
            }
            off += span;
        }
        // Claims are where copy-on-write happens (shared-prefix lanes
        // diverging); report this step's copies to the scheduler.
        scratch.cow_copies = cache.cow_copies() - cow_before;
        let rows = scratch.span_tokens.len();
        if rows == 0 {
            // Every lane refused this step: no forward runs, the
            // scheduler requeues them all.
            scratch.logits.reset2(0, self.dims.vocab);
            if scratch.want_span_logits {
                scratch.span_logits.reset2(0, self.dims.vocab);
            }
            return;
        }
        gather_embed_into(&self.embed, &scratch.span_tokens, &mut scratch.x);
        let hidden = self.dims.hidden;
        let glu = self.dims.glu;
        let kv_dim = self.kv_dim();
        for (l, blk) in self.blocks.iter().enumerate() {
            rmsnorm_into(&scratch.x, &mut scratch.norm);
            // One fused qkv pass: scratch.qkv row r is [q (hidden) |
            // k (kv_dim) | v (kv_dim)], each part staged through its
            // own kernel (bitwise the unfused projections).
            blk.wqkv.matmul_batch_into_fused(&scratch.norm, pool,
                                             &mut scratch.out_t,
                                             &mut scratch.fused_stage,
                                             &mut scratch.qkv);
            // Commit the whole span's k/v first (position order), then
            // attend causally — position j never reads past start+j.
            let mut row = 0usize;
            for (ai, &seq) in scratch.seqs.iter().enumerate() {
                for j in 0..scratch.spans[ai] {
                    let r = scratch.qkv.row(row);
                    cache.write_kv_at(seq, l, scratch.starts[ai] + j,
                                      &r[hidden..hidden + kv_dim],
                                      &r[hidden + kv_dim..]);
                    row += 1;
                }
            }
            scratch.attn.reset2(rows, hidden);
            let win = self.window_for_layer(l);
            let mut row = 0usize;
            for (ai, &seq) in scratch.seqs.iter().enumerate() {
                for j in 0..scratch.spans[ai] {
                    let limit = scratch.starts[ai] + j + 1;
                    let first = win.map_or(0, |w| limit.saturating_sub(w));
                    attend_one(cache, seq, l, self.heads, self.kv_heads,
                               &scratch.qkv.row(row)[..hidden],
                               scratch.attn.row_mut(row),
                               &mut scratch.scores, first, limit);
                    row += 1;
                }
            }
            // The attention-out projection reuses the down buffer (both
            // are (rows, hidden) residual deltas).
            blk.wo.matmul_batch_into(&scratch.attn, pool,
                                     &mut scratch.out_t, &mut scratch.down);
            for (xv, &ov) in scratch.x.data.iter_mut()
                .zip(scratch.down.data.iter())
            {
                *xv += ov;
            }
            rmsnorm_into(&scratch.x, &mut scratch.norm);
            // One fused gate/up pass: row r is [gate (glu) | up (glu)];
            // the GLU activation splits it into the gate buffer.
            blk.gateup.matmul_batch_into_fused(&scratch.norm, pool,
                                               &mut scratch.out_t,
                                               &mut scratch.fused_stage,
                                               &mut scratch.gateup);
            scratch.gate.reset2(rows, glu);
            for r in 0..rows {
                let gu = scratch.gateup.row(r);
                let a = scratch.gate.row_mut(r);
                for j in 0..glu {
                    a[j] = silu(gu[j]) * gu[glu + j];
                }
            }
            blk.down.matmul_batch_into(&scratch.gate, pool,
                                       &mut scratch.out_t, &mut scratch.down);
            for (xv, &dv) in scratch.x.data.iter_mut()
                .zip(scratch.down.data.iter())
            {
                *xv += dv;
            }
        }
        if self.recycles_pages() {
            // Out-of-window pages return to the pool. Keyed on the
            // span *start*: a later speculative rollback never rewinds
            // below the span it verified, so the released frontier
            // stays behind every reachable truncation point.
            for (ai, &seq) in scratch.seqs.iter().enumerate() {
                cache.release_before(seq, scratch.starts[ai]
                                     .saturating_sub(self.window));
            }
        }
        rmsnorm_into(&scratch.x, &mut scratch.norm);
        // Only each lane's final span position feeds the head: gather
        // those rows (row-wise identical to running the head over the
        // full chunk and discarding, but prefill never pays vocab-width
        // compute for intermediate positions).
        {
            let (head_in, norm, spans_a) =
                (&mut scratch.head_in, &scratch.norm, &scratch.spans);
            head_in.reset2(spans_a.len(), self.dims.hidden);
            let mut row = 0usize;
            for (ai, &s) in spans_a.iter().enumerate() {
                row += s;
                head_in.row_mut(ai).copy_from_slice(norm.row(row - 1));
            }
        }
        self.head.matmul_batch_into(&scratch.head_in, pool,
                                    &mut scratch.out_t, &mut scratch.logits);
        if scratch.want_span_logits {
            // Verification needs logits at *every* span position (each
            // draft token is checked against the target's distribution
            // at its own position), so the head also runs over the full
            // flattened span batch. Rows stay lane-major and
            // position-contiguous, mirroring `span_tokens`; the final
            // row of each lane's stretch is bitwise the lane's
            // `scratch.logits` row (same kernel, batch-invariant
            // accumulation), which the speculative harness exploits.
            self.head.matmul_batch_into(&scratch.norm, pool,
                                        &mut scratch.out_t,
                                        &mut scratch.span_logits);
        }
    }

    fn retire_state(&self, state: &mut [f32]) {
        if state[0] != 0.0 {
            let seq = state[0] as usize - 1;
            self.lock_cache().cache.free_seq(seq);
            state[0] = 0.0;
        }
    }

    fn supports_rollback(&self) -> bool {
        true
    }

    fn rollback_state(&self, state: &mut [f32], new_len: usize) {
        if state[0] != 0.0 {
            let seq = state[0] as usize - 1;
            self.lock_cache().cache.truncate_seq(seq, new_len);
        } else {
            debug_assert_eq!(new_len, 0,
                             "rollback of an unbound lane must be to 0");
        }
    }

    /// Map the longest pinned, token-verified prefix of `prompt` into
    /// a fresh sequence bound to `state`. Consumes no free pages
    /// ([`KvCache::share_prefix`] only bumps refcounts), so a hit can
    /// never be refused — backpressure shows up later, on the lane's
    /// first *claim* past the shared prefix.
    fn prefix_reuse(&self, state: &mut [f32], prompt: &[u32]) -> usize {
        if state[0] != 0.0 {
            return 0; // already bound: only fresh lanes can map a prefix
        }
        let g = &mut *self.lock_cache();
        let Some((pin_idx, reuse)) =
            g.prefix.lookup(prompt, g.cache.config().page_tokens)
        else {
            return 0;
        };
        debug_assert!(reuse >= 1 && reuse < prompt.len());
        let seq = g.cache.alloc_seq();
        g.cache.share_prefix(g.prefix.pins[pin_idx].seq, seq, reuse);
        state[0] = (seq + 1) as f32;
        reuse
    }

    /// Pin `prompt`'s committed pages: a dedicated sequence maps them
    /// via [`KvCache::share_prefix`] (the donor lane's later growth
    /// copy-on-writes away from the shared tail page, so the pin stays
    /// frozen at prompt contents), and every page boundary of the
    /// prompt is indexed (first pin wins per key). Prompts shorter
    /// than a full page pin nothing — there is no aligned prefix to
    /// share — and a pool with no free page left pins nothing either
    /// (the donor's next claim would bounce off its own pin).
    fn prefix_register(&self, state: &mut [f32], prompt: &[u32]) {
        if state[0] == 0.0 {
            return;
        }
        let src = state[0] as usize - 1;
        let g = &mut *self.lock_cache();
        if g.cache.released_pages(src) > 0 {
            // A windowed lane that already returned out-of-window pages
            // no longer holds the prompt's front — nothing to donate.
            return;
        }
        let pt = g.cache.config().page_tokens;
        if prompt.len() <= pt {
            return;
        }
        let top = ((prompt.len() - 1) / pt) * pt;
        let mut boundaries: Vec<(usize, u64)> = Vec::new();
        let mut b = pt;
        while b <= top {
            let h = hash_tokens(&prompt[..b]);
            if !g.prefix.by_hash.contains_key(&h) {
                boundaries.push((b, h));
            }
            b += pt;
        }
        if boundaries.is_empty() {
            return; // every boundary already pinned by an earlier prompt
        }
        if g.cache.free_page_count() == 0 {
            // A full pool is no place to grow a cache. Pinning now
            // would trap the donor: its very next claim needs one free
            // page (tail copy-on-write, or plain page growth) and gets
            // refused, the eviction hook drops the just-made pin, the
            // requeued donor re-registers on restart — a livelock the
            // stall guard cannot see, because eviction counts as
            // progress. Skipping the pin breaks the cycle: the donor
            // keeps exclusive pages and its in-page claims stay free.
            return;
        }
        debug_assert!(g.cache.seq_len(src) >= prompt.len(),
                      "prefix_register before the prompt is committed");
        let seq = g.cache.alloc_seq();
        g.cache.share_prefix(src, seq, prompt.len());
        let pin_idx = g.prefix.pins.len();
        g.prefix.pins.push(PrefixPin { seq, tokens: prompt.to_vec(),
                                       last_hit: 0 });
        for (b, h) in boundaries {
            g.prefix.by_hash.insert(h, (pin_idx, b));
        }
    }

    /// Evict exactly one prefix pin — the least-recently-hit one
    /// (never-hit pins first) — returning its pages' refcounts to the
    /// live lanes that still map them (pages with no other holder go
    /// back to the free list). The scheduler calls this once per
    /// KV-refused step, so *persistent* pressure drains the whole pin
    /// cache one step at a time, while a transient spike costs only
    /// the coldest pin instead of the entire index.
    fn release_cached_pages(&self) -> bool {
        let g = &mut *self.lock_cache();
        let Some(victim) = g.prefix.lru_pin() else {
            return false;
        };
        let last = g.prefix.pins.len() - 1;
        let pin = g.prefix.pins.swap_remove(victim);
        g.cache.free_seq(pin.seq);
        // Drop the victim's index entries, then repoint the entries of
        // the pin that swap_remove moved into the victim's slot.
        g.prefix.by_hash.retain(|_, v| v.0 != victim);
        if victim != last {
            for v in g.prefix.by_hash.values_mut() {
                if v.0 == last {
                    v.0 = victim;
                }
            }
        }
        true
    }

    fn kv_bytes_per_token(&self) -> f64 {
        self.lock_cache().cache.config().bytes_per_token() as f64
    }

    fn kv_pages_in_use(&self) -> usize {
        self.lock_cache().cache.pages_in_use()
    }

    fn family_label(&self) -> String {
        self.head.label()
    }

    fn effective_bits_per_param(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut params = 0.0f64;
        for l in self.linears() {
            let p = (l.out_features() * l.in_features()) as f64;
            bits += l.effective_bits_per_param() * p;
            params += p;
        }
        bits / params.max(1.0)
    }
}

/// One block of family-agnostic latent f32 attention + MLP weights.
pub struct LatentAttnBlock {
    pub wq: HostTensor,
    pub wk: HostTensor,
    pub wv: HostTensor,
    pub wo: HostTensor,
    pub gate: HostTensor,
    pub up: HostTensor,
    pub down: HostTensor,
}

/// Family-agnostic latent weights for the attention serving model —
/// the [`LatentLm`] analog with per-block q/k/v/o projections, so
/// cross-family attention benches compare storage formats of the
/// *same* model.
pub struct LatentAttnLm {
    pub dims: LmDims,
    pub heads: usize,
    /// Shared kv heads realized models attend with (defaults to
    /// `heads`; see [`LatentAttnLm::with_kv_heads`]).
    pub kv_heads: usize,
    /// Sliding-window width realized models serve with (0 = off).
    pub window: usize,
    /// Windowed layers per global layer (0 = all layers windowed).
    pub window_interleave: usize,
    /// (vocab, hidden) f32 embeddings (stay float in every family).
    pub embed: HostTensor,
    pub blocks: Vec<LatentAttnBlock>,
    /// (vocab, hidden) latent output head.
    pub head: HostTensor,
    /// Ternary scale shards per block matrix (§A.5); head uses 1.
    pub mp: usize,
}

impl LatentAttnLm {
    /// Seeded random latent weights (the synthetic bench/test model).
    pub fn synthetic(dims: LmDims, heads: usize, mp: usize, seed: u64)
                     -> LatentAttnLm {
        assert!(heads >= 1 && dims.hidden % heads == 0,
                "heads {heads} must divide hidden {}", dims.hidden);
        let embed = HostTensor::randn(vec![dims.vocab, dims.hidden], 0.5,
                                      seed ^ 0xA77E0);
        let mut blocks = Vec::with_capacity(dims.layers);
        for l in 0..dims.layers {
            let ls = seed ^ ((l as u64 + 1) << 24);
            let sq = |shape: Vec<usize>, salt: u64| {
                HostTensor::randn(shape, 0.08, ls ^ salt)
            };
            blocks.push(LatentAttnBlock {
                wq: sq(vec![dims.hidden, dims.hidden], 0x11),
                wk: sq(vec![dims.hidden, dims.hidden], 0x12),
                wv: sq(vec![dims.hidden, dims.hidden], 0x13),
                wo: sq(vec![dims.hidden, dims.hidden], 0x14),
                gate: sq(vec![dims.glu, dims.hidden], 0x15),
                up: sq(vec![dims.glu, dims.hidden], 0x16),
                down: sq(vec![dims.hidden, dims.glu], 0x17),
            });
        }
        let head = HostTensor::randn(vec![dims.vocab, dims.hidden], 0.08,
                                     seed ^ 0xA77E1);
        LatentAttnLm { dims, heads, kv_heads: heads,
                       window: 0, window_interleave: 0,
                       embed, blocks, head, mp }
    }

    /// Grouped-query attention: realized models keep only the first
    /// `kv_heads * head_dim` rows of each latent k/v projection (the
    /// shared heads), shrinking both the projection work and
    /// `kv_bytes_per_token` by the head ratio. `kv_heads == heads`
    /// restores classic multi-head attention bitwise.
    pub fn with_kv_heads(mut self, kv_heads: usize) -> LatentAttnLm {
        assert!(kv_heads >= 1 && kv_heads <= self.heads
                    && self.heads % kv_heads == 0,
                "kv_heads {kv_heads} must divide heads {}", self.heads);
        self.kv_heads = kv_heads;
        self
    }

    /// Sliding-window policy for realized models: `window` tokens per
    /// windowed layer (0 = off); every (`interleave`+1)-th layer stays
    /// global when `interleave > 0`. See [`AttnLm::with_window`].
    pub fn with_window(mut self, window: usize, interleave: usize)
                       -> LatentAttnLm {
        self.window = window;
        self.window_interleave = interleave;
        self
    }

    /// Width of one realized kv row: `kv_heads * head_dim`.
    pub fn kv_dim(&self) -> usize {
        self.kv_heads * (self.dims.hidden / self.heads)
    }

    /// A latent k/v projection reduced to the realized kv rows: the
    /// first `kv_dim` rows (checkpoint-native GQA projections are
    /// already that size and pass through untouched).
    fn kv_proj(&self, w: &HostTensor) -> HostTensor {
        let kd = self.kv_dim();
        let (rows, _) = w.dims2();
        if rows == kd {
            w.clone()
        } else {
            slice_rows(w, 0, kd)
        }
    }

    /// Latent attention weights from a trained checkpoint: `embed` plus,
    /// per layer, either the separate `l{i}.attn_{q,k,v}` projections or
    /// a fused row-stacked `l{i}.attn_qkv` (`hidden + 2*kv_dim` rows),
    /// and either `l{i}.mlp_{gate,up}` or a fused `l{i}.mlp_gateup`
    /// (`2*glu` rows), plus `attn_o` and `mlp_down`; the head falls
    /// back to the tied embedding table. The kv head count is inferred
    /// from the k projection's row count over the head dim, so GQA
    /// checkpoints (`kv_dim < hidden`) load without extra flags.
    pub fn from_checkpoint(ck: &Checkpoint, heads: usize)
                           -> Result<LatentAttnLm> {
        let embed = ck.get("embed")
            .ok_or_else(|| anyhow::anyhow!(
                "checkpoint has no 'embed' tensor; cannot build serve model"))?
            .clone();
        let (vocab, hidden) = embed.dims2();
        if heads == 0 || hidden % heads != 0 {
            anyhow::bail!("heads {heads} must divide hidden {hidden}");
        }
        let dh = hidden / heads;
        let mut blocks = Vec::new();
        let mut glu = 0usize;
        let mut kv_heads = heads;
        for l in 0.. {
            let fused_qkv = ck.get(&format!("l{l}.attn_qkv"));
            if fused_qkv.is_none()
                && ck.get(&format!("l{l}.attn_q")).is_none()
            {
                break;
            }
            let get = |name: &str| {
                ck.get(&format!("l{l}.{name}")).ok_or_else(
                    || anyhow::anyhow!(
                        "layer {l}: attention block without {name}"))
            };
            let (wq, wk, wv) = if let Some(qkv) = fused_qkv {
                let (rows, cols) = qkv.dims2();
                if cols != hidden || rows <= hidden
                    || (rows - hidden) % 2 != 0
                {
                    anyhow::bail!(
                        "layer {l}: attn_qkv is {:?}, expected \
                         (hidden + 2*kv_dim, {hidden})", qkv.dims2());
                }
                let kv_dim = (rows - hidden) / 2;
                (slice_rows(qkv, 0, hidden),
                 slice_rows(qkv, hidden, kv_dim),
                 slice_rows(qkv, hidden + kv_dim, kv_dim))
            } else {
                (get("attn_q")?.clone(), get("attn_k")?.clone(),
                 get("attn_v")?.clone())
            };
            let wo = get("attn_o")?;
            let (gate, up) = if let Some(gu) =
                ck.get(&format!("l{l}.mlp_gateup"))
            {
                let (rows, _) = gu.dims2();
                if rows == 0 || rows % 2 != 0 {
                    anyhow::bail!(
                        "layer {l}: mlp_gateup is {:?}, expected \
                         (2*glu, {hidden})", gu.dims2());
                }
                (slice_rows(gu, 0, rows / 2),
                 slice_rows(gu, rows / 2, rows / 2))
            } else {
                (get("mlp_gate")?.clone(), get("mlp_up")?.clone())
            };
            let down = get("mlp_down")?;
            if l == 0 {
                glu = gate.dims2().0;
                let kv_rows = wk.dims2().0;
                if kv_rows == 0 || kv_rows % dh != 0 {
                    anyhow::bail!(
                        "layer 0: attn_k has {kv_rows} rows, expected a \
                         multiple of the head dim {dh}");
                }
                kv_heads = kv_rows / dh;
                if kv_heads > heads || heads % kv_heads != 0 {
                    anyhow::bail!(
                        "layer 0: attn_k implies kv_heads {kv_heads}, \
                         which must divide heads {heads}");
                }
            }
            let kv_dim = kv_heads * dh;
            // Same shape-drift rejection as LatentLm::from_checkpoint:
            // mismatched tensors must fail at build time, not serve
            // truncated garbage.
            for (name, t, want) in [("attn_q", &wq, (hidden, hidden)),
                                    ("attn_k", &wk, (kv_dim, hidden)),
                                    ("attn_v", &wv, (kv_dim, hidden)),
                                    ("attn_o", wo, (hidden, hidden)),
                                    ("mlp_gate", &gate, (glu, hidden)),
                                    ("mlp_up", &up, (glu, hidden)),
                                    ("mlp_down", down, (hidden, glu))] {
                if t.dims2() != want {
                    anyhow::bail!(
                        "layer {l}: {name} is {:?}, expected {:?} (from \
                         embed hidden {hidden}, l0 glu {glu} and l0 \
                         kv_dim {kv_dim})",
                        t.dims2(), want);
                }
            }
            blocks.push(LatentAttnBlock {
                wq,
                wk,
                wv,
                wo: wo.clone(),
                gate,
                up,
                down: down.clone(),
            });
        }
        if blocks.is_empty() {
            anyhow::bail!("checkpoint has no l0.attn_q or l0.attn_qkv — \
                           not an attention LM (serve it with the \
                           decay-state LatentLm instead)");
        }
        let head = ck.get("head").unwrap_or(&embed).clone();
        if head.dims2().1 != hidden {
            anyhow::bail!("head is {:?}, expected (vocab, {hidden})",
                          head.dims2());
        }
        let layers = blocks.len();
        Ok(LatentAttnLm {
            dims: LmDims { vocab, hidden, glu, layers },
            heads,
            kv_heads,
            window: 0,
            window_interleave: 0,
            embed,
            blocks,
            head,
            mp: 1,
        })
    }

    /// Realize every block with fused q/k/v and gate/up projections:
    /// each part is quantized *separately* through `f` (ternary/quant
    /// scales summarize the matrix they came from, so fusing after
    /// compression keeps fused logits bitwise the unfused ones), then
    /// row-stacked into one [`FusedLinear`] per fusion. GQA truncation
    /// of k/v to the shared heads happens here, before compression.
    fn realize<L: LinearFormat>(&self, lanes: usize, max_context: usize,
                                f: impl Fn(&HostTensor) -> L) -> AttnLm<L> {
        AttnLm::new(
            self.dims.clone(), self.heads, self.embed.clone(),
            self.blocks.iter().map(|b| AttnBlock {
                wqkv: FusedLinear::new(vec![f(&b.wq),
                                            f(&self.kv_proj(&b.wk)),
                                            f(&self.kv_proj(&b.wv))]),
                wo: f(&b.wo),
                gateup: FusedLinear::new(vec![f(&b.gate), f(&b.up)]),
                down: f(&b.down),
            }).collect(),
            f(&self.head), lanes, max_context)
            .with_window(self.window, self.window_interleave)
    }

    /// FloatLM storage: the latent f32 weights served directly.
    pub fn build_float(&self, lanes: usize, max_context: usize)
                       -> AttnLm<DenseF32> {
        self.realize(lanes, max_context, |w| DenseF32 { w: w.clone() })
    }

    /// TriLM storage: absmean-ternarized (§A.5, mp shards per block
    /// matrix, single-shard head) and packed 2-bit.
    pub fn build_ternary(&self, lanes: usize, max_context: usize)
                         -> AttnLm<PackedMatrix> {
        let tern = |w: &HostTensor, mp: usize| {
            PackedMatrix::from_ternary(&TernaryTensor::from_latent(w, mp))
        };
        AttnLm::new(
            self.dims.clone(), self.heads, self.embed.clone(),
            self.blocks.iter().map(|b| AttnBlock {
                wqkv: FusedLinear::new(vec![
                    tern(&b.wq, self.mp),
                    tern(&self.kv_proj(&b.wk), self.mp),
                    tern(&self.kv_proj(&b.wv), self.mp),
                ]),
                wo: tern(&b.wo, self.mp),
                gateup: FusedLinear::new(vec![tern(&b.gate, self.mp),
                                              tern(&b.up, self.mp)]),
                down: tern(&b.down, self.mp),
            }).collect(),
            tern(&self.head, 1), lanes, max_context)
            .with_window(self.window, self.window_interleave)
    }

    /// QuantLM storage via round-to-nearest group quantization.
    pub fn build_quant_rtn(&self, bits: u32, group: usize,
                           lanes: usize, max_context: usize)
                           -> AttnLm<QuantPacked> {
        self.realize(lanes, max_context, |w| {
            QuantPacked::from_quant(&QuantTensor::quantize_rtn(w, bits, group))
        })
    }

    /// QuantLM storage via GPTQ with serve-side synthetic calibration:
    /// the latent f32 *attention* forward (GQA + window policy
    /// included, over a real paged KV cache) is driven on seeded token
    /// traffic to accumulate every linear's input Hessian, then each
    /// linear is quantized with second-order error compensation.
    ///
    /// Calibration sees the fused layout by construction: GPTQ's
    /// Hessian is over a linear's *input*, and every row of a fused
    /// stack shares the same input — so quantizing the q/k/v (and
    /// gate/up) parts against their shared accumulator *is* calibrating
    /// the row-stacked fused matrix, row block by row block.
    pub fn build_quant_gptq(&self, bits: u32, group: usize, seed: u64,
                            lanes: usize, max_context: usize)
                            -> Result<AttnLm<QuantPacked>> {
        let (acc_qkv, acc_o, acc_mlp, acc_g, acc_head) =
            self.calibration_hessians(seed);
        let cfg = GptqConfig::new(bits, group);
        let qp = |w: &HostTensor, acc: &HessianAccumulator|
                 -> Result<QuantPacked> {
            Ok(QuantPacked::from_quant(
                &gptq_quantize(w, &acc.finalize(), cfg)?))
        };
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (l, b) in self.blocks.iter().enumerate() {
            blocks.push(AttnBlock {
                wqkv: FusedLinear::new(vec![
                    qp(&b.wq, &acc_qkv[l])?,
                    qp(&self.kv_proj(&b.wk), &acc_qkv[l])?,
                    qp(&self.kv_proj(&b.wv), &acc_qkv[l])?,
                ]),
                wo: qp(&b.wo, &acc_o[l])?,
                gateup: FusedLinear::new(vec![qp(&b.gate, &acc_mlp[l])?,
                                              qp(&b.up, &acc_mlp[l])?]),
                down: qp(&b.down, &acc_g[l])?,
            });
        }
        Ok(AttnLm::new(self.dims.clone(), self.heads, self.embed.clone(),
                       blocks, qp(&self.head, &acc_head)?,
                       lanes, max_context)
            .with_window(self.window, self.window_interleave))
    }

    /// Realize any family as a boxed [`DecodeModel`], page pool sized
    /// for `lanes` concurrent sequences of `max_context` tokens — the
    /// entry point `serve-bench --attn` and the attention test
    /// harnesses use.
    pub fn build(&self, spec: FamilySpec, lanes: usize, max_context: usize)
                 -> Result<Box<dyn DecodeModel>> {
        let model: Box<dyn DecodeModel> = match spec {
            FamilySpec::Float => {
                Box::new(self.build_float(lanes, max_context))
            }
            FamilySpec::Ternary => {
                Box::new(self.build_ternary(lanes, max_context))
            }
            FamilySpec::Quant { bits, group, method: QuantMethod::Rtn } => {
                Box::new(self.build_quant_rtn(bits, group, lanes,
                                              max_context))
            }
            FamilySpec::Quant { bits, group, method: QuantMethod::Gptq } => {
                Box::new(self.build_quant_gptq(bits, group, 0, lanes,
                                               max_context)?)
            }
        };
        Ok(model)
    }

    /// Drive the latent f32 attention forward on seeded token traffic,
    /// accumulating every linear's input Hessian: q/k/v share the
    /// block-input accumulator (identical inputs), o gets the attention
    /// mix, gate/up share the post-attention norm, down gets the
    /// activated GLU, the head gets the final norm.
    #[allow(clippy::type_complexity)]
    fn calibration_hessians(&self, seed: u64)
                            -> (Vec<HessianAccumulator>,
                                Vec<HessianAccumulator>,
                                Vec<HessianAccumulator>,
                                Vec<HessianAccumulator>,
                                HessianAccumulator) {
        let d = &self.dims;
        let mut acc_qkv: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.hidden)).collect();
        let mut acc_o: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.hidden)).collect();
        let mut acc_mlp: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.hidden)).collect();
        let mut acc_g: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.glu)).collect();
        let mut acc_head = HessianAccumulator::new(d.hidden);
        let mut rng = SplitMix64::new(seed ^ 0xA77CA1);
        // The calibration forward mirrors serving exactly: GQA-sized
        // kv rows and the same per-layer window policy, over a real
        // paged cache.
        let kv_dim = self.kv_dim();
        let wks: Vec<HostTensor> =
            self.blocks.iter().map(|b| self.kv_proj(&b.wk)).collect();
        let wvs: Vec<HostTensor> =
            self.blocks.iter().map(|b| self.kv_proj(&b.wv)).collect();
        let mut cache = KvCache::for_lanes(d.layers, kv_dim,
                                           KV_PAGE_TOKENS, CALIB_LANES,
                                           CALIB_STEPS);
        let seqs: Vec<usize> =
            (0..CALIB_LANES).map(|_| cache.alloc_seq()).collect();
        let mut scores = Vec::new();
        for _ in 0..CALIB_STEPS {
            for &s in &seqs {
                cache.begin_token(s)
                    .expect("calibration cache sized for CALIB_STEPS");
            }
            let mut x = HostTensor::zeros(vec![CALIB_LANES, d.hidden]);
            for b in 0..CALIB_LANES {
                x.row_mut(b).copy_from_slice(self.embed.row(
                    rng.below(d.vocab)));
            }
            for (l, blk) in self.blocks.iter().enumerate() {
                let y = rmsnorm(&x);
                acc_qkv[l].add_batch(&y);
                let q = matmul_dense(&y, &blk.wq);
                let k = matmul_dense(&y, &wks[l]);
                let v = matmul_dense(&y, &wvs[l]);
                for (bi, &s) in seqs.iter().enumerate() {
                    cache.write_kv(s, l, k.row(bi), v.row(bi));
                }
                let mut attn =
                    HostTensor::zeros(vec![CALIB_LANES, d.hidden]);
                let win = window_for_layer(self.window,
                                           self.window_interleave, l);
                for (bi, &s) in seqs.iter().enumerate() {
                    let limit = cache.seq_len(s);
                    let first = win.map_or(0, |w| limit.saturating_sub(w));
                    attend_one(&cache, s, l, self.heads, self.kv_heads,
                               q.row(bi), attn.row_mut(bi), &mut scores,
                               first, limit);
                }
                acc_o[l].add_batch(&attn);
                let o = matmul_dense(&attn, &blk.wo);
                for (xv, &ov) in x.data.iter_mut().zip(o.data.iter()) {
                    *xv += ov;
                }
                let y2 = rmsnorm(&x);
                acc_mlp[l].add_batch(&y2);
                let g = matmul_dense(&y2, &blk.gate);
                let u = matmul_dense(&y2, &blk.up);
                let mut a = g;
                for (av, &uv) in a.data.iter_mut().zip(u.data.iter()) {
                    *av = silu(*av) * uv;
                }
                acc_g[l].add_batch(&a);
                let dd = matmul_dense(&a, &blk.down);
                for (xv, &dv) in x.data.iter_mut().zip(dd.data.iter()) {
                    *xv += dv;
                }
            }
            acc_head.add_batch(&rmsnorm(&x));
        }
        (acc_qkv, acc_o, acc_mlp, acc_g, acc_head)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dims() -> LmDims {
        LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }
    }

    fn step_one(m: &dyn DecodeModel, state: &mut Vec<f32>, tok: u32)
                -> HostTensor {
        let mut refs = [state.as_mut_slice()];
        m.step_batch(&mut refs, &[tok], 1)
    }

    #[test]
    fn ternary_and_dense_twins_agree() {
        // Identical weights, different storage: logits must match to fp
        // accumulation noise.
        let (t, d) = TernaryLm::synthetic_pair(small_dims(), 1, 5);
        let mut st_t = t.zero_state();
        let mut st_d = t.zero_state();
        for tok in [3u32, 17, 40] {
            let lt = step_one(&t, &mut st_t, tok);
            let ld = step_one(&d, &mut st_d, tok);
            assert_eq!(lt.shape, vec![1, 64]);
            for (a, b) in lt.data.iter().zip(ld.data.iter()) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn state_carries_context() {
        // The same token after different histories must produce
        // different logits — the state is doing its job.
        let (t, _) = TernaryLm::synthetic_pair(small_dims(), 1, 6);
        let mut s1 = t.zero_state();
        let mut s2 = t.zero_state();
        step_one(&t, &mut s1, 1);
        step_one(&t, &mut s2, 2);
        let a = step_one(&t, &mut s1, 7);
        let b = step_one(&t, &mut s2, 7);
        let diff: f32 = a.data.iter().zip(b.data.iter())
            .map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3, "history ignored (diff {diff})");
    }

    #[test]
    fn out_of_vocab_tokens_wrap() {
        let (t, _) = TernaryLm::synthetic_pair(small_dims(), 1, 7);
        let mut s1 = t.zero_state();
        let mut s2 = t.zero_state();
        let a = step_one(&t, &mut s1, 3);
        let b = step_one(&t, &mut s2, 3 + 64);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn checkpoint_roundtrip_builds_model() {
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), HostTensor::randn(vec![48, 32], 0.1, 2)),
            ("l0.mlp_up".into(), HostTensor::randn(vec![48, 32], 0.1, 3)),
            ("l0.mlp_down".into(), HostTensor::randn(vec![32, 48], 0.1, 4)),
        ]);
        let lm = TernaryLm::from_checkpoint(&ck).unwrap();
        assert_eq!(lm.dims, LmDims { vocab: 64, hidden: 32, glu: 48,
                                     layers: 1 });
        // tied head: (vocab, hidden) packed
        assert_eq!(lm.head.rows, 64);
        assert_eq!(lm.head.cols, 32);
        let mut st = lm.zero_state();
        let logits = step_one(&lm, &mut st, 5);
        assert_eq!(logits.shape, vec![1, 64]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn checkpoint_without_linears_is_rejected() {
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![8, 4], 0.5, 1)),
        ]);
        assert!(TernaryLm::from_checkpoint(&ck).is_err());
    }

    #[test]
    fn checkpoint_with_inconsistent_shapes_is_rejected() {
        // mlp_up rows disagree with l0's glu: must error at build time,
        // not serve truncated garbage.
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), HostTensor::randn(vec![48, 32], 0.1, 2)),
            ("l0.mlp_up".into(), HostTensor::randn(vec![40, 32], 0.1, 3)),
            ("l0.mlp_down".into(), HostTensor::randn(vec![32, 48], 0.1, 4)),
        ]);
        let err = LatentLm::from_checkpoint(&ck).unwrap_err().to_string();
        assert!(err.contains("mlp_up"), "unhelpful error: {err}");
        // A head with the wrong input width is rejected too.
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), HostTensor::randn(vec![48, 32], 0.1, 2)),
            ("l0.mlp_up".into(), HostTensor::randn(vec![48, 32], 0.1, 3)),
            ("l0.mlp_down".into(), HostTensor::randn(vec![32, 48], 0.1, 4)),
            ("head".into(), HostTensor::randn(vec![64, 16], 0.1, 5)),
        ]);
        assert!(LatentLm::from_checkpoint(&ck).is_err());
    }

    #[test]
    fn family_spec_parses_cli_tokens() {
        assert_eq!(FamilySpec::parse("float", 128), Some(FamilySpec::Float));
        assert_eq!(FamilySpec::parse("TriLM", 128), Some(FamilySpec::Ternary));
        assert_eq!(FamilySpec::parse("quant4", 64),
                   Some(FamilySpec::Quant { bits: 4, group: 64,
                                            method: QuantMethod::Rtn }));
        assert_eq!(FamilySpec::parse("gptq3", 128),
                   Some(FamilySpec::Quant { bits: 3, group: 128,
                                            method: QuantMethod::Gptq }));
        assert_eq!(FamilySpec::parse("quant9", 128), None);
        assert_eq!(FamilySpec::parse("fp17", 128), None);
    }

    #[test]
    fn every_family_builds_and_steps() {
        let latent = LatentLm::synthetic(small_dims(), 1, 8);
        let specs = [
            FamilySpec::Float,
            FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
            FamilySpec::Ternary,
        ];
        for spec in specs {
            let m = latent.build(spec).unwrap();
            assert_eq!(m.dims(), &small_dims(), "{}", spec.label());
            let mut st = vec![0.0f32; 32];
            let logits = step_one(m.as_ref(), &mut st, 9);
            assert_eq!(logits.shape, vec![1, 64], "{}", spec.label());
            assert!(logits.data.iter().all(|v| v.is_finite()),
                    "{}: non-finite logits", spec.label());
        }
    }

    #[test]
    fn step_batch_into_matches_step_batch_bitwise() {
        // The pooled/scratch decode step is the allocating step, run on
        // different plumbing: logits AND updated states must be
        // bitwise identical, for every family, with one scratch reused
        // across families and steps.
        let latent = LatentLm::synthetic(small_dims(), 1, 12);
        let pool = WorkerPool::new(2);
        let mut scratch = DecodeScratch::new();
        let specs = [
            FamilySpec::Float,
            FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Ternary,
        ];
        for spec in specs {
            let m = latent.build(spec).unwrap();
            let mut st_a = vec![vec![0.0f32; 32]; 3];
            let mut st_b = st_a.clone();
            for (step, toks) in [[1u32, 9, 60], [4, 4, 31]].iter().enumerate() {
                let mut refs_a: Vec<&mut [f32]> =
                    st_a.iter_mut().map(|s| s.as_mut_slice()).collect();
                let want = m.step_batch(&mut refs_a, toks, pool.threads());
                let mut refs_b: Vec<&mut [f32]> =
                    st_b.iter_mut().map(|s| s.as_mut_slice()).collect();
                m.step_batch_into(&mut refs_b, toks, &pool, &mut scratch);
                assert_eq!(scratch.logits.shape, want.shape,
                           "{} step {step}", spec.label());
                assert_eq!(scratch.logits.data, want.data,
                           "{} step {step}: logits diverge", spec.label());
                assert_eq!(st_a, st_b,
                           "{} step {step}: states diverge", spec.label());
            }
        }
    }

    #[test]
    fn effective_bits_order_matches_table4() {
        // FloatLM > QuantLM 4 > QuantLM 3 > TriLM — the paper's bit
        // budget axis, measured on the serving models themselves.
        let latent = LatentLm::synthetic(small_dims(), 1, 9);
        let f = latent.build_float().effective_bits_per_param();
        let q4 = latent.build_quant_rtn(4, 128).effective_bits_per_param();
        let q3 = latent.build_quant_rtn(3, 128).effective_bits_per_param();
        let t = latent.build_ternary().effective_bits_per_param();
        assert!(f > q4 && q4 > q3 && q3 > t,
                "bits ordering broken: f={f} q4={q4} q3={q3} t={t}");
        assert_eq!(latent.build_float().family_label(), "fp32");
        assert_eq!(latent.build_ternary().family_label(), "ternary");
    }

    #[test]
    fn quant_families_approximate_float_logits() {
        // Storage formats of the same latent weights: the 4-bit model
        // must land closer to the float logits than the 3-bit model on
        // average (more bits, less quantization error).
        let latent = LatentLm::synthetic(small_dims(), 1, 10);
        let f = latent.build_float();
        let mean_err = |m: &dyn DecodeModel| -> f64 {
            let mut st_a = vec![0.0f32; 32];
            let mut st_b = vec![0.0f32; 32];
            let mut total = 0.0f64;
            let mut n = 0usize;
            for tok in [1u32, 30, 55] {
                let la = step_one(m, &mut st_a, tok);
                let lb = step_one(&f, &mut st_b, tok);
                total += la.data.iter().zip(lb.data.iter())
                    .map(|(x, y)| (x - y).abs() as f64).sum::<f64>();
                n += la.data.len();
            }
            total / n as f64
        };
        let e4 = mean_err(&latent.build_quant_rtn(4, 128));
        let e3 = mean_err(&latent.build_quant_rtn(3, 128));
        assert!(e4 < e3, "4-bit err {e4} should beat 3-bit err {e3}");
        assert!(e4 > 0.0, "quantization must not be a no-op");
    }

    fn attn_latent(seed: u64) -> LatentAttnLm {
        LatentAttnLm::synthetic(small_dims(), 4, 1, seed)
    }

    #[test]
    fn attn_history_carries_context_through_the_cache() {
        // Two lanes fed different first tokens then the same second
        // token: the cached context must make their logits diverge.
        let lm = attn_latent(21).build_float(2, 8);
        let mut s = vec![vec![0.0f32; 32]; 2];
        let mut refs: Vec<&mut [f32]> =
            s.iter_mut().map(|v| v.as_mut_slice()).collect();
        lm.step_batch(&mut refs, &[1, 2], 1);
        let mut refs: Vec<&mut [f32]> =
            s.iter_mut().map(|v| v.as_mut_slice()).collect();
        let logits = lm.step_batch(&mut refs, &[7, 7], 1);
        let diff: f32 = logits.row(0).iter().zip(logits.row(1))
            .map(|(a, b)| (a - b).abs()).sum();
        assert!(diff > 1e-3, "attention ignored history (diff {diff})");
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn attn_lane_is_batch_composition_invariant() {
        // The scheduler contract at the model level: a lane decoding
        // alone and the same lane decoding beside a neighbour produce
        // bitwise-identical logits (two instances: the cache is
        // stateful).
        let latent = attn_latent(22);
        let solo = latent.build_float(1, 8);
        let pair = latent.build_float(2, 8);
        let mut s1 = vec![0.0f32; 32];
        let mut p = vec![vec![0.0f32; 32]; 2];
        for (step, (tok_a, tok_b)) in [(3u32, 50u32), (9, 1)].iter()
            .enumerate()
        {
            let mut refs = [s1.as_mut_slice()];
            let want = solo.step_batch(&mut refs, &[*tok_a], 1);
            let mut refs: Vec<&mut [f32]> =
                p.iter_mut().map(|v| v.as_mut_slice()).collect();
            let got = pair.step_batch(&mut refs, &[*tok_a, *tok_b], 1);
            assert_eq!(want.data.as_slice(), got.row(0),
                       "step {step}: batch neighbour changed lane 0");
        }
    }

    #[test]
    fn attn_every_family_builds_and_steps() {
        let latent = attn_latent(23);
        let specs = [
            FamilySpec::Float,
            FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
            FamilySpec::Ternary,
        ];
        for spec in specs {
            let m = latent.build(spec, 1, 8).unwrap();
            assert_eq!(m.dims(), &small_dims(), "{}", spec.label());
            assert_eq!(m.kv_bytes_per_token(), (2 * 2 * 32 * 4) as f64,
                       "{}", spec.label());
            let mut st = vec![0.0f32; 32];
            let logits = step_one(m.as_ref(), &mut st, 9);
            assert_eq!(logits.shape, vec![1, 64], "{}", spec.label());
            assert!(logits.data.iter().all(|v| v.is_finite()),
                    "{}: non-finite logits", spec.label());
            assert_ne!(st[0], 0.0, "{}: lane did not bind a sequence",
                       spec.label());
        }
    }

    #[test]
    fn attn_step_batch_into_matches_step_batch_bitwise() {
        // Pooled/scratch vs allocating/scoped, on two instances holding
        // identical weights (the cache is stateful, so one instance
        // cannot run both paths): logits AND state tags must be
        // bitwise identical, with one scratch reused across families.
        let latent = attn_latent(24);
        let pool = WorkerPool::new(2);
        let mut scratch = DecodeScratch::new();
        let specs = [
            FamilySpec::Float,
            FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Ternary,
        ];
        for spec in specs {
            let m_a = latent.build(spec, 3, 8).unwrap();
            let m_b = latent.build(spec, 3, 8).unwrap();
            let mut st_a = vec![vec![0.0f32; 32]; 3];
            let mut st_b = st_a.clone();
            for (step, toks) in [[1u32, 9, 60], [4, 4, 31]].iter().enumerate() {
                let mut refs_a: Vec<&mut [f32]> =
                    st_a.iter_mut().map(|s| s.as_mut_slice()).collect();
                let want = m_a.step_batch(&mut refs_a, toks, pool.threads());
                let mut refs_b: Vec<&mut [f32]> =
                    st_b.iter_mut().map(|s| s.as_mut_slice()).collect();
                m_b.step_batch_into(&mut refs_b, toks, &pool, &mut scratch);
                assert_eq!(scratch.logits.shape, want.shape,
                           "{} step {step}", spec.label());
                assert_eq!(scratch.logits.data, want.data,
                           "{} step {step}: logits diverge", spec.label());
                assert_eq!(st_a, st_b,
                           "{} step {step}: states diverge", spec.label());
            }
        }
    }

    #[test]
    fn attn_retire_recycles_pages_and_rebinding_is_clean() {
        // Lane lifecycle: stepping binds a sequence and claims pages;
        // retire_state frees them; a rebound lane on the recycled pages
        // decodes exactly like a fresh model (no stale-KV leakage).
        let latent = attn_latent(25);
        let lm = latent.build_float(1, 8);
        let mut st = vec![0.0f32; 32];
        let first_a = step_one(&lm, &mut st, 3);
        step_one(&lm, &mut st, 9);
        assert_eq!(lm.kv_live_seqs(), 1);
        assert!(lm.kv_pages_in_use() >= 1);
        lm.retire_state(&mut st);
        assert_eq!(st[0], 0.0, "retire must clear the binding tag");
        assert_eq!(lm.kv_live_seqs(), 0);
        assert_eq!(lm.kv_pages_in_use(), 0);
        // Second retire on an unbound state is a no-op, not a crash.
        lm.retire_state(&mut st);
        let first_b = step_one(&lm, &mut st, 3);
        assert_eq!(first_a.data, first_b.data,
                   "recycled pages leaked stale context");
    }

    #[test]
    fn attn_overcommitted_spans_reject_gracefully() {
        // Polarity flip of the old overcommit-panic test: a cache sized
        // for one lane cannot serve two concurrent lanes, but the span
        // step path now *rejects* the second lane (backpressure) instead
        // of panicking — the first lane serves normally, the refused
        // lane's state stays unbound and nothing leaks from the refusal.
        let lm = attn_latent(26).build_float(1, 4);
        let pool = WorkerPool::new(1);
        let mut scratch = DecodeScratch::new();
        let mut s = vec![vec![0.0f32; 32]; 2];
        let mut refs: Vec<&mut [f32]> =
            s.iter_mut().map(|v| v.as_mut_slice()).collect();
        lm.step_spans_into(&mut refs, &[1, 2], &[1, 1], &pool, &mut scratch);
        drop(refs);
        assert_eq!(scratch.rejected, vec![1]);
        assert_eq!(scratch.logits.shape, vec![1, 64],
                   "one logits row for the one lane that ran");
        assert!(scratch.logits.data.iter().all(|v| v.is_finite()));
        assert_ne!(s[0][0], 0.0, "accepted lane must be bound");
        assert_eq!(s[1][0], 0.0, "rejected lane must stay unbound");
        assert_eq!(lm.kv_live_seqs(), 1,
                   "a refused admission must not leak a sequence");
        // Once the first lane retires, the refused lane admits cleanly.
        lm.retire_state(&mut s[0]);
        let mut refs: Vec<&mut [f32]> =
            s.iter_mut().map(|v| v.as_mut_slice()).collect();
        lm.step_spans_into(&mut refs, &[1, 2], &[1, 1], &pool, &mut scratch);
        assert_eq!(scratch.rejected, vec![1],
                   "lane 0 rebinds first and wins the single page again");
    }

    #[test]
    #[should_panic(expected = "out of pages")]
    fn attn_legacy_step_batch_still_panics_on_overcommit() {
        // The legacy step_batch entry point has no rejection channel:
        // overcommit there stays a loud panic (never silent garbage).
        let lm = attn_latent(26).build_float(1, 4);
        let mut s = vec![vec![0.0f32; 32]; 2];
        let mut refs: Vec<&mut [f32]> =
            s.iter_mut().map(|v| v.as_mut_slice()).collect();
        lm.step_batch(&mut refs, &[1, 2], 1);
    }

    #[test]
    fn attn_span_step_is_bitwise_identical_to_token_steps() {
        // The chunked-prefill tentpole at the model level: one span
        // step over ragged chunks [3, 2] must produce bitwise the
        // logits and binding tags that three/two one-token steps
        // produce on a twin instance (same weights, own cache).
        let latent = attn_latent(31);
        for spec in [FamilySpec::Float, FamilySpec::Ternary] {
            let chunked = latent.build(spec, 2, 8).unwrap();
            let tokenwise = latent.build(spec, 2, 8).unwrap();
            let pool = WorkerPool::new(2);
            let mut scratch = DecodeScratch::new();
            let toks = [3u32, 9, 60, 4, 31]; // lane 0: 3,9,60; lane 1: 4,31
            let mut sc = vec![vec![0.0f32; 32]; 2];
            let mut refs: Vec<&mut [f32]> =
                sc.iter_mut().map(|v| v.as_mut_slice()).collect();
            chunked.step_spans_into(&mut refs, &toks, &[3, 2], &pool,
                                    &mut scratch);
            drop(refs);
            assert!(scratch.rejected.is_empty(), "{}", spec.label());
            assert_eq!(scratch.logits.shape, vec![2, 64],
                       "{}: one logits row per lane", spec.label());

            // Reference: the scoped allocating one-token path, ragged
            // tail (lane 1 has no third token).
            let mut st = vec![vec![0.0f32; 32]; 2];
            let mut refs: Vec<&mut [f32]> =
                st.iter_mut().map(|v| v.as_mut_slice()).collect();
            tokenwise.step_batch(&mut refs, &[3, 4], 1);
            let mut refs: Vec<&mut [f32]> =
                st.iter_mut().map(|v| v.as_mut_slice()).collect();
            let l2 = tokenwise.step_batch(&mut refs, &[9, 31], 1);
            let mut refs = [st[0].as_mut_slice()];
            let l3 = tokenwise.step_batch(&mut refs, &[60], 1);
            assert_eq!(scratch.logits.row(0), l3.row(0),
                       "{}: lane 0 chunk-of-3 logits diverge",
                       spec.label());
            assert_eq!(scratch.logits.row(1), l2.row(1),
                       "{}: lane 1 chunk-of-2 logits diverge",
                       spec.label());
            assert_eq!(sc, st, "{}: binding tags diverge", spec.label());
        }
    }

    #[test]
    fn attn_effective_bits_order_matches_table4() {
        let latent = attn_latent(27);
        let f = latent.build_float(1, 4).effective_bits_per_param();
        let q4 = latent.build_quant_rtn(4, 128, 1, 4)
            .effective_bits_per_param();
        let q3 = latent.build_quant_rtn(3, 128, 1, 4)
            .effective_bits_per_param();
        let t = latent.build_ternary(1, 4).effective_bits_per_param();
        assert!(f > q4 && q4 > q3 && q3 > t,
                "bits ordering broken: f={f} q4={q4} q3={q3} t={t}");
        // 7 linears per block + head: the label comes from the head.
        assert_eq!(latent.build_float(1, 4).family_label(), "fp32");
        assert_eq!(latent.build_float(1, 4).linears().len(), 7 * 2 + 1);
    }

    #[test]
    fn attn_checkpoint_roundtrip_builds_model() {
        let h = |shape: Vec<usize>, seed: u64| {
            HostTensor::randn(shape, 0.1, seed)
        };
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.attn_q".into(), h(vec![32, 32], 2)),
            ("l0.attn_k".into(), h(vec![32, 32], 3)),
            ("l0.attn_v".into(), h(vec![32, 32], 4)),
            ("l0.attn_o".into(), h(vec![32, 32], 5)),
            ("l0.mlp_gate".into(), h(vec![48, 32], 6)),
            ("l0.mlp_up".into(), h(vec![48, 32], 7)),
            ("l0.mlp_down".into(), h(vec![32, 48], 8)),
        ]);
        let latent = LatentAttnLm::from_checkpoint(&ck, 4).unwrap();
        assert_eq!(latent.dims, LmDims { vocab: 64, hidden: 32, glu: 48,
                                         layers: 1 });
        let lm = latent.build_ternary(1, 8);
        let mut st = vec![0.0f32; 32];
        let logits = step_one(&lm, &mut st, 5);
        assert_eq!(logits.shape, vec![1, 64]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
        // Missing attn tensors -> not an attention checkpoint.
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), h(vec![48, 32], 6)),
            ("l0.mlp_up".into(), h(vec![48, 32], 7)),
            ("l0.mlp_down".into(), h(vec![32, 48], 8)),
        ]);
        let err = LatentAttnLm::from_checkpoint(&ck, 4)
            .unwrap_err().to_string();
        assert!(err.contains("attn_q"), "unhelpful error: {err}");
        // Heads must divide hidden.
        assert!(LatentAttnLm::from_checkpoint(&ck, 5).is_err());
    }

    #[test]
    fn decay_model_reports_no_kv_and_ignores_retire() {
        let latent = LatentLm::synthetic(small_dims(), 1, 28);
        let m = latent.build_float();
        assert_eq!(m.kv_bytes_per_token(), 0.0);
        let mut st = vec![1.5f32; 32];
        m.retire_state(&mut st);
        assert_eq!(st, vec![1.5f32; 32], "default retire must be a no-op");
    }

    #[test]
    fn gptq_family_is_deterministic() {
        // Same latent + same seed -> bitwise identical quantized model
        // (calibration is seeded, not wall-clock driven).
        let latent = LatentLm::synthetic(small_dims(), 1, 11);
        let a = latent.build_quant_gptq(4, 128, 3).unwrap();
        let b = latent.build_quant_gptq(4, 128, 3).unwrap();
        for (la, lb) in a.linears().iter().zip(b.linears().iter()) {
            assert_eq!(la.bytes, lb.bytes);
            assert_eq!(la.scales, lb.scales);
        }
    }

    #[test]
    fn attn_gqa_matches_replicated_head_mha_reference() {
        // GQA ground truth: a 4-head model sharing 2 kv heads must be
        // bitwise identical to the full MHA model whose k/v projection
        // rows are the shared rows replicated per query-head group —
        // the only difference is that GQA stores (and projects) each
        // shared head once.
        let heads = 4usize;
        let kv_heads = 2usize;
        let dh = 32 / heads;
        let group = heads / kv_heads;
        let gqa = LatentAttnLm::synthetic(small_dims(), heads, 1, 33)
            .with_kv_heads(kv_heads);
        let mut mha = LatentAttnLm::synthetic(small_dims(), heads, 1, 33);
        for b in &mut mha.blocks {
            for w in [&mut b.wk, &mut b.wv] {
                let mut rep = HostTensor::zeros(vec![32, 32]);
                for h in 0..heads {
                    let src = (h / group) * dh * 32;
                    rep.data[h * dh * 32..(h + 1) * dh * 32]
                        .copy_from_slice(&w.data[src..src + dh * 32]);
                }
                *w = rep;
            }
        }
        let mg = gqa.build_float(1, 8);
        let mr = mha.build_float(1, 8);
        assert_eq!(mg.kv_heads, kv_heads);
        assert_eq!(mg.kv_bytes_per_token() * group as f64,
                   mr.kv_bytes_per_token(),
                   "kv bytes must shrink by the head ratio");
        let mut sg = vec![0.0f32; 32];
        let mut sr = vec![0.0f32; 32];
        for tok in [3u32, 9, 60, 4, 31] {
            let lg = step_one(&mg, &mut sg, tok);
            let lr = step_one(&mr, &mut sr, tok);
            assert_eq!(lg.data, lr.data,
                       "GQA diverged from the replicated-head reference");
        }
    }

    #[test]
    fn attn_window_covering_context_is_bitwise_the_unwindowed_model() {
        // The standing invariant: window >= context must be invisible,
        // per family; a genuinely small window must not be.
        let latent = attn_latent(34);
        let wide = attn_latent(34).with_window(8, 0);
        let narrow = attn_latent(34).with_window(2, 0);
        for spec in [FamilySpec::Float, FamilySpec::Ternary] {
            let plain = latent.build(spec, 1, 8).unwrap();
            let w8 = wide.build(spec, 1, 8).unwrap();
            let w2 = narrow.build(spec, 1, 8).unwrap();
            let (mut sp, mut s8, mut s2) =
                (vec![0.0f32; 32], vec![0.0f32; 32], vec![0.0f32; 32]);
            let mut w2_diverged = false;
            for (i, tok) in [3u32, 9, 60, 4, 31, 7].iter().enumerate() {
                let lp = step_one(plain.as_ref(), &mut sp, *tok);
                let l8 = step_one(w8.as_ref(), &mut s8, *tok);
                let l2 = step_one(w2.as_ref(), &mut s2, *tok);
                assert_eq!(lp.data, l8.data,
                           "{} step {i}: covering window changed logits",
                           spec.label());
                w2_diverged |= lp.data != l2.data;
            }
            assert!(w2_diverged,
                    "{}: a 2-token window must actually truncate context",
                    spec.label());
        }
    }

    #[test]
    fn attn_interleaved_global_layers_escape_the_window() {
        // window:global interleave: with interleave = 1 on a 2-layer
        // model, layer 0 is windowed and layer 1 global — the model
        // must differ from both the unwindowed and the all-windowed
        // policies once context exceeds the window.
        let plain = attn_latent(35).build_float(1, 16);
        let mixed = attn_latent(35).with_window(2, 1).build_float(1, 16);
        let full = attn_latent(35).with_window(2, 0).build_float(1, 16);
        let (mut sp, mut sm, mut sf) =
            (vec![0.0f32; 32], vec![0.0f32; 32], vec![0.0f32; 32]);
        let (mut vs_plain, mut vs_full) = (false, false);
        for tok in [3u32, 9, 60, 4, 31, 7, 12, 50] {
            let lp = step_one(&plain, &mut sp, tok);
            let lm = step_one(&mixed, &mut sm, tok);
            let lf = step_one(&full, &mut sf, tok);
            vs_plain |= lm.data != lp.data;
            vs_full |= lm.data != lf.data;
        }
        assert!(vs_plain, "interleaved window never truncated context");
        assert!(vs_full, "global layer of the interleave was windowed too");
        // A mixed policy cannot recycle pages (the global layers still
        // need the full history), so pages grow like the plain model.
        assert_eq!(mixed.kv_pages_in_use(), plain.kv_pages_in_use());
    }

    #[test]
    fn attn_windowed_lanes_plateau_instead_of_growing() {
        // Page recycling: with every layer windowed, out-of-window
        // pages return to the pool and a long-running lane's footprint
        // plateaus; the unwindowed twin keeps growing.
        let windowed = attn_latent(36).with_window(4, 0).build_float(1, 128);
        let plain = attn_latent(36).build_float(1, 128);
        let mut sw = vec![0.0f32; 32];
        let mut sp = vec![0.0f32; 32];
        let mut plateau = 0usize;
        for i in 0..96u32 {
            step_one(&windowed, &mut sw, i % 64);
            step_one(&plain, &mut sp, i % 64);
            if i == 47 {
                plateau = windowed.kv_pages_in_use();
            }
        }
        assert_eq!(windowed.kv_pages_in_use(), plateau,
                   "windowed lane footprint must plateau");
        assert!(windowed.kv_pages_in_use() < plain.kv_pages_in_use(),
                "windowed lane must hold fewer pages than unwindowed \
                 ({} vs {})", windowed.kv_pages_in_use(),
                plain.kv_pages_in_use());
        // Retire still returns everything (released front pages were
        // already freed; the rest free now).
        windowed.retire_state(&mut sw);
        assert_eq!(windowed.kv_pages_in_use(), 0);
    }

    #[test]
    fn attn_fused_checkpoint_names_load_like_separate_ones() {
        // A checkpoint may store the projections pre-fused
        // (l{l}.attn_qkv with hidden + 2*kv_dim rows, l{l}.mlp_gateup
        // with 2*glu rows); it must build the same model the separate
        // names build — here with a GQA kv_dim of one head.
        let h = |shape: Vec<usize>, seed: u64| {
            HostTensor::randn(shape, 0.1, seed)
        };
        let (wq, wk, wv) = (h(vec![32, 32], 2), h(vec![8, 32], 3),
                            h(vec![8, 32], 4));
        let (gate, up) = (h(vec![48, 32], 6), h(vec![48, 32], 7));
        let mut qkv = HostTensor::zeros(vec![32 + 16, 32]);
        qkv.data[..32 * 32].copy_from_slice(&wq.data);
        qkv.data[32 * 32..40 * 32].copy_from_slice(&wk.data);
        qkv.data[40 * 32..].copy_from_slice(&wv.data);
        let mut gu = HostTensor::zeros(vec![96, 32]);
        gu.data[..48 * 32].copy_from_slice(&gate.data);
        gu.data[48 * 32..].copy_from_slice(&up.data);
        let embed = HostTensor::randn(vec![64, 32], 0.5, 1);
        let common = vec![
            ("embed".to_string(), embed.clone()),
            ("l0.attn_o".to_string(), h(vec![32, 32], 5)),
            ("l0.mlp_down".to_string(), h(vec![32, 48], 8)),
        ];
        let mut sep = common.clone();
        sep.extend([("l0.attn_q".to_string(), wq),
                    ("l0.attn_k".to_string(), wk),
                    ("l0.attn_v".to_string(), wv),
                    ("l0.mlp_gate".to_string(), gate),
                    ("l0.mlp_up".to_string(), up)]);
        let mut fused = common;
        fused.extend([("l0.attn_qkv".to_string(), qkv),
                      ("l0.mlp_gateup".to_string(), gu)]);
        let a = LatentAttnLm::from_checkpoint(&Checkpoint::new(sep), 4)
            .unwrap();
        let b = LatentAttnLm::from_checkpoint(&Checkpoint::new(fused), 4)
            .unwrap();
        assert_eq!(a.kv_heads, 1, "kv_heads inferred from attn_k rows");
        assert_eq!(b.kv_heads, 1);
        let ma = a.build_float(1, 8);
        let mb = b.build_float(1, 8);
        assert_eq!(ma.kv_bytes_per_token(), (2 * 1 * 8 * 4) as f64,
                   "one kv head of dh=8 across 1 layer");
        let mut sa = vec![0.0f32; 32];
        let mut sb = vec![0.0f32; 32];
        for tok in [5u32, 11, 40] {
            let la = step_one(&ma, &mut sa, tok);
            let lb = step_one(&mb, &mut sb, tok);
            assert_eq!(la.data, lb.data,
                       "fused and separate checkpoint names diverge");
        }
    }

    #[test]
    fn attn_prefix_eviction_is_one_pin_at_a_time_lru_first() {
        // The eviction bugfix at the model level: each
        // release_cached_pages call drops exactly one pin — the
        // least-recently-hit — so a transient pressure spike costs the
        // coldest pin, not the whole cache.
        let lm = attn_latent(37).build_float(4, 64);
        let prompts: [&[u32]; 2] = [
            &[1, 2, 3, 4, 5, 6, 7, 8, 9, 10, 11, 12, 13, 14, 15, 16, 17],
            &[9, 8, 7, 6, 5, 4, 3, 2, 1, 0, 10, 20, 30, 40, 50, 60, 61],
        ];
        let mut states = Vec::new();
        for prompt in prompts {
            let mut st = vec![0.0f32; 32];
            for &tok in prompt {
                step_one_state(&lm, &mut st, tok);
            }
            lm.prefix_register(&mut st, prompt);
            states.push(st);
        }
        assert_eq!(lm.kv_prefix_pins(), 2);
        // Hit pin 0 so pin 1 becomes the LRU victim.
        let mut fresh = vec![0.0f32; 32];
        assert!(lm.prefix_reuse(&mut fresh, prompts[0]) > 0);
        lm.retire_state(&mut fresh);
        assert!(lm.release_cached_pages(), "one pin must be evicted");
        assert_eq!(lm.kv_prefix_pins(), 1,
                   "eviction must drop exactly one pin");
        // The survivor is the recently-hit prompt: it still serves.
        let mut fresh = vec![0.0f32; 32];
        assert!(lm.prefix_reuse(&mut fresh, prompts[0]) > 0,
                "recently-hit pin must survive the first eviction");
        lm.retire_state(&mut fresh);
        let mut fresh = vec![0.0f32; 32];
        assert_eq!(lm.prefix_reuse(&mut fresh, prompts[1]), 0,
                   "LRU pin must be the one evicted");
        // Persistent pressure drains the rest, one call at a time.
        assert!(lm.release_cached_pages());
        assert!(!lm.release_cached_pages(), "no pins left to evict");
        assert_eq!(lm.kv_prefix_pins(), 0);
    }

    /// `step_one` for tests that keep the state vector (not the logits).
    fn step_one_state(m: &dyn DecodeModel, state: &mut Vec<f32>, tok: u32) {
        let mut refs = [state.as_mut_slice()];
        m.step_batch(&mut refs, &[tok], 1);
    }
}
