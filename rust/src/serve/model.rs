//! CPU decode models driven by the serve scheduler.
//!
//! The PJRT transformer graphs remain the fidelity path for training
//! and evaluation; serving instead runs a compact gated-MLP language
//! model directly on the packed ternary kernels, because that is the
//! layer the paper's §2.1 bandwidth argument lives in: per decode step
//! every linear is one batched (batch x in) @ (out x in)^T against
//! 2-bit weights. Long-range context is carried by a per-lane
//! exponential state (updated after each step) instead of a KV cache,
//! which keeps every lane's computation independent of its batch
//! neighbours — the property the scheduler's determinism guarantee
//! (batch-1 == batch-8 token streams) is built on.
//!
//! Two weight-identical implementations exist so benches and tests can
//! compare storage formats, not architectures:
//!
//! - [`TernaryLm`]: packed 2-bit weights through
//!   [`matmul_ternary_packed`] (the serving hot path).
//! - [`DenseLm`]: the *dequantized* f32 twin through [`matmul_dense`]
//!   (the FloatLM-storage baseline; identical math up to fp rounding).

use crate::checkpoint::Checkpoint;
use crate::runtime::HostTensor;
use crate::ternary::{matmul_dense, matmul_ternary_packed, PackedMatrix,
                     TernaryTensor};
use crate::Result;

/// Architecture sizes of a decode model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LmDims {
    pub vocab: usize,
    pub hidden: usize,
    pub glu: usize,
    pub layers: usize,
}

/// Per-lane context state decay: `state' = DECAY*state + (1-DECAY)*x`.
pub const STATE_DECAY: f32 = 0.5;

const RMS_EPS: f32 = 1e-6;

/// A model the scheduler can drive: one batched decode step at a time.
pub trait DecodeModel {
    fn dims(&self) -> &LmDims;

    /// Advance every lane by one token. `states[i]` is lane i's hidden
    /// context (len = `dims().hidden`, updated in place); `tokens[i]`
    /// is the token it consumes. Returns (batch, vocab) logits.
    ///
    /// Contract: lane i's outputs and state update depend only on
    /// (`states[i]`, `tokens[i]`) — never on the other lanes — so a
    /// request decodes identically at any batch size.
    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  threads: usize) -> HostTensor;
}

/// One gated-MLP residual block, packed ternary weights.
pub struct TernaryBlock {
    /// (glu, hidden)
    pub gate: PackedMatrix,
    /// (glu, hidden)
    pub up: PackedMatrix,
    /// (hidden, glu)
    pub down: PackedMatrix,
}

/// The packed-ternary serving model. Embeddings stay f32 (the paper
/// keeps embeddings in halfprec; §2.1).
pub struct TernaryLm {
    pub dims: LmDims,
    /// (vocab, hidden) f32 input embeddings.
    pub embed: HostTensor,
    pub blocks: Vec<TernaryBlock>,
    /// (vocab, hidden) packed output head.
    pub head: PackedMatrix,
}

/// The dequantized-f32 twin of [`TernaryLm`] (identical weights).
pub struct DenseLm {
    pub dims: LmDims,
    pub embed: HostTensor,
    pub blocks: Vec<DenseBlock>,
    pub head: HostTensor,
}

pub struct DenseBlock {
    pub gate: HostTensor,
    pub up: HostTensor,
    pub down: HostTensor,
}

#[inline]
fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Row-wise RMS norm (no learned gain — the serve model keeps norms
/// parameter-free so checkpoint import only needs the linears).
fn rmsnorm(x: &HostTensor) -> HostTensor {
    let (rows, cols) = x.dims2();
    let mut out = x.clone();
    for r in 0..rows {
        let row = out.row_mut(r);
        let ms: f32 = row.iter().map(|v| v * v).sum::<f32>() / cols as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for v in row {
            *v *= inv;
        }
    }
    out
}

/// x = embed[token] + state, stacked to a (batch, hidden) tensor.
fn gather_input(embed: &HostTensor, states: &[&mut [f32]], tokens: &[u32])
                -> HostTensor {
    let (vocab, hidden) = embed.dims2();
    assert_eq!(states.len(), tokens.len());
    let mut x = HostTensor::zeros(vec![tokens.len(), hidden]);
    for (bi, (&tok, st)) in tokens.iter().zip(states.iter()).enumerate() {
        assert_eq!(st.len(), hidden, "lane {bi} state len");
        let e = embed.row(tok as usize % vocab);
        let row = x.row_mut(bi);
        for j in 0..hidden {
            row[j] = e[j] + st[j];
        }
    }
    x
}

/// state' = DECAY*state + (1-DECAY)*x_row — the per-lane context carry.
fn update_states(states: &mut [&mut [f32]], x: &HostTensor) {
    for (bi, st) in states.iter_mut().enumerate() {
        let row = x.row(bi);
        for (s, &v) in st.iter_mut().zip(row) {
            *s = STATE_DECAY * *s + (1.0 - STATE_DECAY) * v;
        }
    }
}

impl DecodeModel for TernaryLm {
    fn dims(&self) -> &LmDims {
        &self.dims
    }

    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  threads: usize) -> HostTensor {
        let mut x = gather_input(&self.embed, states, tokens);
        for blk in &self.blocks {
            let y = rmsnorm(&x);
            let g = matmul_ternary_packed(&y, &blk.gate, threads);
            let u = matmul_ternary_packed(&y, &blk.up, threads);
            let mut a = g;
            for (av, &uv) in a.data.iter_mut().zip(u.data.iter()) {
                *av = silu(*av) * uv;
            }
            let d = matmul_ternary_packed(&a, &blk.down, threads);
            for (xv, &dv) in x.data.iter_mut().zip(d.data.iter()) {
                *xv += dv;
            }
        }
        let y = rmsnorm(&x);
        update_states(states, &x);
        matmul_ternary_packed(&y, &self.head, threads)
    }
}

impl DecodeModel for DenseLm {
    fn dims(&self) -> &LmDims {
        &self.dims
    }

    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  _threads: usize) -> HostTensor {
        let mut x = gather_input(&self.embed, states, tokens);
        for blk in &self.blocks {
            let y = rmsnorm(&x);
            let g = matmul_dense(&y, &blk.gate);
            let u = matmul_dense(&y, &blk.up);
            let mut a = g;
            for (av, &uv) in a.data.iter_mut().zip(u.data.iter()) {
                *av = silu(*av) * uv;
            }
            let d = matmul_dense(&a, &blk.down);
            for (xv, &dv) in x.data.iter_mut().zip(d.data.iter()) {
                *xv += dv;
            }
        }
        let y = rmsnorm(&x);
        update_states(states, &x);
        matmul_dense(&y, &self.head)
    }
}

impl TernaryLm {
    /// Fresh per-lane context state.
    pub fn zero_state(&self) -> Vec<f32> {
        vec![0.0; self.dims.hidden]
    }

    /// Seeded random weights, ternarized with `mp` scale shards —
    /// plus the dequantized f32 twin holding *identical* weights, so
    /// benches compare storage formats and tests check equivalence.
    pub fn synthetic_pair(dims: LmDims, mp: usize, seed: u64)
                          -> (TernaryLm, DenseLm) {
        let embed = HostTensor::randn(vec![dims.vocab, dims.hidden], 0.5,
                                      seed ^ 0xE3BED);
        let mut blocks = Vec::with_capacity(dims.layers);
        let mut dense_blocks = Vec::with_capacity(dims.layers);
        for l in 0..dims.layers {
            let ls = seed ^ ((l as u64 + 1) << 20);
            let mk = |rows: usize, cols: usize, tag: u64| {
                let w = HostTensor::randn(vec![rows, cols], 0.08, ls ^ tag);
                TernaryTensor::from_latent(&w, mp)
            };
            let (g, u, d) = (mk(dims.glu, dims.hidden, 1),
                             mk(dims.glu, dims.hidden, 2),
                             mk(dims.hidden, dims.glu, 3));
            dense_blocks.push(DenseBlock {
                gate: g.dequant(), up: u.dequant(), down: d.dequant(),
            });
            blocks.push(TernaryBlock {
                gate: PackedMatrix::from_ternary(&g),
                up: PackedMatrix::from_ternary(&u),
                down: PackedMatrix::from_ternary(&d),
            });
        }
        let head_latent = HostTensor::randn(vec![dims.vocab, dims.hidden],
                                            0.08, seed ^ 0x6EAD);
        let head = TernaryTensor::from_latent(&head_latent, 1);
        let dense = DenseLm {
            dims: dims.clone(),
            embed: embed.clone(),
            blocks: dense_blocks,
            head: head.dequant(),
        };
        let ternary = TernaryLm {
            dims,
            embed,
            blocks,
            head: PackedMatrix::from_ternary(&head),
        };
        (ternary, dense)
    }

    /// Build a serving model from a trained checkpoint: the `embed`
    /// table is kept f32, every `l{i}.mlp_{gate,up,down}` linear is
    /// ternarized (single-shard absmean, the §A.5 transform at mp=1)
    /// and packed, and the head ternarizes `head` when present, else
    /// ties to the embedding table.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<TernaryLm> {
        let embed = ck.get("embed")
            .ok_or_else(|| anyhow::anyhow!(
                "checkpoint has no 'embed' tensor; cannot build serve model"))?
            .clone();
        let (vocab, hidden) = embed.dims2();
        let mut blocks = Vec::new();
        let mut glu = 0usize;
        for l in 0.. {
            let Some(gate) = ck.get(&format!("l{l}.mlp_gate")) else { break };
            let up = ck.get(&format!("l{l}.mlp_up")).ok_or_else(
                || anyhow::anyhow!("layer {l}: mlp_gate without mlp_up"))?;
            let down = ck.get(&format!("l{l}.mlp_down")).ok_or_else(
                || anyhow::anyhow!("layer {l}: mlp_gate without mlp_down"))?;
            glu = gate.dims2().0;
            let pack = |w: &HostTensor| {
                PackedMatrix::from_ternary(&TernaryTensor::from_latent(w, 1))
            };
            blocks.push(TernaryBlock {
                gate: pack(gate), up: pack(up), down: pack(down),
            });
        }
        if blocks.is_empty() {
            anyhow::bail!("checkpoint has no l0.mlp_gate — not a spectra LM");
        }
        let head_latent = ck.get("head").unwrap_or(&embed);
        let head = PackedMatrix::from_ternary(
            &TernaryTensor::from_latent(head_latent, 1));
        let layers = blocks.len();
        Ok(TernaryLm {
            dims: LmDims { vocab, hidden, glu, layers },
            embed,
            blocks,
            head,
        })
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dims() -> LmDims {
        LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }
    }

    fn step_one(m: &impl DecodeModel, state: &mut Vec<f32>, tok: u32)
                -> HostTensor {
        let mut refs = [state.as_mut_slice()];
        m.step_batch(&mut refs, &[tok], 1)
    }

    #[test]
    fn ternary_and_dense_twins_agree() {
        // Identical weights, different storage: logits must match to fp
        // accumulation noise.
        let (t, d) = TernaryLm::synthetic_pair(small_dims(), 1, 5);
        let mut st_t = t.zero_state();
        let mut st_d = t.zero_state();
        for tok in [3u32, 17, 40] {
            let lt = step_one(&t, &mut st_t, tok);
            let ld = step_one(&d, &mut st_d, tok);
            assert_eq!(lt.shape, vec![1, 64]);
            for (a, b) in lt.data.iter().zip(ld.data.iter()) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn state_carries_context() {
        // The same token after different histories must produce
        // different logits — the state is doing its job.
        let (t, _) = TernaryLm::synthetic_pair(small_dims(), 1, 6);
        let mut s1 = t.zero_state();
        let mut s2 = t.zero_state();
        step_one(&t, &mut s1, 1);
        step_one(&t, &mut s2, 2);
        let a = step_one(&t, &mut s1, 7);
        let b = step_one(&t, &mut s2, 7);
        let diff: f32 = a.data.iter().zip(b.data.iter())
            .map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3, "history ignored (diff {diff})");
    }

    #[test]
    fn out_of_vocab_tokens_wrap() {
        let (t, _) = TernaryLm::synthetic_pair(small_dims(), 1, 7);
        let mut s1 = t.zero_state();
        let mut s2 = t.zero_state();
        let a = step_one(&t, &mut s1, 3);
        let b = step_one(&t, &mut s2, 3 + 64);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn checkpoint_roundtrip_builds_model() {
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), HostTensor::randn(vec![48, 32], 0.1, 2)),
            ("l0.mlp_up".into(), HostTensor::randn(vec![48, 32], 0.1, 3)),
            ("l0.mlp_down".into(), HostTensor::randn(vec![32, 48], 0.1, 4)),
        ]);
        let lm = TernaryLm::from_checkpoint(&ck).unwrap();
        assert_eq!(lm.dims, LmDims { vocab: 64, hidden: 32, glu: 48,
                                     layers: 1 });
        // tied head: (vocab, hidden) packed
        assert_eq!(lm.head.rows, 64);
        assert_eq!(lm.head.cols, 32);
        let mut st = lm.zero_state();
        let logits = step_one(&lm, &mut st, 5);
        assert_eq!(logits.shape, vec![1, 64]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn checkpoint_without_linears_is_rejected() {
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![8, 4], 0.5, 1)),
        ]);
        assert!(TernaryLm::from_checkpoint(&ck).is_err());
    }
}
