//! Family-generic CPU decode models driven by the serve scheduler.
//!
//! The PJRT transformer graphs remain the fidelity path for training
//! and evaluation; serving instead runs a compact gated-MLP language
//! model directly on packed CPU kernels, because that is the layer the
//! paper's §2.1 bandwidth argument lives in: per decode step every
//! linear is one batched (batch x in) @ (out x in)^T against
//! compressed weights. Long-range context is carried by a per-lane
//! exponential state (updated after each step) instead of a KV cache,
//! which keeps every lane's computation independent of its batch
//! neighbours — the property the scheduler's determinism guarantee
//! (batch-1 == batch-8 token streams) is built on.
//!
//! One model, every storage family: [`SpectraLm<L>`] is generic over
//! [`LinearFormat`], so the same decode math serves
//!
//! - [`DenseLm`] = `SpectraLm<DenseF32>` — f32 rows (FloatLM storage),
//! - [`QuantLm`] = `SpectraLm<QuantPacked>` — k-bit group-quantized
//!   bitstreams (QuantLM storage, RTN or GPTQ),
//! - [`TernaryLm`] = `SpectraLm<PackedMatrix>` — packed 2-bit trits
//!   (TriLM storage, the original hot path).
//!
//! [`LatentLm`] holds the family-agnostic f32 weights (synthetic or
//! from a checkpoint) and realizes any [`FamilySpec`] from them, so
//! cross-family benches compare storage formats of the *same* model —
//! the serving analog of the paper's matched-bit-budget comparison
//! (§4.2, Table 4).

use crate::checkpoint::Checkpoint;
use crate::gptq::{gptq_quantize, GptqConfig, HessianAccumulator};
use crate::linear::{DenseF32, LinearFormat, QuantPacked};
use crate::quant::QuantTensor;
use crate::runtime::{DecodeScratch, HostTensor, SplitMix64, WorkerPool};
use crate::ternary::{matmul_dense, PackedMatrix, TernaryTensor};
use crate::Result;

/// Architecture sizes of a decode model.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct LmDims {
    pub vocab: usize,
    pub hidden: usize,
    pub glu: usize,
    pub layers: usize,
}

/// Per-lane context state decay: `state' = DECAY*state + (1-DECAY)*x`.
pub const STATE_DECAY: f32 = 0.5;

const RMS_EPS: f32 = 1e-6;

/// Serve-side GPTQ calibration traffic: lanes x steps of seeded tokens
/// driven through the f32 latent weights to accumulate per-linear
/// input Hessians.
const CALIB_LANES: usize = 8;
const CALIB_STEPS: usize = 24;

/// A model the scheduler can drive: one batched decode step at a time.
pub trait DecodeModel {
    fn dims(&self) -> &LmDims;

    /// Advance every lane by one token. `states[i]` is lane i's hidden
    /// context (len = `dims().hidden`, updated in place); `tokens[i]`
    /// is the token it consumes. Returns (batch, vocab) logits.
    ///
    /// Contract: lane i's outputs and state update depend only on
    /// (`states[i]`, `tokens[i]`) — never on the other lanes — so a
    /// request decodes identically at any batch size.
    ///
    /// Compatibility entry point: allocates its activations and output
    /// per call. The pooled scheduler drives
    /// [`DecodeModel::step_batch_into`] instead.
    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  threads: usize) -> HostTensor;

    /// Scratch-aware decode step: identical math and numerics to
    /// [`DecodeModel::step_batch`] at `threads = pool.threads()`
    /// (bitwise — the serve determinism suite checks this), but
    /// executed on a persistent [`WorkerPool`] with every activation
    /// buffer reused from `scratch`. The logits land in
    /// `scratch.logits` as a (batch, vocab) tensor.
    ///
    /// The default falls back to the allocating path so external
    /// models stay correct.
    fn step_batch_into(&self, states: &mut [&mut [f32]], tokens: &[u32],
                       pool: &WorkerPool, scratch: &mut DecodeScratch) {
        scratch.logits = self.step_batch(states, tokens, pool.threads());
    }

    /// Storage-format label of the linears (e.g. "fp32", "q4g128",
    /// "ternary") — serving telemetry for the cross-family table.
    fn family_label(&self) -> String;

    /// Params-weighted effective bits per linear-weight parameter
    /// (embeddings excluded; they stay float per §2.1). Keys the
    /// deploy roofline ([`crate::deploy::decode_tokens_per_sec_bits`]).
    fn effective_bits_per_param(&self) -> f64;
}

/// One gated-MLP residual block over any linear storage format.
pub struct SpectraBlock<L> {
    /// (glu, hidden)
    pub gate: L,
    /// (glu, hidden)
    pub up: L,
    /// (hidden, glu)
    pub down: L,
}

/// The family-generic serving model. Embeddings stay f32 (the paper
/// keeps embeddings in halfprec; §2.1); every linear is an `L`.
pub struct SpectraLm<L: LinearFormat> {
    pub dims: LmDims,
    /// (vocab, hidden) f32 input embeddings.
    pub embed: HostTensor,
    pub blocks: Vec<SpectraBlock<L>>,
    /// (vocab, hidden) output head.
    pub head: L,
}

/// TriLM storage: packed 2-bit trits ([`crate::ternary::matmul_ternary_packed`]).
pub type TernaryLm = SpectraLm<PackedMatrix>;

/// FloatLM storage: dense f32 rows.
pub type DenseLm = SpectraLm<DenseF32>;

/// QuantLM storage: k-bit group-quantized bitstreams
/// ([`crate::linear::matmul_quant_packed`]).
pub type QuantLm = SpectraLm<QuantPacked>;

#[inline]
fn silu(v: f32) -> f32 {
    v / (1.0 + (-v).exp())
}

/// Row-wise RMS norm into a reused buffer (no learned gain — the serve
/// model keeps norms parameter-free so checkpoint import only needs
/// the linears). `out` is reshaped in place and fully overwritten; the
/// decode hot path feeds it from [`DecodeScratch`] instead of cloning
/// the full activation tensor every layer.
fn rmsnorm_into(x: &HostTensor, out: &mut HostTensor) {
    let (rows, cols) = x.dims2();
    out.reset2(rows, cols);
    for r in 0..rows {
        let xr = x.row(r);
        let ms: f32 = xr.iter().map(|v| v * v).sum::<f32>() / cols as f32;
        let inv = 1.0 / (ms + RMS_EPS).sqrt();
        for (o, &v) in out.row_mut(r).iter_mut().zip(xr) {
            *o = v * inv;
        }
    }
}

/// Allocating [`rmsnorm_into`] wrapper (calibration + compatibility
/// paths; bitwise-identical output).
fn rmsnorm(x: &HostTensor) -> HostTensor {
    let mut out = HostTensor::zeros(vec![0, 0]);
    rmsnorm_into(x, &mut out);
    out
}

/// x = embed[token] + state, written into a reused (batch, hidden)
/// buffer (reshaped in place, fully overwritten).
fn gather_input_into(embed: &HostTensor, states: &[&mut [f32]],
                     tokens: &[u32], x: &mut HostTensor) {
    let (vocab, hidden) = embed.dims2();
    assert_eq!(states.len(), tokens.len());
    x.reset2(tokens.len(), hidden);
    for (bi, (&tok, st)) in tokens.iter().zip(states.iter()).enumerate() {
        assert_eq!(st.len(), hidden, "lane {bi} state len");
        let e = embed.row(tok as usize % vocab);
        let row = x.row_mut(bi);
        for j in 0..hidden {
            row[j] = e[j] + st[j];
        }
    }
}

/// Allocating [`gather_input_into`] wrapper (compatibility path).
fn gather_input(embed: &HostTensor, states: &[&mut [f32]], tokens: &[u32])
                -> HostTensor {
    let mut x = HostTensor::zeros(vec![0, 0]);
    gather_input_into(embed, states, tokens, &mut x);
    x
}

/// state' = DECAY*state + (1-DECAY)*x_row — the per-lane context carry.
fn update_states(states: &mut [&mut [f32]], x: &HostTensor) {
    for (bi, st) in states.iter_mut().enumerate() {
        let row = x.row(bi);
        for (s, &v) in st.iter_mut().zip(row) {
            *s = STATE_DECAY * *s + (1.0 - STATE_DECAY) * v;
        }
    }
}

impl<L: LinearFormat> DecodeModel for SpectraLm<L> {
    fn dims(&self) -> &LmDims {
        &self.dims
    }

    fn step_batch(&self, states: &mut [&mut [f32]], tokens: &[u32],
                  threads: usize) -> HostTensor {
        let mut x = gather_input(&self.embed, states, tokens);
        for blk in &self.blocks {
            let y = rmsnorm(&x);
            let g = blk.gate.matmul_batch(&y, threads);
            let u = blk.up.matmul_batch(&y, threads);
            let mut a = g;
            for (av, &uv) in a.data.iter_mut().zip(u.data.iter()) {
                *av = silu(*av) * uv;
            }
            let d = blk.down.matmul_batch(&a, threads);
            for (xv, &dv) in x.data.iter_mut().zip(d.data.iter()) {
                *xv += dv;
            }
        }
        let y = rmsnorm(&x);
        update_states(states, &x);
        self.head.matmul_batch(&y, threads)
    }

    /// The allocation-free decode step: every buffer lives in
    /// `scratch`, every matmul runs on `pool`. Identical math (and
    /// bitwise-identical results) to [`SpectraLm::step_batch`]; the
    /// only differences are where buffers come from and that threads
    /// are dispatched instead of spawned.
    fn step_batch_into(&self, states: &mut [&mut [f32]], tokens: &[u32],
                       pool: &WorkerPool, scratch: &mut DecodeScratch) {
        gather_input_into(&self.embed, states, tokens, &mut scratch.x);
        for blk in &self.blocks {
            rmsnorm_into(&scratch.x, &mut scratch.norm);
            blk.gate.matmul_batch_into(&scratch.norm, pool,
                                       &mut scratch.out_t, &mut scratch.gate);
            blk.up.matmul_batch_into(&scratch.norm, pool,
                                     &mut scratch.out_t, &mut scratch.up);
            // Fuse the GLU activation in place into the gate buffer.
            for (av, &uv) in scratch.gate.data.iter_mut()
                .zip(scratch.up.data.iter())
            {
                *av = silu(*av) * uv;
            }
            blk.down.matmul_batch_into(&scratch.gate, pool,
                                       &mut scratch.out_t, &mut scratch.down);
            for (xv, &dv) in scratch.x.data.iter_mut()
                .zip(scratch.down.data.iter())
            {
                *xv += dv;
            }
        }
        rmsnorm_into(&scratch.x, &mut scratch.norm);
        update_states(states, &scratch.x);
        self.head.matmul_batch_into(&scratch.norm, pool, &mut scratch.out_t,
                                    &mut scratch.logits);
    }

    fn family_label(&self) -> String {
        self.head.label()
    }

    fn effective_bits_per_param(&self) -> f64 {
        let mut bits = 0.0f64;
        let mut params = 0.0f64;
        for l in self.linears() {
            let p = (l.out_features() * l.in_features()) as f64;
            bits += l.effective_bits_per_param() * p;
            params += p;
        }
        bits / params.max(1.0)
    }
}

impl<L: LinearFormat> SpectraLm<L> {
    /// Fresh per-lane context state.
    pub fn zero_state(&self) -> Vec<f32> {
        vec![0.0; self.dims.hidden]
    }

    /// Every linear in the model (blocks then head).
    pub fn linears(&self) -> Vec<&L> {
        let mut out = Vec::with_capacity(3 * self.blocks.len() + 1);
        for b in &self.blocks {
            out.push(&b.gate);
            out.push(&b.up);
            out.push(&b.down);
        }
        out.push(&self.head);
        out
    }
}

/// How quant-family weights are produced from the latent f32 weights.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum QuantMethod {
    /// Round-to-nearest group quantization.
    Rtn,
    /// GPTQ with serve-side synthetic calibration (Hessians accumulated
    /// by driving the latent f32 model on seeded token traffic).
    Gptq,
}

/// A serving family at a bit budget — the §4.2 axis, executable.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum FamilySpec {
    Float,
    Quant { bits: u32, group: usize, method: QuantMethod },
    Ternary,
}

impl FamilySpec {
    /// Parse a CLI family token: `float` | `ternary` | `quant<bits>` |
    /// `gptq<bits>` (bits 2..=8). `group` applies to the quant forms.
    pub fn parse(s: &str, group: usize) -> Option<FamilySpec> {
        let s = s.trim().to_ascii_lowercase();
        match s.as_str() {
            "float" | "fp32" | "dense" => return Some(FamilySpec::Float),
            "ternary" | "trilm" => return Some(FamilySpec::Ternary),
            _ => {}
        }
        for (prefix, method) in [("quant", QuantMethod::Rtn),
                                 ("rtn", QuantMethod::Rtn),
                                 ("gptq", QuantMethod::Gptq)] {
            if let Some(rest) = s.strip_prefix(prefix) {
                if let Ok(bits) = rest.parse::<u32>() {
                    if (2..=8).contains(&bits) {
                        return Some(FamilySpec::Quant { bits, group, method });
                    }
                }
            }
        }
        None
    }

    /// Paper-style family name for tables.
    pub fn label(&self) -> String {
        match *self {
            FamilySpec::Float => "FloatLM".into(),
            FamilySpec::Ternary => "TriLM".into(),
            FamilySpec::Quant { bits, method: QuantMethod::Rtn, .. } => {
                format!("QuantLM {bits}-bit")
            }
            FamilySpec::Quant { bits, method: QuantMethod::Gptq, .. } => {
                format!("QuantLM {bits}-bit (GPTQ)")
            }
        }
    }
}

/// One block of family-agnostic latent f32 weights.
pub struct LatentBlock {
    pub gate: HostTensor,
    pub up: HostTensor,
    pub down: HostTensor,
}

/// Family-agnostic latent weights: the single source every serving
/// family is realized from (checkpoint-trained or synthetic), so
/// cross-family comparisons are between storage formats of the same
/// model, never between different models.
pub struct LatentLm {
    pub dims: LmDims,
    /// (vocab, hidden) f32 embeddings (stay float in every family).
    pub embed: HostTensor,
    pub blocks: Vec<LatentBlock>,
    /// (vocab, hidden) latent output head.
    pub head: HostTensor,
    /// Ternary scale shards per block matrix (§A.5); head uses 1.
    pub mp: usize,
}

impl LatentLm {
    /// Seeded random latent weights (the synthetic bench/test model).
    pub fn synthetic(dims: LmDims, mp: usize, seed: u64) -> LatentLm {
        let embed = HostTensor::randn(vec![dims.vocab, dims.hidden], 0.5,
                                      seed ^ 0xE3BED);
        let mut blocks = Vec::with_capacity(dims.layers);
        for l in 0..dims.layers {
            let ls = seed ^ ((l as u64 + 1) << 20);
            blocks.push(LatentBlock {
                gate: HostTensor::randn(vec![dims.glu, dims.hidden], 0.08,
                                        ls ^ 1),
                up: HostTensor::randn(vec![dims.glu, dims.hidden], 0.08,
                                      ls ^ 2),
                down: HostTensor::randn(vec![dims.hidden, dims.glu], 0.08,
                                        ls ^ 3),
            });
        }
        let head = HostTensor::randn(vec![dims.vocab, dims.hidden], 0.08,
                                     seed ^ 0x6EAD);
        LatentLm { dims, embed, blocks, head, mp }
    }

    /// Latent weights from a trained checkpoint: the `embed` table plus
    /// every `l{i}.mlp_{gate,up,down}` linear; the head falls back to
    /// the tied embedding table when absent.
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<LatentLm> {
        let embed = ck.get("embed")
            .ok_or_else(|| anyhow::anyhow!(
                "checkpoint has no 'embed' tensor; cannot build serve model"))?
            .clone();
        let (vocab, hidden) = embed.dims2();
        let mut blocks = Vec::new();
        let mut glu = 0usize;
        for l in 0.. {
            let Some(gate) = ck.get(&format!("l{l}.mlp_gate")) else { break };
            let up = ck.get(&format!("l{l}.mlp_up")).ok_or_else(
                || anyhow::anyhow!("layer {l}: mlp_gate without mlp_up"))?;
            let down = ck.get(&format!("l{l}.mlp_down")).ok_or_else(
                || anyhow::anyhow!("layer {l}: mlp_gate without mlp_down"))?;
            if l == 0 {
                glu = gate.dims2().0;
            }
            // Reject shape drift here: step_batch's element-wise zips
            // would silently truncate on mismatched tensors and serve
            // garbage logits instead of failing.
            for (name, t, want) in [("mlp_gate", gate, (glu, hidden)),
                                    ("mlp_up", up, (glu, hidden)),
                                    ("mlp_down", down, (hidden, glu))] {
                if t.dims2() != want {
                    anyhow::bail!(
                        "layer {l}: {name} is {:?}, expected {:?} (from \
                         embed hidden {hidden} and l0 glu {glu})",
                        t.dims2(), want);
                }
            }
            blocks.push(LatentBlock {
                gate: gate.clone(),
                up: up.clone(),
                down: down.clone(),
            });
        }
        if blocks.is_empty() {
            anyhow::bail!("checkpoint has no l0.mlp_gate — not a spectra LM");
        }
        let head = ck.get("head").unwrap_or(&embed).clone();
        if head.dims2().1 != hidden {
            anyhow::bail!("head is {:?}, expected (vocab, {hidden})",
                          head.dims2());
        }
        let layers = blocks.len();
        Ok(LatentLm {
            dims: LmDims { vocab, hidden, glu, layers },
            embed,
            blocks,
            head,
            mp: 1,
        })
    }

    fn realize<L: LinearFormat>(&self, f: impl Fn(&HostTensor) -> L)
                                -> SpectraLm<L> {
        SpectraLm {
            dims: self.dims.clone(),
            embed: self.embed.clone(),
            blocks: self.blocks.iter().map(|b| SpectraBlock {
                gate: f(&b.gate),
                up: f(&b.up),
                down: f(&b.down),
            }).collect(),
            head: f(&self.head),
        }
    }

    /// FloatLM storage: the latent f32 weights served directly.
    pub fn build_float(&self) -> DenseLm {
        self.realize(|w| DenseF32 { w: w.clone() })
    }

    /// TriLM storage: absmean-ternarized (§A.5, mp shards per block
    /// matrix, single-shard head) and packed 2-bit.
    pub fn build_ternary(&self) -> TernaryLm {
        let tern = |w: &HostTensor, mp: usize| {
            PackedMatrix::from_ternary(&TernaryTensor::from_latent(w, mp))
        };
        SpectraLm {
            dims: self.dims.clone(),
            embed: self.embed.clone(),
            blocks: self.blocks.iter().map(|b| SpectraBlock {
                gate: tern(&b.gate, self.mp),
                up: tern(&b.up, self.mp),
                down: tern(&b.down, self.mp),
            }).collect(),
            head: tern(&self.head, 1),
        }
    }

    /// QuantLM storage via round-to-nearest group quantization.
    pub fn build_quant_rtn(&self, bits: u32, group: usize) -> QuantLm {
        self.realize(|w| {
            QuantPacked::from_quant(&QuantTensor::quantize_rtn(w, bits, group))
        })
    }

    /// QuantLM storage via GPTQ: per-linear input Hessians are
    /// accumulated by driving the latent f32 model on seeded synthetic
    /// token traffic (the serving analog of the training-distribution
    /// calibration in `gptq::pipeline`), then each linear is quantized
    /// with second-order error compensation.
    pub fn build_quant_gptq(&self, bits: u32, group: usize, seed: u64)
                            -> Result<QuantLm> {
        let (acc_h, acc_g, acc_head) = self.calibration_hessians(seed);
        let cfg = GptqConfig::new(bits, group);
        let qp = |w: &HostTensor, acc: &HessianAccumulator|
                 -> Result<QuantPacked> {
            Ok(QuantPacked::from_quant(
                &gptq_quantize(w, &acc.finalize(), cfg)?))
        };
        let mut blocks = Vec::with_capacity(self.blocks.len());
        for (l, b) in self.blocks.iter().enumerate() {
            blocks.push(SpectraBlock {
                gate: qp(&b.gate, &acc_h[l])?,
                up: qp(&b.up, &acc_h[l])?,
                down: qp(&b.down, &acc_g[l])?,
            });
        }
        Ok(SpectraLm {
            dims: self.dims.clone(),
            embed: self.embed.clone(),
            blocks,
            head: qp(&self.head, &acc_head)?,
        })
    }

    /// Realize any family as a boxed [`DecodeModel`] the scheduler can
    /// drive — the one entry point `serve-bench --family` and the
    /// cross-family test harnesses use.
    pub fn build(&self, spec: FamilySpec) -> Result<Box<dyn DecodeModel>> {
        let model: Box<dyn DecodeModel> = match spec {
            FamilySpec::Float => Box::new(self.build_float()),
            FamilySpec::Ternary => Box::new(self.build_ternary()),
            FamilySpec::Quant { bits, group, method: QuantMethod::Rtn } => {
                Box::new(self.build_quant_rtn(bits, group))
            }
            FamilySpec::Quant { bits, group, method: QuantMethod::Gptq } => {
                Box::new(self.build_quant_gptq(bits, group, 0)?)
            }
        };
        Ok(model)
    }

    /// Drive the latent f32 weights through the decode math on seeded
    /// token traffic, accumulating every linear's input Hessian:
    /// gate/up share the block-input accumulator (identical inputs),
    /// down gets the activated GLU, the head gets the final norm.
    fn calibration_hessians(&self, seed: u64)
                            -> (Vec<HessianAccumulator>,
                                Vec<HessianAccumulator>,
                                HessianAccumulator) {
        let d = &self.dims;
        let mut acc_h: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.hidden)).collect();
        let mut acc_g: Vec<HessianAccumulator> = (0..d.layers)
            .map(|_| HessianAccumulator::new(d.glu)).collect();
        let mut acc_head = HessianAccumulator::new(d.hidden);
        let mut rng = SplitMix64::new(seed ^ 0xCA11B);
        let mut states = HostTensor::zeros(vec![CALIB_LANES, d.hidden]);
        for _ in 0..CALIB_STEPS {
            let mut x = HostTensor::zeros(vec![CALIB_LANES, d.hidden]);
            for b in 0..CALIB_LANES {
                let e = self.embed.row(rng.below(d.vocab));
                let s = states.row(b);
                let row = x.row_mut(b);
                for j in 0..d.hidden {
                    row[j] = e[j] + s[j];
                }
            }
            for (l, blk) in self.blocks.iter().enumerate() {
                let y = rmsnorm(&x);
                acc_h[l].add_batch(&y);
                let g = matmul_dense(&y, &blk.gate);
                let u = matmul_dense(&y, &blk.up);
                let mut a = g;
                for (av, &uv) in a.data.iter_mut().zip(u.data.iter()) {
                    *av = silu(*av) * uv;
                }
                acc_g[l].add_batch(&a);
                let dd = matmul_dense(&a, &blk.down);
                for (xv, &dv) in x.data.iter_mut().zip(dd.data.iter()) {
                    *xv += dv;
                }
            }
            acc_head.add_batch(&rmsnorm(&x));
            for b in 0..CALIB_LANES {
                let row = &x.data[b * d.hidden..(b + 1) * d.hidden];
                let s = states.row_mut(b);
                for (sv, &xv) in s.iter_mut().zip(row) {
                    *sv = STATE_DECAY * *sv + (1.0 - STATE_DECAY) * xv;
                }
            }
        }
        (acc_h, acc_g, acc_head)
    }
}

impl SpectraLm<PackedMatrix> {
    /// Seeded random weights, ternarized with `mp` scale shards —
    /// plus the dequantized f32 twin holding *identical* weights, so
    /// benches compare storage formats and tests check equivalence.
    pub fn synthetic_pair(dims: LmDims, mp: usize, seed: u64)
                          -> (TernaryLm, DenseLm) {
        let latent = LatentLm::synthetic(dims, mp, seed);
        let ternary = latent.build_ternary();
        // The dense twin dequantizes the *ternarized* weights (not the
        // latent ones): identical math up to fp rounding.
        let dense = SpectraLm {
            dims: latent.dims.clone(),
            embed: latent.embed.clone(),
            blocks: ternary.blocks.iter().map(|b| SpectraBlock {
                gate: DenseF32 { w: b.gate.dequant() },
                up: DenseF32 { w: b.up.dequant() },
                down: DenseF32 { w: b.down.dequant() },
            }).collect(),
            head: DenseF32 { w: ternary.head.dequant() },
        };
        (ternary, dense)
    }

    /// Ternarized serving model from a trained checkpoint (single-shard
    /// absmean, the §A.5 transform at mp=1).
    pub fn from_checkpoint(ck: &Checkpoint) -> Result<TernaryLm> {
        Ok(LatentLm::from_checkpoint(ck)?.build_ternary())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn small_dims() -> LmDims {
        LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }
    }

    fn step_one(m: &dyn DecodeModel, state: &mut Vec<f32>, tok: u32)
                -> HostTensor {
        let mut refs = [state.as_mut_slice()];
        m.step_batch(&mut refs, &[tok], 1)
    }

    #[test]
    fn ternary_and_dense_twins_agree() {
        // Identical weights, different storage: logits must match to fp
        // accumulation noise.
        let (t, d) = TernaryLm::synthetic_pair(small_dims(), 1, 5);
        let mut st_t = t.zero_state();
        let mut st_d = t.zero_state();
        for tok in [3u32, 17, 40] {
            let lt = step_one(&t, &mut st_t, tok);
            let ld = step_one(&d, &mut st_d, tok);
            assert_eq!(lt.shape, vec![1, 64]);
            for (a, b) in lt.data.iter().zip(ld.data.iter()) {
                assert!((a - b).abs() < 1e-3, "{a} vs {b}");
            }
        }
    }

    #[test]
    fn state_carries_context() {
        // The same token after different histories must produce
        // different logits — the state is doing its job.
        let (t, _) = TernaryLm::synthetic_pair(small_dims(), 1, 6);
        let mut s1 = t.zero_state();
        let mut s2 = t.zero_state();
        step_one(&t, &mut s1, 1);
        step_one(&t, &mut s2, 2);
        let a = step_one(&t, &mut s1, 7);
        let b = step_one(&t, &mut s2, 7);
        let diff: f32 = a.data.iter().zip(b.data.iter())
            .map(|(x, y)| (x - y).abs()).sum();
        assert!(diff > 1e-3, "history ignored (diff {diff})");
    }

    #[test]
    fn out_of_vocab_tokens_wrap() {
        let (t, _) = TernaryLm::synthetic_pair(small_dims(), 1, 7);
        let mut s1 = t.zero_state();
        let mut s2 = t.zero_state();
        let a = step_one(&t, &mut s1, 3);
        let b = step_one(&t, &mut s2, 3 + 64);
        assert_eq!(a.data, b.data);
    }

    #[test]
    fn checkpoint_roundtrip_builds_model() {
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), HostTensor::randn(vec![48, 32], 0.1, 2)),
            ("l0.mlp_up".into(), HostTensor::randn(vec![48, 32], 0.1, 3)),
            ("l0.mlp_down".into(), HostTensor::randn(vec![32, 48], 0.1, 4)),
        ]);
        let lm = TernaryLm::from_checkpoint(&ck).unwrap();
        assert_eq!(lm.dims, LmDims { vocab: 64, hidden: 32, glu: 48,
                                     layers: 1 });
        // tied head: (vocab, hidden) packed
        assert_eq!(lm.head.rows, 64);
        assert_eq!(lm.head.cols, 32);
        let mut st = lm.zero_state();
        let logits = step_one(&lm, &mut st, 5);
        assert_eq!(logits.shape, vec![1, 64]);
        assert!(logits.data.iter().all(|v| v.is_finite()));
    }

    #[test]
    fn checkpoint_without_linears_is_rejected() {
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![8, 4], 0.5, 1)),
        ]);
        assert!(TernaryLm::from_checkpoint(&ck).is_err());
    }

    #[test]
    fn checkpoint_with_inconsistent_shapes_is_rejected() {
        // mlp_up rows disagree with l0's glu: must error at build time,
        // not serve truncated garbage.
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), HostTensor::randn(vec![48, 32], 0.1, 2)),
            ("l0.mlp_up".into(), HostTensor::randn(vec![40, 32], 0.1, 3)),
            ("l0.mlp_down".into(), HostTensor::randn(vec![32, 48], 0.1, 4)),
        ]);
        let err = LatentLm::from_checkpoint(&ck).unwrap_err().to_string();
        assert!(err.contains("mlp_up"), "unhelpful error: {err}");
        // A head with the wrong input width is rejected too.
        let ck = Checkpoint::new(vec![
            ("embed".into(), HostTensor::randn(vec![64, 32], 0.5, 1)),
            ("l0.mlp_gate".into(), HostTensor::randn(vec![48, 32], 0.1, 2)),
            ("l0.mlp_up".into(), HostTensor::randn(vec![48, 32], 0.1, 3)),
            ("l0.mlp_down".into(), HostTensor::randn(vec![32, 48], 0.1, 4)),
            ("head".into(), HostTensor::randn(vec![64, 16], 0.1, 5)),
        ]);
        assert!(LatentLm::from_checkpoint(&ck).is_err());
    }

    #[test]
    fn family_spec_parses_cli_tokens() {
        assert_eq!(FamilySpec::parse("float", 128), Some(FamilySpec::Float));
        assert_eq!(FamilySpec::parse("TriLM", 128), Some(FamilySpec::Ternary));
        assert_eq!(FamilySpec::parse("quant4", 64),
                   Some(FamilySpec::Quant { bits: 4, group: 64,
                                            method: QuantMethod::Rtn }));
        assert_eq!(FamilySpec::parse("gptq3", 128),
                   Some(FamilySpec::Quant { bits: 3, group: 128,
                                            method: QuantMethod::Gptq }));
        assert_eq!(FamilySpec::parse("quant9", 128), None);
        assert_eq!(FamilySpec::parse("fp17", 128), None);
    }

    #[test]
    fn every_family_builds_and_steps() {
        let latent = LatentLm::synthetic(small_dims(), 1, 8);
        let specs = [
            FamilySpec::Float,
            FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Quant { bits: 4, group: 128, method: QuantMethod::Gptq },
            FamilySpec::Ternary,
        ];
        for spec in specs {
            let m = latent.build(spec).unwrap();
            assert_eq!(m.dims(), &small_dims(), "{}", spec.label());
            let mut st = vec![0.0f32; 32];
            let logits = step_one(m.as_ref(), &mut st, 9);
            assert_eq!(logits.shape, vec![1, 64], "{}", spec.label());
            assert!(logits.data.iter().all(|v| v.is_finite()),
                    "{}: non-finite logits", spec.label());
        }
    }

    #[test]
    fn step_batch_into_matches_step_batch_bitwise() {
        // The pooled/scratch decode step is the allocating step, run on
        // different plumbing: logits AND updated states must be
        // bitwise identical, for every family, with one scratch reused
        // across families and steps.
        let latent = LatentLm::synthetic(small_dims(), 1, 12);
        let pool = WorkerPool::new(2);
        let mut scratch = DecodeScratch::new();
        let specs = [
            FamilySpec::Float,
            FamilySpec::Quant { bits: 3, group: 128, method: QuantMethod::Rtn },
            FamilySpec::Ternary,
        ];
        for spec in specs {
            let m = latent.build(spec).unwrap();
            let mut st_a = vec![vec![0.0f32; 32]; 3];
            let mut st_b = st_a.clone();
            for (step, toks) in [[1u32, 9, 60], [4, 4, 31]].iter().enumerate() {
                let mut refs_a: Vec<&mut [f32]> =
                    st_a.iter_mut().map(|s| s.as_mut_slice()).collect();
                let want = m.step_batch(&mut refs_a, toks, pool.threads());
                let mut refs_b: Vec<&mut [f32]> =
                    st_b.iter_mut().map(|s| s.as_mut_slice()).collect();
                m.step_batch_into(&mut refs_b, toks, &pool, &mut scratch);
                assert_eq!(scratch.logits.shape, want.shape,
                           "{} step {step}", spec.label());
                assert_eq!(scratch.logits.data, want.data,
                           "{} step {step}: logits diverge", spec.label());
                assert_eq!(st_a, st_b,
                           "{} step {step}: states diverge", spec.label());
            }
        }
    }

    #[test]
    fn effective_bits_order_matches_table4() {
        // FloatLM > QuantLM 4 > QuantLM 3 > TriLM — the paper's bit
        // budget axis, measured on the serving models themselves.
        let latent = LatentLm::synthetic(small_dims(), 1, 9);
        let f = latent.build_float().effective_bits_per_param();
        let q4 = latent.build_quant_rtn(4, 128).effective_bits_per_param();
        let q3 = latent.build_quant_rtn(3, 128).effective_bits_per_param();
        let t = latent.build_ternary().effective_bits_per_param();
        assert!(f > q4 && q4 > q3 && q3 > t,
                "bits ordering broken: f={f} q4={q4} q3={q3} t={t}");
        assert_eq!(latent.build_float().family_label(), "fp32");
        assert_eq!(latent.build_ternary().family_label(), "ternary");
    }

    #[test]
    fn quant_families_approximate_float_logits() {
        // Storage formats of the same latent weights: the 4-bit model
        // must land closer to the float logits than the 3-bit model on
        // average (more bits, less quantization error).
        let latent = LatentLm::synthetic(small_dims(), 1, 10);
        let f = latent.build_float();
        let mean_err = |m: &dyn DecodeModel| -> f64 {
            let mut st_a = vec![0.0f32; 32];
            let mut st_b = vec![0.0f32; 32];
            let mut total = 0.0f64;
            let mut n = 0usize;
            for tok in [1u32, 30, 55] {
                let la = step_one(m, &mut st_a, tok);
                let lb = step_one(&f, &mut st_b, tok);
                total += la.data.iter().zip(lb.data.iter())
                    .map(|(x, y)| (x - y).abs() as f64).sum::<f64>();
                n += la.data.len();
            }
            total / n as f64
        };
        let e4 = mean_err(&latent.build_quant_rtn(4, 128));
        let e3 = mean_err(&latent.build_quant_rtn(3, 128));
        assert!(e4 < e3, "4-bit err {e4} should beat 3-bit err {e3}");
        assert!(e4 > 0.0, "quantization must not be a no-op");
    }

    #[test]
    fn gptq_family_is_deterministic() {
        // Same latent + same seed -> bitwise identical quantized model
        // (calibration is seeded, not wall-clock driven).
        let latent = LatentLm::synthetic(small_dims(), 1, 11);
        let a = latent.build_quant_gptq(4, 128, 3).unwrap();
        let b = latent.build_quant_gptq(4, 128, 3).unwrap();
        for (la, lb) in a.linears().iter().zip(b.linears().iter()) {
            assert_eq!(la.bytes, lb.bytes);
            assert_eq!(la.scales, lb.scales);
        }
    }
}
