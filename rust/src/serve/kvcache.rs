//! Block-paged KV cache: the attention serving path's memory substrate.
//!
//! The Spectra paper's inference claim (§2.1) is a bandwidth story, and
//! in production decoding the bandwidth bill has two lines: the
//! compressed weights (what the storage families change) and the KV
//! cache (what they do not — cached activations stay f32 here in every
//! family). This module provides the cache the attention decode model
//! ([`crate::serve::model::AttnLm`]) streams per step, organized the
//! way production engines organize it (vLLM-style paging): fixed-size
//! *pages* of [`KvCacheConfig::page_tokens`] token slots, handed out
//! from a free list as sequences grow and returned wholesale when a
//! lane retires, so fragmentation never accumulates across lane churn
//! and admission control is a single free-list length check.
//!
//! Layout: one flat f32 slab of `n_pages` pages. A page holds
//! `page_tokens` token slots; a token slot holds the token's keys and
//! values for *every* layer (`layers * 2 * hidden` f32), so one
//! [`KvCache::begin_token`] claim covers the whole forward pass of one
//! decode step. A sequence is a page table (`Vec<usize>`) plus a
//! length; position `p` lives in `pages[p / page_tokens]` at slot
//! `p % page_tokens`.
//!
//! Pages are *reference counted* so committed prompt prefixes can be
//! shared across sequences ([`KvCache::share_prefix`]): a shared page
//! sits in several page tables at once and returns to the free list
//! only when the last holder retires. Writes never land on a shared
//! page — [`KvCache::begin_tokens`] performs copy-on-write at claim
//! time (claim a fresh page, copy the committed slots, swap the
//! page-table entry), so divergence is physically isolated before the
//! first write and the write path stays infallible.
//!
//! Invariants the serve test suite leans on:
//!
//! - **Physical placement never affects values.** Reads go through the
//!   page table in position order, and every claimed slot is fully
//!   written ([`KvCache::write_kv`] per layer) before it is read — so
//!   which physical page a token lands on (which varies with lane
//!   churn) is invisible to decode results. This is what keeps the
//!   scheduler's batch-1 == batch-N determinism contract intact for
//!   attention models (`tests/serve_determinism.rs`). Sharing keeps
//!   this: a shared slot holds exactly the bytes prefill would have
//!   recomputed, and copy-on-write copies them bit-for-bit.
//! - **Lane independence.** A sequence only ever reads slots it
//!   claimed itself or mapped via [`KvCache::share_prefix`]; recycled
//!   pages are claimed-then-written before any read, so no stale bytes
//!   from a retired lane can leak. Copy-on-write means a sequence can
//!   never write a slot a sibling reads.
//! - **Admission refusal is loud and harmless.** [`KvCache::begin_token`]
//!   returns [`OutOfPages`] without mutating the sequence, so a refused
//!   claim can be retried after a lane retires. The copy-on-write page
//!   is part of the same all-or-nothing claim.

/// Token slots per page. Small enough that a retiring short lane
/// returns most of its memory, large enough that the page table stays
/// tiny; fixed (never derived from batch or context) so page-table
/// shapes are reproducible across runs.
pub const KV_PAGE_TOKENS: usize = 16;

/// Geometry of a paged KV cache.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct KvCacheConfig {
    /// Transformer layers caching k/v per token.
    pub layers: usize,
    /// Model width: k and v are `hidden` f32 each, per layer.
    pub hidden: usize,
    /// Token slots per page.
    pub page_tokens: usize,
    /// Total pages in the pool (the admission-control budget).
    pub n_pages: usize,
}

impl KvCacheConfig {
    /// f32 elements one token slot occupies (k + v across all layers).
    pub fn token_stride(&self) -> usize {
        2 * self.layers * self.hidden
    }

    /// f32 elements per page.
    pub fn page_stride(&self) -> usize {
        self.page_tokens * self.token_stride()
    }

    /// Bytes appended to the cache per decoded token — the per-token
    /// bandwidth tax attention serving adds on top of weight streaming
    /// (the `kv_bytes_per_token` field of BENCH_serve.json).
    pub fn bytes_per_token(&self) -> usize {
        self.token_stride() * std::mem::size_of::<f32>()
    }

    /// Total token capacity of the pool.
    pub fn capacity_tokens(&self) -> usize {
        self.n_pages * self.page_tokens
    }
}

/// Admission refusal: the page pool is exhausted. The failed claim did
/// not mutate the sequence; retry after a lane retires and returns its
/// pages.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct OutOfPages {
    /// Sequence that needed a fresh page.
    pub seq: usize,
    /// Its committed length at refusal time (unchanged by the refusal).
    pub len: usize,
}

impl std::fmt::Display for OutOfPages {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "kv cache out of pages (seq {} at {} tokens)",
               self.seq, self.len)
    }
}

impl std::error::Error for OutOfPages {}

/// One lane-bound sequence: a page table plus committed length.
///
/// `released` counts *leading logical pages* handed back by
/// [`KvCache::release_before`] (sliding-window page recycling):
/// `pages[i]` is the physical page of logical page `released + i`, and
/// positions below `released * page_tokens` are gone — the attention
/// window guarantees nothing reads them again.
#[derive(Debug, Default)]
struct Seq {
    live: bool,
    pages: Vec<usize>,
    len: usize,
    released: usize,
}

/// A block-paged KV cache over one flat f32 slab (see the module docs
/// for layout and invariants). One cache serves all lanes of one
/// [`crate::serve::model::AttnLm`]; sequences are allocated when the
/// scheduler first steps a lane and freed when the lane retires
/// (via [`crate::serve::model::DecodeModel::retire_state`]).
pub struct KvCache {
    cfg: KvCacheConfig,
    data: Vec<f32>,
    /// Unused page ids; `pop` hands out the most recently freed page
    /// first (placement is invisible to results — see module docs).
    free_pages: Vec<usize>,
    /// Holders per page: 0 = free, 1 = exclusively owned, >1 = shared
    /// via [`KvCache::share_prefix`] (read-only until copy-on-write).
    refcounts: Vec<u32>,
    seqs: Vec<Seq>,
    /// Retired sequence ids available for reuse.
    free_seq_ids: Vec<usize>,
    /// Copy-on-write page copies performed since construction.
    cow_copies: usize,
    /// Fault injection ([`KvCache::inject_refusals`]): the next this
    /// many claims refuse with [`OutOfPages`] regardless of free
    /// pages. 0 (the default) on the healthy path.
    forced_refusals: usize,
}

impl KvCache {
    pub fn new(cfg: KvCacheConfig) -> KvCache {
        assert!(cfg.layers >= 1 && cfg.hidden >= 1,
                "kv cache needs layers >= 1 and hidden >= 1");
        assert!(cfg.page_tokens >= 1, "kv cache needs page_tokens >= 1");
        let data = vec![0.0; cfg.n_pages * cfg.page_stride()];
        // Reversed so pop() hands out pages 0, 1, 2, ... initially —
        // not load-bearing (placement is invisible), just easy to read
        // in a debugger.
        let free_pages = (0..cfg.n_pages).rev().collect();
        KvCache { cfg, data, free_pages,
                  refcounts: vec![0; cfg.n_pages],
                  seqs: Vec::new(),
                  free_seq_ids: Vec::new(),
                  cow_copies: 0,
                  forced_refusals: 0 }
    }

    /// A cache sized for `lanes` concurrent sequences of up to
    /// `max_context` tokens each: exactly `lanes * ceil(max_context /
    /// page_tokens)` pages, so a full complement of max-length lanes
    /// fits and one more page claim is refused.
    pub fn for_lanes(layers: usize, hidden: usize, page_tokens: usize,
                     lanes: usize, max_context: usize) -> KvCache {
        let pages_per_lane = max_context.div_ceil(page_tokens).max(1);
        KvCache::new(KvCacheConfig {
            layers,
            hidden,
            page_tokens,
            n_pages: lanes.max(1) * pages_per_lane,
        })
    }

    pub fn config(&self) -> &KvCacheConfig {
        &self.cfg
    }

    /// Start a fresh sequence (no pages yet — they are claimed lazily
    /// by [`KvCache::begin_token`]). Sequence ids are recycled after
    /// [`KvCache::free_seq`], so long-running serving never grows the
    /// sequence table past the peak lane count.
    pub fn alloc_seq(&mut self) -> usize {
        if let Some(id) = self.free_seq_ids.pop() {
            debug_assert!(!self.seqs[id].live);
            self.seqs[id].live = true;
            self.seqs[id].len = 0;
            self.seqs[id].released = 0;
            debug_assert!(self.seqs[id].pages.is_empty());
            return id;
        }
        self.seqs.push(Seq { live: true, pages: Vec::new(), len: 0,
                             released: 0 });
        self.seqs.len() - 1
    }

    /// Retire a sequence: drop one reference from every page it held —
    /// a page returns to the free list only when its last holder lets
    /// go, so retiring a lane never invalidates a prefix a sibling
    /// still reads. The lane-retire → page-recycle path of the
    /// scheduler's state recycling lands here.
    pub fn free_seq(&mut self, seq: usize) {
        let s = &mut self.seqs[seq];
        assert!(s.live, "free_seq({seq}) on a sequence that is not live");
        s.live = false;
        s.len = 0;
        s.released = 0;
        let pages = std::mem::take(&mut s.pages);
        for page in pages {
            let rc = self.refcounts[page].checked_sub(1)
                .expect("free_seq on a page with refcount 0");
            self.refcounts[page] = rc;
            if rc == 0 {
                self.free_pages.push(page);
            }
        }
        self.free_seq_ids.push(seq);
    }

    /// Map the first `n_tokens` committed tokens of `src` into the page
    /// table of `dst` (a freshly allocated, empty sequence), bumping
    /// the refcount of every covered page — including a partially
    /// filled last page when `n_tokens` is not page-aligned (the case
    /// copy-on-write exists for). No slab data moves and no free pages
    /// are consumed, so sharing is infallible. Returns the number of
    /// pages now shared. `dst` reads positions `< n_tokens` exactly as
    /// `src` does; its first claim past a shared partial page triggers
    /// copy-on-write in [`KvCache::begin_tokens`].
    pub fn share_prefix(&mut self, src: usize, dst: usize,
                        n_tokens: usize) -> usize {
        assert!(src != dst, "share_prefix needs two distinct sequences");
        assert!(self.seqs[src].live, "share_prefix from retired seq {src}");
        assert!(self.seqs[src].released == 0,
                "share_prefix from seq {src} with front-released pages \
                 (windowed sequences cannot donate prefixes)");
        assert!(self.seqs[dst].live, "share_prefix into retired seq {dst}");
        assert!(self.seqs[dst].len == 0 && self.seqs[dst].pages.is_empty(),
                "share_prefix target seq {dst} must be fresh");
        assert!(n_tokens >= 1 && n_tokens <= self.seqs[src].len,
                "share_prefix of {n_tokens} tokens from a {}-token seq",
                self.seqs[src].len);
        let n_pages = n_tokens.div_ceil(self.cfg.page_tokens);
        let shared: Vec<usize> =
            self.seqs[src].pages[..n_pages].to_vec();
        for &page in &shared {
            self.refcounts[page] += 1;
        }
        self.seqs[dst].pages = shared;
        self.seqs[dst].len = n_tokens;
        n_pages
    }

    /// Claim the next token slot of `seq`, taking a page from the free
    /// list when the sequence crosses a page boundary. Returns the new
    /// position on success; on [`OutOfPages`] the sequence is
    /// unchanged.
    pub fn begin_token(&mut self, seq: usize)
                       -> std::result::Result<usize, OutOfPages> {
        self.begin_tokens(seq, 1)
    }

    /// Claim the next `n` token slots of `seq` in one all-or-nothing
    /// transaction (chunked prefill claims a whole prompt chunk up
    /// front), taking as many pages from the free list as the new
    /// length requires. Returns the first claimed position on success;
    /// on [`OutOfPages`] neither the sequence nor the free list has
    /// changed, so a refused lane can be deferred and retried after
    /// another lane retires.
    ///
    /// Copy-on-write happens here, not at write time: when the slot at
    /// position `len` lands inside a *shared* partially filled page
    /// (refcount > 1, mapped by [`KvCache::share_prefix`]), the claim
    /// needs one extra page — a fresh private copy of the committed
    /// slots — counted in the same all-or-nothing check, so the write
    /// path stays infallible and [`OutOfPages`] remains the single
    /// refusal channel.
    pub fn begin_tokens(&mut self, seq: usize, n: usize)
                        -> std::result::Result<usize, OutOfPages> {
        assert!(n >= 1, "begin_tokens needs n >= 1");
        let len = self.seqs[seq].len;
        debug_assert!(self.seqs[seq].live,
                      "begin_tokens on retired seq {seq}");
        // Fault injection: a scripted refusal takes the *exact* real
        // refusal exit — before any mutation, so the all-or-nothing
        // contract holds for injected faults too.
        if self.forced_refusals > 0 {
            self.forced_refusals -= 1;
            return Err(OutOfPages { seq, len });
        }
        // Is position `len` inside a shared page? Only possible when
        // the last mapped page is partially filled (len not
        // page-aligned); full shared pages are never written again.
        // Page-table indices are logical-page minus `released` (leading
        // pages handed back by `release_before` are simply gone).
        let released = self.seqs[seq].released;
        let fill = len % self.cfg.page_tokens;
        let cow = fill != 0 && {
            let last = self.seqs[seq].pages[len / self.cfg.page_tokens
                                            - released];
            self.refcounts[last] > 1
        };
        let need_pages = (len + n).div_ceil(self.cfg.page_tokens)
            .saturating_sub(released + self.seqs[seq].pages.len())
            + usize::from(cow);
        if need_pages > self.free_pages.len() {
            return Err(OutOfPages { seq, len });
        }
        if cow {
            let idx = len / self.cfg.page_tokens - released;
            let old = self.seqs[seq].pages[idx];
            let page = self.free_pages.pop().expect("free count checked");
            debug_assert_eq!(self.refcounts[page], 0);
            // Copy the committed slots; the remainder of the fresh page
            // is claimed-then-written before any read, as always.
            let stride = self.cfg.page_stride();
            let filled = fill * self.cfg.token_stride();
            let (src, dst) = (old * stride, page * stride);
            self.data.copy_within(src..src + filled, dst);
            self.seqs[seq].pages[idx] = page;
            self.refcounts[page] = 1;
            self.refcounts[old] -= 1;
            debug_assert!(self.refcounts[old] >= 1,
                          "cow source page must still have a holder");
            self.cow_copies += 1;
        }
        while (len + n).div_ceil(self.cfg.page_tokens)
            > released + self.seqs[seq].pages.len() {
            let page = self.free_pages.pop().expect("free count checked");
            debug_assert_eq!(self.refcounts[page], 0);
            self.refcounts[page] = 1;
            self.seqs[seq].pages.push(page);
        }
        self.seqs[seq].len = len + n;
        Ok(len)
    }

    /// Roll a live sequence back to `new_len` committed tokens,
    /// returning every page that held only rejected positions to the
    /// free list. The speculative-decoding rollback primitive: a
    /// draft-verify lane claims its whole proposal span up front
    /// ([`KvCache::begin_tokens`]) and truncates the rejected suffix
    /// here, so mis-speculated slots never linger in the pool.
    ///
    /// Refcount-aware like [`KvCache::free_seq`]: dropped pages lose
    /// one holder and return to the free list only at zero, so a
    /// shared prefix donor (or any sibling mapped via
    /// [`KvCache::share_prefix`]) is never invalidated by a sharer's
    /// rollback. A kept partial last page stays in the table with its
    /// sharing state intact — if it is still shared, the sequence's
    /// next claim copy-on-writes exactly as it would have without the
    /// truncation. Truncating to 0 releases the whole page table like
    /// `free_seq` but keeps the sequence live (and growable); `new_len
    /// > len` is a caller bug and panics. Returns the number of pages
    /// actually freed.
    pub fn truncate_seq(&mut self, seq: usize, new_len: usize) -> usize {
        let s = &mut self.seqs[seq];
        assert!(s.live, "truncate_seq({seq}) on a sequence that is not live");
        assert!(new_len <= s.len,
                "truncate_seq({seq}) to {new_len} tokens on a {}-token \
                 sequence — rollback cannot extend",
                s.len);
        let keep = new_len.div_ceil(self.cfg.page_tokens);
        // A rollback target below the front-released point would need
        // pages that no longer exist; the speculative verify path can
        // never produce one (the window release uses the span *start*,
        // rollback targets sit at or past it). Truncate-to-zero is the
        // one sanctioned full reset.
        assert!(new_len == 0 || keep >= s.released,
                "truncate_seq({seq}) to {new_len} tokens crosses {} \
                 front-released pages", s.released);
        let cut = if new_len == 0 { 0 } else { keep - s.released };
        let dropped: Vec<usize> = s.pages.drain(cut..).collect();
        if new_len == 0 {
            s.released = 0;
        }
        s.len = new_len;
        let mut freed = 0usize;
        for page in dropped {
            let rc = self.refcounts[page].checked_sub(1)
                .expect("truncate_seq on a page with refcount 0");
            self.refcounts[page] = rc;
            if rc == 0 {
                self.free_pages.push(page);
                freed += 1;
            }
        }
        freed
    }

    /// Sliding-window page recycling: return every page holding only
    /// positions `< pos` to the pool (refcount-aware, like
    /// [`KvCache::truncate_seq`] at the other end). The attention model
    /// calls this once all layers' windows have moved past `pos` —
    /// released positions are unreadable afterwards, which is exactly
    /// the windowed-attention guarantee. Committed length and position
    /// numbering are unchanged: the sequence still *addresses*
    /// positions `>= released_tokens`, it just no longer holds the
    /// pages below them, so a long-context windowed lane plateaus at
    /// `O(window)` pages instead of growing `O(context)`. Returns the
    /// number of pages actually freed (a shared page drops a holder but
    /// frees only at zero).
    pub fn release_before(&mut self, seq: usize, pos: usize) -> usize {
        let s = &mut self.seqs[seq];
        assert!(s.live,
                "release_before({seq}) on a sequence that is not live");
        let cut = pos.min(s.len) / self.cfg.page_tokens;
        if cut <= s.released {
            return 0;
        }
        let drop_n = cut - s.released;
        let dropped: Vec<usize> = s.pages.drain(..drop_n).collect();
        s.released = cut;
        let mut freed = 0usize;
        for page in dropped {
            let rc = self.refcounts[page].checked_sub(1)
                .expect("release_before on a page with refcount 0");
            self.refcounts[page] = rc;
            if rc == 0 {
                self.free_pages.push(page);
                freed += 1;
            }
        }
        freed
    }

    /// Leading logical pages of `seq` handed back by
    /// [`KvCache::release_before`] (diagnostic/test visibility).
    pub fn released_pages(&self, seq: usize) -> usize {
        self.seqs[seq].released
    }

    /// Committed length of `seq` in tokens.
    pub fn seq_len(&self, seq: usize) -> usize {
        self.seqs[seq].len
    }

    /// Flat-slab offset of (seq, layer, pos)'s k vector; v follows at
    /// `+ hidden`.
    fn offset(&self, seq: usize, layer: usize, pos: usize) -> usize {
        let s = &self.seqs[seq];
        debug_assert!(pos < s.len, "pos {pos} >= seq len {}", s.len);
        debug_assert!(layer < self.cfg.layers);
        let logical = pos / self.cfg.page_tokens;
        debug_assert!(logical >= s.released,
                      "pos {pos} reads below the released window \
                       ({} pages gone)", s.released);
        let page = s.pages[logical - s.released];
        page * self.cfg.page_stride()
            + (pos % self.cfg.page_tokens) * self.cfg.token_stride()
            + layer * 2 * self.cfg.hidden
    }

    /// Write layer `layer`'s k/v for the token slot most recently
    /// claimed by [`KvCache::begin_token`] (position `seq_len - 1`).
    pub fn write_kv(&mut self, seq: usize, layer: usize,
                    k: &[f32], v: &[f32]) {
        let pos = self.seqs[seq].len.checked_sub(1)
            .expect("write_kv before begin_token");
        self.write_kv_at(seq, layer, pos, k, v);
    }

    /// Write layer `layer`'s k/v for an explicit claimed position
    /// (`pos < seq_len`). Chunked prefill claims a whole span with
    /// [`KvCache::begin_tokens`] and then fills each position of the
    /// span in order through this entry point.
    pub fn write_kv_at(&mut self, seq: usize, layer: usize, pos: usize,
                       k: &[f32], v: &[f32]) {
        let hidden = self.cfg.hidden;
        assert_eq!(k.len(), hidden, "k width");
        assert_eq!(v.len(), hidden, "v width");
        debug_assert_eq!(
            self.refcounts[self.seqs[seq].pages[pos / self.cfg.page_tokens
                                                - self.seqs[seq].released]],
            1, "write into a shared page: copy-on-write was skipped");
        let off = self.offset(seq, layer, pos);
        self.data[off..off + hidden].copy_from_slice(k);
        self.data[off + hidden..off + 2 * hidden].copy_from_slice(v);
    }

    /// Read (k, v) of (seq, layer, pos). `pos` must be < the committed
    /// length, so every read hits a slot [`KvCache::write_kv`] filled.
    pub fn kv(&self, seq: usize, layer: usize, pos: usize)
              -> (&[f32], &[f32]) {
        let hidden = self.cfg.hidden;
        let off = self.offset(seq, layer, pos);
        (&self.data[off..off + hidden],
         &self.data[off + hidden..off + 2 * hidden])
    }

    /// *Physical* pages currently held by live sequences — a page
    /// shared by N page tables counts once (that is the capacity
    /// multiplier prefix sharing buys).
    pub fn pages_in_use(&self) -> usize {
        self.cfg.n_pages - self.free_pages.len()
    }

    /// Copy-on-write page copies performed since construction.
    pub fn cow_copies(&self) -> usize {
        self.cow_copies
    }

    /// Current holder count of the page containing position `pos` of
    /// `seq` (test/diagnostic visibility into sharing state).
    pub fn page_refcount(&self, seq: usize, pos: usize) -> u32 {
        self.refcounts[self.seqs[seq].pages[pos / self.cfg.page_tokens
                                            - self.seqs[seq].released]]
    }

    /// Pages available for claims.
    pub fn free_page_count(&self) -> usize {
        self.free_pages.len()
    }

    /// Live (allocated, not yet freed) sequences.
    pub fn live_seqs(&self) -> usize {
        self.seqs.iter().filter(|s| s.live).count()
    }

    /// Fault injection: force the next `n` claims
    /// ([`KvCache::begin_token`] / [`KvCache::begin_tokens`]) to
    /// refuse with [`OutOfPages`] even though free pages exist. Unlike
    /// the scheduler-level forcing this drives the *real* refusal
    /// path through the model's claim code; chaos tests use it to
    /// prove injected and genuine exhaustion are handled identically.
    pub fn inject_refusals(&mut self, n: usize) {
        self.forced_refusals += n;
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn tiny(n_pages: usize) -> KvCache {
        KvCache::new(KvCacheConfig {
            layers: 2,
            hidden: 4,
            page_tokens: 3,
            n_pages,
        })
    }

    #[test]
    fn pages_are_claimed_lazily_and_freed_wholesale() {
        let mut c = tiny(4);
        assert_eq!(c.pages_in_use(), 0);
        let s = c.alloc_seq();
        assert_eq!(c.pages_in_use(), 0, "alloc_seq must not claim pages");
        for i in 0..7 {
            assert_eq!(c.begin_token(s).unwrap(), i);
        }
        // 7 tokens at 3 tokens/page = 3 pages.
        assert_eq!(c.seq_len(s), 7);
        assert_eq!(c.pages_in_use(), 3);
        c.free_seq(s);
        assert_eq!(c.pages_in_use(), 0);
        assert_eq!(c.live_seqs(), 0);
    }

    #[test]
    fn kv_roundtrip_is_exact_across_pages_and_layers() {
        let mut c = tiny(4);
        let s = c.alloc_seq();
        for pos in 0..5 {
            c.begin_token(s).unwrap();
            for layer in 0..2 {
                let k: Vec<f32> =
                    (0..4).map(|j| (100 * pos + 10 * layer + j) as f32).collect();
                let v: Vec<f32> = k.iter().map(|x| -x).collect();
                c.write_kv(s, layer, &k, &v);
            }
        }
        for pos in 0..5 {
            for layer in 0..2 {
                let (k, v) = c.kv(s, layer, pos);
                for j in 0..4 {
                    let want = (100 * pos + 10 * layer + j) as f32;
                    assert_eq!(k[j], want, "k seq pos {pos} layer {layer}");
                    assert_eq!(v[j], -want, "v seq pos {pos} layer {layer}");
                }
            }
        }
    }

    #[test]
    fn sequences_are_isolated() {
        // Interleaved growth of two sequences: each reads back only its
        // own writes.
        let mut c = tiny(6);
        let a = c.alloc_seq();
        let b = c.alloc_seq();
        for pos in 0..4 {
            for (&s, sign) in [(&a, 1.0f32), (&b, -1.0)] {
                c.begin_token(s).unwrap();
                let k = vec![sign * (pos as f32 + 1.0); 4];
                for layer in 0..2 {
                    c.write_kv(s, layer, &k, &k);
                }
            }
        }
        for pos in 0..4 {
            assert!(c.kv(a, 0, pos).0.iter().all(|&x| x > 0.0));
            assert!(c.kv(b, 0, pos).0.iter().all(|&x| x < 0.0));
        }
    }

    #[test]
    fn out_of_pages_refuses_without_corrupting_the_sequence() {
        let mut c = tiny(2); // 2 pages x 3 tokens = 6-token pool
        let a = c.alloc_seq();
        let b = c.alloc_seq();
        for _ in 0..3 {
            c.begin_token(a).unwrap();
        }
        for _ in 0..3 {
            c.begin_token(b).unwrap();
        }
        // Both pages held; the next boundary crossing must refuse.
        let err = c.begin_token(a).unwrap_err();
        assert_eq!(err, OutOfPages { seq: a, len: 3 });
        assert!(err.to_string().contains("out of pages"));
        assert_eq!(c.seq_len(a), 3, "failed claim must not grow the seq");
        // Retiring b makes the claim succeed — admission control, not a
        // permanent failure.
        c.free_seq(b);
        assert_eq!(c.begin_token(a).unwrap(), 3);
    }

    #[test]
    fn lane_churn_recycles_pages_and_seq_ids() {
        // A serving-shaped workload: waves of short sequences over a
        // pool sized for 3 concurrent lanes. Pages and seq ids must be
        // reused, never exhausted, across many waves.
        let mut c = KvCache::for_lanes(2, 4, 3, 3, 5);
        assert_eq!(c.config().n_pages, 3 * 2); // ceil(5/3) = 2 per lane
        for wave in 0..50 {
            let seqs: Vec<usize> = (0..3).map(|_| c.alloc_seq()).collect();
            for &s in &seqs {
                for _ in 0..5 {
                    c.begin_token(s).unwrap();
                    for layer in 0..2 {
                        c.write_kv(s, layer, &[wave as f32; 4],
                                   &[wave as f32; 4]);
                    }
                }
                assert_eq!(c.kv(s, 1, 4).0[0], wave as f32);
            }
            assert_eq!(c.pages_in_use(), 6, "wave {wave}");
            for &s in &seqs {
                c.free_seq(s);
            }
            assert_eq!(c.pages_in_use(), 0, "wave {wave}");
        }
        // Seq-id table stayed at the peak lane count.
        assert!(c.seqs.len() <= 3, "seq table grew to {}", c.seqs.len());
    }

    #[test]
    fn for_lanes_capacity_is_exact() {
        // lanes * max_context tokens all admit; one more page claim
        // refuses (the admission-control contract AttnLm sizes by).
        let mut c = KvCache::for_lanes(1, 2, 4, 2, 8);
        let seqs: Vec<usize> = (0..2).map(|_| c.alloc_seq()).collect();
        for &s in &seqs {
            for _ in 0..8 {
                c.begin_token(s).unwrap();
            }
        }
        assert!(c.begin_token(seqs[0]).is_err());
        assert_eq!(c.config().capacity_tokens(), 16);
    }

    #[test]
    fn bytes_per_token_accounts_all_layers() {
        let cfg = KvCacheConfig { layers: 4, hidden: 256, page_tokens: 16,
                                  n_pages: 8 };
        // k + v, 4 layers, 256 f32 each: 2 * 4 * 256 * 4 bytes.
        assert_eq!(cfg.bytes_per_token(), 8192);
        assert_eq!(cfg.token_stride(), 2048);
        assert_eq!(cfg.page_stride(), 16 * 2048);
    }

    #[test]
    fn begin_tokens_claims_spans_across_page_boundaries() {
        // One 7-slot span over 3-token pages: 3 pages claimed at once,
        // positions numbered contiguously, per-position writes land
        // exactly where one-token claims would have put them.
        let mut c = tiny(4);
        let s = c.alloc_seq();
        assert_eq!(c.begin_tokens(s, 7).unwrap(), 0);
        assert_eq!(c.seq_len(s), 7);
        assert_eq!(c.pages_in_use(), 3);
        for pos in 0..7 {
            for layer in 0..2 {
                let k = vec![(10 * pos + layer) as f32; 4];
                c.write_kv_at(s, layer, pos, &k, &k);
            }
        }
        // A follow-up span continues from the committed length.
        assert_eq!(c.begin_tokens(s, 2).unwrap(), 7);
        assert_eq!(c.pages_in_use(), 3); // 9 tokens still fit 3 pages
        for pos in 0..7 {
            assert_eq!(c.kv(s, 1, pos).0[0], (10 * pos + 1) as f32);
        }
    }

    #[test]
    fn begin_tokens_refusal_is_all_or_nothing() {
        // 2 pages x 3 tokens = 6 slots; a 3-slot span by seq b leaves
        // room for nothing more: a 4-slot claim must refuse without
        // claiming the one free page it could have taken.
        let mut c = tiny(2);
        let a = c.alloc_seq();
        let b = c.alloc_seq();
        c.begin_tokens(b, 3).unwrap();
        let err = c.begin_tokens(a, 4).unwrap_err();
        assert_eq!(err, OutOfPages { seq: a, len: 0 });
        assert_eq!(c.seq_len(a), 0, "failed span claim must not grow seq");
        assert_eq!(c.free_page_count(), 1,
                   "failed span claim must not take partial pages");
        // A span that does fit still succeeds afterwards.
        assert_eq!(c.begin_tokens(a, 3).unwrap(), 0);
        assert_eq!(c.free_page_count(), 0);
    }

    #[test]
    fn single_and_multi_token_claims_interleave() {
        let mut c = tiny(4);
        let s = c.alloc_seq();
        assert_eq!(c.begin_token(s).unwrap(), 0);
        assert_eq!(c.begin_tokens(s, 4).unwrap(), 1);
        assert_eq!(c.begin_token(s).unwrap(), 5);
        assert_eq!(c.seq_len(s), 6);
        assert_eq!(c.pages_in_use(), 2);
    }

    #[test]
    #[should_panic(expected = "not live")]
    fn double_free_is_rejected() {
        let mut c = tiny(2);
        let s = c.alloc_seq();
        c.free_seq(s);
        c.free_seq(s);
    }

    /// Fill `n` positions of `seq` with per-position values scaled by
    /// `tag` so reads identify exactly which write they see.
    fn fill(c: &mut KvCache, seq: usize, from: usize, to: usize, tag: f32) {
        for pos in from..to {
            for layer in 0..2 {
                let k = vec![tag * (pos as f32 + 1.0); 4];
                c.write_kv_at(seq, layer, pos, &k, &k);
            }
        }
    }

    #[test]
    fn shared_prefix_pages_are_counted_once() {
        // A 5-token prefix over 3-token pages = 2 pages; three sharers
        // hold them physically once.
        let mut c = tiny(6);
        let src = c.alloc_seq();
        c.begin_tokens(src, 5).unwrap();
        fill(&mut c, src, 0, 5, 1.0);
        assert_eq!(c.pages_in_use(), 2);
        for _ in 0..2 {
            let dst = c.alloc_seq();
            assert_eq!(c.share_prefix(src, dst, 5), 2);
            assert_eq!(c.seq_len(dst), 5);
        }
        assert_eq!(c.pages_in_use(), 2, "sharing must not consume pages");
        assert_eq!(c.page_refcount(src, 0), 3);
        assert_eq!(c.page_refcount(src, 4), 3);
    }

    #[test]
    fn shared_reads_match_the_source_bitwise() {
        let mut c = tiny(4);
        let src = c.alloc_seq();
        c.begin_tokens(src, 5).unwrap();
        fill(&mut c, src, 0, 5, 1.0);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 5);
        for pos in 0..5 {
            for layer in 0..2 {
                assert_eq!(c.kv(src, layer, pos), c.kv(dst, layer, pos),
                           "shared read pos {pos} layer {layer}");
            }
        }
    }

    #[test]
    fn cow_isolates_divergence_from_the_sibling() {
        // Share a partial last page (4 tokens over 3-token pages: page 1
        // holds one committed slot), then grow the sharer: the claim
        // must copy page 1, and the sharer's writes must never reach
        // the source's reads.
        let mut c = tiny(6);
        let src = c.alloc_seq();
        c.begin_tokens(src, 4).unwrap();
        fill(&mut c, src, 0, 4, 1.0);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 4);
        assert_eq!(c.pages_in_use(), 2);
        assert_eq!(c.cow_copies(), 0);
        assert_eq!(c.begin_tokens(dst, 2).unwrap(), 4);
        assert_eq!(c.cow_copies(), 1, "partial shared page must copy");
        assert_eq!(c.pages_in_use(), 3, "one private copy of page 1");
        assert_eq!(c.page_refcount(src, 3), 1, "src owns its tail again");
        assert_eq!(c.page_refcount(dst, 3), 1, "dst owns the copy");
        // The copy carried the committed slot bit-for-bit...
        for layer in 0..2 {
            assert_eq!(c.kv(dst, layer, 3), c.kv(src, layer, 3));
        }
        // ...and divergent writes stay private in both directions.
        fill(&mut c, dst, 4, 6, -1.0);
        fill(&mut c, src, 3, 4, 7.0);
        assert_eq!(c.kv(dst, 0, 3).0[0], 4.0, "sibling write must not leak");
        assert_eq!(c.kv(src, 0, 3).0[0], 7.0 * 4.0);
    }

    #[test]
    fn aligned_share_grows_without_cow() {
        // A page-aligned prefix (3 tokens = exactly page 0) leaves no
        // partial page to diverge in: growth claims a fresh page, no
        // copy.
        let mut c = tiny(4);
        let src = c.alloc_seq();
        c.begin_tokens(src, 3).unwrap();
        fill(&mut c, src, 0, 3, 1.0);
        let dst = c.alloc_seq();
        assert_eq!(c.share_prefix(src, dst, 3), 1);
        c.begin_tokens(dst, 1).unwrap();
        assert_eq!(c.cow_copies(), 0, "aligned divergence needs no copy");
        assert_eq!(c.pages_in_use(), 2);
    }

    #[test]
    fn cow_page_is_part_of_the_all_or_nothing_claim() {
        // 2 pages, both held: the sharer's 1-token claim needs one CoW
        // page and must refuse without mutating anything. Once the
        // source retires, the sharer owns the pages exclusively and the
        // same claim succeeds with no copy at all.
        let mut c = tiny(2);
        let src = c.alloc_seq();
        c.begin_tokens(src, 4).unwrap(); // both pages
        fill(&mut c, src, 0, 4, 1.0);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 4);
        let err = c.begin_token(dst).unwrap_err();
        assert_eq!(err, OutOfPages { seq: dst, len: 4 });
        assert_eq!(c.seq_len(dst), 4, "refused cow claim must not grow seq");
        assert_eq!(c.cow_copies(), 0, "refused claim must not copy");
        assert_eq!(c.page_refcount(dst, 3), 2, "refusal leaves sharing intact");
        c.free_seq(src);
        assert_eq!(c.page_refcount(dst, 3), 1);
        assert_eq!(c.begin_token(dst).unwrap(), 4);
        assert_eq!(c.cow_copies(), 0,
                   "exclusive ownership regained: no copy needed");
        assert_eq!(c.pages_in_use(), 2);
    }

    #[test]
    fn refcounted_free_releases_pages_only_at_zero() {
        let mut c = tiny(4);
        let src = c.alloc_seq();
        c.begin_tokens(src, 5).unwrap();
        fill(&mut c, src, 0, 5, 1.0);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 5);
        c.free_seq(src);
        assert_eq!(c.pages_in_use(), 2,
                   "sharer still holds both pages after src retires");
        for pos in 0..5 {
            assert_eq!(c.kv(dst, 0, pos).0[0], pos as f32 + 1.0,
                       "prefix must survive the source retiring");
        }
        c.free_seq(dst);
        assert_eq!(c.pages_in_use(), 0, "last holder frees the pages");
        // Churn after sharing: everything is recyclable.
        let s = c.alloc_seq();
        c.begin_tokens(s, 12).unwrap(); // the whole pool
        assert_eq!(c.free_page_count(), 0);
        c.free_seq(s);
        assert_eq!(c.free_page_count(), 4);
    }

    #[test]
    fn injected_refusals_take_the_real_out_of_pages_exit() {
        // Forced refusals refuse without mutating anything (the
        // all-or-nothing contract), decrement one per claim, and the
        // cache behaves normally once the script is spent.
        let mut c = tiny(4);
        let s = c.alloc_seq();
        c.begin_token(s).unwrap();
        c.inject_refusals(2);
        for _ in 0..2 {
            let err = c.begin_token(s).unwrap_err();
            assert_eq!(err, OutOfPages { seq: s, len: 1 });
            assert_eq!(c.seq_len(s), 1, "injected refusal mutated the seq");
        }
        assert_eq!(c.begin_token(s).unwrap(), 1,
                   "spent fault script must stop refusing");
        assert_eq!(c.pages_in_use(), 1);
    }

    #[test]
    #[should_panic(expected = "must be fresh")]
    fn share_into_a_grown_sequence_is_rejected() {
        let mut c = tiny(4);
        let src = c.alloc_seq();
        c.begin_tokens(src, 3).unwrap();
        let dst = c.alloc_seq();
        c.begin_token(dst).unwrap();
        c.share_prefix(src, dst, 3);
    }

    #[test]
    fn truncate_returns_exactly_the_rejected_pages() {
        // 8 tokens over 3-token pages = pages [0..3), [3..6), [6..8).
        // Rolling back to 4 rejects positions 4..8: only the last page
        // is wholly rejected; the middle page keeps position 3.
        let mut c = tiny(4);
        let s = c.alloc_seq();
        c.begin_tokens(s, 8).unwrap();
        fill(&mut c, s, 0, 8, 1.0);
        assert_eq!(c.pages_in_use(), 3);
        assert_eq!(c.truncate_seq(s, 4), 1);
        assert_eq!(c.seq_len(s), 4);
        assert_eq!(c.pages_in_use(), 2);
        for pos in 0..4 {
            assert_eq!(c.kv(s, 0, pos).0[0], pos as f32 + 1.0,
                       "surviving slot {pos} must be untouched");
        }
        // Regrowth reclaims the freed page and renumbers from 4.
        assert_eq!(c.begin_tokens(s, 3).unwrap(), 4);
        assert_eq!(c.pages_in_use(), 3);
        // Page-boundary math: 7 -> 6 frees exactly the page holding
        // position 6, and a no-op truncate frees nothing.
        assert_eq!(c.truncate_seq(s, 6), 1);
        assert_eq!(c.truncate_seq(s, 6), 0, "no-op truncate frees nothing");
        assert_eq!(c.seq_len(s), 6);
    }

    #[test]
    fn shared_prefix_donor_survives_a_sharers_truncation() {
        // dst shares src's 5-token prefix, CoW-diverges, then rolls all
        // the way back to 2 tokens: its private copy and growth page
        // return to the free list, while the shared page 0 keeps both
        // holders and src's data is never invalidated.
        let mut c = tiny(6);
        let src = c.alloc_seq();
        c.begin_tokens(src, 5).unwrap();
        fill(&mut c, src, 0, 5, 1.0);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 5);
        assert_eq!(c.begin_tokens(dst, 3).unwrap(), 5);
        assert_eq!(c.cow_copies(), 1);
        assert_eq!(c.pages_in_use(), 4);
        assert_eq!(c.truncate_seq(dst, 2), 2,
                   "private copy + growth page rejected; shared page kept");
        assert_eq!(c.pages_in_use(), 2);
        assert_eq!(c.page_refcount(src, 0), 2,
                   "shared page keeps both holders");
        assert_eq!(c.page_refcount(src, 4), 1,
                   "src owns its tail exclusively again");
        for pos in 0..5 {
            assert_eq!(c.kv(src, 0, pos).0[0], pos as f32 + 1.0,
                       "donor slot {pos} must survive the rollback");
        }
        // CoW safety after rollback: dst's kept last page is still
        // shared and partial, so its next claim copies before writing.
        assert_eq!(c.begin_tokens(dst, 1).unwrap(), 2);
        assert_eq!(c.cow_copies(), 2,
                   "regrowth into the kept shared page must CoW");
        fill(&mut c, dst, 2, 3, -1.0);
        assert_eq!(c.kv(src, 0, 2).0[0], 3.0,
                   "post-rollback divergence must stay private");
    }

    #[test]
    fn truncate_to_zero_frees_like_free_seq_but_keeps_the_seq_live() {
        let mut c = tiny(4);
        let s = c.alloc_seq();
        c.begin_tokens(s, 7).unwrap();
        c.free_seq(s);
        assert_eq!(c.pages_in_use(), 0);
        let s2 = c.alloc_seq();
        c.begin_tokens(s2, 7).unwrap();
        assert_eq!(c.truncate_seq(s2, 0), 3,
                   "truncate-to-zero returns the whole page table");
        assert_eq!(c.pages_in_use(), 0, "page-wise identical to free_seq");
        assert_eq!(c.seq_len(s2), 0);
        // ...but unlike free_seq the sequence stays live and growable.
        assert_eq!(c.live_seqs(), 1);
        assert_eq!(c.begin_tokens(s2, 4).unwrap(), 0);
        assert_eq!(c.pages_in_use(), 2);
    }

    #[test]
    fn cow_pages_freed_by_truncation_are_reclaimable() {
        // Pool of 3: src holds pages 0,1 (4 tokens); dst shares and its
        // claim CoW-copies page 1 into the last free page. The pool is
        // now exhausted — until dst's rollback drops the copy, at which
        // point a third lane can claim it immediately.
        let mut c = tiny(3);
        let src = c.alloc_seq();
        c.begin_tokens(src, 4).unwrap();
        fill(&mut c, src, 0, 4, 1.0);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 4);
        assert_eq!(c.begin_tokens(dst, 1).unwrap(), 4);
        assert_eq!(c.cow_copies(), 1);
        assert_eq!(c.free_page_count(), 0);
        let other = c.alloc_seq();
        assert!(c.begin_tokens(other, 1).is_err(), "pool must be exhausted");
        assert_eq!(c.truncate_seq(dst, 3), 1,
                   "rollback below the copy frees the CoW page itself");
        assert_eq!(c.free_page_count(), 1);
        assert_eq!(c.begin_tokens(other, 3).unwrap(), 0,
                   "freed CoW page is immediately claimable");
        assert_eq!(c.free_page_count(), 0);
        assert_eq!(c.page_refcount(dst, 0), 2, "page 0 still shared");
        for pos in 0..4 {
            assert_eq!(c.kv(src, 0, pos).0[0], pos as f32 + 1.0,
                       "src never loses a slot to the sharer's rollback");
        }
    }

    #[test]
    fn release_before_frees_whole_pages_and_keeps_the_tail_readable() {
        // 8 tokens over 3-token pages: releasing before position 7
        // frees pages [0..3) and [3..6); positions 6/7 stay readable
        // at their original numbering and growth continues from 8.
        let mut c = tiny(4);
        let s = c.alloc_seq();
        c.begin_tokens(s, 8).unwrap();
        fill(&mut c, s, 0, 8, 1.0);
        assert_eq!(c.pages_in_use(), 3);
        assert_eq!(c.release_before(s, 7), 2);
        assert_eq!(c.released_pages(s), 2);
        assert_eq!(c.pages_in_use(), 1);
        assert_eq!(c.seq_len(s), 8, "release must not change the length");
        for pos in 6..8 {
            assert_eq!(c.kv(s, 0, pos).0[0], pos as f32 + 1.0,
                       "in-window slot {pos} must survive the release");
        }
        // Position numbering is unchanged: the next claim is 8, lands
        // on a fresh page, and reads back at its logical position.
        assert_eq!(c.begin_token(s).unwrap(), 8);
        fill(&mut c, s, 8, 9, -1.0);
        assert_eq!(c.kv(s, 1, 8).0[0], -9.0);
        assert_eq!(c.pages_in_use(), 2);
        // Releasing at or below the already-released point is a no-op.
        assert_eq!(c.release_before(s, 6), 0);
        assert_eq!(c.release_before(s, 3), 0);
        // free_seq returns everything and the id recycles clean.
        c.free_seq(s);
        assert_eq!(c.pages_in_use(), 0);
        let s2 = c.alloc_seq();
        assert_eq!(c.released_pages(s2), 0);
        assert_eq!(c.begin_tokens(s2, 4).unwrap(), 0);
    }

    #[test]
    fn windowed_lane_plateaus_instead_of_growing() {
        // The recycling claim itself: a lane decoding far past its
        // window never holds more than window-plus-one-page of pages.
        let mut c = tiny(3); // 3 pages x 3 tokens: pool of 9 slots
        let s = c.alloc_seq();
        let window = 4usize;
        for pos in 0..40 {
            c.begin_token(s).unwrap();
            fill(&mut c, s, pos, pos + 1, 1.0);
            c.release_before(s, (pos + 1).saturating_sub(window));
            assert!(c.pages_in_use() <= 3, "pos {pos} overflowed the pool");
            // The in-window suffix always reads back intact.
            for p in (pos + 1).saturating_sub(window)..=pos {
                assert_eq!(c.kv(s, 0, p).0[0], p as f32 + 1.0);
            }
        }
        assert_eq!(c.seq_len(s), 40);
        c.free_seq(s);
        assert_eq!(c.pages_in_use(), 0, "no pages leak across the churn");
    }

    #[test]
    fn release_before_is_refcount_safe_on_shared_pages() {
        // A sharer releasing its front drops a holder; the donor's data
        // survives, and the page frees only when the donor lets go too.
        let mut c = tiny(4);
        let src = c.alloc_seq();
        c.begin_tokens(src, 6).unwrap();
        fill(&mut c, src, 0, 6, 1.0);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 6);
        assert_eq!(c.pages_in_use(), 2);
        assert_eq!(c.release_before(dst, 3), 0,
                   "shared page drops a holder but must not free");
        assert_eq!(c.page_refcount(src, 0), 1, "src holds page 0 alone now");
        for pos in 0..6 {
            assert_eq!(c.kv(src, 0, pos).0[0], pos as f32 + 1.0,
                       "donor slot {pos} must survive the sharer's release");
        }
        c.free_seq(src);
        assert_eq!(c.pages_in_use(), 1, "dst still holds the tail page");
        c.free_seq(dst);
        assert_eq!(c.pages_in_use(), 0);
    }

    #[test]
    fn truncate_interacts_safely_with_a_released_front() {
        // Speculative rollback on a windowed lane: truncate back toward
        // (but not past) the released point, then regrow.
        let mut c = tiny(4);
        let s = c.alloc_seq();
        c.begin_tokens(s, 10).unwrap();
        fill(&mut c, s, 0, 10, 1.0);
        assert_eq!(c.release_before(s, 6), 2); // pages 0,1 gone
        assert_eq!(c.pages_in_use(), 2);
        // Roll back 10 -> 8: page [9..12) is wholly rejected.
        assert_eq!(c.truncate_seq(s, 8), 1);
        assert_eq!(c.seq_len(s), 8);
        assert_eq!(c.kv(s, 0, 7).0[0], 8.0);
        // Regrowth renumbers from 8 as usual.
        assert_eq!(c.begin_tokens(s, 2).unwrap(), 8);
        assert_eq!(c.truncate_seq(s, 0), 2,
                   "truncate-to-zero drops the remaining table");
        assert_eq!(c.released_pages(s), 0,
                   "full reset clears the released front");
        assert_eq!(c.pages_in_use(), 0);
        assert_eq!(c.begin_tokens(s, 3).unwrap(), 0);
    }

    #[test]
    #[should_panic(expected = "front-released")]
    fn truncating_into_the_released_front_is_rejected() {
        let mut c = tiny(4);
        let s = c.alloc_seq();
        c.begin_tokens(s, 10).unwrap();
        c.release_before(s, 6);
        c.truncate_seq(s, 3); // needs logical page 1, which is gone
    }

    #[test]
    #[should_panic(expected = "cannot donate")]
    fn windowed_sequences_cannot_donate_prefixes() {
        let mut c = tiny(4);
        let src = c.alloc_seq();
        c.begin_tokens(src, 8).unwrap();
        c.release_before(src, 6);
        let dst = c.alloc_seq();
        c.share_prefix(src, dst, 8);
    }
}
