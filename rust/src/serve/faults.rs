//! Deterministic fault injection for the serving stack.
//!
//! Production failure modes — KV-pool exhaustion, worker panics,
//! clients hanging up mid-stream — are timing-dependent by nature,
//! which makes their tests flaky by nature unless the faults are
//! *scripted*. A [`FaultPlan`] is that script: a declarative list of
//! faults keyed to deterministic coordinates (scheduler step numbers,
//! admission ordinals, token indices) instead of wall-clock time, so a
//! chaos test reproduces the identical failure sequence on every run
//! and at every machine speed.
//!
//! Three layers consume the plan:
//!
//! - the [`Scheduler`](crate::serve::Scheduler) treats every live lane
//!   as KV-refused on the steps in
//!   [`FaultPlan::out_of_pages_steps`] (the model is not invoked at
//!   all that step, so the forcing works identically for all four
//!   storage families and for decay models with no KV cache);
//! - the shard worker ([`crate::server`]) drops a request's stream
//!   sink at the scripted token index of [`FaultPlan::disconnect_at`]
//!   — indistinguishable from the client hanging up — and panics
//!   after the step in [`FaultPlan::panic_after_step`] to exercise
//!   the supervisor's catch_unwind/rebuild path;
//! - the paged KV cache can separately force real `OutOfPages`
//!   refusals via
//!   [`KvCache::inject_refusals`](crate::serve::KvCache::inject_refusals)
//!   (plumbed through
//!   [`AttnLm::inject_kv_refusals`](crate::serve::AttnLm::inject_kv_refusals)),
//!   which exercises the genuine refusal path rather than the
//!   scheduler-level synthesis.
//!
//! The empty plan is the default and injects nothing: every consumer
//! checks `is_empty()` first, so the fault hooks cost nothing on the
//! healthy path.

/// A deterministic fault script, threaded into the scheduler and the
/// shard worker. All coordinates are deterministic counters, never
/// wall clock. The default (all fields empty) injects nothing.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct FaultPlan {
    /// Scheduler steps (1-based: the Nth call that actually runs
    /// lanes) on which *every* live lane is treated as refused by KV
    /// admission — the full-pool backpressure path (release pages,
    /// requeue, deferred readmission) without needing a cache small
    /// enough to actually fill.
    pub out_of_pages_steps: Vec<usize>,
    /// Panic the shard worker after it completes this scheduler step
    /// (1-based, counted by the worker). The supervisor's
    /// catch_unwind / rebuild / restart-counting path is the consumer.
    /// Consumed by the first worker incarnation only, so the rebuilt
    /// worker does not re-panic in a loop.
    pub panic_after_step: Option<usize>,
    /// `(request ordinal, token index)` pairs: the shard worker drops
    /// request `ordinal`'s stream sink (the admission ticket, 0-based
    /// in admission order) once the stream has delivered `token
    /// index` — exactly what a mid-stream client hangup looks like
    /// from the worker's side, minus the socket timing.
    pub disconnect_at: Vec<(usize, usize)>,
}

impl FaultPlan {
    /// True when the plan injects nothing (the healthy-path default).
    pub fn is_empty(&self) -> bool {
        self.out_of_pages_steps.is_empty()
            && self.panic_after_step.is_none()
            && self.disconnect_at.is_empty()
    }

    /// Should scheduler step `step` (1-based) treat every live lane as
    /// KV-refused?
    pub fn forces_out_of_pages(&self, step: usize) -> bool {
        self.out_of_pages_steps.contains(&step)
    }

    /// Should the worker panic after completing step `step` (1-based)?
    pub fn panics_after(&self, step: usize) -> bool {
        self.panic_after_step == Some(step)
    }

    /// The scripted disconnect index for request `ordinal`, if any:
    /// the stream is cut once token `index` has been delivered.
    pub fn disconnect_index(&self, ordinal: usize) -> Option<usize> {
        self.disconnect_at.iter()
            .find(|&&(o, _)| o == ordinal)
            .map(|&(_, idx)| idx)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_plan_is_empty_and_injects_nothing() {
        let p = FaultPlan::default();
        assert!(p.is_empty());
        assert!(!p.forces_out_of_pages(1));
        assert!(!p.panics_after(1));
        assert_eq!(p.disconnect_index(0), None);
    }

    #[test]
    fn coordinates_match_exactly() {
        let p = FaultPlan {
            out_of_pages_steps: vec![3, 5],
            panic_after_step: Some(7),
            disconnect_at: vec![(0, 2), (4, 0)],
        };
        assert!(!p.is_empty());
        assert!(p.forces_out_of_pages(3));
        assert!(p.forces_out_of_pages(5));
        assert!(!p.forces_out_of_pages(4));
        assert!(p.panics_after(7));
        assert!(!p.panics_after(6));
        assert_eq!(p.disconnect_index(0), Some(2));
        assert_eq!(p.disconnect_index(4), Some(0));
        assert_eq!(p.disconnect_index(1), None);
    }
}
