//! The multi-request decode scheduler: continuous batching over any
//! [`DecodeModel`] — the blocked ternary, k-bit quant, and dense f32
//! serving models all run underneath it unchanged.
//!
//! The scheduler owns `max_batch` *lanes*. Each step it (1) admits
//! queued requests into empty lanes, (2) assembles the live lanes'
//! states + per-lane token *spans* into one flattened kernel
//! invocation, (3) advances every lane — a lane with unconsumed prompt
//! feeds up to [`Scheduler::prefill_chunk()`] tokens this step (chunked
//! prefill; the default chunk of 1 is the classic one-token path),
//! then sampling starts on the final prompt position — and (4) retires
//! finished lanes, whose slots are refilled from the queue on the next
//! step while the remaining lanes continue mid-flight (continuous
//! batching: the batch never drains to refill).
//!
//! Determinism: a lane's computation depends only on its own state and
//! token stream ([`DecodeModel::step_spans_into`]'s contract + the
//! kernels' batch-invariant accumulation order), greedy argmax breaks
//! ties by token id, and top-k sampling draws from a per-request
//! seeded [`SplitMix64`]. The same request set therefore yields
//! identical token streams at batch 1 and batch 8 *and at any prefill
//! chunk size* — `tests/serve_determinism.rs` and
//! `tests/prefill_chunking.rs` lock this in.
//!
//! Backpressure: a model with per-lane admission control (the paged-KV
//! [`crate::serve::AttnLm`]) may reject lanes whose cache claim fails.
//! The scheduler treats a rejection as *deferral*, never as an error:
//! the lane's model-side resources are released
//! ([`DecodeModel::retire_state`]) and the request returns to the head
//! of the queue to restart later — decoding is deterministic, so the
//! retry reproduces the identical stream. Admission backs off with
//! one-step hysteresis: after a step that bounced a lane, no fresh
//! request is admitted until the survivors run one clean step (and
//! after a full drain, exactly one request is readmitted, serializing
//! the restart). Readmitted lanes may bounce again while capacity is
//! still held — requeue churn under sustained overcommit is expected.
//! Its cost is recompute: a refused claim itself runs no kernels, but
//! a bounced *mid-flight* lane discards the prefill/decode work it had
//! done and redoes it after restart (recompute-preemption, the
//! vLLM-style trade; swapped preemption is a ROADMAP refinement). An
//! overcommitted
//! server therefore degrades to queueing; the only loud failure left
//! is a *single* request whose context alone exceeds the whole cache
//! (a sizing error no amount of queueing can fix). Models with a
//! prefix cache add one more relief valve: on any step that rejects a
//! lane, the scheduler asks the model to drop its pinned prefix pages
//! ([`DecodeModel::release_cached_pages`]) *before* requeueing —
//! cached pages always yield to live traffic, and an eviction counts
//! as forward progress for the stall/sizing guards (pages held by
//! pins, unlike pages held by wedged lanes, are always recoverable).
//!
//! Lane lifecycle stays model-blind: the scheduler hands every
//! admitted lane a zeroed state buffer and, when the lane retires,
//! calls [`DecodeModel::retire_state`] exactly once before recycling
//! the buffer. Decay-state models treat both as plain memory; the
//! attention model uses the zeroed buffer as "unbound" and the retire
//! hook to free its paged KV-cache sequence — so paged attention
//! serving needs no scheduler changes beyond this one hook.

use std::collections::VecDeque;

use crate::runtime::{DecodeScratch, SplitMix64, WorkerPool};
use crate::serve::faults::FaultPlan;
use crate::serve::model::{DecodeModel, FamilySpec};

/// Per-lane sampling policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Argmax; ties break toward the lower token id.
    Greedy,
    /// Sample among the `k` highest logits at `temperature`, from a
    /// stream seeded by `seed` (deterministic per request, independent
    /// of batch composition).
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// Speculative decoding configuration
/// ([`Scheduler::set_speculative`]): a cheap draft model proposes `k`
/// greedy tokens per decode round and the target verifies the whole
/// proposal in one chunked [`DecodeModel::step_spans_into`] pass,
/// accepting the longest prefix the lane's own sampling rule agrees
/// with. The paper's thesis as a latency win: TriLM matches FloatLM
/// quality at a fraction of the bits, which makes it the natural
/// `draft_family` for a float or quant target — every accepted token
/// skips one full-price target step.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SpecConfig {
    /// Storage family of the draft model (TriLM by default at the CLI;
    /// telemetry — the scheduler drives whatever draft it was handed).
    pub draft_family: FamilySpec,
    /// Draft tokens proposed per verify round (>= 1). Higher k
    /// amortizes more target steps when acceptance is high and wastes
    /// more verify compute when it is low —
    /// [`crate::deploy::speculative_speedup_bits`] is the analytic
    /// trade-off.
    pub k: usize,
}

/// The scheduler's installed speculative state: the draft model
/// reference plus its [`SpecConfig`].
struct Spec<'m> {
    draft: &'m dyn DecodeModel,
    cfg: SpecConfig,
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
}

impl GenRequest {
    pub fn greedy(id: usize, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        GenRequest { id, prompt, max_new_tokens, sampling: Sampling::Greedy }
    }

    pub fn top_k(id: usize, prompt: Vec<u32>, max_new_tokens: usize,
                 k: usize, temperature: f32, seed: u64) -> Self {
        GenRequest { id, prompt, max_new_tokens,
                     sampling: Sampling::TopK { k, temperature, seed } }
    }
}

/// Why a request's stream ended — carried on every [`Completion`] and
/// surfaced verbatim in the HTTP done trailer's `finish_reason` field,
/// so clients can tell a budget-complete stream from a truncated or
/// failed one without parsing error prose.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FinishReason {
    /// The request generated its full `max_new_tokens` budget — the
    /// normal completion.
    Length,
    /// The decode wall-clock deadline fired ([`Scheduler::expire`]):
    /// the stream was truncated; the tokens delivered so far stand.
    DeadlineExpired,
    /// The request's context alone exceeds the model's whole KV page
    /// pool — a sizing error no amount of requeueing can fix. The
    /// request fails (partial tokens, if any, are in the completion);
    /// the process no longer panics for it.
    KvOverflow,
}

impl FinishReason {
    /// Wire label used in the ndjson done trailer.
    pub fn as_str(self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::DeadlineExpired => "deadline_expired",
            FinishReason::KvOverflow => "kv_overflow",
        }
    }
}

/// A finished request: the generated continuation (prompt excluded).
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    /// Batched steps this request occupied a lane for (prefill + decode).
    pub lane_steps: usize,
    /// Batched steps from (the last) admission to the first generated
    /// token — time-to-first-token in scheduler steps. One-token
    /// prefill pays `prompt_len` steps; a prefill chunk >= prompt_len
    /// pays 1.
    pub ttft_steps: usize,
    /// Why the stream ended ([`FinishReason::Length`] for the normal
    /// budget-complete case).
    pub finish_reason: FinishReason,
}

/// An incremental streaming event emitted by
/// [`Scheduler::step_observed`] — the hook the HTTP front end
/// ([`crate::server`]) uses to stream tokens to clients as they are
/// sampled instead of polling whole [`Completion`]s.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StreamEvent {
    /// Request `id` sampled `token` as generated-stream position
    /// `index` (0-based, prompt excluded) on this step. Emitted for
    /// every sampled token, including the final one — a request's
    /// `Token` events concatenated by `index` are exactly its
    /// [`Completion::tokens`].
    Token { id: usize, token: u32, index: usize },
    /// Request `id` was bounced by KV backpressure and requeued after
    /// having already emitted `discarded` `Token` events. Decoding is
    /// deterministic, so its restart re-emits the *identical* tokens
    /// from `index` 0 — a streaming consumer keeps a high-water mark
    /// per request and forwards only `index >= emitted` (the dedupe
    /// the [`crate::server`] shard workers perform), never a
    /// correction to the client.
    Requeued { id: usize, discarded: usize },
}

/// Per-tenant serving counters — filled by the HTTP front end's
/// admission layer ([`crate::server`]); a scheduler driven directly
/// (serve-bench, tests) has no tenants and leaves the list empty.
#[derive(Debug, Clone, Default, PartialEq, Eq)]
pub struct TenantStats {
    pub tenant: String,
    /// Completions delivered to this tenant.
    pub served: usize,
    /// Requests currently waiting in the admission queue.
    pub queued: usize,
    /// Requests refused at admission (429 queue-full + 413
    /// context-too-large).
    pub rejected: usize,
}

/// Aggregate serving counters for throughput reporting.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Kernel invocations (batched steps with >= 1 live lane).
    pub batch_steps: usize,
    /// Sum over steps of lanes that ran (batch_steps * avg occupancy).
    /// Counts kernel work actually executed, including attempts later
    /// abandoned to backpressure.
    pub lane_steps: usize,
    /// Prompt tokens ingested for *delivered* work: an attempt
    /// abandoned to backpressure is rolled back out, so after a drain
    /// this equals the sum of completed prompts' lengths even under
    /// heavy requeueing (throughput numbers never count redone work).
    pub prefill_tokens: usize,
    /// Tokens generated for *delivered* work (abandoned attempts
    /// rolled back, as above).
    pub generated_tokens: usize,
    pub peak_occupancy: usize,
    /// Sum over completed requests of steps-to-first-token; divide by
    /// completions for the mean TTFT in steps. Delivered-work counter
    /// like the token counts: a requeued lane's abandoned TTFT is
    /// rolled back and the restart's TTFT is what lands here.
    pub ttft_steps: usize,
    /// Lanes bounced by model backpressure (KV pages exhausted) and
    /// requeued. The restarted request re-decodes deterministically,
    /// so requeues never change completion streams — only latency.
    pub requeued: usize,
    /// Admissions whose prompt prefix was served from the model's
    /// prefix cache ([`DecodeModel::prefix_reuse`]). Delivered-work
    /// counter: a hit lane later bounced by backpressure is rolled
    /// back out (the restart re-earns its own hit or miss).
    pub prefix_hits: usize,
    /// Prompt tokens served by *mapping* cached KV pages instead of
    /// running prefill over them. Disjoint from `prefill_tokens` (which
    /// keeps counting only tokens actually fed through kernels), so
    /// `prefill_tokens + prefix_tokens_reused` sums completed prompts'
    /// lengths. Rolled back on requeue like the other delivered-work
    /// counters.
    pub prefix_tokens_reused: usize,
    /// Copy-on-write KV page copies (shared-prefix lanes diverging).
    /// Like `lane_steps`, this measures work actually executed and is
    /// never rolled back.
    pub cow_copies: usize,
    /// Deepest the HTTP admission queue has been ([`crate::server`]'s
    /// bounded per-shard queue; `Retry-After` fires past its cap).
    /// Scheduler-only use (serve-bench, tests) leaves it 0.
    pub queue_depth_max: usize,
    /// Requests refused with `429 Retry-After` because the shard's
    /// admission queue was full. Server-side counter, 0 off the HTTP
    /// path.
    pub rejected_429: usize,
    /// Requests refused with `413` because prompt + max_new_tokens
    /// exceeded the per-lane KV context the server was sized for (the
    /// admission control that keeps a single oversized request from
    /// tripping the scheduler's sizing panic). Server-side counter, 0
    /// off the HTTP path.
    pub rejected_413: usize,
    /// Per-tenant served/queued/rejected counters (admission
    /// fairness telemetry). Server-side; empty off the HTTP path.
    pub tenants: Vec<TenantStats>,
    /// Requests aborted mid-flight ([`Scheduler::cancel`]) — queued or
    /// live lanes whose client went away. A cancelled lane's
    /// delivered-work counters are rolled back (nobody received the
    /// stream), its pages are released, and no completion is produced.
    pub cancelled: usize,
    /// Requests whose deadline fired ([`Scheduler::expire`] — parked
    /// past the queue-admission deadline, or decoding past the
    /// wall-clock cap). Unlike cancellation the truncated stream *was*
    /// delivered, so delivered-work counters stand.
    pub deadline_expired: usize,
    /// Shard-worker panics survived by the supervisor (the worker's
    /// model+scheduler stack was rebuilt and the shard kept serving).
    /// Server-side counter, 0 off the HTTP path.
    pub worker_restarts: usize,
    /// Draft tokens proposed to the target for verification
    /// (speculative decoding; 0 off that path). Delivered-work
    /// counter: a requeued/cancelled lane's proposals are rolled back
    /// with the rest of its stream.
    pub spec_proposed: usize,
    /// Proposed tokens the target accepted *and emitted* — each one is
    /// a full-price target decode step the lane skipped. Delivered-work
    /// counter, rolled back like `spec_proposed`.
    pub spec_accepted: usize,
    /// Verify rounds executed (one per speculative decode-phase lane
    /// per step, including rounds the draft sat out with zero
    /// proposals). Like `batch_steps` this measures work actually
    /// executed and is never rolled back.
    pub spec_verify_steps: usize,
    /// Current acceptance-adaptive speculative proposal length — the
    /// value [`Scheduler::propose`] actually uses in place of the
    /// configured [`SpecConfig`] `k`: halved when fewer than half the
    /// proposed tokens land, nudged back up on fully-accepted rounds,
    /// clamped to `[1, SpecConfig.k]`. A gauge, not a counter —
    /// [`ServeStats::absorb`] takes the max so `/stats` totals report
    /// the most aggressive shard. 0 off the speculative path.
    pub spec_k_effective: usize,
}

impl ServeStats {
    /// Fold `other` into `self`: additive counters sum, peak counters
    /// take the max, tenant rows merge by name. This is how the shard
    /// supervisor accumulates stats across worker restarts — a rebuilt
    /// worker starts a fresh `ServeStats`, and `/stats` must never go
    /// backwards.
    pub fn absorb(&mut self, other: &ServeStats) {
        self.batch_steps += other.batch_steps;
        self.lane_steps += other.lane_steps;
        self.prefill_tokens += other.prefill_tokens;
        self.generated_tokens += other.generated_tokens;
        self.peak_occupancy = self.peak_occupancy.max(other.peak_occupancy);
        self.ttft_steps += other.ttft_steps;
        self.requeued += other.requeued;
        self.prefix_hits += other.prefix_hits;
        self.prefix_tokens_reused += other.prefix_tokens_reused;
        self.cow_copies += other.cow_copies;
        self.queue_depth_max = self.queue_depth_max.max(other.queue_depth_max);
        self.rejected_429 += other.rejected_429;
        self.rejected_413 += other.rejected_413;
        self.cancelled += other.cancelled;
        self.deadline_expired += other.deadline_expired;
        self.worker_restarts += other.worker_restarts;
        self.spec_proposed += other.spec_proposed;
        self.spec_accepted += other.spec_accepted;
        self.spec_verify_steps += other.spec_verify_steps;
        self.spec_k_effective =
            self.spec_k_effective.max(other.spec_k_effective);
        for t in &other.tenants {
            match self.tenants.iter_mut().find(|m| m.tenant == t.tenant) {
                Some(m) => {
                    m.served += t.served;
                    m.queued += t.queued;
                    m.rejected += t.rejected;
                }
                None => self.tenants.push(t.clone()),
            }
        }
    }

    /// Mean draft tokens accepted per executed verify round — the
    /// realized-speedup knob of the speculative roofline
    /// ([`crate::deploy::speculative_speedup_bits`]): each accepted
    /// token is a target step the lane did not pay for. In `[0, k]`;
    /// `0.0` when speculation never ran.
    pub fn accepted_per_step(&self) -> f64 {
        if self.spec_verify_steps == 0 {
            0.0
        } else {
            self.spec_accepted as f64 / self.spec_verify_steps as f64
        }
    }
}

struct Lane {
    req: GenRequest,
    state: Vec<f32>,
    /// Prompt tokens consumed so far (starts at `prefix_reused` when
    /// admission mapped a cached prefix).
    pos: usize,
    generated: Vec<u32>,
    rng: SplitMix64,
    steps: usize,
    /// Steps from admission to the first generated token (0 until it
    /// exists).
    ttft_steps: usize,
    /// Prompt tokens served from the prefix cache at admission (0 on a
    /// miss) — the slice of `pos` that was mapped, not fed, so requeue
    /// rollback can split the two.
    prefix_reused: usize,
    /// Draft-model lane state (speculative decoding). `None` off the
    /// speculative path; allocated at admission when a draft is
    /// installed, retired alongside `state` on every exit path.
    draft_state: Option<Vec<f32>>,
    /// Tokens of this lane's committed stream the draft cache holds —
    /// always a prefix of the target-committed context. The proposal
    /// round's pending catch-up feeds the gap (healthy-path lag is 0
    /// or 1; a refused draft claim just grows it for a round).
    draft_valid: usize,
    /// Draft tokens proposed for this lane (delivered work: rolled
    /// back with the lane on requeue/cancel).
    spec_proposed: usize,
    /// Proposed tokens the target accepted and emitted (delivered
    /// work, rolled back like `spec_proposed`).
    spec_accepted: usize,
    /// This verify round's draft proposals (cleared every round).
    proposals: Vec<u32>,
    /// Absolute next draft feed position during a proposal round;
    /// after the round, the draft cache's committed length.
    spec_fed: usize,
    /// The draft refused a page claim this round: the lane verifies
    /// whatever proposals it already has (possibly a plain one-token
    /// step) and the draft catches up on a later round.
    spec_refused: bool,
}

impl Lane {
    /// `state` is a zeroed hidden-state buffer — freshly allocated or
    /// recycled from a retired lane (the scheduler's admission path
    /// reuses buffers so steady-state traffic stops allocating one
    /// `Vec<f32>` per admitted request).
    fn new(req: GenRequest, state: Vec<f32>) -> Lane {
        let seed = match req.sampling {
            Sampling::TopK { seed, .. } => seed,
            Sampling::Greedy => req.id as u64,
        };
        Lane {
            state,
            pos: 0,
            generated: Vec::with_capacity(req.max_new_tokens),
            rng: SplitMix64::new(seed),
            steps: 0,
            ttft_steps: 0,
            prefix_reused: 0,
            draft_state: None,
            draft_valid: 0,
            spec_proposed: 0,
            spec_accepted: 0,
            proposals: Vec::new(),
            spec_fed: 0,
            spec_refused: false,
            req,
        }
    }

    /// The token this lane feeds at position `pos` of the next step's
    /// span (prompt positions during prefill; the last sampled token
    /// once the prompt is consumed).
    fn token_at(&self, pos: usize) -> u32 {
        if pos < self.req.prompt.len() {
            self.req.prompt[pos]
        } else {
            *self.generated.last().expect("generating lane has a last token")
        }
    }

    /// Tokens this lane feeds into the next batched step: up to `chunk`
    /// unconsumed prompt tokens (chunked prefill), or exactly 1 once
    /// sampling has started.
    fn span_len(&self, chunk: usize) -> usize {
        let remaining = self.req.prompt.len().saturating_sub(self.pos);
        remaining.clamp(1, chunk.max(1))
    }
}

/// Continuous-batching decode engine over any [`DecodeModel`]
/// (including trait objects).
///
/// The scheduler owns the serving execution substrate for its whole
/// lifetime: one persistent [`WorkerPool`] (kernel threads are
/// dispatched, never spawned, across every matmul of every step) and
/// one [`DecodeScratch`] (activation/logit/accumulator buffers reused
/// across steps), plus recycled lane-state buffers — steady-state
/// tensor/thread traffic is gone; the only per-step heap use left is
/// one small vector of lane-state borrows (it cannot outlive the step,
/// so it cannot be cached).
pub struct Scheduler<'m, M: DecodeModel + ?Sized> {
    model: &'m M,
    max_batch: usize,
    pool: WorkerPool,
    scratch: DecodeScratch,
    queue: VecDeque<GenRequest>,
    lanes: Vec<Option<Lane>>,
    /// Zeroable hidden-state buffers handed back by retired lanes,
    /// reused on admission.
    free_states: Vec<Vec<f32>>,
    /// Flattened span-token staging buffer reused across steps.
    token_buf: Vec<u32>,
    /// Per-live-lane span lengths staged alongside `token_buf`.
    span_buf: Vec<usize>,
    /// Max prompt tokens a lane feeds per step (>= 1; 1 = the classic
    /// one-token prefill).
    prefill_chunk: usize,
    /// True after a step saw KV backpressure: admission of fresh
    /// requests pauses until the surviving lanes run a clean step, so
    /// capacity drains instead of thrashing.
    defer_admission: bool,
    /// Consecutive steps in which no lane ran (every live lane was
    /// rejected) — the wedge detector behind the sizing panic.
    stalled_steps: usize,
    /// Deterministic fault script ([`crate::serve::faults`]); the
    /// default empty plan injects nothing.
    faults: FaultPlan,
    /// Speculative decoding: the draft model plus [`SpecConfig`]
    /// ([`Scheduler::set_speculative`]); `None` = plain decode.
    spec: Option<Spec<'m>>,
    /// Acceptance-adaptive proposal length ([`Scheduler::propose`]
    /// drafts this many tokens per lane, not the configured
    /// `SpecConfig.k`). Live-clamped to `[1, SpecConfig.k]` by the
    /// controller at the end of every verify step; 0 (unused) while
    /// `spec` is `None`.
    spec_k_eff: usize,
    /// Recycled draft-state buffers (the draft's hidden width may
    /// differ from the target's, so these never mix with
    /// `free_states`).
    free_draft_states: Vec<Vec<f32>>,
    stats: ServeStats,
}

impl<'m, M: DecodeModel + ?Sized> Scheduler<'m, M> {
    /// `max_batch` lanes; `threads` sizes the persistent kernel pool
    /// (0 = auto). Prefill is one-token ([`Scheduler::set_prefill_chunk`]
    /// / [`Scheduler::with_prefill_chunk`] turn on chunked prompt
    /// ingestion).
    pub fn new(model: &'m M, max_batch: usize, threads: usize) -> Self {
        let max_batch = max_batch.max(1);
        Scheduler {
            model,
            max_batch,
            pool: WorkerPool::new(threads),
            scratch: DecodeScratch::new(),
            queue: VecDeque::new(),
            lanes: (0..max_batch).map(|_| None).collect(),
            free_states: Vec::new(),
            token_buf: Vec::new(),
            span_buf: Vec::new(),
            prefill_chunk: 1,
            defer_admission: false,
            stalled_steps: 0,
            faults: FaultPlan::default(),
            spec: None,
            spec_k_eff: 0,
            free_draft_states: Vec::new(),
            stats: ServeStats::default(),
        }
    }

    /// [`Scheduler::new`] with chunked prefill enabled: a lane with
    /// unconsumed prompt feeds up to `prefill_chunk` tokens per batched
    /// step. Chunking changes step counts and TTFT, never streams —
    /// generated tokens are bitwise identical at every chunk size
    /// (`tests/prefill_chunking.rs`).
    pub fn with_prefill_chunk(model: &'m M, max_batch: usize,
                              threads: usize, prefill_chunk: usize) -> Self {
        let mut s = Scheduler::new(model, max_batch, threads);
        s.set_prefill_chunk(prefill_chunk);
        s
    }

    /// Set the prefill chunk (clamped to >= 1). Takes effect from the
    /// next step; safe to change mid-serve.
    pub fn set_prefill_chunk(&mut self, prefill_chunk: usize) {
        self.prefill_chunk = prefill_chunk.max(1);
    }

    /// Max prompt tokens a lane feeds per batched step.
    pub fn prefill_chunk(&self) -> usize {
        self.prefill_chunk
    }

    /// Enqueue a request. Empty prompts are normalized to `[0]` and
    /// `max_new_tokens` to at least 1 so every request terminates.
    pub fn submit(&mut self, mut req: GenRequest) {
        if req.prompt.is_empty() {
            req.prompt.push(0);
        }
        req.max_new_tokens = req.max_new_tokens.max(1);
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
            + self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Requests currently occupying a lane (admitted, not yet
    /// retired). `pending() - live_lanes()` is the internal queue
    /// depth.
    pub fn live_lanes(&self) -> usize {
        self.lanes.iter().filter(|l| l.is_some()).count()
    }

    /// Requests waiting in the scheduler's internal queue (submitted
    /// or requeued, not yet in a lane).
    pub fn queued(&self) -> usize {
        self.queue.len()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    /// Install a deterministic fault script ([`FaultPlan`]). Steps
    /// already taken are unaffected; the default empty plan injects
    /// nothing.
    pub fn set_fault_plan(&mut self, faults: FaultPlan) {
        self.faults = faults;
    }

    /// Turn on draft-verify speculative decoding: each decode round,
    /// `draft` proposes up to `cfg.k` greedy tokens per lane and the
    /// target verifies the whole proposal in one chunked span pass,
    /// accepting the longest prefix the lane's own sampling rule
    /// agrees with and rolling the rejected tail back out of both KV
    /// caches ([`DecodeModel::rollback_state`]).
    ///
    /// Losslessness: every emitted token — accepted draft token,
    /// correction, or bonus — is sampled from the *target's* logits at
    /// its own stream position with the lane's own RNG in stream
    /// order, so greedy and seeded top-k streams are bitwise identical
    /// to non-speculative decode (`tests/speculative.rs` proves this
    /// for all four target families); speculation only changes how
    /// many tokens one step emits. Prefix-cache reuse is disabled
    /// while a draft is installed (the draft has no mapping for reused
    /// pages; composing the two is a ROADMAP follow-on).
    ///
    /// Panics if `cfg.k == 0` or either model cannot roll back
    /// rejected tokens (only positional-state models can — serve with
    /// `--attn`; a decay carry cannot be rewound).
    pub fn set_speculative(&mut self, draft: &'m dyn DecodeModel,
                           cfg: SpecConfig) {
        assert!(cfg.k >= 1, "speculative k must be >= 1");
        assert!(self.model.supports_rollback(),
                "speculative target (family {}) cannot roll back \
                 rejected tokens — speculation needs the paged-KV \
                 attention model",
                self.model.family_label());
        assert!(draft.supports_rollback(),
                "speculative draft (family {}) cannot roll back \
                 rejected tokens — speculation needs the paged-KV \
                 attention model",
                draft.family_label());
        assert!(self.lanes.iter().all(|l| l.is_none()),
                "set_speculative must run before any lane is admitted \
                 (live lanes have no draft state to verify against)");
        self.spec_k_eff = cfg.k;
        self.stats.spec_k_effective = cfg.k;
        self.spec = Some(Spec { draft, cfg });
    }

    /// The installed speculative configuration, if any.
    pub fn speculative(&self) -> Option<&SpecConfig> {
        self.spec.as_ref().map(|s| &s.cfg)
    }

    /// Abort request `id` — queued or live — because its consumer went
    /// away (client hangup). A queued request is simply removed; a
    /// live lane releases its model-side resources (KV pages, via the
    /// same [`DecodeModel::retire_state`] hook lane retirement uses)
    /// and its delivered-work stats are rolled back exactly like an
    /// abandoned requeue attempt — nobody received the stream, so
    /// throughput numbers must not count it. No [`Completion`] is
    /// produced. Returns whether the request was found.
    ///
    /// Cancelling between steps is immediate: the lane's pages are
    /// free before the next [`Scheduler::step_observed`] call admits
    /// or runs anything.
    pub fn cancel(&mut self, id: usize) -> bool {
        if let Some(qi) = self.queue.iter().position(|r| r.id == id) {
            self.queue.remove(qi);
            self.stats.cancelled += 1;
            return true;
        }
        let draft = self.spec.as_ref().map(|s| s.draft);
        for slot in &mut self.lanes {
            if slot.as_ref().is_some_and(|l| l.req.id == id) {
                let mut lane = slot.take().unwrap();
                self.model.retire_state(&mut lane.state);
                retire_draft(draft, &mut lane, &mut self.free_draft_states);
                rollback_delivered(&mut self.stats, &lane);
                self.free_states.push(lane.state);
                self.stats.cancelled += 1;
                return true;
            }
        }
        false
    }

    /// Expire request `id` on a deadline: the stream ends *now*, with
    /// whatever tokens it has, marked [`FinishReason::DeadlineExpired`].
    /// Unlike [`Scheduler::cancel`] the consumer is still there and
    /// received the truncated stream, so delivered-work stats stand
    /// (a lane expired mid-prefill leaves its partial prefill counted
    /// — the kernel work was done and the deadline, not backpressure,
    /// abandoned it). A queued request expires to an empty-token
    /// completion. Returns `None` when `id` is not present.
    pub fn expire(&mut self, id: usize) -> Option<Completion> {
        if let Some(qi) = self.queue.iter().position(|r| r.id == id) {
            let req = self.queue.remove(qi).expect("position was in range");
            self.stats.deadline_expired += 1;
            return Some(Completion {
                id: req.id,
                prompt_len: req.prompt.len(),
                tokens: Vec::new(),
                lane_steps: 0,
                ttft_steps: 0,
                finish_reason: FinishReason::DeadlineExpired,
            });
        }
        let draft = self.spec.as_ref().map(|s| s.draft);
        for slot in &mut self.lanes {
            if slot.as_ref().is_some_and(|l| l.req.id == id) {
                let mut lane = slot.take().unwrap();
                self.model.retire_state(&mut lane.state);
                retire_draft(draft, &mut lane, &mut self.free_draft_states);
                self.free_states.push(lane.state);
                self.stats.deadline_expired += 1;
                return Some(Completion {
                    id: lane.req.id,
                    prompt_len: lane.req.prompt.len(),
                    tokens: lane.generated,
                    lane_steps: lane.steps,
                    ttft_steps: lane.ttft_steps,
                    finish_reason: FinishReason::DeadlineExpired,
                });
            }
        }
        None
    }

    /// Fill empty lanes from the queue, at most `cap` this call (the
    /// backpressure path admits one at a time to serialize restarts;
    /// the healthy path admits without limit).
    fn admit(&mut self, cap: usize) {
        let hidden = self.model.dims().hidden;
        let mut admitted = 0usize;
        for slot in &mut self.lanes {
            if admitted >= cap {
                break;
            }
            if slot.is_none() {
                let Some(req) = self.queue.pop_front() else { break };
                // Recycle a retired lane's state buffer when one is
                // available (zeroed here; `free_states` holds them
                // as-retired).
                let state = match self.free_states.pop() {
                    Some(mut s) => {
                        debug_assert_eq!(s.len(), hidden);
                        s.fill(0.0);
                        s
                    }
                    None => vec![0.0; hidden],
                };
                let mut lane = Lane::new(req, state);
                if let Some(spec) = &self.spec {
                    // Speculative lane: wire in a zeroed draft-state
                    // buffer (recycled like the target's). Prefix
                    // reuse is skipped below — mapped pages exist only
                    // in the target's cache, and a draft with no
                    // mirror of that context would mis-propose from
                    // position zero.
                    let dh = spec.draft.dims().hidden;
                    let ds = match self.free_draft_states.pop() {
                        Some(mut s) => {
                            debug_assert_eq!(s.len(), dh);
                            s.fill(0.0);
                            s
                        }
                        None => vec![0.0; dh],
                    };
                    lane.draft_state = Some(ds);
                    *slot = Some(lane);
                    admitted += 1;
                    continue;
                }
                // Prefix cache: a hit maps the cached pages into the
                // fresh lane (consuming no free pages, so it cannot be
                // refused) and prefill starts at the first unshared
                // token. The reused slice is accounted separately from
                // prefill_tokens — those keep counting only tokens fed
                // through kernels.
                let reused = self.model.prefix_reuse(&mut lane.state,
                                                     &lane.req.prompt);
                if reused > 0 {
                    debug_assert!(reused < lane.req.prompt.len(),
                                  "prefix_reuse must leave >= 1 token");
                    lane.pos = reused;
                    lane.prefix_reused = reused;
                    self.stats.prefix_hits += 1;
                    self.stats.prefix_tokens_reused += reused;
                }
                *slot = Some(lane);
                admitted += 1;
            }
        }
    }

    /// One batched step across all live lanes. Returns any requests
    /// that finished on this step.
    ///
    /// Compatibility wrapper over [`Scheduler::step_into`] — it
    /// allocates the completion vector per call; callers that step in
    /// a loop should pass one reusable vector to `step_into` (as
    /// [`Scheduler::run`] does).
    pub fn step(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        self.step_into(&mut done);
        done
    }

    /// One batched step across all live lanes; requests that finished
    /// on this step are appended to `done`. Steady-state allocation is
    /// reduced to the one unavoidable piece: tokens and spans stage in
    /// reused buffers, the kernel invocation runs through the
    /// scheduler's pool + scratch, nothing is allocated when no lane
    /// retires — only the batch-sized vector of `&mut` lane-state
    /// borrows is built per step (a borrow cannot be stored across
    /// steps), plus a tiny requeue vector on the rare backpressure
    /// step.
    pub fn step_into(&mut self, done: &mut Vec<Completion>) {
        self.step_observed(done, &mut |_| {});
    }

    /// [`Scheduler::step_into`] with an incremental per-token observer:
    /// `obs` fires a [`StreamEvent::Token`] the moment each lane
    /// samples a token — before the request completes — and a
    /// [`StreamEvent::Requeued`] when backpressure bounces a lane that
    /// had already emitted tokens (its restart re-emits the identical
    /// stream from index 0; consumers dedupe by high-water mark, see
    /// [`StreamEvent`]). The no-op observer is exactly `step_into`:
    /// same admissions, same kernel work, same stats, bitwise-same
    /// streams — the observer only *watches* sampling, it cannot
    /// perturb it.
    pub fn step_observed(&mut self, done: &mut Vec<Completion>,
                         obs: &mut dyn FnMut(StreamEvent)) {
        // Backpressure defers admission: after a step that bounced a
        // lane, no fresh request is admitted until the survivors run a
        // clean step, so held KV capacity is released instead of
        // fought over. If pressure drained *every* lane, exactly one
        // request is readmitted — the lone lane claims from a fully
        // free pool and runs to completion, which breaks the symmetric
        // wedge where identically-restarted lanes would hit the same
        // page boundary in lockstep forever.
        let live_before = self.lanes.iter().filter(|l| l.is_some()).count();
        if !self.defer_admission {
            self.admit(usize::MAX);
        } else if live_before == 0 {
            self.admit(1);
        }
        // Speculative draft phase: decode-phase lanes run the cheap
        // draft model for up to k greedy proposals each (batched
        // one-token draft steps across lanes). Off the speculative
        // path this is a no-op and every `proposals` list stays empty,
        // so the staging below degenerates to the classic spans.
        if self.spec.is_some() {
            self.propose();
        }
        self.token_buf.clear();
        self.span_buf.clear();
        for s in self.lanes.iter() {
            if let Some(lane) = s {
                let mut span = lane.span_len(self.prefill_chunk);
                for j in 0..span {
                    self.token_buf.push(lane.token_at(lane.pos + j));
                }
                if lane.pos >= lane.req.prompt.len() {
                    // Speculative verify span: the pending input plus
                    // this round's draft proposals, checked by the
                    // target in one chunked pass.
                    self.token_buf.extend_from_slice(&lane.proposals);
                    span += lane.proposals.len();
                }
                self.span_buf.push(span);
            }
        }
        if self.span_buf.is_empty() {
            return;
        }
        // Deterministic fault injection: on a scripted step
        // ([`FaultPlan::out_of_pages_steps`]) every live lane is
        // treated as KV-refused and the model is not invoked at all.
        // Skipping the kernels makes the forcing family-blind (decay
        // models have no cache to overflow, yet still exercise the
        // full requeue path) and cannot perturb later steps: a
        // refused lane restarts from scratch anyway.
        let forced = self.faults
            .forces_out_of_pages(self.stats.batch_steps + 1);
        // Verification needs the target's logits at *every* span
        // position (the draft calls in `propose`/the mirror pass
        // switch this back off — only the verify pass pays the
        // full-span head).
        self.scratch.want_span_logits = self.spec.is_some();
        if forced {
            self.scratch.rejected.clear();
            self.scratch.rejected.extend(0..self.span_buf.len());
            self.scratch.cow_copies = 0;
            self.scratch.logits.reset2(0, self.model.dims().vocab);
        } else {
            let mut state_refs: Vec<&mut [f32]> = self.lanes.iter_mut()
                .filter_map(|s| s.as_mut().map(|l| l.state.as_mut_slice()))
                .collect();
            self.model.step_spans_into(&mut state_refs, &self.token_buf,
                                       &self.span_buf, &self.pool,
                                       &mut self.scratch);
        }

        let live = self.span_buf.len();
        let ran = live - self.scratch.rejected.len();
        // Under backpressure, evict the model's prefix-cache pins
        // *before* any lane is requeued: pinned pages are a cache, and
        // an all-rejected drain only frees the whole pool if nothing
        // stays pinned behind it. Without this, the stall/sizing
        // guards below would fire spuriously on a recoverable state
        // (pages held by evictable pins, not by any lane). An eviction
        // is forward progress — freed pages are what the requeued
        // lanes restart into. A forced (injected) refusal evicts
        // nothing: the pool is not actually under pressure.
        let evicted = !forced && ran < live
            && self.model.release_cached_pages();
        // A lane refused while it is the only live lane and nothing is
        // pinned cannot be helped by requeueing: its context alone
        // exceeds the whole pool. This used to panic the process; it
        // now fails *that request* with [`FinishReason::KvOverflow`]
        // in the retire loop below (direct `Scheduler` users keep
        // their process; the HTTP path already 413s these upstream).
        let overflow = ran == 0 && !evicted && !forced && live == 1;
        if ran > 0 || evicted || forced || overflow {
            self.stalled_steps = 0;
        } else {
            self.stalled_steps += 1;
            // After an all-rejected step every lane releases its pages,
            // so the next admission claims from a free pool — repeated
            // all-rejected steps mean the requests can never fit.
            if self.stalled_steps > self.max_batch + 1 {
                panic!("serve: {} consecutive steps without progress — \
                        the kv cache cannot fit any admitted request's \
                        next claim; size the cache for at least prompt + \
                        max_new_tokens tokens per lane",
                       self.stalled_steps);
            }
        }
        self.stats.batch_steps += 1;
        self.stats.lane_steps += ran;
        self.stats.cow_copies += self.scratch.cow_copies;
        self.stats.peak_occupancy = self.stats.peak_occupancy.max(ran);

        let logits = &self.scratch.logits;
        let span_logits = &self.scratch.span_logits;
        let draft = self.spec.as_ref().map(|s| s.draft);
        let mut requeue: Vec<GenRequest> = Vec::new();
        // Prefill chunks the target accepted this step, to mirror into
        // the draft cache after the loop (slot indices, ascending;
        // always empty off the speculative path).
        let mut mirror: Vec<usize> = Vec::new();
        let mut ai = 0usize; // logits row: ordinal among lanes that ran
        let mut flat = 0usize; // span_logits row: flattened span cursor
        // Step-local speculative accounting for the adaptive-k
        // controller after the loop: verify rounds executed this step,
        // their proposed/accepted token sums, and whether every round
        // drafted the full effective k (budget-clamped rounds must not
        // count as evidence either way).
        let mut verify_rounds = 0usize;
        let mut verify_proposed = 0usize;
        let mut verify_accepted = 0usize;
        let mut verify_full = true;
        let mut si = 0usize; // live-lane ordinal (indexes span_buf)
        // `rejected` is sorted ascending (the model contract) and `si`
        // walks live lanes in order, so one cursor replaces a per-lane
        // `contains` scan — O(live), not O(live x rejected).
        debug_assert!(self.scratch.rejected.windows(2).all(|w| w[0] < w[1]),
                      "model rejected list must be sorted ascending");
        let mut rj = 0usize; // cursor into scratch.rejected
        for (li, slot) in self.lanes.iter_mut().enumerate() {
            let Some(lane) = slot.as_mut() else { continue };
            let span = self.span_buf[si];
            let rejected = self.scratch.rejected.get(rj) == Some(&si);
            if rejected {
                rj += 1;
            }
            si += 1;
            if rejected {
                // KV backpressure: release this lane's model-side
                // resources (both the target's and — speculative lanes
                // — the draft's pages come back here). Normally the
                // request goes back to the head of the queue (decoding
                // is deterministic, so the restart reproduces the same
                // stream from scratch — requeues cost latency, never
                // correctness); the `overflow` case instead
                // error-completes the request, because requeueing a
                // context that exceeds the whole pool would livelock.
                let mut lane = slot.take().unwrap();
                self.model.retire_state(&mut lane.state);
                retire_draft(draft, &mut lane, &mut self.free_draft_states);
                if overflow {
                    rollback_delivered(&mut self.stats, &lane);
                    self.free_states.push(lane.state);
                    done.push(Completion {
                        id: lane.req.id,
                        prompt_len: lane.req.prompt.len(),
                        tokens: lane.generated,
                        lane_steps: lane.steps,
                        ttft_steps: lane.ttft_steps,
                        finish_reason: FinishReason::KvOverflow,
                    });
                    continue;
                }
                self.free_states.push(lane.state);
                self.stats.requeued += 1;
                obs(StreamEvent::Requeued { id: lane.req.id,
                                            discarded: lane.generated.len() });
                rollback_delivered(&mut self.stats, &lane);
                requeue.push(lane.req);
                continue;
            }
            lane.steps += 1;
            let was_prefill = lane.pos < lane.req.prompt.len();
            if was_prefill {
                lane.pos += span;
                self.stats.prefill_tokens += span;
            }
            if was_prefill || draft.is_none() {
                // Classic path: prefill advance and/or one-token
                // decode. Once the final prompt token has been fed,
                // every step's logits row produces one sampled
                // continuation token.
                if lane.pos == lane.req.prompt.len() {
                    let tok = sample(logits.row(ai), &lane.req.sampling,
                                     &mut lane.rng);
                    lane.generated.push(tok);
                    self.stats.generated_tokens += 1;
                    obs(StreamEvent::Token { id: lane.req.id, token: tok,
                                             index: lane.generated.len() - 1 });
                    if lane.generated.len() == 1 {
                        lane.ttft_steps = lane.steps;
                        self.stats.ttft_steps += lane.steps;
                        // First sampled token proves the whole prompt
                        // is committed in the model's cache: offer it
                        // to the prefix cache so later identical/
                        // shared prompts map these pages instead of
                        // re-running prefill. Speculative mode leaves
                        // the cache alone — reuse is disabled there
                        // (the draft holds no mirror of mapped pages).
                        if draft.is_none() {
                            self.model.prefix_register(&mut lane.state,
                                                       &lane.req.prompt);
                        }
                    }
                    if lane.generated.len() >= lane.req.max_new_tokens {
                        let mut lane = slot.take().unwrap();
                        // Lane retire: release model-side per-lane
                        // resources (an AttnLm frees its KV-cache
                        // pages here) before the state buffer is
                        // recycled.
                        self.model.retire_state(&mut lane.state);
                        retire_draft(draft, &mut lane,
                                     &mut self.free_draft_states);
                        self.free_states.push(lane.state);
                        done.push(Completion {
                            id: lane.req.id,
                            prompt_len: lane.req.prompt.len(),
                            tokens: lane.generated,
                            lane_steps: lane.steps,
                            ttft_steps: lane.ttft_steps,
                            finish_reason: FinishReason::Length,
                        });
                    }
                }
            } else {
                // Speculative verify walk: row r of this lane's
                // span-logits stretch is the target's distribution at
                // its own stream position, conditioned on the pending
                // input plus the draft's first r proposals. Sample
                // each row under the lane's own rule, in stream order,
                // with the lane's own RNG: a sample equal to the
                // draft's r-th proposal IS that token (accept — the
                // next row was conditioned on it), a mismatch is the
                // correction token and ends the round (later rows
                // condition on rejected context), and the final row —
                // reachable only when every proposal matched — yields
                // the bonus token. Every emitted token is therefore
                // exactly what non-speculative decode would have
                // sampled, bitwise; speculation only changes how many
                // tokens one step emits.
                let j = lane.proposals.len();
                debug_assert_eq!(span, 1 + j);
                let mut accepted = 0usize;
                for r in 0..span {
                    let tok = sample(span_logits.row(flat + r),
                                     &lane.req.sampling, &mut lane.rng);
                    lane.generated.push(tok);
                    self.stats.generated_tokens += 1;
                    obs(StreamEvent::Token {
                        id: lane.req.id, token: tok,
                        index: lane.generated.len() - 1,
                    });
                    let matched = r < j && tok == lane.proposals[r];
                    if matched {
                        accepted += 1;
                    }
                    if !matched
                        || lane.generated.len() >= lane.req.max_new_tokens
                    {
                        break;
                    }
                }
                lane.spec_proposed += j;
                lane.spec_accepted += accepted;
                self.stats.spec_proposed += j;
                self.stats.spec_accepted += accepted;
                self.stats.spec_verify_steps += 1;
                verify_rounds += 1;
                verify_proposed += j;
                verify_accepted += accepted;
                verify_full &= j == self.spec_k_eff;
                if lane.generated.len() >= lane.req.max_new_tokens {
                    // Budget reached mid-round: retire outright —
                    // freeing the sequences releases committed and
                    // rejected pages alike, no precise truncate
                    // needed.
                    let mut lane = slot.take().unwrap();
                    self.model.retire_state(&mut lane.state);
                    retire_draft(draft, &mut lane,
                                 &mut self.free_draft_states);
                    self.free_states.push(lane.state);
                    done.push(Completion {
                        id: lane.req.id,
                        prompt_len: lane.req.prompt.len(),
                        tokens: lane.generated,
                        lane_steps: lane.steps,
                        ttft_steps: lane.ttft_steps,
                        finish_reason: FinishReason::Length,
                    });
                } else {
                    // Roll the rejected tail out of both caches. The
                    // target claimed the whole verify span up front;
                    // its committed context is everything before the
                    // (still unfed) last generated token. The draft
                    // keeps its longest held prefix that is still
                    // committed — lag 0 after a rejection, lag 1 after
                    // a full accept (the final proposal was sampled
                    // but never fed back) or a refused round, absorbed
                    // by the next round's pending catch-up.
                    let ctx = lane.pos + lane.generated.len() - 1;
                    self.model.rollback_state(&mut lane.state, ctx);
                    let new_valid = lane.spec_fed.min(ctx);
                    let ds = lane.draft_state.as_mut()
                        .expect("speculative lane has a draft state");
                    draft.expect("verify walk implies a draft")
                        .rollback_state(ds, new_valid);
                    lane.draft_valid = new_valid;
                }
            }
            // Surviving speculative prefill lanes mirror this step's
            // accepted chunk into the draft cache after the loop (one
            // batched pass), so a lane enters decode with its prompt
            // already drafted.
            if draft.is_some() && was_prefill {
                if let Some(l) = slot.as_ref() {
                    if l.draft_valid < l.pos {
                        mirror.push(li);
                    }
                }
            }
            ai += 1;
            flat += span;
        }
        // Acceptance-adaptive speculative k: a draft that keeps getting
        // rejected wastes a long verify span (and its transient KV
        // claim) every round, so when fewer than half the proposed
        // tokens landed this step the proposal length halves (floor 1);
        // a step whose every round drafted the full effective k and
        // landed every token nudges it back up, clamped to the
        // configured `SpecConfig.k`. Pure scheduling: losslessness
        // means streams are bitwise identical at every k, so the
        // controller only moves the work/latency trade-off. Budget-
        // clamped or refused rounds (`verify_full == false` with full
        // acceptance) leave k where it is — they say nothing about the
        // draft's quality.
        if verify_rounds > 0 {
            if let Some(spec) = self.spec.as_ref() {
                if verify_full && verify_accepted == verify_proposed {
                    self.spec_k_eff =
                        (self.spec_k_eff + 1).min(spec.cfg.k);
                } else if verify_accepted * 2 < verify_proposed {
                    self.spec_k_eff = (self.spec_k_eff / 2).max(1);
                }
                self.stats.spec_k_effective = self.spec_k_eff;
            }
        }
        self.defer_admission = !requeue.is_empty();
        // Deferred lanes go back to the *head* of the queue in their
        // original relative order — they were already in flight.
        for req in requeue.into_iter().rev() {
            self.queue.push_front(req);
        }
        if !mirror.is_empty() {
            self.mirror_prefill(&mirror);
        }
    }

    /// Speculative draft phase: run the draft model over every
    /// decode-phase lane until each has `k` greedy proposals — clamped
    /// to the lane's remaining budget minus one, past which a proposal
    /// could never be emitted — or its draft claim was refused.
    /// Batched: each loop iteration is one
    /// one-token draft step across all still-proposing lanes. A lane's
    /// feeds first catch the draft cache up to the lane's committed
    /// context (`pending`: committed tokens past `draft_valid`, then
    /// the pending input), then each sampled proposal is fed back to
    /// condition the next — `lag + k` feeds on the healthy path, where
    /// lag is 0 or 1.
    fn propose(&mut self) {
        let Some(spec) = self.spec.as_ref() else { return };
        let draft = spec.draft;
        // The *effective* k, not the configured one: the adaptive
        // controller in `step_observed` moves this between 1 and
        // `SpecConfig.k` based on realized acceptance.
        let k = self.spec_k_eff;
        debug_assert!(k >= 1, "adaptive k must stay >= 1 while drafting");
        let mut active: Vec<usize> = Vec::new();
        for (i, s) in self.lanes.iter_mut().enumerate() {
            if let Some(lane) = s {
                lane.proposals.clear();
                lane.spec_refused = false;
                lane.spec_fed = lane.draft_valid;
                if lane.pos >= lane.req.prompt.len()
                    && !lane.generated.is_empty()
                {
                    active.push(i);
                }
            }
        }
        // Draft calls never need per-position logits (one greedy
        // sample per lane per step) — only the verify pass pays the
        // full-span head.
        self.scratch.want_span_logits = false;
        // The draft's greedy argmax never draws from an RNG; a
        // throwaway generator keeps that explicit (lane RNGs must
        // advance only on emitted tokens, or bitwise losslessness
        // breaks).
        let mut no_rng = SplitMix64::new(0);
        let mut tokens: Vec<u32> = Vec::new();
        let mut spans: Vec<usize> = Vec::new();
        loop {
            active.retain(|&i| {
                let l = self.lanes[i].as_ref().expect("active lane is live");
                // Clamp by the lane's remaining budget: with r tokens
                // left, the verify walk emits at most r, so proposals
                // past r - 1 could never be accepted — and clamping
                // keeps the verify span's transient KV claim inside
                // the plain-decode bound (prompt + max_new - 1 tokens
                // per lane; no speculative page headroom needed).
                let k_lane = k.min(l.req.max_new_tokens
                                   - l.generated.len() - 1);
                !l.spec_refused && l.proposals.len() < k_lane
            });
            if active.is_empty() {
                break;
            }
            tokens.clear();
            spans.clear();
            for &i in &active {
                let l = self.lanes[i].as_ref().expect("active lane is live");
                let ctx = l.pos + l.generated.len() - 1;
                let p = l.spec_fed;
                // Feed position p: a committed token during catch-up
                // (prompt or delivered continuation — the pending
                // input at p == ctx is just `generated.last()`), a
                // prior proposal past it.
                let tok = if p <= ctx {
                    if p < l.pos {
                        l.req.prompt[p]
                    } else {
                        l.generated[p - l.pos]
                    }
                } else {
                    l.proposals[p - ctx - 1]
                };
                tokens.push(tok);
                spans.push(1);
            }
            // &mut draft-state borrows of the active lanes (`active`
            // is ascending, so one pass over the slots collects them).
            let mut it = active.iter().copied().peekable();
            let mut refs: Vec<&mut [f32]> = Vec::with_capacity(active.len());
            for (i, s) in self.lanes.iter_mut().enumerate() {
                if it.peek() == Some(&i) {
                    it.next();
                    let lane = s.as_mut().expect("active lane is live");
                    refs.push(lane.draft_state.as_mut()
                        .expect("speculative lane has a draft state")
                        .as_mut_slice());
                }
            }
            draft.step_spans_into(&mut refs, &tokens, &spans, &self.pool,
                                  &mut self.scratch);
            drop(refs);
            // Refused ordinals end those lanes' rounds (they verify
            // what they have); accepted rows advance the feed cursor
            // and — once at or past the pending input — sample one
            // greedy proposal each.
            let mut rj = 0usize;
            let mut row = 0usize;
            for (ord, &i) in active.iter().enumerate() {
                let lane = self.lanes[i].as_mut().expect("active lane");
                if self.scratch.rejected.get(rj) == Some(&ord) {
                    rj += 1;
                    lane.spec_refused = true;
                    continue;
                }
                let ctx = lane.pos + lane.generated.len() - 1;
                let fed_pos = lane.spec_fed;
                lane.spec_fed += 1;
                if fed_pos >= ctx {
                    let tok = sample(self.scratch.logits.row(row),
                                     &Sampling::Greedy, &mut no_rng);
                    lane.proposals.push(tok);
                }
                row += 1;
            }
        }
    }

    /// Mirror this step's accepted prefill chunks into the draft cache
    /// in one batched pass (logits discarded). Feeds each lane from
    /// `draft_valid` — not from the chunk start — so a previously
    /// refused mirror is caught up instead of leaving a hole. A mirror
    /// refused here just leaves `draft_valid` behind; the proposal
    /// round's pending catch-up absorbs the gap.
    fn mirror_prefill(&mut self, mirror: &[usize]) {
        let Some(spec) = self.spec.as_ref() else { return };
        let draft = spec.draft;
        self.scratch.want_span_logits = false;
        self.token_buf.clear();
        self.span_buf.clear();
        for &li in mirror {
            let l = self.lanes[li].as_ref().expect("mirrored lane is live");
            let to = l.pos.min(l.req.prompt.len());
            self.token_buf.extend_from_slice(&l.req.prompt[l.draft_valid..to]);
            self.span_buf.push(to - l.draft_valid);
        }
        let mut it = mirror.iter().copied().peekable();
        let mut refs: Vec<&mut [f32]> = Vec::with_capacity(mirror.len());
        for (i, s) in self.lanes.iter_mut().enumerate() {
            if it.peek() == Some(&i) {
                it.next();
                let lane = s.as_mut().expect("mirrored lane is live");
                refs.push(lane.draft_state.as_mut()
                    .expect("speculative lane has a draft state")
                    .as_mut_slice());
            }
        }
        draft.step_spans_into(&mut refs, &self.token_buf, &self.span_buf,
                              &self.pool, &mut self.scratch);
        drop(refs);
        let mut rj = 0usize;
        for (ord, &li) in mirror.iter().enumerate() {
            if self.scratch.rejected.get(rj) == Some(&ord) {
                rj += 1;
                continue;
            }
            let l = self.lanes[li].as_mut().expect("mirrored lane is live");
            l.draft_valid = l.pos.min(l.req.prompt.len());
        }
    }

    /// Drain the queue: step until every submitted request completes.
    /// Completions are returned sorted by request id.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            self.step_into(&mut out);
        }
        out.sort_by_key(|c| c.id);
        out
    }
}

impl<M: DecodeModel + ?Sized> Drop for Scheduler<'_, M> {
    /// Abandoned mid-flight lanes still release their model-side
    /// resources (KV-cache pages, for the target *and* any speculative
    /// draft): a scheduler dropped before draining must not leak pages
    /// out of either model's pool.
    fn drop(&mut self) {
        let model = self.model;
        let draft = self.spec.as_ref().map(|s| s.draft);
        for slot in &mut self.lanes {
            if let Some(lane) = slot.as_mut() {
                model.retire_state(&mut lane.state);
                if let (Some(d), Some(ds)) = (draft, lane.draft_state.as_mut())
                {
                    d.retire_state(ds);
                }
            }
        }
    }
}

/// Release a lane's draft-model resources (speculative decoding): the
/// draft's KV sequence is freed through the same
/// [`DecodeModel::retire_state`] hook the target uses, and the state
/// buffer goes back to the recycle list. A no-op off the speculative
/// path (no draft state was ever wired in).
fn retire_draft(draft: Option<&dyn DecodeModel>, lane: &mut Lane,
                free: &mut Vec<Vec<f32>>) {
    if let Some(mut ds) = lane.draft_state.take() {
        if let Some(d) = draft {
            d.retire_state(&mut ds);
        }
        free.push(ds);
    }
}

/// Roll an abandoned lane's delivered-work counters back out of
/// `stats`: the work was discarded (requeue restart will re-earn it;
/// a cancel/overflow never delivers it), and token/prefill/TTFT
/// totals must never count work nobody received (throughput reporting
/// divides these by wall clock). `batch_steps`/`lane_steps`/
/// `cow_copies` stay — they measure kernel work actually executed.
/// Checked subtraction: accounting drift here would otherwise wrap
/// silently and poison every later benchmark number.
fn rollback_delivered(stats: &mut ServeStats, lane: &Lane) {
    stats.generated_tokens = stats.generated_tokens
        .checked_sub(lane.generated.len())
        .expect("rollback underflowed generated_tokens");
    let fed = lane.pos.checked_sub(lane.prefix_reused)
        .expect("lane.pos fell below its reused prefix");
    stats.prefill_tokens = stats.prefill_tokens
        .checked_sub(fed)
        .expect("rollback underflowed prefill_tokens");
    stats.ttft_steps = stats.ttft_steps
        .checked_sub(lane.ttft_steps)
        .expect("rollback underflowed ttft_steps");
    if lane.prefix_reused > 0 {
        stats.prefix_tokens_reused = stats.prefix_tokens_reused
            .checked_sub(lane.prefix_reused)
            .expect("rollback underflowed prefix_tokens_reused");
        stats.prefix_hits = stats.prefix_hits
            .checked_sub(1)
            .expect("rollback underflowed prefix_hits");
    }
    // Speculative accounting is delivered-work-only too: a bounced
    // lane's proposals/accepts are re-earned by its restart
    // (`spec_verify_steps`, like `batch_steps`, measures executed
    // work and stays).
    stats.spec_proposed = stats.spec_proposed
        .checked_sub(lane.spec_proposed)
        .expect("rollback underflowed spec_proposed");
    stats.spec_accepted = stats.spec_accepted
        .checked_sub(lane.spec_accepted)
        .expect("rollback underflowed spec_accepted");
}

fn sample(row: &[f32], sampling: &Sampling, rng: &mut SplitMix64) -> u32 {
    match *sampling {
        Sampling::Greedy => {
            // Strict-greater scan: ties keep the lowest token id, which
            // is batch-independent (no float-order ambiguity). A NaN
            // incumbent is evicted by the first finite logit — without
            // that, a NaN at token 0 would win every comparison by
            // making them all false. All-NaN rows degrade to token 0,
            // matching the top-k policy below.
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                let b = row[best];
                if (b.is_nan() && !v.is_nan()) || v > b {
                    best = i;
                }
            }
            best as u32
        }
        Sampling::TopK { k, temperature, .. } => {
            let k = k.clamp(1, row.len());
            // Total order (finite logits desc, then NaNs, then token
            // id) makes the top-k *set* unique even under ties, so an
            // unstable partition selects deterministically; only the k
            // survivors are sorted, not the whole vocab.
            //
            // NaN needs explicit handling: `partial_cmp` returns None
            // for any NaN comparison, and mapping that to `Equal` (the
            // old code) silently produces a *non-transitive* relation —
            // selection would then depend on element order inside
            // `select_nth_unstable_by`, breaking the batch-invariance
            // determinism contract the moment any logit goes NaN. NaNs
            // instead sort deterministically *behind* every finite
            // logit (a NaN is never preferred over a real candidate)
            // and get zero sampling weight below.
            let desc = |a: &usize, b: &usize| {
                row[*a].is_nan().cmp(&row[*b].is_nan())
                    .then_with(|| row[*b].partial_cmp(&row[*a])
                        .unwrap_or(std::cmp::Ordering::Equal))
                    .then_with(|| a.cmp(b))
            };
            let mut idx: Vec<usize> = (0..row.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, desc);
                idx.truncate(k);
            }
            idx.sort_by(desc);
            let t = temperature.max(1e-6);
            let mx = row[idx[0]];
            // NaN survivors (possible only when fewer than k finite
            // logits exist) weigh 0 and are never drawn; an all-NaN row
            // degrades to the lowest token id — deterministic, and the
            // rng still advances exactly one draw either way.
            let ws: Vec<f64> = idx.iter()
                .map(|&j| {
                    let w = (((row[j] - mx) / t) as f64).exp();
                    if w.is_nan() { 0.0 } else { w }
                })
                .collect();
            idx[rng.weighted(&ws)] as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::{LmDims, TernaryLm};

    fn small_model() -> TernaryLm {
        TernaryLm::synthetic_pair(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 1, 9).0
    }

    #[test]
    fn completes_all_requests_with_more_requests_than_lanes() {
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 4, 1);
        // Heterogeneous budgets so lanes retire at different steps.
        let budget = |id: usize| 2 + id % 5;
        for id in 0..10 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 5],
                                            budget(id)));
        }
        let done = sched.run();
        assert_eq!(done.len(), 10);
        for (i, c) in done.iter().enumerate() {
            assert_eq!(c.id, i);
            assert_eq!(c.tokens.len(), budget(i));
            // Sampling starts on the final prompt step, so a lane is
            // occupied prompt_len + max_new - 1 steps.
            assert_eq!(c.lane_steps, 2 + budget(i) - 1);
        }
        let st = sched.stats();
        assert_eq!(st.generated_tokens, 40);
        assert_eq!(st.prefill_tokens, 20);
        assert_eq!(st.peak_occupancy, 4);
        assert_eq!(st.lane_steps, 50);
        // Continuous batching: retired lanes refill mid-flight, packing
        // 50 lane-steps into 16 batched steps; a drain-then-refill
        // scheduler (groups of 4, bounded by each group's longest
        // request) would need 20.
        assert_eq!(st.batch_steps, 16);
    }

    #[test]
    fn empty_prompt_and_zero_budget_are_normalized() {
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 2, 1);
        sched.submit(GenRequest::greedy(0, vec![], 0));
        let done = sched.run();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].prompt_len, 1);
        assert_eq!(done[0].tokens.len(), 1);
    }

    #[test]
    fn top_k_is_reproducible_and_respects_k() {
        let lm = small_model();
        let run = || {
            let mut sched = Scheduler::new(&lm, 3, 1);
            for id in 0..5 {
                sched.submit(GenRequest::top_k(id, vec![2, 3], 8, 4, 0.8,
                                               100 + id as u64));
            }
            sched.run()
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "top-k not reproducible");
        }
        // k=1 degenerates to greedy.
        let mut g = Scheduler::new(&lm, 1, 1);
        g.submit(GenRequest::greedy(0, vec![7], 5));
        let mut t = Scheduler::new(&lm, 1, 1);
        t.submit(GenRequest::top_k(0, vec![7], 5, 1, 1.0, 42));
        assert_eq!(g.run()[0].tokens, t.run()[0].tokens);
    }

    #[test]
    fn recycled_state_buffers_do_not_leak_context() {
        // A second wave served by a scheduler whose lanes all recycle
        // retired-state buffers must decode exactly like a fresh
        // scheduler: recycling is invisible (buffers are re-zeroed).
        let lm = small_model();
        let reqs = |base: usize| -> Vec<GenRequest> {
            (0..6).map(|i| GenRequest::greedy(
                base + i, vec![(3 * i) as u32, 11], 4)).collect()
        };
        let mut warm = Scheduler::new(&lm, 3, 2);
        for r in reqs(0) {
            warm.submit(r);
        }
        let _ = warm.run(); // every lane has now retired at least once
        for r in reqs(100) {
            warm.submit(r);
        }
        let warm_tokens: Vec<Vec<u32>> =
            warm.run().into_iter().map(|c| c.tokens).collect();

        let mut fresh = Scheduler::new(&lm, 3, 2);
        for r in reqs(100) {
            fresh.submit(r);
        }
        let fresh_tokens: Vec<Vec<u32>> =
            fresh.run().into_iter().map(|c| c.tokens).collect();
        assert_eq!(warm_tokens, fresh_tokens);
    }

    #[test]
    fn step_into_appends_without_clearing() {
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 2, 1);
        for id in 0..4 {
            sched.submit(GenRequest::greedy(id, vec![1], 2));
        }
        let mut done = Vec::new();
        while sched.pending() > 0 {
            sched.step_into(&mut done);
        }
        assert_eq!(done.len(), 4, "completions must accumulate in place");
    }

    #[test]
    fn attention_lanes_release_pages_on_retire_and_drop() {
        // The lane-retire -> page-recycle path, end to end through the
        // unmodified scheduler: a drained run leaves the model's page
        // pool empty, and a scheduler dropped mid-flight releases the
        // pages its live lanes held.
        use crate::serve::model::LatentAttnLm;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 13);
        let lm = latent.build_float(3, 8);
        let mut sched = Scheduler::new(&lm, 3, 1);
        for id in 0..6 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 5], 3));
        }
        let done = sched.run();
        assert_eq!(done.len(), 6);
        assert_eq!(lm.kv_pages_in_use(), 0,
                   "drained scheduler must leave no pages in use");
        let mut sched = Scheduler::new(&lm, 3, 1);
        for id in 0..3 {
            sched.submit(GenRequest::greedy(id, vec![1, 2, 3], 5));
        }
        sched.step();
        assert!(lm.kv_pages_in_use() > 0, "live lanes must hold pages");
        drop(sched);
        assert_eq!(lm.kv_pages_in_use(), 0,
                   "dropped scheduler leaked kv pages");
    }

    #[test]
    fn stats_start_empty() {
        let lm = small_model();
        let sched = Scheduler::new(&lm, 2, 1);
        assert_eq!(sched.pending(), 0);
        assert_eq!(sched.stats().batch_steps, 0);
        assert_eq!(sched.stats().ttft_steps, 0);
        assert_eq!(sched.stats().requeued, 0);
        assert_eq!(sched.stats().prefix_hits, 0);
        assert_eq!(sched.stats().prefix_tokens_reused, 0);
        assert_eq!(sched.stats().cow_copies, 0);
    }

    #[test]
    fn chunked_prefill_compresses_steps_and_ttft_not_streams() {
        // Prompt of 6 at chunk 6: the whole prompt is ingested in one
        // batched step (TTFT 1 instead of 6), total prefill accounting
        // is unchanged, and the generated stream is bitwise identical.
        let lm = small_model();
        let prompt: Vec<u32> = vec![3, 1, 4, 1, 5, 9];
        let run = |chunk: usize| {
            let mut sched = Scheduler::with_prefill_chunk(&lm, 2, 1, chunk);
            sched.submit(GenRequest::greedy(0, prompt.clone(), 4));
            let done = sched.run();
            (done[0].clone(), sched.stats().clone())
        };
        let (c1, s1) = run(1);
        let (c6, s6) = run(6);
        assert_eq!(c1.tokens, c6.tokens, "chunking changed the stream");
        assert_eq!(s1.prefill_tokens, 6);
        assert_eq!(s6.prefill_tokens, 6,
                   "prefill accounting must not depend on chunking");
        assert_eq!(c1.ttft_steps, 6);
        assert_eq!(c6.ttft_steps, 1);
        assert_eq!(s6.ttft_steps, 1);
        // 6 prefill steps + 3 more decode steps vs 1 + 3.
        assert_eq!(c1.lane_steps, 9);
        assert_eq!(c6.lane_steps, 4);
        assert_eq!(s6.batch_steps, 4);
        // A chunk larger than any prompt behaves like chunk=prompt_len.
        let (c99, _) = run(99);
        assert_eq!(c99.tokens, c1.tokens);
        assert_eq!(c99.ttft_steps, 1);
    }

    #[test]
    fn overcommitted_attn_scheduler_completes_without_panicking() {
        // THE backpressure regression (polarity flip of the old
        // overcommit panic): a page pool sized for 2 lanes serving 6
        // requests on 4 scheduler lanes used to panic in bind_and_begin
        // the moment lane 3 claimed its first page; now the refused
        // lanes are requeued and every request completes — with the
        // exact streams an uncontended cache produces.
        use crate::serve::model::LatentAttnLm;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 33);
        let reqs = || -> Vec<GenRequest> {
            (0..6).map(|id| GenRequest::greedy(
                id, vec![id as u32, 7, 11], 4)).collect()
        };
        // Uncontended reference: room for all 6 lanes at once.
        let roomy = latent.build_float(6, 8);
        let mut sched = Scheduler::new(&roomy, 6, 1);
        for r in reqs() {
            sched.submit(r);
        }
        let want: Vec<Vec<u32>> =
            sched.run().into_iter().map(|c| c.tokens).collect();

        // Overcommitted: 2 lanes' worth of pages, 4 lanes, 6 requests.
        let tight = latent.build_float(2, 8);
        let mut sched = Scheduler::new(&tight, 4, 1);
        for r in reqs() {
            sched.submit(r);
        }
        let done = sched.run();
        assert_eq!(done.len(), 6, "all requests must complete");
        let got: Vec<Vec<u32>> = done.into_iter().map(|c| c.tokens).collect();
        assert_eq!(got, want, "backpressure must never change streams");
        assert!(sched.stats().requeued > 0,
                "this workload must actually exercise backpressure");
        assert_eq!(tight.kv_pages_in_use(), 0,
                   "drained overcommitted scheduler must leak no pages");
        // Delivered-work accounting survives requeues: abandoned
        // attempts are rolled back, so the totals equal exactly what
        // was handed to callers (throughput numbers never inflate).
        assert_eq!(sched.stats().generated_tokens, 6 * 4,
                   "generated_tokens must count delivered tokens only");
        assert_eq!(sched.stats().prefill_tokens, 6 * 3,
                   "prefill_tokens must count delivered prompts only");
    }

    #[test]
    fn stochastic_sampling_survives_requeue_bitwise() {
        // "Requeues cost latency, never correctness" must hold for
        // *sampled* lanes too: a top-k lane bounced by backpressure had
        // already drawn from its rng, and the restart must reproduce
        // the identical stream — which only works because `Lane::new`
        // re-seeds the rng from the request instead of resuming the
        // half-consumed stream. Same overcommit geometry as the greedy
        // test above, so backpressure is actually exercised.
        use crate::serve::model::LatentAttnLm;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 57);
        let reqs = || -> Vec<GenRequest> {
            (0..6).map(|id| GenRequest::top_k(
                id, vec![id as u32, 7, 11], 4, 5, 0.9,
                1000 + id as u64)).collect()
        };
        // Uncontended reference: room for all 6 lanes at once.
        let roomy = latent.build_float(6, 8);
        let mut sched = Scheduler::new(&roomy, 6, 1);
        for r in reqs() {
            sched.submit(r);
        }
        let want: Vec<Vec<u32>> =
            sched.run().into_iter().map(|c| c.tokens).collect();

        // Overcommitted: 2 lanes' worth of pages, 4 lanes, 6 requests.
        let tight = latent.build_float(2, 8);
        let mut sched = Scheduler::new(&tight, 4, 1);
        for r in reqs() {
            sched.submit(r);
        }
        let done = sched.run();
        assert_eq!(done.len(), 6, "all requests must complete");
        let got: Vec<Vec<u32>> =
            done.into_iter().map(|c| c.tokens).collect();
        assert_eq!(got, want,
                   "a requeued top-k lane must restart its rng from the \
                    request seed and reproduce the uncontended stream");
        assert!(sched.stats().requeued > 0,
                "this workload must actually exercise backpressure");
        assert_eq!(tight.kv_pages_in_use(), 0,
                   "drained overcommitted scheduler must leak no pages");
    }

    #[test]
    fn observer_streams_every_token_exactly_once_in_order() {
        // The streaming contract: concatenating a request's Token
        // events by index reproduces its Completion bitwise, and the
        // no-op-observer path (step_into) yields identical streams.
        use std::collections::BTreeMap;
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 3, 1);
        for id in 0..5 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 5], 3 + id));
        }
        let mut done = Vec::new();
        let mut streams: BTreeMap<usize, Vec<u32>> = BTreeMap::new();
        while sched.pending() > 0 {
            sched.step_observed(&mut done, &mut |ev| match ev {
                StreamEvent::Token { id, token, index } => {
                    let s = streams.entry(id).or_default();
                    assert_eq!(index, s.len(),
                               "tokens must stream in index order");
                    s.push(token);
                }
                StreamEvent::Requeued { .. } => {
                    panic!("no backpressure in this workload");
                }
            });
        }
        assert_eq!(done.len(), 5);
        for c in &done {
            assert_eq!(streams[&c.id], c.tokens,
                       "streamed tokens must equal the completion");
        }
        // And the observer changed nothing vs the plain path.
        let mut plain = Scheduler::new(&lm, 3, 1);
        for id in 0..5 {
            plain.submit(GenRequest::greedy(id, vec![id as u32, 5], 3 + id));
        }
        let want = plain.run();
        let mut got = done.clone();
        got.sort_by_key(|c| c.id);
        for (a, b) in got.iter().zip(want.iter()) {
            assert_eq!(a.tokens, b.tokens, "observer perturbed decoding");
        }
    }

    #[test]
    fn observer_requeue_reemits_identical_tokens_from_zero() {
        // Under KV backpressure a streamed lane restarts: the observer
        // sees Requeued{discarded}, then the restart re-emits the same
        // tokens from index 0 — a high-water-mark consumer forwards
        // each index once and the deduped stream equals the
        // completion. Overcommit geometry borrowed from the
        // backpressure tests above.
        use crate::serve::model::LatentAttnLm;
        use std::collections::BTreeMap;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 33);
        let tight = latent.build_float(2, 8);
        let mut sched = Scheduler::new(&tight, 4, 1);
        for id in 0..6 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 7, 11], 4));
        }
        struct Watch { emitted: usize, forwarded: Vec<u32>, requeues: usize }
        let mut watch: BTreeMap<usize, Watch> = BTreeMap::new();
        let mut done = Vec::new();
        while sched.pending() > 0 {
            sched.step_observed(&mut done, &mut |ev| match ev {
                StreamEvent::Token { id, token, index } => {
                    let w = watch.entry(id).or_insert(
                        Watch { emitted: 0, forwarded: Vec::new(),
                                requeues: 0 });
                    assert!(index <= w.emitted,
                            "restart may only replay or extend");
                    if index >= w.emitted {
                        w.forwarded.push(token);
                        w.emitted = index + 1;
                    } else {
                        // Replayed token must be bitwise identical to
                        // what was already forwarded at this index.
                        assert_eq!(w.forwarded[index], token,
                                   "requeue replay diverged");
                    }
                }
                StreamEvent::Requeued { id, discarded } => {
                    if let Some(w) = watch.get_mut(&id) {
                        // A bounced attempt's token count never
                        // exceeds the high-water mark (a re-bounce
                        // mid-replay discards fewer).
                        assert!(discarded <= w.emitted,
                                "attempt emitted past the high-water mark");
                        w.requeues += 1;
                    }
                }
            });
        }
        assert_eq!(done.len(), 6);
        assert!(sched.stats().requeued > 0,
                "workload must exercise backpressure");
        let total_requeues: usize =
            watch.values().map(|w| w.requeues).sum();
        assert!(total_requeues <= sched.stats().requeued,
                "observer saw more requeues than the stats counted");
        for c in &done {
            assert_eq!(watch[&c.id].forwarded, c.tokens,
                       "deduped stream must equal the completion");
        }
    }

    #[test]
    fn server_side_stats_fields_default_to_empty() {
        // The HTTP-layer counters ride on ServeStats but are only
        // written by the server's admission path — direct scheduler
        // use must leave them zeroed so serve-bench numbers stay
        // comparable across schema 4 -> 5.
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 2, 1);
        sched.submit(GenRequest::greedy(0, vec![1], 2));
        let _ = sched.run();
        let st = sched.stats();
        assert_eq!(st.queue_depth_max, 0);
        assert_eq!(st.rejected_429, 0);
        assert_eq!(st.rejected_413, 0);
        assert_eq!(st.cancelled, 0);
        assert_eq!(st.deadline_expired, 0);
        assert_eq!(st.worker_restarts, 0);
        assert!(st.tenants.is_empty());
    }

    #[test]
    fn cancel_aborts_queued_and_live_lanes_and_frees_pages() {
        // Cancellation is the client-hangup path: a live lane's pages
        // come back immediately, its delivered-work stats roll back
        // (nobody received the stream), and no completion appears.
        use crate::serve::model::LatentAttnLm;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 21);
        let lm = latent.build_float(3, 8);
        let mut sched = Scheduler::new(&lm, 2, 1);
        for id in 0..3 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 5], 6));
        }
        sched.step(); // ids 0 and 1 live, id 2 still queued
        assert!(lm.kv_pages_in_use() > 0);
        assert!(sched.cancel(2), "queued request must cancel");
        assert!(sched.cancel(0), "live lane must cancel");
        assert!(!sched.cancel(9), "unknown id must report not-found");
        assert_eq!(sched.stats().cancelled, 2);
        let done = sched.run();
        assert_eq!(done.len(), 1, "cancelled requests yield no completion");
        assert_eq!(done[0].id, 1);
        assert_eq!(done[0].finish_reason, FinishReason::Length);
        assert_eq!(lm.kv_pages_in_use(), 0, "cancelled lane leaked pages");
        // Only the delivered stream is counted.
        assert_eq!(sched.stats().generated_tokens, 6);
        assert_eq!(sched.stats().prefill_tokens, 2);
    }

    #[test]
    fn expire_truncates_live_streams_and_empties_queued_ones() {
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 1, 1);
        sched.submit(GenRequest::greedy(0, vec![1, 2], 9));
        sched.submit(GenRequest::greedy(1, vec![3], 9));
        for _ in 0..4 {
            sched.step(); // 2 prefill-ish steps + sampling: 3 tokens out
        }
        let c = sched.expire(0).expect("live lane must expire");
        assert_eq!(c.finish_reason, FinishReason::DeadlineExpired);
        assert_eq!(c.tokens.len(), 3, "truncated stream keeps its tokens");
        // Expiry delivers the truncated stream, so stats stand.
        assert_eq!(sched.stats().generated_tokens, 3);
        let q = sched.expire(1).expect("queued request must expire");
        assert_eq!(q.finish_reason, FinishReason::DeadlineExpired);
        assert!(q.tokens.is_empty());
        assert_eq!(sched.stats().deadline_expired, 2);
        assert!(sched.expire(7).is_none());
        assert_eq!(sched.pending(), 0);
    }

    #[test]
    fn forced_out_of_pages_bounces_lanes_without_changing_streams() {
        // The scheduler-level fault: scripted steps treat every live
        // lane as KV-refused without invoking the model — works on a
        // decay model (no cache at all), exercises the real requeue
        // path, and must never change delivered streams or totals.
        let lm = small_model();
        let run = |plan: Option<FaultPlan>| {
            let mut sched = Scheduler::new(&lm, 3, 1);
            if let Some(p) = plan {
                sched.set_fault_plan(p);
            }
            for id in 0..5 {
                sched.submit(GenRequest::greedy(id, vec![id as u32, 9], 4));
            }
            let done = sched.run();
            let streams: Vec<Vec<u32>> =
                done.into_iter().map(|c| c.tokens).collect();
            (streams, sched.stats().clone())
        };
        let (want, clean) = run(None);
        assert_eq!(clean.requeued, 0);
        let plan = FaultPlan { out_of_pages_steps: vec![2, 4],
                               ..FaultPlan::default() };
        let (got, faulted) = run(Some(plan));
        assert_eq!(got, want, "forced refusals must never change streams");
        assert!(faulted.requeued >= 3, "step 2 must bounce every live lane");
        assert_eq!(faulted.generated_tokens, clean.generated_tokens,
                   "bounced work must be rolled back");
        assert_eq!(faulted.prefill_tokens, clean.prefill_tokens,
                   "bounced prefill must be rolled back");
    }

    #[test]
    fn absorb_sums_counters_maxes_peaks_and_merges_tenants() {
        let mut a = ServeStats {
            generated_tokens: 5,
            peak_occupancy: 3,
            queue_depth_max: 2,
            cancelled: 1,
            spec_k_effective: 4,
            ..ServeStats::default()
        };
        a.tenants.push(TenantStats { tenant: "t".into(), served: 1,
                                     queued: 0, rejected: 2 });
        let mut b = ServeStats {
            generated_tokens: 7,
            peak_occupancy: 2,
            queue_depth_max: 4,
            worker_restarts: 1,
            deadline_expired: 3,
            spec_k_effective: 3,
            ..ServeStats::default()
        };
        b.tenants.push(TenantStats { tenant: "t".into(), served: 2,
                                     queued: 1, rejected: 0 });
        b.tenants.push(TenantStats { tenant: "u".into(), served: 1,
                                     queued: 0, rejected: 0 });
        a.absorb(&b);
        assert_eq!(a.generated_tokens, 12);
        assert_eq!(a.peak_occupancy, 3, "peaks take the max");
        assert_eq!(a.queue_depth_max, 4, "peaks take the max");
        assert_eq!(a.spec_k_effective, 4, "gauges take the max");
        assert_eq!(a.cancelled, 1);
        assert_eq!(a.deadline_expired, 3);
        assert_eq!(a.worker_restarts, 1);
        assert_eq!(a.tenants.len(), 2, "tenant rows merge by name");
        let t = a.tenants.iter().find(|t| t.tenant == "t").unwrap();
        assert_eq!((t.served, t.queued, t.rejected), (3, 1, 2));
    }

    #[test]
    fn nan_wide_k_never_draws_nan() {
        // k spanning the whole vocab: the NaN survivor is selected into
        // the set (fewer finite candidates than k) but weighs zero.
        let mut row = vec![0.0f32; 8];
        row[2] = 5.0;
        row[5] = f32::NAN;
        let s = Sampling::TopK { k: 8, temperature: 1.0, seed: 7 };
        for trial in 0..64u64 {
            let t = sample(&row, &s, &mut SplitMix64::new(trial));
            assert_ne!(t, 5, "zero-weight NaN survivor was drawn");
        }
    }

    #[test]
    fn top_k_orders_nan_deterministically_last() {
        // partial_cmp maps NaN to Equal, which is non-transitive under
        // select_nth_unstable_by — the old comparator could pick
        // NaN-dependent top-k sets. NaNs now lose to every finite
        // logit and are never sampled while finite candidates fill k.
        let mut row = vec![0.0f32; 8];
        row[2] = 5.0;
        row[5] = f32::NAN;
        row[6] = 4.0;
        row[7] = 3.0;
        let s = Sampling::TopK { k: 3, temperature: 1.0, seed: 7 };
        for trial in 0..64u64 {
            let mut rng = SplitMix64::new(trial);
            let t = sample(&row, &s, &mut rng);
            assert_ne!(t, 5, "NaN logit must never be sampled while \
                              finite candidates fill k");
            assert!(t == 2 || t == 6 || t == 7,
                    "token {t} outside the finite top-3");
        }
        // Identical rng state -> identical draw (sample is a function).
        let a = sample(&row, &s, &mut SplitMix64::new(9));
        let b = sample(&row, &s, &mut SplitMix64::new(9));
        assert_eq!(a, b);
        // An all-NaN row degrades to the lowest token id, not chaos.
        let nan_row = vec![f32::NAN; 4];
        assert_eq!(sample(&nan_row, &s, &mut SplitMix64::new(3)), 0);
        // Greedy never prefers NaN over a finite logit either — not
        // even a NaN at token 0, which would otherwise win every
        // strict-greater comparison by making them all false.
        assert_eq!(sample(&row, &Sampling::Greedy,
                          &mut SplitMix64::new(1)), 2);
        let mut nan_first = row.clone();
        nan_first[0] = f32::NAN;
        assert_eq!(sample(&nan_first, &Sampling::Greedy,
                          &mut SplitMix64::new(1)), 2);
        assert_eq!(sample(&nan_row, &Sampling::Greedy,
                          &mut SplitMix64::new(1)), 0);
    }

    #[test]
    fn speculative_greedy_streams_match_plain_decode() {
        // The losslessness contract at unit scale (tests/speculative.rs
        // runs the four-family matrix): a ternary draft proposing for a
        // float target changes how many tokens a step emits, never
        // which tokens — and a drained run leaves both models' page
        // pools empty.
        use crate::serve::model::LatentAttnLm;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 17);
        let reqs = || -> Vec<GenRequest> {
            (0..6).map(|id| GenRequest::greedy(
                id, vec![id as u32, 7, 11], 5)).collect()
        };
        let target = latent.build_float(4, 24);
        let mut plain = Scheduler::new(&target, 4, 1);
        for r in reqs() {
            plain.submit(r);
        }
        let mut want: Vec<Completion> = plain.run();
        want.sort_by_key(|c| c.id);
        let want: Vec<Vec<u32>> =
            want.into_iter().map(|c| c.tokens).collect();

        let draft = latent.build_ternary(4, 24);
        let mut sched = Scheduler::new(&target, 4, 1);
        sched.set_speculative(&draft, SpecConfig {
            draft_family: FamilySpec::Ternary, k: 3 });
        for r in reqs() {
            sched.submit(r);
        }
        let mut done = sched.run();
        done.sort_by_key(|c| c.id);
        let got: Vec<Vec<u32>> =
            done.into_iter().map(|c| c.tokens).collect();
        assert_eq!(got, want, "speculation must never change streams");
        let st = sched.stats();
        assert!(st.spec_proposed > 0, "draft never proposed");
        assert!(st.spec_verify_steps > 0, "target never verified");
        assert!(st.spec_accepted <= st.spec_proposed);
        assert!(st.spec_k_effective >= 1 && st.spec_k_effective <= 3,
                "adaptive k must stay clamped to [1, SpecConfig.k]");
        assert_eq!(target.kv_pages_in_use(), 0,
                   "drained speculative run leaked target pages");
        assert_eq!(draft.kv_pages_in_use(), 0,
                   "drained speculative run leaked draft pages");
    }

    #[test]
    fn identical_draft_accepts_every_proposal() {
        // A draft built from the same latent weights in the same format
        // produces bitwise-identical greedy argmaxes, so every proposal
        // must land: with budget = 1 + (k+1) the whole decode is one
        // verify round per lane — accepted_per_step == k exactly. Any
        // drift here means verify rows and draft feeds disagree about
        // positions.
        use crate::serve::model::LatentAttnLm;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 29);
        let target = latent.build_float(4, 24);
        let draft = latent.build_float(4, 24);
        let mut sched = Scheduler::new(&target, 4, 1);
        sched.set_speculative(&draft, SpecConfig {
            draft_family: FamilySpec::Float, k: 3 });
        for id in 0..4 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 7, 11], 5));
        }
        let done = sched.run();
        assert_eq!(done.len(), 4);
        for c in &done {
            assert_eq!(c.tokens.len(), 5);
        }
        let st = sched.stats();
        assert_eq!(st.spec_accepted, st.spec_proposed,
                   "an identical draft must land every proposal");
        assert_eq!(st.spec_proposed, 4 * 3);
        assert_eq!(st.spec_verify_steps, 4,
                   "budget 1 + (k+1) is exactly one verify round");
        assert!((st.accepted_per_step() - 3.0).abs() < 1e-12);
        assert_eq!(st.spec_k_effective, 3,
                   "full acceptance must never shrink the adaptive k");
        assert_eq!(target.kv_pages_in_use(), 0);
        assert_eq!(draft.kv_pages_in_use(), 0);
    }

    #[test]
    #[should_panic(expected = "cannot roll back")]
    fn set_speculative_rejects_a_decay_target() {
        // The decay families carry a recurrent state that cannot be
        // rewound to an earlier position, so speculation must refuse
        // them up front instead of corrupting streams at the first
        // rejected proposal.
        use crate::serve::model::LatentAttnLm;
        let lm = small_model();
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 3);
        let draft = latent.build_ternary(2, 8);
        let mut sched = Scheduler::new(&lm, 2, 1);
        sched.set_speculative(&draft, SpecConfig {
            draft_family: FamilySpec::Ternary, k: 2 });
    }

    #[test]
    fn spec_counters_stay_zero_off_the_speculative_path() {
        // Non-speculative runs must report exact zeros (the BENCH
        // schema-7 smoke asserts this end to end), and the ratio is
        // well-defined with no verify steps.
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 2, 1);
        sched.submit(GenRequest::greedy(0, vec![1], 3));
        let _ = sched.run();
        let st = sched.stats();
        assert!(sched.speculative().is_none());
        assert_eq!(st.spec_proposed, 0);
        assert_eq!(st.spec_accepted, 0);
        assert_eq!(st.spec_verify_steps, 0);
        assert_eq!(st.accepted_per_step(), 0.0);
        let synth = ServeStats { spec_accepted: 9, spec_verify_steps: 4,
                                 ..ServeStats::default() };
        assert!((synth.accepted_per_step() - 2.25).abs() < 1e-12);
    }
}
