//! The multi-request decode scheduler: continuous batching over any
//! [`DecodeModel`] — the blocked ternary, k-bit quant, and dense f32
//! serving models all run underneath it unchanged.
//!
//! The scheduler owns `max_batch` *lanes*. Each step it (1) admits
//! queued requests into empty lanes, (2) assembles the live lanes'
//! states + next tokens into one (batch, hidden) kernel invocation,
//! (3) advances every lane — prompt tokens are consumed one per step
//! (prefill), then sampling starts — and (4) retires finished lanes,
//! whose slots are refilled from the queue on the next step while the
//! remaining lanes continue mid-flight (continuous batching: the batch
//! never drains to refill).
//!
//! Determinism: a lane's computation depends only on its own state and
//! token stream ([`DecodeModel::step_batch`]'s contract + the kernels'
//! batch-invariant accumulation order), greedy argmax breaks ties by
//! token id, and top-k sampling draws from a per-request seeded
//! [`SplitMix64`]. The same request set therefore yields identical
//! token streams at batch 1 and batch 8 — `tests/serve_determinism.rs`
//! locks this in.
//!
//! Lane lifecycle stays model-blind: the scheduler hands every
//! admitted lane a zeroed state buffer and, when the lane retires,
//! calls [`DecodeModel::retire_state`] exactly once before recycling
//! the buffer. Decay-state models treat both as plain memory; the
//! attention model uses the zeroed buffer as "unbound" and the retire
//! hook to free its paged KV-cache sequence — so paged attention
//! serving needs no scheduler changes beyond this one hook.

use std::collections::VecDeque;

use crate::runtime::{DecodeScratch, SplitMix64, WorkerPool};
use crate::serve::model::DecodeModel;

/// Per-lane sampling policy.
#[derive(Debug, Clone, Copy, PartialEq)]
pub enum Sampling {
    /// Argmax; ties break toward the lower token id.
    Greedy,
    /// Sample among the `k` highest logits at `temperature`, from a
    /// stream seeded by `seed` (deterministic per request, independent
    /// of batch composition).
    TopK { k: usize, temperature: f32, seed: u64 },
}

/// One generation request.
#[derive(Debug, Clone)]
pub struct GenRequest {
    pub id: usize,
    pub prompt: Vec<u32>,
    pub max_new_tokens: usize,
    pub sampling: Sampling,
}

impl GenRequest {
    pub fn greedy(id: usize, prompt: Vec<u32>, max_new_tokens: usize) -> Self {
        GenRequest { id, prompt, max_new_tokens, sampling: Sampling::Greedy }
    }

    pub fn top_k(id: usize, prompt: Vec<u32>, max_new_tokens: usize,
                 k: usize, temperature: f32, seed: u64) -> Self {
        GenRequest { id, prompt, max_new_tokens,
                     sampling: Sampling::TopK { k, temperature, seed } }
    }
}

/// A finished request: the generated continuation (prompt excluded).
#[derive(Debug, Clone)]
pub struct Completion {
    pub id: usize,
    pub prompt_len: usize,
    pub tokens: Vec<u32>,
    /// Batched steps this request occupied a lane for (prefill + decode).
    pub lane_steps: usize,
}

/// Aggregate serving counters for throughput reporting.
#[derive(Debug, Clone, Default)]
pub struct ServeStats {
    /// Kernel invocations (batched steps with >= 1 live lane).
    pub batch_steps: usize,
    /// Sum over steps of live lanes (batch_steps * avg occupancy).
    pub lane_steps: usize,
    pub prefill_tokens: usize,
    pub generated_tokens: usize,
    pub peak_occupancy: usize,
}

struct Lane {
    req: GenRequest,
    state: Vec<f32>,
    /// Prompt tokens consumed so far.
    pos: usize,
    generated: Vec<u32>,
    rng: SplitMix64,
    steps: usize,
}

impl Lane {
    /// `state` is a zeroed hidden-state buffer — freshly allocated or
    /// recycled from a retired lane (the scheduler's admission path
    /// reuses buffers so steady-state traffic stops allocating one
    /// `Vec<f32>` per admitted request).
    fn new(req: GenRequest, state: Vec<f32>) -> Lane {
        let seed = match req.sampling {
            Sampling::TopK { seed, .. } => seed,
            Sampling::Greedy => req.id as u64,
        };
        Lane {
            state,
            pos: 0,
            generated: Vec::with_capacity(req.max_new_tokens),
            rng: SplitMix64::new(seed),
            steps: 0,
            req,
        }
    }

    /// The token this lane feeds into the next batched step.
    fn next_token(&self) -> u32 {
        if self.pos < self.req.prompt.len() {
            self.req.prompt[self.pos]
        } else {
            *self.generated.last().expect("generating lane has a last token")
        }
    }
}

/// Continuous-batching decode engine over any [`DecodeModel`]
/// (including trait objects).
///
/// The scheduler owns the serving execution substrate for its whole
/// lifetime: one persistent [`WorkerPool`] (kernel threads are
/// dispatched, never spawned, across every matmul of every step) and
/// one [`DecodeScratch`] (activation/logit/accumulator buffers reused
/// across steps), plus recycled lane-state buffers — steady-state
/// tensor/thread traffic is gone; the only per-step heap use left is
/// one small vector of lane-state borrows (it cannot outlive the step,
/// so it cannot be cached).
pub struct Scheduler<'m, M: DecodeModel + ?Sized> {
    model: &'m M,
    max_batch: usize,
    pool: WorkerPool,
    scratch: DecodeScratch,
    queue: VecDeque<GenRequest>,
    lanes: Vec<Option<Lane>>,
    /// Zeroable hidden-state buffers handed back by retired lanes,
    /// reused on admission.
    free_states: Vec<Vec<f32>>,
    /// Next-token staging buffer reused across steps.
    token_buf: Vec<u32>,
    stats: ServeStats,
}

impl<'m, M: DecodeModel + ?Sized> Scheduler<'m, M> {
    /// `max_batch` lanes; `threads` sizes the persistent kernel pool
    /// (0 = auto).
    pub fn new(model: &'m M, max_batch: usize, threads: usize) -> Self {
        let max_batch = max_batch.max(1);
        Scheduler {
            model,
            max_batch,
            pool: WorkerPool::new(threads),
            scratch: DecodeScratch::new(),
            queue: VecDeque::new(),
            lanes: (0..max_batch).map(|_| None).collect(),
            free_states: Vec::new(),
            token_buf: Vec::new(),
            stats: ServeStats::default(),
        }
    }

    /// Enqueue a request. Empty prompts are normalized to `[0]` and
    /// `max_new_tokens` to at least 1 so every request terminates.
    pub fn submit(&mut self, mut req: GenRequest) {
        if req.prompt.is_empty() {
            req.prompt.push(0);
        }
        req.max_new_tokens = req.max_new_tokens.max(1);
        self.queue.push_back(req);
    }

    pub fn pending(&self) -> usize {
        self.queue.len()
            + self.lanes.iter().filter(|l| l.is_some()).count()
    }

    pub fn stats(&self) -> &ServeStats {
        &self.stats
    }

    fn admit(&mut self) {
        let hidden = self.model.dims().hidden;
        for slot in &mut self.lanes {
            if slot.is_none() {
                let Some(req) = self.queue.pop_front() else { break };
                // Recycle a retired lane's state buffer when one is
                // available (zeroed here; `free_states` holds them
                // as-retired).
                let state = match self.free_states.pop() {
                    Some(mut s) => {
                        debug_assert_eq!(s.len(), hidden);
                        s.fill(0.0);
                        s
                    }
                    None => vec![0.0; hidden],
                };
                *slot = Some(Lane::new(req, state));
            }
        }
    }

    /// One batched step across all live lanes. Returns any requests
    /// that finished on this step.
    ///
    /// Compatibility wrapper over [`Scheduler::step_into`] — it
    /// allocates the completion vector per call; callers that step in
    /// a loop should pass one reusable vector to `step_into` (as
    /// [`Scheduler::run`] does).
    pub fn step(&mut self) -> Vec<Completion> {
        let mut done = Vec::new();
        self.step_into(&mut done);
        done
    }

    /// One batched step across all live lanes; requests that finished
    /// on this step are appended to `done`. Steady-state allocation is
    /// reduced to the one unavoidable piece: tokens stage in a reused
    /// buffer, the kernel invocation runs through the scheduler's
    /// pool + scratch, nothing is allocated when no lane retires — only
    /// the batch-sized vector of `&mut` lane-state borrows is built per
    /// step (a borrow cannot be stored across steps).
    pub fn step_into(&mut self, done: &mut Vec<Completion>) {
        self.admit();
        self.token_buf.clear();
        for s in self.lanes.iter() {
            if let Some(lane) = s {
                self.token_buf.push(lane.next_token());
            }
        }
        if self.token_buf.is_empty() {
            return;
        }
        let mut state_refs: Vec<&mut [f32]> = self.lanes.iter_mut()
            .filter_map(|s| s.as_mut().map(|l| l.state.as_mut_slice()))
            .collect();
        self.model.step_batch_into(&mut state_refs, &self.token_buf,
                                   &self.pool, &mut self.scratch);
        drop(state_refs);
        let logits = &self.scratch.logits;

        self.stats.batch_steps += 1;
        self.stats.lane_steps += self.token_buf.len();
        self.stats.peak_occupancy =
            self.stats.peak_occupancy.max(self.token_buf.len());

        let mut ai = 0usize; // index into the batch = live-lane ordinal
        for slot in &mut self.lanes {
            let Some(lane) = slot.as_mut() else { continue };
            lane.steps += 1;
            let fed_prompt = lane.pos < lane.req.prompt.len();
            if fed_prompt {
                lane.pos += 1;
                self.stats.prefill_tokens += 1;
            }
            // Once the final prompt token has been fed, every step's
            // logits produce one sampled continuation token.
            if lane.pos == lane.req.prompt.len() {
                let tok = sample(logits.row(ai), &lane.req.sampling,
                                 &mut lane.rng);
                lane.generated.push(tok);
                self.stats.generated_tokens += 1;
                if lane.generated.len() >= lane.req.max_new_tokens {
                    let mut lane = slot.take().unwrap();
                    // Lane retire: release model-side per-lane resources
                    // (an AttnLm frees its KV-cache pages here) before
                    // the state buffer is recycled.
                    self.model.retire_state(&mut lane.state);
                    self.free_states.push(lane.state);
                    done.push(Completion {
                        id: lane.req.id,
                        prompt_len: lane.req.prompt.len(),
                        tokens: lane.generated,
                        lane_steps: lane.steps,
                    });
                }
            }
            ai += 1;
        }
    }

    /// Drain the queue: step until every submitted request completes.
    /// Completions are returned sorted by request id.
    pub fn run(&mut self) -> Vec<Completion> {
        let mut out = Vec::new();
        while self.pending() > 0 {
            self.step_into(&mut out);
        }
        out.sort_by_key(|c| c.id);
        out
    }
}

impl<M: DecodeModel + ?Sized> Drop for Scheduler<'_, M> {
    /// Abandoned mid-flight lanes still release their model-side
    /// resources (KV-cache pages): a scheduler dropped before draining
    /// must not leak pages out of the model's pool.
    fn drop(&mut self) {
        let model = self.model;
        for slot in &mut self.lanes {
            if let Some(lane) = slot.as_mut() {
                model.retire_state(&mut lane.state);
            }
        }
    }
}

fn sample(row: &[f32], sampling: &Sampling, rng: &mut SplitMix64) -> u32 {
    match *sampling {
        Sampling::Greedy => {
            // Strict-greater scan: ties keep the lowest token id, which
            // is batch-independent (no float-order ambiguity).
            let mut best = 0usize;
            for (i, &v) in row.iter().enumerate() {
                if v > row[best] {
                    best = i;
                }
            }
            best as u32
        }
        Sampling::TopK { k, temperature, .. } => {
            let k = k.clamp(1, row.len());
            // Total order (logit desc, then token id) makes the top-k
            // *set* unique even under ties, so an unstable partition
            // selects deterministically; only the k survivors are
            // sorted, not the whole vocab.
            let desc = |a: &usize, b: &usize| {
                row[*b].partial_cmp(&row[*a])
                    .unwrap_or(std::cmp::Ordering::Equal)
                    .then(a.cmp(b))
            };
            let mut idx: Vec<usize> = (0..row.len()).collect();
            if k < idx.len() {
                idx.select_nth_unstable_by(k - 1, desc);
                idx.truncate(k);
            }
            idx.sort_by(desc);
            let t = temperature.max(1e-6);
            let mx = row[idx[0]];
            let ws: Vec<f64> = idx.iter()
                .map(|&j| (((row[j] - mx) / t) as f64).exp())
                .collect();
            idx[rng.weighted(&ws)] as u32
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::serve::model::{LmDims, TernaryLm};

    fn small_model() -> TernaryLm {
        TernaryLm::synthetic_pair(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 1, 9).0
    }

    #[test]
    fn completes_all_requests_with_more_requests_than_lanes() {
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 4, 1);
        // Heterogeneous budgets so lanes retire at different steps.
        let budget = |id: usize| 2 + id % 5;
        for id in 0..10 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 5],
                                            budget(id)));
        }
        let done = sched.run();
        assert_eq!(done.len(), 10);
        for (i, c) in done.iter().enumerate() {
            assert_eq!(c.id, i);
            assert_eq!(c.tokens.len(), budget(i));
            // Sampling starts on the final prompt step, so a lane is
            // occupied prompt_len + max_new - 1 steps.
            assert_eq!(c.lane_steps, 2 + budget(i) - 1);
        }
        let st = sched.stats();
        assert_eq!(st.generated_tokens, 40);
        assert_eq!(st.prefill_tokens, 20);
        assert_eq!(st.peak_occupancy, 4);
        assert_eq!(st.lane_steps, 50);
        // Continuous batching: retired lanes refill mid-flight, packing
        // 50 lane-steps into 16 batched steps; a drain-then-refill
        // scheduler (groups of 4, bounded by each group's longest
        // request) would need 20.
        assert_eq!(st.batch_steps, 16);
    }

    #[test]
    fn empty_prompt_and_zero_budget_are_normalized() {
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 2, 1);
        sched.submit(GenRequest::greedy(0, vec![], 0));
        let done = sched.run();
        assert_eq!(done.len(), 1);
        assert_eq!(done[0].prompt_len, 1);
        assert_eq!(done[0].tokens.len(), 1);
    }

    #[test]
    fn top_k_is_reproducible_and_respects_k() {
        let lm = small_model();
        let run = || {
            let mut sched = Scheduler::new(&lm, 3, 1);
            for id in 0..5 {
                sched.submit(GenRequest::top_k(id, vec![2, 3], 8, 4, 0.8,
                                               100 + id as u64));
            }
            sched.run()
        };
        let a = run();
        let b = run();
        for (x, y) in a.iter().zip(b.iter()) {
            assert_eq!(x.tokens, y.tokens, "top-k not reproducible");
        }
        // k=1 degenerates to greedy.
        let mut g = Scheduler::new(&lm, 1, 1);
        g.submit(GenRequest::greedy(0, vec![7], 5));
        let mut t = Scheduler::new(&lm, 1, 1);
        t.submit(GenRequest::top_k(0, vec![7], 5, 1, 1.0, 42));
        assert_eq!(g.run()[0].tokens, t.run()[0].tokens);
    }

    #[test]
    fn recycled_state_buffers_do_not_leak_context() {
        // A second wave served by a scheduler whose lanes all recycle
        // retired-state buffers must decode exactly like a fresh
        // scheduler: recycling is invisible (buffers are re-zeroed).
        let lm = small_model();
        let reqs = |base: usize| -> Vec<GenRequest> {
            (0..6).map(|i| GenRequest::greedy(
                base + i, vec![(3 * i) as u32, 11], 4)).collect()
        };
        let mut warm = Scheduler::new(&lm, 3, 2);
        for r in reqs(0) {
            warm.submit(r);
        }
        let _ = warm.run(); // every lane has now retired at least once
        for r in reqs(100) {
            warm.submit(r);
        }
        let warm_tokens: Vec<Vec<u32>> =
            warm.run().into_iter().map(|c| c.tokens).collect();

        let mut fresh = Scheduler::new(&lm, 3, 2);
        for r in reqs(100) {
            fresh.submit(r);
        }
        let fresh_tokens: Vec<Vec<u32>> =
            fresh.run().into_iter().map(|c| c.tokens).collect();
        assert_eq!(warm_tokens, fresh_tokens);
    }

    #[test]
    fn step_into_appends_without_clearing() {
        let lm = small_model();
        let mut sched = Scheduler::new(&lm, 2, 1);
        for id in 0..4 {
            sched.submit(GenRequest::greedy(id, vec![1], 2));
        }
        let mut done = Vec::new();
        while sched.pending() > 0 {
            sched.step_into(&mut done);
        }
        assert_eq!(done.len(), 4, "completions must accumulate in place");
    }

    #[test]
    fn attention_lanes_release_pages_on_retire_and_drop() {
        // The lane-retire -> page-recycle path, end to end through the
        // unmodified scheduler: a drained run leaves the model's page
        // pool empty, and a scheduler dropped mid-flight releases the
        // pages its live lanes held.
        use crate::serve::model::LatentAttnLm;
        let latent = LatentAttnLm::synthetic(
            LmDims { vocab: 64, hidden: 32, glu: 48, layers: 2 }, 4, 1, 13);
        let lm = latent.build_float(3, 8);
        let mut sched = Scheduler::new(&lm, 3, 1);
        for id in 0..6 {
            sched.submit(GenRequest::greedy(id, vec![id as u32, 5], 3));
        }
        let done = sched.run();
        assert_eq!(done.len(), 6);
        assert_eq!(lm.kv_pages_in_use(), 0,
                   "drained scheduler must leave no pages in use");
        let mut sched = Scheduler::new(&lm, 3, 1);
        for id in 0..3 {
            sched.submit(GenRequest::greedy(id, vec![1, 2, 3], 5));
        }
        sched.step();
        assert!(lm.kv_pages_in_use() > 0, "live lanes must hold pages");
        drop(sched);
        assert_eq!(lm.kv_pages_in_use(), 0,
                   "dropped scheduler leaked kv pages");
    }

    #[test]
    fn stats_start_empty() {
        let lm = small_model();
        let sched = Scheduler::new(&lm, 2, 1);
        assert_eq!(sched.pending(), 0);
        assert_eq!(sched.stats().batch_steps, 0);
    }
}
