//! GPTQ post-training quantization (Frantar et al. 2022) — the algorithm
//! behind the paper's QuantLM family (§4.2).
//!
//! Pipeline: the Rust coordinator runs the FloatLM `capture` graph on
//! calibration batches, accumulates per-linear Hessians H = 2/n · XᵀX
//! here, and quantizes each weight matrix column-by-column with
//! second-order error compensation:
//!
//!   1. H ← H + λ·mean(diag H)·I                (percdamp damping)
//!   2. U = chol(H⁻¹)ᵀ (upper triangular)       (via Cholesky twice)
//!   3. for each column j (grouped by `group` input channels, symmetric
//!      absmax scales from the *current*, error-compensated weights):
//!        q_j   = quant(w_j)
//!        err_j = (w_j − q_j) / U[j,j]
//!        w_{j'} −= err_j · U[j, j'] for j' > j  (compensate later cols)
//!
//! Matches the paper's setup: symmetric quantization, group size 128,
//! calibration data from the training distribution.

pub mod pipeline;

pub use pipeline::{accumulate_hessians, quantize_model, QuantizedModel};

use crate::quant::QuantTensor;
use crate::runtime::HostTensor;
use crate::Result;

/// Accumulates the GPTQ Hessian for one linear layer.
#[derive(Debug, Clone)]
pub struct HessianAccumulator {
    pub dim: usize,
    pub n_samples: usize,
    /// Row-major dim x dim, f64 accumulation.
    pub h: Vec<f64>,
}

impl HessianAccumulator {
    pub fn new(dim: usize) -> Self {
        HessianAccumulator { dim, n_samples: 0, h: vec![0.0; dim * dim] }
    }

    /// Add a batch of input activations X (rows = samples, cols = dim).
    pub fn add_batch(&mut self, x: &HostTensor) {
        let (n, d) = x.dims2();
        assert_eq!(d, self.dim);
        for s in 0..n {
            let row = x.row(s);
            for i in 0..d {
                let xi = row[i] as f64;
                if xi == 0.0 {
                    continue;
                }
                let hrow = &mut self.h[i * d..(i + 1) * d];
                for j in 0..d {
                    hrow[j] += xi * row[j] as f64;
                }
            }
        }
        self.n_samples += n;
    }

    /// Finalized H = 2/n · XᵀX.
    pub fn finalize(&self) -> Vec<f64> {
        let scale = 2.0 / self.n_samples.max(1) as f64;
        self.h.iter().map(|v| v * scale).collect()
    }
}

/// Lower-triangular Cholesky: A = L·Lᵀ. Errors if A is not PD.
pub fn cholesky(a: &[f64], n: usize) -> Result<Vec<f64>> {
    let mut l = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = a[i * n + j];
            for k in 0..j {
                sum -= l[i * n + k] * l[j * n + k];
            }
            if i == j {
                if sum <= 0.0 {
                    anyhow::bail!("cholesky: not positive definite at {i} ({sum})");
                }
                l[i * n + i] = sum.sqrt();
            } else {
                l[i * n + j] = sum / l[j * n + j];
            }
        }
    }
    Ok(l)
}

/// Invert a symmetric PD matrix via its Cholesky factor.
fn pd_inverse(a: &[f64], n: usize) -> Result<Vec<f64>> {
    let l = cholesky(a, n)?;
    // Invert L (lower triangular) by forward substitution.
    let mut linv = vec![0.0f64; n * n];
    for i in 0..n {
        linv[i * n + i] = 1.0 / l[i * n + i];
        for j in 0..i {
            let mut sum = 0.0;
            for k in j..i {
                sum -= l[i * n + k] * linv[k * n + j];
            }
            linv[i * n + j] = sum / l[i * n + i];
        }
    }
    // A⁻¹ = L⁻ᵀ · L⁻¹.
    let mut inv = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            let mut sum = 0.0;
            for k in i..n {
                // (L⁻ᵀ)[i,k] = linv[k,i]
                sum += linv[k * n + i] * linv[k * n + j];
            }
            inv[i * n + j] = sum;
            inv[j * n + i] = sum;
        }
    }
    Ok(inv)
}

/// Upper-triangular factor U with H⁻¹ = Uᵀ·U... specifically GPTQ uses
/// the Cholesky of H⁻¹ in *upper* form: H⁻¹ = L'·L'ᵀ with U = L'ᵀ.
fn hinv_upper(h: &[f64], n: usize) -> Result<Vec<f64>> {
    let hinv = pd_inverse(h, n)?;
    let l = cholesky(&hinv, n)?;
    // U[i][j] = L[j][i] (upper triangular)
    let mut u = vec![0.0f64; n * n];
    for i in 0..n {
        for j in 0..=i {
            u[j * n + i] = l[i * n + j];
        }
    }
    Ok(u)
}

/// GPTQ configuration.
#[derive(Debug, Clone, Copy)]
pub struct GptqConfig {
    pub bits: u32,
    pub group: usize,
    /// Damping fraction of mean(diag H) (GPTQ's percdamp, default 0.01).
    pub percdamp: f64,
}

impl GptqConfig {
    pub fn new(bits: u32, group: usize) -> Self {
        GptqConfig { bits, group, percdamp: 0.01 }
    }
}

/// Quantize one weight matrix (rows = out, cols = in) given its Hessian.
pub fn gptq_quantize(w: &HostTensor, hessian: &[f64], cfg: GptqConfig)
                     -> Result<QuantTensor> {
    let (rows, cols) = w.dims2();
    assert_eq!(hessian.len(), cols * cols);
    let group = cfg.group;
    let qmax = QuantTensor::qmax(cfg.bits);

    // Damping: H += percdamp * mean(diag) * I; dead columns (H_jj = 0)
    // get diag 1 so the factorization stays PD.
    let mut h = hessian.to_vec();
    let mean_diag = (0..cols).map(|j| h[j * cols + j]).sum::<f64>()
        / cols as f64;
    let damp = (cfg.percdamp * mean_diag).max(1e-8);
    for j in 0..cols {
        if h[j * cols + j] <= 0.0 {
            h[j * cols + j] = 1.0;
        }
        h[j * cols + j] += damp;
    }
    let u = hinv_upper(&h, cols)?;

    // Working copy of weights; error-compensated in place. Groups are
    // ragged: the final group of a row is short when group ∤ cols (the
    // caller-visible group size is recorded verbatim, see quant/).
    let mut work: Vec<f64> = w.data.iter().map(|&x| x as f64).collect();
    let ng = QuantTensor::n_groups(cols, group);
    let mut q = vec![0i8; rows * cols];
    let mut scales = vec![0.0f32; rows * ng];

    for g in 0..ng {
        let (c0, c1) = (g * group, ((g + 1) * group).min(cols));
        // Group scales from the *current* (compensated) weights.
        for r in 0..rows {
            let absmax = (c0..c1).fold(0.0f64, |a, c| a.max(work[r * cols + c].abs()));
            scales[r * ng + g] = ((absmax / qmax as f64).max(1e-5)) as f32;
        }
        for j in c0..c1 {
            let ujj = u[j * cols + j];
            for r in 0..rows {
                let scale = scales[r * ng + g] as f64;
                let wj = work[r * cols + j];
                let qv = (wj / scale).round().clamp(-qmax as f64, qmax as f64);
                q[r * cols + j] = qv as i8;
                let err = (wj - qv * scale) / ujj;
                // Compensate all later columns in this row.
                let urow = &u[j * cols..(j + 1) * cols];
                let wrow = &mut work[r * cols..(r + 1) * cols];
                for j2 in (j + 1)..cols {
                    wrow[j2] -= err * urow[j2];
                }
            }
        }
    }

    Ok(QuantTensor { rows, cols, bits: cfg.bits, group, q, scales })
}

/// Layer-output squared error ‖(W − Ŵ)·Xᵀ‖² proxy: tr((W−Ŵ) H (W−Ŵ)ᵀ).
/// This is the objective GPTQ minimizes — used by tests and benches to
/// verify GPTQ beats round-to-nearest.
pub fn hessian_weighted_error(w: &HostTensor, q: &QuantTensor, h: &[f64]) -> f64 {
    let (rows, cols) = w.dims2();
    let dq = q.dequant();
    let mut total = 0.0;
    for r in 0..rows {
        let diff: Vec<f64> = (0..cols)
            .map(|c| (w.at2(r, c) - dq.at2(r, c)) as f64)
            .collect();
        for i in 0..cols {
            if diff[i] == 0.0 {
                continue;
            }
            let hrow = &h[i * cols..(i + 1) * cols];
            let mut acc = 0.0;
            for j in 0..cols {
                acc += hrow[j] * diff[j];
            }
            total += diff[i] * acc;
        }
    }
    total
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::runtime::SplitMix64;

    fn correlated_inputs(n: usize, d: usize, seed: u64) -> HostTensor {
        // Inputs with strong cross-channel correlation — the regime where
        // GPTQ's compensation matters.
        let mut rng = SplitMix64::new(seed);
        let mut data = Vec::with_capacity(n * d);
        for _ in 0..n {
            let base = rng.next_gaussian();
            for j in 0..d {
                let x = 0.7 * base + 0.3 * rng.next_gaussian()
                    + if j % 7 == 0 { 0.5 * base } else { 0.0 };
                data.push(x as f32);
            }
        }
        HostTensor::new(vec![n, d], data)
    }

    #[test]
    fn cholesky_reconstructs() {
        let a = vec![4.0, 2.0, 2.0, 3.0]; // PD 2x2
        let l = cholesky(&a, 2).unwrap();
        let rec = [
            l[0] * l[0], l[0] * l[2],
            l[2] * l[0], l[2] * l[2] + l[3] * l[3],
        ];
        for (x, y) in rec.iter().zip(a.iter()) {
            assert!((x - y).abs() < 1e-12);
        }
    }

    #[test]
    fn cholesky_rejects_indefinite() {
        let a = vec![1.0, 2.0, 2.0, 1.0]; // eigenvalues 3, -1
        assert!(cholesky(&a, 2).is_err());
    }

    #[test]
    fn pd_inverse_is_inverse() {
        let a = vec![4.0, 1.0, 0.5, 1.0, 3.0, 0.2, 0.5, 0.2, 2.0];
        let inv = pd_inverse(&a, 3).unwrap();
        for i in 0..3 {
            for j in 0..3 {
                let mut s = 0.0;
                for k in 0..3 {
                    s += a[i * 3 + k] * inv[k * 3 + j];
                }
                let want = if i == j { 1.0 } else { 0.0 };
                assert!((s - want).abs() < 1e-9, "({i},{j}) = {s}");
            }
        }
    }

    #[test]
    fn hessian_accumulator_matches_manual() {
        let x = HostTensor::new(vec![2, 2], vec![1.0, 2.0, 3.0, 4.0]);
        let mut acc = HessianAccumulator::new(2);
        acc.add_batch(&x);
        let h = acc.finalize();
        // XᵀX = [[10, 14], [14, 20]]; H = 2/2 * that.
        assert_eq!(h, vec![10.0, 14.0, 14.0, 20.0]);
    }

    #[test]
    fn gptq_beats_rtn_on_correlated_data() {
        let d = 32;
        let w = HostTensor::randn(vec![16, d], 0.1, 5);
        let x = correlated_inputs(256, d, 6);
        let mut acc = HessianAccumulator::new(d);
        acc.add_batch(&x);
        let h = acc.finalize();

        let cfg = GptqConfig::new(3, 32);
        let gptq = gptq_quantize(&w, &h, cfg).unwrap();
        let rtn = QuantTensor::quantize_rtn(&w, 3, 32);

        let e_gptq = hessian_weighted_error(&w, &gptq, &h);
        let e_rtn = hessian_weighted_error(&w, &rtn, &h);
        assert!(e_gptq < e_rtn,
                "GPTQ {e_gptq} should beat RTN {e_rtn} on H-weighted error");
    }

    #[test]
    fn gptq_q_values_in_range() {
        let d = 16;
        let w = HostTensor::randn(vec![8, d], 0.1, 7);
        let x = correlated_inputs(64, d, 8);
        let mut acc = HessianAccumulator::new(d);
        acc.add_batch(&x);
        let q = gptq_quantize(&w, &acc.finalize(), GptqConfig::new(4, 16)).unwrap();
        let qmax = QuantTensor::qmax(4) as i8;
        assert!(q.q.iter().all(|&v| v.abs() <= qmax));
    }

    #[test]
    fn gptq_handles_ragged_groups() {
        // cols = 20, group 16: a 4-wide ragged final group, with the
        // caller-visible group recorded verbatim.
        let d = 20;
        let w = HostTensor::randn(vec![6, d], 0.1, 11);
        let x = correlated_inputs(96, d, 12);
        let mut acc = HessianAccumulator::new(d);
        acc.add_batch(&x);
        let q = gptq_quantize(&w, &acc.finalize(),
                              GptqConfig::new(4, 16)).unwrap();
        assert_eq!(q.group, 16);
        assert_eq!(q.scales.len(), 6 * 2);
        let qmax = QuantTensor::qmax(4) as i8;
        assert!(q.q.iter().all(|&v| v.abs() <= qmax));
        assert!(q.mse(&w).is_finite());
    }

    #[test]
    fn gptq_higher_bits_lower_mse() {
        let d = 16;
        let w = HostTensor::randn(vec![8, d], 0.1, 9);
        let x = correlated_inputs(64, d, 10);
        let mut acc = HessianAccumulator::new(d);
        acc.add_batch(&x);
        let h = acc.finalize();
        let m3 = gptq_quantize(&w, &h, GptqConfig::new(3, 16)).unwrap().mse(&w);
        let m8 = gptq_quantize(&w, &h, GptqConfig::new(8, 16)).unwrap().mse(&w);
        assert!(m8 < m3);
    }
}
