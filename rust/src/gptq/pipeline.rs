//! QuantLM construction pipeline (§4.2): FloatLM checkpoint +
//! calibration data -> GPTQ-quantized model.
//!
//! Runs the AOT-compiled `capture` graph over calibration batches to
//! collect the input activations of every linear layer, accumulates the
//! per-layer Hessians, GPTQ-quantizes each weight matrix, and returns
//! params with the quantized weights substituted (dequantized f32 — the
//! paper's QuantLMs also compute in halfprec; storage-bits accounting
//! lives in deploy::bits).

use std::collections::HashMap;

use crate::gptq::{gptq_quantize, GptqConfig, HessianAccumulator};
use crate::quant::QuantTensor;
use crate::runtime::{self, HostTensor, Runtime};
use crate::Result;

/// Capture points per transformer layer, in graph output order:
/// inputs of (q,k,v), (o), (gate,up), (down).
pub const CAPTURES_PER_LAYER: usize = 4;

/// Largest divisor of `dim` not exceeding `target`.
pub fn largest_divisor(dim: usize, target: usize) -> usize {
    let mut d = target.min(dim).max(1);
    while dim % d != 0 {
        d -= 1;
    }
    d
}

/// Which linear weights each capture point feeds.
pub fn capture_targets(layer: usize, point: usize) -> Vec<String> {
    let names: &[&str] = match point {
        0 => &["attn_q", "attn_k", "attn_v"],
        1 => &["attn_o"],
        2 => &["mlp_gate", "mlp_up"],
        3 => &["mlp_down"],
        _ => panic!("bad capture point {point}"),
    };
    names.iter().map(|n| format!("l{layer}.{n}")).collect()
}

/// Accumulate per-capture-point Hessians over calibration batches.
///
/// `batches`: each is capture_batch * seq i32 tokens.
pub fn accumulate_hessians(rt: &Runtime, model: &str,
                           params: &[xla::Literal],
                           batches: &[Vec<i32>])
                           -> Result<Vec<HessianAccumulator>> {
    let entry = rt.manifest().model(model)?;
    let graph = rt.load_graph(model, "capture")?;
    let layers = entry.config.layers;
    let b = rt.manifest().capture_batch;
    let s = rt.manifest().seq;

    let mut accs: Vec<HessianAccumulator> = (0..layers * CAPTURES_PER_LAYER)
        .map(|i| {
            let dim = if i % CAPTURES_PER_LAYER == 3 {
                entry.config.glu
            } else {
                entry.config.hidden
            };
            HessianAccumulator::new(dim)
        })
        .collect();

    for batch in batches {
        assert_eq!(batch.len(), b * s, "capture batch must be {b}x{s}");
        let toks = runtime::literal_i32(&[b, s], batch)?;
        let mut args: Vec<&xla::Literal> = params.iter().collect();
        args.push(&toks);
        let outs = graph.run(&args)?;
        for (i, lit) in outs.iter().enumerate() {
            let x = runtime::tensor_from_literal(lit)?;
            accs[i].add_batch(&x);
        }
    }
    Ok(accs)
}

/// Result of quantizing one model at one bitwidth.
pub struct QuantizedModel {
    /// Parameters with dequantized (f32) GPTQ weights substituted.
    pub params: Vec<HostTensor>,
    /// The raw quantized linears by name (storage format / accounting).
    pub quantized: HashMap<String, QuantTensor>,
    pub bits: u32,
    pub group: usize,
}

/// Apply GPTQ at `bits` to every linear layer of a FloatLM.
pub fn quantize_model(rt: &Runtime, model: &str, params: &[HostTensor],
                      hessians: &[HessianAccumulator], bits: u32,
                      group: usize) -> Result<QuantizedModel> {
    let entry = rt.manifest().model(model)?;
    let layers = entry.config.layers;
    assert_eq!(hessians.len(), layers * CAPTURES_PER_LAYER);

    let name_index: HashMap<&str, usize> = entry.params.iter().enumerate()
        .map(|(i, p)| (p.name.as_str(), i))
        .collect();

    let mut out = params.to_vec();
    let mut quantized = HashMap::new();
    for l in 0..layers {
        for point in 0..CAPTURES_PER_LAYER {
            let h = hessians[l * CAPTURES_PER_LAYER + point].finalize();
            for target in capture_targets(l, point) {
                let idx = *name_index.get(target.as_str())
                    .ok_or_else(|| anyhow::anyhow!("missing param {target}"))?;
                let w = &params[idx];
                // group must divide in_features; shrink to the largest
                // divisor for layers narrower than the target group.
                let g = largest_divisor(w.shape[1], group);
                let cfg = GptqConfig::new(bits, g);
                let qt = gptq_quantize(w, &h, cfg)?;
                out[idx] = qt.dequant();
                quantized.insert(target, qt);
            }
        }
    }
    Ok(QuantizedModel { params: out, quantized, bits, group })
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn capture_targets_cover_all_linears() {
        let mut all: Vec<String> = Vec::new();
        for point in 0..CAPTURES_PER_LAYER {
            all.extend(capture_targets(0, point));
        }
        all.sort();
        let mut want: Vec<String> =
            ["attn_q", "attn_k", "attn_v", "attn_o",
             "mlp_gate", "mlp_up", "mlp_down"]
                .iter().map(|n| format!("l0.{n}")).collect();
        want.sort();
        assert_eq!(all, want);
    }
}
