//! Deterministic token batcher.
//!
//! The paper's suite property "Uniform Training" (§4.1) — identical data
//! sequences and ordering across model families — is reproduced here:
//! the batcher chunks one tokenized corpus into fixed (batch, seq+1)
//! blocks whose order is a seeded permutation, so every family at every
//! size consumes byte-identical batches (loss spikes line up across
//! scales, paper §4.3).

use crate::runtime::SplitMix64;

/// Iterator over (batch, seq+1) i32 token blocks.
pub struct Batcher {
    tokens: Vec<i32>,
    pub batch: usize,
    pub seq: usize,
    order: Vec<usize>,
    cursor: usize,
    epoch: usize,
    seed: u64,
}

impl Batcher {
    pub fn new(tokens: Vec<u32>, batch: usize, seq: usize, seed: u64) -> Self {
        let tokens: Vec<i32> = tokens.into_iter().map(|t| t as i32).collect();
        let n_chunks = tokens.len() / (seq + 1);
        assert!(n_chunks >= batch,
                "corpus too small: {} tokens for batch={batch} seq={seq}",
                tokens.len());
        let order = SplitMix64::new(seed).permutation(n_chunks);
        Batcher { tokens, batch, seq, order, cursor: 0, epoch: 0, seed }
    }

    pub fn n_chunks(&self) -> usize {
        self.order.len()
    }

    /// Batches per epoch.
    pub fn batches_per_epoch(&self) -> usize {
        self.n_chunks() / self.batch
    }

    pub fn epoch(&self) -> usize {
        self.epoch
    }

    /// Next (batch * (seq+1)) token block, row-major; reshuffles at epoch
    /// boundaries with a per-epoch derived seed.
    pub fn next_batch(&mut self) -> Vec<i32> {
        let stride = self.seq + 1;
        let mut out = Vec::with_capacity(self.batch * stride);
        for _ in 0..self.batch {
            if self.cursor >= self.order.len() {
                self.epoch += 1;
                self.order = SplitMix64::new(self.seed ^ (self.epoch as u64))
                    .permutation(self.order.len());
                self.cursor = 0;
            }
            let chunk = self.order[self.cursor];
            self.cursor += 1;
            out.extend_from_slice(&self.tokens[chunk * stride..(chunk + 1) * stride]);
        }
        out
    }

    /// Deterministic restart (used to replay identical data across
    /// families, and to build eval sets from a held-out tail).
    pub fn reset(&mut self) {
        self.order = SplitMix64::new(self.seed).permutation(self.order.len());
        self.cursor = 0;
        self.epoch = 0;
    }
}

/// Split tokens into train/validation parts (validation = final tail).
pub fn train_val_split(tokens: Vec<u32>, val_fraction: f64) -> (Vec<u32>, Vec<u32>) {
    let val_len = ((tokens.len() as f64) * val_fraction) as usize;
    let cut = tokens.len() - val_len;
    let val = tokens[cut..].to_vec();
    let mut train = tokens;
    train.truncate(cut);
    (train, val)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn toks(n: usize) -> Vec<u32> {
        (0..n as u32).collect()
    }

    #[test]
    fn batches_are_deterministic_across_instances() {
        let mut a = Batcher::new(toks(10_000), 4, 16, 1);
        let mut b = Batcher::new(toks(10_000), 4, 16, 1);
        for _ in 0..20 {
            assert_eq!(a.next_batch(), b.next_batch());
        }
    }

    #[test]
    fn batch_has_expected_shape_and_values() {
        let mut b = Batcher::new(toks(1000), 2, 8, 0);
        let batch = b.next_batch();
        assert_eq!(batch.len(), 2 * 9);
        for &t in &batch {
            assert!((0..1000).contains(&t));
        }
    }

    #[test]
    fn epochs_reshuffle() {
        let mut b = Batcher::new(toks(200), 2, 9, 5); // 20 chunks, 10 batches
        let first_epoch: Vec<Vec<i32>> = (0..10).map(|_| b.next_batch()).collect();
        let second_epoch: Vec<Vec<i32>> = (0..10).map(|_| b.next_batch()).collect();
        assert_eq!(b.epoch(), 1);
        assert_ne!(first_epoch, second_epoch, "epoch order should reshuffle");
        // but the multiset of tokens is identical
        let mut f: Vec<i32> = first_epoch.concat();
        let mut s: Vec<i32> = second_epoch.concat();
        f.sort_unstable();
        s.sort_unstable();
        assert_eq!(f, s);
    }

    #[test]
    fn reset_replays() {
        let mut b = Batcher::new(toks(1000), 2, 8, 3);
        let x1 = b.next_batch();
        b.next_batch();
        b.reset();
        assert_eq!(b.next_batch(), x1);
    }

    #[test]
    fn split_is_disjoint_tail() {
        let (train, val) = train_val_split(toks(100), 0.1);
        assert_eq!(train.len(), 90);
        assert_eq!(val, (90..100).collect::<Vec<_>>());
    }
}
