//! Synthetic training corpus — the stand-in for the paper's 300B-token
//! SlimPajama subset (§A.2, Table 2).
//!
//! The generator produces a deterministic, seeded mixture of "domains"
//! mirroring SlimPajama's subset structure (web / wikipedia-like /
//! book-like / code), built from:
//!
//! - a stochastic grammar over a Zipfian content vocabulary (so token
//!   statistics are natural-language-like and the LM has syntax to learn),
//! - a world of entity–relation *facts* rendered through templates (the
//!   learnable "knowledge" probed by the SciQ/TriviaQA-analog tasks),
//! - fixed *implication patterns* ("if it rains , the ground gets wet")
//!   that play the role of commonsense regularities (ARC/PIQA analogs),
//! - narrative collocations whose final word is predictable from long
//!   context (the LAMBADA-analog cloze signal).
//!
//! Domains share the grammar but differ in mixture weights and noise, so
//! in-domain vs out-of-domain perplexity comparisons (paper Fig. 13) are
//! meaningful.


use crate::runtime::SplitMix64;

/// One entity–relation–value fact, e.g. capital(Valdoria) = Merenthal.
#[derive(Debug, Clone)]
pub struct Fact {
    pub relation: usize,
    pub entity: String,
    pub value: String,
}

/// An antecedent->consequent pattern pair, e.g. "rains" -> "wet ground".
#[derive(Debug, Clone)]
pub struct Pattern {
    pub cause: String,
    pub effect: String,
}

/// The fixed synthetic "world" every corpus and benchmark draws from.
#[derive(Debug, Clone)]
pub struct World {
    pub entities: Vec<String>,
    pub values: Vec<String>,
    pub facts: Vec<Fact>,
    pub patterns: Vec<Pattern>,
    pub content_words: Vec<String>,
    /// attributes[i] = the attribute the corpus statistically associates
    /// with entity i (the CrowS-Pairs-analog "stereotype" signal): the
    /// corpus asserts it with probability ATTR_BIAS, the opposite
    /// otherwise, so models absorb a measurable association bias.
    pub attributes: Vec<usize>,
}

/// The two attribute words used by the bias probe.
pub const ATTRIBUTES: [&str; 2] = ["brave", "quiet"];

/// P(corpus asserts the biased attribute) vs the counter-attribute.
pub const ATTR_BIAS: f64 = 0.9;

pub const RELATIONS: [(&str, &str); 4] = [
    ("the capital of", "is"),
    ("the element discovered in", "is called"),
    ("the river that crosses", "is"),
    ("the founder of", "was"),
];

const ONSETS: [&str; 12] = ["b", "br", "d", "dr", "f", "gr", "k", "m", "n",
                            "p", "st", "v"];
const NUCLEI: [&str; 6] = ["a", "e", "i", "o", "u", "ai"];
const CODAS: [&str; 8] = ["l", "n", "r", "rn", "s", "th", "x", "nd"];

fn make_word(rng: &mut SplitMix64, syllables: usize) -> String {
    let mut w = String::new();
    for _ in 0..syllables {
        w.push_str(ONSETS[rng.below(ONSETS.len())]);
        w.push_str(NUCLEI[rng.below(NUCLEI.len())]);
        if rng.next_f64() < 0.5 {
            w.push_str(CODAS[rng.below(CODAS.len())]);
        }
    }
    w
}

impl World {
    /// Build the deterministic world used across training and evaluation.
    pub fn new(seed: u64) -> Self {
        let mut rng = SplitMix64::new(seed);
        let entities: Vec<String> = (0..48)
            .map(|_| {
                let syl = 2 + rng.below(2);
                let mut w = make_word(&mut rng, syl);
                // Capitalize: proper nouns are distinct token shapes.
                w[..1].make_ascii_uppercase();
                w
            })
            .collect();
        let values: Vec<String> = (0..48)
            .map(|_| {
                let syl = 2 + rng.below(2);
                let mut w = make_word(&mut rng, syl);
                w[..1].make_ascii_uppercase();
                w
            })
            .collect();
        // One fact per (relation, entity): value drawn uniquely per pair.
        let mut facts = Vec::new();
        for relation in 0..RELATIONS.len() {
            for entity in &entities {
                facts.push(Fact {
                    relation,
                    entity: entity.clone(),
                    value: values[rng.below(values.len())].clone(),
                });
            }
        }
        let causes = ["it rains", "the sun sets", "the wind rises",
                      "the fire burns", "the ice melts", "the bell rings",
                      "the door opens", "the seed grows"];
        let effects = ["the ground gets wet", "the sky turns dark",
                       "the leaves start to move", "the room becomes warm",
                       "the water level rises", "the people look up",
                       "the cold air comes in", "a small plant appears"];
        let patterns = causes.iter().zip(effects.iter())
            .map(|(c, e)| Pattern { cause: c.to_string(), effect: e.to_string() })
            .collect();
        let content_words = (0..400).map(|_| {
            let syl = 1 + rng.below(3);
            make_word(&mut rng, syl)
        }).collect();
        let attributes = (0..entities.len()).map(|_| rng.below(2)).collect();
        World { entities, values, facts, patterns, content_words, attributes }
    }

    pub fn fact(&self, relation: usize, entity: &str) -> Option<&Fact> {
        self.facts.iter().find(|f| f.relation == relation && f.entity == entity)
    }
}

/// Corpus domains (SlimPajama-subset analogs, Table 2 / Fig. 13).
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Domain {
    /// CommonCrawl/C4-like: grammar sentences + facts + noise.
    Web,
    /// Wikipedia-like: fact-dense, clean.
    Wiki,
    /// Book-like: long narrative collocations (cloze signal).
    Book,
    /// GitHub-like: toy code lines.
    Code,
}

impl Domain {
    pub const ALL: [Domain; 4] = [Domain::Web, Domain::Wiki, Domain::Book,
                                  Domain::Code];

    pub fn as_str(self) -> &'static str {
        match self {
            Domain::Web => "web",
            Domain::Wiki => "wiki",
            Domain::Book => "book",
            Domain::Code => "code",
        }
    }
}

/// Seeded text generator over a [`World`].
pub struct Generator<'w> {
    pub world: &'w World,
    rng: SplitMix64,
    /// Zipf weights over content words.
    zipf: Vec<f64>,
}

impl<'w> Generator<'w> {
    pub fn new(world: &'w World, seed: u64) -> Self {
        let zipf = (0..world.content_words.len())
            .map(|i| 1.0 / (i as f64 + 1.0))
            .collect();
        Generator { world, rng: SplitMix64::new(seed), zipf }
    }

    fn content(&mut self) -> &'w str {
        let i = self.rng.weighted(&self.zipf);
        &self.world.content_words[i]
    }

    /// A grammar sentence: det N V det N (P det N)? .
    fn grammar_sentence(&mut self) -> String {
        let dets = ["the", "a", "some", "this"];
        let preps = ["near", "under", "over", "behind", "inside"];
        let mut s = String::new();
        s.push_str(dets[self.rng.below(dets.len())]);
        s.push(' ');
        s.push_str(self.content());
        s.push(' ');
        s.push_str(self.content());
        s.push_str("s ");
        s.push_str(dets[self.rng.below(dets.len())]);
        s.push(' ');
        s.push_str(self.content());
        if self.rng.next_f64() < 0.4 {
            s.push(' ');
            s.push_str(preps[self.rng.below(preps.len())]);
            s.push_str(" the ");
            s.push_str(self.content());
        }
        s.push_str(" . ");
        s
    }

    /// Render one fact through its relation template.
    fn fact_sentence(&mut self) -> String {
        let f = &self.world.facts[self.rng.below(self.world.facts.len())];
        let (pre, mid) = RELATIONS[f.relation];
        format!("{pre} {} {mid} {} . ", f.entity, f.value)
    }

    fn pattern_sentence(&mut self) -> String {
        let p = &self.world.patterns[self.rng.below(self.world.patterns.len())];
        match self.rng.below(3) {
            0 => format!("if {} , then {} . ", p.cause, p.effect),
            1 => format!("when {} , {} . ", p.cause, p.effect),
            _ => format!("{} and so {} . ", p.cause, p.effect),
        }
    }

    /// Narrative with a long-range predictable final word: the opening
    /// names a character; the closing sentence repeats it (LAMBADA-like).
    fn narrative(&mut self) -> String {
        let hero = &self.world.entities[self.rng.below(self.world.entities.len())];
        let mut s = format!("one day {hero} walked to the old bridge . ");
        for _ in 0..2 + self.rng.below(3) {
            s.push_str(&self.grammar_sentence());
        }
        s.push_str(&format!("at the end of the long road stood {hero} . "));
        s
    }

    /// Biased attribute assertion (the stereotype signal).
    fn attribute_sentence(&mut self) -> String {
        let i = self.rng.below(self.world.entities.len());
        let biased = self.world.attributes[i];
        let attr = if self.rng.next_f64() < ATTR_BIAS {
            ATTRIBUTES[biased]
        } else {
            ATTRIBUTES[1 - biased]
        };
        format!("everyone says that {} is very {attr} . ",
                self.world.entities[i])
    }

    fn code_line(&mut self) -> String {
        let names = ["count", "total", "index", "value", "sum", "size"];
        let a = names[self.rng.below(names.len())];
        let b = names[self.rng.below(names.len())];
        match self.rng.below(3) {
            0 => format!("let {a} = {b} + {} ; ", self.rng.below(100)),
            1 => format!("if {a} > {} then {b} = 0 ; ", self.rng.below(10)),
            _ => format!("for {a} in 0 .. {} do {b} = {b} + {a} ; ",
                         self.rng.below(32)),
        }
    }

    /// Generate about `target_chars` of text from one domain.
    pub fn domain_text(&mut self, domain: Domain, target_chars: usize) -> String {
        let mut out = String::with_capacity(target_chars + 128);
        while out.len() < target_chars {
            let piece = match domain {
                Domain::Web => match self.rng.below(10) {
                    0..=3 => self.grammar_sentence(),
                    4..=5 => self.fact_sentence(),
                    6..=7 => self.pattern_sentence(),
                    8 => self.attribute_sentence(),
                    _ => self.narrative(),
                },
                Domain::Wiki => match self.rng.below(10) {
                    0..=6 => self.fact_sentence(),
                    _ => self.grammar_sentence(),
                },
                Domain::Book => match self.rng.below(10) {
                    0..=5 => self.narrative(),
                    6..=7 => self.pattern_sentence(),
                    _ => self.grammar_sentence(),
                },
                Domain::Code => self.code_line(),
            };
            out.push_str(&piece);
        }
        out
    }

    /// The training mixture (weights ~ Table 2's subset proportions:
    /// web-heavy, then wiki/book/code).
    pub fn training_text(&mut self, target_chars: usize) -> String {
        let weights = [(Domain::Web, 0.55), (Domain::Wiki, 0.20),
                       (Domain::Book, 0.15), (Domain::Code, 0.10)];
        let mut out = String::with_capacity(target_chars + 128);
        while out.len() < target_chars {
            let w: Vec<f64> = weights.iter().map(|&(_, p)| p).collect();
            let d = weights[self.rng.weighted(&w)].0;
            // Interleave domains in chunks, like shuffled corpus shards.
            out.push_str(&self.domain_text(d, 512));
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn world_is_deterministic() {
        let a = World::new(1);
        let b = World::new(1);
        assert_eq!(a.facts.len(), b.facts.len());
        assert_eq!(a.facts[0].value, b.facts[0].value);
        assert_eq!(a.entities, b.entities);
    }

    #[test]
    fn facts_cover_all_relation_entity_pairs() {
        let w = World::new(1);
        assert_eq!(w.facts.len(), RELATIONS.len() * w.entities.len());
        for f in &w.facts {
            assert!(w.fact(f.relation, &f.entity).is_some());
        }
    }

    #[test]
    fn generator_is_deterministic() {
        let w = World::new(1);
        let a = Generator::new(&w, 7).training_text(5000);
        let b = Generator::new(&w, 7).training_text(5000);
        assert_eq!(a, b);
    }

    #[test]
    fn different_seeds_differ() {
        let w = World::new(1);
        let a = Generator::new(&w, 7).training_text(2000);
        let b = Generator::new(&w, 8).training_text(2000);
        assert_ne!(a, b);
    }

    #[test]
    fn domains_have_distinct_statistics() {
        let w = World::new(1);
        let mut g = Generator::new(&w, 3);
        let code = g.domain_text(Domain::Code, 4000);
        let wiki = g.domain_text(Domain::Wiki, 4000);
        assert!(code.matches(';').count() > 50);
        assert_eq!(wiki.matches(';').count(), 0);
        // wiki is fact-dense: relation templates appear often
        assert!(wiki.matches(" is ").count() + wiki.matches(" was ").count() > 20);
    }

    #[test]
    fn training_text_contains_facts_and_patterns() {
        let w = World::new(1);
        let text = Generator::new(&w, 5).training_text(60_000);
        assert!(text.contains("the capital of"));
        assert!(text.contains("if it rains"));
        assert!(text.contains("one day"));
    }

    #[test]
    fn narratives_repeat_the_hero() {
        let w = World::new(1);
        let mut g = Generator::new(&w, 9);
        let n = g.narrative();
        let hero = n.split_whitespace().nth(2).unwrap();
        assert!(n.trim_end_matches(" . ").trim_end().ends_with(hero),
                "{n}");
    }
}
