//! Data substrate: synthetic corpus, BPE tokenizer, deterministic batcher.
//!
//! Together these reproduce the paper's data pipeline properties (§4.1):
//! every model family trains on identical token sequences in identical
//! order, and held-out per-domain corpora support the Fig. 13
//! cross-corpus perplexity study.

pub mod batcher;
pub mod bpe;
pub mod corpus;

pub use batcher::{train_val_split, Batcher};
pub use bpe::Bpe;
pub use corpus::{Domain, Fact, Generator, Pattern, World, ATTRIBUTES,
                 ATTR_BIAS, RELATIONS};

use std::path::Path;

use crate::Result;

/// Everything the coordinator needs from the data layer, built once and
/// cached on disk (`<run_dir>/data/`): the world, the tokenizer and the
/// tokenized train/val splits.
pub struct Dataset {
    pub world: World,
    pub bpe: Bpe,
    pub train: Vec<u32>,
    pub val: Vec<u32>,
}

impl Dataset {
    /// Build (or reload) the standard dataset: `chars` characters of the
    /// training mixture, vocab-512 BPE, 2% held-out validation tail.
    pub fn build(cache_dir: &Path, chars: usize, seed: u64) -> Result<Self> {
        std::fs::create_dir_all(cache_dir)?;
        let bpe_path = cache_dir.join("bpe.txt");
        let toks_path = cache_dir.join(format!("tokens_{chars}_{seed}.bin"));

        let world = World::new(seed);
        let mut gen = Generator::new(&world, seed.wrapping_add(1));

        let (bpe, all) = if bpe_path.exists() && toks_path.exists() {
            let bpe = Bpe::load(&bpe_path)?;
            let bytes = std::fs::read(&toks_path)?;
            let all: Vec<u32> = bytes.chunks_exact(4)
                .map(|b| u32::from_le_bytes([b[0], b[1], b[2], b[3]]))
                .collect();
            (bpe, all)
        } else {
            let text = gen.training_text(chars);
            // Train BPE on a prefix: enough to learn the corpus' merges.
            let sample_len = text.len().min(250_000);
            let bpe = Bpe::train(&text[..sample_len], 512);
            let all = bpe.encode(&text);
            bpe.save(&bpe_path)?;
            let mut bytes = Vec::with_capacity(all.len() * 4);
            for &t in &all {
                bytes.extend_from_slice(&t.to_le_bytes());
            }
            std::fs::write(&toks_path, bytes)?;
            (bpe, all)
        };

        let (train, val) = train_val_split(all, 0.02);
        Ok(Dataset { world, bpe, train, val })
    }

    /// Tokenize a fresh sample of one domain (Fig. 13 eval corpora).
    pub fn domain_tokens(&self, domain: Domain, chars: usize, seed: u64) -> Vec<u32> {
        let mut gen = Generator::new(&self.world, seed);
        self.bpe.encode(&gen.domain_text(domain, chars))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dataset_build_and_cache_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let d1 = Dataset::build(dir.path(), 60_000, 1).unwrap();
        assert!(d1.train.len() > 5_000, "train too small: {}", d1.train.len());
        assert!(d1.val.len() > 100);
        // Second build must reload the cache and produce identical tokens.
        let d2 = Dataset::build(dir.path(), 60_000, 1).unwrap();
        assert_eq!(d1.train, d2.train);
        assert_eq!(d1.val, d2.val);
    }

    #[test]
    fn domain_tokens_are_in_vocab() {
        let dir = crate::util::testutil::TempDir::new();
        let d = Dataset::build(dir.path(), 60_000, 1).unwrap();
        for dom in Domain::ALL {
            let toks = d.domain_tokens(dom, 2_000, 9);
            assert!(!toks.is_empty());
            assert!(toks.iter().all(|&t| (t as usize) < d.bpe.vocab_size()));
        }
    }
}
