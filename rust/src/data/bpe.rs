//! Byte-level BPE tokenizer (trainer + encoder + decoder).
//!
//! Stands in for the paper's GPT-NeoX 20B tokenizer (§A.2): the suite
//! needs a real subword tokenizer so that corpus token statistics,
//! perplexities, and the benchmark harness exercise the same code paths
//! as the paper's pipeline. Vocab defaults to 512 (256 bytes + 256
//! learned merges), matching the model configs.

use std::collections::HashMap;


/// A trained byte-level BPE model.
#[derive(Debug, Clone)]
pub struct Bpe {
    /// merges[i] = (left, right) token ids merged into id 256 + i.
    pub merges: Vec<(u32, u32)>,
    /// vocab[id] = byte sequence for that token.
    pub vocab: Vec<Vec<u8>>,
}

impl Bpe {
    pub fn vocab_size(&self) -> usize {
        self.vocab.len()
    }

    /// Train on `text` until `vocab_size` tokens exist.
    ///
    /// Classic BPE over whitespace-delimited words (spaces are attached
    /// to the following word, GPT-2 style, so decoding is lossless).
    pub fn train(text: &str, vocab_size: usize) -> Self {
        assert!(vocab_size >= 256, "vocab must cover all bytes");
        // word -> count, each word as a token-id sequence.
        let mut words: HashMap<Vec<u32>, usize> = HashMap::new();
        for w in split_words(text) {
            *words.entry(w.bytes().map(|b| b as u32).collect()).or_insert(0) += 1;
        }
        let mut vocab: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = Vec::new();

        while vocab.len() < vocab_size {
            // Count adjacent pairs.
            let mut pairs: HashMap<(u32, u32), usize> = HashMap::new();
            for (word, count) in &words {
                for pair in word.windows(2) {
                    *pairs.entry((pair[0], pair[1])).or_insert(0) += count;
                }
            }
            // Deterministic tie-break: highest count, then lowest ids.
            let Some((&best, _)) = pairs.iter().max_by_key(|(&(a, b), &c)| {
                (c, std::cmp::Reverse((a, b)))
            }) else {
                break;
            };
            if pairs[&best] < 2 {
                break; // nothing worth merging
            }
            let new_id = vocab.len() as u32;
            let mut merged_bytes = vocab[best.0 as usize].clone();
            merged_bytes.extend_from_slice(&vocab[best.1 as usize]);
            vocab.push(merged_bytes);
            merges.push(best);
            // Apply the merge to every word.
            words = words.into_iter().map(|(word, count)| {
                (apply_merge(&word, best, new_id), count)
            }).collect();
        }
        Bpe { merges, vocab }
    }

    /// Encode text to token ids.
    pub fn encode(&self, text: &str) -> Vec<u32> {
        let ranks: HashMap<(u32, u32), u32> = self.merges.iter().enumerate()
            .map(|(i, &p)| (p, 256 + i as u32)).collect();
        let mut out = Vec::with_capacity(text.len() / 3);
        let mut cache: HashMap<&str, Vec<u32>> = HashMap::new();
        for w in split_words(text) {
            if let Some(toks) = cache.get(w) {
                out.extend_from_slice(toks);
                continue;
            }
            let toks = self.encode_word(w, &ranks);
            out.extend_from_slice(&toks);
            cache.insert(w, toks);
        }
        out
    }

    fn encode_word(&self, word: &str, ranks: &HashMap<(u32, u32), u32>) -> Vec<u32> {
        let mut toks: Vec<u32> = word.bytes().map(|b| b as u32).collect();
        loop {
            // Lowest-rank (earliest-learned) applicable merge first.
            let mut best: Option<(u32, usize)> = None;
            for (i, pair) in toks.windows(2).enumerate() {
                if let Some(&id) = ranks.get(&(pair[0], pair[1])) {
                    if best.map_or(true, |(b, _)| id < b) {
                        best = Some((id, i));
                    }
                }
            }
            let Some((id, i)) = best else { break };
            toks.splice(i..i + 2, [id]);
        }
        toks
    }

    /// Decode token ids back to text (lossless for valid UTF-8 input).
    pub fn decode(&self, tokens: &[u32]) -> String {
        let mut bytes = Vec::new();
        for &t in tokens {
            bytes.extend_from_slice(&self.vocab[t as usize]);
        }
        String::from_utf8_lossy(&bytes).into_owned()
    }

    /// Save as a merge list: one `left right` pair per line (the vocab
    /// is fully determined by the merges, so that is all we store).
    pub fn save(&self, path: &std::path::Path) -> crate::Result<()> {
        let mut out = String::from("spectra-bpe-v1\n");
        for &(a, b) in &self.merges {
            out.push_str(&format!("{a} {b}\n"));
        }
        std::fs::write(path, out)?;
        Ok(())
    }

    pub fn load(path: &std::path::Path) -> crate::Result<Self> {
        let text = std::fs::read_to_string(path)?;
        let mut lines = text.lines();
        if lines.next() != Some("spectra-bpe-v1") {
            anyhow::bail!("{} is not a spectra BPE file", path.display());
        }
        let mut vocab: Vec<Vec<u8>> = (0..=255u8).map(|b| vec![b]).collect();
        let mut merges = Vec::new();
        for line in lines {
            let mut it = line.split_whitespace();
            let (Some(a), Some(b)) = (it.next(), it.next()) else {
                anyhow::bail!("bad merge line: {line}");
            };
            let (a, b): (u32, u32) = (a.parse()?, b.parse()?);
            let mut bytes = vocab[a as usize].clone();
            bytes.extend_from_slice(&vocab[b as usize]);
            vocab.push(bytes);
            merges.push((a, b));
        }
        Ok(Bpe { merges, vocab })
    }
}

/// Split into words with leading whitespace attached (GPT-2 style).
fn split_words(text: &str) -> impl Iterator<Item = &str> {
    let bytes = text.as_bytes();
    let mut starts = vec![0usize];
    for i in 1..bytes.len() {
        // start a new word at every space->nonspace boundary
        if bytes[i] != b' ' && bytes[i - 1] == b' ' && i >= 1 {
            // attach exactly one leading space to the word
            starts.push(i - 1);
        }
    }
    starts.push(bytes.len());
    starts.windows(2).map(|w| &text[w[0]..w[1]]).collect::<Vec<_>>().into_iter()
}

fn apply_merge(word: &[u32], pair: (u32, u32), new_id: u32) -> Vec<u32> {
    let mut out = Vec::with_capacity(word.len());
    let mut i = 0;
    while i < word.len() {
        if i + 1 < word.len() && word[i] == pair.0 && word[i + 1] == pair.1 {
            out.push(new_id);
            i += 2;
        } else {
            out.push(word[i]);
            i += 1;
        }
    }
    out
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = "the cat sat on the mat . the cat ran to the cat \
                          house and the mat stayed on the floor . ";

    #[test]
    fn train_learns_merges() {
        let bpe = Bpe::train(SAMPLE, 300);
        assert!(bpe.vocab_size() > 256, "no merges learned");
        assert!(bpe.vocab_size() <= 300);
    }

    #[test]
    fn encode_decode_roundtrip() {
        let bpe = Bpe::train(SAMPLE, 320);
        for text in [SAMPLE, "the cat", "unseen words zyx !", "a", ""] {
            assert_eq!(bpe.decode(&bpe.encode(text)), text);
        }
    }

    #[test]
    fn compression_beats_bytes() {
        let bpe = Bpe::train(SAMPLE, 400);
        let toks = bpe.encode(SAMPLE);
        assert!(toks.len() < SAMPLE.len(), "{} !< {}", toks.len(), SAMPLE.len());
    }

    #[test]
    fn training_is_deterministic() {
        let a = Bpe::train(SAMPLE, 300);
        let b = Bpe::train(SAMPLE, 300);
        assert_eq!(a.merges, b.merges);
    }

    #[test]
    fn all_ids_below_vocab() {
        let bpe = Bpe::train(SAMPLE, 512);
        for t in bpe.encode("completely novel text 123 !@#") {
            assert!((t as usize) < bpe.vocab_size());
        }
    }

    #[test]
    fn save_load_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let path = dir.path().join("bpe.txt");
        let bpe = Bpe::train(SAMPLE, 300);
        bpe.save(&path).unwrap();
        let loaded = Bpe::load(&path).unwrap();
        assert_eq!(loaded.merges, bpe.merges);
        assert_eq!(loaded.encode(SAMPLE), bpe.encode(SAMPLE));
    }
}
