//! Dynamic loss scaling — the FP16 mixed-precision mechanism whose
//! artifacts Table 5 documents (min loss-scale, skipped batches).
//!
//! The train graph multiplies the loss by the current scale before
//! backprop and reports whether all (fp16-round-tripped) gradients were
//! finite; this state machine owns the scale: halve + skip on overflow,
//! double after a window of clean steps. Defaults follow the paper's
//! cited recipe (Micikevicius et al. 2018) with the recommended floor
//! of 128 referenced in §A.5.

/// Dynamic loss-scale controller.
#[derive(Debug, Clone)]
pub struct DynamicLossScale {
    pub scale: f32,
    pub growth_interval: usize,
    pub max_scale: f32,
    pub min_scale: f32,
    good_steps: usize,
    /// Lowest scale ever reached (Table 5 "Min. Loss-Scale").
    pub min_seen: f32,
    /// Batches skipped due to overflow (Table 5 "# Skipped Batches").
    pub skipped: usize,
}

impl Default for DynamicLossScale {
    fn default() -> Self {
        DynamicLossScale::new(65_536.0)
    }
}

impl DynamicLossScale {
    pub fn new(initial: f32) -> Self {
        DynamicLossScale {
            scale: initial,
            growth_interval: 200,
            max_scale: 65_536.0,
            min_scale: 1.0,
            good_steps: 0,
            min_seen: initial,
            skipped: 0,
        }
    }

    /// Record a step outcome; returns the scale for the *next* step.
    pub fn update(&mut self, grads_finite: bool) -> f32 {
        if grads_finite {
            self.good_steps += 1;
            if self.good_steps >= self.growth_interval {
                self.scale = (self.scale * 2.0).min(self.max_scale);
                self.good_steps = 0;
            }
        } else {
            self.skipped += 1;
            self.scale = (self.scale / 2.0).max(self.min_scale);
            self.good_steps = 0;
        }
        self.min_seen = self.min_seen.min(self.scale);
        self.scale
    }

    /// Whether the run stayed at or above the recommended floor of 128
    /// (the §A.5 health check for FP16 training).
    pub fn above_recommended_floor(&self) -> bool {
        self.min_seen >= 128.0
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn overflow_halves_and_counts() {
        let mut ls = DynamicLossScale::new(1024.0);
        ls.update(false);
        assert_eq!(ls.scale, 512.0);
        assert_eq!(ls.skipped, 1);
        ls.update(false);
        assert_eq!(ls.scale, 256.0);
        assert_eq!(ls.min_seen, 256.0);
    }

    #[test]
    fn growth_after_interval() {
        let mut ls = DynamicLossScale::new(256.0);
        ls.growth_interval = 3;
        ls.update(true);
        ls.update(true);
        assert_eq!(ls.scale, 256.0);
        ls.update(true);
        assert_eq!(ls.scale, 512.0);
    }

    #[test]
    fn overflow_resets_growth_window() {
        let mut ls = DynamicLossScale::new(256.0);
        ls.growth_interval = 2;
        ls.update(true);
        ls.update(false); // resets window, halves
        ls.update(true);
        assert_eq!(ls.scale, 128.0, "growth window must restart");
    }

    #[test]
    fn respects_bounds() {
        let mut ls = DynamicLossScale::new(2.0);
        for _ in 0..10 {
            ls.update(false);
        }
        assert_eq!(ls.scale, ls.min_scale);
        let mut ls = DynamicLossScale::new(65_536.0);
        ls.growth_interval = 1;
        for _ in 0..5 {
            ls.update(true);
        }
        assert_eq!(ls.scale, ls.max_scale);
    }

    #[test]
    fn floor_check_tracks_min_seen() {
        let mut ls = DynamicLossScale::new(1024.0);
        assert!(ls.above_recommended_floor());
        for _ in 0..4 {
            ls.update(false);
        }
        assert_eq!(ls.min_seen, 64.0);
        assert!(!ls.above_recommended_floor());
    }
}
