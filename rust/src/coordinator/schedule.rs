//! The Spectra optimization schedule (§3.2, Fig. 6).
//!
//! TriLM/BiLM/BitNet: linear decay with warmup plus two interventions —
//!   (1) *Peak LR*: at the halfway point the peak learning rate drops
//!       (Table 3's "2.4e-3 -> 1.5e-3" arrows);
//!   (2) *L2 Reg.*: at the two-thirds point weight decay is removed
//!       (ternarization provides sufficient regularization).
//! FloatLM: cosine decay with warmup and constant weight decay (§A.4).

use crate::config::TrainConfig;

/// Learning rate at `step` (0-based) for the configured schedule.
pub fn learning_rate(cfg: &TrainConfig, step: usize) -> f32 {
    let s = step as f32;
    let total = cfg.steps as f32;
    let warmup = cfg.warmup_steps as f32;
    if s < warmup {
        return cfg.peak_lr * (s + 1.0) / warmup;
    }
    let progress = ((s - warmup) / (total - warmup).max(1.0)).min(1.0);
    if cfg.cosine {
        // Cosine to 10% of peak (Pythia/OLMo-style floor).
        let min_lr = 0.1 * cfg.peak_lr;
        return min_lr
            + 0.5 * (cfg.peak_lr - min_lr)
                * (1.0 + (std::f32::consts::PI * progress).cos());
    }
    // Linear decay to zero; after the halfway intervention the schedule
    // is re-anchored at the lower peak (same decay endpoint).
    let peak = if cfg.drop_peak_lr && s >= total / 2.0 {
        cfg.post_drop_lr
    } else {
        cfg.peak_lr
    };
    peak * (1.0 - progress)
}

/// Weight decay at `step`: removed at the 2/3 mark when configured.
pub fn weight_decay(cfg: &TrainConfig, step: usize) -> f32 {
    if cfg.drop_weight_decay && (step as f32) >= (cfg.steps as f32) * 2.0 / 3.0 {
        0.0
    } else {
        cfg.weight_decay
    }
}

/// The four Fig. 6 ablation variants of the TriLM schedule.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ScheduleVariant {
    /// Both interventions (the TriLM default).
    Both,
    /// Only the halfway peak-LR drop.
    OnlyPeakLrDrop,
    /// Only the two-thirds weight-decay removal.
    OnlyWdRemoval,
    /// Vanilla linear decay with constant weight decay.
    Baseline,
}

impl ScheduleVariant {
    pub const ALL: [ScheduleVariant; 4] = [
        ScheduleVariant::Both,
        ScheduleVariant::OnlyPeakLrDrop,
        ScheduleVariant::OnlyWdRemoval,
        ScheduleVariant::Baseline,
    ];

    pub fn as_str(self) -> &'static str {
        match self {
            ScheduleVariant::Both => "both",
            ScheduleVariant::OnlyPeakLrDrop => "only_peak_lr",
            ScheduleVariant::OnlyWdRemoval => "only_l2_removal",
            ScheduleVariant::Baseline => "baseline",
        }
    }

    pub fn apply(self, mut cfg: TrainConfig) -> TrainConfig {
        let (drop_lr, drop_wd) = match self {
            ScheduleVariant::Both => (true, true),
            ScheduleVariant::OnlyPeakLrDrop => (true, false),
            ScheduleVariant::OnlyWdRemoval => (false, true),
            ScheduleVariant::Baseline => (false, false),
        };
        cfg.drop_peak_lr = drop_lr;
        cfg.drop_weight_decay = drop_wd;
        cfg
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::Family;

    fn trilm(steps: usize) -> TrainConfig {
        TrainConfig::for_family(Family::Ternary, steps)
    }

    fn floatlm(steps: usize) -> TrainConfig {
        TrainConfig::for_family(Family::Float, steps)
    }

    #[test]
    fn warmup_ramps_to_peak() {
        let cfg = trilm(1000);
        assert!(learning_rate(&cfg, 0) < cfg.peak_lr / 2.0);
        let at_warmup = learning_rate(&cfg, cfg.warmup_steps);
        assert!((at_warmup - cfg.peak_lr).abs() / cfg.peak_lr < 0.05);
    }

    #[test]
    fn peak_lr_drops_at_halfway() {
        let cfg = trilm(1000);
        let before = learning_rate(&cfg, 499);
        let after = learning_rate(&cfg, 500);
        assert!(after < before, "{after} !< {before}");
        // The drop ratio mirrors post_drop/peak.
        let ratio = after / before;
        let want = cfg.post_drop_lr / cfg.peak_lr;
        assert!((ratio - want).abs() < 0.05, "{ratio} vs {want}");
    }

    #[test]
    fn no_drop_without_intervention() {
        let cfg = ScheduleVariant::Baseline.apply(trilm(1000));
        let before = learning_rate(&cfg, 499);
        let after = learning_rate(&cfg, 500);
        assert!(after <= before && before - after < 0.01 * cfg.peak_lr);
    }

    #[test]
    fn weight_decay_removed_at_two_thirds() {
        let cfg = trilm(900);
        assert_eq!(weight_decay(&cfg, 599), cfg.weight_decay);
        assert_eq!(weight_decay(&cfg, 600), 0.0);
    }

    #[test]
    fn floatlm_cosine_keeps_wd_and_never_drops() {
        let cfg = floatlm(1000);
        assert_eq!(weight_decay(&cfg, 999), cfg.weight_decay);
        // Cosine is smooth through the halfway point.
        let d = learning_rate(&cfg, 499) - learning_rate(&cfg, 501);
        assert!(d.abs() < 1e-5 * 1000.0);
        // Ends at the 10% floor.
        let end = learning_rate(&cfg, 1000);
        assert!((end - 0.1 * cfg.peak_lr).abs() < 0.02 * cfg.peak_lr);
    }

    #[test]
    fn linear_decay_reaches_zero() {
        let cfg = ScheduleVariant::Baseline.apply(trilm(1000));
        assert!(learning_rate(&cfg, 1000) < 1e-6);
    }

    #[test]
    fn variants_differ_only_in_flags() {
        let base = trilm(100);
        let v = ScheduleVariant::OnlyWdRemoval.apply(base.clone());
        assert!(!v.drop_peak_lr && v.drop_weight_decay);
        assert_eq!(v.peak_lr, base.peak_lr);
    }
}
