//! The Spectra suite runner (§4): trains the size x family grid on
//! identical data, builds QuantLMs from the trained FloatLMs, and
//! evaluates everything — the engine behind Figs. 1, 8, 9, 11, 12 and
//! Tables 6/7/9/12-analogs.

use std::path::{Path, PathBuf};


use crate::analysis;
use crate::checkpoint::Checkpoint;
use crate::config::{suite_config, Family, TrainConfig};
use crate::coordinator::trainer::Trainer;
use crate::data::{Batcher, Dataset, Domain};
use crate::deploy::{model_size_bits, SizeFamily};
use crate::eval::{self, Evaluator, TaskKind, TaskScore};
use crate::gptq;
use crate::runtime::{self, HostTensor, Runtime};
use crate::util::Json;
use crate::Result;

/// What to run.
#[derive(Debug, Clone)]
pub struct SuiteSpec {
    pub sizes: Vec<String>,
    pub families: Vec<Family>,
    pub steps: usize,
    /// GPTQ bitwidths applied to each trained FloatLM.
    pub quant_bits: Vec<u32>,
    pub eval_items: usize,
    pub calib_batches: usize,
    pub seed: u64,
}

impl Default for SuiteSpec {
    fn default() -> Self {
        SuiteSpec {
            sizes: vec!["160k".into(), "430k".into(), "930k".into()],
            families: vec![Family::Float, Family::Ternary],
            steps: 300,
            quant_bits: vec![3, 4, 8],
            eval_items: 50,
            calib_batches: 4,
            seed: 0,
        }
    }
}

/// One evaluated model (trained family or derived QuantLM).
#[derive(Debug, Clone)]
pub struct ModelRecord {
    pub name: String,
    pub size: String,
    /// "float", "ternary", "binary", "bitnet", or "quant3"/"quant4"/...
    pub family: String,
    pub n_params: usize,
    pub size_bits: f64,
    pub final_train_loss: f32,
    pub val_nll: f64,
    /// Per-domain val NLL (Fig. 13 analog).
    pub domain_nll: Vec<(String, f64)>,
    pub tasks: Vec<TaskScore>,
}

/// Suite output: all records + where artifacts were written.
#[derive(Debug, Clone)]
pub struct SuiteResults {
    pub records: Vec<ModelRecord>,
    pub run_dir: String,
}

impl ModelRecord {
    fn to_json(&self) -> Json {
        Json::obj(vec![
            ("name", Json::str(self.name.clone())),
            ("size", Json::str(self.size.clone())),
            ("family", Json::str(self.family.clone())),
            ("n_params", Json::num(self.n_params as f64)),
            ("size_bits", Json::num(self.size_bits)),
            ("final_train_loss", Json::num(self.final_train_loss as f64)),
            ("val_nll", Json::num(self.val_nll)),
            ("domain_nll", Json::arr(self.domain_nll.iter().map(|(d, v)| {
                Json::arr([Json::str(d.clone()), Json::num(*v)])
            }))),
            ("tasks", Json::arr(self.tasks.iter().map(|t| {
                Json::obj(vec![
                    ("task", Json::str(t.task.clone())),
                    ("n", Json::num(t.n as f64)),
                    ("acc", Json::num(t.acc)),
                    ("acc_norm", Json::num(t.acc_norm)),
                    ("stderr", Json::num(t.stderr)),
                ])
            }))),
        ])
    }

    fn from_json(j: &Json) -> Result<Self> {
        Ok(ModelRecord {
            name: j.get("name")?.as_str()?.to_string(),
            size: j.get("size")?.as_str()?.to_string(),
            family: j.get("family")?.as_str()?.to_string(),
            n_params: j.get("n_params")?.as_usize()?,
            size_bits: j.get("size_bits")?.as_f64()?,
            final_train_loss: j.get("final_train_loss")?.as_f64()? as f32,
            val_nll: j.get("val_nll")?.as_f64()?,
            domain_nll: j.get("domain_nll")?.as_arr()?.iter().map(|p| {
                let pair = p.as_arr()?;
                Ok((pair[0].as_str()?.to_string(), pair[1].as_f64()?))
            }).collect::<Result<Vec<_>>>()?,
            tasks: j.get("tasks")?.as_arr()?.iter().map(|t| {
                Ok(TaskScore {
                    task: t.get("task")?.as_str()?.to_string(),
                    n: t.get("n")?.as_usize()?,
                    acc: t.get("acc")?.as_f64()?,
                    acc_norm: t.get("acc_norm")?.as_f64()?,
                    stderr: t.get("stderr")?.as_f64()?,
                })
            }).collect::<Result<Vec<_>>>()?,
        })
    }
}

impl SuiteResults {
    pub fn save(&self, path: &Path) -> Result<()> {
        let j = Json::obj(vec![
            ("run_dir", Json::str(self.run_dir.clone())),
            ("records", Json::arr(self.records.iter()
                .map(|r| r.to_json()))),
        ]);
        std::fs::write(path, j.to_string())?;
        Ok(())
    }

    pub fn load(path: &Path) -> Result<Self> {
        let j = Json::parse(&std::fs::read_to_string(path)?)?;
        Ok(SuiteResults {
            run_dir: j.get("run_dir")?.as_str()?.to_string(),
            records: j.get("records")?.as_arr()?.iter()
                .map(ModelRecord::from_json)
                .collect::<Result<Vec<_>>>()?,
        })
    }

    /// (params, val_nll) points for one family — scaling-fit input.
    pub fn family_points(&self, family: &str) -> Vec<(f64, f64)> {
        self.records.iter()
            .filter(|r| r.family == family)
            .map(|r| (r.n_params as f64, r.val_nll))
            .collect()
    }
}

/// Evaluate a parameter set: val nll, per-domain nll, all tasks.
#[allow(clippy::too_many_arguments)]
fn evaluate_model(rt: &Runtime, model: &str, params: &[HostTensor],
                  data: &Dataset, spec: &SuiteSpec, name: &str,
                  family_label: &str, size: &str,
                  final_train_loss: f32, bits_family: SizeFamily)
                  -> Result<ModelRecord> {
    let ev = Evaluator::new(rt, model)?;
    let lits: Vec<xla::Literal> = params.iter()
        .map(runtime::literal_from_tensor)
        .collect::<Result<_>>()?;
    let val_nll = ev.nll(&lits, &data.val)?;

    let mut domain_nll = Vec::new();
    for dom in Domain::ALL {
        let toks = data.domain_tokens(dom, 40_000, spec.seed ^ 0xD0);
        domain_nll.push((dom.as_str().to_string(), ev.nll(&lits, &toks)?));
    }

    let mut tasks = Vec::new();
    for kind in TaskKind::ALL {
        let n = if kind == TaskKind::FactRecall {
            spec.eval_items / 2 // 48-way items are slow; half count
        } else {
            spec.eval_items
        };
        let items = eval::generate(&data.world, kind, n, spec.seed ^ 0xE0);
        tasks.push(eval::run_task(&ev, &lits, &data.bpe, kind, &items)?);
    }

    let cfg = suite_config(size, Family::Float).unwrap();
    Ok(ModelRecord {
        name: name.to_string(),
        size: size.to_string(),
        family: family_label.to_string(),
        n_params: cfg.n_params(),
        size_bits: model_size_bits(&cfg, bits_family),
        final_train_loss,
        val_nll,
        domain_nll,
        tasks,
    })
}

/// Train + evaluate the whole grid. Writes checkpoints, loss CSVs and
/// `suite_results.json` under `run_dir`.
pub fn run_suite(rt: &Runtime, data: &Dataset, spec: &SuiteSpec,
                 run_dir: &Path) -> Result<SuiteResults> {
    std::fs::create_dir_all(run_dir)?;
    let mut records = Vec::new();

    for size in &spec.sizes {
        for &family in &spec.families {
            let model = format!("{size}_{}", family.as_str());
            if rt.manifest().models.get(&model).is_none() {
                // paper scope: binary/bitnet exist only at select sizes
                continue;
            }
            let ckpt_path = run_dir.join(format!("{model}.spt"));
            // Resume support: a completed checkpoint in the run dir is
            // reused instead of retraining (incremental suite runs).
            let (params, final_loss) = if ckpt_path.exists() {
                eprintln!("[suite] reusing checkpoint for {model}");
                let ck = Checkpoint::load(&ckpt_path)?;
                let loss: f32 = ck.metadata.get("final_loss")
                    .and_then(|v| v.parse().ok()).unwrap_or(f32::NAN);
                (ck.tensor_list(), loss)
            } else {
                eprintln!("[suite] training {model} ({} steps)", spec.steps);
                let cfg = TrainConfig {
                    seed: spec.seed,
                    ..TrainConfig::for_family(family, spec.steps)
                };
                let mut trainer = Trainer::new(rt, &model, cfg)?;
                // Identical data order across families: seed fixed per size.
                let mut batcher = Batcher::new(data.train.clone(),
                                               rt.manifest().train_batch,
                                               rt.manifest().seq, spec.seed);
                let mut last_print = std::time::Instant::now();
                trainer.train(&mut batcher, spec.steps, |m| {
                    if last_print.elapsed().as_secs() >= 10 {
                        eprintln!("[suite] {model} step {} loss {:.4}",
                                  m.step, m.loss);
                        last_print = std::time::Instant::now();
                    }
                })?;
                trainer.log.write_csv(&run_dir.join(format!("{model}_loss.csv")))?;
                trainer.save_checkpoint(rt, &model, &ckpt_path)?;
                (trainer.params()?, trainer.log.final_loss(20))
            };
            records.push(evaluate_model(
                rt, &model, &params, data, spec, &model,
                family.as_str(), size, final_loss,
                SizeFamily::from_family(family))?);

            // Incremental save: a crash or OOM never loses finished work.
            SuiteResults { records: records.clone(),
                           run_dir: run_dir.display().to_string() }
                .save(&run_dir.join("suite_results.json"))?;

            // QuantLM derivation from the trained FloatLM (§4.2).
            if family == Family::Float && !spec.quant_bits.is_empty() {
                let calib = calibration_batches(rt, data, spec);
                let lits: Vec<xla::Literal> = params.iter()
                    .map(runtime::literal_from_tensor)
                    .collect::<Result<_>>()?;
                let hessians =
                    gptq::accumulate_hessians(rt, &model, &lits, &calib)?;
                for &bits in &spec.quant_bits {
                    eprintln!("[suite] GPTQ {model} -> {bits}-bit");
                    let qm = gptq::quantize_model(rt, &model, &params,
                                                  &hessians, bits, 128)?;
                    let label = format!("quant{bits}");
                    records.push(evaluate_model(
                        rt, &model, &qm.params, data, spec,
                        &format!("{size}_{label}"), &label, size, final_loss,
                        SizeFamily::Quant { bits, group: 128 })?);
                    SuiteResults { records: records.clone(),
                                   run_dir: run_dir.display().to_string() }
                        .save(&run_dir.join("suite_results.json"))?;
                }
            }
        }
    }

    let results = SuiteResults {
        records,
        run_dir: run_dir.display().to_string(),
    };
    results.save(&run_dir.join("suite_results.json"))?;
    Ok(results)
}

/// Calibration batches drawn deterministically from the training stream
/// (the paper uses training-distribution calibration data).
pub fn calibration_batches(rt: &Runtime, data: &Dataset, spec: &SuiteSpec)
                           -> Vec<Vec<i32>> {
    let b = rt.manifest().capture_batch;
    let s = rt.manifest().seq;
    let mut batcher = Batcher::new(data.train.clone(), b, s - 1,
                                   spec.seed ^ 0xCA11B);
    (0..spec.calib_batches).map(|_| {
        // batcher yields b*(s) tokens with seq = s-1; capture wants b*s.
        batcher.next_batch()
    }).collect()
}

/// Fit the Fig. 9/10 scaling laws from suite results.
pub fn scaling_from_results(results: &SuiteResults)
                            -> Option<analysis::ScalingReport> {
    let trilm = results.family_points("ternary");
    let floatlm = results.family_points("float");
    if trilm.len() >= 3 && floatlm.len() >= 3 {
        Some(analysis::scaling_report(&trilm, &floatlm))
    } else {
        None
    }
}

/// Run directory convention: `runs/<tag>/`.
pub fn run_dir(tag: &str) -> PathBuf {
    PathBuf::from("runs").join(tag)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_spec_is_sane() {
        let s = SuiteSpec::default();
        assert!(s.sizes.len() >= 3);
        assert!(s.families.contains(&Family::Ternary));
    }

    #[test]
    fn results_roundtrip() {
        let dir = crate::util::testutil::TempDir::new();
        let r = SuiteResults {
            records: vec![ModelRecord {
                name: "160k_float".into(), size: "160k".into(),
                family: "float".into(), n_params: 160064,
                size_bits: 2.5e6, final_train_loss: 3.0, val_nll: 3.1,
                domain_nll: vec![("web".into(), 3.0)],
                tasks: vec![],
            }],
            run_dir: "runs/test".into(),
        };
        let path = dir.path().join("r.json");
        r.save(&path).unwrap();
        let back = SuiteResults::load(&path).unwrap();
        assert_eq!(back.records.len(), 1);
        assert_eq!(back.family_points("float"), vec![(160064.0, 3.1)]);
    }
}
