//! The training orchestrator: drives the AOT-compiled train-step graph
//! from the request path with zero Python.
//!
//! One `Trainer` owns the compiled graph, the model state (params +
//! Adam moments as device-ready literals), the LR/WD schedule, and the
//! dynamic loss-scale state machine. The main loop is: pull a batch from
//! the deterministic batcher, assemble the flat argument list per the
//! manifest calling convention, execute, thread the returned state into
//! the next step, and log metrics.

use std::path::Path;

use crate::checkpoint::Checkpoint;
use crate::config::TrainConfig;
use crate::coordinator::loss_scale::DynamicLossScale;
use crate::coordinator::schedule;
use crate::data::Batcher;
use crate::runtime::{self, Graph, HostTensor, Runtime, TrainState};
use crate::Result;

/// Per-step metrics (one CSV row).
#[derive(Debug, Clone)]
pub struct StepMetrics {
    pub step: usize,
    pub loss: f32,
    pub grad_norm: f32,
    pub lr: f32,
    pub weight_decay: f32,
    pub loss_scale: f32,
    pub grads_finite: bool,
    pub tokens_seen: usize,
}

/// A whole run's metric log, CSV-serializable.
#[derive(Debug, Clone, Default)]
pub struct RunLog {
    pub rows: Vec<StepMetrics>,
}

impl RunLog {
    pub fn losses(&self) -> Vec<f32> {
        self.rows.iter().map(|r| r.loss).collect()
    }

    /// Mean loss over the final `n` steps (smoothed "final training loss").
    pub fn final_loss(&self, n: usize) -> f32 {
        let tail: Vec<f32> = self.rows.iter().rev().take(n)
            .filter(|r| r.grads_finite).map(|r| r.loss).collect();
        tail.iter().sum::<f32>() / tail.len().max(1) as f32
    }

    pub fn write_csv(&self, path: &Path) -> Result<()> {
        if let Some(parent) = path.parent() {
            std::fs::create_dir_all(parent)?;
        }
        let mut out = String::from(
            "step,loss,grad_norm,lr,weight_decay,loss_scale,grads_finite,tokens\n");
        for r in &self.rows {
            out.push_str(&format!("{},{},{},{},{},{},{},{}\n",
                r.step, r.loss, r.grad_norm, r.lr, r.weight_decay,
                r.loss_scale, r.grads_finite as u8, r.tokens_seen));
        }
        std::fs::write(path, out)?;
        Ok(())
    }
}

/// Trains one model on one dataset with the Spectra schedule.
pub struct Trainer {
    graph: Graph,
    state: TrainState,
    pub cfg: TrainConfig,
    pub loss_scale: DynamicLossScale,
    pub log: RunLog,
    n_params_arrays: usize,
    step: usize,
    batch_shape: (usize, usize),
}

impl Trainer {
    /// Compile the model's train graph and initialize fresh state.
    pub fn new(rt: &Runtime, model: &str, cfg: TrainConfig) -> Result<Self> {
        let entry = rt.manifest().model(model)?;
        let graph_name = if cfg.fp16 { "train_fp16" } else { "train" };
        let graph = rt.load_graph(model, graph_name)?;
        let params = runtime::init_params_like(entry, cfg.seed);
        let state = TrainState::init(&params)?;
        let batch_shape = (rt.manifest().train_batch, rt.manifest().seq + 1);
        let loss_scale = if cfg.fp16 {
            DynamicLossScale::default()
        } else {
            // f32 training: scale pinned at 1, never overflows.
            let mut ls = DynamicLossScale::new(1.0);
            ls.max_scale = 1.0;
            ls
        };
        Ok(Trainer {
            graph,
            state,
            cfg,
            loss_scale,
            log: RunLog::default(),
            n_params_arrays: entry.n_param_arrays(),
            step: 0,
            batch_shape,
        })
    }

    /// Restore parameters from a checkpoint (moments reset to zero).
    pub fn load_params(&mut self, ck: &Checkpoint) -> Result<()> {
        self.state = TrainState::init(&ck.tensor_list())?;
        Ok(())
    }

    pub fn step_count(&self) -> usize {
        self.step
    }

    /// Execute one train step on a (batch * (seq+1)) token block.
    pub fn step(&mut self, tokens: &[i32]) -> Result<StepMetrics> {
        let (b, s1) = self.batch_shape;
        assert_eq!(tokens.len(), b * s1, "bad batch shape");
        let lr = schedule::learning_rate(&self.cfg, self.step);
        let wd = schedule::weight_decay(&self.cfg, self.step);
        let scale = self.loss_scale.scale;

        let toks = runtime::literal_i32(&[b, s1], tokens)?;
        let lr_l = runtime::scalar_f32(lr);
        let wd_l = runtime::scalar_f32(wd);
        let scale_l = runtime::scalar_f32(scale);

        let p = self.n_params_arrays;
        let mut args: Vec<&xla::Literal> = Vec::with_capacity(3 * p + 5);
        args.extend(self.state.params.iter());
        args.extend(self.state.m.iter());
        args.extend(self.state.v.iter());
        args.push(&self.state.step);
        args.push(&toks);
        args.push(&lr_l);
        args.push(&wd_l);
        args.push(&scale_l);

        let mut outs = self.graph.run(&args)?;
        // Outputs: params(P), m(P), v(P), step, loss, gnorm, finite.
        let finite = runtime::scalar_from_literal(&outs[3 * p + 3])? > 0.5;
        let gnorm = runtime::scalar_from_literal(&outs[3 * p + 2])?;
        let loss = runtime::scalar_from_literal(&outs[3 * p + 1])?;
        outs.truncate(3 * p + 1);
        let step_lit = outs.pop().unwrap();
        let v = outs.split_off(2 * p);
        let m = outs.split_off(p);
        self.state = TrainState { params: outs, m, v, step: step_lit };

        self.loss_scale.update(finite);
        self.step += 1;
        let metrics = StepMetrics {
            step: self.step,
            loss,
            grad_norm: gnorm,
            lr,
            weight_decay: wd,
            loss_scale: scale,
            grads_finite: finite,
            tokens_seen: self.step * b * (s1 - 1),
        };
        self.log.rows.push(metrics.clone());
        Ok(metrics)
    }

    /// Run `n` steps against a batcher, optionally reporting progress.
    pub fn train(&mut self, batcher: &mut Batcher, n: usize,
                 mut progress: impl FnMut(&StepMetrics)) -> Result<()> {
        for _ in 0..n {
            let batch = batcher.next_batch();
            let m = self.step(&batch)?;
            progress(&m);
        }
        Ok(())
    }

    /// Snapshot current parameters to host.
    pub fn params(&self) -> Result<Vec<HostTensor>> {
        self.state.params_to_host()
    }

    /// Borrow the raw device-ready parameter literals (for evaluation
    /// without a host round-trip).
    pub fn param_literals(&self) -> &[xla::Literal] {
        &self.state.params
    }

    /// Save a checkpoint with run metadata.
    pub fn save_checkpoint(&self, rt: &Runtime, model: &str, path: &Path)
                           -> Result<()> {
        let entry = rt.manifest().model(model)?;
        let params = self.params()?;
        let tensors = entry.params.iter().zip(params)
            .map(|(spec, t)| (spec.name.clone(), t))
            .collect();
        Checkpoint::new(tensors)
            .with_meta("model", model)
            .with_meta("step", self.step)
            .with_meta("final_loss", self.log.final_loss(20))
            .save(path)
    }
}
