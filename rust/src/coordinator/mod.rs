//! The L3 coordination layer: training orchestration for the Spectra
//! suite — the Rust owner of the event loop, schedules, loss-scaling
//! state, checkpoints and the size x family grid. All compute runs
//! through AOT-compiled PJRT executables; Python is never invoked.

pub mod loss_scale;
pub mod schedule;
pub mod suite;
pub mod trainer;

pub use loss_scale::DynamicLossScale;
pub use schedule::{learning_rate, weight_decay, ScheduleVariant};
pub use suite::{run_suite, scaling_from_results, ModelRecord, SuiteResults,
                SuiteSpec};
pub use trainer::{RunLog, StepMetrics, Trainer};
