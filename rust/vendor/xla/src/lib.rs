//! Offline stand-in for the `xla` (xla_extension / PJRT) bindings.
//!
//! The real crate links the multi-GB xla_extension C++ runtime, which
//! this build environment does not ship. Everything spectra does on the
//! *host* side — building, reshaping and reading back [`Literal`]s —
//! is implemented for real here, so checkpoint I/O, batching, GPTQ and
//! the CPU ternary kernels all work. Only actual device execution
//! ([`PjRtLoadedExecutable::execute_b`]) is unavailable: it returns a
//! clear error. The integration tests and every `Runtime`-driven
//! command already skip / fail gracefully when `artifacts/` is absent,
//! and the serve/ subsystem runs decode entirely on the CPU kernels
//! without PJRT.

use std::fmt;
use std::path::Path;

/// Error type mirroring `xla::Error`'s role (Display + std::error).
#[derive(Debug, Clone)]
pub struct Error {
    pub msg: String,
}

impl Error {
    pub fn new(msg: impl Into<String>) -> Self {
        Error { msg: msg.into() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl std::error::Error for Error {}

pub type Result<T> = std::result::Result<T, Error>;

/// Element types a [`Literal`] can hold (the subset spectra uses).
pub trait NativeType: Copy + 'static {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal;
    fn unwrap_slice(lit: &Literal) -> Result<&[Self]>;
}

impl NativeType for f32 {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal::F32 { dims, data }
    }
    fn unwrap_slice(lit: &Literal) -> Result<&[Self]> {
        match lit {
            Literal::F32 { data, .. } => Ok(data),
            other => Err(Error::new(format!(
                "literal is not f32: {}", other.kind()))),
        }
    }
}

impl NativeType for i32 {
    fn wrap(dims: Vec<i64>, data: Vec<Self>) -> Literal {
        Literal::I32 { dims, data }
    }
    fn unwrap_slice(lit: &Literal) -> Result<&[Self]> {
        match lit {
            Literal::I32 { data, .. } => Ok(data),
            other => Err(Error::new(format!(
                "literal is not i32: {}", other.kind()))),
        }
    }
}

/// A host tensor value: shaped f32/i32 arrays or a tuple of literals.
#[derive(Debug, Clone, PartialEq)]
pub enum Literal {
    F32 { dims: Vec<i64>, data: Vec<f32> },
    I32 { dims: Vec<i64>, data: Vec<i32> },
    Tuple(Vec<Literal>),
}

/// Array shape (dims only; element type lives on the literal).
#[derive(Debug, Clone)]
pub struct ArrayShape {
    dims: Vec<i64>,
}

impl ArrayShape {
    pub fn dims(&self) -> &[i64] {
        &self.dims
    }
}

impl Literal {
    fn kind(&self) -> &'static str {
        match self {
            Literal::F32 { .. } => "f32",
            Literal::I32 { .. } => "i32",
            Literal::Tuple(_) => "tuple",
        }
    }

    /// Rank-1 literal from a slice.
    pub fn vec1<T: NativeType>(data: &[T]) -> Literal {
        T::wrap(vec![data.len() as i64], data.to_vec())
    }

    /// Rank-0 (scalar) literal.
    pub fn scalar<T: NativeType>(x: T) -> Literal {
        T::wrap(vec![], vec![x])
    }

    /// Same data, new dims (element count must match).
    pub fn reshape(&self, dims: &[i64]) -> Result<Literal> {
        let n: i64 = dims.iter().product();
        if n as usize != self.element_count() {
            return Err(Error::new(format!(
                "reshape {:?} -> {dims:?}: element count mismatch",
                self.element_count())));
        }
        Ok(match self {
            Literal::F32 { data, .. } =>
                Literal::F32 { dims: dims.to_vec(), data: data.clone() },
            Literal::I32 { data, .. } =>
                Literal::I32 { dims: dims.to_vec(), data: data.clone() },
            Literal::Tuple(_) =>
                return Err(Error::new("cannot reshape a tuple literal")),
        })
    }

    pub fn element_count(&self) -> usize {
        match self {
            Literal::F32 { data, .. } => data.len(),
            Literal::I32 { data, .. } => data.len(),
            Literal::Tuple(ts) => ts.iter().map(|t| t.element_count()).sum(),
        }
    }

    pub fn array_shape(&self) -> Result<ArrayShape> {
        match self {
            Literal::F32 { dims, .. } | Literal::I32 { dims, .. } =>
                Ok(ArrayShape { dims: dims.clone() }),
            Literal::Tuple(_) =>
                Err(Error::new("tuple literal has no array shape")),
        }
    }

    pub fn to_vec<T: NativeType>(&self) -> Result<Vec<T>> {
        T::unwrap_slice(self).map(|s| s.to_vec())
    }

    pub fn get_first_element<T: NativeType>(&self) -> Result<T> {
        let s = T::unwrap_slice(self)?;
        s.first().copied()
            .ok_or_else(|| Error::new("empty literal has no first element"))
    }

    /// Flatten a tuple literal into its members.
    pub fn to_tuple(self) -> Result<Vec<Literal>> {
        match self {
            Literal::Tuple(ts) => Ok(ts),
            other => Ok(vec![other]),
        }
    }
}

/// Parsed (well — retained) HLO module text.
#[derive(Debug, Clone)]
pub struct HloModuleProto {
    pub text: String,
}

impl HloModuleProto {
    pub fn from_text_file(path: impl AsRef<Path>) -> Result<Self> {
        let path = path.as_ref();
        let text = std::fs::read_to_string(path).map_err(|e| {
            Error::new(format!("reading {}: {e}", path.display()))
        })?;
        Ok(HloModuleProto { text })
    }
}

/// An XLA computation wrapping an HLO module.
#[derive(Debug, Clone)]
pub struct XlaComputation {
    _text: String,
}

impl XlaComputation {
    pub fn from_proto(proto: &HloModuleProto) -> Self {
        XlaComputation { _text: proto.text.clone() }
    }
}

/// PJRT client stand-in ("platform" is host-only).
#[derive(Debug, Clone)]
pub struct PjRtClient;

/// Device buffer stand-in: holds the staged host literal.
#[derive(Debug, Clone)]
pub struct PjRtBuffer {
    lit: Literal,
}

/// Compiled-executable stand-in. Execution is unavailable offline.
#[derive(Debug, Clone)]
pub struct PjRtLoadedExecutable;

const NO_BACKEND: &str =
    "PJRT execution is unavailable in this offline build: the vendored \
     xla stub only supports host literals. Graph-driven paths (train / \
     eval / capture) need the real xla_extension backend; the serve/ \
     subsystem and ternary CPU kernels run without it.";

impl PjRtClient {
    pub fn cpu() -> Result<Self> {
        Ok(PjRtClient)
    }

    pub fn platform_name(&self) -> String {
        "host-stub".to_string()
    }

    pub fn compile(&self, _comp: &XlaComputation) -> Result<PjRtLoadedExecutable> {
        Ok(PjRtLoadedExecutable)
    }

    pub fn buffer_from_host_literal(&self, _device: Option<usize>,
                                    lit: &Literal) -> Result<PjRtBuffer> {
        Ok(PjRtBuffer { lit: lit.clone() })
    }
}

impl PjRtBuffer {
    pub fn to_literal_sync(&self) -> Result<Literal> {
        Ok(self.lit.clone())
    }
}

impl PjRtLoadedExecutable {
    pub fn execute_b<T: std::borrow::Borrow<PjRtBuffer>>(
        &self, _args: &[T]) -> Result<Vec<Vec<PjRtBuffer>>> {
        Err(Error::new(NO_BACKEND))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn literal_roundtrip_and_reshape() {
        let l = Literal::vec1(&[1.0f32, 2.0, 3.0, 4.0]);
        assert_eq!(l.element_count(), 4);
        let r = l.reshape(&[2, 2]).unwrap();
        assert_eq!(r.array_shape().unwrap().dims(), &[2, 2]);
        assert_eq!(r.to_vec::<f32>().unwrap(), vec![1.0, 2.0, 3.0, 4.0]);
        assert!(l.reshape(&[3, 2]).is_err());
    }

    #[test]
    fn scalar_and_i32() {
        let s = Literal::scalar(2.5f32);
        assert_eq!(s.get_first_element::<f32>().unwrap(), 2.5);
        let i = Literal::vec1(&[7i32, 8]);
        assert_eq!(i.to_vec::<i32>().unwrap(), vec![7, 8]);
        assert!(i.to_vec::<f32>().is_err());
    }

    #[test]
    fn execution_is_gated_with_clear_error() {
        let client = PjRtClient::cpu().unwrap();
        let comp = XlaComputation::from_proto(
            &HloModuleProto { text: "HloModule m".into() });
        let exe = client.compile(&comp).unwrap();
        let buf = client
            .buffer_from_host_literal(None, &Literal::scalar(1.0f32))
            .unwrap();
        let err = exe.execute_b(&[buf]).unwrap_err();
        assert!(err.to_string().contains("offline"));
    }
}
