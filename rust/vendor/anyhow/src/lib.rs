//! Offline stand-in for the `anyhow` crate.
//!
//! The build environment has no crates.io access, so this vendored
//! shim provides exactly the subset spectra uses: [`Error`],
//! [`Result`], [`anyhow!`] and [`bail!`]. Like real anyhow, `Error`
//! deliberately does NOT implement `std::error::Error` — that is what
//! makes the blanket `From<E: std::error::Error>` impl coherent, so
//! `?` converts any std error into an [`Error`].

use std::fmt;

/// A type-erased error: a message plus (optionally) the source error's
/// rendered chain. Construction is either [`Error::msg`] (the
/// [`anyhow!`] macro) or the blanket `From` impl used by `?`.
pub struct Error {
    msg: String,
}

impl Error {
    pub fn msg(msg: impl fmt::Display) -> Self {
        Error { msg: msg.to_string() }
    }
}

impl fmt::Display for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        f.write_str(&self.msg)
    }
}

impl fmt::Debug for Error {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        // `main() -> anyhow::Result<()>` prints the Debug form on Err;
        // show the plain message like real anyhow does.
        f.write_str(&self.msg)
    }
}

// Coherent because `Error` itself does not (and, by the orphan rule,
// never can downstream) implement `std::error::Error`.
impl<E: std::error::Error + Send + Sync + 'static> From<E> for Error {
    fn from(e: E) -> Self {
        Error { msg: e.to_string() }
    }
}

/// `anyhow::Result<T>` — crate-wide shorthand.
pub type Result<T, E = Error> = std::result::Result<T, E>;

/// Construct an [`Error`] from a format string.
#[macro_export]
macro_rules! anyhow {
    ($msg:literal $(,)?) => {
        $crate::Error::msg(format!($msg))
    };
    ($fmt:literal, $($arg:tt)*) => {
        $crate::Error::msg(format!($fmt, $($arg)*))
    };
    ($msg:expr $(,)?) => {
        $crate::Error::msg($msg)
    };
}

/// Early-return with an [`Error`] built like [`anyhow!`].
#[macro_export]
macro_rules! bail {
    ($($t:tt)*) => {
        return Err($crate::anyhow!($($t)*).into())
    };
}

#[cfg(test)]
mod tests {
    use super::*;

    fn io_fail() -> Result<()> {
        std::fs::read("/definitely/not/a/path/anywhere")?;
        Ok(())
    }

    #[test]
    fn question_mark_converts_std_errors() {
        let e = io_fail().unwrap_err();
        assert!(!e.to_string().is_empty());
    }

    #[test]
    fn macros_format() {
        let x = 3;
        let e = anyhow!("bad value {x}");
        assert_eq!(e.to_string(), "bad value 3");
        let e2 = anyhow!("{} and {}", 1, 2);
        assert_eq!(e2.to_string(), "1 and 2");
    }

    #[test]
    fn bail_returns_err() {
        fn f() -> Result<()> {
            bail!("nope {}", 7);
        }
        assert_eq!(f().unwrap_err().to_string(), "nope 7");
    }
}
