//! Bench: packed-ternary CPU matvec vs dense f32 — the §2.1 / Fig. 2b
//! memory-wall realization on this testbed. Decoding one token is a
//! mat*vec per linear layer; the speedup ceiling is the weight-bytes
//! ratio (16x for 2-bit vs f32). Reports realized speedup per size.

use spectra::runtime::HostTensor;
use spectra::ternary::{matmul_dense, matmul_ternary_dense,
                       matmul_ternary_packed, matvec_dense,
                       matvec_ternary_packed, Packed2Bit, PackedMatrix,
                       TernaryTensor};
use spectra::util::bench::{bench, black_box};

fn main() {
    println!("== ternary_matmul: Fig 2b realization (decode mat*vec) ==");
    for (rows, cols) in [(512, 512), (1024, 1024), (2048, 2048)] {
        let w = HostTensor::randn(vec![rows, cols], 0.05, 1);
        let t = TernaryTensor::from_latent(&w, 1);
        let packed = Packed2Bit::pack(&t.states);
        let dense_w = t.dequant();
        let x = HostTensor::randn(vec![1, cols], 1.0, 2).data;

        let d = bench(&format!("dense_f32_matvec_{rows}x{cols}"), || {
            black_box(matvec_dense(&dense_w, &x));
        });
        d.report_throughput("weight-bytes", (rows * cols * 4) as f64);
        let p = bench(&format!("packed2bit_matvec_{rows}x{cols}"), || {
            black_box(matvec_ternary_packed(&packed, rows, cols, &t.scales, &x));
        });
        p.report_throughput("weight-bytes", (rows * cols) as f64 / 4.0);
        println!("  -> realized speedup {:.2}x (bytes ratio 16x, paper's \
                  fp16 ceiling 10x)\n",
                 d.mean_secs() / p.mean_secs());
    }

    println!("== batched matmul (prefill-shaped, m=32) ==");
    let (rows, cols) = (1024, 1024);
    let w = HostTensor::randn(vec![rows, cols], 0.05, 3);
    let t = TernaryTensor::from_latent(&w, 1);
    let dense_w = t.dequant();
    let x = HostTensor::randn(vec![32, cols], 1.0, 4);
    bench("dense_f32_matmul_32x1024x1024", || {
        black_box(matmul_dense(&x, &dense_w));
    }).report();
    bench("ternary_dense_matmul_32x1024x1024", || {
        black_box(matmul_ternary_dense(&x, &t));
    }).report();

    println!("\n== blocked packed matmul (decode-shaped, m=8) ==");
    let pm = PackedMatrix::from_ternary(&t);
    let xb = HostTensor::randn(vec![8, cols], 1.0, 5);
    let base = bench("packed_blocked_matmul_8x1024x1024_t1", || {
        black_box(matmul_ternary_packed(&xb, &pm, 1));
    });
    base.report();
    for threads in [2usize, 4] {
        let r = bench(&format!("packed_blocked_matmul_8x1024x1024_t{threads}"),
                      || {
            black_box(matmul_ternary_packed(&xb, &pm, threads));
        });
        r.report();
        println!("  -> thread scaling {:.2}x over 1 thread",
                 base.mean_secs() / r.mean_secs());
    }
}
