//! Bench: batched threaded decode through the serve engine —
//! (a) a cross-family sweep (FloatLM / QuantLM 3,4-bit / TriLM storage
//! of the *same* latent weights at batch 8: the paper's
//! bits-vs-throughput story on the serving path), then (b) the ternary
//! batch/thread grid against the single-thread scalar reference and
//! the dense f32 twin holding identical weights.
//!
//! Acceptance target: batch-8 threaded ternary >= 3x the single-thread
//! scalar tokens/sec.
//!
//! Also measured: kernel dispatch substrate overhead — the same
//! decode-shaped ternary matmul through per-call scoped threads
//! (spawn/join + fresh buffers every call) vs the persistent
//! [`WorkerPool`] with reused scratch (the scheduler's hot path).

use spectra::runtime::{HostTensor, WorkerPool};
use spectra::serve::{bench_requests, DecodeModel, FamilySpec, LatentAttnLm,
                     LatentLm, LmDims, Scheduler, TernaryLm};
use spectra::ternary::{matmul_ternary_packed, matmul_ternary_packed_into,
                       PackedMatrix, TernaryTensor};
use spectra::util::bench::{bench_few, black_box};

const N_REQUESTS: usize = 24;
const MAX_NEW: usize = 24;

/// One full drain of the request set; returns generated-token count.
fn drain(model: &dyn DecodeModel, batch: usize, threads: usize) -> usize {
    let mut sched = Scheduler::new(model, batch, threads);
    for r in bench_requests(model.dims().vocab, N_REQUESTS, MAX_NEW, 1) {
        sched.submit(r);
    }
    let done = sched.run();
    done.iter().map(|c| c.tokens.len()).sum()
}

fn main() {
    let dims = LmDims { vocab: 512, hidden: 256, glu: 704, layers: 4 };
    println!("== serve_throughput: {} requests x {MAX_NEW} tokens, \
              vocab {} hidden {} glu {} layers {} ==",
             N_REQUESTS, dims.vocab, dims.hidden, dims.glu, dims.layers);
    let (tlm, dlm) = TernaryLm::synthetic_pair(dims.clone(), 2, 1);
    let total_tokens = (N_REQUESTS * MAX_NEW) as f64;

    // Cross-family sweep: same latent weights, same traffic, one
    // storage format per row (group 128 => ragged groups at these dims).
    let latent = LatentLm::synthetic(dims.clone(), 2, 1);
    for fam in ["float", "quant3", "quant4", "ternary"] {
        let spec = FamilySpec::parse(fam, 128).unwrap();
        let model = latent.build(spec).unwrap();
        let r = bench_few(
            &format!("family {} ({:.2} bits/param) batch=8",
                     spec.label(), model.effective_bits_per_param()),
            3, || {
                assert_eq!(drain(model.as_ref(), 8, 2),
                           N_REQUESTS * MAX_NEW);
            });
        r.report_throughput("tokens", total_tokens);
    }

    // Attention serving: the paged KV-cache decode path on the same
    // traffic — measures what real per-token cache growth (reported as
    // kv B/token) costs next to the cache-free decay-state rows above.
    let attn_latent = LatentAttnLm::synthetic(dims.clone(), 4, 2, 1);
    for fam in ["float", "ternary"] {
        let spec = FamilySpec::parse(fam, 128).unwrap();
        let model = attn_latent.build(spec, 8, 16 + MAX_NEW + 1).unwrap();
        let r = bench_few(
            &format!("attn family {} ({:.0} kv B/token) batch=8",
                     spec.label(), model.kv_bytes_per_token()),
            3, || {
                assert_eq!(drain(model.as_ref(), 8, 2),
                           N_REQUESTS * MAX_NEW);
            });
        r.report_throughput("tokens", total_tokens);
    }

    let cores = std::thread::available_parallelism()
        .map(|t| t.get()).unwrap_or(1);
    let thread_counts: Vec<usize> =
        [1usize, 2, 4, 8].into_iter().filter(|&t| t <= cores.max(1)).collect();

    let scalar = bench_few("ternary batch=1 threads=1 (scalar ref)", 3, || {
        assert_eq!(drain(&tlm, 1, 1), N_REQUESTS * MAX_NEW);
    });
    scalar.report_throughput("tokens", total_tokens);
    let scalar_tps = total_tokens / scalar.mean_secs();

    let mut best_b8 = 0.0f64;
    for &threads in &thread_counts {
        for batch in [2usize, 4, 8] {
            let r = bench_few(
                &format!("ternary batch={batch} threads={threads}"), 3, || {
                    drain(&tlm, batch, threads);
                });
            r.report_throughput("tokens", total_tokens);
            if batch == 8 {
                best_b8 = best_b8.max(total_tokens / r.mean_secs());
            }
        }
    }

    let dense = bench_few("dense f32 batch=8 (baseline)", 3, || {
        drain(&dlm, 8, 1);
    });
    dense.report_throughput("tokens", total_tokens);

    // Dispatch-substrate microbench: one decode-shaped matmul
    // (m=8 lanes against the glu x hidden gate projection), scoped
    // spawns vs pooled dispatch. The delta is pure per-call overhead —
    // results are bitwise identical (tests/pool_equivalence.rs).
    let w = HostTensor::randn(vec![dims.glu, dims.hidden], 0.05, 7);
    let pm = PackedMatrix::from_ternary(&TernaryTensor::from_latent(&w, 2));
    let x = HostTensor::randn(vec![8, dims.hidden], 1.0, 8);
    let pool_threads = thread_counts.iter().copied().max().unwrap_or(1);
    let iters = 400;
    let scoped = bench_few(
        &format!("matmul m=8 scoped threads={pool_threads} x{iters}"), 3,
        || {
            for _ in 0..iters {
                black_box(matmul_ternary_packed(&x, &pm, pool_threads));
            }
        });
    scoped.report_throughput("matmuls", iters as f64);
    let pool = WorkerPool::new(pool_threads);
    let mut out_t = Vec::new();
    let mut out = HostTensor::zeros(vec![0, 0]);
    let pooled = bench_few(
        &format!("matmul m=8 pooled threads={pool_threads} x{iters}"), 3,
        || {
            for _ in 0..iters {
                matmul_ternary_packed_into(&x, &pm, &pool, &mut out_t,
                                           &mut out);
                black_box(out.data[0]);
            }
        });
    pooled.report_throughput("matmuls", iters as f64);
    println!("pooled dispatch vs scoped spawn on the decode-step matmul: \
              {:.2}x", scoped.mean_secs() / pooled.mean_secs());

    println!("\nbatch-8 threaded ternary vs single-thread scalar: {:.2}x \
              (target >= 3x; {cores} cores available)",
             best_b8 / scalar_tps);
    println!("batch-8 ternary vs dense f32 batch-8: {:.2}x",
             best_b8 / (total_tokens / dense.mean_secs()));
}
