//! Bench + regeneration check: Table 4 / Fig. 2 / Fig. 21 analytics.
//! These are analytical, so the bench doubles as the regeneration run:
//! it prints the table values alongside the paper's numbers.

use spectra::deploy::{self, SizeFamily};
use spectra::util::bench::{bench, black_box};

fn main() {
    bench("table4_full_regeneration", || {
        black_box(deploy::table4());
    }).report();
    bench("fig2_series", || {
        black_box(deploy::fig2_series());
    }).report();
    bench("fig21_trends", || {
        black_box(deploy::memory_per_tflop_trend());
        black_box(deploy::bandwidth_per_tflop_trend());
    }).report();

    // Regeneration vs paper (Table 4 rows, bits x 1e9).
    println!("\nTable 4 check (ours vs paper):");
    let paper_float = [1.60, 3.05, 6.28, 9.11, 13.34, 18.39, 24.23, 39.38, 63.83];
    let paper_trilm = [0.90, 1.42, 2.11, 2.76, 3.55, 4.42, 5.36, 7.23, 10.76];
    for (fam, paper) in [(SizeFamily::Float, paper_float),
                         (SizeFamily::Ternary, paper_trilm)] {
        print!("{:<10}", fam.label());
        for (row, p) in deploy::PAPER_SUITE.iter().zip(paper.iter()) {
            print!(" {:.2}/{:.2}", row.size_bits(fam) / 1e9, p);
        }
        println!();
    }
}
