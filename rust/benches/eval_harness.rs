//! Bench: the downstream-eval harness (Tables 6/7/9 machinery) — MCQ
//! scoring and perplexity throughput through the AOT eval graph.
//! Requires `make artifacts`; skips gracefully otherwise.

use spectra::config::{Family, TrainConfig};
use spectra::coordinator::Trainer;
use spectra::data::Dataset;
use spectra::eval::{self, Evaluator, TaskKind};
use spectra::runtime::Runtime;
use spectra::util::bench::bench_few;

fn main() {
    let Ok(rt) = Runtime::new("artifacts") else {
        println!("eval_harness: artifacts/ missing, run `make artifacts`");
        return;
    };
    let data = Dataset::build(std::path::Path::new("runs/data"), 400_000, 0)
        .expect("dataset");
    let model = "160k_ternary";
    // Fresh params are fine: we're benching the harness, not the model.
    let trainer = Trainer::new(&rt, model,
                               TrainConfig::for_family(Family::Ternary, 10))
        .expect("trainer");
    let ev = Evaluator::new(&rt, model).expect("evaluator");
    let params = trainer.param_literals();

    let val: Vec<u32> = data.val.iter().take(8 * 129 * 4).cloned().collect();
    bench_few("perplexity_nll_4x8x128tok", 5, || {
        std::hint::black_box(ev.nll(params, &val).unwrap());
    }).report_throughput("tokens", val.len() as f64);

    for kind in [TaskKind::PatternMcq, TaskKind::Cloze, TaskKind::FactRecall] {
        let items = eval::generate(&data.world, kind, 8, 3);
        let r = bench_few(&format!("score_{}_8items", kind.as_str()), 3, || {
            for item in &items {
                std::hint::black_box(
                    ev.score_choices(params, &data.bpe, item).unwrap());
            }
        });
        let choices: usize = items.iter().map(|i| i.choices.len()).sum();
        r.report_throughput("choice-scores", choices as f64);
    }
}
