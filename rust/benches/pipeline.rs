//! Bench: the data substrate — corpus generation, BPE train/encode,
//! batcher throughput. The L3 data pipeline must never be the training
//! bottleneck (§Perf: batcher >> train-step rate).

use spectra::data::{Batcher, Bpe, Generator, World};
use spectra::util::bench::{bench, bench_few, black_box};

fn main() {
    let world = World::new(0);

    bench("corpus_generate_100kchars", || {
        let mut g = Generator::new(&world, 1);
        black_box(g.training_text(100_000));
    }).report_throughput("chars", 100_000.0);

    let mut g = Generator::new(&world, 2);
    let text = g.training_text(200_000);

    bench_few("bpe_train_vocab512_200kchars", 3, || {
        black_box(Bpe::train(&text[..100_000], 512));
    }).report_throughput("chars", 100_000.0);

    let bpe = Bpe::train(&text[..100_000], 512);
    bench("bpe_encode_100kchars", || {
        black_box(bpe.encode(&text[..100_000]));
    }).report_throughput("chars", 100_000.0);

    let tokens = bpe.encode(&text);
    println!("  compression: {:.2} chars/token",
             text.len() as f64 / tokens.len() as f64);

    let mut batcher = Batcher::new(tokens, 8, 128, 0);
    bench("batcher_next_batch_8x129", || {
        black_box(batcher.next_batch());
    }).report_throughput("tokens", (8 * 129) as f64);
}
